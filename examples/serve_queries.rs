//! END-TO-END VALIDATION DRIVER (DESIGN.md §5, EXPERIMENTS.md §E2E).
//!
//! Serves a realistic workload through the full production stack —
//! synthetic AIDS-like database -> staged pipeline (admission -> batcher
//! -> encoder -> executor -> responder) -> AOT-compiled SimGNN on the
//! PJRT runtime — and reports latency, throughput and the per-stage
//! latency split, proving all three layers compose: L1 Pallas kernels
//! and the L2 jax model live inside the HLO artifacts, and L3 (this
//! process) never touches python.
//!
//!     make artifacts && cargo run --release --example serve_queries
//!
//! Flags: --queries N (default 10000, the paper's §5.1 query count),
//!        --engine KINDS (comma-separated EngineKind names, e.g.
//!        xla | native | sim | native,sim for heterogeneous lanes),
//!        --batch-max B, --workers K,
//!        --pipeline-depth D (0 = sequential encode+execute baseline).

use std::collections::HashMap;

use spa_gcn::coordinator::server::{serve_workload, ServeConfig};
use spa_gcn::runtime::EngineKind;

fn main() -> anyhow::Result<()> {
    let mut flags: HashMap<String, String> = HashMap::new();
    let mut iter = std::env::args().skip(1);
    while let Some(a) = iter.next() {
        if let Some(k) = a.strip_prefix("--") {
            flags.insert(k.to_string(), iter.next().unwrap_or_default());
        }
    }
    let get = |k: &str, d: usize| -> usize {
        flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
    };

    let spec = flags.get("engine").cloned().unwrap_or_else(|| "xla".into());
    let engines = EngineKind::parse_list(&spec)?;
    let queries = get("queries", 10_000);
    // Batch sweep first (the Fig. 11 experiment on the real runtime) ...
    println!("== batching sweep on the real {spec} runtime ==");
    for batch_max in [1usize, 4, 16, 64] {
        let cfg = ServeConfig {
            engines: engines.clone(),
            queries: (queries / 8).max(64),
            workers: 1,
            batch_max,
            batch_timeout_us: 200,
            seed: 11,
            ..ServeConfig::default()
        };
        let t = serve_workload(&cfg)?;
        let g = |k: &str| t.get(k).unwrap_or("-").to_string();
        println!(
            "batch_max={batch_max:<3} -> throughput {:>8} q/s, p50 {} ms, p99 {} ms \
             (queue {} / encode {} / execute {} ms)",
            g("throughput (query/s)"),
            g("latency p50 (ms)"),
            g("latency p99 (ms)"),
            g("queue wait mean (ms)"),
            g("encode mean (ms)"),
            g("execute mean (ms)"),
        );
    }

    // ... then the full serving run through the staged pipeline.
    let cfg = ServeConfig {
        engines,
        queries,
        workers: get("workers", 1),
        batch_max: get("batch-max", 64),
        batch_timeout_us: get("batch-timeout-us", 200) as u64,
        seed: 42,
        pipeline_depth: get("pipeline-depth", 2),
        ..ServeConfig::default()
    };
    println!("\n== full serving run: {} queries ==", cfg.queries);
    let report = serve_workload(&cfg)?;
    println!("{}", report.render());
    println!("serve_queries OK (record this table in EXPERIMENTS.md §E2E)");
    Ok(())
}
