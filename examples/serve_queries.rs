//! END-TO-END VALIDATION DRIVER (DESIGN.md §5, EXPERIMENTS.md §E2E).
//!
//! Serves a realistic workload through the full production stack —
//! synthetic AIDS-like database -> admission router -> dynamic batcher ->
//! AOT-compiled SimGNN on the PJRT runtime — and reports latency and
//! throughput, proving all three layers compose: L1 Pallas kernels and
//! the L2 jax model live inside the HLO artifacts, and L3 (this process)
//! never touches python.
//!
//!     make artifacts && cargo run --release --example serve_queries
//!
//! Flags: --queries N (default 10000, the paper's §5.1 query count),
//!        --engine xla|native|sim, --batch-max B, --workers K.

use std::collections::HashMap;

use spa_gcn::coordinator::server::{serve_workload, ServeConfig};

fn main() -> anyhow::Result<()> {
    let mut flags: HashMap<String, String> = HashMap::new();
    let mut iter = std::env::args().skip(1);
    while let Some(a) = iter.next() {
        if let Some(k) = a.strip_prefix("--") {
            flags.insert(k.to_string(), iter.next().unwrap_or_default());
        }
    }
    let get = |k: &str, d: usize| -> usize {
        flags.get(k).and_then(|v| v.parse().ok()).unwrap_or(d)
    };

    let engine = flags.get("engine").cloned().unwrap_or_else(|| "xla".into());
    let queries = get("queries", 10_000);
    // Batch sweep first (the Fig. 11 experiment on the real runtime) ...
    println!("== batching sweep on the real {engine} runtime ==");
    for batch_max in [1usize, 4, 16, 64] {
        let cfg = ServeConfig {
            artifacts_dir: "artifacts".into(),
            engine: engine.clone(),
            queries: (queries / 8).max(64),
            workers: 1,
            batch_max,
            batch_timeout_us: 200,
            seed: 11,
        };
        let t = serve_workload(&cfg)?;
        // rows: scored/rejected/errors/throughput/mean/p50/p95/p99/batch
        let tput = &t.rows[3][1];
        let p50 = &t.rows[5][1];
        let p99 = &t.rows[7][1];
        println!(
            "batch_max={batch_max:<3} -> throughput {tput:>8} q/s, p50 {p50} ms, p99 {p99} ms"
        );
    }

    // ... then the full serving run.
    let cfg = ServeConfig {
        artifacts_dir: "artifacts".into(),
        engine,
        queries,
        workers: get("workers", 1),
        batch_max: get("batch-max", 64),
        batch_timeout_us: get("batch-timeout-us", 200) as u64,
        seed: 42,
    };
    println!("\n== full serving run: {} queries ==", cfg.queries);
    let report = serve_workload(&cfg)?;
    println!("{}", report.render());
    println!("serve_queries OK (record this table in EXPERIMENTS.md §E2E)");
    Ok(())
}
