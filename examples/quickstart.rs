//! Quickstart: load the AOT artifacts, score one graph pair on the PJRT
//! runtime, and cross-check against the independent rust numerics.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! The native numerics run the vectorized kernel layer (DESIGN.md S16;
//! `--no-default-features` selects the scalar reference). To track the
//! scalar-vs-lanes perf of every hot kernel, run
//! `cargo bench --bench kernels` — it rewrites the machine-readable
//! `BENCH_6.json` snapshot; commit the refresh alongside kernel changes.

use std::sync::Arc;

use spa_gcn::coordinator::corpus::Corpus;
use spa_gcn::coordinator::corpus_store::CorpusStore;
use spa_gcn::coordinator::pipeline::PipelineConfig;
use spa_gcn::coordinator::server::{run_replay, serve_workload, ServeConfig};
use spa_gcn::coordinator::trace::{bench_p50_e2e, bench_snapshot, check_bench, Trace};
use spa_gcn::graph::encode::{encode, PackedBatch};
use spa_gcn::graph::generate::{generate, perturb, Family};
use spa_gcn::graph::Graph;
use spa_gcn::net::client::NetClient;
use spa_gcn::net::server::NetServer;
use spa_gcn::net::wire::Response;
use spa_gcn::net::NetConfig;
use spa_gcn::nn::simgnn::simgnn_score;
use spa_gcn::nn::weights::Weights;
use spa_gcn::runtime::native::NativeEngine;
use spa_gcn::runtime::pjrt::XlaEngine;
use spa_gcn::runtime::{Engine, EngineBuilder, EngineKind};
use spa_gcn::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from("artifacts");

    // 1. Load the compiled SimGNN (HLO text -> PJRT executable).
    let mut engine = XlaEngine::load(&artifacts)?;
    println!(
        "loaded SimGNN artifacts on platform '{}' (batch ladder {:?})",
        engine.platform(),
        engine.caps().batch_ladder()
    );
    let cfg = engine.meta().config.clone();

    // 2. Make a query: an AIDS-like molecule and a 6-edit perturbation.
    let mut rng = Rng::new(7);
    let g1 = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    let g2 = perturb(&mut rng, &g1, 6, cfg.n_max, cfg.num_labels);
    println!(
        "graph 1: {} nodes / {} edges; graph 2 (6 edits): {} nodes / {} edges",
        g1.num_nodes(),
        g1.num_edges(),
        g2.num_nodes(),
        g2.num_edges()
    );

    // 3. Encode + score on the accelerator runtime.
    let e1 = encode(&g1, cfg.n_max, cfg.num_labels)?;
    let e2 = encode(&g2, cfg.n_max, cfg.num_labels)?;
    let batch = PackedBatch::pack(&[(e1.clone(), e2.clone())], 1)?;
    let out = engine.score_batch(&batch)?;
    let scores = out.scores;
    println!("PJRT similarity score: {:.6}", scores[0]);
    if let Some(exec) = out.telemetry[0].exec {
        println!(
            "execute telemetry: upload {:.0} µs, device {:.0} µs, download {:.0} µs",
            exec.upload_us, exec.execute_us, exec.download_us
        );
    }

    // 4. Cross-check with the independent rust reference numerics.
    let weights = Weights::load(&cfg, &artifacts)?;
    let native = simgnn_score(&cfg, &weights, &e1, &e2);
    println!("native similarity score: {native:.6}");
    anyhow::ensure!(
        (scores[0] - native).abs() < 1e-4,
        "engines disagree: {} vs {native}",
        scores[0]
    );

    // 5. An identical pair should score strictly higher than the edited one.
    let same = PackedBatch::pack(&[(e1.clone(), e1.clone())], 1)?;
    let same_score = engine.score_batch(&same)?.scores[0];
    println!("identical-pair score:    {same_score:.6}");
    println!(
        "ranking check: identical {} edited pair",
        if same_score > scores[0] { ">" } else { "<= (unexpected)" }
    );

    // 6. One-vs-many corpus search through the embedding cache: build a
    // small molecule corpus, rank it against g1, and ask again — the
    // second query pays zero GCN forwards (the cache holds every
    // embedding; only the NTN+FCN tail runs per candidate).
    let mut native_engine = NativeEngine::load(&artifacts)?;
    let entries: Vec<(u64, Graph)> = (0..16)
        .map(|i| (i, generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels)))
        .collect();
    let corpus = Corpus::build("quickstart-molecules", &entries, cfg.n_max, cfg.num_labels)?;
    let cold = native_engine.score_corpus(&e1, corpus.graphs())?;
    let ranked = corpus.rank(&cold.scores, 3);
    println!("top-3 of {}-graph corpus for graph 1:", corpus.len());
    for (rank, (id, score)) in ranked.iter().enumerate() {
        println!("  #{} corpus graph {id}: {score:.6}", rank + 1);
    }
    let cold_stats = cold.telemetry.embed_cache.expect("native reports cache stats");
    let warm = native_engine.score_corpus(&e1, corpus.graphs())?;
    let warm_stats = warm.telemetry.embed_cache.expect("native reports cache stats");
    println!(
        "gcn forwards: cold query {} (query graph + {} unique corpus graphs), \
         warm repeat {} (all cached)",
        cold_stats.gcn_forwards(),
        corpus.unique_graphs(),
        warm_stats.gcn_forwards()
    );
    anyhow::ensure!(warm_stats.gcn_forwards() == 0, "warm corpus query re-ran the GCN");
    anyhow::ensure!(warm.scores == cold.scores, "cache changed corpus scores");

    // 7. The same scoring over the network front door (DESIGN.md S17).
    // Operationally this is two processes —
    //     spa-gcn serve --listen 127.0.0.1:7700 --engine native
    //     spa-gcn load  --connect 127.0.0.1:7700 --rate 200
    // — here, one in-process server on an ephemeral loopback port. The
    // wire carries f32 scores through JSON losslessly, and the overload
    // layers (token buckets, deadline shed, degraded mode) answer with
    // typed retry-after/error responses when traffic exceeds capacity.
    let server = NetServer::start(
        cfg.clone(),
        vec![EngineBuilder::new(EngineKind::Native, artifacts.clone()).into_factory()],
        PipelineConfig::default(),
        NetConfig::default(),
        vec![],
        "127.0.0.1:0",
    )?;
    server.wait_ready();
    let mut client = NetClient::connect(&server.addr().to_string(), "quickstart")?;
    match client.pair(g1.clone(), g2.clone())?.resp {
        Response::Score { score, degraded } => {
            println!("wire similarity score:   {score:.6} (degraded: {degraded})");
            anyhow::ensure!((score - native).abs() < 1e-4, "wire score diverged from native");
        }
        other => anyhow::bail!("unexpected front-door response: {other:?}"),
    }
    drop(client);
    let metrics = server.finish();
    let net = metrics.net.expect("front-door counters");
    println!(
        "front door: {} accepted, {} throttled, {} shed, {} degraded",
        net.accepted, net.throttled, net.shed_deadline, net.degraded
    );
    // 8. Deterministic workload record/replay + the serving bench
    // snapshot (DESIGN.md S19). Operationally:
    //     spa-gcn serve  --engine native --queries 200 --corpus 64 --record trace.jsonl
    //     spa-gcn replay --trace trace.jsonl --selfcheck --bench-out bench.json
    //     spa-gcn bench-check bench.json --baseline BENCH_10.json
    // Here in-process: record a small corpus-search workload, replay it
    // twice (byte-identical outcome dumps — the CI determinism gate),
    // and read the bench-serving-v1 snapshot off the replay's metrics.
    let trace_path = std::env::temp_dir()
        .join(format!("spa-gcn-quickstart-{}.trace.jsonl", std::process::id()));
    let serve_cfg = ServeConfig {
        engines: vec![EngineKind::Native],
        queries: 24,
        corpus_size: 16,
        topk: 3,
        seed: 7,
        record: Some(trace_path.clone()),
        ..ServeConfig::default()
    };
    serve_workload(&serve_cfg)?;
    let trace =
        Trace::read(&trace_path).map_err(|e| anyhow::anyhow!("reading recorded trace: {e}"))?;
    let replay_cfg = ServeConfig { record: None, ..serve_cfg };
    let (replay_metrics, wall_s, dump) = run_replay(&replay_cfg, &trace, None)?;
    let (_, _, dump2) = run_replay(&replay_cfg, &trace, None)?;
    anyhow::ensure!(dump == dump2, "replay determinism violated: outcome dumps differ");
    let snap = bench_snapshot(&replay_metrics, wall_s, 10, "measured: quickstart step 8");
    check_bench(&snap).map_err(|e| anyhow::anyhow!("bench snapshot schema: {e}"))?;
    println!(
        "record/replay: {} queries recorded, 2 replays byte-identical; \
         bench p50 e2e {:.3} ms, throughput {:.0} q/s",
        trace.len(),
        bench_p50_e2e(&snap).unwrap_or(0.0),
        snap.get("throughput_qps").as_f64().unwrap_or(0.0)
    );
    let _ = std::fs::remove_file(&trace_path);

    // 9. Live corpus + coarse-to-fine cascade over the wire (DESIGN.md
    // S20). Operationally:
    //     spa-gcn serve --listen 127.0.0.1:7700 --engine native --corpus 64
    //     spa-gcn load  --connect 127.0.0.1:7700 --topk 3 --budget 8 --upserts 2
    // Register the step-6 molecules as a live CorpusStore (generation
    // 1), upsert a new molecule through the front door — the response
    // acks the bumped epoch — then ask a budgeted top-k: the coarse
    // stage prunes candidates with integer signal distances before the
    // NTN+FCN tail runs, and the response pins the epoch the query was
    // admitted against.
    let store = Arc::new(CorpusStore::build(
        "quickstart-live",
        &entries,
        cfg.n_max,
        cfg.num_labels,
    )?);
    let server = NetServer::start(
        cfg.clone(),
        vec![EngineBuilder::new(EngineKind::Native, artifacts.clone()).into_factory()],
        PipelineConfig::default(),
        NetConfig::default(),
        vec![Arc::clone(&store)],
        "127.0.0.1:0",
    )?;
    server.wait_ready();
    let mut client = NetClient::connect(&server.addr().to_string(), "quickstart")?;
    let fresh = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    match client.upsert("quickstart-live", 100, fresh)?.resp {
        Response::Mutated { epoch, size } => {
            println!("upsert acked: corpus now {size} candidates at epoch {epoch}");
            anyhow::ensure!(epoch == 2, "first mutation must publish generation 2");
            anyhow::ensure!(size == entries.len() + 1, "upsert must grow the corpus by one");
        }
        other => anyhow::bail!("unexpected upsert response: {other:?}"),
    }
    match client.topk_budgeted("quickstart-live", g1.clone(), 3, 8)?.resp {
        Response::TopK { ranked, epoch, .. } => {
            println!(
                "budgeted top-3 at epoch {epoch} (cheap signals keep 8 of {} candidates):",
                entries.len() + 1
            );
            for (rank, (id, score)) in ranked.iter().enumerate() {
                println!("  #{} corpus graph {id}: {score:.6}", rank + 1);
            }
            anyhow::ensure!(epoch == 2, "response must pin the post-upsert admission epoch");
        }
        other => anyhow::bail!("unexpected top-k response: {other:?}"),
    }
    drop(client);
    let live_metrics = server.finish();
    let live_table = live_metrics.render_table("quickstart live corpus");
    anyhow::ensure!(
        live_table.get("cascade queries").is_some(),
        "budgeted query must leave cascade telemetry"
    );
    println!(
        "cascade telemetry: {} budgeted queries, mean pruned {}",
        live_table.get("cascade queries").unwrap_or_default(),
        live_table.get("cascade pruned mean").unwrap_or_default()
    );

    println!("quickstart OK");
    Ok(())
}
