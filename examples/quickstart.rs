//! Quickstart: load the AOT artifacts, score one graph pair on the PJRT
//! runtime, and cross-check against the independent rust numerics.
//!
//!     make artifacts && cargo run --release --example quickstart

use spa_gcn::graph::encode::{encode, PackedBatch};
use spa_gcn::graph::generate::{generate, perturb, Family};
use spa_gcn::nn::simgnn::simgnn_score;
use spa_gcn::nn::weights::Weights;
use spa_gcn::runtime::pjrt::XlaEngine;
use spa_gcn::runtime::Engine;
use spa_gcn::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from("artifacts");

    // 1. Load the compiled SimGNN (HLO text -> PJRT executable).
    let mut engine = XlaEngine::load(&artifacts)?;
    println!(
        "loaded SimGNN artifacts on platform '{}' (batch ladder {:?})",
        engine.platform(),
        engine.caps().batch_ladder()
    );
    let cfg = engine.meta().config.clone();

    // 2. Make a query: an AIDS-like molecule and a 6-edit perturbation.
    let mut rng = Rng::new(7);
    let g1 = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    let g2 = perturb(&mut rng, &g1, 6, cfg.n_max, cfg.num_labels);
    println!(
        "graph 1: {} nodes / {} edges; graph 2 (6 edits): {} nodes / {} edges",
        g1.num_nodes(),
        g1.num_edges(),
        g2.num_nodes(),
        g2.num_edges()
    );

    // 3. Encode + score on the accelerator runtime.
    let e1 = encode(&g1, cfg.n_max, cfg.num_labels)?;
    let e2 = encode(&g2, cfg.n_max, cfg.num_labels)?;
    let batch = PackedBatch::pack(&[(e1.clone(), e2.clone())], 1)?;
    let out = engine.score_batch(&batch)?;
    let scores = out.scores;
    println!("PJRT similarity score: {:.6}", scores[0]);
    if let Some(exec) = out.telemetry[0].exec {
        println!(
            "execute telemetry: upload {:.0} µs, device {:.0} µs, download {:.0} µs",
            exec.upload_us, exec.execute_us, exec.download_us
        );
    }

    // 4. Cross-check with the independent rust reference numerics.
    let weights = Weights::load(&cfg, &artifacts)?;
    let native = simgnn_score(&cfg, &weights, &e1, &e2);
    println!("native similarity score: {native:.6}");
    anyhow::ensure!(
        (scores[0] - native).abs() < 1e-4,
        "engines disagree: {} vs {native}",
        scores[0]
    );

    // 5. An identical pair should score strictly higher than the edited one.
    let same = PackedBatch::pack(&[(e1.clone(), e1.clone())], 1)?;
    let same_score = engine.score_batch(&same)?.scores[0];
    println!("identical-pair score:    {same_score:.6}");
    println!(
        "ranking check: identical {} edited pair",
        if same_score > scores[0] { ">" } else { "<= (unexpected)" }
    );
    println!("quickstart OK");
    Ok(())
}
