//! Design-space exploration: the profiling step the paper describes in
//! §5.3.2 ("Since it is a highly workload-dependent decision, we employ
//! profiling results for setting each of the parallelization factors").
//!
//! Sweeps the per-layer (SIMD_FT, DF, P) of the sparse architecture on a
//! real AIDS-like workload, reporting kernel time, DSPs and the
//! latency-area product — and prints the Pareto frontier. This is the
//! ablation behind Table 4's +Extended Sparsity row.
//!
//!     cargo run --release --example design_space [--queries N]

use spa_gcn::report::tables::{simulate_workload, Context};
use spa_gcn::sim::config::{ArchConfig, ArchVariant, LayerParams};
use spa_gcn::sim::platform::U280;
use spa_gcn::sim::resources::gcn_resources;

fn main() -> anyhow::Result<()> {
    let queries: usize = std::env::args()
        .skip_while(|a| a != "--queries")
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);
    let ctx = Context::load(std::path::Path::new("artifacts"))?;
    let pairs = ctx.workload(queries, 0xde51);

    println!("sweeping sparse-FT design points on U280 ({queries} queries)...\n");
    println!(
        "{:<28} {:>8} {:>10} {:>12} {:>10}",
        "design (DF/P per layer)", "DSP", "kernel ms", "Kernel*DSP", "bubbles/q"
    );

    let mut results: Vec<(String, f64, f64, f64)> = Vec::new();
    for df1 in [1usize, 2, 4] {
        for df23 in [1usize, 2, 4] {
            for p in [2usize, 4, 8] {
                let mk = |simd: usize, df: usize, p: usize| LayerParams {
                    simd_ft: simd,
                    simd_agg: simd,
                    df,
                    p,
                };
                let arch = ArchConfig {
                    variant: ArchVariant::ExtendedSparsity,
                    layers: [mk(32, df1, p), mk(32, df23, p), mk(16, df23, p)],
                    att_simd: 8,
                    ntn_simd: 8,
                    prune_width: 4,
                };
                let run = simulate_workload(&ctx, &arch, &U280, &pairs);
                let res = gcn_resources(&ctx.cfg, &arch);
                let kdsp = run.kernel_ms * res.dsp;
                let name = format!("DF {df1}/{df23}/{df23}, P {p}");
                println!(
                    "{:<28} {:>8.0} {:>10.4} {:>12.2} {:>10.1}",
                    name, res.dsp, run.kernel_ms, kdsp, run.ft_bubbles_per_query
                );
                results.push((name, res.dsp, run.kernel_ms, kdsp));
            }
        }
    }

    // Pareto frontier on (DSP, kernel_ms).
    println!("\nPareto frontier (no other point is better in both DSP and kernel time):");
    let mut frontier: Vec<&(String, f64, f64, f64)> = Vec::new();
    for r in &results {
        if !results
            .iter()
            .any(|o| o.1 <= r.1 && o.2 <= r.2 && (o.1 < r.1 || o.2 < r.2))
        {
            frontier.push(r);
        }
    }
    frontier.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    for (name, dsp, ms, kdsp) in frontier {
        println!("  {name:<28} DSP {dsp:>5.0}  kernel {ms:.4} ms  Kernel*DSP {kdsp:.2}");
    }
    println!(
        "\npaper's chosen point: DF 2/1/1, P 8/2/2 (their workload profile);\n\
         our simulator's frontier shows the same trade-off the paper describes:\n\
         higher DF wastes PEs on starved FIFOs + RAW bubbles, DF 1-2 is optimal."
    );
    Ok(())
}
