//! Graph-similarity search: the paper's motivating application (§1 —
//! "searching for antivirus chemical compounds is an important step in
//! drug repurposing").
//!
//! Builds a database of small molecules, runs a top-k similarity search
//! with the trained SimGNN (via the native engine), and evaluates the
//! ranking against EXACT GED (the NP-complete ground truth SimGNN
//! approximates) computed by our A* on tiny graphs.
//!
//!     make artifacts && cargo run --release --example ged_search

use spa_gcn::ged::{exact_ged, ged_similarity};
use spa_gcn::graph::dataset::GraphDb;
use spa_gcn::graph::encode::encode;
use spa_gcn::graph::generate::{generate, perturb, Family};
use spa_gcn::runtime::native::NativeEngine;
use spa_gcn::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let engine = NativeEngine::load(std::path::Path::new("artifacts"))?;
    let cfg = engine.config().clone();
    let mut rng = Rng::new(1234);

    // Tiny molecules so exact GED stays tractable (A* is exponential).
    let family = Family::ErdosRenyi { n: 7, p_millis: 250 };
    let db = GraphDb::synthesize(&mut rng, family, 64, cfg.n_max, cfg.num_labels);

    // Query: a perturbed copy of a database entry — its source should rank
    // near the top.
    let source_idx = 17;
    let query = perturb(&mut rng, &db.graphs[source_idx], 1, cfg.n_max, cfg.num_labels);
    let qe = encode(&query, cfg.n_max, cfg.num_labels)?;

    println!(
        "query: {} nodes, {} edges (1 edit from db[{source_idx}])",
        query.num_nodes(),
        query.num_edges()
    );
    println!("scoring against {} database graphs...\n", db.len());

    // SimGNN ranking.
    let mut scored: Vec<(usize, f32)> = db
        .graphs
        .iter()
        .enumerate()
        .map(|(i, g)| {
            let e = encode(g, cfg.n_max, cfg.num_labels).unwrap();
            (i, engine.score_pair(&qe, &e))
        })
        .collect();
    scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());

    // Exact GED ground truth for the top-10 and 10 random others.
    println!(
        "{:<6} {:>12} {:>8} {:>14}",
        "db idx", "SimGNN score", "GED", "exp(-2GED/ΣV)"
    );
    for &(i, s) in scored.iter().take(10) {
        let ged = exact_ged(&query, &db.graphs[i], 3_000_000);
        let (g_str, sim_str) = match ged {
            Some(d) => (
                format!("{d:.0}"),
                format!(
                    "{:.4}",
                    ged_similarity(d, query.num_nodes(), db.graphs[i].num_nodes())
                ),
            ),
            None => ("t/o".into(), "-".into()),
        };
        let marker = if i == source_idx { "  <-- source" } else { "" };
        println!("{i:<6} {s:>12.4} {g_str:>8} {sim_str:>14}{marker}");
    }

    // Ranking quality: Spearman correlation between SimGNN rank and exact
    // GED over a sample.
    let sample: Vec<usize> = (0..db.len()).step_by(4).collect();
    let mut pairs: Vec<(f64, f64)> = Vec::new();
    for &i in &sample {
        if let Some(d) = exact_ged(&query, &db.graphs[i], 3_000_000) {
            let score = scored.iter().find(|(j, _)| *j == i).unwrap().1;
            pairs.push((score as f64, -d)); // higher score should mean lower GED
        }
    }
    let corr = pearson(&pairs);
    let rank_of_source = scored.iter().position(|(i, _)| *i == source_idx).unwrap();
    println!("\nsource graph ranked #{} of {}", rank_of_source + 1, db.len());
    println!("Pearson(score, -GED) over {} pairs: {corr:.3}", pairs.len());
    println!(
        "(SimGNN approximates GED: positive correlation expected; the paper's\n\
         claim is speed — ms-scale scoring vs NP-complete exact search)"
    );

    // Timing contrast: SimGNN vs exact GED on one pair of 8-node graphs.
    let a = generate(&mut rng, Family::ErdosRenyi { n: 8, p_millis: 300 }, 32, 8);
    let b = generate(&mut rng, Family::ErdosRenyi { n: 8, p_millis: 300 }, 32, 8);
    let ea = encode(&a, cfg.n_max, cfg.num_labels)?;
    let eb = encode(&b, cfg.n_max, cfg.num_labels)?;
    let t0 = std::time::Instant::now();
    let _ = engine.score_pair(&ea, &eb);
    let t_nn = t0.elapsed();
    let t1 = std::time::Instant::now();
    let _ = exact_ged(&a, &b, 10_000_000);
    let t_exact = t1.elapsed();
    println!(
        "\nspeed contrast on one 8-node pair: SimGNN {:?} vs exact A* GED {:?} ({}x)",
        t_nn,
        t_exact,
        (t_exact.as_secs_f64() / t_nn.as_secs_f64()).round()
    );
    Ok(())
}

fn pearson(pairs: &[(f64, f64)]) -> f64 {
    let n = pairs.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = pairs.iter().map(|p| p.0).sum::<f64>() / n;
    let my = pairs.iter().map(|p| p.1).sum::<f64>() / n;
    let cov = pairs.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum::<f64>();
    let sx = pairs.iter().map(|p| (p.0 - mx).powi(2)).sum::<f64>().sqrt();
    let sy = pairs.iter().map(|p| (p.1 - my).powi(2)).sum::<f64>().sqrt();
    if sx == 0.0 || sy == 0.0 {
        0.0
    } else {
        cov / (sx * sy)
    }
}
