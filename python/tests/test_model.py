"""L2 correctness: full SimGNN forward — Pallas path vs oracle + invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import ModelConfig
from compile.graphgen import (SmallGraph, make_pair_dataset, perturb,
                              random_connected_graph, to_padded)
from compile.model import init_params, simgnn_batch, simgnn_batch_ref

CFG = ModelConfig()


@pytest.fixture(scope="module")
def params():
    return init_params(CFG)


@pytest.fixture(scope="module")
def pairs():
    rng = np.random.RandomState(42)
    data, y = make_pair_dataset(rng, CFG, 8)
    return tuple(jnp.array(d) for d in data), y


def test_pallas_matches_oracle(params, pairs):
    data, _ = pairs
    got = np.asarray(simgnn_batch(params, CFG, *data))
    want = np.asarray(simgnn_batch_ref(params, CFG, *data))
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)


def test_scores_in_unit_interval(params, pairs):
    data, _ = pairs
    s = np.asarray(simgnn_batch(params, CFG, *data))
    assert np.all(s > 0.0) and np.all(s < 1.0)


def test_batch_equals_loop(params, pairs):
    """Batched execution must equal per-pair execution (batcher invariant)."""
    data, _ = pairs
    full = np.asarray(simgnn_batch(params, CFG, *data))
    for i in range(full.shape[0]):
        one = tuple(d[i:i + 1] for d in data)
        s = np.asarray(simgnn_batch(params, CFG, *one))[0]
        np.testing.assert_allclose(s, full[i], atol=1e-5)


def test_identical_graphs_score_high(params):
    """After training, identical pairs must score near 1 — here we only
    check symmetry + determinism with untrained weights."""
    rng = np.random.RandomState(0)
    g = random_connected_graph(rng, CFG)
    a, h, m = (jnp.array(x[None]) for x in to_padded(g, CFG))
    s1 = float(simgnn_batch(params, CFG, a, h, m, a, h, m)[0])
    s2 = float(simgnn_batch(params, CFG, a, h, m, a, h, m)[0])
    assert s1 == s2


def test_padding_invariance(params):
    """Scoring must not depend on how much padding a graph carries:
    re-encode the same graph with a bigger n_max-style zero tail."""
    rng = np.random.RandomState(1)
    g = SmallGraph(5, [(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)], [1, 2, 3, 4, 5])
    a, h, m = to_padded(g, CFG)
    # Shuffle nothing; instead verify zero rows beyond g.n
    assert np.all(a[g.n:, :] == 0) and np.all(h[g.n:, :] == 0)
    g2 = perturb(rng, g, 2, CFG)
    a2, h2, m2 = to_padded(g2, CFG)
    inputs = tuple(jnp.array(x[None]) for x in (a, h, m, a2, h2, m2))
    s = float(simgnn_batch(params, CFG, *inputs)[0])
    assert 0.0 < s < 1.0


def test_graph_generator_statistics():
    """Generated graphs match published AIDS stats (25.6 nodes, ~27.6 edges)."""
    rng = np.random.RandomState(3)
    ns, ms = [], []
    for _ in range(200):
        g = random_connected_graph(rng, CFG)
        ns.append(g.n)
        ms.append(g.m)
        # connectivity: union-find over edges
        parent = list(range(g.n))

        def find(x):
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for u, v in g.edges:
            parent[find(u)] = find(v)
        assert len({find(i) for i in range(g.n)}) == 1, "graph not connected"
    assert 20 <= np.mean(ns) <= 30
    assert np.mean(ms) >= np.mean(ns)  # edge_factor > 1


def test_perturb_is_bounded():
    rng = np.random.RandomState(4)
    g = random_connected_graph(rng, CFG)
    g2 = perturb(rng, g, 5, CFG)
    assert g2.n <= CFG.n_max
    assert len(g2.labels) == g2.n
    for (u, v) in g2.edges:
        assert 0 <= u < v < g2.n


def test_approx_ged_lower_bound_properties():
    """The random-pair training label: 0 on identical graphs, symmetric,
    and grows with obvious structural differences."""
    from compile.graphgen import approx_ged_lower_bound, random_connected_graph

    rng = np.random.RandomState(17)
    for _ in range(20):
        g1 = random_connected_graph(rng, CFG)
        g2 = random_connected_graph(rng, CFG)
        a = approx_ged_lower_bound(g1, g2)
        b = approx_ged_lower_bound(g2, g1)
        assert a == b, "lower bound must be symmetric"
        assert a >= abs(g1.n - g2.n)
        assert approx_ged_lower_bound(g1, g1) == 0.0


def test_dataset_mixture_has_both_regimes():
    """make_pair_dataset mixes perturbation pairs (similar) and random
    pairs (dissimilar): targets must cover a wide range."""
    rng = np.random.RandomState(23)
    _, y = make_pair_dataset(rng, CFG, 256)
    assert y.max() == 1.0        # k=0 perturbation pairs
    assert y.min() < 0.6         # dissimilar random pairs
    assert np.std(y) > 0.1
