"""AOT path: artifacts exist, parse, and agree with meta.json + weights IO."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import ARTIFACT_BATCH_SIZES, ModelConfig
from compile.model import init_params, simgnn_batch
from compile.weights import load_weights, manifest_entries, save_weights

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "meta.json")),
    reason="run `make artifacts` first",
)


def test_meta_lists_all_artifacts():
    with open(os.path.join(ART, "meta.json")) as f:
        meta = json.load(f)
    names = {a["name"] for a in meta["artifacts"]}
    for b in ARTIFACT_BATCH_SIZES:
        assert f"simgnn_b{b}.hlo.txt" in names
    assert "gcn3_b1.hlo.txt" in names
    for n in names:
        assert os.path.exists(os.path.join(ART, n)), n


def test_hlo_text_well_formed():
    with open(os.path.join(ART, "simgnn_b1.hlo.txt")) as f:
        text = f.read()
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # 6 parameters: a1 h1 m1 a2 h2 m2
    assert text.count("parameter(") >= 6


def test_weights_roundtrip(tmp_path):
    cfg = ModelConfig()
    params = init_params(cfg)
    save_weights(params, cfg, str(tmp_path))
    loaded = load_weights(cfg, str(tmp_path))
    for i in range(3):
        np.testing.assert_array_equal(np.asarray(params["gcn_w"][i]),
                                      np.asarray(loaded["gcn_w"][i]))
    np.testing.assert_array_equal(np.asarray(params["ntn_w"]),
                                  np.asarray(loaded["ntn_w"]))
    np.testing.assert_array_equal(np.asarray(params["out_w"]),
                                  np.asarray(loaded["out_w"]))


def test_manifest_matches_bin_size():
    cfg = ModelConfig()
    with open(os.path.join(ART, "weights.json")) as f:
        doc = json.load(f)
    entries = manifest_entries(cfg)
    assert [t["name"] for t in doc["tensors"]] == [n for n, _ in entries]
    total = sum(int(np.prod(s)) for _, s in entries)
    assert doc["total_floats"] == total
    size = os.path.getsize(os.path.join(ART, "weights.bin"))
    assert size == 4 * total


def test_sparsity_stats_match_paper_shape():
    """§3.4: the paper reports 52%/47% sparsity into GCN layers 2/3; our
    synthetic AIDS-like data should land in the same regime (30-80%)."""
    with open(os.path.join(ART, "meta.json")) as f:
        meta = json.load(f)
    s2 = meta["sparsity"]["layer2_input_sparsity"]
    s3 = meta["sparsity"]["layer3_input_sparsity"]
    assert 0.3 <= s2 <= 0.8, s2
    assert 0.3 <= s3 <= 0.8, s3
    assert meta["sparsity"]["layer1_input_sparsity"] > 0.9  # one-hot


def test_golden_scores_reproducible():
    """Re-running the trained weights on the golden inputs reproduces the
    stored scores (guards against weight/golden drift)."""
    golden_path = os.path.join(os.path.dirname(__file__), "..", "..",
                               "tests", "golden", "simgnn_golden.json")
    with open(golden_path) as f:
        g = json.load(f)
    cfg = ModelConfig.from_json_dict(g["config"])
    params = load_weights(cfg, ART)
    n_pairs = g["num_pairs"]
    n, l = cfg.n_max, cfg.num_labels
    shape = lambda flat, *s: jnp.array(np.array(flat, np.float32).reshape(*s))
    a1 = shape(g["a1"], n_pairs, n, n)
    h1 = shape(g["h1"], n_pairs, n, l)
    m1 = shape(g["m1"], n_pairs, n)
    a2 = shape(g["a2"], n_pairs, n, n)
    h2 = shape(g["h2"], n_pairs, n, l)
    m2 = shape(g["m2"], n_pairs, n)
    scores = np.asarray(simgnn_batch(params, cfg, a1, h1, m1, a2, h2, m2))
    np.testing.assert_allclose(scores, np.array(g["scores"]), atol=1e-5)
