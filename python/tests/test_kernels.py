"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes, sparsity rates and batch sizes — the CORE
correctness signal for the kernels that end up inside the AOT artifacts.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.config import ModelConfig
from compile.kernels import attention_pool, gcn_layer, ntn, ref

CFG = ModelConfig()


def random_graph_tensors(rng, bsz, n, f_in, sparsity=0.0):
    """Random padded (a_norm, h, mask) batch with per-graph real-node count."""
    a = np.zeros((bsz, n, n), np.float32)
    h = rng.randn(bsz, n, f_in).astype(np.float32)
    mask = np.zeros((bsz, n), np.float32)
    for i in range(bsz):
        real = rng.randint(2, n + 1)
        mask[i, :real] = 1.0
        adj = (rng.rand(n, n) < 0.15).astype(np.float32)
        adj = np.maximum(adj, adj.T)
        np.fill_diagonal(adj, 0.0)
        a[i] = np.asarray(
            ref.normalize_adjacency(jnp.array(adj), jnp.array(mask[i])))
    if sparsity > 0:
        h *= (rng.rand(*h.shape) >= sparsity)
    h *= mask[:, :, None]
    return a, h, mask


@settings(max_examples=20, deadline=None)
@given(
    bsz=st.integers(1, 4),
    n=st.sampled_from([4, 8, 16, 32]),
    f_in=st.sampled_from([8, 29, 64]),
    f_out=st.sampled_from([8, 16, 32]),
    relu=st.booleans(),
    sparsity=st.sampled_from([0.0, 0.5, 0.9]),
)
def test_gcn_layer_matches_ref(bsz, n, f_in, f_out, relu, sparsity):
    rng = np.random.RandomState(bsz * 1000 + n * 10 + f_in + f_out)
    a, h, mask = random_graph_tensors(rng, bsz, n, f_in, sparsity)
    w = rng.randn(f_in, f_out).astype(np.float32)
    b = rng.randn(f_out).astype(np.float32)
    got = np.asarray(gcn_layer(jnp.array(a), jnp.array(h), jnp.array(w),
                               jnp.array(b), jnp.array(mask), relu=relu))
    want = np.stack([
        np.asarray(ref.gcn_layer(jnp.array(a[i]), jnp.array(h[i]),
                                 jnp.array(w), jnp.array(b), relu,
                                 jnp.array(mask[i])))
        for i in range(bsz)
    ])
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(bsz=st.integers(1, 4), n=st.sampled_from([4, 16, 32]),
       f=st.sampled_from([8, 16, 32]))
def test_attention_pool_matches_ref(bsz, n, f):
    rng = np.random.RandomState(bsz * 77 + n + f)
    h = rng.randn(bsz, n, f).astype(np.float32)
    mask = np.zeros((bsz, n), np.float32)
    for i in range(bsz):
        mask[i, : rng.randint(1, n + 1)] = 1.0
    h *= mask[:, :, None]
    w = rng.randn(f, f).astype(np.float32)
    got = np.asarray(attention_pool(jnp.array(h), jnp.array(w),
                                    jnp.array(mask)))
    want = np.stack([
        np.asarray(ref.attention_pool(jnp.array(h[i]), jnp.array(w),
                                      jnp.array(mask[i])))
        for i in range(bsz)
    ])
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(bsz=st.integers(1, 4), f=st.sampled_from([4, 16, 32]),
       k=st.sampled_from([1, 8, 16]))
def test_ntn_matches_ref(bsz, f, k):
    rng = np.random.RandomState(bsz + f * 3 + k * 7)
    hg1 = rng.randn(bsz, f).astype(np.float32)
    hg2 = rng.randn(bsz, f).astype(np.float32)
    w = rng.randn(k, f, f).astype(np.float32)
    v = rng.randn(k, 2 * f).astype(np.float32)
    b = rng.randn(k).astype(np.float32)
    got = np.asarray(ntn(jnp.array(hg1), jnp.array(hg2), jnp.array(w),
                         jnp.array(v), jnp.array(b)))
    want = np.stack([
        np.asarray(ref.ntn(jnp.array(hg1[i]), jnp.array(hg2[i]),
                           jnp.array(w), jnp.array(v), jnp.array(b)))
        for i in range(bsz)
    ])
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_gcn_layer_padding_is_inert():
    """Padded rows stay exactly zero through the kernel."""
    rng = np.random.RandomState(0)
    a, h, mask = random_graph_tensors(rng, 2, 32, 29)
    w = rng.randn(29, 16).astype(np.float32)
    b = rng.randn(16).astype(np.float32)
    out = np.asarray(gcn_layer(jnp.array(a), jnp.array(h), jnp.array(w),
                               jnp.array(b), jnp.array(mask), relu=True))
    pad = (1.0 - mask)[:, :, None]
    assert np.all(out * pad == 0.0)


def test_gcn_layer_equals_dense_unmasked():
    """With a full mask the kernel equals the plain dense formula."""
    rng = np.random.RandomState(1)
    n, f_in, f_out = 8, 8, 8
    adj = (rng.rand(n, n) < 0.3).astype(np.float32)
    adj = np.maximum(adj, adj.T)
    np.fill_diagonal(adj, 0.0)
    mask = np.ones(n, np.float32)
    a = np.asarray(ref.normalize_adjacency(jnp.array(adj), jnp.array(mask)))
    h = rng.randn(n, f_in).astype(np.float32)
    w = rng.randn(f_in, f_out).astype(np.float32)
    b = rng.randn(f_out).astype(np.float32)
    out = np.asarray(gcn_layer(jnp.array(a[None]), jnp.array(h[None]),
                               jnp.array(w), jnp.array(b),
                               jnp.array(mask[None]), relu=False))[0]
    want = a @ (h @ w) + b[None, :]
    np.testing.assert_allclose(out, want, atol=1e-4, rtol=1e-4)


def test_normalize_adjacency_symmetric_rows():
    """A' of an undirected graph is symmetric with unit spectral props."""
    rng = np.random.RandomState(5)
    n = 16
    adj = (rng.rand(n, n) < 0.2).astype(np.float32)
    adj = np.maximum(adj, adj.T)
    np.fill_diagonal(adj, 0.0)
    mask = np.ones(n, np.float32)
    a = np.asarray(ref.normalize_adjacency(jnp.array(adj), jnp.array(mask)))
    np.testing.assert_allclose(a, a.T, atol=1e-6)
    # isolated-node-free graph: every diagonal entry is 1/deg~ > 0
    assert np.all(np.diag(a) > 0)
