"""Trainer sanity: loss decreases, Adam state behaves, dataset is balanced."""

import numpy as np

from compile.config import ModelConfig
from compile.graphgen import make_pair_dataset
from compile.train import Adam, train
import jax.numpy as jnp

CFG = ModelConfig()


def test_loss_decreases_short_run():
    params, log = train(CFG, steps=30, num_pairs=128, batch=32,
                        log_every=5, verbose=False, seed=123)
    curve = [e["loss"] for e in log["curve"]]
    assert curve[-1] < curve[0], curve
    assert log["eval_mse"] < 0.25


def test_adam_moves_params_toward_minimum():
    """Minimize f(x) = (x-3)^2 with the hand-rolled Adam."""
    x = {"x": jnp.array([0.0])}
    opt = Adam(x, lr=0.1)
    for _ in range(200):
        g = {"x": 2 * (x["x"] - 3.0)}
        x = opt.step(x, g)
    assert abs(float(x["x"][0]) - 3.0) < 0.1


def test_targets_span_unit_interval():
    rng = np.random.RandomState(9)
    _, y = make_pair_dataset(rng, CFG, 256)
    assert y.min() >= 0.0 and y.max() <= 1.0
    assert (y == 1.0).sum() > 0          # k=0 pairs present
    assert (y < 0.9).sum() > 50          # and plenty of dissimilar ones
