"""AOT compile path: python runs ONCE here, never on the request path.

Emits into artifacts/:
  * simgnn_b{B}.hlo.txt  — full SimGNN pipeline, batch B, weights baked in
  * gcn3_b1.hlo.txt      — GCN stage only (node embeddings), for quickstart
  * weights.bin/json     — trained weights (rust nn/ + simulator consume)
  * meta.json            — config, artifact manifest, sparsity stats
  * train_log.json       — loss curve of the build-time training run
and into tests/golden/:
  * simgnn_golden.json   — deterministic inputs + oracle outputs for rust

Interchange format is HLO TEXT (not .serialize()): jax>=0.5 emits protos
with 64-bit instruction ids that xla_extension 0.5.1 rejects; the HLO text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .config import ARTIFACT_BATCH_SIZES, DEFAULT_CONFIG, ModelConfig
from .graphgen import make_pair_dataset
from .model import gcn_embed, init_params, simgnn_batch, simgnn_batch_ref
from .train import save_log, train
from .weights import load_weights, save_weights


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    assert "constant({...})" not in text, "HLO printer elided constants"
    return text


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_simgnn(params, cfg: ModelConfig, batch: int,
                 fused: bool = False) -> str:
    """Lower the batched SimGNN forward.

    fused=False: the Pallas-kernel path (interpret=True) — faithful L1,
      the artifact a TPU deployment would compile from the same source.
    fused=True: the pure-jnp path (identical math, test-asserted equal) —
      XLA fuses it into batched GEMMs, which is the fast form for the CPU
      PJRT backend (interpret-mode Pallas pays a per-grid-step loop with
      full-tensor updates on CPU). See EXPERIMENTS.md §Perf (L2).
    """
    n, l = cfg.n_max, cfg.num_labels

    def fn(a1, h1, m1, a2, h2, m2):
        if fused:
            return (simgnn_batch_ref(params, cfg, a1, h1, m1, a2, h2, m2),)
        return (simgnn_batch(params, cfg, a1, h1, m1, a2, h2, m2),)

    lowered = jax.jit(fn).lower(
        _spec(batch, n, n), _spec(batch, n, l), _spec(batch, n),
        _spec(batch, n, n), _spec(batch, n, l), _spec(batch, n),
    )
    return to_hlo_text(lowered)


def lower_gcn3(params, cfg: ModelConfig, batch: int) -> str:
    n, l = cfg.n_max, cfg.num_labels

    def fn(a, h, m):
        return (gcn_embed(params, cfg, a, h, m),)

    lowered = jax.jit(fn).lower(_spec(batch, n, n), _spec(batch, n, l),
                                _spec(batch, n))
    return to_hlo_text(lowered)


def measure_sparsity(params, cfg: ModelConfig, num_pairs: int = 64,
                     seed: int = 11) -> dict:
    """§3.4 reproduction: sparsity of the inputs to GCN layers 2 and 3.

    Paper reports 52% / 47% on AIDS-drawn graphs (zeros among the features
    of *real* nodes after ReLU).
    """
    rng = np.random.RandomState(seed)
    (a1, h1, m1, *_), _ = make_pair_dataset(rng, cfg, num_pairs)
    a1, h1, m1 = jnp.array(a1), jnp.array(h1), jnp.array(m1)
    from .kernels import gcn_layer

    stats = {}
    x = h1
    for i in range(3):
        x = gcn_layer(a1, x, params["gcn_w"][i], params["gcn_b"][i], m1,
                      relu=cfg.relu_mask[i])
        real = np.asarray(m1).sum() * x.shape[2]
        zeros = float(((np.asarray(x) == 0.0) * np.asarray(m1)[:, :, None]).sum())
        if i < 2:  # sparsity of input to layer i+2
            stats[f"layer{i + 2}_input_sparsity"] = float(zeros / real)
    h0_real = np.asarray(m1).sum() * h1.shape[2]
    h0_zeros = float(((np.asarray(h1) == 0.0) * np.asarray(m1)[:, :, None]).sum())
    stats["layer1_input_sparsity"] = float(h0_zeros / h0_real)  # one-hot
    return stats


def emit_golden(params, cfg: ModelConfig, path: str, num_pairs: int = 6,
                seed: int = 3) -> None:
    """Deterministic input/output vectors for the rust test-suite."""
    rng = np.random.RandomState(seed)
    data, y = make_pair_dataset(rng, cfg, num_pairs)
    inputs = tuple(jnp.array(d) for d in data)
    scores = np.asarray(simgnn_batch_ref(params, cfg, *inputs))
    scores_pallas = np.asarray(simgnn_batch(params, cfg, *inputs))
    assert np.allclose(scores, scores_pallas, atol=1e-5), "pallas != oracle"
    emb1 = np.asarray(gcn_embed(params, cfg, inputs[0], inputs[1], inputs[2]))
    doc = {
        "config": cfg.to_json_dict(),
        "num_pairs": num_pairs,
        "a1": np.asarray(data[0]).reshape(-1).tolist(),
        "h1": np.asarray(data[1]).reshape(-1).tolist(),
        "m1": np.asarray(data[2]).reshape(-1).tolist(),
        "a2": np.asarray(data[3]).reshape(-1).tolist(),
        "h2": np.asarray(data[4]).reshape(-1).tolist(),
        "m2": np.asarray(data[5]).reshape(-1).tolist(),
        "scores": scores.tolist(),
        "embeddings1": emb1.reshape(-1).tolist(),
        "edit_targets": np.asarray(y).tolist(),
    }
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f)
    print(f"[aot] wrote golden vectors ({num_pairs} pairs) to {path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts dir")
    ap.add_argument("--golden", default="../tests/golden/simgnn_golden.json")
    ap.add_argument("--train-steps", type=int, default=800)
    ap.add_argument("--skip-train", action="store_true",
                    help="use seeded init instead of training")
    ap.add_argument("--reuse-weights", action="store_true",
                    help="load existing weights.bin instead of retraining")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    cfg = DEFAULT_CONFIG
    if args.reuse_weights and os.path.exists(os.path.join(out, "weights.bin")):
        print("[aot] reusing existing weights.bin")
        params = load_weights(cfg, out)
    elif args.skip_train:
        print("[aot] using seeded init (skip-train)")
        params = init_params(cfg)
    else:
        print(f"[aot] training SimGNN for {args.train_steps} steps ...")
        params, log_doc = train(cfg, steps=args.train_steps)
        save_log(log_doc, os.path.join(out, "train_log.json"))

    save_weights(params, cfg, out)
    print("[aot] wrote weights.bin / weights.json")

    artifacts = []
    for b in ARTIFACT_BATCH_SIZES:
        for fused in (False, True):
            text = lower_simgnn(params, cfg, b, fused=fused)
            kind = "simgnn_fused" if fused else "simgnn"
            name = f"{kind}_b{b}.hlo.txt"
            with open(os.path.join(out, name), "w") as f:
                f.write(text)
            artifacts.append({"name": name, "kind": kind, "batch": b,
                              "inputs": ["a1", "h1", "m1", "a2", "h2", "m2"],
                              "outputs": ["scores"]})
            print(f"[aot] wrote {name} ({len(text)} chars)")
    text = lower_gcn3(params, cfg, 1)
    with open(os.path.join(out, "gcn3_b1.hlo.txt"), "w") as f:
        f.write(text)
    artifacts.append({"name": "gcn3_b1.hlo.txt", "kind": "gcn3", "batch": 1,
                      "inputs": ["a", "h", "m"], "outputs": ["embeddings"]})
    print(f"[aot] wrote gcn3_b1.hlo.txt ({len(text)} chars)")

    sparsity = measure_sparsity(params, cfg)
    print(f"[aot] sparsity stats: {sparsity}")

    meta = {
        "config": cfg.to_json_dict(),
        "artifact_batch_sizes": list(ARTIFACT_BATCH_SIZES),
        "artifacts": artifacts,
        "sparsity": sparsity,
        "jax_version": jax.__version__,
    }
    with open(os.path.join(out, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    print("[aot] wrote meta.json")

    emit_golden(params, cfg, args.golden)
    print("[aot] done")


if __name__ == "__main__":
    sys.exit(main())
