"""Weight serialization shared between python (producer) and rust (consumer).

Format:
  * weights.bin  — all tensors as little-endian f32, concatenated in
    MANIFEST order, no header.
  * weights.json — manifest: [{"name", "shape", "offset"}], offset in
    *floats* from the start of the file.

The manifest order is fixed so the rust loader (rust/src/nn/weights.rs)
can also be used without the JSON (defensive double-check: it validates
offsets against shapes).
"""

from __future__ import annotations

import json
import os
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .model import Params


def manifest_entries(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """The fixed (name, shape) manifest for a given config."""
    f3 = cfg.filters[-1]
    k = cfg.ntn_k
    dims_in = [cfg.num_labels, cfg.filters[0], cfg.filters[1]]
    entries: List[Tuple[str, Tuple[int, ...]]] = []
    for i in range(3):
        entries.append((f"gcn_w{i}", (dims_in[i], cfg.filters[i])))
        entries.append((f"gcn_b{i}", (cfg.filters[i],)))
    entries.append(("att_w", (f3, f3)))
    entries.append(("ntn_w", (k, f3, f3)))
    entries.append(("ntn_v", (k, 2 * f3)))
    entries.append(("ntn_b", (k,)))
    d = k
    for i, h in enumerate(cfg.fc_dims):
        entries.append((f"fc_w{i}", (d, h)))
        entries.append((f"fc_b{i}", (h,)))
        d = h
    entries.append(("out_w", (d, 1)))
    entries.append(("out_b", (1,)))
    return entries


def _flatten_in_manifest_order(params: Params, cfg: ModelConfig):
    tensors = []
    for i in range(3):
        tensors.append((f"gcn_w{i}", params["gcn_w"][i]))
        tensors.append((f"gcn_b{i}", params["gcn_b"][i]))
    tensors.append(("att_w", params["att_w"]))
    tensors.append(("ntn_w", params["ntn_w"]))
    tensors.append(("ntn_v", params["ntn_v"]))
    tensors.append(("ntn_b", params["ntn_b"]))
    for i in range(len(cfg.fc_dims)):
        tensors.append((f"fc_w{i}", params["fc_w"][i]))
        tensors.append((f"fc_b{i}", params["fc_b"][i]))
    tensors.append(("out_w", params["out_w"]))
    tensors.append(("out_b", params["out_b"]))
    return tensors


def save_weights(params: Params, cfg: ModelConfig, out_dir: str) -> dict:
    """Write weights.bin + weights.json into out_dir; return the manifest."""
    tensors = _flatten_in_manifest_order(params, cfg)
    expected = manifest_entries(cfg)
    manifest = []
    offset = 0
    blobs = []
    for (name, arr), (exp_name, exp_shape) in zip(tensors, expected):
        assert name == exp_name, (name, exp_name)
        a = np.asarray(arr, dtype=np.float32)
        assert a.shape == tuple(exp_shape), (name, a.shape, exp_shape)
        manifest.append({"name": name, "shape": list(a.shape), "offset": offset})
        offset += a.size
        blobs.append(a.reshape(-1))
    flat = np.concatenate(blobs).astype("<f4")
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        f.write(flat.tobytes())
    doc = {"total_floats": int(offset), "tensors": manifest}
    with open(os.path.join(out_dir, "weights.json"), "w") as f:
        json.dump(doc, f, indent=1)
    return doc


def load_weights(cfg: ModelConfig, out_dir: str) -> Params:
    """Read weights.bin back into a Params dict (inverse of save_weights)."""
    flat = np.fromfile(os.path.join(out_dir, "weights.bin"), dtype="<f4")
    entries = manifest_entries(cfg)
    arrays = {}
    offset = 0
    for name, shape in entries:
        size = int(np.prod(shape))
        arrays[name] = jnp.array(flat[offset:offset + size].reshape(shape))
        offset += size
    assert offset == flat.size, (offset, flat.size)
    params: Params = {
        "gcn_w": [arrays[f"gcn_w{i}"] for i in range(3)],
        "gcn_b": [arrays[f"gcn_b{i}"] for i in range(3)],
        "att_w": arrays["att_w"],
        "ntn_w": arrays["ntn_w"],
        "ntn_v": arrays["ntn_v"],
        "ntn_b": arrays["ntn_b"],
        "fc_w": [arrays[f"fc_w{i}"] for i in range(len(cfg.fc_dims))],
        "fc_b": [arrays[f"fc_b{i}"] for i in range(len(cfg.fc_dims))],
        "out_w": arrays["out_w"],
        "out_b": arrays["out_b"],
    }
    return params
