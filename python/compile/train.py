"""Build-time trainer for SimGNN on synthetic GED pairs.

The paper uses a pre-trained SimGNN (weights from [45]); we cannot download
them, so we train the same model ourselves with jax autodiff on the
synthetic perturbation-pair protocol (graphgen.py). Training goes through
the pure-jnp oracle forward (`simgnn_batch_ref`) because `pallas_call` has
no registered VJP; the Pallas path is inference-only and is asserted equal
to the oracle in python/tests.

Hand-rolled Adam (no optax in this environment). Runs in ~a minute on CPU
for the default 300 steps; the loss curve is logged to
artifacts/train_log.json and summarized in EXPERIMENTS.md.
"""

from __future__ import annotations

import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .graphgen import make_pair_dataset
from .model import Params, init_params, simgnn_batch_ref


def _tree_map2(f, a, b):
    return jax.tree_util.tree_map(f, a, b)


class Adam:
    """Minimal Adam over a jax pytree."""

    def __init__(self, params: Params, lr: float = 1e-3,
                 b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8):
        self.lr, self.b1, self.b2, self.eps = lr, b1, b2, eps
        zeros = lambda p: jax.tree_util.tree_map(jnp.zeros_like, p)
        self.m = zeros(params)
        self.v = zeros(params)
        self.t = 0

    def step(self, params: Params, grads: Params) -> Params:
        self.t += 1
        self.m = _tree_map2(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                            self.m, grads)
        self.v = _tree_map2(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                            self.v, grads)
        mhat_scale = 1.0 / (1 - self.b1 ** self.t)
        vhat_scale = 1.0 / (1 - self.b2 ** self.t)

        def upd(p, m, v):
            return p - self.lr * (m * mhat_scale) / (
                jnp.sqrt(v * vhat_scale) + self.eps)

        return jax.tree_util.tree_map(upd, params, self.m, self.v)


def train(cfg: ModelConfig, steps: int = 300, batch: int = 64,
          num_pairs: int = 2048, lr: float = 2e-3,
          seed: int = 7, log_every: int = 10,
          verbose: bool = True) -> (Params, Dict):
    """Train SimGNN; returns (params, log_dict)."""
    rng = np.random.RandomState(seed)
    data, y = make_pair_dataset(rng, cfg, num_pairs)
    data = tuple(jnp.array(d) for d in data)
    y = jnp.array(y)
    params = init_params(cfg)

    def loss_fn(p, idx):
        batch_in = tuple(d[idx] for d in data)
        pred = simgnn_batch_ref(p, cfg, *batch_in)
        return jnp.mean((pred - y[idx]) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    opt = Adam(params, lr=lr)
    log: List[Dict] = []
    t0 = time.time()
    for step in range(steps):
        idx = jnp.array(rng.randint(0, num_pairs, size=batch))
        loss, grads = grad_fn(params, idx)
        params = opt.step(params, grads)
        if step % log_every == 0 or step == steps - 1:
            entry = {"step": step, "loss": float(loss),
                     "elapsed_s": round(time.time() - t0, 2)}
            log.append(entry)
            if verbose:
                print(f"[train] step {step:4d} loss {float(loss):.6f}")
    # Held-out evaluation on fresh pairs.
    eval_data, eval_y = make_pair_dataset(np.random.RandomState(seed + 1),
                                          cfg, 256)
    pred = simgnn_batch_ref(params, cfg, *(jnp.array(d) for d in eval_data))
    eval_mse = float(jnp.mean((pred - jnp.array(eval_y)) ** 2))
    # Ranking sanity: Spearman-ish — correlation of pred with target.
    p = np.asarray(pred)
    corr = float(np.corrcoef(p, eval_y)[0, 1])
    log_doc = {
        "steps": steps, "batch": batch, "num_pairs": num_pairs, "lr": lr,
        "final_train_loss": log[-1]["loss"], "eval_mse": eval_mse,
        "eval_pearson": corr, "curve": log,
    }
    if verbose:
        print(f"[train] eval mse {eval_mse:.6f} pearson {corr:.4f}")
    return params, log_doc


def save_log(log_doc: Dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(log_doc, f, indent=1)
