"""Shared model/shape configuration for the SPA-GCN SimGNN reproduction.

This is the single source of truth for every static shape that crosses the
python->rust AOT boundary. `aot.py` serializes it into artifacts/meta.json;
the rust side (`rust/src/nn/config.rs`) parses that file and must agree.

Defaults follow the reference SimGNN implementation
(benedekrozemberczki/SimGNN) scaled to the dimensions used throughout the
SPA-GCN paper's discussion of small graphs: three GCN layers, a
global-context attention pooling stage, a neural tensor network with K
similarity slices, and a small fully-connected scorer.
"""

from __future__ import annotations

import dataclasses
import json
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static configuration of the SimGNN pipeline.

    Attributes:
      n_max: padded node count. Graphs with more nodes are rejected by the
        rust router. AIDS graphs have 25.6 nodes on average (paper §5.1),
        so 32 keeps padding waste low while staying MXU/SIMD friendly.
      num_labels: one-hot node-label vocabulary (29 distinct atom types in
        the AIDS antiviral screen dataset as used by SimGNN).
      filters: output feature count of each of the three GCN layers.
      relu_mask: whether each GCN layer ends in ReLU. The paper exploits
        post-ReLU sparsity of the inputs to layers 2 and 3 (52%/47%,
        §3.4), which requires ReLU on layers 1 and 2.
      ntn_k: number of NTN similarity slices (hyper-parameter K in Eq. 4).
      fc_dims: hidden dims of the fully-connected reduction stage; the
        final layer to a scalar + sigmoid is implicit.
    """

    n_max: int = 32
    num_labels: int = 29
    filters: Tuple[int, int, int] = (64, 32, 16)
    relu_mask: Tuple[bool, bool, bool] = (True, True, False)
    ntn_k: int = 16
    fc_dims: Tuple[int, ...] = (16, 8)
    seed: int = 20210521  # arbitrary but fixed: SPA-GCN arXiv submission date

    @property
    def feature_dims(self) -> List[int]:
        """Per-layer input feature dims: [num_labels, f1, f2]."""
        return [self.num_labels, self.filters[0], self.filters[1]]

    @property
    def embed_dim(self) -> int:
        """Graph-level embedding dim F (output of GCN stage / Att)."""
        return self.filters[-1]

    def to_json_dict(self) -> dict:
        return {
            "n_max": self.n_max,
            "num_labels": self.num_labels,
            "filters": list(self.filters),
            "relu_mask": list(self.relu_mask),
            "ntn_k": self.ntn_k,
            "fc_dims": list(self.fc_dims),
            "seed": self.seed,
        }

    @staticmethod
    def from_json_dict(d: dict) -> "ModelConfig":
        return ModelConfig(
            n_max=int(d["n_max"]),
            num_labels=int(d["num_labels"]),
            filters=tuple(d["filters"]),
            relu_mask=tuple(bool(x) for x in d["relu_mask"]),
            ntn_k=int(d["ntn_k"]),
            fc_dims=tuple(d["fc_dims"]),
            seed=int(d["seed"]),
        )

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json_dict(), f, indent=2)

    @staticmethod
    def load(path: str) -> "ModelConfig":
        with open(path) as f:
            return ModelConfig.from_json_dict(json.load(f))


DEFAULT_CONFIG = ModelConfig()

# Batch sizes for which `aot.py` emits a pre-lowered HLO artifact. The rust
# batcher picks the largest artifact <= pending queries and loops.
ARTIFACT_BATCH_SIZES = (1, 4, 16, 64)
