"""Synthetic small-graph generator (python side: training + golden vectors).

The AIDS dataset (42,687 antivirus compounds; 25.6 nodes / 27.6 edges on
average, 29 node labels — paper §5.1) is not downloadable in this
environment, so we generate graphs matching its published statistics:
connected sparse graphs with |E| ≈ 1.08 |V| and a Zipf-skewed label
distribution (chemistry is mostly C/O/N with a long tail).

Training pairs are produced by the standard synthetic-GED protocol: apply
k random edit operations (relabel / edge-insert / edge-delete / node-insert)
to a base graph; k upper-bounds (and for small k tightly approximates) the
GED, and the regression target is the normalized similarity
    sim = exp(-2 k / (|V1| + |V2|))
as in SimGNN. The rust side additionally has an exact A* GED
(rust/src/ged) used to validate this protocol on tiny graphs.
"""

from __future__ import annotations

from typing import List, Tuple

import numpy as np

from .config import ModelConfig
from .kernels.ref import normalize_adjacency
import jax.numpy as jnp


class SmallGraph:
    """Adjacency-set small graph with integer node labels."""

    def __init__(self, n: int, edges: List[Tuple[int, int]], labels: List[int]):
        self.n = n
        self.edges = sorted({(min(u, v), max(u, v)) for u, v in edges if u != v})
        self.labels = list(labels)

    @property
    def m(self) -> int:
        return len(self.edges)


def label_distribution(num_labels: int) -> np.ndarray:
    """Zipf-like skew: p(i) ∝ 1/(i+1)."""
    p = 1.0 / (np.arange(num_labels) + 1.0)
    return p / p.sum()


def random_connected_graph(rng: np.random.RandomState, cfg: ModelConfig,
                           mean_nodes: float = 25.6, std_nodes: float = 5.0,
                           edge_factor: float = 1.08) -> SmallGraph:
    """AIDS-like graph: connected, sparse, labeled."""
    n = int(np.clip(round(rng.normal(mean_nodes, std_nodes)), 4, cfg.n_max))
    edges = []
    # Random spanning tree (random attachment) guarantees connectivity.
    for v in range(1, n):
        edges.append((rng.randint(0, v), v))
    extra = max(0, int(round(n * edge_factor)) - len(edges))
    tries = 0
    eset = set(edges)
    while extra > 0 and tries < 50 * n:
        u, v = rng.randint(0, n), rng.randint(0, n)
        tries += 1
        key = (min(u, v), max(u, v))
        if u != v and key not in eset:
            eset.add(key)
            extra -= 1
    labels = rng.choice(cfg.num_labels, size=n,
                        p=label_distribution(cfg.num_labels)).tolist()
    return SmallGraph(n, sorted(eset), labels)


def perturb(rng: np.random.RandomState, g: SmallGraph, k: int,
            cfg: ModelConfig) -> SmallGraph:
    """Apply k random edit operations; the result stays within n_max nodes."""
    n = g.n
    edges = set(g.edges)
    labels = list(g.labels)
    for _ in range(k):
        op = rng.randint(0, 4)
        if op == 0:  # relabel
            v = rng.randint(0, n)
            labels[v] = int(rng.choice(cfg.num_labels,
                                       p=label_distribution(cfg.num_labels)))
        elif op == 1 and n < cfg.n_max:  # node insert (attached)
            u = rng.randint(0, n)
            labels.append(int(rng.choice(cfg.num_labels,
                                         p=label_distribution(cfg.num_labels))))
            edges.add((u, n))
            n += 1
        elif op == 2:  # edge insert
            for _ in range(10):
                u, v = rng.randint(0, n), rng.randint(0, n)
                key = (min(u, v), max(u, v))
                if u != v and key not in edges:
                    edges.add(key)
                    break
        else:  # edge delete (keep at least a tree's worth of edges)
            if len(edges) > n - 1:
                idx = rng.randint(0, len(edges))
                edges.discard(sorted(edges)[idx])
    return SmallGraph(n, sorted(edges), labels)


def to_padded(g: SmallGraph, cfg: ModelConfig):
    """Dense padded tensors: (A' normalized, one-hot H0, mask)."""
    n = cfg.n_max
    adj = np.zeros((n, n), np.float32)
    for u, v in g.edges:
        adj[u, v] = adj[v, u] = 1.0
    mask = np.zeros(n, np.float32)
    mask[: g.n] = 1.0
    h0 = np.zeros((n, cfg.num_labels), np.float32)
    for i, lab in enumerate(g.labels):
        h0[i, lab] = 1.0
    a_norm = np.asarray(normalize_adjacency(jnp.array(adj), jnp.array(mask)))
    return a_norm, h0, mask


def approx_ged_lower_bound(g1: SmallGraph, g2: SmallGraph) -> float:
    """Cheap label-aware GED lower bound for *random* (non-perturbation)
    pairs: node-count difference + label-multiset mismatch + edge-count
    difference. Admissible (ignores structure), so the similarity target
    it induces is an upper bound — good enough to teach the model that
    random pairs are dissimilar (the exact value is NP-complete)."""
    n_diff = abs(g1.n - g2.n)
    c1 = np.bincount(g1.labels, minlength=64)
    c2 = np.bincount(g2.labels, minlength=64)
    label_mismatch = int(np.abs(c1 - c2).sum() - n_diff) // 2
    m_diff = abs(g1.m - g2.m)
    return float(n_diff + max(label_mismatch, 0) + m_diff)


def make_pair_dataset(rng: np.random.RandomState, cfg: ModelConfig,
                      num_pairs: int, max_edits: int = 12,
                      random_frac: float = 0.35):
    """Batched padded tensors: a mixture of perturbation pairs (edit count
    as GED label, SimGNN's synthetic protocol) and random pairs (labeled
    with a GED lower bound) so targets span the full (0, 1] range."""
    A1 = np.zeros((num_pairs, cfg.n_max, cfg.n_max), np.float32)
    H1 = np.zeros((num_pairs, cfg.n_max, cfg.num_labels), np.float32)
    M1 = np.zeros((num_pairs, cfg.n_max), np.float32)
    A2, H2, M2 = A1.copy(), H1.copy(), M1.copy()
    y = np.zeros(num_pairs, np.float32)
    # Mix of size regimes so the model generalizes from LINUX-sized (~8
    # nodes) to AIDS-sized (~25) graphs — the paper's datasets span 5-50.
    size_means = [8.0, 14.0, 25.6]
    for i in range(num_pairs):
        mean_n = size_means[rng.randint(0, len(size_means))]
        g1 = random_connected_graph(rng, cfg, mean_nodes=mean_n,
                                    std_nodes=max(2.0, mean_n / 5.0))
        if rng.rand() < random_frac:
            g2 = random_connected_graph(rng, cfg, mean_nodes=mean_n,
                                        std_nodes=max(2.0, mean_n / 5.0))
            ged = approx_ged_lower_bound(g1, g2)
        else:
            k = rng.randint(0, max_edits + 1)
            g2 = perturb(rng, g1, k, cfg)
            ged = float(k)
        A1[i], H1[i], M1[i] = to_padded(g1, cfg)
        A2[i], H2[i], M2[i] = to_padded(g2, cfg)
        y[i] = np.exp(-2.0 * ged / (g1.n + g2.n))
    return (A1, H1, M1, A2, H2, M2), y
