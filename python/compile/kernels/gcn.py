"""L1 Pallas kernel: one fused GCN layer (Feature Transformation +
Aggregation + bias + ReLU) over a batch of padded small graphs.

This is the compute hot-spot of the paper (§2.1, §3): per layer
    out = relu(A' @ (H @ W) + b)
with the paper's chosen association A' x (H x W), which keeps both matmuls
sparse-dense (§3, "we have chosen the latter since it results in a fewer
number of operations").

TPU adaptation (DESIGN.md §Hardware-Adaptation): the paper streams H
column-major through DF x SIMD MAC arrays with FIFOs between MULT and ACG
modules. On a TPU the analogous schedule is: keep the whole per-graph
working set (A' 32x32, H 32x64, W 64x64 worst case, ~49 KiB) resident in
VMEM and issue both matmuls back-to-back on the MXU, one grid step per
graph in the batch — the leading grid dimension plays the role of the
paper's query-level parallelism (§5.4.3). Zero-skipping is not profitable
on a systolic MXU, so sparsity exploitation lives in the cycle simulator
(rust/src/sim) that models the FPGA.

The kernel MUST be lowered with interpret=True in this environment: real
TPU lowering emits a Mosaic custom-call the CPU PJRT plugin cannot run.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gcn_layer_kernel(a_ref, h_ref, w_ref, b_ref, m_ref, o_ref, *, relu: bool):
    """Body for one grid step = one graph of the batch.

    Block shapes: a (1,n,n), h (1,n,fin), w (fin,fout), b (fout,),
    m (1,n), o (1,n,fout).
    """
    a = a_ref[0]
    h = h_ref[0]
    w = w_ref[...]
    b = b_ref[...]
    m = m_ref[0]
    # Feature Transformation (paper's MULT + ACC units): X = H @ W.
    x = jnp.dot(h, w, preferred_element_type=jnp.float32)
    # Aggregation (paper's ACG unit): weighted gather over neighbors.
    agg = jnp.dot(a, x, preferred_element_type=jnp.float32)
    # Bias is masked so padded rows remain exactly zero (padding invariant).
    out = agg + m[:, None] * b[None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    else:
        out = out * m[:, None]
    o_ref[0] = out


@functools.partial(jax.jit, static_argnames=("relu", "interpret"))
def gcn_layer(a_norm, h, w, b, mask, relu: bool = True, interpret: bool = True):
    """Batched fused GCN layer.

    Args:
      a_norm: (B, n, n) normalized padded adjacency A'.
      h: (B, n, f_in) node embeddings.
      w: (f_in, f_out) layer weight (shared across the batch — the data
        reuse the paper exploits by caching W on-chip).
      b: (f_out,) bias.
      mask: (B, n) 1.0 for real nodes.
      relu: apply ReLU (layers 1-2 in SimGNN) or just mask (layer 3).

    Returns:
      (B, n, f_out) output embeddings; padded rows are exactly zero.
    """
    bsz, n, f_in = h.shape
    f_out = w.shape[1]
    kernel = functools.partial(_gcn_layer_kernel, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, f_in), lambda i: (i, 0, 0)),
            pl.BlockSpec((f_in, f_out), lambda i: (0, 0)),
            pl.BlockSpec((f_out,), lambda i: (0,)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, f_out), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, n, f_out), jnp.float32),
        interpret=interpret,
    )(a_norm, h, w, b, mask)
