"""L1 Pallas kernel: SimGNN neural tensor network (paper §4.3, Eq. 4).

Per pair of graph embeddings:
    s_k = relu(hG1^T W_k hG2 + V_k . [hG1; hG2] + b_k),  k = 1..K

The paper notes this stage is "a series of fixed-size MVMs" and keeps it
deliberately small; here it is one grid step per pair with the K slices
evaluated as a single (K*F, F) matmul against hG2 followed by a dot with
hG1 — a shape the MXU handles in one pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ntn_kernel(h1_ref, h2_ref, w_ref, v_ref, b_ref, o_ref):
    h1 = h1_ref[0]      # (F,)
    h2 = h2_ref[0]      # (F,)
    w = w_ref[...]      # (K, F, F)
    v = v_ref[...]      # (K, 2F)
    b = b_ref[...]      # (K,)
    k, f, _ = w.shape
    # Bilinear term: fold K into the row dimension for a single MXU pass.
    wh2 = jnp.dot(w.reshape(k * f, f), h2,
                  preferred_element_type=jnp.float32).reshape(k, f)
    bilinear = jnp.dot(wh2, h1, preferred_element_type=jnp.float32)
    linear = jnp.dot(v, jnp.concatenate([h1, h2]),
                     preferred_element_type=jnp.float32)
    o_ref[0] = jnp.maximum(bilinear + linear + b, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ntn(hg1, hg2, w_ntn, v, b, interpret: bool = True):
    """Batched NTN: (B, F) x (B, F) -> (B, K) similarity slices."""
    bsz, f = hg1.shape
    k = w_ntn.shape[0]
    return pl.pallas_call(
        _ntn_kernel,
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, f), lambda i: (i, 0)),
            pl.BlockSpec((1, f), lambda i: (i, 0)),
            pl.BlockSpec((k, f, f), lambda i: (0, 0, 0)),
            pl.BlockSpec((k, 2 * f), lambda i: (0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, k), jnp.float32),
        interpret=interpret,
    )(hg1, hg2, w_ntn, v, b)
