"""L1 Pallas kernels for the SPA-GCN / SimGNN reproduction."""
from . import ref  # noqa: F401
from .att import attention_pool  # noqa: F401
from .gcn import gcn_layer  # noqa: F401
from .ntn import ntn  # noqa: F401
