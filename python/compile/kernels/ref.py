"""Pure-jnp oracle for every Pallas kernel and for the full SimGNN forward.

This file is the CORE correctness anchor of the reproduction:
  * `python/tests/test_kernels.py` sweeps the Pallas kernels against these
    functions with hypothesis;
  * `aot.py` emits golden vectors computed with these functions that the
    independent rust reference (`rust/src/nn/`) and the PJRT runtime are
    both tested against.

Everything here is straight-line jnp on dense padded tensors — no pallas,
no custom control flow — so it is easy to audit against the equations in
the paper (Eq. 1-4).
"""

from __future__ import annotations

import jax.numpy as jnp


def normalize_adjacency(adj: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Eq. 2: A' = D^-1/2 (A + I) D^-1/2, restricted to real (masked) nodes.

    `adj` is a dense padded (n, n) 0/1 matrix, `mask` a (n,) 0/1 vector.
    Padded rows/cols of the result are exactly zero so that padding is
    mathematically inert downstream.
    """
    adj = adj * mask[:, None] * mask[None, :]
    a_tilde = adj + jnp.diag(mask)
    deg = a_tilde.sum(axis=1)
    inv_sqrt = jnp.where(deg > 0, 1.0 / jnp.sqrt(jnp.maximum(deg, 1e-12)), 0.0)
    return a_tilde * inv_sqrt[:, None] * inv_sqrt[None, :]


def gcn_layer(a_norm, h, w, b, relu: bool, mask=None):
    """Eq. 1 with the paper's chosen association A' x (H x W) (§3).

    The bias add is masked so padded rows stay exactly zero (the paper's
    architecture simply never emits padded rows; zero-ness is our padding
    invariant).
    """
    x = h @ w  # Feature Transformation (MULT + ACC)
    agg = a_norm @ x  # Aggregation
    if mask is None:
        out = agg + b[None, :]
    else:
        out = agg + mask[:, None] * b[None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    elif mask is not None:
        out = out * mask[:, None]
    return out


def attention_pool(h, w_att, mask):
    """Eq. 3: global-context attention pooling.

    c   = tanh(W_att . mean_n h_n)            (mean over real nodes)
    a_n = sigmoid(h_n . c)
    h_G = sum_n a_n h_n                        (only real nodes contribute)
    """
    count = jnp.maximum(mask.sum(), 1.0)
    mean = (h * mask[:, None]).sum(axis=0) / count
    c = jnp.tanh(w_att @ mean)
    scores = h @ c
    a = (1.0 / (1.0 + jnp.exp(-scores))) * mask
    return (h * a[:, None]).sum(axis=0)


def ntn(hg1, hg2, w_ntn, v, b):
    """Eq. 4: neural tensor network producing K similarity scores.

    w_ntn: (K, F, F); v: (K, 2F); b: (K,). Activation is ReLU, matching
    the reference SimGNN implementation.
    """
    bilinear = jnp.einsum("f,kfg,g->k", hg1, w_ntn, hg2)
    linear = v @ jnp.concatenate([hg1, hg2])
    return jnp.maximum(bilinear + linear + b, 0.0)


def fcn(s, fc_ws, fc_bs, out_w, out_b):
    """Final fully-connected reduction to a single similarity in (0, 1)."""
    x = s
    for w, b in zip(fc_ws, fc_bs):
        x = jnp.maximum(x @ w + b, 0.0)
    logit = x @ out_w + out_b
    return 1.0 / (1.0 + jnp.exp(-logit))


def gcn_stack(params, a_norm, h0, mask, relu_mask):
    """Three GCN layers -> node embeddings H (n, F)."""
    h = h0
    for i, (w, b) in enumerate(zip(params["gcn_w"], params["gcn_b"])):
        h = gcn_layer(a_norm, h, w, b, relu_mask[i], mask)
    return h


def simgnn_pair(params, a1, h1, m1, a2, h2, m2, relu_mask):
    """Full SimGNN forward on one padded graph pair -> scalar score."""
    e1 = gcn_stack(params, a1, h1, m1, relu_mask)
    e2 = gcn_stack(params, a2, h2, m2, relu_mask)
    hg1 = attention_pool(e1, params["att_w"], m1)
    hg2 = attention_pool(e2, params["att_w"], m2)
    s = ntn(hg1, hg2, params["ntn_w"], params["ntn_v"], params["ntn_b"])
    return fcn(s, params["fc_w"], params["fc_b"], params["out_w"], params["out_b"])[0]
