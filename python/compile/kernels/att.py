"""L1 Pallas kernel: SimGNN global-context attention pooling (paper §4.2).

Per graph (Eq. 3):
    c   = tanh(W_att @ mean_n h_n)     (mean over real nodes only)
    a_n = sigmoid(h_n . c)             (zeroed for padded nodes)
    h_G = sum_n a_n h_n

The paper implements this as a low-area module reusing the MVM adders
(Eq. 5: sum(W_att . H, 2)); here the whole stage is one VMEM-resident
block per graph. We keep the Eq. 5 rewrite in the rust cycle model where
adder reuse matters; numerically both orders agree to f32 round-off and
the oracle (ref.attention_pool) uses the textbook order.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _att_kernel(h_ref, w_ref, m_ref, o_ref):
    h = h_ref[0]          # (n, F)
    w_att = w_ref[...]    # (F, F)
    m = m_ref[0]          # (n,)
    count = jnp.maximum(jnp.sum(m), 1.0)
    mean = jnp.sum(h * m[:, None], axis=0) / count
    c = jnp.tanh(jnp.dot(w_att, mean, preferred_element_type=jnp.float32))
    scores = jnp.dot(h, c, preferred_element_type=jnp.float32)
    a = (1.0 / (1.0 + jnp.exp(-scores))) * m
    o_ref[0] = jnp.sum(h * a[:, None], axis=0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def attention_pool(h, w_att, mask, interpret: bool = True):
    """Batched attention pooling: (B, n, F) -> (B, F) graph embeddings."""
    bsz, n, f = h.shape
    return pl.pallas_call(
        _att_kernel,
        grid=(bsz,),
        in_specs=[
            pl.BlockSpec((1, n, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((f, f), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, f), jnp.float32),
        interpret=interpret,
    )(h, w_att, mask)
