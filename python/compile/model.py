"""L2: the SimGNN compute graph in JAX, calling the L1 Pallas kernels.

Two entry points:
  * `simgnn_batch(params, cfg, ...)`  — batched pair scoring used for the
    AOT artifacts that the rust runtime executes (Pallas kernels inside).
  * `simgnn_batch_ref(...)`           — identical math on the pure-jnp
    oracle (`kernels.ref`), used for training (autodiff does not flow
    through `pallas_call` without a custom VJP) and as the test oracle.

Parameter manifest order is FIXED and shared with rust via
artifacts/weights.json — see weights.py.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .kernels import attention_pool, gcn_layer, ntn, ref

Params = Dict[str, object]


def _glorot(rng: np.random.RandomState, shape) -> np.ndarray:
    fan_in, fan_out = shape[0], shape[-1]
    limit = np.sqrt(6.0 / (fan_in + fan_out))
    return rng.uniform(-limit, limit, size=shape).astype(np.float32)


def init_params(cfg: ModelConfig) -> Params:
    """Deterministic Glorot init from cfg.seed (shared with tests)."""
    rng = np.random.RandomState(cfg.seed)
    f1, f2, f3 = cfg.filters
    k = cfg.ntn_k
    dims_in = [cfg.num_labels, f1, f2]
    dims_out = [f1, f2, f3]
    params: Params = {
        "gcn_w": [jnp.array(_glorot(rng, (i, o))) for i, o in zip(dims_in, dims_out)],
        "gcn_b": [jnp.array(np.zeros(o, np.float32)) for o in dims_out],
        "att_w": jnp.array(_glorot(rng, (f3, f3))),
        "ntn_w": jnp.array(
            np.stack([_glorot(rng, (f3, f3)) for _ in range(k)])
        ),
        "ntn_v": jnp.array(_glorot(rng, (k, 2 * f3))),
        "ntn_b": jnp.array(np.zeros(k, np.float32)),
    }
    fc_ws: List[jnp.ndarray] = []
    fc_bs: List[jnp.ndarray] = []
    d = k
    for h in cfg.fc_dims:
        fc_ws.append(jnp.array(_glorot(rng, (d, h))))
        fc_bs.append(jnp.array(np.zeros(h, np.float32)))
        d = h
    params["fc_w"] = fc_ws
    params["fc_b"] = fc_bs
    params["out_w"] = jnp.array(_glorot(rng, (d, 1)))
    params["out_b"] = jnp.array(np.zeros(1, np.float32))
    return params


def _fcn_batch(params: Params, s: jnp.ndarray) -> jnp.ndarray:
    """Final FC reduction, batched: (B, K) -> (B,) similarity in (0,1)."""
    x = s
    for w, b in zip(params["fc_w"], params["fc_b"]):
        x = jnp.maximum(x @ w + b[None, :], 0.0)
    logit = (x @ params["out_w"] + params["out_b"])[:, 0]
    return 1.0 / (1.0 + jnp.exp(-logit))


def gcn_embed(params: Params, cfg: ModelConfig, a, h, m,
              interpret: bool = True) -> jnp.ndarray:
    """The GCN stage (paper §3): 3 fused Pallas layers -> (B, n, F)."""
    x = h
    for i in range(3):
        x = gcn_layer(a, x, params["gcn_w"][i], params["gcn_b"][i], m,
                      relu=cfg.relu_mask[i], interpret=interpret)
    return x


def simgnn_batch(params: Params, cfg: ModelConfig,
                 a1, h1, m1, a2, h2, m2, interpret: bool = True) -> jnp.ndarray:
    """Full SimGNN pipeline on B padded pairs -> (B,) scores.

    Mirrors the paper's stage structure (Fig. 7): GCN x3 -> Att -> NTN ->
    FCN. The two graphs share the GCN/Att weights exactly as the paper's
    accelerator reuses one GCN module for both graphs of a query (§4.2).
    """
    e1 = gcn_embed(params, cfg, a1, h1, m1, interpret)
    e2 = gcn_embed(params, cfg, a2, h2, m2, interpret)
    hg1 = attention_pool(e1, params["att_w"], m1, interpret=interpret)
    hg2 = attention_pool(e2, params["att_w"], m2, interpret=interpret)
    s = ntn(hg1, hg2, params["ntn_w"], params["ntn_v"], params["ntn_b"],
            interpret=interpret)
    return _fcn_batch(params, s)


def simgnn_pair_ref(params: Params, cfg: ModelConfig, a1, h1, m1, a2, h2, m2):
    """Single-pair oracle forward (differentiable; used by train.py)."""
    return ref.simgnn_pair(params, a1, h1, m1, a2, h2, m2, cfg.relu_mask)


def simgnn_batch_ref(params: Params, cfg: ModelConfig, a1, h1, m1, a2, h2, m2):
    """Batched oracle forward via vmap (differentiable)."""
    fn = lambda A1, H1, M1, A2, H2, M2: simgnn_pair_ref(
        params, cfg, A1, H1, M1, A2, H2, M2)
    return jax.vmap(fn)(a1, h1, m1, a2, h2, m2)
