//! Front-door end-to-end tests over real loopback sockets.
//!
//! No artifacts needed: lanes run `NativeEngine` with synthetic weights,
//! which is bit-deterministic — the acceptance test can demand that a
//! score served over the wire is bit-identical to the same query
//! submitted in-process. The overload tests drive the server past its
//! admission capacity and assert the typed taxonomy: throttled clients
//! get `retry_after_ms`, queue depth stays bounded, degraded responses
//! are marked, and a disconnecting or slow-reading client never stalls
//! siblings or leaks its connection slot.

use std::collections::HashMap;
use std::io::Write;
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use spa_gcn::coordinator::corpus::Corpus;
use spa_gcn::coordinator::corpus_store::CorpusStore;
use spa_gcn::coordinator::pipeline::{Pipeline, PipelineConfig};
use spa_gcn::coordinator::query::{Outcome, Query};
use spa_gcn::ged::ged_similarity;
use spa_gcn::ged::heuristics::greedy_ged;
use spa_gcn::graph::dataset::GraphDb;
use spa_gcn::graph::generate::{generate, Family};
use spa_gcn::graph::Graph;
use spa_gcn::net::client::{run_load, LoadConfig, NetClient};
use spa_gcn::net::server::NetServer;
use spa_gcn::net::wire::{write_frame, Response};
use spa_gcn::net::NetConfig;
use spa_gcn::nn::config::ModelConfig;
use spa_gcn::nn::weights::Weights;
use spa_gcn::runtime::native::NativeEngine;
use spa_gcn::runtime::{Engine, EngineFactory};
use spa_gcn::util::rng::Rng;

fn model() -> ModelConfig {
    ModelConfig {
        n_max: 8,
        num_labels: 4,
        ..ModelConfig::default()
    }
}

fn native_factory(cfg: &ModelConfig) -> EngineFactory {
    let cfg = cfg.clone();
    Arc::new(move || {
        Ok(Box::new(NativeEngine::new(cfg.clone(), Weights::synthetic(&cfg, 2024)))
            as Box<dyn Engine>)
    })
}

/// A front door that never throttles, sheds, or degrades: overload
/// layers out of the way so functional tests see pure scoring.
fn generous_net() -> NetConfig {
    NetConfig {
        refill_per_s: 1e9,
        burst: 1e9,
        deadline_ms: 60_000,
        degrade_hi: 1e9,
        degrade_lo: 1e9,
        ..NetConfig::default()
    }
}

fn pairs(cfg: &ModelConfig, seed: u64, count: usize) -> Vec<(Graph, Graph)> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| {
            (
                generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels),
                generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels),
            )
        })
        .collect()
}

fn start_server(ncfg: NetConfig, corpora: Vec<Arc<Corpus>>) -> NetServer {
    let cfg = model();
    // Wrap pre-built corpora store-shaped: the front door serves epoch
    // snapshots, never bare corpora.
    let stores = corpora
        .into_iter()
        .map(|c| Arc::new(CorpusStore::adopt(c)))
        .collect();
    let server = NetServer::start(
        cfg.clone(),
        vec![native_factory(&cfg)],
        PipelineConfig::default(),
        ncfg,
        stores,
        "127.0.0.1:0",
    )
    .expect("server binds loopback");
    assert_eq!(server.wait_ready(), 1, "native lane must construct");
    server
}

/// Poll until `cond` holds or the timeout passes; avoids sleeps sized
/// to the slowest CI machine.
fn eventually(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < timeout {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    cond()
}

#[test]
fn wire_pair_scores_bit_identical_to_in_process() {
    let cfg = model();
    let workload = pairs(&cfg, 71, 12);

    // In-process baseline: same engine recipe, scores collected via the
    // responder tap.
    let collected: Arc<Mutex<HashMap<u64, f32>>> = Arc::new(Mutex::new(HashMap::new()));
    let tap = {
        let collected = Arc::clone(&collected);
        Arc::new(move |r: &spa_gcn::coordinator::query::QueryResult| {
            if let Outcome::Score(s) = r.outcome {
                collected.lock().unwrap().insert(r.id, s);
            }
        }) as spa_gcn::coordinator::pipeline::ResultTap
    };
    let pipeline = Pipeline::start_with_tap(
        cfg.clone(),
        vec![native_factory(&cfg)],
        PipelineConfig::default(),
        Some(tap),
    );
    pipeline.wait_ready();
    for (i, (g1, g2)) in workload.iter().enumerate() {
        pipeline.submit(Query::new(i as u64, g1.clone(), g2.clone()));
    }
    pipeline.finish();
    let baseline = collected.lock().unwrap().clone();
    assert_eq!(baseline.len(), workload.len());

    // Same pairs over the wire.
    let server = start_server(generous_net(), vec![]);
    let addr = server.addr().to_string();
    let mut client = NetClient::connect(&addr, "bitident").unwrap();
    for (i, (g1, g2)) in workload.iter().enumerate() {
        let frame = client.pair(g1.clone(), g2.clone()).unwrap();
        match frame.resp {
            Response::Score { score, degraded } => {
                assert!(!degraded, "generous config must not degrade");
                assert_eq!(
                    score.to_bits(),
                    baseline[&(i as u64)].to_bits(),
                    "pair {i}: wire {} != in-process {}",
                    score,
                    baseline[&(i as u64)]
                );
            }
            other => panic!("pair {i}: unexpected response {other:?}"),
        }
    }
    drop(client);
    let metrics = server.finish();
    let net = metrics.net.expect("front-door counters attached");
    assert_eq!(net.accepted, workload.len() as u64);
    assert_eq!((net.throttled, net.shed_deadline, net.degraded), (0, 0, 0));
}

#[test]
fn wire_topk_matches_in_process_ranking() {
    let cfg = model();
    let mut rng = Rng::new(303);
    let db = GraphDb::synthesize(&mut rng, Family::Aids, 16, cfg.n_max, cfg.num_labels);
    let corpus = Arc::new(Corpus::from_db("aids-synth", &db, cfg.n_max, cfg.num_labels).unwrap());
    let query = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    let k = 5;

    let collected: Arc<Mutex<Option<Vec<(u64, f32)>>>> = Arc::new(Mutex::new(None));
    let tap = {
        let collected = Arc::clone(&collected);
        Arc::new(move |r: &spa_gcn::coordinator::query::QueryResult| {
            if let Outcome::TopK(ranked) = &r.outcome {
                *collected.lock().unwrap() = Some(ranked.clone());
            }
        }) as spa_gcn::coordinator::pipeline::ResultTap
    };
    let pipeline = Pipeline::start_with_tap(
        cfg.clone(),
        vec![native_factory(&cfg)],
        PipelineConfig::default(),
        Some(tap),
    );
    pipeline.wait_ready();
    pipeline.submit(Query::topk(0, query.clone(), Arc::clone(&corpus), k));
    pipeline.finish();
    let baseline = collected.lock().unwrap().clone().expect("top-k scored");

    let server = start_server(generous_net(), vec![Arc::clone(&corpus)]);
    let addr = server.addr().to_string();
    let mut client = NetClient::connect(&addr, "topk").unwrap();
    let (n_max, num_labels, corpora) = client.hello().unwrap();
    assert_eq!((n_max, num_labels), (cfg.n_max, cfg.num_labels));
    assert_eq!(corpora, vec!["aids-synth".to_string()]);
    match client.topk("aids-synth", query, k).unwrap().resp {
        Response::TopK {
            ranked,
            degraded,
            epoch,
        } => {
            assert!(!degraded);
            assert_eq!(epoch, 0, "adopted standalone corpus keeps its epoch (0)");
            assert_eq!(ranked.len(), baseline.len());
            for (wire, base) in ranked.iter().zip(&baseline) {
                assert_eq!(wire.0, base.0, "candidate order must match");
                assert_eq!(wire.1.to_bits(), base.1.to_bits(), "scores bit-identical");
            }
        }
        other => panic!("unexpected top-k response {other:?}"),
    }
    // Unknown corpus ids get a typed error, not a hang or a panic.
    let g = generate(&mut Rng::new(1), Family::Aids, cfg.n_max, cfg.num_labels);
    match client.topk("no-such-corpus", g, 3).unwrap().resp {
        Response::Error { code, .. } => assert_eq!(code, "unknown_corpus"),
        other => panic!("unexpected response {other:?}"),
    }
    drop(client);
    server.finish();
}

#[test]
fn wire_mutations_swap_epochs_and_budgeted_topk_prunes() {
    let cfg = model();
    let mut rng = Rng::new(505);
    let db = GraphDb::synthesize(&mut rng, Family::Aids, 12, cfg.n_max, cfg.num_labels);
    let corpus = Arc::new(Corpus::from_db("aids-synth", &db, cfg.n_max, cfg.num_labels).unwrap());
    let server = start_server(generous_net(), vec![corpus]);
    let addr = server.addr().to_string();
    let mut client = NetClient::connect(&addr, "mutator").unwrap();

    // Upsert a fresh candidate: the adopted generation-0 corpus swaps
    // to generation 1 with one more entry.
    let g = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    match client.upsert("aids-synth", 100, g.clone()).unwrap().resp {
        Response::Mutated { epoch, size } => assert_eq!((epoch, size), (1, 13)),
        other => panic!("unexpected upsert response {other:?}"),
    }
    // Fingerprint-identical upsert: dedup no-op, no epoch bump.
    match client.upsert("aids-synth", 100, g).unwrap().resp {
        Response::Mutated { epoch, size } => assert_eq!((epoch, size), (1, 13)),
        other => panic!("unexpected dedup response {other:?}"),
    }
    // Queries admitted after the swap are pinned to the new epoch, and
    // a budget caps how deep the fine stage ranks.
    let q = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    match client.topk_budgeted("aids-synth", q, 3, 4).unwrap().resp {
        Response::TopK { ranked, epoch, .. } => {
            assert_eq!(epoch, 1, "response pinned to the admission snapshot");
            assert!(!ranked.is_empty() && ranked.len() <= 3);
        }
        other => panic!("unexpected budgeted response {other:?}"),
    }
    // Remove swaps again; removing an id the store never held is an
    // acknowledged no-op at the same epoch.
    match client.remove("aids-synth", 100).unwrap().resp {
        Response::Mutated { epoch, size } => assert_eq!((epoch, size), (2, 12)),
        other => panic!("unexpected remove response {other:?}"),
    }
    match client.remove("aids-synth", 100).unwrap().resp {
        Response::Mutated { epoch, size } => assert_eq!((epoch, size), (2, 12)),
        other => panic!("unexpected no-op remove response {other:?}"),
    }
    // Mutations against unknown corpora answer typed, like queries.
    let g2 = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    match client.upsert("no-such-corpus", 1, g2).unwrap().resp {
        Response::Error { code, .. } => assert_eq!(code, "unknown_corpus"),
        other => panic!("unexpected response {other:?}"),
    }
    drop(client);
    let metrics = server.finish();
    let t = metrics.render_table("mutations");
    assert_eq!(t.get("cascade queries"), Some("1"), "{}", t.render());
}

#[test]
fn overload_throttles_with_retry_after_and_bounded_queue() {
    // Tight budget: 2-token burst, 1 token/s refill — a back-to-back
    // burst of 40 gets a couple of scores and a pile of retry-afters.
    let ncfg = NetConfig {
        refill_per_s: 1.0,
        burst: 2.0,
        admit_cap: 8,
        deadline_ms: 60_000,
        degrade_hi: 1e9,
        degrade_lo: 1e9,
        ..NetConfig::default()
    };
    let admit_cap = ncfg.admit_cap;
    let cfg = model();
    let server = start_server(ncfg, vec![]);
    let addr = server.addr().to_string();
    let mut client = NetClient::connect(&addr, "burster").unwrap();
    let workload = pairs(&cfg, 5, 40);
    let (mut scored, mut throttled) = (0u64, 0u64);
    for (g1, g2) in workload {
        match client.pair(g1, g2).unwrap().resp {
            Response::Score { .. } => scored += 1,
            Response::Throttled { retry_after_ms } => {
                assert!(retry_after_ms >= 1, "retry hint must be actionable");
                throttled += 1;
            }
            other => panic!("unexpected response {other:?}"),
        }
    }
    assert!(scored >= 2, "burst allowance must admit, got {scored}");
    assert!(throttled >= 30, "tight bucket must throttle, got {throttled}");
    drop(client);
    let metrics = server.finish();
    let net = metrics.net.unwrap();
    assert_eq!(net.accepted, scored);
    assert_eq!(net.throttled, throttled);
    // No unbounded queue growth: the admission channel's peak depth is
    // pinned by its capacity (plus transiently mid-send producers).
    let admit = metrics
        .channels
        .iter()
        .find(|c| c.name == "net.admit")
        .expect("net.admit snapshot attached");
    assert!(
        admit.max_depth <= admit_cap + 1,
        "admission queue grew past its bound: {} > {}",
        admit.max_depth,
        admit_cap
    );
}

#[test]
fn load_tool_drives_front_door_end_to_end() {
    let server = start_server(generous_net(), vec![]);
    let addr = server.addr().to_string();
    let table = run_load(&LoadConfig {
        connect: addr,
        clients: 2,
        rate_qps: 500.0,
        queries: 30,
        seed: 9,
        topk: 0,
    })
    .unwrap();
    assert_eq!(table.get("sent"), Some("30"), "{}", table.render());
    assert_eq!(table.get("scored ok"), Some("30"), "{}", table.render());
    assert_eq!(table.get("throttled"), Some("0"), "{}", table.render());
    assert_eq!(table.get("io errors"), Some("0"), "{}", table.render());
    let metrics = server.finish();
    assert_eq!(metrics.net.unwrap().accepted, 30);
}

#[test]
fn degraded_mode_falls_back_to_ged_and_shrinks_k() {
    // hi = lo = -1 keeps the EWMA signal permanently engaged: the
    // degraded path itself is under test, not the hysteresis (that has
    // its own unit tests).
    let ncfg = NetConfig {
        degrade_hi: -1.0,
        degrade_lo: -1.0,
        degraded_topk: 3,
        refill_per_s: 1e9,
        burst: 1e9,
        deadline_ms: 60_000,
        ..NetConfig::default()
    };
    let cfg = model();
    let mut rng = Rng::new(404);
    let db = GraphDb::synthesize(&mut rng, Family::Aids, 8, cfg.n_max, cfg.num_labels);
    let corpus = Arc::new(Corpus::from_db("aids-synth", &db, cfg.n_max, cfg.num_labels).unwrap());
    let server = start_server(ncfg, vec![Arc::clone(&corpus)]);
    let addr = server.addr().to_string();
    let mut client = NetClient::connect(&addr, "degraded").unwrap();

    // Pair queries answer from the GED-bound heuristic, marked degraded.
    let g1 = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    let g2 = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    let expected =
        ged_similarity(greedy_ged(&g1, &g2), g1.num_nodes(), g2.num_nodes()) as f32;
    match client.pair(g1, g2).unwrap().resp {
        Response::Score { score, degraded } => {
            assert!(degraded, "degraded flag must be recorded on the response");
            assert_eq!(score.to_bits(), expected.to_bits());
        }
        other => panic!("unexpected response {other:?}"),
    }

    // Top-k depth shrinks to degraded_topk.
    let q = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    match client.topk("aids-synth", q, 7).unwrap().resp {
        Response::TopK { ranked, degraded, .. } => {
            assert!(degraded);
            assert_eq!(ranked.len(), 3, "k must shrink to degraded_topk");
        }
        other => panic!("unexpected response {other:?}"),
    }
    drop(client);
    let metrics = server.finish();
    assert!(metrics.net.unwrap().degraded >= 2);
    // The degraded rows surface in the rendered report.
    let t = metrics.render_table("degraded");
    let row: u64 = t.get("degraded responses").unwrap().parse().unwrap();
    assert!(row >= 2, "{}", t.render());
}

#[test]
fn oversized_wire_graphs_rejected_in_degraded_mode_not_ged_scored() {
    // Forced-degraded front door: the degraded pair lane runs greedy
    // GED *on the front-stage thread*. A wire graph past the model's
    // n_max (the wire codec allows up to MAX_WIRE_NODES=4096) must be
    // rejected by the front stage's shape gate, never handed to the
    // O(n^3) fallback — and never earn a fabricated score for a query
    // the engine path would reject with TooManyNodes.
    let ncfg = NetConfig {
        degrade_hi: -1.0,
        degrade_lo: -1.0,
        refill_per_s: 1e9,
        burst: 1e9,
        deadline_ms: 60_000,
        ..NetConfig::default()
    };
    let cfg = model();
    let server = start_server(ncfg, vec![]);
    let addr = server.addr().to_string();
    let mut client = NetClient::connect(&addr, "oversize").unwrap();

    // 16 nodes against n_max = 8: decodes fine, must be rejected.
    let big = Graph::new(16, (0..15u16).map(|i| (i, i + 1)).collect(), vec![0u16; 16]);
    let small = generate(&mut Rng::new(1), Family::Aids, cfg.n_max, cfg.num_labels);
    match client.pair(big, small.clone()).unwrap().resp {
        Response::Error { code, detail } => {
            assert_eq!(code, "rejected", "oversized pair must reject, got {detail}");
        }
        other => panic!("oversized pair not rejected: {other:?}"),
    }
    // Label arity outside the model is the same gate.
    let bad_label = Graph::new(2, vec![(0, 1)], vec![cfg.num_labels as u16, 0]);
    match client.pair(bad_label, small.clone()).unwrap().resp {
        Response::Error { code, .. } => assert_eq!(code, "rejected"),
        other => panic!("out-of-range label not rejected: {other:?}"),
    }
    // A shape-valid pair still flows through the degraded GED lane.
    let g2 = generate(&mut Rng::new(2), Family::Aids, cfg.n_max, cfg.num_labels);
    match client.pair(small, g2).unwrap().resp {
        Response::Score { degraded, .. } => assert!(degraded),
        other => panic!("valid degraded pair failed: {other:?}"),
    }
    drop(client);
    server.finish();
}

#[test]
fn oversized_topk_graph_rejected_at_front_stage() {
    let cfg = model();
    let mut rng = Rng::new(71);
    let db = GraphDb::synthesize(&mut rng, Family::Aids, 8, cfg.n_max, cfg.num_labels);
    let corpus = Arc::new(Corpus::from_db("aids-synth", &db, cfg.n_max, cfg.num_labels).unwrap());
    let server = start_server(generous_net(), vec![corpus]);
    let addr = server.addr().to_string();
    let mut client = NetClient::connect(&addr, "oversize-topk").unwrap();
    let big = Graph::new(cfg.n_max + 1, vec![], vec![0u16; cfg.n_max + 1]);
    match client.topk("aids-synth", big, 3).unwrap().resp {
        Response::Error { code, .. } => assert_eq!(code, "rejected"),
        other => panic!("oversized top-k graph not rejected: {other:?}"),
    }
    drop(client);
    server.finish();
}

#[test]
fn idle_connection_is_closed_and_frees_its_slot() {
    // A connection that never sends a frame must not hold a conn-cap
    // slot forever: 64 silent TCP connects would otherwise pin the
    // default cap and every later client would be answered "busy".
    let ncfg = NetConfig {
        idle_timeout_ms: 200,
        conn_cap: 2,
        ..generous_net()
    };
    let cfg = model();
    let server = start_server(ncfg, vec![]);
    let addr = server.addr().to_string();
    let silent = TcpStream::connect(&addr).unwrap();
    assert!(
        eventually(Duration::from_secs(5), || server.active_connections() == 1),
        "silent connection not registered"
    );
    // The peer stays connected but idle: the server must close it.
    assert!(
        eventually(Duration::from_secs(10), || server.active_connections() == 0),
        "idle connection still holds its slot: {} active",
        server.active_connections()
    );
    // The front door still serves a real client afterwards.
    let (g1, g2) = pairs(&cfg, 17, 1).remove(0);
    let mut client = NetClient::connect(&addr, "after-idle").unwrap();
    match client.pair(g1, g2).unwrap().resp {
        Response::Score { .. } => {}
        other => panic!("service did not survive idle close: {other:?}"),
    }
    drop(client);
    drop(silent);
    server.finish();
}

#[test]
fn finished_connection_handles_are_reaped() {
    // The accept loop must not accumulate one JoinHandle per connection
    // ever served: finished threads are joined on accept-loop ticks, so
    // the tracked list stays proportional to live connections.
    let cfg = model();
    let server = start_server(generous_net(), vec![]);
    let addr = server.addr().to_string();
    for (i, (g1, g2)) in pairs(&cfg, 23, 5).into_iter().enumerate() {
        let mut client = NetClient::connect(&addr, &format!("churn-{i}")).unwrap();
        match client.pair(g1, g2).unwrap().resp {
            Response::Score { .. } => {}
            other => panic!("churn connection {i} failed: {other:?}"),
        }
    }
    assert!(
        eventually(Duration::from_secs(10), || {
            server.active_connections() == 0 && server.tracked_conn_handles() == 0
        }),
        "handles leaked: {} tracked, {} active",
        server.tracked_conn_handles(),
        server.active_connections()
    );
    server.finish();
}

#[test]
fn disconnect_mid_response_leaks_neither_slot_nor_route() {
    // Tiny connection cap: a leaked slot would starve the later
    // connections into "busy" errors.
    let ncfg = NetConfig {
        conn_cap: 2,
        ..generous_net()
    };
    let cfg = model();
    let server = start_server(ncfg, vec![]);
    let addr = server.addr().to_string();
    let workload = pairs(&cfg, 13, 7);
    for (g1, g2) in &workload[..6] {
        // Wait for the previous iteration's slot to come back (TCP
        // close is asynchronous), then send a request and hang up
        // without reading the response.
        assert!(
            eventually(Duration::from_secs(10), || server.active_connections() == 0),
            "connection slot not released between disconnects"
        );
        let frame = spa_gcn::net::wire::RequestFrame {
            client: "quitter".into(),
            id: 1,
            req: spa_gcn::net::wire::Request::Pair {
                g1: g1.clone(),
                g2: g2.clone(),
            },
        };
        let mut raw = TcpStream::connect(&addr).unwrap();
        write_frame(&mut raw, &frame.encode()).unwrap();
        drop(raw);
    }
    // Every slot must come back...
    assert!(
        eventually(Duration::from_secs(10), || server.active_connections() == 0),
        "connection slots leaked: {} still active",
        server.active_connections()
    );
    // ...every result route must drain (the tap delivers into dropped
    // reply slots as a no-op and removes the route)...
    assert!(
        eventually(Duration::from_secs(10), || server.pending_routes() == 0),
        "result routes leaked: {} still pending",
        server.pending_routes()
    );
    // ...and the front door still serves.
    let (g1, g2) = workload[6].clone();
    let mut client = NetClient::connect(&addr, "survivor").unwrap();
    match client.pair(g1, g2).unwrap().resp {
        Response::Score { .. } => {}
        other => panic!("service did not survive disconnects: {other:?}"),
    }
    drop(client);
    server.finish();
}

#[test]
fn slow_reader_does_not_stall_sibling_connections() {
    let cfg = model();
    let server = start_server(generous_net(), vec![]);
    let addr = server.addr().to_string();

    // The slow reader: sends one request and never reads the response.
    let (g1, g2) = pairs(&cfg, 31, 1).remove(0);
    let mut slow = TcpStream::connect(&addr).unwrap();
    let frame = spa_gcn::net::wire::RequestFrame {
        client: "slow".into(),
        id: 7,
        req: spa_gcn::net::wire::Request::Pair { g1, g2 },
    };
    write_frame(&mut slow, &frame.encode()).unwrap();
    slow.flush().unwrap();

    // Meanwhile a sibling connection completes a full workload.
    let mut client = NetClient::connect(&addr, "sibling").unwrap();
    for (g1, g2) in pairs(&cfg, 37, 10) {
        match client.pair(g1, g2).unwrap().resp {
            Response::Score { .. } => {}
            other => panic!("sibling stalled or failed: {other:?}"),
        }
    }
    drop(client);
    drop(slow);
    let metrics = server.finish();
    assert!(metrics.net.unwrap().accepted >= 10);
}

#[test]
fn malformed_frame_gets_typed_error_and_connection_survives() {
    let server = start_server(generous_net(), vec![]);
    let addr = server.addr().to_string();
    let mut raw = TcpStream::connect(&addr).unwrap();
    // Intact frame, garbage body: typed error, connection stays up.
    write_frame(&mut raw, b"{\"v\":1,\"id\":0,\"kind\":\"nonsense\"}").unwrap();
    let body = spa_gcn::net::wire::read_frame(&mut raw, 1 << 20)
        .unwrap()
        .expect("typed error frame");
    match spa_gcn::net::wire::ResponseFrame::decode(&body).unwrap().resp {
        Response::Error { code, .. } => assert_eq!(code, "malformed"),
        other => panic!("unexpected response {other:?}"),
    }
    // The same connection still answers a well-formed hello.
    let hello = spa_gcn::net::wire::RequestFrame {
        client: String::new(),
        id: 2,
        req: spa_gcn::net::wire::Request::Hello,
    };
    write_frame(&mut raw, &hello.encode()).unwrap();
    let body = spa_gcn::net::wire::read_frame(&mut raw, 1 << 20)
        .unwrap()
        .expect("hello response");
    match spa_gcn::net::wire::ResponseFrame::decode(&body).unwrap().resp {
        Response::Hello { .. } => {}
        other => panic!("unexpected response {other:?}"),
    }
    drop(raw);
    server.finish();
}
