//! Live-corpus epochs and cascade retrieval (DESIGN.md S20),
//! artifact-free.
//!
//! The acceptance bar this file pins:
//!  * a query pinned to snapshot N returns bit-identical results no
//!    matter how many upserts/removes land mid-flight — generations are
//!    immutable and the store only ever swaps whole snapshots;
//!  * shard partials from different epochs can never merge into one
//!    ranking: `rank_sharded` refuses them with a typed
//!    `EpochMismatch`, not a silent mis-rank;
//!  * `CascadeMode::Exact` through the staged pipeline is bit-identical
//!    to the direct `score_corpus` + `rank` path (the pre-cascade
//!    contract);
//!  * `CascadeMode::Budgeted` over a 4096-candidate corpus sends at
//!    most 25% of the candidates through the exact scoring tail and
//!    still returns the true top-1.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use spa_gcn::coordinator::corpus::{ShardCoverageError, ShardPartial};
use spa_gcn::coordinator::corpus_store::CorpusStore;
use spa_gcn::coordinator::pipeline::{Pipeline, PipelineConfig, ResultTap};
use spa_gcn::coordinator::query::{CascadeMode, Query, QueryResult};
use spa_gcn::graph::encode::{encode, CheapSignals, EncodedGraph, PackedBatch};
use spa_gcn::graph::generate::{generate, Family};
use spa_gcn::graph::Graph;
use spa_gcn::nn::config::ModelConfig;
use spa_gcn::nn::weights::Weights;
use spa_gcn::runtime::embed_cache::CachedEmbed;
use spa_gcn::runtime::native::NativeEngine;
use spa_gcn::runtime::{
    BatchOutput, CorpusOutput, Engine, EngineCaps, EngineError, EngineFactory, MacCounts,
    QueryEmbed, QueryTelemetry,
};
use spa_gcn::util::rng::Rng;

fn small_cfg() -> ModelConfig {
    ModelConfig {
        n_max: 8,
        num_labels: 4,
        ..ModelConfig::default()
    }
}

fn engine(cfg: &ModelConfig) -> NativeEngine {
    NativeEngine::new(cfg.clone(), Weights::synthetic(cfg, 2024))
}

fn entries(rng: &mut Rng, cfg: &ModelConfig, count: usize) -> Vec<(u64, Graph)> {
    (0..count)
        .map(|i| (i as u64, generate(rng, Family::Aids, cfg.n_max, cfg.num_labels)))
        .collect()
}

/// A tap that clones every result off the responder thread.
fn capture_tap() -> (Arc<Mutex<Vec<QueryResult>>>, ResultTap) {
    let captured: Arc<Mutex<Vec<QueryResult>>> = Arc::new(Mutex::new(Vec::new()));
    let tap: ResultTap = {
        let captured = Arc::clone(&captured);
        Arc::new(move |r: &QueryResult| captured.lock().unwrap().push(r.clone()))
    };
    (captured, tap)
}

#[test]
fn pinned_snapshot_is_bit_identical_under_mid_flight_mutations() {
    // Property: results of a query admitted against epoch N depend only
    // on generation N. Mutations landing after admission publish new
    // generations but never touch the one the query holds.
    let cfg = small_cfg();
    let mut rng = Rng::new(2026);
    let store =
        CorpusStore::build("live", &entries(&mut rng, &cfg, 12), cfg.n_max, cfg.num_labels)
            .unwrap();
    let pinned = store.snapshot();
    assert_eq!(pinned.epoch, 1);

    let qg = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    let query = encode(&qg, cfg.n_max, cfg.num_labels).unwrap();
    let before_scores = engine(&cfg)
        .score_corpus(&query, pinned.corpus.graphs())
        .unwrap()
        .scores;
    let before = pinned.corpus.rank(&before_scores, 5);

    // Mid-flight mutations: insert, replace, remove. Each publishes a
    // new generation in the store.
    store.upsert(50, generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels)).unwrap();
    store.upsert(3, generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels)).unwrap();
    store.remove(7).unwrap();
    assert_eq!(store.epoch(), 4, "three committed mutations");
    let latest = store.snapshot();
    assert_eq!(latest.corpus.len(), 12, "one insert + one remove");
    assert_ne!(latest.corpus.ids(), pinned.corpus.ids());

    // The pinned snapshot re-serves the same bits, even from a fresh
    // engine with a cold cache.
    assert_eq!(pinned.epoch, 1);
    assert_eq!(pinned.corpus.len(), 12);
    let after_scores = engine(&cfg)
        .score_corpus(&query, pinned.corpus.graphs())
        .unwrap()
        .scores;
    assert_eq!(before_scores, after_scores, "pinned generation must be frozen");
    assert_eq!(before, pinned.corpus.rank(&after_scores, 5));
}

#[test]
fn mixed_epoch_partials_are_refused_by_rank_sharded() {
    // A shard scored against a newer generation (an upsert landed
    // between scatter and gather) must be a typed error, never a
    // silently mixed ranking.
    let cfg = small_cfg();
    let mut rng = Rng::new(31);
    let store =
        CorpusStore::build("live", &entries(&mut rng, &cfg, 10), cfg.n_max, cfg.num_labels)
            .unwrap();
    let old = store.snapshot();
    store.upsert(99, generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels)).unwrap();
    let new = store.snapshot();
    assert_eq!((old.epoch, new.epoch), (1, 2));

    let shards = old.corpus.shards(2);
    let scores: Vec<f32> = old
        .corpus
        .keys()
        .iter()
        .map(|key| (key.0 % 7) as f32 / 6.0)
        .collect();
    // Same-epoch partials merge fine...
    let good: Vec<ShardPartial> = shards
        .iter()
        .map(|s| ShardPartial {
            epoch: old.epoch,
            shard: *s,
            scores: &scores[s.start..s.end],
        })
        .collect();
    assert_eq!(
        old.corpus.rank_sharded(&good, 4).unwrap(),
        old.corpus.rank(&scores, 4)
    );
    // ...but one partial stamped with the post-upsert epoch poisons the
    // merge.
    let mixed = [
        ShardPartial {
            epoch: old.epoch,
            shard: shards[0],
            scores: &scores[shards[0].start..shards[0].end],
        },
        ShardPartial {
            epoch: new.epoch,
            shard: shards[1],
            scores: &scores[shards[1].start..shards[1].end],
        },
    ];
    match old.corpus.rank_sharded(&mixed, 4) {
        Err(ShardCoverageError::EpochMismatch { expected, got }) => {
            assert_eq!((expected, got), (1, 2));
        }
        other => panic!("expected EpochMismatch, got {other:?}"),
    }
}

#[test]
fn exact_cascade_through_the_pipeline_matches_the_direct_path() {
    // CascadeMode::Exact is the pre-cascade contract: the staged
    // pipeline must return exactly what score_corpus + rank return
    // directly, and the plain 4-arg topk constructor must behave
    // identically (it IS Exact).
    let cfg = ModelConfig::default();
    let mut rng = Rng::new(55);
    let store =
        CorpusStore::build("live", &entries(&mut rng, &cfg, 16), cfg.n_max, cfg.num_labels)
            .unwrap();
    let snap = store.snapshot();
    let qg = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    let query = encode(&qg, cfg.n_max, cfg.num_labels).unwrap();
    let reference = {
        let scores = engine(&cfg).score_corpus(&query, snap.corpus.graphs()).unwrap().scores;
        snap.corpus.rank(&scores, 5)
    };

    let factory: EngineFactory = {
        let cfg = cfg.clone();
        Arc::new(move || {
            Ok(Box::new(NativeEngine::new(cfg.clone(), Weights::synthetic(&cfg, 2024)))
                as Box<dyn Engine>)
        })
    };
    let (captured, tap) = capture_tap();
    let pipeline =
        Pipeline::start_with_tap(cfg.clone(), vec![factory], PipelineConfig::default(), Some(tap));
    assert!(pipeline.submit(Query::topk(1, qg.clone(), Arc::clone(&snap.corpus), 5)));
    assert!(pipeline.submit(Query::topk_with(
        2,
        qg,
        Arc::clone(&snap.corpus),
        5,
        CascadeMode::Exact,
    )));
    let metrics = pipeline.finish();
    assert_eq!(metrics.topk, 2);
    assert_eq!(metrics.engine_errors, 0);

    let results = captured.lock().unwrap();
    for id in [1u64, 2] {
        let r = results.iter().find(|r| r.id == id).expect("result delivered");
        assert_eq!(
            r.ranked().expect("ranked"),
            &reference[..],
            "query {id}: pipeline diverged from the direct path"
        );
        assert!(r.cascade.is_none(), "Exact queries carry no cascade telemetry");
    }
}

/// A corpus-capable engine whose scores are a pure function of the
/// cheap signals (`1 / (1 + distance)`) and which counts every
/// candidate that reaches its exact scoring tail — the witness that a
/// budgeted query never scores the candidates the coarse stage pruned.
struct CountingCascadeEngine {
    caps: EngineCaps,
    num_labels: usize,
    scored: Arc<AtomicUsize>,
}

impl CountingCascadeEngine {
    fn new(cfg: &ModelConfig, scored: Arc<AtomicUsize>) -> Self {
        CountingCascadeEngine {
            caps: EngineCaps::new("counting-cascade", vec![1], cfg.n_max, cfg.num_labels)
                .with_corpus_scoring()
                .with_corpus_sharding(),
            num_labels: cfg.num_labels,
            scored,
        }
    }

    fn signals_of(&self, g: &EncodedGraph) -> CheapSignals {
        CheapSignals::from_graph(&g.decode().expect("test graphs decode"), self.num_labels)
    }
}

fn signal_score(q: &CheapSignals, c: &CheapSignals) -> f32 {
    1.0 / (1.0 + q.distance(c) as f32)
}

impl Engine for CountingCascadeEngine {
    fn caps(&self) -> &EngineCaps {
        &self.caps
    }

    fn score_batch(&mut self, _batch: &PackedBatch) -> Result<BatchOutput, EngineError> {
        Err(EngineError::Unavailable {
            reason: "corpus-only test engine".into(),
        })
    }

    fn score_corpus(
        &mut self,
        query: &EncodedGraph,
        corpus: &[EncodedGraph],
    ) -> Result<CorpusOutput, EngineError> {
        let q = self.signals_of(query);
        self.scored.fetch_add(corpus.len(), Ordering::SeqCst);
        let scores = corpus.iter().map(|g| signal_score(&q, &self.signals_of(g))).collect();
        Ok(CorpusOutput {
            scores,
            telemetry: QueryTelemetry::default(),
        })
    }

    fn embed_query(&mut self, query: &EncodedGraph) -> Result<QueryEmbed, EngineError> {
        // The "embedding" is the signal vector: [nodes, edges, hist...].
        let s = self.signals_of(query);
        let mut hg = vec![s.nodes as f32, s.edges as f32];
        hg.extend(s.hist.iter().map(|&b| b as f32));
        Ok(QueryEmbed {
            embed: Arc::new(CachedEmbed {
                hg,
                macs: MacCounts::default(),
            }),
            telemetry: QueryTelemetry::default(),
        })
    }

    fn score_corpus_with(
        &mut self,
        query_hg: &[f32],
        shard: &[EncodedGraph],
    ) -> Result<CorpusOutput, EngineError> {
        let q = CheapSignals {
            nodes: query_hg[0] as u32,
            edges: query_hg[1] as u32,
            hist: query_hg[2..].iter().map(|&f| f as u32).collect(),
        };
        self.scored.fetch_add(shard.len(), Ordering::SeqCst);
        let scores = shard.iter().map(|g| signal_score(&q, &self.signals_of(g))).collect();
        Ok(CorpusOutput {
            scores,
            telemetry: QueryTelemetry::default(),
        })
    }
}

#[test]
fn budgeted_cascade_scores_a_quarter_and_keeps_the_true_top1() {
    // THE cascade acceptance bar: 4096 candidates, budget 1024 — at
    // most 25% of the corpus may reach the exact scoring tail, and the
    // true top-1 (the planted exact-profile match at id 0, which every
    // full scan would rank first) must survive the coarse stage.
    let cfg = small_cfg();
    let mut rng = Rng::new(4096);
    let qg = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    let mut corpus_entries = vec![(0u64, qg.clone())];
    corpus_entries.extend(
        (1..4096u64).map(|i| (i, generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels))),
    );
    let store =
        CorpusStore::build("big", &corpus_entries, cfg.n_max, cfg.num_labels).unwrap();
    let snap = store.snapshot();
    assert_eq!(snap.corpus.len(), 4096);

    // Ground truth under the engine's score function, full scan.
    let q_sig = CheapSignals::from_graph(&qg, cfg.num_labels);
    let all_scores: Vec<f32> = snap
        .corpus
        .signals()
        .iter()
        .map(|s| signal_score(&q_sig, s))
        .collect();
    let true_top1 = snap.corpus.rank(&all_scores, 1)[0];
    assert_eq!(true_top1, (0, 1.0), "the planted match is the unambiguous best");

    let scored = Arc::new(AtomicUsize::new(0));
    let factory: EngineFactory = {
        let cfg = cfg.clone();
        let scored = Arc::clone(&scored);
        Arc::new(move || {
            Ok(Box::new(CountingCascadeEngine::new(&cfg, Arc::clone(&scored)))
                as Box<dyn Engine>)
        })
    };
    let (captured, tap) = capture_tap();
    let pipeline =
        Pipeline::start_with_tap(cfg.clone(), vec![factory], PipelineConfig::default(), Some(tap));
    assert_eq!(pipeline.wait_ready(), 1);
    assert!(pipeline.submit(Query::topk_with(
        7,
        qg,
        Arc::clone(&snap.corpus),
        10,
        CascadeMode::Budgeted { budget: 1024 },
    )));
    let metrics = pipeline.finish();
    assert_eq!(metrics.topk, 1);
    assert_eq!(metrics.engine_errors, 0);

    let results = captured.lock().unwrap();
    let r = results.iter().find(|r| r.id == 7).expect("result delivered");
    let ranked = r.ranked().expect("ranked");
    assert_eq!(ranked.len(), 10);
    assert_eq!(ranked[0], true_top1, "budgeted ranking lost the true top-1");
    let cascade = r.cascade.expect("budgeted queries carry cascade telemetry");
    assert_eq!(cascade.survivors, 1024);
    assert_eq!(cascade.pruned, 4096 - 1024);
    // The engine-side witness: exactly the survivors were scored.
    let tallied = scored.load(Ordering::SeqCst);
    assert_eq!(tallied, 1024, "pruned candidates must never reach the engine");
    assert!(tallied * 4 <= snap.corpus.len(), "budget must stay at <= 25%");
}
