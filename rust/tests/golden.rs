//! Cross-implementation integration tests: the python oracle (golden
//! vectors), the independent rust numerics, and the PJRT-executed AOT
//! artifacts must all produce the same scores.
//!
//! Requires `make artifacts` to have run (skips with a message otherwise).

use std::path::{Path, PathBuf};

use spa_gcn::graph::encode::{CsrAdj, EncodedGraph, PackedBatch};
use spa_gcn::nn::config::ModelConfig;
use spa_gcn::nn::simgnn::{gcn_forward, simgnn_score};
use spa_gcn::nn::weights::Weights;
use spa_gcn::runtime::pjrt::XlaEngine;
use spa_gcn::runtime::Engine;
use spa_gcn::util::json::{parse, Json};

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn artifacts_dir() -> PathBuf {
    repo_root().join("artifacts")
}

struct Golden {
    cfg: ModelConfig,
    pairs: Vec<(EncodedGraph, EncodedGraph)>,
    scores: Vec<f32>,
    embeddings1: Vec<f32>,
}

fn load_golden() -> Option<Golden> {
    let path = repo_root().join("tests/golden/simgnn_golden.json");
    if !path.exists() {
        eprintln!("SKIP: {} missing (run `make artifacts`)", path.display());
        return None;
    }
    let doc = parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let cfg = ModelConfig::from_json(doc.get("config")).unwrap();
    let np = doc.get("num_pairs").as_usize().unwrap();
    let (n, l) = (cfg.n_max, cfg.num_labels);
    let f = |k: &str| -> Vec<f32> { doc.get(k).as_f32_vec().unwrap() };
    let (a1, h1, m1) = (f("a1"), f("h1"), f("m1"));
    let (a2, h2, m2) = (f("a2"), f("h2"), f("m2"));
    let slot = |a: &[f32], h: &[f32], m: &[f32], i: usize| {
        let a_norm = a[i * n * n..(i + 1) * n * n].to_vec();
        let num_nodes = m[i * n..(i + 1) * n].iter().filter(|&&x| x != 0.0).count();
        let csr = CsrAdj::from_dense(&a_norm, num_nodes, n);
        let h0 = h[i * n * l..(i + 1) * n * l].to_vec();
        let key = EncodedGraph::compute_fingerprint(&h0, &csr, num_nodes, l);
        EncodedGraph {
            a_norm,
            h0,
            mask: m[i * n..(i + 1) * n].to_vec(),
            csr,
            num_nodes,
            num_edges: 0,
            key,
        }
    };
    let pairs = (0..np)
        .map(|i| (slot(&a1, &h1, &m1, i), slot(&a2, &h2, &m2, i)))
        .collect();
    Some(Golden {
        cfg,
        pairs,
        scores: doc.get("scores").as_f32_vec().unwrap(),
        embeddings1: doc.get("embeddings1").as_f32_vec().unwrap(),
    })
}

fn load_weights(cfg: &ModelConfig) -> Option<Weights> {
    let dir = artifacts_dir();
    if !dir.join("weights.bin").exists() {
        eprintln!("SKIP: weights.bin missing (run `make artifacts`)");
        return None;
    }
    Some(Weights::load(cfg, &dir).unwrap())
}

#[test]
fn native_matches_python_scores() {
    let Some(g) = load_golden() else { return };
    let Some(w) = load_weights(&g.cfg) else { return };
    for (i, (g1, g2)) in g.pairs.iter().enumerate() {
        let got = simgnn_score(&g.cfg, &w, g1, g2);
        let want = g.scores[i];
        assert!(
            (got - want).abs() < 1e-4,
            "pair {i}: native {got} vs python {want}"
        );
    }
}

#[test]
fn native_matches_python_embeddings() {
    let Some(g) = load_golden() else { return };
    let Some(w) = load_weights(&g.cfg) else { return };
    let f = g.cfg.embed_dim();
    let n = g.cfg.n_max;
    for (i, (g1, _)) in g.pairs.iter().enumerate() {
        let trace = gcn_forward(&g.cfg, &w, g1);
        let want = &g.embeddings1[i * n * f..(i + 1) * n * f];
        for (j, (&got, &exp)) in trace.embeddings.iter().zip(want.iter()).enumerate() {
            assert!(
                (got - exp).abs() < 1e-3,
                "pair {i} elem {j}: native {got} vs python {exp}"
            );
        }
    }
}

#[test]
fn pjrt_matches_python_scores() {
    let Some(g) = load_golden() else { return };
    if !artifacts_dir().join("meta.json").exists() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let mut engine = XlaEngine::load(&artifacts_dir()).unwrap();
    // Exercise two batch paths: exact-fit (if 16 >= pairs) and singles.
    let b = engine.caps().pick_batch_size(g.pairs.len());
    let packed = PackedBatch::pack(&g.pairs, b).unwrap();
    let out = engine.score_batch(&packed).unwrap();
    let scores = out.scores;
    // Every slot of the PJRT chunk shares its exec-timing telemetry.
    assert_eq!(out.telemetry.len(), b);
    assert!(out.telemetry.iter().all(|t| t.exec.is_some()));
    for (i, want) in g.scores.iter().enumerate() {
        assert!(
            (scores[i] - want).abs() < 1e-4,
            "pair {i}: pjrt {} vs python {want}",
            scores[i]
        );
    }
    // batch-of-1 path
    let single = PackedBatch::pack(&g.pairs[..1], 1).unwrap();
    let s1 = engine.score_batch(&single).unwrap().scores;
    assert!((s1[0] - g.scores[0]).abs() < 1e-4);
}

#[test]
fn pjrt_gcn3_matches_native_embeddings() {
    let Some(g) = load_golden() else { return };
    let Some(w) = load_weights(&g.cfg) else { return };
    if !artifacts_dir().join("gcn3_b1.hlo.txt").exists() {
        eprintln!("SKIP: gcn3 artifact missing");
        return;
    }
    let engine = XlaEngine::load(&artifacts_dir()).unwrap();
    let (g1, _) = &g.pairs[0];
    let emb = engine
        .gcn3_embeddings(&g1.a_norm, &g1.h0, &g1.mask)
        .unwrap();
    let trace = gcn_forward(&g.cfg, &w, g1);
    assert_eq!(emb.len(), trace.embeddings.len());
    for (i, (&a, &b)) in emb.iter().zip(trace.embeddings.iter()).enumerate() {
        assert!((a - b).abs() < 1e-3, "elem {i}: pjrt {a} vs native {b}");
    }
}

#[test]
fn golden_file_is_wellformed() {
    let Some(g) = load_golden() else { return };
    assert!(!g.pairs.is_empty());
    assert_eq!(g.pairs.len(), g.scores.len());
    for (i, s) in g.scores.iter().enumerate() {
        assert!(*s > 0.0 && *s < 1.0, "score {i} = {s} out of range");
    }
    // Json helpers on a miniature doc (sanity of the test harness itself).
    let j = parse("{\"x\": [1, 2]}").unwrap();
    assert_eq!(j.get("x"), &Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]));
}

#[test]
fn fused_artifacts_match_pallas_artifacts() {
    // The fused (pure-jnp) and Pallas artifact flavors encode identical
    // math; their scores must agree to float tolerance.
    let Some(g) = load_golden() else { return };
    if !artifacts_dir().join("simgnn_fused_b1.hlo.txt").exists() {
        eprintln!("SKIP: fused artifacts missing");
        return;
    }
    let mut pallas = XlaEngine::load(&artifacts_dir()).unwrap();
    let mut fused = XlaEngine::load_fused(&artifacts_dir()).unwrap();
    assert_eq!(pallas.caps().name, "xla-pjrt");
    assert_eq!(fused.caps().name, "xla-pjrt-fused");
    let b = pallas.caps().pick_batch_size(g.pairs.len());
    let packed = PackedBatch::pack(&g.pairs, b).unwrap();
    let s1 = pallas.score_batch(&packed).unwrap().scores;
    let s2 = fused.score_batch(&packed).unwrap().scores;
    for (i, (a, c)) in s1.iter().zip(s2.iter()).enumerate() {
        assert!((a - c).abs() < 1e-4, "pair {i}: pallas {a} vs fused {c}");
    }
}
