//! Scatter/gather for corpus top-k queries (DESIGN.md S15),
//! artifact-free.
//!
//! The acceptance bar this file pins:
//!  * merged sharded rankings are bit-identical to the unsharded
//!    `Corpus::rank`, property-tested over random corpora with
//!    duplicate fingerprints and tied scores, across shard counts
//!    1..=lanes and k in {0, 1, K/2, K, K+7};
//!  * the sharded engine path (embed once, ship the embedding, score
//!    shards on separate engines over one shared cache) returns the
//!    same bits as one unsharded `score_corpus`;
//!  * a scattered top-k query through the staged pipeline costs exactly
//!    `unique_graphs + 1` GCN forwards *total across all lanes* — the
//!    shared-cache contract.

use std::collections::HashSet;
use std::sync::Arc;

use spa_gcn::coordinator::corpus::{Corpus, CorpusShard, ShardPartial};
use spa_gcn::coordinator::pipeline::{Pipeline, PipelineConfig};
use spa_gcn::coordinator::query::Query;
use spa_gcn::graph::encode::encode;
use spa_gcn::graph::generate::{generate, Family};
use spa_gcn::graph::Graph;
use spa_gcn::nn::config::ModelConfig;
use spa_gcn::nn::weights::Weights;
use spa_gcn::runtime::embed_cache::EmbedCache;
use spa_gcn::runtime::native::NativeEngine;
use spa_gcn::runtime::{Engine, EngineFactory};
use spa_gcn::util::rng::Rng;

fn engine() -> NativeEngine {
    let cfg = ModelConfig::default();
    let w = Weights::synthetic(&cfg, 2024);
    NativeEngine::new(cfg, w)
}

/// Generate `count` graphs with pairwise-distinct content fingerprints
/// (random draws may collide; tests that pin forward counts need
/// certainty, not likelihood).
fn distinct_graphs(rng: &mut Rng, cfg: &ModelConfig, count: usize) -> Vec<Graph> {
    let mut seen = HashSet::new();
    let mut out = Vec::new();
    while out.len() < count {
        let g = generate(rng, Family::Aids, cfg.n_max, cfg.num_labels);
        let key = encode(&g, cfg.n_max, cfg.num_labels).unwrap().fingerprint().0;
        if seen.insert(key) {
            out.push(g);
        }
    }
    out
}

#[test]
fn merged_sharded_topk_is_bit_identical_across_shard_counts_and_k() {
    // Property: for corpora with duplicate fingerprints and heavily
    // tied scores, rank_sharded == rank bit-for-bit, whatever the
    // shard count and k. Scores are synthetic and quantized to five
    // levels so ties abound — the id tie-break is what's under test.
    let cfg = ModelConfig::default();
    let mut rng = Rng::new(4242);
    for trial in 0..8u64 {
        let unique = 3 + (trial as usize % 5);
        let dups = trial as usize % 4;
        let graphs = distinct_graphs(&mut rng, &cfg, unique);
        let mut entries: Vec<(u64, Graph)> = graphs
            .iter()
            .cloned()
            .enumerate()
            .map(|(i, g)| (i as u64, g))
            .collect();
        for d in 0..dups {
            // Duplicate content under a fresh id.
            entries.push(((unique + d) as u64, graphs[d % unique].clone()));
        }
        let corpus = Corpus::build("prop", &entries, cfg.n_max, cfg.num_labels).unwrap();
        let k_total = corpus.len();
        // Tied scores: duplicate fingerprints share a score by
        // construction, and the coarse quantization ties distinct
        // graphs too.
        let scores: Vec<f32> = corpus
            .keys()
            .iter()
            .map(|key| (key.0 % 5) as f32 / 4.0)
            .collect();
        let lanes = 4;
        for n in 1..=lanes {
            let shards = corpus.shards(n);
            let covered: usize = shards.iter().map(CorpusShard::len).sum();
            assert_eq!(covered, corpus.len(), "trial {trial}: shards must tile");
            let partials: Vec<ShardPartial> = shards
                .iter()
                .map(|s| ShardPartial {
                    epoch: corpus.epoch(),
                    shard: *s,
                    scores: &scores[s.start..s.end],
                })
                .collect();
            for k in [0, 1, k_total / 2, k_total, k_total + 7] {
                assert_eq!(
                    corpus.rank_sharded(&partials, k).unwrap(),
                    corpus.rank(&scores, k),
                    "trial {trial}, {n} shards, k={k}"
                );
            }
        }
    }
}

#[test]
fn sharded_engine_scores_merge_bit_identical_to_score_corpus() {
    // Real engine scores this time (duplicate graphs produce exactly
    // tied scores): two engines over one shared cache play the two
    // lanes, the query embedding is computed once and shipped, and the
    // merged ranking must equal the unsharded one bit-for-bit.
    let cfg = ModelConfig::default();
    let mut rng = Rng::new(77);
    let graphs = distinct_graphs(&mut rng, &cfg, 9);
    let mut entries: Vec<(u64, Graph)> = graphs[..8]
        .iter()
        .cloned()
        .enumerate()
        .map(|(i, g)| (i as u64, g))
        .collect();
    // Two duplicates: tied scores with distinct ids.
    entries.push((8, graphs[0].clone()));
    entries.push((9, graphs[3].clone()));
    let corpus = Corpus::build("eng", &entries, cfg.n_max, cfg.num_labels).unwrap();
    let query = encode(&graphs[8], cfg.n_max, cfg.num_labels).unwrap();

    let mut reference = engine();
    let whole = reference.score_corpus(&query, corpus.graphs()).unwrap();

    let shared = Arc::new(EmbedCache::new(1024));
    let mut lane_a = engine().with_cache(Arc::clone(&shared));
    let mut lane_b = engine().with_cache(Arc::clone(&shared));
    let embed = lane_a.embed_query(&query).unwrap();
    for n in 1..=3usize {
        let shards = corpus.shards(n);
        let partials: Vec<(CorpusShard, Vec<f32>)> = shards
            .iter()
            .enumerate()
            .map(|(i, s)| {
                // Alternate lanes per shard, as the router would.
                let lane = if i % 2 == 0 { &mut lane_a } else { &mut lane_b };
                let out = lane
                    .score_corpus_with(&embed.embed.hg, corpus.shard_graphs(*s))
                    .unwrap();
                (*s, out.scores)
            })
            .collect();
        let borrowed: Vec<ShardPartial> = partials
            .iter()
            .map(|(s, v)| ShardPartial {
                epoch: corpus.epoch(),
                shard: *s,
                scores: v.as_slice(),
            })
            .collect();
        for k in [0usize, 1, 5, 10, 17] {
            assert_eq!(
                corpus.rank_sharded(&borrowed, k).unwrap(),
                corpus.rank(&whole.scores, k),
                "{n} shards, k={k}"
            );
        }
    }
}

#[test]
fn sharded_topk_costs_unique_plus_one_gcn_forwards_across_lanes() {
    // The shared-cache contract through the full staged pipeline: a
    // scattered top-k over K candidates performs exactly
    // unique_graphs + 1 GCN forwards total across all lanes (embed
    // telemetry is summed by the gather stage, so the pipeline metrics
    // see the cross-lane total). Duplicates are confined within one
    // shard: a duplicate *spanning* the boundary may, under
    // concurrency, legitimately embed once per lane — the contract is
    // exact only where the partitioning keeps repeated content
    // together, which is what this test pins.
    let cfg = ModelConfig {
        n_max: 8,
        num_labels: 4,
        ..ModelConfig::default()
    };
    let shared = Arc::new(EmbedCache::new(4096));
    let factory: EngineFactory = {
        let cfg = cfg.clone();
        let shared = Arc::clone(&shared);
        Arc::new(move || {
            Ok(Box::new(
                NativeEngine::new(cfg.clone(), Weights::synthetic(&cfg, 2024))
                    .with_cache(Arc::clone(&shared)),
            ) as Box<dyn Engine>)
        })
    };
    let pipeline = Pipeline::start(
        cfg.clone(),
        vec![Arc::clone(&factory), factory],
        PipelineConfig::default(),
    );
    assert_eq!(pipeline.wait_ready(), 2, "both native lanes must construct");

    let mut rng = Rng::new(99);
    let graphs = distinct_graphs(&mut rng, &cfg, 15); // 14 corpus + 1 query
    let query = graphs[14].clone();
    let mut entries: Vec<(u64, Graph)> = Vec::new();
    // First half (shard 0 of 2): six uniques + two duplicates of them.
    for (i, g) in graphs[..6].iter().enumerate() {
        entries.push((i as u64, g.clone()));
    }
    entries.push((6, graphs[0].clone()));
    entries.push((7, graphs[1].clone()));
    // Second half (shard 1): eight more uniques.
    for (i, g) in graphs[6..14].iter().enumerate() {
        entries.push(((8 + i) as u64, g.clone()));
    }
    let corpus = Arc::new(Corpus::build("halves", &entries, cfg.n_max, cfg.num_labels).unwrap());
    assert_eq!(corpus.len(), 16);
    assert_eq!(corpus.unique_graphs(), 14);
    // The 2-way split puts both duplicates in the same shard as their
    // originals — the fixture this test's exactness rests on.
    let shards = corpus.shards(2);
    assert_eq!(shards[0], CorpusShard { start: 0, end: 8 });
    assert_eq!(corpus.unique_in(shards[0]), 6);
    assert_eq!(corpus.unique_in(shards[1]), 8);

    assert!(pipeline.submit(Query::topk(1, query, Arc::clone(&corpus), 5)));
    let metrics = pipeline.finish();
    assert_eq!(metrics.scored, 1);
    assert_eq!(metrics.topk, 1);
    assert_eq!(metrics.engine_errors, 0);
    assert_eq!(metrics.topk_shards.mean(), 2.0, "the query must have scattered");
    assert_eq!(
        metrics.embed_misses,
        corpus.unique_graphs() as u64 + 1,
        "unique_graphs + 1 GCN forwards total across all lanes"
    );
    assert_eq!(metrics.embed_hits, 2, "the two duplicates hit the shared cache");
    assert_eq!(metrics.gcn_forwards.mean(), 15.0);
    // And the shared cache holds exactly the unique graphs + the query.
    assert_eq!(shared.stats().entries, 15);
}
