//! Scalar↔vectorized kernel parity (DESIGN.md S16): every kernel in the
//! `nn::kernels` dispatch layer must honor its published contract —
//! bit-identity for the GCN kernels (`csr_spmm`, `onehot_gather`,
//! `sparse_row_matmul`, `vec_mat`), the pinned reassociation epsilon
//! for the reductions (`dot`, `matvec`, `ntn_bilinear`) — across the
//! batch ladder, padded tails, all-zero rows, and nnz-bucket boundary
//! sizes (LANE_WIDTH ± 1). MAC counts must be identical on both paths.
//!
//! Kernel-level checks call the `scalar`/`lanes` modules explicitly, so
//! they hold regardless of the `simd` feature. The engine-level ladder
//! check toggles the process-wide dispatch under a lock (the global is
//! shared by every test thread in this binary) and restores the
//! compiled default even on panic.

use std::sync::Mutex;

use spa_gcn::graph::encode::{encode, EncodedGraph, PackedBatch};
use spa_gcn::graph::generate::{generate, Family};
use spa_gcn::nn::config::ModelConfig;
use spa_gcn::nn::kernels::{self, lanes, scalar, KernelPath, LANE_WIDTH, REASSOC_EPS_REL};
use spa_gcn::nn::simgnn::{gcn_forward_with, SparsePolicy};
use spa_gcn::nn::weights::Weights;
use spa_gcn::runtime::native::NativeEngine;
use spa_gcn::runtime::Engine;
use spa_gcn::util::prop::check;
use spa_gcn::util::rng::Rng;

/// Guards the process-wide kernel path; restores the compiled default
/// on drop so a failing test cannot leak a toggled path into others.
static PATH_LOCK: Mutex<()> = Mutex::new(());

struct PathGuard<'a>(#[allow(dead_code)] std::sync::MutexGuard<'a, ()>);

impl<'a> PathGuard<'a> {
    fn lock() -> Self {
        PathGuard(PATH_LOCK.lock().unwrap_or_else(|p| p.into_inner()))
    }
}

impl Drop for PathGuard<'_> {
    fn drop(&mut self) {
        kernels::set_kernel_path(KernelPath::compiled_default());
    }
}

/// nnz-per-row values straddling every bucket boundary the schedule
/// cares about, LANE_WIDTH ± 1 included.
const BOUNDARY_NNZ: [usize; 10] = [0, 1, 2, 3, 7, 8, 9, 15, 16, 17];

/// Feature widths covering sub-lane, exact-lane and lane±1 tails.
const BOUNDARY_F: [usize; 8] = [1, 4, 7, 8, 9, 16, 31, 33];

/// Random CSR with per-row nnz drawn from the boundary set: distinct
/// ascending columns per row, signed fractional weights.
fn random_csr(rng: &mut Rng, rows: usize, cols: usize) -> (Vec<u32>, Vec<u16>, Vec<f32>) {
    let mut indptr = vec![0u32];
    let mut indices = Vec::new();
    let mut weights = Vec::new();
    for _ in 0..rows {
        let nnz = BOUNDARY_NNZ[rng.below(BOUNDARY_NNZ.len())].min(cols);
        let mut pool: Vec<usize> = (0..cols).collect();
        rng.shuffle(&mut pool);
        let mut picked = pool[..nnz].to_vec();
        picked.sort_unstable();
        for c in picked {
            indices.push(c as u16);
            weights.push((rng.f32() - 0.5) * 2.0);
        }
        indptr.push(indices.len() as u32);
    }
    (indptr, indices, weights)
}

#[test]
fn property_csr_spmm_bit_identical_at_bucket_boundaries() {
    check(
        "csr-spmm-lanes-bit-identity",
        24,
        |rng: &mut Rng| {
            let rows = 1 + rng.below(24);
            let cols = 1 + rng.below(24);
            let rows_out = rows + rng.below(4); // padded output rows
            let f = BOUNDARY_F[rng.below(BOUNDARY_F.len())];
            let csr = random_csr(rng, rows, cols);
            let x: Vec<f32> = (0..cols * f).map(|_| (rng.f32() - 0.5) * 2.0).collect();
            (csr, x, rows_out, f)
        },
        |((indptr, indices, weights), x, rows_out, f)| {
            let (want, wm) = scalar::csr_spmm(indptr, indices, weights, x, *rows_out, *f);
            let (got, gm) = lanes::csr_spmm(indptr, indices, weights, x, *rows_out, *f);
            if got != want {
                return Err("lanes csr_spmm output diverged from scalar".into());
            }
            if gm != wm {
                return Err(format!("MAC counts diverged: lanes {gm} vs scalar {wm}"));
            }
            Ok(())
        },
    );
}

#[test]
fn property_ft_kernels_bit_identical_with_zero_rows() {
    check(
        "ft-kernels-lanes-bit-identity",
        24,
        |rng: &mut Rng| {
            let rows = 1 + rng.below(12);
            let rows_out = rows + rng.below(4);
            let f_in = BOUNDARY_F[rng.below(BOUNDARY_F.len())];
            let f_out = BOUNDARY_F[rng.below(BOUNDARY_F.len())];
            // Post-ReLU-like input: ~half zeros, some all-zero rows.
            let mut h = vec![0.0f32; rows * f_in];
            for (i, v) in h.iter_mut().enumerate() {
                if (i / f_in) % 5 != 4 && rng.bool(0.5) {
                    *v = (rng.f32() - 0.5) * 2.0;
                }
            }
            // One-hot input for the gather (all-zero rows sprinkled in).
            let mut onehot = vec![0.0f32; rows * f_in];
            for r in 0..rows {
                if !rng.bool(0.2) {
                    onehot[r * f_in + rng.below(f_in)] = 1.0 + rng.f32();
                }
            }
            let w: Vec<f32> = (0..f_in * f_out).map(|_| (rng.f32() - 0.5) * 2.0).collect();
            (h, onehot, w, rows, rows_out, f_in, f_out)
        },
        |(h, onehot, w, rows, rows_out, f_in, f_out)| {
            let sw = scalar::sparse_row_matmul(h, w, *rows, *rows_out, *f_in, *f_out);
            let lw = lanes::sparse_row_matmul(h, w, *rows, *rows_out, *f_in, *f_out);
            if sw != lw {
                return Err("sparse_row_matmul diverged (out, nnz, macs)".into());
            }
            let sg = scalar::onehot_gather(onehot, w, *rows, *rows_out, *f_in, *f_out);
            let lg = lanes::onehot_gather(onehot, w, *rows, *rows_out, *f_in, *f_out);
            if sg != lg {
                return Err("onehot_gather diverged (out, nnz, macs)".into());
            }
            Ok(())
        },
    );
}

#[test]
fn property_vec_mat_bit_identical() {
    check(
        "vec-mat-lanes-bit-identity",
        24,
        |rng: &mut Rng| {
            let d = 1 + rng.below(40);
            let h = BOUNDARY_F[rng.below(BOUNDARY_F.len())];
            // Zeros in x exercise the shared zero-skip branch.
            let x: Vec<f32> = (0..d)
                .map(|_| if rng.bool(0.3) { 0.0 } else { (rng.f32() - 0.5) * 2.0 })
                .collect();
            let w: Vec<f32> = (0..d * h).map(|_| (rng.f32() - 0.5) * 2.0).collect();
            (x, w, d, h)
        },
        |(x, w, d, h)| {
            if scalar::vec_mat(x, w, *d, *h) != lanes::vec_mat(x, w, *d, *h) {
                return Err("vec_mat diverged from scalar matmul row".into());
            }
            Ok(())
        },
    );
}

#[test]
fn property_reductions_within_pinned_epsilon() {
    // The epsilon contract the docs promise: per-element
    // |lanes − scalar| ≤ REASSOC_EPS_REL · (1 + |scalar|).
    let within = |l: f32, s: f32| (l - s).abs() <= REASSOC_EPS_REL * (1.0 + s.abs());
    check(
        "reductions-epsilon-contract",
        24,
        |rng: &mut Rng| {
            let n = BOUNDARY_F[rng.below(BOUNDARY_F.len())];
            let m = 1 + rng.below(8);
            let a: Vec<f32> = (0..m * n).map(|_| (rng.f32() - 0.5) * 2.0).collect();
            let x: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 2.0).collect();
            let y: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 2.0).collect();
            let wk: Vec<f32> = (0..n * n).map(|_| (rng.f32() - 0.5) * 2.0).collect();
            (a, x, y, wk, m, n)
        },
        |(a, x, y, wk, m, n)| {
            if !within(lanes::dot(x, y), scalar::dot(x, y)) {
                return Err("dot outside epsilon".into());
            }
            let sm = scalar::matvec(a, x, *m, *n);
            let lm = lanes::matvec(a, x, *m, *n);
            for (i, (&l, &s)) in lm.iter().zip(sm.iter()).enumerate() {
                if !within(l, s) {
                    return Err(format!("matvec[{i}] outside epsilon: {l} vs {s}"));
                }
            }
            let sb = scalar::ntn_bilinear(wk, x, y, *n);
            let lb = lanes::ntn_bilinear(wk, x, y, *n);
            if !within(lb, sb) {
                return Err(format!("ntn_bilinear outside epsilon: {lb} vs {sb}"));
            }
            Ok(())
        },
    );
}

#[test]
fn empty_and_all_zero_csr_rows_stay_zero_on_both_paths() {
    // Empty CSR (no rows), rows with zero nnz, and a fully-padded
    // output: both paths must return exact zeros and zero MACs.
    for f in [1usize, 7, 8, 9] {
        let (so, sm) = scalar::csr_spmm(&[0], &[], &[], &[], 4, f);
        let (lo, lm) = lanes::csr_spmm(&[0], &[], &[], &[], 4, f);
        assert_eq!(so, vec![0.0; 4 * f]);
        assert_eq!(so, lo);
        assert_eq!((sm, lm), (0, 0));
        // Three rows, middle one empty.
        let indptr = vec![0u32, 1, 1, 2];
        let indices = vec![0u16, 1];
        let weights = vec![0.5f32, -0.25];
        let x: Vec<f32> = (0..2 * f).map(|i| i as f32 * 0.3 - 1.0).collect();
        let (so, _) = scalar::csr_spmm(&indptr, &indices, &weights, &x, 4, f);
        let (lo, _) = lanes::csr_spmm(&indptr, &indices, &weights, &x, 4, f);
        assert_eq!(so, lo);
        assert_eq!(&so[f..2 * f], vec![0.0; f].as_slice(), "empty row leaked (f={f})");
        assert_eq!(&so[3 * f..], vec![0.0; f].as_slice(), "padded row leaked (f={f})");
    }
}

#[test]
fn bucket_order_covers_every_row_exactly_once() {
    let mut rng = Rng::new(0x5eed);
    for _ in 0..20 {
        let rows = 1 + rng.below(40);
        let (indptr, _, _) = random_csr(&mut rng, rows, 24);
        let order = lanes::nnz_bucket_order(&indptr);
        let mut seen = order.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..rows as u32).collect::<Vec<_>>());
        // Classes ascend along the schedule; ids ascend within a class.
        let class_of = |r: u32| lanes::nnz_class(indptr[r as usize + 1] - indptr[r as usize]);
        for w in order.windows(2) {
            let (ca, cb) = (class_of(w[0]), class_of(w[1]));
            assert!(ca < cb || (ca == cb && w[0] < w[1]), "schedule not stable-grouped");
        }
    }
}

#[test]
#[should_panic(expected = "CSR column")]
fn scalar_csr_spmm_rejects_out_of_range_column() {
    // Regression for the vacuous `x.len() % f == 0` check: column 9
    // with x covering 2 rows must panic, not read out of bounds or
    // silently alias.
    let (got, _) = scalar::csr_spmm(&[0, 1], &[9], &[1.0], &[0.1, 0.2, 0.3, 0.4], 1, 2);
    std::hint::black_box(got);
}

#[test]
#[should_panic(expected = "CSR column")]
fn lanes_csr_spmm_rejects_out_of_range_column() {
    let (got, _) = lanes::csr_spmm(&[0, 1], &[9], &[1.0], &[0.1, 0.2, 0.3, 0.4], 1, 2);
    std::hint::black_box(got);
}

// ---------------------------------------------------------------------
// Engine-level: the batch ladder under each dispatch path.
// ---------------------------------------------------------------------

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        n_max: 16,
        num_labels: 8,
        // Deliberately off-lane (LANE_WIDTH ± 1 style) feature widths so
        // the engine run exercises lane tails end to end.
        filters: [LANE_WIDTH + 1, LANE_WIDTH, LANE_WIDTH - 1],
        relu_mask: [true, true, false],
        ntn_k: 6,
        fc_dims: vec![7],
        seed: 0,
    }
}

fn random_pairs(
    rng: &mut Rng,
    cfg: &ModelConfig,
    count: usize,
) -> Vec<(EncodedGraph, EncodedGraph)> {
    (0..count)
        .map(|_| {
            let n1 = 2 + rng.below(cfg.n_max - 2);
            let n2 = 2 + rng.below(cfg.n_max - 2);
            let f1 = Family::ErdosRenyi { n: n1, p_millis: 350 };
            let f2 = Family::ErdosRenyi { n: n2, p_millis: 350 };
            let g1 = generate(rng, f1, cfg.n_max, cfg.num_labels);
            let g2 = generate(rng, f2, cfg.n_max, cfg.num_labels);
            (
                encode(&g1, cfg.n_max, cfg.num_labels).unwrap(),
                encode(&g2, cfg.n_max, cfg.num_labels).unwrap(),
            )
        })
        .collect()
}

#[test]
fn engine_scores_agree_across_ladder_under_both_paths() {
    let _guard = PathGuard::lock();
    let cfg = tiny_cfg();
    let weights = Weights::synthetic(&cfg, 0xD15);
    let ladder = NativeEngine::new(cfg.clone(), weights.clone())
        .caps()
        .batch_ladder()
        .to_vec();
    let mut rng = Rng::new(0xABCD);
    for &b in &ladder {
        // Underfill by one where possible so padded tail slots ride too.
        let pairs = random_pairs(&mut rng, &cfg, if b > 1 { b - 1 } else { 1 });
        let pb = PackedBatch::pack(&pairs, b).unwrap();

        kernels::set_kernel_path(KernelPath::Scalar);
        let mut eng_s = NativeEngine::new(cfg.clone(), weights.clone());
        let s = eng_s.score_batch(&pb).unwrap();

        kernels::set_kernel_path(KernelPath::Lanes);
        let mut eng_l = NativeEngine::new(cfg.clone(), weights.clone());
        let l = eng_l.score_batch(&pb).unwrap();

        for (i, (ss, ls)) in s.scores.iter().zip(l.scores.iter()).enumerate() {
            assert!(
                (ss - ls).abs() < 1e-5,
                "batch {b} slot {i}: scalar {ss} vs lanes {ls}"
            );
        }
        // Work telemetry is path-independent: identical MAC and element
        // counts slot by slot (the GCN kernels are bit-identical and
        // both paths count the same closed forms).
        for (i, (ts, tl)) in s.telemetry.iter().zip(l.telemetry.iter()).enumerate() {
            assert_eq!(
                ts.macs.unwrap(),
                tl.macs.unwrap(),
                "batch {b} slot {i}: MAC telemetry diverged between paths"
            );
        }
    }
}

#[test]
fn gcn_stage_is_bit_identical_between_paths() {
    // Scores may move by the tail's epsilon, but the GCN stage itself
    // (all bit-identical kernels) must match exactly path to path.
    let _guard = PathGuard::lock();
    let cfg = tiny_cfg();
    let w = Weights::synthetic(&cfg, 0xF00D);
    let mut rng = Rng::new(0x77);
    for _ in 0..6 {
        let (e, _) = random_pairs(&mut rng, &cfg, 1).pop().unwrap();
        kernels::set_kernel_path(KernelPath::Scalar);
        let ts = gcn_forward_with(&cfg, &w, &e, SparsePolicy::Csr);
        kernels::set_kernel_path(KernelPath::Lanes);
        let tl = gcn_forward_with(&cfg, &w, &e, SparsePolicy::Csr);
        assert_eq!(ts.embeddings, tl.embeddings);
        assert_eq!(ts.layer_inputs, tl.layer_inputs);
        assert_eq!(ts.macs, tl.macs);
        assert_eq!(ts.ft_elements, tl.ft_elements);
        assert_eq!(ts.agg_elements, tl.agg_elements);
    }
}
