//! Replay-determinism integration tests (ISSUE 9 / DESIGN.md S19).
//!
//! No artifacts needed: lanes run `NativeEngine` with synthetic
//! weights (bit-deterministic), and the workload comes from a
//! `spa-gcn-trace-v1` document built with `TraceWriter` and parsed
//! back with `Trace::parse` — the exact codec path `spa-gcn replay`
//! uses. The acceptance bar: replaying the same trace twice produces
//! byte-identical sorted outcome dumps (score bits AND per-query gcn
//! forward counts ride in every line) and identical forward-count
//! telemetry in `Metrics`. This is the in-process half of the CI
//! `replay` job; the workflow's CLI half exercises `run_replay`
//! against real artifacts.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use spa_gcn::coordinator::corpus::Corpus;
use spa_gcn::coordinator::metrics::Metrics;
use spa_gcn::coordinator::pipeline::{Pipeline, PipelineConfig, ResultTap};
use spa_gcn::coordinator::query::{Outcome, QueryResult};
use spa_gcn::coordinator::trace::{outcome_line, Trace, TraceHeader, TraceWriter};
use spa_gcn::graph::generate::{generate, Family};
use spa_gcn::graph::Graph;
use spa_gcn::nn::config::ModelConfig;
use spa_gcn::nn::weights::Weights;
use spa_gcn::runtime::native::NativeEngine;
use spa_gcn::runtime::{Engine, EngineFactory};
use spa_gcn::util::rng::Rng;

fn model() -> ModelConfig {
    ModelConfig {
        n_max: 8,
        num_labels: 4,
        ..ModelConfig::default()
    }
}

fn native_factory(cfg: &ModelConfig) -> EngineFactory {
    let cfg = cfg.clone();
    Arc::new(move || {
        Ok(Box::new(NativeEngine::new(cfg.clone(), Weights::synthetic(&cfg, 2024)))
            as Box<dyn Engine>)
    })
}

fn graphs(cfg: &ModelConfig, seed: u64, count: usize) -> Vec<Graph> {
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|_| generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels))
        .collect()
}

/// A mixed pair/top-k trace over synthetic graphs, plus the corpus map
/// its top-k entries reference — everything `to_query` needs.
fn fixture(cfg: &ModelConfig) -> (Trace, BTreeMap<String, Arc<Corpus>>) {
    let gs = graphs(cfg, 404, 14);
    let corpus = Arc::new(
        Corpus::build(
            "trace-fixture",
            &gs[8..].iter().cloned().enumerate().map(|(i, g)| (i as u64, g)).collect::<Vec<_>>(),
            cfg.n_max,
            cfg.num_labels,
        )
        .expect("fixture corpus encodes"),
    );
    let mut w = TraceWriter::new(&TraceHeader {
        seed: 404,
        corpus_size: 0, // corpus supplied in-process, not resynthesized
        topk: 3,
        n_max: cfg.n_max,
        num_labels: cfg.num_labels,
    });
    // Interleave payload kinds; offsets are present but the replay
    // below floods (schedule ignored), matching --as-fast-as-possible.
    for i in 0..8u64 {
        if i % 3 == 2 {
            w.topk("it", 100 + i, i * 250, &gs[i as usize], "trace-fixture", 3);
        } else {
            w.pair("it", 100 + i, i * 250, &gs[i as usize], &gs[(i as usize + 1) % 8]);
        }
    }
    let trace = Trace::parse(w.as_text()).expect("fixture trace parses");
    let mut corpora = BTreeMap::new();
    corpora.insert(corpus.name().to_string(), corpus);
    (trace, corpora)
}

/// One flood replay of `trace` through a fresh pipeline: the sorted
/// outcome dump (what `spa-gcn replay --out` writes) plus full metrics.
fn replay_once(trace: &Trace, corpora: &BTreeMap<String, Arc<Corpus>>) -> (String, Metrics) {
    let cfg = model();
    let lines: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let tap: ResultTap = {
        let lines = Arc::clone(&lines);
        Arc::new(move |r: &QueryResult| {
            lines.lock().unwrap().push(outcome_line(r));
        })
    };
    let pipeline = Pipeline::start_with_tap(
        cfg.clone(),
        vec![native_factory(&cfg)],
        PipelineConfig::default(),
        Some(tap),
    );
    assert_eq!(pipeline.wait_ready(), 1, "native lane must construct");
    for e in trace.entries() {
        let q = e.to_query(corpora).expect("fixture entries convert");
        assert!(pipeline.submit(q), "pipeline accepts the fixture load");
    }
    let metrics = pipeline.finish();
    let mut dump = std::mem::take(&mut *lines.lock().unwrap());
    dump.sort();
    (dump.join("\n"), metrics)
}

#[test]
fn same_trace_replays_bit_identical() {
    let cfg = model();
    let (trace, corpora) = fixture(&cfg);
    let (dump1, m1) = replay_once(&trace, &corpora);
    let (dump2, m2) = replay_once(&trace, &corpora);

    assert!(!dump1.is_empty(), "replay produced no outcomes");
    assert_eq!(dump1.lines().count(), trace.len(), "one outcome line per trace entry");
    // The gate: score bits and per-query forward counts are embedded in
    // every outcome line, so byte equality IS bit-identical scoring.
    assert_eq!(dump1, dump2, "two replays of the same trace diverged");
    assert!(dump1.contains("score_bits="), "pair outcomes carry score bits");
    assert!(dump1.contains(" topk "), "topk outcomes present");

    // Forward-count telemetry must agree sample-for-sample, not just in
    // the dump: `gcn forwards per query` is the embed-cache witness.
    assert_eq!(m1.scored, m2.scored);
    assert_eq!(m1.topk, m2.topk);
    assert_eq!(m1.rejected, m2.rejected);
    assert_eq!(m1.engine_errors, 0);
    assert_eq!(m2.engine_errors, 0);
    assert_eq!(
        m1.gcn_forwards.mean().to_bits(),
        m2.gcn_forwards.mean().to_bits(),
        "gcn forwards per query drifted between replays"
    );
    assert_eq!(m1.embed_misses, m2.embed_misses);
    assert_eq!(m1.embed_hits, m2.embed_hits);
}

#[test]
fn replayed_queries_score_like_direct_submission() {
    // `to_query` must hand the pipeline the payloads that were recorded
    // — a replayed pair scores bit-identically to the same pair
    // submitted without a trace round-trip in the middle.
    let cfg = model();
    let gs = graphs(&cfg, 505, 4);

    let mut w = TraceWriter::new(&TraceHeader {
        seed: 505,
        corpus_size: 0,
        topk: 1,
        n_max: cfg.n_max,
        num_labels: cfg.num_labels,
    });
    w.pair("it", 7, 0, &gs[0], &gs[1]);
    w.pair("it", 8, 10, &gs[2], &gs[3]);
    let trace = Trace::parse(w.as_text()).expect("trace parses");
    let (dump, _) = replay_once(&trace, &BTreeMap::new());

    // Direct path: same pairs, same ids, no codec in the loop.
    let scores: Arc<Mutex<BTreeMap<u64, u32>>> = Arc::new(Mutex::new(BTreeMap::new()));
    let tap: ResultTap = {
        let scores = Arc::clone(&scores);
        Arc::new(move |r: &QueryResult| {
            if let Outcome::Score(s) = r.outcome {
                scores.lock().unwrap().insert(r.id, s.to_bits());
            }
        })
    };
    let pipeline = Pipeline::start_with_tap(
        cfg.clone(),
        vec![native_factory(&cfg)],
        PipelineConfig::default(),
        Some(tap),
    );
    assert_eq!(pipeline.wait_ready(), 1);
    use spa_gcn::coordinator::query::Query;
    assert!(pipeline.submit(Query::new(7, gs[0].clone(), gs[1].clone())));
    assert!(pipeline.submit(Query::new(8, gs[2].clone(), gs[3].clone())));
    pipeline.finish();

    let scores = scores.lock().unwrap();
    assert_eq!(scores.len(), 2);
    for (id, bits) in scores.iter() {
        let want = format!("{id:020} pair score_bits={bits:08x}");
        assert!(
            dump.lines().any(|l| l.starts_with(&want)),
            "replayed dump missing direct-submission score: want `{want}` in\n{dump}"
        );
    }
}
