//! One-vs-many corpus search through the fingerprinted embedding cache
//! (DESIGN.md S14), artifact-free: in-memory engines with deterministic
//! pseudo-random weights.
//!
//! The acceptance bar this file pins:
//!  * a top-k corpus query over K candidates performs exactly
//!    `unique_graphs` GCN forwards (asserted via the embed-cache / MAC
//!    telemetry), never `1 + K`;
//!  * corpus scores are bit-identical to the pairwise path, across the
//!    batch ladder and with warm or cold caches;
//!  * `QueryPayload::TopK` rides the full staged pipeline end to end.

use std::sync::Arc;

use spa_gcn::coordinator::corpus::{Corpus, CorpusError};
use spa_gcn::coordinator::pipeline::{Pipeline, PipelineConfig};
use spa_gcn::coordinator::query::Query;
use spa_gcn::graph::dataset::GraphDb;
use spa_gcn::graph::encode::{encode, PackedBatch};
use spa_gcn::graph::generate::{generate, Family};
use spa_gcn::graph::Graph;
use spa_gcn::nn::config::ModelConfig;
use spa_gcn::nn::weights::Weights;
use spa_gcn::runtime::native::NativeEngine;
use spa_gcn::runtime::{Engine, EngineFactory, MacCounts};
use spa_gcn::util::rng::Rng;

fn engine() -> NativeEngine {
    let cfg = ModelConfig::default();
    let w = Weights::synthetic(&cfg, 2024);
    NativeEngine::new(cfg, w)
}

/// A corpus of `unique` distinct AIDS-like graphs with `dups` extra
/// entries duplicating the first graphs (distinct ids, same content).
fn corpus_with_dups(seed: u64, unique: usize, dups: usize) -> Arc<Corpus> {
    let cfg = ModelConfig::default();
    let mut rng = Rng::new(seed);
    let mut entries: Vec<(u64, Graph)> = (0..unique)
        .map(|i| (i as u64, generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels)))
        .collect();
    for d in 0..dups {
        entries.push(((unique + d) as u64, entries[d % unique].1.clone()));
    }
    Arc::new(Corpus::build("test", &entries, cfg.n_max, cfg.num_labels).unwrap())
}

#[test]
fn topk_runs_exactly_unique_graphs_gcn_forwards() {
    let mut eng = engine();
    let corpus = corpus_with_dups(7, 20, 12); // 32 candidates, 20 unique
    assert_eq!(corpus.len(), 32);
    assert_eq!(corpus.unique_graphs(), 20);
    let cfg = ModelConfig::default();
    let mut rng = Rng::new(8);
    let query = encode(
        &generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels),
        cfg.n_max,
        cfg.num_labels,
    )
    .unwrap();

    let out = eng.score_corpus(&query, corpus.graphs()).unwrap();
    let cache = out.telemetry.embed_cache.expect("native reports cache telemetry");
    // THE acceptance assertion: unique_graphs forwards (+1 for the
    // query graph itself), not 1 + K.
    assert_eq!(
        cache.gcn_forwards(),
        corpus.unique_graphs() as u64 + 1,
        "a corpus query must embed each unique graph exactly once"
    );
    assert_eq!(cache.hits, (corpus.len() - corpus.unique_graphs()) as u64);
    // A second identical query executes zero GCN forwards.
    let warm = eng.score_corpus(&query, corpus.graphs()).unwrap();
    let warm_cache = warm.telemetry.embed_cache.unwrap();
    assert_eq!(warm_cache.gcn_forwards(), 0);
    assert_eq!(warm.telemetry.macs.unwrap(), MacCounts::default());
    assert_eq!(warm.scores, out.scores, "caching must not change scores");
}

#[test]
fn corpus_scores_bit_identical_to_pairwise_across_ladder() {
    let mut cached = engine();
    let corpus = corpus_with_dups(17, 12, 4); // 16 candidates
    let cfg = ModelConfig::default();
    let mut rng = Rng::new(18);
    let qg = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    let query = encode(&qg, cfg.n_max, cfg.num_labels).unwrap();
    let corpus_scores = cached.score_corpus(&query, corpus.graphs()).unwrap().scores;

    // Pairwise reference on a FRESH engine (cold cache) across every
    // ladder batch size, padded tails included: bit-identical.
    let ladder = cached.caps().batch_ladder().to_vec();
    for &b in &ladder {
        let mut fresh = engine();
        let mut got = Vec::new();
        for chunk in corpus.graphs().chunks(b) {
            let pairs: Vec<_> = chunk.iter().map(|c| (query.clone(), c.clone())).collect();
            let filled = pairs.len();
            let pb = PackedBatch::pack(&pairs, b).unwrap();
            let out = fresh.score_batch(&pb).unwrap();
            got.extend_from_slice(&out.scores[..filled]);
        }
        assert_eq!(
            corpus_scores, got,
            "batch size {b}: corpus path diverged from pairwise path"
        );
    }
    // And the warm cached engine re-serves the same bits.
    let again = cached.score_corpus(&query, corpus.graphs()).unwrap().scores;
    assert_eq!(corpus_scores, again);
}

#[test]
fn ranking_matches_manual_sort_of_pairwise_scores() {
    let mut eng = engine();
    let corpus = corpus_with_dups(27, 10, 0);
    let cfg = ModelConfig::default();
    let mut rng = Rng::new(28);
    let query = encode(
        &generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels),
        cfg.n_max,
        cfg.num_labels,
    )
    .unwrap();
    let out = eng.score_corpus(&query, corpus.graphs()).unwrap();
    let top3 = corpus.rank(&out.scores, 3);
    assert_eq!(top3.len(), 3);
    // Best-first, and each (id, score) consistent with the raw fan-out.
    assert!(top3[0].1 >= top3[1].1 && top3[1].1 >= top3[2].1);
    for (id, score) in &top3 {
        assert_eq!(out.scores[*id as usize], *score);
    }
    let max = out.scores.iter().copied().fold(f32::MIN, f32::max);
    assert_eq!(top3[0].1, max);
}

#[test]
fn duplicate_candidate_ids_are_rejected_at_build() {
    // Regression: duplicate ids used to slip through Corpus::build and
    // could surface the same id twice in one top-k response. They are
    // now a typed build-time error (CorpusError::DuplicateId), from
    // both the entry-list and the GraphDb constructors.
    let cfg = ModelConfig::default();
    let mut rng = Rng::new(91);
    let g1 = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    let g2 = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
    let entries = vec![(0u64, g1.clone()), (1, g2.clone()), (0, g2)];
    match Corpus::build("dup", &entries, cfg.n_max, cfg.num_labels) {
        Err(CorpusError::DuplicateId { id }) => assert_eq!(id, 0),
        other => panic!("expected DuplicateId {{ id: 0 }}, got {other:?}"),
    }
    // Distinct ids with duplicate *content* stay legal (the embed
    // cache's whole reason to exist).
    let ok = vec![(0u64, g1.clone()), (1, g1)];
    assert!(Corpus::build("dup-content", &ok, cfg.n_max, cfg.num_labels).is_ok());
}

#[test]
fn topk_rides_the_staged_pipeline_with_native_lanes() {
    let cfg = ModelConfig::default();
    let factory: EngineFactory = {
        let cfg = cfg.clone();
        Arc::new(move || {
            Ok(Box::new(NativeEngine::new(cfg.clone(), Weights::synthetic(&cfg, 2024)))
                as Box<dyn Engine>)
        })
    };
    let pipeline = Pipeline::start(cfg.clone(), vec![factory], PipelineConfig::default());
    let corpus = corpus_with_dups(37, 24, 8); // 32 candidates, 24 unique
    let mut rng = Rng::new(38);
    let db = GraphDb::synthesize(&mut rng, Family::Aids, 6, cfg.n_max, cfg.num_labels);
    // Mixed workload: pair queries interleaved with top-k queries.
    for id in 0..6u64 {
        let g1 = db.graphs[(id as usize) % db.len()].clone();
        let g2 = db.graphs[(id as usize + 1) % db.len()].clone();
        assert!(pipeline.submit(Query::new(id, g1, g2)));
        let q = generate(&mut rng, Family::Aids, cfg.n_max, cfg.num_labels);
        assert!(pipeline.submit(Query::topk(100 + id, q, Arc::clone(&corpus), 5)));
    }
    let metrics = pipeline.finish();
    assert_eq!(metrics.scored, 12, "6 pairs + 6 top-k all answered");
    assert_eq!(metrics.topk, 6);
    assert_eq!(metrics.rejected, 0);
    assert_eq!(metrics.engine_errors, 0);
    // The cache amortizes across queries on the lane: total forwards
    // stay far below the cacheless 6*2 + 6*(1+32).
    assert!(metrics.embed_misses > 0);
    let cacheless = (6 * 2 + 6 * (1 + corpus.len())) as u64;
    assert!(
        metrics.embed_misses < cacheless / 2,
        "cache inactive: {} forwards vs {} cacheless",
        metrics.embed_misses,
        cacheless
    );
    // The serve report carries the new rows.
    let t = metrics.render_table("corpus smoke");
    assert!(t.get("topk queries").is_some());
    assert!(t.get("embed cache hit rate").is_some());
    assert!(t.get("gcn forwards per query").is_some());
}
