//! The repository lints itself: `cargo test` fails if any architectural
//! invariant in `analysis::rules` is violated by the shipped tree
//! (DESIGN.md S18). CI runs the same check as `spa-gcn lint`; this test
//! makes it impossible to merge a violation even without CI.

use std::path::Path;

use spa_gcn::analysis::{report, run_lint, WAIVERS};

#[test]
fn shipped_tree_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let outcome = run_lint(root).expect("scanning the repository source tree");
    assert!(
        outcome.files_scanned > 50,
        "lint scanned only {} files — wrong root?",
        outcome.files_scanned
    );
    assert!(
        outcome.ok(),
        "repository lint found violations:\n{}",
        report::render_text(&outcome)
    );
}

#[test]
fn no_waiver_is_stale_or_malformed() {
    // `run_lint` turns stale/malformed waivers into findings, so the
    // clean-tree assertion above covers them — but check directly too,
    // with a message pointing at waivers.txt, so a dead waiver fails
    // with "fix the waiver file" instead of a generic lint failure.
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let outcome = run_lint(root).expect("scanning the repository source tree");
    let waiver_problems: Vec<_> = outcome
        .findings
        .iter()
        .filter(|f| f.rule.starts_with("WAIVER-"))
        .collect();
    assert!(
        waiver_problems.is_empty(),
        "rust/src/analysis/waivers.txt has dead entries:\n{}",
        waiver_problems
            .iter()
            .map(|f| f.render())
            .collect::<Vec<_>>()
            .join("\n")
    );
    // And the waiver file itself is exercised: the shipped tree relies
    // on waivers (the pipeline's structural expects), so an empty or
    // unparsed file would be a silent regression.
    assert!(
        WAIVERS.lines().any(|l| l.trim_start().starts_with("PANIC-FREE")),
        "waivers.txt lost its PANIC-FREE entries"
    );
}
