//! Failure injection: every loader must fail loudly (never silently
//! truncate or mis-shape) when artifacts are corrupt, and the serving
//! path must degrade gracefully — including a lane dying mid-scatter
//! of a sharded top-k query (DESIGN.md S15): the query must resolve
//! with one typed error, the gather stage must not hang, and sibling
//! queries must be unaffected.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use spa_gcn::coordinator::batcher::BatchPolicy;
use spa_gcn::coordinator::corpus::Corpus;
use spa_gcn::coordinator::pipeline::{Pipeline, PipelineConfig};
use spa_gcn::coordinator::query::Query;
use spa_gcn::graph::encode::{EncodedGraph, PackedBatch};
use spa_gcn::graph::Graph;
use spa_gcn::nn::config::{ArtifactsMeta, ModelConfig};
use spa_gcn::nn::weights::Weights;
use spa_gcn::runtime::embed_cache::CachedEmbed;
use spa_gcn::runtime::pjrt::XlaEngine;
use spa_gcn::runtime::{
    BatchOutput, CorpusOutput, Engine, EngineCaps, EngineError, EngineFactory, MacCounts,
    QueryEmbed, QueryTelemetry,
};
use spa_gcn::util::json::parse;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        None
    }
}

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("spa_gcn_fail_{name}"));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn copy_artifacts(src: &Path, dst: &Path) {
    for entry in fs::read_dir(src).unwrap() {
        let e = entry.unwrap();
        if e.file_type().unwrap().is_file() {
            fs::copy(e.path(), dst.join(e.file_name())).unwrap();
        }
    }
}

#[test]
fn truncated_weights_rejected() {
    let Some(src) = artifacts() else { return };
    let dir = scratch("truncweights");
    copy_artifacts(&src, &dir);
    let bytes = fs::read(dir.join("weights.bin")).unwrap();
    fs::write(dir.join("weights.bin"), &bytes[..bytes.len() - 8]).unwrap();
    let meta = ArtifactsMeta::load(&dir).unwrap();
    let err = Weights::load(&meta.config, &dir);
    assert!(err.is_err(), "truncated weights must not load");
}

#[test]
fn manifest_shape_mismatch_rejected() {
    let Some(src) = artifacts() else { return };
    let dir = scratch("badmanifest");
    copy_artifacts(&src, &dir);
    // Corrupt the second tensor's offset in weights.json (gcn_b0 starts
    // at 29*64 = 1856 floats with the default config).
    let doc = fs::read_to_string(dir.join("weights.json")).unwrap();
    let corrupted = doc.replacen("1856", "1857", 1);
    assert_ne!(doc, corrupted, "fixture assumes gcn_b0 offset 1856");
    fs::write(dir.join("weights.json"), corrupted).unwrap();
    let meta = ArtifactsMeta::load(&dir).unwrap();
    assert!(Weights::load(&meta.config, &dir).is_err());
}

#[test]
fn garbage_meta_rejected() {
    let dir = scratch("badmeta");
    fs::write(dir.join("meta.json"), "{not json").unwrap();
    assert!(ArtifactsMeta::load(&dir).is_err());
    fs::write(dir.join("meta.json"), "{}").unwrap();
    assert!(ArtifactsMeta::load(&dir).is_err(), "missing config must fail");
}

#[test]
fn missing_hlo_artifact_rejected() {
    let Some(src) = artifacts() else { return };
    let dir = scratch("missinghlo");
    copy_artifacts(&src, &dir);
    fs::remove_file(dir.join("simgnn_b1.hlo.txt")).unwrap();
    assert!(XlaEngine::load(&dir).is_err());
}

#[test]
fn corrupt_hlo_text_rejected() {
    let Some(src) = artifacts() else { return };
    let dir = scratch("badhlo");
    copy_artifacts(&src, &dir);
    fs::write(dir.join("simgnn_b1.hlo.txt"), "HloModule garbage { nonsense }").unwrap();
    assert!(XlaEngine::load(&dir).is_err());
}

#[test]
fn default_config_agrees_with_artifacts() {
    // Guards against python/rust config drift: the artifacts' config must
    // parse and match the rust default (they are the same source of truth).
    let Some(dir) = artifacts() else { return };
    let meta = ArtifactsMeta::load(&dir).unwrap();
    assert_eq!(meta.config, ModelConfig::default());
}

/// Shard-capable engine double with injectable failures. `score_batch`
/// always works (pair traffic must survive the injected corpus
/// failures), `embed_query`/`score_corpus_with` fail on demand.
struct FlakyShardEngine {
    caps: EngineCaps,
    fail_embed: bool,
    fail_shard: bool,
    shard_calls: Arc<AtomicU64>,
}

impl Engine for FlakyShardEngine {
    fn caps(&self) -> &EngineCaps {
        &self.caps
    }
    fn score_batch(&mut self, batch: &PackedBatch) -> Result<BatchOutput, EngineError> {
        Ok(BatchOutput::untimed(vec![0.5; batch.batch]))
    }
    fn score_corpus(
        &mut self,
        _query: &EncodedGraph,
        corpus: &[EncodedGraph],
    ) -> Result<CorpusOutput, EngineError> {
        Ok(CorpusOutput {
            scores: (0..corpus.len()).map(|i| 1.0 / (1.0 + i as f32)).collect(),
            telemetry: QueryTelemetry::default(),
        })
    }
    fn embed_query(&mut self, _query: &EncodedGraph) -> Result<QueryEmbed, EngineError> {
        if self.fail_embed {
            return Err(EngineError::Backend {
                engine: "flaky-shard".into(),
                detail: "embed killed mid-scatter".into(),
            });
        }
        Ok(QueryEmbed {
            embed: Arc::new(CachedEmbed {
                hg: vec![0.5; 4],
                macs: MacCounts::default(),
            }),
            telemetry: QueryTelemetry::default(),
        })
    }
    fn score_corpus_with(
        &mut self,
        _query_hg: &[f32],
        shard: &[EncodedGraph],
    ) -> Result<CorpusOutput, EngineError> {
        self.shard_calls.fetch_add(1, Ordering::Relaxed);
        if self.fail_shard {
            return Err(EngineError::Backend {
                engine: "flaky-shard".into(),
                detail: "shard killed mid-scatter".into(),
            });
        }
        Ok(CorpusOutput {
            scores: vec![0.5; shard.len()],
            telemetry: QueryTelemetry::default(),
        })
    }
}

fn flaky_factory(
    fail_embed: bool,
    fail_shard: bool,
    shard_calls: Arc<AtomicU64>,
) -> EngineFactory {
    Arc::new(move || {
        Ok(Box::new(FlakyShardEngine {
            caps: EngineCaps::new("flaky-shard", vec![1, 4], 8, 4)
                .with_corpus_scoring()
                .with_corpus_sharding(),
            fail_embed,
            fail_shard,
            shard_calls: Arc::clone(&shard_calls),
        }) as Box<dyn Engine>)
    })
}

fn shard_model() -> ModelConfig {
    ModelConfig {
        n_max: 8,
        num_labels: 4,
        ..ModelConfig::default()
    }
}

fn shard_pipeline_config() -> PipelineConfig {
    PipelineConfig {
        policy: BatchPolicy {
            max_batch: 4,
            timeout: Duration::from_micros(100),
        },
        ..PipelineConfig::default()
    }
}

fn shard_corpus(entries: usize) -> Arc<Corpus> {
    let graphs: Vec<(u64, Graph)> = (0..entries)
        .map(|i| {
            (
                i as u64,
                Graph::new(3, vec![(0, 1), (1, 2)], vec![0, 1, (i % 4) as u16]),
            )
        })
        .collect();
    Arc::new(Corpus::build("flaky", &graphs, 8, 4).unwrap())
}

fn pair_query(id: u64) -> Query {
    let g = Graph::new(3, vec![(0, 1), (1, 2)], vec![0, 1, 2]);
    Query::new(id, g.clone(), g)
}

#[test]
fn lane_killed_mid_scatter_resolves_with_one_typed_error() {
    // One healthy shard lane + one whose shard scoring dies: every
    // scattered query must resolve as exactly one typed EngineError —
    // no gather hang (finish() returning IS the no-hang witness) and
    // no lost sibling pair queries.
    let shard_calls = Arc::new(AtomicU64::new(0));
    let pipeline = Pipeline::start(
        shard_model(),
        vec![
            flaky_factory(false, false, Arc::clone(&shard_calls)),
            flaky_factory(false, true, Arc::clone(&shard_calls)),
        ],
        shard_pipeline_config(),
    );
    assert_eq!(pipeline.wait_ready(), 2);
    let corpus = shard_corpus(6);
    for id in 0..3 {
        assert!(pipeline.submit(pair_query(id)));
    }
    for id in 3..5 {
        assert!(pipeline.submit(Query::topk(
            id,
            Graph::new(2, vec![(0, 1)], vec![0, 1]),
            Arc::clone(&corpus),
            2,
        )));
    }
    for id in 5..8 {
        assert!(pipeline.submit(pair_query(id)));
    }
    let metrics = pipeline.finish();
    assert_eq!(metrics.scored, 6, "sibling pair queries must all survive");
    assert_eq!(metrics.topk, 0);
    assert_eq!(
        metrics.engine_errors, 2,
        "each scattered query resolves exactly once, as a typed error"
    );
    assert_eq!(metrics.rejected, 0);
    // Both lanes really were scattered to (2 shards per query).
    assert_eq!(shard_calls.load(Ordering::Relaxed), 4);
}

#[test]
fn embedder_death_poisons_siblings_instead_of_hanging_them() {
    // Both lanes fail at embed time: whichever lane draws the embedder
    // shard dies, the poisoned cell fails the waiting sibling fast, and
    // the gather stage still resolves the query exactly once. Pair
    // traffic on the same lanes is untouched.
    let shard_calls = Arc::new(AtomicU64::new(0));
    let factory = flaky_factory(true, false, Arc::clone(&shard_calls));
    let pipeline = Pipeline::start(
        shard_model(),
        vec![Arc::clone(&factory), factory],
        shard_pipeline_config(),
    );
    assert_eq!(pipeline.wait_ready(), 2);
    for id in 0..4 {
        assert!(pipeline.submit(pair_query(id)));
    }
    assert!(pipeline.submit(Query::topk(
        9,
        Graph::new(2, vec![(0, 1)], vec![0, 1]),
        shard_corpus(6),
        3,
    )));
    let metrics = pipeline.finish();
    assert_eq!(metrics.scored, 4);
    assert_eq!(metrics.engine_errors, 1, "one typed error for the scattered query");
    assert_eq!(metrics.topk, 0);
    // The embedder died before scoring, so at most the sibling's
    // (cell-poisoned, never-scored) shard could have been attempted:
    // no shard may have produced scores.
    assert_eq!(shard_calls.load(Ordering::Relaxed), 0);
}

#[test]
fn json_parser_survives_adversarial_inputs() {
    // Robustness sweep: none of these may panic.
    for bad in [
        "", "{", "}", "[", "]", "nul", "tru", "\"", "\"\\", "\"\\u12", "1e",
        "{\"a\"}", "{\"a\":}", "[1,,2]", "{\"a\":1,}", "\u{7f}", "[[[[[[[[",
        "-", "+1", "01x", "{\"k\": \"\\q\"}",
    ] {
        let _ = parse(bad);
    }
    // Deeply nested arrays parse without stack issues at moderate depth.
    let deep = format!("{}1{}", "[".repeat(200), "]".repeat(200));
    assert!(parse(&deep).is_ok());
}
