//! Failure injection: every loader must fail loudly (never silently
//! truncate or mis-shape) when artifacts are corrupt, and the serving
//! path must degrade gracefully.

use std::fs;
use std::path::{Path, PathBuf};

use spa_gcn::nn::config::{ArtifactsMeta, ModelConfig};
use spa_gcn::nn::weights::Weights;
use spa_gcn::runtime::pjrt::XlaEngine;
use spa_gcn::util::json::parse;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("meta.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts missing (run `make artifacts`)");
        None
    }
}

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("spa_gcn_fail_{name}"));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn copy_artifacts(src: &Path, dst: &Path) {
    for entry in fs::read_dir(src).unwrap() {
        let e = entry.unwrap();
        if e.file_type().unwrap().is_file() {
            fs::copy(e.path(), dst.join(e.file_name())).unwrap();
        }
    }
}

#[test]
fn truncated_weights_rejected() {
    let Some(src) = artifacts() else { return };
    let dir = scratch("truncweights");
    copy_artifacts(&src, &dir);
    let bytes = fs::read(dir.join("weights.bin")).unwrap();
    fs::write(dir.join("weights.bin"), &bytes[..bytes.len() - 8]).unwrap();
    let meta = ArtifactsMeta::load(&dir).unwrap();
    let err = Weights::load(&meta.config, &dir);
    assert!(err.is_err(), "truncated weights must not load");
}

#[test]
fn manifest_shape_mismatch_rejected() {
    let Some(src) = artifacts() else { return };
    let dir = scratch("badmanifest");
    copy_artifacts(&src, &dir);
    // Corrupt the second tensor's offset in weights.json (gcn_b0 starts
    // at 29*64 = 1856 floats with the default config).
    let doc = fs::read_to_string(dir.join("weights.json")).unwrap();
    let corrupted = doc.replacen("1856", "1857", 1);
    assert_ne!(doc, corrupted, "fixture assumes gcn_b0 offset 1856");
    fs::write(dir.join("weights.json"), corrupted).unwrap();
    let meta = ArtifactsMeta::load(&dir).unwrap();
    assert!(Weights::load(&meta.config, &dir).is_err());
}

#[test]
fn garbage_meta_rejected() {
    let dir = scratch("badmeta");
    fs::write(dir.join("meta.json"), "{not json").unwrap();
    assert!(ArtifactsMeta::load(&dir).is_err());
    fs::write(dir.join("meta.json"), "{}").unwrap();
    assert!(ArtifactsMeta::load(&dir).is_err(), "missing config must fail");
}

#[test]
fn missing_hlo_artifact_rejected() {
    let Some(src) = artifacts() else { return };
    let dir = scratch("missinghlo");
    copy_artifacts(&src, &dir);
    fs::remove_file(dir.join("simgnn_b1.hlo.txt")).unwrap();
    assert!(XlaEngine::load(&dir).is_err());
}

#[test]
fn corrupt_hlo_text_rejected() {
    let Some(src) = artifacts() else { return };
    let dir = scratch("badhlo");
    copy_artifacts(&src, &dir);
    fs::write(dir.join("simgnn_b1.hlo.txt"), "HloModule garbage { nonsense }").unwrap();
    assert!(XlaEngine::load(&dir).is_err());
}

#[test]
fn default_config_agrees_with_artifacts() {
    // Guards against python/rust config drift: the artifacts' config must
    // parse and match the rust default (they are the same source of truth).
    let Some(dir) = artifacts() else { return };
    let meta = ArtifactsMeta::load(&dir).unwrap();
    assert_eq!(meta.config, ModelConfig::default());
}

#[test]
fn json_parser_survives_adversarial_inputs() {
    // Robustness sweep: none of these may panic.
    for bad in [
        "", "{", "}", "[", "]", "nul", "tru", "\"", "\"\\", "\"\\u12", "1e",
        "{\"a\"}", "{\"a\":}", "[1,,2]", "{\"a\":1,}", "\u{7f}", "[[[[[[[[",
        "-", "+1", "01x", "{\"k\": \"\\q\"}",
    ] {
        let _ = parse(bad);
    }
    // Deeply nested arrays parse without stack issues at moderate depth.
    let deep = format!("{}1{}", "[".repeat(200), "]".repeat(200));
    assert!(parse(&deep).is_ok());
}
