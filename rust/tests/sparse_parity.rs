//! Dense↔sparse scoring-path parity (DESIGN.md S13): the CSR + one-hot
//! native path must produce the same scores as the dense padded path —
//! within 1e-5 per the acceptance bar, bit-identical in practice — on
//! random AIDS-like and Erdős–Rényi workloads, across every ladder batch
//! size, padded tail slots included. Also pins the sparse path's work
//! accounting to the cycle simulator's nonzero-stream model.
//!
//! Runs artifact-free: weights are deterministic pseudo-random.

use spa_gcn::graph::encode::{encode, EncodedGraph, PackedBatch};
use spa_gcn::graph::generate::{generate, Family};
use spa_gcn::nn::config::ModelConfig;
use spa_gcn::nn::simgnn::{gcn_forward_with, simgnn_forward_with, SparsePolicy};
use spa_gcn::nn::weights::Weights;
use spa_gcn::runtime::native::NativeEngine;
use spa_gcn::runtime::Engine;
use spa_gcn::sim::ft::nonzero_stream;
use spa_gcn::util::prop::check;
use spa_gcn::util::rng::Rng;

/// Deterministic pseudo-random weights (the shared artifact-free
/// constructor — one manifest-shaped builder for every test file).
fn default_weights(cfg: &ModelConfig, seed: u64) -> Weights {
    Weights::synthetic(cfg, seed)
}

fn random_graph(rng: &mut Rng, cfg: &ModelConfig) -> EncodedGraph {
    // Alternate between the AIDS-like family and Erdős–Rényi of varied
    // size so both workload shapes of the acceptance bar are covered.
    let g = if rng.below(2) == 0 {
        generate(rng, Family::Aids, cfg.n_max, cfg.num_labels)
    } else {
        let n = 2 + rng.below(10);
        generate(
            rng,
            Family::ErdosRenyi { n, p_millis: 300 },
            cfg.n_max,
            cfg.num_labels,
        )
    };
    encode(&g, cfg.n_max, cfg.num_labels).unwrap()
}

#[test]
fn property_dense_and_sparse_scores_agree_across_ladder() {
    let cfg = ModelConfig::default();
    let weights = default_weights(&cfg, 0xFEED);
    let ladder = NativeEngine::new(cfg.clone(), weights.clone())
        .caps()
        .batch_ladder()
        .to_vec();
    check(
        "dense-sparse-parity",
        12,
        |rng: &mut Rng| {
            // Random fill degree: from a single pair up to a full batch at
            // some ladder size (the rest of the slots are zero padding).
            let b = ladder[rng.below(ladder.len())];
            let fill = 1 + rng.below(b);
            let pairs: Vec<_> = (0..fill)
                .map(|_| (random_graph(rng, &cfg), random_graph(rng, &cfg)))
                .collect();
            (b, pairs)
        },
        |(b, pairs)| {
            let mut sparse = NativeEngine::new(cfg.clone(), weights.clone());
            let mut dense = NativeEngine::new(cfg.clone(), weights.clone())
                .with_policy(SparsePolicy::Dense);
            let pb = PackedBatch::pack(pairs, *b).map_err(|e| e.to_string())?;
            let s = sparse.score_batch(&pb).map_err(|e| e.to_string())?;
            let d = dense.score_batch(&pb).map_err(|e| e.to_string())?;
            for (i, (ss, ds)) in s.scores.iter().zip(d.scores.iter()).enumerate() {
                if (ss - ds).abs() >= 1e-5 {
                    return Err(format!(
                        "batch {b} slot {i} (fill {}): sparse {ss} vs dense {ds}",
                        pairs.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn sparse_trace_matches_dense_trace_exactly() {
    // Beyond scores: the per-layer intermediates the cycle simulator
    // consumes must be identical, so a sim driven by either path sees
    // the same nonzero structure.
    let cfg = ModelConfig::default();
    let w = default_weights(&cfg, 0xBEEF);
    let mut rng = Rng::new(21);
    for _ in 0..8 {
        let e = random_graph(&mut rng, &cfg);
        let d = gcn_forward_with(&cfg, &w, &e, SparsePolicy::Dense);
        let s = gcn_forward_with(&cfg, &w, &e, SparsePolicy::Csr);
        assert_eq!(d.embeddings, s.embeddings);
        assert_eq!(d.layer_inputs, s.layer_inputs);
        assert_eq!(d.input_sparsity, s.input_sparsity);
    }
}

#[test]
fn sparse_mac_counts_match_nonzero_stream_on_the_same_trace() {
    // The satellite bar: sparse-path FT element counts equal the element
    // counts of `sim::ft::nonzero_stream` on the same trace — the
    // software path and the cycle model prune identically.
    let cfg = ModelConfig::default();
    let w = default_weights(&cfg, 0xCAFE);
    let dims_in = cfg.feature_dims();
    let mut rng = Rng::new(33);
    for _ in 0..8 {
        let e1 = random_graph(&mut rng, &cfg);
        let e2 = random_graph(&mut rng, &cfg);
        let pt = simgnn_forward_with(&cfg, &w, &e1, &e2, SparsePolicy::Csr);
        for (t, e) in [(&pt.trace1, &e1), (&pt.trace2, &e2)] {
            let mut stream_total = 0u64;
            for layer in 0..3 {
                let stream = nonzero_stream(&t.layer_inputs[layer], e.num_nodes, dims_in[layer]);
                assert_eq!(
                    t.ft_elements[layer],
                    stream.len() as u64,
                    "layer {layer} FT elements vs nonzero stream"
                );
                stream_total += stream.len() as u64;
            }
            // MAC totals decompose as Σ nnz·f_out per stage.
            let ft_macs: u64 = (0..3)
                .map(|l| t.ft_elements[l] * cfg.filters[l] as u64)
                .sum();
            let agg_macs: u64 = cfg
                .filters
                .iter()
                .map(|&f| e.csr.nnz() as u64 * f as u64)
                .sum();
            assert_eq!(t.macs, ft_macs + agg_macs);
            assert_eq!(t.ft_elements.iter().sum::<u64>(), stream_total);
            assert_eq!(t.agg_elements, 3 * e.csr.nnz() as u64);
        }
    }
}
