//! Report harness: regenerates every table and figure of the paper's
//! evaluation section (§5) from the cycle simulator, the analytical
//! baselines and the real measured engines. Shared by the CLI
//! (`spa-gcn report <name>`) and the benches.

pub mod tables;

use std::fmt::Write as _;

/// A rendered report table: header + rows, printable as aligned text and
/// serializable to JSON for EXPERIMENTS.md tooling.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity");
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: &str) {
        self.notes.push(s.to_string());
    }

    /// Value cell of the first row whose label (first column) matches —
    /// lets callers read metric tables by name instead of brittle row
    /// indices.
    pub fn get(&self, label: &str) -> Option<&str> {
        self.rows
            .iter()
            .find(|r| r.first().is_some_and(|c| c == label))
            .and_then(|r| r.get(1))
            .map(String::as_str)
    }

    /// Render as aligned monospace text.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", line(&self.columns, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (widths.len() - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", line(row, &widths));
        }
        for n in &self.notes {
            let _ = writeln!(out, "  note: {n}");
        }
        out
    }

    /// Serialize to a JSON value (for machine-readable report dumps).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{arr, obj, s, Json};
        obj(vec![
            ("title", s(&self.title)),
            (
                "columns",
                arr(self.columns.iter().map(|c| s(c)).collect()),
            ),
            (
                "rows",
                arr(self
                    .rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| s(c)).collect()))
                    .collect()),
            ),
            ("notes", arr(self.notes.iter().map(|n| s(n)).collect())),
        ])
    }
}

/// Format a float with sensible precision for report cells.
pub fn fmt(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 1000.0 {
        format!("{v:.0}")
    } else if v.abs() >= 10.0 {
        format!("{v:.1}")
    } else if v.abs() >= 0.1 {
        format!("{v:.3}")
    } else {
        format!("{v:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["a", "bee"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        t.note("hello");
        let r = t.render();
        assert!(r.contains("== demo =="));
        assert!(r.contains("a    bee"));
        assert!(r.contains("note: hello"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn rejects_bad_rows() {
        let mut t = Table::new("demo", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn get_by_label() {
        let mut t = Table::new("demo", &["Metric", "Value"]);
        t.row(vec!["throughput".into(), "123".into()]);
        t.row(vec!["p50".into(), "4.5".into()]);
        assert_eq!(t.get("p50"), Some("4.5"));
        assert_eq!(t.get("missing"), None);
    }

    #[test]
    fn fmt_precision() {
        assert_eq!(fmt(0.0), "0");
        assert_eq!(fmt(1234.6), "1235");
        assert_eq!(fmt(12.34), "12.3");
        assert_eq!(fmt(0.3274), "0.327");
        assert_eq!(fmt(0.0123), "0.0123");
    }
}
