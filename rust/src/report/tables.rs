//! Regeneration of every table/figure in the paper's evaluation (§5).
//!
//! Absolute simulator times are cycle counts at modeled frequencies and
//! are NOT claimed to match the authors' silicon; the reproduction
//! targets are the ratios (ablation deltas, platform ordering, FPGA vs
//! CPU vs GPU, batching knee). EXPERIMENTS.md records paper-vs-measured
//! for each row.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::graph::dataset::{random_pairs, GraphDb, QueryPair};
use crate::graph::encode::{encode, PackedBatch};
use crate::graph::generate::Family;
use crate::nn::config::{ArtifactsMeta, ModelConfig};
use crate::nn::simgnn::{gcn_forward, simgnn_forward};
use crate::nn::weights::Weights;
use crate::runtime::pjrt::XlaEngine;
use crate::runtime::Engine;
use crate::sim::baseline::{CpuModel, GpuModel, QueryWork};
use crate::sim::config::{ArchConfig, LayerParams};
use crate::sim::e2e::{batching_sweep, e2e_ms_per_query, query_bytes, HostOverhead};
use crate::sim::gcn::simulate_query;
use crate::sim::platform::{Platform, ALL_PLATFORMS, U280};
use crate::sim::resources::{gcn_resources, max_replicas, simgnn_resources, Resources};
use crate::util::rng::Rng;

use super::{fmt, Table};

/// Everything the harness needs from `make artifacts`.
#[derive(Debug)]
pub struct Context {
    pub cfg: ModelConfig,
    pub weights: Weights,
    pub artifacts_dir: std::path::PathBuf,
}

impl Context {
    pub fn load(artifacts_dir: &Path) -> Result<Context> {
        let meta = ArtifactsMeta::load(artifacts_dir)?;
        let weights = Weights::load(&meta.config, artifacts_dir)?;
        Ok(Context {
            cfg: meta.config,
            weights,
            artifacts_dir: artifacts_dir.to_path_buf(),
        })
    }

    /// The evaluation workload: random pairs from an AIDS-like database
    /// (paper §5.1: 10,000 pairs; default here is smaller for test speed,
    /// benches pass the full count).
    pub fn workload(&self, queries: usize, seed: u64) -> Vec<QueryPair> {
        let mut rng = Rng::new(seed);
        let db = GraphDb::synthesize(
            &mut rng,
            Family::Aids,
            512,
            self.cfg.n_max,
            self.cfg.num_labels,
        );
        random_pairs(&mut rng, &db, queries)
    }
}

/// Mean steady-state kernel ms + mean query stats for an (arch, platform)
/// over a workload.
#[derive(Debug)]
pub struct SimRun {
    pub kernel_ms: f64,
    pub mean_interval_cycles: f64,
    pub ft_bubbles_per_query: f64,
    pub mean_nodes: f64,
    pub mean_edges: f64,
}

pub fn simulate_workload(
    ctx: &Context,
    arch: &ArchConfig,
    plat: &Platform,
    pairs: &[QueryPair],
) -> SimRun {
    let mut total_interval = 0u64;
    let mut bubbles = 0u64;
    let mut nodes = 0usize;
    let mut edges = 0usize;
    for q in pairs {
        let e1 = encode(&q.g1, ctx.cfg.n_max, ctx.cfg.num_labels).unwrap();
        let e2 = encode(&q.g2, ctx.cfg.n_max, ctx.cfg.num_labels).unwrap();
        let t1 = gcn_forward(&ctx.cfg, &ctx.weights, &e1);
        let t2 = gcn_forward(&ctx.cfg, &ctx.weights, &e2);
        let qc = simulate_query(
            &ctx.cfg,
            arch,
            plat,
            (&q.g1, &e1, &t1),
            (&q.g2, &e2, &t2),
        );
        total_interval += qc.interval;
        for g in [&qc.gcn1, &qc.gcn2] {
            for l in &g.layers {
                bubbles += l.ft.raw_bubbles;
            }
        }
        nodes += q.g1.num_nodes() + q.g2.num_nodes();
        edges += q.g1.num_edges() + q.g2.num_edges();
    }
    let n = pairs.len().max(1) as f64;
    let mean_interval = total_interval as f64 / n;
    SimRun {
        kernel_ms: mean_interval / (plat.achieved_freq_mhz(arch.variant) * 1e3),
        mean_interval_cycles: mean_interval,
        ft_bubbles_per_query: bubbles as f64 / n,
        mean_nodes: nodes as f64 / (2.0 * n),
        mean_edges: edges as f64 / (2.0 * n),
    }
}

fn params_str(arch: &ArchConfig) -> (String, String, String, String) {
    let f = |get: fn(&LayerParams) -> usize| -> String {
        if arch.dataflow() {
            format!(
                "{}/{}/{}",
                get(&arch.layers[0]),
                get(&arch.layers[1]),
                get(&arch.layers[2])
            )
        } else {
            format!("{}", get(&arch.layers[0]))
        }
    };
    (
        f(|p| p.simd_ft),
        f(|p| p.simd_agg),
        f(|p| p.df),
        if arch.sparse_ft() { f(|p| p.p) } else { "-".into() },
    )
}

/// Table 3: platform properties (sanity echo of the constants).
pub fn table3() -> Table {
    let mut t = Table::new(
        "Table 3: FPGA platform properties",
        &["Platform", "BRAM(Mb)", "LUT(K)", "FF(K)", "DSP", "URAM(Mb)", "MaxBW(GB/s)"],
    );
    for p in &ALL_PLATFORMS {
        t.row(vec![
            p.name.into(),
            fmt(p.bram_mb),
            fmt(p.lut_k),
            fmt(p.ff_k),
            format!("{}", p.dsp),
            fmt(p.uram_mb),
            fmt(p.max_bw_gbs),
        ]);
    }
    t
}

/// Table 4: impact of the GCN architecture optimizations on U280.
pub fn table4(ctx: &Context, queries: usize) -> Table {
    let pairs = ctx.workload(queries, 0x7ab1e4);
    let variants: Vec<(&str, ArchConfig)> = vec![
        ("Baseline", ArchConfig::baseline()),
        ("+Inter-Layer Pipeline", ArchConfig::inter_layer()),
        ("+Extended Sparsity", ArchConfig::extended_sparsity()),
    ];
    let mut t = Table::new(
        "Table 4: GCN architecture ablation on U280 (paper: kernel 1x/1.56x/2.27x, Kernel*DSP 1x/0.66x/3.88x)",
        &["Architecture", "SIMD_FT", "SIMD_Agg", "DF", "P", "DSP", "DSP(%)",
          "Freq(MHz)", "Kernel(ms)", "Speedup", "Kernel*DSP", "vs base"],
    );
    let mut base_kernel = 0.0;
    let mut base_kdsp = 0.0;
    for (i, (name, arch)) in variants.iter().enumerate() {
        let run = simulate_workload(ctx, arch, &U280, &pairs);
        let res = gcn_resources(&ctx.cfg, arch);
        let kdsp = run.kernel_ms * res.dsp;
        if i == 0 {
            base_kernel = run.kernel_ms;
            base_kdsp = kdsp;
        }
        let (s_ft, s_agg, df, p) = params_str(arch);
        t.row(vec![
            name.to_string(),
            s_ft,
            s_agg,
            df,
            p,
            fmt(res.dsp),
            fmt(res.utilization(&U280)[2]),
            fmt(U280.achieved_freq_mhz(arch.variant)),
            fmt(run.kernel_ms),
            format!("{:.2}x", base_kernel / run.kernel_ms),
            fmt(kdsp),
            format!("{:.2}x", base_kdsp / kdsp),
        ]);
    }
    t.note("paper row order: baseline 0.599ms/4.46 -> +IL 0.383/6.74 -> +ES 0.264/1.15");
    t.note("absolute times are simulator cycles x modeled freq; compare ratios");
    t
}

/// Table 5: whole SimGNN pipeline across the three FPGAs.
pub fn table5(ctx: &Context, queries: usize) -> Table {
    let pairs = ctx.workload(queries, 0x7ab1e5);
    let arch = ArchConfig::spa_gcn();
    let mut t = Table::new(
        "Table 5: SPA-GCN (full SimGNN) on three FPGAs (paper: 0.786/0.423/0.327 kernel ms; 881/1858/1965 q/s)",
        &["FPGA", "LUT/FF/DSP/BRAM/URAM (%)", "Freq(MHz)", "Kernel(ms)",
          "E2E(ms)", "E2E(query/s)"],
    );
    for plat in &ALL_PLATFORMS {
        let run = simulate_workload(ctx, &arch, plat, &pairs);
        let res = simgnn_resources(&ctx.cfg, &arch).total;
        let u = res.utilization(plat);
        let over = HostOverhead::for_platform(plat);
        let bytes = query_bytes(run.mean_nodes as usize, run.mean_edges as usize);
        let e2e = e2e_ms_per_query(run.kernel_ms, bytes, plat, &over, 1);
        t.row(vec![
            plat.name.into(),
            format!(
                "{:.0}/{:.0}/{:.1}/{:.0}/{:.1}",
                u[0], u[1], u[2], u[3], u[4]
            ),
            fmt(plat.achieved_freq_mhz(arch.variant)),
            fmt(run.kernel_ms),
            fmt(e2e),
            fmt(1000.0 / e2e),
        ]);
    }
    t.note("HBM parts run faster than the DDR part via higher achieved clock + FPU latency (paper §5.4.1)");
    t
}

/// Measured engine timings (rust native + PJRT) on a workload.
#[derive(Debug)]
pub struct Measured {
    pub name: String,
    pub kernel_ms: f64,
    pub e2e_ms: f64,
}

pub fn measure_native(ctx: &Context, pairs: &[QueryPair]) -> Measured {
    let t0 = Instant::now();
    let mut encoded = Vec::with_capacity(pairs.len());
    for q in pairs {
        encoded.push((
            encode(&q.g1, ctx.cfg.n_max, ctx.cfg.num_labels).unwrap(),
            encode(&q.g2, ctx.cfg.n_max, ctx.cfg.num_labels).unwrap(),
        ));
    }
    let prep = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let mut acc = 0.0f32;
    for (e1, e2) in &encoded {
        // The uncached fused forward, NOT the engine's cache-aware
        // score_pair: this row is the measured cost of a full native
        // forward, compared against the uncached PJRT engine — repeated
        // database graphs must not be served from the embedding cache.
        acc += simgnn_forward(&ctx.cfg, &ctx.weights, e1, e2).score;
    }
    std::hint::black_box(acc);
    let kernel = t1.elapsed().as_secs_f64();
    let n = pairs.len().max(1) as f64;
    Measured {
        name: "rust-native (measured)".into(),
        kernel_ms: kernel * 1000.0 / n,
        e2e_ms: (kernel + prep) * 1000.0 / n,
    }
}

pub fn measure_pjrt(ctx: &Context, pairs: &[QueryPair], batch: usize) -> Result<Measured> {
    let mut eng = XlaEngine::load(&ctx.artifacts_dir)?;
    let b = eng.caps().pick_batch_size(batch);
    let t0 = Instant::now();
    let encoded: Vec<_> = pairs
        .iter()
        .map(|q| {
            (
                encode(&q.g1, ctx.cfg.n_max, ctx.cfg.num_labels).unwrap(),
                encode(&q.g2, ctx.cfg.n_max, ctx.cfg.num_labels).unwrap(),
            )
        })
        .collect();
    let prep = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let mut kernel = 0.0f64;
    for chunk in encoded.chunks(b) {
        let pb = PackedBatch::pack(chunk, b).expect("chunks(b) yields 1..=b pairs");
        let te = Instant::now();
        let scores = eng.score_batch(&pb)?.scores;
        kernel += te.elapsed().as_secs_f64();
        std::hint::black_box(scores);
    }
    let wall = t1.elapsed().as_secs_f64();
    let n = pairs.len().max(1) as f64;
    Ok(Measured {
        name: format!("pjrt-cpu b={b} (measured)"),
        kernel_ms: kernel * 1000.0 / n,
        e2e_ms: (wall + prep) * 1000.0 / n,
    })
}

/// Table 6: SPA-GCN vs CPU vs GPU.
pub fn table6(ctx: &Context, queries: usize, with_pjrt: bool) -> Table {
    let pairs = ctx.workload(queries, 0x7ab1e6);
    let arch = ArchConfig::spa_gcn();
    let work = QueryWork::from_dims(
        (pairs
            .iter()
            .map(|q| q.g1.num_nodes() + q.g2.num_nodes())
            .sum::<usize>() as f64
            / (2.0 * pairs.len() as f64))
            .round() as usize,
        ctx.cfg.filters,
        ctx.cfg.num_labels,
        ctx.cfg.ntn_k,
    );
    let cpu = CpuModel::default();
    let gpu = GpuModel::default();
    let cpu_e2e = cpu.e2e_ms(&work);
    let gpu_e2e = gpu.e2e_ms(&work);

    let mut t = Table::new(
        "Table 6: SimGNN on different hardware (paper: U280 18.2x over CPU, 26.9x over GPU; GPU 0.68x of CPU)",
        &["Platform", "MaxBW(GB/s)", "Kernel(ms)", "E2E(ms)", "Speedup/CPU", "Speedup/GPU"],
    );
    for plat in &ALL_PLATFORMS {
        let run = simulate_workload(ctx, &arch, plat, &pairs);
        let over = HostOverhead::for_platform(plat);
        let bytes = query_bytes(run.mean_nodes as usize, run.mean_edges as usize);
        let e2e = e2e_ms_per_query(run.kernel_ms, bytes, plat, &over, 1);
        t.row(vec![
            format!("{} (sim)", plat.name),
            fmt(plat.max_bw_gbs),
            fmt(run.kernel_ms),
            fmt(e2e),
            format!("{:.1}", cpu_e2e / e2e),
            format!("{:.1}", gpu_e2e / e2e),
        ]);
    }
    t.row(vec![
        "PyG-CPU (model)".into(),
        "76.8".into(),
        fmt(cpu.kernel_ms(&work)),
        fmt(cpu_e2e),
        "1".into(),
        format!("{:.1}", gpu_e2e / cpu_e2e),
    ]);
    t.row(vec![
        "PyG-GPU V100 (model)".into(),
        "900".into(),
        fmt(gpu.kernel_ms(&work)),
        fmt(gpu_e2e),
        format!("{:.2}", cpu_e2e / gpu_e2e),
        "1".into(),
    ]);
    // Grounded measurements on this machine.
    let nat = measure_native(ctx, &pairs);
    t.row(vec![
        nat.name.clone(),
        "-".into(),
        fmt(nat.kernel_ms),
        fmt(nat.e2e_ms),
        format!("{:.1}", cpu_e2e / nat.e2e_ms),
        format!("{:.1}", gpu_e2e / nat.e2e_ms),
    ]);
    if with_pjrt {
        if let Ok(p) = measure_pjrt(ctx, &pairs, 16) {
            t.row(vec![
                p.name.clone(),
                "-".into(),
                fmt(p.kernel_ms),
                fmt(p.e2e_ms),
                format!("{:.1}", cpu_e2e / p.e2e_ms),
                format!("{:.1}", gpu_e2e / p.e2e_ms),
            ]);
        }
    }
    t.note("CPU/GPU rows use the calibrated analytical models (DESIGN.md substitutions)");
    t.note("GPU slower than CPU: 225 launches x ~41us dominates 4.6KFLOP kernels (paper §5.4.2)");
    t
}

/// Fig. 10: resource breakdown of the whole pipeline on U280.
pub fn fig10(ctx: &Context) -> Table {
    let arch = ArchConfig::spa_gcn();
    let b = simgnn_resources(&ctx.cfg, &arch);
    let mut t = Table::new(
        "Fig 10: resource breakdown of SimGNN on U280 (% of module totals)",
        &["Module", "DSP", "BRAM18", "URAM", "LUT", "FF", "DSP share(%)"],
    );
    let rows: Vec<(&str, &Resources)> = vec![
        ("GCN (3 layers)", &b.gcn),
        ("Att", &b.att),
        ("NTN+FCN", &b.ntn_fcn),
        ("Prefetch/mem", &b.prefetch),
        ("TOTAL", &b.total),
    ];
    for (name, r) in rows {
        t.row(vec![
            name.into(),
            fmt(r.dsp),
            fmt(r.bram18),
            fmt(r.uram),
            fmt(r.lut),
            fmt(r.ff),
            fmt(100.0 * r.dsp / b.total.dsp.max(1.0)),
        ]);
    }
    t.note("paper Fig 10: GCN stage dominates every resource class");
    t
}

/// Fig. 11: effect of batching queries (simulated + measured PJRT).
pub fn fig11(ctx: &Context, queries: usize, with_pjrt: bool) -> Table {
    let pairs = ctx.workload(queries, 0x7ab1f1);
    let arch = ArchConfig::spa_gcn();
    let run = simulate_workload(ctx, &arch, &U280, &pairs);
    let over = HostOverhead::for_platform(&U280);
    let bytes = query_bytes(run.mean_nodes as usize, run.mean_edges as usize);
    let batches = [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 300, 512];
    let sweep = batching_sweep(run.kernel_ms, bytes, &U280, &over, &batches);
    let mut t = Table::new(
        "Fig 11: query batching on U280 (paper: ~300-query batches amortize setup, 2.8x)",
        &["Batch", "sim E2E ms/query", "sim speedup", "measured PJRT ms/query"],
    );
    let base = sweep[0].1;
    // Measured PJRT batching for the sizes with artifacts.
    let mut measured: std::collections::BTreeMap<usize, f64> = Default::default();
    if with_pjrt {
        for &b in &[1usize, 4, 16, 64] {
            if b <= pairs.len() {
                if let Ok(m) = measure_pjrt(ctx, &pairs, b) {
                    measured.insert(b, m.e2e_ms);
                }
            }
        }
    }
    for (b, ms) in &sweep {
        t.row(vec![
            format!("{b}"),
            fmt(*ms),
            format!("{:.2}x", base / ms),
            measured.get(b).map(|m| fmt(*m)).unwrap_or_else(|| "-".into()),
        ]);
    }
    t.note("sim model: E2E/query = kernel + PCIe + (fixed launch)/batch");
    t
}

/// §5.4.3 replication: throughput scaling with multiple pipelines.
pub fn replication(ctx: &Context, queries: usize) -> Table {
    let pairs = ctx.workload(queries, 0x7ab1f2);
    let arch = ArchConfig::spa_gcn();
    let mut t = Table::new(
        "§5.4.3: pipeline replication (paper: 6 pipelines on U280 -> 33,522 query/s)",
        &["FPGA", "Max replicas (80% cap)", "E2E/query(ms, b=512)", "Throughput (query/s)"],
    );
    for plat in &ALL_PLATFORMS {
        let run = simulate_workload(ctx, &arch, plat, &pairs);
        let over = HostOverhead::for_platform(plat);
        let bytes = query_bytes(run.mean_nodes as usize, run.mean_edges as usize);
        let e2e = e2e_ms_per_query(run.kernel_ms, bytes, plat, &over, 512);
        let reps = max_replicas(&ctx.cfg, &arch, plat, 0.8);
        let tput = crate::sim::e2e::replicated_throughput(e2e, run.kernel_ms, bytes, plat, reps);
        t.row(vec![
            plat.name.into(),
            format!("{reps}"),
            fmt(e2e),
            fmt(tput),
        ]);
    }
    t
}

/// §3.4 sparsity statistics on the synthetic AIDS-like workload.
pub fn sparsity(ctx: &Context, queries: usize) -> Table {
    let pairs = ctx.workload(queries, 0x7ab1f3);
    let mut s = [0f64; 3];
    let mut count = 0f64;
    for q in pairs.iter() {
        for g in [&q.g1, &q.g2] {
            let e = encode(g, ctx.cfg.n_max, ctx.cfg.num_labels).unwrap();
            let tr = gcn_forward(&ctx.cfg, &ctx.weights, &e);
            for (i, v) in tr.input_sparsity.iter().enumerate() {
                s[i] += v;
            }
            count += 1.0;
        }
    }
    let mut t = Table::new(
        "§3.4: measured input sparsity per GCN layer (paper: L2 52%, L3 47%)",
        &["Layer input", "Sparsity (%)"],
    );
    t.row(vec!["L1 (one-hot)".into(), fmt(100.0 * s[0] / count)]);
    t.row(vec!["L2 (post-ReLU)".into(), fmt(100.0 * s[1] / count)]);
    t.row(vec!["L3 (post-ReLU)".into(), fmt(100.0 * s[2] / count)]);
    t
}

/// Quick correctness echo: sim score == native score on a few pairs.
pub fn crosscheck(ctx: &Context) -> Table {
    let pairs = ctx.workload(8, 0x7ab1f4);
    let mut t = Table::new(
        "Cross-check: native score vs target (first 8 workload pairs)",
        &["Pair", "|V1|", "|V2|", "Score"],
    );
    for (i, q) in pairs.iter().enumerate() {
        let e1 = encode(&q.g1, ctx.cfg.n_max, ctx.cfg.num_labels).unwrap();
        let e2 = encode(&q.g2, ctx.cfg.n_max, ctx.cfg.num_labels).unwrap();
        let s = simgnn_forward(&ctx.cfg, &ctx.weights, &e1, &e2).score;
        t.row(vec![
            format!("{i}"),
            format!("{}", q.g1.num_nodes()),
            format!("{}", q.g2.num_nodes()),
            fmt(s as f64),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Option<Context> {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("meta.json").exists() {
            eprintln!("SKIP: artifacts missing");
            return None;
        }
        Some(Context::load(&dir).unwrap())
    }

    #[test]
    fn table4_speedups_positive() {
        let Some(ctx) = ctx() else { return };
        let t = table4(&ctx, 12);
        assert_eq!(t.rows.len(), 3);
        // +IL must beat baseline kernel time (col 8 = Kernel(ms))
        let k: Vec<f64> = t.rows.iter().map(|r| r[8].parse().unwrap()).collect();
        assert!(k[1] < k[0], "inter-layer {} !< baseline {}", k[1], k[0]);
        // +ES must win the latency-area product (col 10)
        let kd: Vec<f64> = t.rows.iter().map(|r| r[10].parse().unwrap()).collect();
        assert!(kd[2] < kd[0] && kd[2] < kd[1], "{kd:?}");
    }

    #[test]
    fn table5_platform_ordering() {
        let Some(ctx) = ctx() else { return };
        let t = table5(&ctx, 12);
        let kernel: Vec<f64> = t.rows.iter().map(|r| r[3].parse().unwrap()).collect();
        // KU15P slowest; U280 fastest (paper ordering)
        assert!(kernel[0] > kernel[1] && kernel[1] >= kernel[2], "{kernel:?}");
        let qps: Vec<f64> = t.rows.iter().map(|r| r[5].parse().unwrap()).collect();
        assert!(qps[2] > qps[0], "{qps:?}");
    }

    #[test]
    fn table6_fpga_beats_cpu_beats_gpu() {
        let Some(ctx) = ctx() else { return };
        let t = table6(&ctx, 10, false);
        // row 2 = U280 sim; rows 3/4 = CPU/GPU models
        let e2e: Vec<f64> = t.rows.iter().map(|r| r[3].parse().unwrap()).collect();
        assert!(e2e[2] < e2e[3], "U280 {} !< CPU {}", e2e[2], e2e[3]);
        assert!(e2e[3] < e2e[4], "CPU {} !< GPU {}", e2e[3], e2e[4]);
    }

    #[test]
    fn fig11_monotone() {
        let Some(ctx) = ctx() else { return };
        let t = fig11(&ctx, 10, false);
        let ms: Vec<f64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
        for w in ms.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "{ms:?}");
        }
    }

    #[test]
    fn sparsity_in_paper_regime() {
        let Some(ctx) = ctx() else { return };
        let t = sparsity(&ctx, 8);
        let l2: f64 = t.rows[1][1].parse().unwrap();
        let l3: f64 = t.rows[2][1].parse().unwrap();
        assert!((30.0..80.0).contains(&l2), "L2 {l2}");
        assert!((30.0..80.0).contains(&l3), "L3 {l3}");
    }
}

/// Accuracy context (SimGNN's own evaluation): correlation of each
/// similarity method with exact GED on tiny graphs, plus per-query cost.
/// SimGNN trades a little accuracy for orders-of-magnitude lower latency
/// than combinatorial search — the premise SPA-GCN accelerates.
pub fn accuracy(ctx: &Context, pairs_count: usize) -> Table {
    use crate::ged::heuristics::{beam_ged, greedy_ged};
    use crate::ged::hungarian::hungarian_ged;
    use crate::ged::{exact_ged, ged_similarity};

    let mut rng = Rng::new(0xacc);
    let family = crate::graph::generate::Family::ErdosRenyi { n: 7, p_millis: 250 };
    let db = GraphDb::synthesize(&mut rng, family, 64, ctx.cfg.n_max, ctx.cfg.num_labels);

    // per pair: (exact, nn, greedy, beam, hungarian) similarities
    let mut rows: Vec<(f64, f64, f64, f64, f64)> = Vec::new();
    let mut t_nn = 0.0;
    let mut t_greedy = 0.0;
    let mut t_beam = 0.0;
    let mut t_hung = 0.0;
    let mut t_exact = 0.0;
    for i in 0..pairs_count {
        // Half random database pairs (large GED), half perturbation pairs
        // (small GED) so the target range is covered — the mix SimGNN's
        // own evaluation uses.
        let g1 = db.graphs[rng.below(db.len())].clone();
        let g2 = if i % 2 == 0 {
            db.graphs[rng.below(db.len())].clone()
        } else {
            let k = rng.below(4);
            crate::graph::generate::perturb(&mut rng, &g1, k, ctx.cfg.n_max, ctx.cfg.num_labels)
        };
        let (g1, g2) = (&g1, &g2);
        let e1 = encode(g1, ctx.cfg.n_max, ctx.cfg.num_labels).unwrap();
        let e2 = encode(g2, ctx.cfg.n_max, ctx.cfg.num_labels).unwrap();
        let t = Instant::now();
        let Some(exact) = exact_ged(g1, g2, 3_000_000) else { continue };
        t_exact += t.elapsed().as_secs_f64();
        let sim_exact = ged_similarity(exact, g1.num_nodes(), g2.num_nodes());
        let t = Instant::now();
        // Uncached fused forward: the timing row measures a full
        // inference, not a cache hit on a repeated database graph.
        let nn = simgnn_forward(&ctx.cfg, &ctx.weights, &e1, &e2).score as f64;
        t_nn += t.elapsed().as_secs_f64();
        let t = Instant::now();
        let gr = ged_similarity(greedy_ged(g1, g2), g1.num_nodes(), g2.num_nodes());
        t_greedy += t.elapsed().as_secs_f64();
        let t = Instant::now();
        let bm = ged_similarity(beam_ged(g1, g2, 8), g1.num_nodes(), g2.num_nodes());
        t_beam += t.elapsed().as_secs_f64();
        let t = Instant::now();
        let hu = ged_similarity(hungarian_ged(g1, g2), g1.num_nodes(), g2.num_nodes());
        t_hung += t.elapsed().as_secs_f64();
        rows.push((sim_exact, nn, gr, bm, hu));
    }
    let n = rows.len().max(1) as f64;
    let pearson = |f: &dyn Fn(&(f64, f64, f64, f64, f64)) -> f64| -> f64 {
        let xs: Vec<f64> = rows.iter().map(|r| r.0).collect();
        let ys: Vec<f64> = rows.iter().map(f).collect();
        let mx = xs.iter().sum::<f64>() / n;
        let my = ys.iter().sum::<f64>() / n;
        let cov: f64 = xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum();
        let sx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum::<f64>().sqrt();
        let sy: f64 = ys.iter().map(|y| (y - my).powi(2)).sum::<f64>().sqrt();
        if sx == 0.0 || sy == 0.0 { 0.0 } else { cov / (sx * sy) }
    };
    let mse = |f: &dyn Fn(&(f64, f64, f64, f64, f64)) -> f64| -> f64 {
        rows.iter().map(|r| (f(r) - r.0).powi(2)).sum::<f64>() / n
    };
    let mut t = Table::new(
        "Accuracy: similarity methods vs exact GED (SimGNN premise; tiny 7-node graphs)",
        &["Method", "Pearson vs exact", "MSE vs exact", "mean time/pair (ms)"],
    );
    t.row(vec!["exact A* GED".into(), "1".into(), "0".into(), fmt(1e3 * t_exact / n)]);
    t.row(vec![
        "SimGNN (native engine)".into(),
        format!("{:.3}", pearson(&|r| r.1)),
        format!("{:.4}", mse(&|r| r.1)),
        fmt(1e3 * t_nn / n),
    ]);
    t.row(vec![
        "greedy assignment".into(),
        format!("{:.3}", pearson(&|r| r.2)),
        format!("{:.4}", mse(&|r| r.2)),
        fmt(1e3 * t_greedy / n),
    ]);
    t.row(vec![
        "beam search (w=8)".into(),
        format!("{:.3}", pearson(&|r| r.3)),
        format!("{:.4}", mse(&|r| r.3)),
        fmt(1e3 * t_beam / n),
    ]);
    t.row(vec![
        "hungarian (bipartite)".into(),
        format!("{:.3}", pearson(&|r| r.4)),
        format!("{:.4}", mse(&|r| r.4)),
        fmt(1e3 * t_hung / n),
    ]);
    t.note("SimGNN runs in O(1) model time per pair; combinatorial methods blow up with |V|");
    t
}

/// Energy-per-query comparison (Table 3 TDPs; DESIGN.md energy model).
pub fn energy(ctx: &Context, queries: usize) -> Table {
    use crate::sim::energy::{
        cpu_energy_per_query_mj, design_power_watts, energy_per_query_mj,
        gpu_energy_per_query_mj,
    };
    let pairs = ctx.workload(queries, 0x7ab1e7);
    let arch = ArchConfig::spa_gcn();
    let work = QueryWork::from_dims(26, ctx.cfg.filters, ctx.cfg.num_labels, ctx.cfg.ntn_k);
    let cpu = CpuModel::default();
    let gpu = GpuModel::default();
    let mut t = Table::new(
        "Energy per query (TDP-based model; paper quotes U50 75W / U280 225W TDP)",
        &["Platform", "Power (W)", "Kernel(ms)", "Energy/query (mJ)"],
    );
    for plat in &ALL_PLATFORMS {
        let run = simulate_workload(ctx, &arch, plat, &pairs);
        let res = simgnn_resources(&ctx.cfg, &arch).total;
        t.row(vec![
            plat.name.into(),
            fmt(design_power_watts(plat, &res)),
            fmt(run.kernel_ms),
            fmt(energy_per_query_mj(plat, &res, run.kernel_ms)),
        ]);
    }
    t.row(vec![
        "PyG-CPU (model)".into(),
        "145".into(),
        fmt(cpu.kernel_ms(&work)),
        fmt(cpu_energy_per_query_mj(cpu.kernel_ms(&work))),
    ]);
    t.row(vec![
        "PyG-GPU (model)".into(),
        "300".into(),
        fmt(gpu.kernel_ms(&work)),
        fmt(gpu_energy_per_query_mj(gpu.kernel_ms(&work))),
    ]);
    t
}

/// FIFO-depth ablation via the event-driven dataflow simulator: validates
/// the analytic "interval = max(stage)" rule and shows backpressure with
/// shallow FIFOs (the design choice behind Fig. 2/4's stream connections).
pub fn fifo_ablation(ctx: &Context, queries: usize) -> Table {
    use crate::sim::dataflow::{simgnn_chain, simulate_pipeline};
    let pairs = ctx.workload(queries, 0x7ab1e8);
    let arch = ArchConfig::spa_gcn();
    // Per-query layer busy times from the cycle simulator.
    let mut layer_busy: Vec<[u64; 3]> = Vec::new();
    let mut stage = (0u64, 0u64);
    for q in &pairs {
        for g in [&q.g1, &q.g2] {
            let e = encode(g, ctx.cfg.n_max, ctx.cfg.num_labels).unwrap();
            let tr = gcn_forward(&ctx.cfg, &ctx.weights, &e);
            let gc = crate::sim::gcn::simulate_gcn(&ctx.cfg, &arch, &U280, g, &e, &tr);
            layer_busy.push([
                gc.layers[0].acg_busy(),
                gc.layers[1].acg_busy(),
                gc.layers[2].acg_busy(),
            ]);
            let sc = crate::sim::gcn::stage_cycles(&ctx.cfg, &arch, e.num_nodes, e.num_nodes);
            stage = (sc.att1, sc.ntn + sc.fcn);
        }
    }
    let analytic_max: f64 = layer_busy
        .iter()
        .map(|l| *l.iter().max().unwrap() as f64)
        .sum::<f64>()
        / layer_busy.len() as f64;
    let mut t = Table::new(
        "FIFO-depth ablation (event-driven dataflow sim vs analytic max-rule)",
        &["Inter-module FIFO depth", "Steady interval (cycles/graph)", "Blocked cycles", "vs analytic max"],
    );
    for depth in [1usize, 2, 4, 16, 64] {
        let chain = simgnn_chain(&layer_busy, stage.0, stage.1, depth);
        let run = simulate_pipeline(&chain);
        let blocked: u64 = run.blocked_cycles.iter().sum();
        t.row(vec![
            format!("{depth}"),
            fmt(run.steady_interval),
            format!("{blocked}"),
            format!("{:.3}x", run.steady_interval / analytic_max),
        ]);
    }
    t.note("deep FIFOs converge to the analytic rule; depth 1-2 pays backpressure");
    t
}
