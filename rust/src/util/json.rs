//! Minimal JSON codec (parser + writer).
//!
//! The offline environment vendors only the `xla` crate's dependency tree,
//! so `serde_json` is unavailable; this module implements the small JSON
//! subset the project needs: objects, arrays, strings (with escapes),
//! f64 numbers, booleans and null. It is used to read `artifacts/meta.json`,
//! `artifacts/weights.json` and `tests/golden/*.json`, and to write report
//! documents.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }
    /// Array of f64s (fails on any non-number element).
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }
    /// Array of f32s, the common case for tensor payloads.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect()
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report-building code.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}
pub fn num(n: f64) -> Json {
    Json::Num(n)
}
pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}

/// Parse a JSON document. Returns a descriptive error with byte offset.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|e| e.to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number '{}' at byte {}: {}", text, start, e))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (may be multi-byte).
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] (found {:?})", other)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} (found {:?})", other)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Bool(false));
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",null,true],"z":{"q":-3}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
    }

    #[test]
    fn f32_vec() {
        let v = parse("[1, 2.5, -3]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1.0, 2.5, -3.0]);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""A\téé""#).unwrap();
        assert_eq!(v.as_str(), Some("A\té\u{e9}"));
    }
}
