//! Deterministic PRNG (SplitMix64) with the distributions the generators
//! need. No external `rand` crate is available offline; SplitMix64 is tiny,
//! fast and passes BigCrush for our purposes (workload synthesis, not
//! cryptography).

/// SplitMix64 PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        // Rejection-free lemire-style reduction is overkill here; modulo
        // bias is negligible for our n << 2^64.
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
            let x = r.range(3, 5);
            assert!((3..=5).contains(&x));
        }
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(4);
        let w = [1.0, 0.0, 9.0];
        let mut counts = [0usize; 3];
        for _ in 0..5000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
