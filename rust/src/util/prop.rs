//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` generated inputs
//! and, on failure, performs greedy shrinking via the generator's own
//! seed-replay: it reports the failing seed so the case is reproducible.
//! Generators are plain `Fn(&mut Rng) -> T`, which keeps the API tiny while
//! covering what proptest would give us here: randomized structured inputs
//! with reproducible failures.

use super::rng::Rng;

/// Run a property over `cases` random inputs. Panics (with the seed) on the
/// first failing case so `cargo test` reports it like any other assertion.
pub fn check<T, G, P>(name: &str, cases: usize, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: Fn(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    // Base seed derives from the property name so adding properties does
    // not perturb existing ones.
    let base = name
        .bytes()
        .fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3));
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivially() {
        check("trivial", 50, |r| r.below(10), |x| {
            if *x < 10 {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn reports_failure_with_seed() {
        check("fails", 50, |r| r.below(10), |x| {
            if *x < 5 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }
}
