//! Small in-tree substrates: the offline environment vendors only the xla
//! crate's dependency tree, so JSON, PRNG, property testing and stats are
//! implemented here instead of pulling serde/rand/proptest/criterion.
pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
