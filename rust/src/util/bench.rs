//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! `bench(name, f)` warms up, picks an iteration count targeting ~0.5 s,
//! then reports mean / stddev / throughput over timed batches — the same
//! basic methodology criterion uses, without the plotting.

use std::time::Instant;

use super::stats::Samples;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean_ns: f64,
    /// Median over the timed batches — the robust ns/op figure the
    /// machine-readable `BENCH_*.json` snapshots record.
    pub p50_ns: f64,
    pub stddev_ns: f64,
    pub iters: u64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let (val, unit) = humanize(self.mean_ns);
        let (sd, sd_unit) = humanize(self.stddev_ns);
        format!(
            "{:<44} {:>9.3} {}/iter (+/- {:.2} {}, {} iters)",
            self.name, val, unit, sd, sd_unit, self.iters
        )
    }
}

fn humanize(ns: f64) -> (f64, &'static str) {
    if ns < 1e3 {
        (ns, "ns")
    } else if ns < 1e6 {
        (ns / 1e3, "us")
    } else if ns < 1e9 {
        (ns / 1e6, "ms")
    } else {
        (ns / 1e9, "s")
    }
}

/// Benchmark `f`, printing and returning the result.
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    // Warmup + calibration: run until 50 ms elapsed to estimate cost.
    let t0 = Instant::now();
    let mut calib_iters = 0u64;
    while t0.elapsed().as_millis() < 50 {
        f();
        calib_iters += 1;
    }
    let per_iter = t0.elapsed().as_secs_f64() / calib_iters as f64;
    // Target ~0.5 s total across 10 batches.
    let batch_iters = ((0.05 / per_iter).ceil() as u64).max(1);
    let mut samples = Samples::new();
    let mut total_iters = 0u64;
    for _ in 0..10 {
        let t = Instant::now();
        for _ in 0..batch_iters {
            f();
        }
        let ns = t.elapsed().as_nanos() as f64 / batch_iters as f64;
        samples.push(ns);
        total_iters += batch_iters;
    }
    let r = BenchResult {
        name: name.to_string(),
        mean_ns: samples.mean(),
        p50_ns: samples.percentile(50.0),
        stddev_ns: samples.stddev(),
        iters: total_iters,
    };
    println!("{}", r.report());
    r
}

/// Time a single execution of `f` (for expensive whole-table runs).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    let secs = t0.elapsed().as_secs_f64();
    println!("{name:<44} {secs:>9.3} s (single run)");
    (out, secs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.mean_ns >= 0.0);
        assert!(r.p50_ns >= 0.0);
        assert!(r.iters > 0);
    }

    #[test]
    fn humanize_units() {
        assert_eq!(humanize(500.0).1, "ns");
        assert_eq!(humanize(5e4).1, "us");
        assert_eq!(humanize(5e7).1, "ms");
        assert_eq!(humanize(5e9).1, "s");
    }
}
