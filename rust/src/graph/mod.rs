//! Graph substrate: small labeled undirected graphs, normalization (Eq. 2),
//! synthetic dataset generators, padding/one-hot encoding for the AOT
//! artifacts, and the paper's offline edge-reordering preprocessing.

pub mod dataset;
pub mod io;
pub mod encode;
pub mod generate;
pub mod normalize;
pub mod reorder;

/// A small undirected labeled graph.
///
/// Invariants (enforced by `Graph::new`):
///  * edges are deduplicated, self-loop-free and stored as (min, max);
///  * `labels.len() == n`;
///  * all endpoints < n.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    n: usize,
    edges: Vec<(u16, u16)>,
    labels: Vec<u16>,
}

impl Graph {
    pub fn new(n: usize, edges: Vec<(u16, u16)>, labels: Vec<u16>) -> Self {
        assert_eq!(labels.len(), n, "labels must cover all nodes");
        let mut norm: Vec<(u16, u16)> = edges
            .into_iter()
            .filter(|&(u, v)| u != v)
            .map(|(u, v)| (u.min(v), u.max(v)))
            .collect();
        norm.sort_unstable();
        norm.dedup();
        for &(u, v) in &norm {
            assert!((v as usize) < n, "edge ({u},{v}) out of range for n={n}");
        }
        Graph {
            n,
            edges: norm,
            labels,
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.n
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn edges(&self) -> &[(u16, u16)] {
        &self.edges
    }

    pub fn labels(&self) -> &[u16] {
        &self.labels
    }

    pub fn has_edge(&self, u: u16, v: u16) -> bool {
        let key = (u.min(v), u.max(v));
        self.edges.binary_search(&key).is_ok()
    }

    /// Node degrees (without self-loops).
    pub fn degrees(&self) -> Vec<usize> {
        let mut deg = vec![0usize; self.n];
        for &(u, v) in &self.edges {
            deg[u as usize] += 1;
            deg[v as usize] += 1;
        }
        deg
    }

    /// Adjacency lists.
    pub fn adjacency(&self) -> Vec<Vec<u16>> {
        let mut adj = vec![Vec::new(); self.n];
        for &(u, v) in &self.edges {
            adj[u as usize].push(v);
            adj[v as usize].push(u);
        }
        adj
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let adj = self.adjacency();
        let mut seen = vec![false; self.n];
        let mut queue = std::collections::VecDeque::from([0u16]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for &v in &adj[u as usize] {
                if !seen[v as usize] {
                    seen[v as usize] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == self.n
    }

    /// Directed edge list with both orientations plus self-loops — the
    /// stream format the paper feeds the Aggregation engine (§3.2.2):
    /// each entry is (dst, src, weight) with A'[dst][src] as weight.
    pub fn directed_edges_with_self_loops(&self) -> Vec<(u16, u16)> {
        let mut out = Vec::with_capacity(self.edges.len() * 2 + self.n);
        for &(u, v) in &self.edges {
            out.push((u, v));
            out.push((v, u));
        }
        for i in 0..self.n as u16 {
            out.push((i, i));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> Graph {
        Graph::new(3, vec![(0, 1), (1, 2)], vec![0, 1, 2])
    }

    #[test]
    fn normalizes_edges() {
        let g = Graph::new(3, vec![(1, 0), (1, 0), (2, 1), (2, 2)], vec![0, 0, 0]);
        assert_eq!(g.edges(), &[(0, 1), (1, 2)]);
    }

    #[test]
    fn degrees_and_adjacency() {
        let g = path3();
        assert_eq!(g.degrees(), vec![1, 2, 1]);
        assert_eq!(g.adjacency()[1], vec![0, 2]);
        assert!(g.has_edge(1, 0));
        assert!(!g.has_edge(0, 2));
    }

    #[test]
    fn connectivity() {
        assert!(path3().is_connected());
        let g = Graph::new(4, vec![(0, 1), (2, 3)], vec![0; 4]);
        assert!(!g.is_connected());
    }

    #[test]
    fn directed_stream_has_self_loops() {
        let g = path3();
        let stream = g.directed_edges_with_self_loops();
        assert_eq!(stream.len(), 2 * 2 + 3);
        assert!(stream.contains(&(2, 2)));
        assert!(stream.contains(&(0, 1)) && stream.contains(&(1, 0)));
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_bad_edges() {
        Graph::new(2, vec![(0, 5)], vec![0, 0]);
    }
}
