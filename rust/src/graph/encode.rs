//! Padding + one-hot encoding: `Graph` -> the dense tensors the AOT HLO
//! artifacts take as input (DESIGN.md "Fixed shapes / padding"), plus the
//! CSR view of the normalized adjacency the sparse native scoring path
//! consumes (DESIGN.md S13).

use super::normalize::normalized_dense;
use super::Graph;

/// Content fingerprint of one graph's `(labels, edges)` structure — the
/// key of the runtime's graph-embedding cache (DESIGN.md S14). Two
/// encodings collide exactly when they describe the same labeled graph:
/// the key covers the real-node count, the label sequence, and the
/// normalized undirected edge list (in `Graph::new` order), and is
/// independent of the padding shape (`n_max`), so the same graph keyed
/// through different artifact configs still deduplicates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct GraphKey(pub u128);

/// FNV-1a, 128-bit flavor: tiny, dependency-free, and with a 2^128 key
/// space the birthday bound for any realistic corpus is negligible.
struct Fnv128(u128);

impl Fnv128 {
    const OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
    const PRIME: u128 = 0x0000000001000000000000000000013b;

    fn new() -> Self {
        Fnv128(Self::OFFSET)
    }

    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u128;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    fn write_u16(&mut self, v: u16) {
        self.write(&v.to_le_bytes());
    }

    fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }
}

/// CSR view of the normalized adjacency A' over the REAL rows only
/// (`num_nodes` rows — padded rows have no entries by construction).
/// Column indices are ascending within each row, so a CSR traversal
/// accumulates in exactly the order the zero-skipping dense loop does
/// (bit-for-bit score parity between the two paths).
#[derive(Debug, Clone, PartialEq)]
pub struct CsrAdj {
    /// Row pointers, `num_rows() + 1` entries.
    pub indptr: Vec<u32>,
    /// Column index of each non-zero weight (always a real node).
    pub indices: Vec<u16>,
    /// Normalized edge weights, parallel to `indices`.
    pub weights: Vec<f32>,
}

impl CsrAdj {
    /// Build from the dense padded A' by scanning its first `rows` rows
    /// (the real nodes); used by [`encode`] and [`PackedBatch::unpack_slot`]
    /// so both construction paths share one definition of the view.
    pub fn from_dense(a_norm: &[f32], rows: usize, n_max: usize) -> Self {
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut weights = Vec::new();
        indptr.push(0);
        for i in 0..rows {
            for j in 0..n_max {
                let w = a_norm[i * n_max + j];
                if w != 0.0 {
                    indices.push(j as u16);
                    weights.push(w);
                }
            }
            indptr.push(indices.len() as u32);
        }
        CsrAdj {
            indptr,
            indices,
            weights,
        }
    }

    /// Real rows covered by this view.
    pub fn num_rows(&self) -> usize {
        self.indptr.len() - 1
    }

    /// Non-zero count (self-loops + both directions of every edge).
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }
}

/// A graph encoded as padded dense tensors (all row-major f32), carrying
/// the CSR adjacency view alongside.
#[derive(Debug, Clone)]
pub struct EncodedGraph {
    /// Normalized adjacency A', n_max * n_max.
    pub a_norm: Vec<f32>,
    /// One-hot node features, n_max * num_labels.
    pub h0: Vec<f32>,
    /// Real-node mask, n_max.
    pub mask: Vec<f32>,
    /// CSR view of A' over the real rows (sparse scoring path).
    pub csr: CsrAdj,
    /// Real node count (pre-padding).
    pub num_nodes: usize,
    /// Undirected edge count (pre-padding, without self-loops).
    pub num_edges: usize,
    /// Precomputed content fingerprint — the embedding-cache key,
    /// computed once at construction ([`EncodedGraph::compute_fingerprint`])
    /// so per-query cache lookups are a field read, not a re-hash.
    pub key: GraphKey,
}

/// Cheap per-graph signals computed once at encode/ingest time — the
/// coarse stage of cascade retrieval (DESIGN.md S20). Everything here is
/// integer arithmetic over counts, so comparing a query against a
/// million candidates costs a few adds per candidate, no floats, no
/// hashing, no GCN forward.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheapSignals {
    /// Real node count.
    pub nodes: u32,
    /// Undirected edge count (no self-loops).
    pub edges: u32,
    /// Label histogram, `num_labels` buckets.
    pub hist: Vec<u32>,
}

impl CheapSignals {
    /// Compute the signals from a raw graph. `num_labels` fixes the
    /// histogram width so signals from the same artifact config are
    /// directly comparable; labels outside the vocab are clamped into
    /// the last bucket (encode rejects them separately).
    pub fn from_graph(g: &Graph, num_labels: usize) -> Self {
        let width = num_labels.max(1);
        let mut hist = vec![0u32; width];
        for &l in g.labels() {
            hist[(l as usize).min(width - 1)] += 1;
        }
        CheapSignals {
            nodes: g.num_nodes() as u32,
            edges: g.num_edges() as u32,
            hist,
        }
    }

    /// Coarse dissimilarity: |Δnodes| + |Δedges| + label-histogram L1.
    /// Each term bounds a family of edit operations from below (node
    /// insert/delete, edge insert/delete, relabel — the same unit-cost
    /// model `ged/heuristics.rs` upper-bounds), so graphs that are
    /// cheap-close are the only ones that can be edit-close. Zero iff
    /// the count profile matches exactly (not iff the graphs match —
    /// this is a prune key, never a score).
    pub fn distance(&self, other: &CheapSignals) -> u64 {
        let dn = (self.nodes as i64 - other.nodes as i64).unsigned_abs();
        let de = (self.edges as i64 - other.edges as i64).unsigned_abs();
        let mut l1 = 0u64;
        let (short, long) = if self.hist.len() <= other.hist.len() {
            (&self.hist, &other.hist)
        } else {
            (&other.hist, &self.hist)
        };
        for (i, &b) in long.iter().enumerate() {
            let a = short.get(i).copied().unwrap_or(0);
            l1 += (a as i64 - b as i64).unsigned_abs();
        }
        dn + de + l1
    }
}

/// Errors produced when a graph cannot be encoded for the fixed shapes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EncodeError {
    TooManyNodes { nodes: usize, n_max: usize },
    LabelOutOfRange { label: u16, num_labels: usize },
}

impl std::fmt::Display for EncodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EncodeError::TooManyNodes { nodes, n_max } => {
                write!(f, "graph has {nodes} nodes, artifact limit is {n_max}")
            }
            EncodeError::LabelOutOfRange { label, num_labels } => {
                write!(f, "node label {label} out of range (vocab {num_labels})")
            }
        }
    }
}

impl std::error::Error for EncodeError {}

/// A real-node mask that is not a `1...10...0` prefix: every row scan in
/// the decode/unpack path (edge recovery, label recovery, sparse
/// real-row iteration) relies on real rows forming a prefix, so a
/// corrupted batch must fail loudly instead of silently mis-decoding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NonPrefixMask {
    /// Index of the first non-zero mask entry found after a zero.
    pub index: usize,
}

impl std::fmt::Display for NonPrefixMask {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "real-node mask is not a prefix (non-zero entry at index {} after a zero)",
            self.index
        )
    }
}

impl std::error::Error for NonPrefixMask {}

/// Validate that every non-zero mask entry precedes every zero entry.
/// Returns the real-node count on success. (The sparse forward pass
/// `debug_assert`s the same invariant where it trusts `num_nodes`; this
/// is the typed-error boundary for corrupted batches in release builds.)
fn validate_prefix_mask(mask: &[f32]) -> Result<usize, NonPrefixMask> {
    let num_nodes = mask.iter().filter(|&&x| x != 0.0).count();
    match mask[num_nodes..].iter().position(|&x| x != 0.0) {
        None => Ok(num_nodes),
        Some(off) => Err(NonPrefixMask {
            index: num_nodes + off,
        }),
    }
}

impl EncodedGraph {
    /// Reconstruct the graph structure from the padded tensors: node
    /// count from the mask, labels from the one-hot rows, edges from the
    /// off-diagonal non-zeros of A' (the diagonal carries self-loops the
    /// normalization added; real edges always have a strictly positive
    /// normalized weight, so the non-zero pattern is exact).
    ///
    /// Inverse of [`encode`] up to edge order (`Graph::new` normalizes).
    /// Fails when the real-node mask is not a prefix — the row scans
    /// below would silently miss real rows otherwise.
    pub fn decode(&self) -> Result<Graph, NonPrefixMask> {
        let n_max = self.mask.len();
        let num_labels = if n_max == 0 { 0 } else { self.h0.len() / n_max };
        let n = validate_prefix_mask(&self.mask)?;
        let labels = (0..n)
            .map(|i| {
                self.h0[i * num_labels..(i + 1) * num_labels]
                    .iter()
                    .position(|&x| x != 0.0)
                    .unwrap_or(0) as u16
            })
            .collect();
        let mut edges = Vec::with_capacity(self.num_edges);
        for i in 0..n {
            for j in (i + 1)..n {
                if self.a_norm[i * n_max + j] != 0.0 {
                    edges.push((i as u16, j as u16));
                }
            }
        }
        Ok(Graph::new(n, edges, labels))
    }

    /// Content fingerprint over `(num_nodes, labels, edges)` — the
    /// embedding-cache key (see [`GraphKey`]), precomputed at
    /// construction (this is a field read on the scoring hot path).
    pub fn fingerprint(&self) -> GraphKey {
        self.key
    }

    /// Compute the content fingerprint from padded tensors: labels from
    /// the one-hot rows, edges from the upper triangle of the CSR view,
    /// whose ascending column order matches `Graph::edges()`, so the key
    /// is deterministic in the graph alone (padding-independent). Used
    /// by every [`EncodedGraph`] constructor; cost is
    /// O(n·labels + nnz), paid once per encode.
    pub fn compute_fingerprint(
        h0: &[f32],
        csr: &CsrAdj,
        num_nodes: usize,
        num_labels: usize,
    ) -> GraphKey {
        let mut h = Fnv128::new();
        h.write_u64(num_nodes as u64);
        for i in 0..num_nodes {
            let label = h0[i * num_labels..(i + 1) * num_labels]
                .iter()
                .position(|&x| x != 0.0)
                .unwrap_or(0);
            h.write_u16(label as u16);
        }
        // Domain separator so a trailing label can never be read as the
        // start of the edge list.
        h.write_u64(u64::MAX);
        for r in 0..csr.num_rows() {
            let (s, t) = (csr.indptr[r] as usize, csr.indptr[r + 1] as usize);
            for &c in &csr.indices[s..t] {
                // Upper triangle only: self-loops and the mirrored lower
                // half come from normalization, not graph content.
                if (c as usize) > r {
                    h.write_u16(r as u16);
                    h.write_u16(c);
                }
            }
        }
        GraphKey(h.0)
    }
}

/// Encode one graph into padded tensors (+ the CSR adjacency view).
pub fn encode(g: &Graph, n_max: usize, num_labels: usize) -> Result<EncodedGraph, EncodeError> {
    if g.num_nodes() > n_max {
        return Err(EncodeError::TooManyNodes {
            nodes: g.num_nodes(),
            n_max,
        });
    }
    if let Some(&bad) = g.labels().iter().find(|&&l| l as usize >= num_labels) {
        return Err(EncodeError::LabelOutOfRange {
            label: bad,
            num_labels,
        });
    }
    let mut h0 = vec![0.0f32; n_max * num_labels];
    for (i, &lab) in g.labels().iter().enumerate() {
        h0[i * num_labels + lab as usize] = 1.0;
    }
    let mut mask = vec![0.0f32; n_max];
    for m in mask.iter_mut().take(g.num_nodes()) {
        *m = 1.0;
    }
    let a_norm = normalized_dense(g, n_max);
    let csr = CsrAdj::from_dense(&a_norm, g.num_nodes(), n_max);
    let key = EncodedGraph::compute_fingerprint(&h0, &csr, g.num_nodes(), num_labels);
    Ok(EncodedGraph {
        a_norm,
        h0,
        mask,
        csr,
        num_nodes: g.num_nodes(),
        num_edges: g.num_edges(),
        key,
    })
}

/// Why a chunk of encoded pairs could not be packed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PackError {
    /// No pairs to pack. The batcher never releases an empty batch, but
    /// an empty flush must surface as a typed error instead of taking an
    /// executor lane down via an assert.
    EmptyBatch,
    /// More pairs than the logical batch size can hold.
    Overflow { pairs: usize, batch: usize },
}

impl std::fmt::Display for PackError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PackError::EmptyBatch => write!(f, "cannot pack an empty pair list"),
            PackError::Overflow { pairs, batch } => {
                write!(f, "{pairs} pairs exceed logical batch size {batch}")
            }
        }
    }
}

impl std::error::Error for PackError {}

/// Batch of encoded pairs packed contiguously for one PJRT execute call.
#[derive(Debug, Clone)]
pub struct PackedBatch {
    pub batch: usize,
    pub n_max: usize,
    pub num_labels: usize,
    pub a1: Vec<f32>,
    pub h1: Vec<f32>,
    pub m1: Vec<f32>,
    pub a2: Vec<f32>,
    pub h2: Vec<f32>,
    pub m2: Vec<f32>,
    /// Per-slot content fingerprints of the first graphs, carried from
    /// pack so `unpack_slot` copies instead of re-hashing on the
    /// scoring hot path. Padding slots hold the empty-graph key.
    pub k1: Vec<GraphKey>,
    /// Per-slot content fingerprints of the second graphs.
    pub k2: Vec<GraphKey>,
}

impl PackedBatch {
    /// Pack `pairs.len()` encoded pairs into batch tensors of logical batch
    /// size `batch` (>= pairs.len(); the tail is zero padding whose scores
    /// are discarded by the caller). Empty or oversized inputs return a
    /// typed [`PackError`] instead of panicking an executor lane.
    pub fn pack(pairs: &[(EncodedGraph, EncodedGraph)], batch: usize) -> Result<Self, PackError> {
        if pairs.is_empty() {
            return Err(PackError::EmptyBatch);
        }
        if pairs.len() > batch {
            return Err(PackError::Overflow {
                pairs: pairs.len(),
                batch,
            });
        }
        let n = pairs[0].0.mask.len();
        let l = pairs[0].0.h0.len() / n;
        // Zero-padded tail slots decode as 0-node graphs, so they carry
        // the empty graph's fingerprint.
        let empty_key =
            EncodedGraph::compute_fingerprint(&[], &CsrAdj::from_dense(&[], 0, 0), 0, 0);
        let mut pb = PackedBatch {
            batch,
            n_max: n,
            num_labels: l,
            a1: vec![0.0; batch * n * n],
            h1: vec![0.0; batch * n * l],
            m1: vec![0.0; batch * n],
            a2: vec![0.0; batch * n * n],
            h2: vec![0.0; batch * n * l],
            m2: vec![0.0; batch * n],
            k1: vec![empty_key; batch],
            k2: vec![empty_key; batch],
        };
        for (i, (g1, g2)) in pairs.iter().enumerate() {
            pb.a1[i * n * n..(i + 1) * n * n].copy_from_slice(&g1.a_norm);
            pb.h1[i * n * l..(i + 1) * n * l].copy_from_slice(&g1.h0);
            pb.m1[i * n..(i + 1) * n].copy_from_slice(&g1.mask);
            pb.k1[i] = g1.key;
            pb.a2[i * n * n..(i + 1) * n * n].copy_from_slice(&g2.a_norm);
            pb.h2[i * n * l..(i + 1) * n * l].copy_from_slice(&g2.h0);
            pb.m2[i * n..(i + 1) * n].copy_from_slice(&g2.mask);
            pb.k2[i] = g2.key;
        }
        // Zero-padded tail graphs have empty masks; every stage treats them
        // as 0-node graphs and produces a harmless score.
        Ok(pb)
    }

    /// Validate slot `i`'s real-node masks (the `1...10...0` prefix
    /// invariant) without unpacking any tensors — O(n_max), no copies.
    /// The engines' warm-cache fast path uses this so a corrupted batch
    /// fails with the same typed error whether or not its fingerprints
    /// are cached.
    pub fn validate_slot_masks(&self, i: usize) -> Result<(), NonPrefixMask> {
        assert!(i < self.batch, "slot {i} out of range (batch {})", self.batch);
        let n = self.n_max;
        validate_prefix_mask(&self.m1[i * n..(i + 1) * n])?;
        validate_prefix_mask(&self.m2[i * n..(i + 1) * n])?;
        Ok(())
    }

    /// Unpack slot `i` back into the two [`EncodedGraph`]s it was packed
    /// from (the shared inverse of [`PackedBatch::pack`], used by the
    /// native and sim engines). `num_nodes` is recovered from the mask
    /// and `num_edges` from the off-diagonal non-zeros of A' — real
    /// edges always carry a strictly positive normalized weight, so the
    /// count is exact. Padding slots come back as 0-node graphs.
    ///
    /// The recovered mask must be a prefix (`1...10...0`): the edge and
    /// label scans — and the sparse path's real-row iteration — cover
    /// rows `0..num_nodes`, so a corrupted non-prefix mask returns a
    /// typed error instead of silently dropping real rows.
    pub fn unpack_slot(&self, i: usize) -> Result<(EncodedGraph, EncodedGraph), NonPrefixMask> {
        Ok((self.unpack_slot_g1(i)?, self.unpack_slot_g2(i)?))
    }

    /// Unpack only slot `i`'s first graph — the engines' warm fast path
    /// copies just the missed side's tensors instead of both.
    pub fn unpack_slot_g1(&self, i: usize) -> Result<EncodedGraph, NonPrefixMask> {
        self.unpack_side(i, &self.a1, &self.h1, &self.m1, self.k1[i])
    }

    /// Unpack only slot `i`'s second graph.
    pub fn unpack_slot_g2(&self, i: usize) -> Result<EncodedGraph, NonPrefixMask> {
        self.unpack_side(i, &self.a2, &self.h2, &self.m2, self.k2[i])
    }

    fn unpack_side(
        &self,
        i: usize,
        a: &[f32],
        h: &[f32],
        m: &[f32],
        key: GraphKey,
    ) -> Result<EncodedGraph, NonPrefixMask> {
        assert!(i < self.batch, "slot {i} out of range (batch {})", self.batch);
        let (n, l) = (self.n_max, self.num_labels);
        let mask = m[i * n..(i + 1) * n].to_vec();
        let num_nodes = validate_prefix_mask(&mask)?;
        let a_norm = a[i * n * n..(i + 1) * n * n].to_vec();
        let csr = CsrAdj::from_dense(&a_norm, num_nodes, n);
        // A' carries one strictly positive self-loop per real node
        // plus both directions of every edge, so the CSR nonzero
        // count gives the edge count without a second dense scan
        // (this runs per slot on the scoring hot path).
        let num_edges = csr.nnz().saturating_sub(num_nodes) / 2;
        Ok(EncodedGraph {
            a_norm,
            h0: h[i * n * l..(i + 1) * n * l].to_vec(),
            mask,
            csr,
            num_nodes,
            num_edges,
            // Carried verbatim from pack — no per-slot re-hash on
            // the scoring hot path.
            key,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{generate, Family};
    use crate::util::rng::Rng;

    #[test]
    fn encode_shapes_and_padding() {
        let g = Graph::new(3, vec![(0, 1), (1, 2)], vec![2, 0, 5]);
        let e = encode(&g, 8, 29).unwrap();
        assert_eq!(e.a_norm.len(), 64);
        assert_eq!(e.h0.len(), 8 * 29);
        assert_eq!(e.mask, vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(e.h0[0 * 29 + 2], 1.0);
        assert_eq!(e.h0[1 * 29 + 0], 1.0);
        assert_eq!(e.h0[2 * 29 + 5], 1.0);
        // exactly one 1 per real row, all-zero pad rows
        for i in 0..8 {
            let row: f32 = e.h0[i * 29..(i + 1) * 29].iter().sum();
            assert_eq!(row, if i < 3 { 1.0 } else { 0.0 });
        }
    }

    #[test]
    fn csr_view_matches_dense() {
        let mut rng = Rng::new(11);
        for _ in 0..5 {
            let g = generate(&mut rng, Family::Aids, 32, 29);
            let e = encode(&g, 32, 29).unwrap();
            assert_eq!(e.csr.num_rows(), g.num_nodes());
            // entries: self-loop per node + both directions per edge
            assert_eq!(e.csr.nnz(), g.num_nodes() + 2 * g.num_edges());
            // Rebuild dense from CSR and compare the real rows exactly.
            let mut rebuilt = vec![0.0f32; 32 * 32];
            for r in 0..e.csr.num_rows() {
                let (s, t) = (e.csr.indptr[r] as usize, e.csr.indptr[r + 1] as usize);
                let row = &e.csr.indices[s..t];
                // ascending column order within each row
                assert!(row.windows(2).all(|w| w[0] < w[1]), "row {r} not sorted");
                for (k, &c) in row.iter().enumerate() {
                    rebuilt[r * 32 + c as usize] = e.csr.weights[s + k];
                }
            }
            assert_eq!(rebuilt, e.a_norm);
        }
    }

    #[test]
    fn rejects_oversize_and_bad_labels() {
        let g = Graph::new(5, vec![(0, 1)], vec![0; 5]);
        assert!(matches!(
            encode(&g, 4, 29),
            Err(EncodeError::TooManyNodes { .. })
        ));
        let g = Graph::new(2, vec![(0, 1)], vec![0, 40]);
        assert!(matches!(
            encode(&g, 4, 29),
            Err(EncodeError::LabelOutOfRange { .. })
        ));
    }

    #[test]
    fn pack_rejects_empty_and_overflow() {
        let mut rng = Rng::new(17);
        let g = generate(&mut rng, Family::Aids, 32, 29);
        let e = encode(&g, 32, 29).unwrap();
        assert_eq!(PackedBatch::pack(&[], 4).unwrap_err(), PackError::EmptyBatch);
        let pairs = vec![(e.clone(), e.clone()); 3];
        assert_eq!(
            PackedBatch::pack(&pairs, 2).unwrap_err(),
            PackError::Overflow { pairs: 3, batch: 2 }
        );
        assert!(PackedBatch::pack(&pairs, 3).is_ok());
    }

    #[test]
    fn unpack_slot_recovers_counts_and_tensors() {
        let mut rng = Rng::new(3);
        let pairs: Vec<_> = (0..2)
            .map(|_| {
                let g1 = generate(&mut rng, Family::Aids, 32, 29);
                let g2 = generate(&mut rng, Family::Aids, 32, 29);
                (encode(&g1, 32, 29).unwrap(), encode(&g2, 32, 29).unwrap())
            })
            .collect();
        let pb = PackedBatch::pack(&pairs, 4).unwrap();
        for (i, (e1, e2)) in pairs.iter().enumerate() {
            let (u1, u2) = pb.unpack_slot(i).unwrap();
            // Tensors roundtrip exactly, and the true edge count is
            // recovered from A' (not the old hardcoded zero).
            assert_eq!(u1.a_norm, e1.a_norm);
            assert_eq!(u1.h0, e1.h0);
            assert_eq!(u1.mask, e1.mask);
            assert_eq!(u1.num_nodes, e1.num_nodes);
            assert_eq!(u1.num_edges, e1.num_edges, "slot {i} g1 edge count");
            assert_eq!(u2.num_edges, e2.num_edges, "slot {i} g2 edge count");
            // The CSR view is rebuilt identically on unpack.
            assert_eq!(u1.csr, e1.csr, "slot {i} g1 CSR roundtrip");
            assert_eq!(u2.csr, e2.csr, "slot {i} g2 CSR roundtrip");
        }
        // Padding slots unpack as empty graphs carrying the canonical
        // empty-graph fingerprint (so every pad shares one cache entry).
        let (p1, p2) = pb.unpack_slot(3).unwrap();
        assert_eq!(p1.num_nodes, 0);
        assert_eq!(p1.num_edges, 0);
        assert_eq!(p1.csr.nnz(), 0);
        assert_eq!(p2.num_nodes, 0);
        let empty = encode(&Graph::new(0, vec![], vec![]), 32, 29).unwrap();
        assert_eq!(p1.fingerprint(), empty.fingerprint());
        assert_eq!(p2.fingerprint(), empty.fingerprint());
    }

    #[test]
    fn unpack_rejects_non_prefix_mask() {
        let mut rng = Rng::new(5);
        let g1 = generate(&mut rng, Family::Aids, 32, 29);
        let g2 = generate(&mut rng, Family::Aids, 32, 29);
        let e1 = encode(&g1, 32, 29).unwrap();
        let e2 = encode(&g2, 32, 29).unwrap();
        let mut pb = PackedBatch::pack(&[(e1, e2)], 2).unwrap();
        // Corrupt slot 0's g1 mask: clear an interior entry so a real row
        // trails a zero — `num_nodes` (non-zero count) no longer covers
        // every real row and the scans would silently drop one.
        pb.m1[1] = 0.0;
        let err = pb.unpack_slot(0).unwrap_err();
        assert!(err.index >= 1, "offending index reported: {err}");
        // The copy-free validator agrees with the unpack path.
        assert!(pb.validate_slot_masks(0).is_err());
        // The other slot (all-zero padding) is still fine.
        assert!(pb.unpack_slot(1).is_ok());
        assert!(pb.validate_slot_masks(1).is_ok());
    }

    #[test]
    fn decode_rejects_non_prefix_mask() {
        let mut rng = Rng::new(6);
        let g = generate(&mut rng, Family::Aids, 32, 29);
        let mut e = encode(&g, 32, 29).unwrap();
        e.mask[0] = 0.0; // first row zeroed, later rows still real
        assert!(e.decode().is_err());
    }

    #[test]
    fn decode_inverts_encode() {
        let mut rng = Rng::new(4);
        for _ in 0..5 {
            let g = generate(&mut rng, Family::Aids, 32, 29);
            let d = encode(&g, 32, 29).unwrap().decode().unwrap();
            assert_eq!(d.num_nodes(), g.num_nodes());
            assert_eq!(d.num_edges(), g.num_edges());
            assert_eq!(d.labels(), g.labels());
            assert_eq!(d.edges(), g.edges(), "Graph::new normalizes edge order");
        }
    }

    #[test]
    fn fingerprint_is_content_deterministic() {
        let g = Graph::new(4, vec![(0, 1), (1, 2), (2, 3)], vec![3, 1, 4, 1]);
        // Same graph, same key — including across different padding
        // shapes (the key covers content, not the artifact config).
        let a = encode(&g, 8, 8).unwrap().fingerprint();
        let b = encode(&g, 8, 8).unwrap().fingerprint();
        let wide = encode(&g, 16, 8).unwrap().fingerprint();
        assert_eq!(a, b);
        assert_eq!(a, wide, "padding shape must not enter the key");
        // The packed-batch roundtrip preserves the key too.
        let e = encode(&g, 8, 8).unwrap();
        let pb = PackedBatch::pack(&[(e.clone(), e.clone())], 2).unwrap();
        let (u1, _) = pb.unpack_slot(0).unwrap();
        assert_eq!(u1.fingerprint(), a);
    }

    #[test]
    fn fingerprint_separates_labels_edges_and_sizes() {
        let base = Graph::new(3, vec![(0, 1), (1, 2)], vec![0, 1, 2]);
        let key = |g: &Graph| encode(g, 8, 8).unwrap().fingerprint();
        // Same topology, permuted labels -> distinct keys.
        let permuted = Graph::new(3, vec![(0, 1), (1, 2)], vec![2, 1, 0]);
        assert_ne!(key(&base), key(&permuted));
        // Same labels, one edge moved -> distinct keys.
        let rewired = Graph::new(3, vec![(0, 1), (0, 2)], vec![0, 1, 2]);
        assert_ne!(key(&base), key(&rewired));
        // Label/edge-boundary confusion: an extra isolated node is not
        // the same as an extra edge entry.
        let bigger = Graph::new(4, vec![(0, 1), (1, 2)], vec![0, 1, 2, 0]);
        assert_ne!(key(&base), key(&bigger));
        // Empty graphs have a stable key of their own (padding slots all
        // share it, so one cache entry serves every pad).
        let empty = Graph::new(0, vec![], vec![]);
        assert_eq!(key(&empty), key(&empty));
        assert_ne!(key(&empty), key(&base));
    }

    #[test]
    fn fingerprints_are_distinct_over_random_graphs() {
        // Collision smoke test: 200 random AIDS-like graphs, no key
        // collisions unless the graphs are actually equal.
        let mut rng = Rng::new(23);
        let mut seen: Vec<(super::GraphKey, Graph)> = Vec::new();
        for _ in 0..200 {
            let g = generate(&mut rng, Family::Aids, 32, 29);
            let k = encode(&g, 32, 29).unwrap().fingerprint();
            for (prev_k, prev_g) in &seen {
                if *prev_k == k {
                    assert_eq!(prev_g, &g, "distinct graphs collided on {k:?}");
                }
            }
            seen.push((k, g));
        }
    }

    #[test]
    fn cheap_signals_profile_and_distance() {
        let g = Graph::new(3, vec![(0, 1), (1, 2)], vec![2, 0, 2]);
        let s = CheapSignals::from_graph(&g, 8);
        assert_eq!(s.nodes, 3);
        assert_eq!(s.edges, 2);
        assert_eq!(s.hist[0], 1);
        assert_eq!(s.hist[2], 2);
        assert_eq!(s.hist.iter().sum::<u32>(), 3);
        // Zero to itself, symmetric, and positive under any count change.
        assert_eq!(s.distance(&s), 0);
        let bigger = Graph::new(4, vec![(0, 1), (1, 2), (2, 3)], vec![2, 0, 2, 5]);
        let sb = CheapSignals::from_graph(&bigger, 8);
        assert_eq!(s.distance(&sb), sb.distance(&s));
        // +1 node, +1 edge, +1 histogram entry.
        assert_eq!(s.distance(&sb), 3);
        // Relabel-only change: nodes/edges agree, histogram moves by 2
        // (one bucket loses a count, another gains one).
        let relabeled = Graph::new(3, vec![(0, 1), (1, 2)], vec![2, 1, 2]);
        assert_eq!(s.distance(&CheapSignals::from_graph(&relabeled, 8)), 2);
        // Mismatched histogram widths still compare (missing buckets
        // read as zero), so mixed-config signals never panic.
        let narrow = CheapSignals::from_graph(&g, 3);
        assert_eq!(s.distance(&narrow), 0);
    }

    #[test]
    fn pack_layout_roundtrip() {
        let mut rng = Rng::new(1);
        let g1 = generate(&mut rng, Family::Aids, 32, 29);
        let g2 = generate(&mut rng, Family::Aids, 32, 29);
        let e1 = encode(&g1, 32, 29).unwrap();
        let e2 = encode(&g2, 32, 29).unwrap();
        let pb = PackedBatch::pack(&[(e1.clone(), e2.clone())], 4).unwrap();
        assert_eq!(pb.a1.len(), 4 * 32 * 32);
        assert_eq!(&pb.a1[..32 * 32], e1.a_norm.as_slice());
        assert_eq!(&pb.m2[..32], e2.mask.as_slice());
        // tail is zero
        assert!(pb.a1[32 * 32..].iter().all(|&x| x == 0.0));
        assert!(pb.m1[32..].iter().all(|&x| x == 0.0));
    }
}
