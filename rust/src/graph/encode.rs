//! Padding + one-hot encoding: `Graph` -> the dense tensors the AOT HLO
//! artifacts take as input (DESIGN.md "Fixed shapes / padding").

use super::normalize::normalized_dense;
use super::Graph;

/// A graph encoded as padded dense tensors (all row-major f32).
#[derive(Debug, Clone)]
pub struct EncodedGraph {
    /// Normalized adjacency A', n_max * n_max.
    pub a_norm: Vec<f32>,
    /// One-hot node features, n_max * num_labels.
    pub h0: Vec<f32>,
    /// Real-node mask, n_max.
    pub mask: Vec<f32>,
    /// Real node count (pre-padding).
    pub num_nodes: usize,
    /// Undirected edge count (pre-padding, without self-loops).
    pub num_edges: usize,
}

/// Errors produced when a graph cannot be encoded for the fixed shapes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EncodeError {
    TooManyNodes { nodes: usize, n_max: usize },
    LabelOutOfRange { label: u16, num_labels: usize },
}

impl std::fmt::Display for EncodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EncodeError::TooManyNodes { nodes, n_max } => {
                write!(f, "graph has {nodes} nodes, artifact limit is {n_max}")
            }
            EncodeError::LabelOutOfRange { label, num_labels } => {
                write!(f, "node label {label} out of range (vocab {num_labels})")
            }
        }
    }
}

impl std::error::Error for EncodeError {}

impl EncodedGraph {
    /// Reconstruct the graph structure from the padded tensors: node
    /// count from the mask, labels from the one-hot rows, edges from the
    /// off-diagonal non-zeros of A' (the diagonal carries self-loops the
    /// normalization added; real edges always have a strictly positive
    /// normalized weight, so the non-zero pattern is exact).
    ///
    /// Inverse of [`encode`] up to edge order (`Graph::new` normalizes).
    pub fn decode(&self) -> Graph {
        let n_max = self.mask.len();
        let num_labels = if n_max == 0 { 0 } else { self.h0.len() / n_max };
        let n = self.num_nodes;
        let labels = (0..n)
            .map(|i| {
                self.h0[i * num_labels..(i + 1) * num_labels]
                    .iter()
                    .position(|&x| x != 0.0)
                    .unwrap_or(0) as u16
            })
            .collect();
        let mut edges = Vec::with_capacity(self.num_edges);
        for i in 0..n {
            for j in (i + 1)..n {
                if self.a_norm[i * n_max + j] != 0.0 {
                    edges.push((i as u16, j as u16));
                }
            }
        }
        Graph::new(n, edges, labels)
    }
}

/// Encode one graph into padded tensors.
pub fn encode(g: &Graph, n_max: usize, num_labels: usize) -> Result<EncodedGraph, EncodeError> {
    if g.num_nodes() > n_max {
        return Err(EncodeError::TooManyNodes {
            nodes: g.num_nodes(),
            n_max,
        });
    }
    if let Some(&bad) = g.labels().iter().find(|&&l| l as usize >= num_labels) {
        return Err(EncodeError::LabelOutOfRange {
            label: bad,
            num_labels,
        });
    }
    let mut h0 = vec![0.0f32; n_max * num_labels];
    for (i, &lab) in g.labels().iter().enumerate() {
        h0[i * num_labels + lab as usize] = 1.0;
    }
    let mut mask = vec![0.0f32; n_max];
    for m in mask.iter_mut().take(g.num_nodes()) {
        *m = 1.0;
    }
    Ok(EncodedGraph {
        a_norm: normalized_dense(g, n_max),
        h0,
        mask,
        num_nodes: g.num_nodes(),
        num_edges: g.num_edges(),
    })
}

/// Batch of encoded pairs packed contiguously for one PJRT execute call.
#[derive(Debug, Clone)]
pub struct PackedBatch {
    pub batch: usize,
    pub n_max: usize,
    pub num_labels: usize,
    pub a1: Vec<f32>,
    pub h1: Vec<f32>,
    pub m1: Vec<f32>,
    pub a2: Vec<f32>,
    pub h2: Vec<f32>,
    pub m2: Vec<f32>,
}

impl PackedBatch {
    /// Pack `pairs.len()` encoded pairs into batch tensors of logical batch
    /// size `batch` (>= pairs.len(); the tail is zero padding whose scores
    /// are discarded by the caller).
    pub fn pack(pairs: &[(EncodedGraph, EncodedGraph)], batch: usize) -> Self {
        assert!(!pairs.is_empty() && pairs.len() <= batch);
        let n = pairs[0].0.mask.len();
        let l = pairs[0].0.h0.len() / n;
        let mut pb = PackedBatch {
            batch,
            n_max: n,
            num_labels: l,
            a1: vec![0.0; batch * n * n],
            h1: vec![0.0; batch * n * l],
            m1: vec![0.0; batch * n],
            a2: vec![0.0; batch * n * n],
            h2: vec![0.0; batch * n * l],
            m2: vec![0.0; batch * n],
        };
        for (i, (g1, g2)) in pairs.iter().enumerate() {
            pb.a1[i * n * n..(i + 1) * n * n].copy_from_slice(&g1.a_norm);
            pb.h1[i * n * l..(i + 1) * n * l].copy_from_slice(&g1.h0);
            pb.m1[i * n..(i + 1) * n].copy_from_slice(&g1.mask);
            pb.a2[i * n * n..(i + 1) * n * n].copy_from_slice(&g2.a_norm);
            pb.h2[i * n * l..(i + 1) * n * l].copy_from_slice(&g2.h0);
            pb.m2[i * n..(i + 1) * n].copy_from_slice(&g2.mask);
        }
        // Zero-padded tail graphs have empty masks; every stage treats them
        // as 0-node graphs and produces a harmless score.
        pb
    }

    /// Unpack slot `i` back into the two [`EncodedGraph`]s it was packed
    /// from (the shared inverse of [`PackedBatch::pack`], used by the
    /// native and sim engines). `num_nodes` is recovered from the mask
    /// and `num_edges` from the off-diagonal non-zeros of A' — real
    /// edges always carry a strictly positive normalized weight, so the
    /// count is exact. Padding slots come back as 0-node graphs.
    pub fn unpack_slot(&self, i: usize) -> (EncodedGraph, EncodedGraph) {
        assert!(i < self.batch, "slot {i} out of range (batch {})", self.batch);
        let (n, l) = (self.n_max, self.num_labels);
        let grab = |a: &[f32], h: &[f32], m: &[f32]| {
            let mask = m[i * n..(i + 1) * n].to_vec();
            let num_nodes = mask.iter().filter(|&&x| x != 0.0).count();
            let a_norm = a[i * n * n..(i + 1) * n * n].to_vec();
            let num_edges = (0..num_nodes)
                .map(|r| {
                    a_norm[r * n..r * n + num_nodes]
                        .iter()
                        .skip(r + 1)
                        .filter(|&&x| x != 0.0)
                        .count()
                })
                .sum();
            EncodedGraph {
                a_norm,
                h0: h[i * n * l..(i + 1) * n * l].to_vec(),
                mask,
                num_nodes,
                num_edges,
            }
        };
        (
            grab(&self.a1, &self.h1, &self.m1),
            grab(&self.a2, &self.h2, &self.m2),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{generate, Family};
    use crate::util::rng::Rng;

    #[test]
    fn encode_shapes_and_padding() {
        let g = Graph::new(3, vec![(0, 1), (1, 2)], vec![2, 0, 5]);
        let e = encode(&g, 8, 29).unwrap();
        assert_eq!(e.a_norm.len(), 64);
        assert_eq!(e.h0.len(), 8 * 29);
        assert_eq!(e.mask, vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0]);
        assert_eq!(e.h0[0 * 29 + 2], 1.0);
        assert_eq!(e.h0[1 * 29 + 0], 1.0);
        assert_eq!(e.h0[2 * 29 + 5], 1.0);
        // exactly one 1 per real row, all-zero pad rows
        for i in 0..8 {
            let row: f32 = e.h0[i * 29..(i + 1) * 29].iter().sum();
            assert_eq!(row, if i < 3 { 1.0 } else { 0.0 });
        }
    }

    #[test]
    fn rejects_oversize_and_bad_labels() {
        let g = Graph::new(5, vec![(0, 1)], vec![0; 5]);
        assert!(matches!(
            encode(&g, 4, 29),
            Err(EncodeError::TooManyNodes { .. })
        ));
        let g = Graph::new(2, vec![(0, 1)], vec![0, 40]);
        assert!(matches!(
            encode(&g, 4, 29),
            Err(EncodeError::LabelOutOfRange { .. })
        ));
    }

    #[test]
    fn unpack_slot_recovers_counts_and_tensors() {
        let mut rng = Rng::new(3);
        let pairs: Vec<_> = (0..2)
            .map(|_| {
                let g1 = generate(&mut rng, Family::Aids, 32, 29);
                let g2 = generate(&mut rng, Family::Aids, 32, 29);
                (encode(&g1, 32, 29).unwrap(), encode(&g2, 32, 29).unwrap())
            })
            .collect();
        let pb = PackedBatch::pack(&pairs, 4);
        for (i, (e1, e2)) in pairs.iter().enumerate() {
            let (u1, u2) = pb.unpack_slot(i);
            // Tensors roundtrip exactly, and the true edge count is
            // recovered from A' (not the old hardcoded zero).
            assert_eq!(u1.a_norm, e1.a_norm);
            assert_eq!(u1.h0, e1.h0);
            assert_eq!(u1.mask, e1.mask);
            assert_eq!(u1.num_nodes, e1.num_nodes);
            assert_eq!(u1.num_edges, e1.num_edges, "slot {i} g1 edge count");
            assert_eq!(u2.num_edges, e2.num_edges, "slot {i} g2 edge count");
        }
        // Padding slots unpack as empty graphs.
        let (p1, p2) = pb.unpack_slot(3);
        assert_eq!(p1.num_nodes, 0);
        assert_eq!(p1.num_edges, 0);
        assert_eq!(p2.num_nodes, 0);
    }

    #[test]
    fn decode_inverts_encode() {
        let mut rng = Rng::new(4);
        for _ in 0..5 {
            let g = generate(&mut rng, Family::Aids, 32, 29);
            let d = encode(&g, 32, 29).unwrap().decode();
            assert_eq!(d.num_nodes(), g.num_nodes());
            assert_eq!(d.num_edges(), g.num_edges());
            assert_eq!(d.labels(), g.labels());
            assert_eq!(d.edges(), g.edges(), "Graph::new normalizes edge order");
        }
    }

    #[test]
    fn pack_layout_roundtrip() {
        let mut rng = Rng::new(1);
        let g1 = generate(&mut rng, Family::Aids, 32, 29);
        let g2 = generate(&mut rng, Family::Aids, 32, 29);
        let e1 = encode(&g1, 32, 29).unwrap();
        let e2 = encode(&g2, 32, 29).unwrap();
        let pb = PackedBatch::pack(&[(e1.clone(), e2.clone())], 4);
        assert_eq!(pb.a1.len(), 4 * 32 * 32);
        assert_eq!(&pb.a1[..32 * 32], e1.a_norm.as_slice());
        assert_eq!(&pb.m2[..32], e2.mask.as_slice());
        // tail is zero
        assert!(pb.a1[32 * 32..].iter().all(|&x| x == 0.0));
        assert!(pb.m1[32..].iter().all(|&x| x == 0.0));
    }
}
