//! Graph-database serialization: a compact binary format for synthetic
//! corpora (so benches/examples can reuse one fixed database) plus an
//! edge-list text export for interop with external graph tools.
//!
//! Binary layout (little-endian):
//!   magic "SPAG" | u32 version | u32 graph_count
//!   per graph: u16 n | u16 m | n x u16 labels | m x (u16, u16) edges

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{Context, Result};

use super::dataset::GraphDb;
use super::generate::Family;
use super::Graph;

const MAGIC: &[u8; 4] = b"SPAG";
const VERSION: u32 = 1;

fn w16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn w32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Serialize a database to bytes.
pub fn to_bytes(db: &GraphDb) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MAGIC);
    w32(&mut out, VERSION);
    w32(&mut out, db.graphs.len() as u32);
    for g in &db.graphs {
        w16(&mut out, g.num_nodes() as u16);
        w16(&mut out, g.num_edges() as u16);
        for &l in g.labels() {
            w16(&mut out, l);
        }
        for &(u, v) in g.edges() {
            w16(&mut out, u);
            w16(&mut out, v);
        }
    }
    out
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn r16(&mut self) -> Result<u16> {
        let b = self
            .buf
            .get(self.pos..self.pos + 2)
            .context("truncated graph db")?;
        self.pos += 2;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }
    fn r32(&mut self) -> Result<u32> {
        let b = self
            .buf
            .get(self.pos..self.pos + 4)
            .context("truncated graph db")?;
        self.pos += 4;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
}

/// Deserialize a database (validates magic/version/bounds).
pub fn from_bytes(buf: &[u8]) -> Result<GraphDb> {
    anyhow::ensure!(buf.len() >= 12 && &buf[..4] == MAGIC, "bad magic");
    let mut r = Reader { buf, pos: 4 };
    let version = r.r32()?;
    anyhow::ensure!(version == VERSION, "unsupported version {version}");
    let count = r.r32()? as usize;
    anyhow::ensure!(count < 10_000_000, "implausible graph count {count}");
    let mut graphs = Vec::with_capacity(count);
    for _ in 0..count {
        let n = r.r16()? as usize;
        let m = r.r16()? as usize;
        let mut labels = Vec::with_capacity(n);
        for _ in 0..n {
            labels.push(r.r16()?);
        }
        let mut edges = Vec::with_capacity(m);
        for _ in 0..m {
            let u = r.r16()?;
            let v = r.r16()?;
            anyhow::ensure!((u as usize) < n && (v as usize) < n, "edge out of range");
            edges.push((u, v));
        }
        graphs.push(Graph::new(n, edges, labels));
    }
    anyhow::ensure!(r.pos == buf.len(), "trailing bytes in graph db");
    Ok(GraphDb {
        graphs,
        family: Family::Aids, // family is not serialized; informational only
    })
}

pub fn save(db: &GraphDb, path: &Path) -> Result<()> {
    let mut f = std::fs::File::create(path)?;
    f.write_all(&to_bytes(db))?;
    Ok(())
}

pub fn load(path: &Path) -> Result<GraphDb> {
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_end(&mut buf)?;
    from_bytes(&buf)
}

/// Export one graph as a labeled edge-list text (one "u v" per line after
/// a "#labels ..." header) for external tooling.
pub fn to_edge_list(g: &Graph) -> String {
    let mut s = String::new();
    s.push_str("# nodes ");
    s.push_str(&g.num_nodes().to_string());
    s.push_str("\n# labels");
    for &l in g.labels() {
        s.push(' ');
        s.push_str(&l.to_string());
    }
    s.push('\n');
    for &(u, v) in g.edges() {
        s.push_str(&format!("{u} {v}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::dataset::GraphDb;
    use crate::graph::generate::Family;
    use crate::util::rng::Rng;

    fn db() -> GraphDb {
        let mut rng = Rng::new(111);
        GraphDb::synthesize(&mut rng, Family::Aids, 20, 32, 29)
    }

    #[test]
    fn roundtrip_is_identity() {
        let d = db();
        let bytes = to_bytes(&d);
        let d2 = from_bytes(&bytes).unwrap();
        assert_eq!(d.graphs.len(), d2.graphs.len());
        for (a, b) in d.graphs.iter().zip(d2.graphs.iter()) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn rejects_corruption() {
        let d = db();
        let mut bytes = to_bytes(&d);
        assert!(from_bytes(&bytes[..6]).is_err()); // truncated
        bytes[0] = b'X';
        assert!(from_bytes(&bytes).is_err()); // bad magic
        let mut bytes2 = to_bytes(&d);
        bytes2.push(0); // trailing byte
        assert!(from_bytes(&bytes2).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let d = db();
        let path = std::env::temp_dir().join("spa_gcn_io_test.bin");
        save(&d, &path).unwrap();
        let d2 = load(&path).unwrap();
        assert_eq!(d.graphs, d2.graphs);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn edge_list_format() {
        let g = Graph::new(3, vec![(0, 1), (1, 2)], vec![5, 6, 7]);
        let s = to_edge_list(&g);
        assert!(s.contains("# nodes 3"));
        assert!(s.contains("# labels 5 6 7"));
        assert!(s.contains("0 1\n"));
        assert!(s.contains("1 2\n"));
    }
}
