//! Adjacency normalization (paper Eq. 2):
//!     Ã = A + I,  D̃_ii = Σ_j Ã_ij,  A' = D̃^{-1/2} Ã D̃^{-1/2}
//!
//! Two output forms:
//!  * dense padded matrix — input tensor for the AOT HLO artifacts;
//!  * weighted COO edge stream — what the paper streams to the FPGA's
//!    Aggregation engine ("we prune this matrix and only pass its non-zero
//!    elements, which represent edges", §3.2.2).

use super::Graph;

/// A weighted directed edge of the normalized adjacency: dst <- src.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WEdge {
    pub dst: u16,
    pub src: u16,
    pub w: f32,
}

/// Dense normalized adjacency padded to `n_max` (row-major, n_max * n_max).
/// Padded rows/cols are zero, so padding is inert downstream.
pub fn normalized_dense(g: &Graph, n_max: usize) -> Vec<f32> {
    assert!(g.num_nodes() <= n_max);
    let n = g.num_nodes();
    let mut deg = vec![1.0f64; n]; // self-loop contributes 1 to every degree
    for &(u, v) in g.edges() {
        deg[u as usize] += 1.0;
        deg[v as usize] += 1.0;
    }
    let inv_sqrt: Vec<f64> = deg.iter().map(|d| 1.0 / d.sqrt()).collect();
    let mut a = vec![0.0f32; n_max * n_max];
    for i in 0..n {
        a[i * n_max + i] = (inv_sqrt[i] * inv_sqrt[i]) as f32;
    }
    for &(u, v) in g.edges() {
        let (u, v) = (u as usize, v as usize);
        let w = (inv_sqrt[u] * inv_sqrt[v]) as f32;
        a[u * n_max + v] = w;
        a[v * n_max + u] = w;
    }
    a
}

/// Weighted COO stream of A' non-zeros (both directions + self-loops),
/// ordered by (dst, src). This is the edge stream the Aggregation engine
/// consumes; `reorder::reorder_edges` rearranges it for the RAW window.
pub fn normalized_edges(g: &Graph) -> Vec<WEdge> {
    let n = g.num_nodes();
    let mut deg = vec![1.0f64; n];
    for &(u, v) in g.edges() {
        deg[u as usize] += 1.0;
        deg[v as usize] += 1.0;
    }
    let inv_sqrt: Vec<f64> = deg.iter().map(|d| 1.0 / d.sqrt()).collect();
    let mut out = Vec::with_capacity(g.num_edges() * 2 + n);
    for i in 0..n {
        out.push(WEdge {
            dst: i as u16,
            src: i as u16,
            w: (inv_sqrt[i] * inv_sqrt[i]) as f32,
        });
    }
    for &(u, v) in g.edges() {
        let w = (inv_sqrt[u as usize] * inv_sqrt[v as usize]) as f32;
        out.push(WEdge { dst: u, src: v, w });
        out.push(WEdge { dst: v, src: u, w });
    }
    out.sort_by_key(|e| (e.dst, e.src));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path3() -> Graph {
        Graph::new(3, vec![(0, 1), (1, 2)], vec![0, 0, 0])
    }

    #[test]
    fn dense_matches_hand_computation() {
        // path 0-1-2: deg~ = [2,3,2]
        let a = normalized_dense(&path3(), 4);
        let d = [2.0f64, 3.0, 2.0];
        assert!((a[0] as f64 - 1.0 / d[0]).abs() < 1e-6); // (0,0)
        assert!((a[1] as f64 - 1.0 / (d[0] * d[1]).sqrt()).abs() < 1e-6); // (0,1)
        assert_eq!(a[2], 0.0); // (0,2) no edge
        assert_eq!(a[3], 0.0); // padding col
        assert_eq!(a[12], 0.0); // padding row
    }

    #[test]
    fn dense_is_symmetric() {
        let g = Graph::new(
            5,
            vec![(0, 1), (0, 2), (1, 3), (2, 3), (3, 4)],
            vec![0; 5],
        );
        let n = 8;
        let a = normalized_dense(&g, n);
        for i in 0..n {
            for j in 0..n {
                assert!((a[i * n + j] - a[j * n + i]).abs() < 1e-7);
            }
        }
    }

    #[test]
    fn edges_match_dense() {
        let g = Graph::new(4, vec![(0, 1), (1, 2), (2, 3), (0, 3)], vec![0; 4]);
        let n = 4;
        let dense = normalized_dense(&g, n);
        let edges = normalized_edges(&g);
        let mut rebuilt = vec![0.0f32; n * n];
        for e in &edges {
            rebuilt[e.dst as usize * n + e.src as usize] = e.w;
        }
        assert_eq!(dense, rebuilt);
        // count: 2|E| + n entries
        assert_eq!(edges.len(), 2 * g.num_edges() + g.num_nodes());
    }

    #[test]
    fn rows_of_anorm_sum_leq_one_ish() {
        // For a regular-ish graph, row sums of A' are bounded by 1 + eps.
        let g = path3();
        let a = normalized_dense(&g, 3);
        for i in 0..3 {
            let row: f32 = (0..3).map(|j| a[i * 3 + j]).sum();
            assert!(row <= 1.2, "row {i} sums to {row}");
        }
    }
}
