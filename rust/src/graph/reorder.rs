//! Offline edge reordering (paper §3.2.2): re-arrange the Aggregation
//! edge stream so that edges sharing a destination node are at least `L`
//! positions apart (`L` = accumulator latency). With this guarantee the
//! Aggregation engine sustains II=1 with no RAW-hazard control logic.
//!
//! Greedy longest-remaining-first list scheduling: at each slot, among the
//! destinations whose last emission is >= L slots ago, pick the one with
//! the most remaining edges. This is the classic task-spacing heuristic;
//! when a perfect spacing is impossible (a single destination owns more
//! than 1/L of the stream, which cannot happen for simple graphs with
//! L <= ~8 but can for pathological inputs), the residual edges are
//! appended and the *simulator* accounts for the bubbles.

use super::normalize::WEdge;

/// Result of reordering: the permuted stream plus scheduling metadata.
#[derive(Debug, Clone)]
pub struct ReorderedEdges {
    pub edges: Vec<WEdge>,
    /// Number of trailing edges that violate the spacing guarantee (0 when
    /// a perfect schedule exists).
    pub violations: usize,
}

/// Reorder `edges` so same-destination entries are >= `l` apart.
pub fn reorder_edges(edges: &[WEdge], l: usize) -> ReorderedEdges {
    if l <= 1 || edges.len() <= 1 {
        return ReorderedEdges {
            edges: edges.to_vec(),
            violations: 0,
        };
    }
    let max_dst = edges.iter().map(|e| e.dst as usize).max().unwrap_or(0);
    // Bucket edges per destination.
    let mut buckets: Vec<Vec<WEdge>> = vec![Vec::new(); max_dst + 1];
    for &e in edges {
        buckets[e.dst as usize].push(e);
    }
    let mut last_pos: Vec<isize> = vec![isize::MIN / 2; max_dst + 1];
    let mut out: Vec<WEdge> = Vec::with_capacity(edges.len());
    let mut remaining = edges.len();
    let mut violations = 0usize;
    while remaining > 0 {
        let pos = out.len() as isize;
        // Eligible destination with most remaining edges.
        let mut best: Option<usize> = None;
        for d in 0..buckets.len() {
            if buckets[d].is_empty() || pos - last_pos[d] < l as isize {
                continue;
            }
            match best {
                None => best = Some(d),
                Some(b) if buckets[d].len() > buckets[b].len() => best = Some(d),
                _ => {}
            }
        }
        let d = match best {
            Some(d) => d,
            None => {
                // No eligible destination: forced violation. Emit from the
                // fullest bucket; the hardware would stall here.
                violations += 1;
                (0..buckets.len())
                    .filter(|&d| !buckets[d].is_empty())
                    .max_by_key(|&d| buckets[d].len())
                    .unwrap()
            }
        };
        out.push(buckets[d].pop().unwrap());
        last_pos[d] = pos;
        remaining -= 1;
    }
    ReorderedEdges {
        edges: out,
        violations,
    }
}

/// Minimum distance between two same-destination entries in `edges`
/// (usize::MAX when every destination appears at most once).
pub fn min_same_dst_distance(edges: &[WEdge]) -> usize {
    let mut last: std::collections::HashMap<u16, usize> = Default::default();
    let mut min = usize::MAX;
    for (i, e) in edges.iter().enumerate() {
        if let Some(&p) = last.get(&e.dst) {
            min = min.min(i - p);
        }
        last.insert(e.dst, i);
    }
    min
}

/// Count of RAW stall cycles an II=1 engine with latency `l` would suffer
/// on this stream (0 for a perfectly reordered stream).
pub fn raw_stall_cycles(edges: &[WEdge], l: usize) -> usize {
    let mut last_commit: std::collections::HashMap<u16, usize> = Default::default();
    let mut cycle = 0usize;
    let mut stalls = 0usize;
    for e in edges {
        if let Some(&c) = last_commit.get(&e.dst) {
            // previous update to this dst commits at cycle c + l
            if cycle < c + l {
                stalls += (c + l) - cycle;
                cycle = c + l;
            }
        }
        last_commit.insert(e.dst, cycle);
        cycle += 1;
    }
    stalls
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{generate, Family};
    use crate::graph::normalize::normalized_edges;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn key(e: &WEdge) -> (u16, u16, u32) {
        (e.dst, e.src, e.w.to_bits())
    }

    #[test]
    fn is_permutation_and_spaced() {
        let mut rng = Rng::new(21);
        for _ in 0..20 {
            let g = generate(&mut rng, Family::Aids, 32, 29);
            let edges = normalized_edges(&g);
            let l = 8;
            let r = reorder_edges(&edges, l);
            // permutation check
            let mut a: Vec<_> = edges.iter().map(key).collect();
            let mut b: Vec<_> = r.edges.iter().map(key).collect();
            a.sort_unstable();
            b.sort_unstable();
            assert_eq!(a, b, "reorder must be a permutation");
            if r.violations == 0 {
                assert!(
                    min_same_dst_distance(&r.edges) >= l,
                    "spacing violated without being reported"
                );
                assert_eq!(raw_stall_cycles(&r.edges, l), 0);
            }
        }
    }

    #[test]
    fn reorder_eliminates_stalls_on_sorted_stream() {
        let mut rng = Rng::new(22);
        let g = generate(&mut rng, Family::Aids, 32, 29);
        let edges = normalized_edges(&g); // sorted by dst: worst case
        let l = 8;
        let before = raw_stall_cycles(&edges, l);
        let after = raw_stall_cycles(&reorder_edges(&edges, l).edges, l);
        assert!(before > 0, "sorted stream should stall");
        assert_eq!(after, 0, "reordered stream should not stall");
    }

    #[test]
    fn pathological_stream_reports_violations() {
        // 5 edges all to dst 0 with L=4: needs 4*4 gaps but only 4 fillers.
        let edges: Vec<WEdge> = (0..5)
            .map(|i| WEdge {
                dst: 0,
                src: i as u16,
                w: 1.0,
            })
            .collect();
        let r = reorder_edges(&edges, 4);
        assert!(r.violations > 0);
        assert_eq!(r.edges.len(), 5);
    }

    #[test]
    fn property_reorder_random_streams() {
        check(
            "reorder-spacing",
            40,
            |rng| {
                let g = generate(rng, Family::Aids, 32, 29);
                let l = rng.range(2, 9);
                (normalized_edges(&g), l)
            },
            |(edges, l)| {
                let r = reorder_edges(edges, *l);
                if r.edges.len() != edges.len() {
                    return Err("length changed".into());
                }
                if r.violations == 0 && min_same_dst_distance(&r.edges) < *l {
                    return Err(format!(
                        "min distance {} < L {} with no reported violation",
                        min_same_dst_distance(&r.edges),
                        l
                    ));
                }
                Ok(())
            },
        );
    }
}
