//! Synthetic small-graph workload generators.
//!
//! The paper evaluates on AIDS (25.6 nodes / 27.6 edges avg, 29 node
//! labels), and motivates with LINUX (program dependence graphs, ~7.6
//! nodes) and IMDB (ego-networks, denser). None are downloadable here, so
//! we generate graphs matching their published statistics (DESIGN.md
//! substitution table). All generators yield *connected* graphs.

use crate::util::rng::Rng;

use super::Graph;

/// Workload family, matching the datasets referenced by the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// Antivirus chemical compounds: sparse, labeled, ~25.6 nodes.
    Aids,
    /// Program dependence graphs: small (~7.6 nodes), unlabeled.
    Linux,
    /// Actor ego-networks: ~13 nodes, dense.
    Imdb,
    /// Uniform random baseline G(n, p).
    ErdosRenyi { n: usize, p_millis: u32 },
}

/// Zipf-ish label distribution: chemistry is mostly C/O/N with a long
/// tail, p(i) ∝ 1/(i+1).
pub fn label_weights(num_labels: usize) -> Vec<f64> {
    (0..num_labels).map(|i| 1.0 / (i as f64 + 1.0)).collect()
}

/// Connected random graph: random-attachment spanning tree + extra edges.
fn tree_plus_extra(
    rng: &mut Rng,
    n: usize,
    target_edges: usize,
    num_labels: usize,
) -> Graph {
    let mut edges: Vec<(u16, u16)> = Vec::with_capacity(target_edges);
    for v in 1..n {
        let u = rng.below(v);
        edges.push((u as u16, v as u16));
    }
    let mut eset: std::collections::HashSet<(u16, u16)> =
        edges.iter().copied().map(|(u, v)| (u.min(v), u.max(v))).collect();
    let mut extra = target_edges.saturating_sub(edges.len());
    let mut tries = 0;
    while extra > 0 && tries < 50 * n {
        let u = rng.below(n) as u16;
        let v = rng.below(n) as u16;
        tries += 1;
        if u != v && eset.insert((u.min(v), u.max(v))) {
            extra -= 1;
        }
    }
    let weights = label_weights(num_labels.max(1));
    let labels = (0..n)
        .map(|_| {
            if num_labels <= 1 {
                0u16
            } else {
                rng.weighted(&weights) as u16
            }
        })
        .collect();
    Graph::new(n, eset.into_iter().collect(), labels)
}

/// Generate one graph of the given family, bounded to `n_max` nodes.
pub fn generate(rng: &mut Rng, family: Family, n_max: usize, num_labels: usize) -> Graph {
    match family {
        Family::Aids => {
            let n = (rng.normal_ms(25.6, 5.0).round() as i64).clamp(4, n_max as i64) as usize;
            let m = ((n as f64) * 1.08).round() as usize;
            tree_plus_extra(rng, n, m, num_labels)
        }
        Family::Linux => {
            let n = (rng.normal_ms(7.6, 2.0).round() as i64).clamp(4, n_max as i64) as usize;
            let m = n; // PDGs are nearly tree-like
            tree_plus_extra(rng, n, m, 1)
        }
        Family::Imdb => {
            let n = (rng.normal_ms(13.0, 4.0).round() as i64).clamp(4, n_max as i64) as usize;
            // ego-nets are dense: ~35% of all pairs
            let m = ((n * (n - 1) / 2) as f64 * 0.35).round() as usize;
            tree_plus_extra(rng, n, m.max(n - 1), 1)
        }
        Family::ErdosRenyi { n, p_millis } => {
            let n = n.min(n_max).max(2);
            let p = p_millis as f64 / 1000.0;
            let m = ((n * (n - 1) / 2) as f64 * p).round() as usize;
            tree_plus_extra(rng, n, m.max(n - 1), num_labels)
        }
    }
}

/// Apply `k` random edit operations (relabel / node-insert / edge-insert /
/// edge-delete), mirroring python/compile/graphgen.py. The result is the
/// standard synthetic-GED training protocol: GED(g, perturb(g,k)) <= k.
pub fn perturb(rng: &mut Rng, g: &Graph, k: usize, n_max: usize, num_labels: usize) -> Graph {
    let mut n = g.num_nodes();
    let mut edges: std::collections::BTreeSet<(u16, u16)> =
        g.edges().iter().copied().collect();
    let mut labels = g.labels().to_vec();
    let weights = label_weights(num_labels.max(1));
    for _ in 0..k {
        match rng.below(4) {
            0 => {
                let v = rng.below(n);
                labels[v] = rng.weighted(&weights) as u16;
            }
            1 if n < n_max => {
                let u = rng.below(n) as u16;
                labels.push(rng.weighted(&weights) as u16);
                edges.insert((u.min(n as u16), u.max(n as u16)));
                n += 1;
            }
            2 => {
                for _ in 0..10 {
                    let u = rng.below(n) as u16;
                    let v = rng.below(n) as u16;
                    if u != v && edges.insert((u.min(v), u.max(v))) {
                        break;
                    }
                }
            }
            _ => {
                if edges.len() > n - 1 {
                    let idx = rng.below(edges.len());
                    let e = *edges.iter().nth(idx).unwrap();
                    edges.remove(&e);
                }
            }
        }
    }
    Graph::new(n, edges.into_iter().collect(), labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aids_statistics() {
        let mut rng = Rng::new(11);
        let mut nodes = 0.0;
        let mut edges = 0.0;
        let trials = 300;
        for _ in 0..trials {
            let g = generate(&mut rng, Family::Aids, 32, 29);
            assert!(g.is_connected());
            nodes += g.num_nodes() as f64;
            edges += g.num_edges() as f64;
        }
        let mean_n = nodes / trials as f64;
        let mean_m = edges / trials as f64;
        assert!((20.0..=30.0).contains(&mean_n), "mean nodes {mean_n}");
        assert!(mean_m >= mean_n, "edges {mean_m} < nodes {mean_n}");
    }

    #[test]
    fn linux_is_small_and_unlabeled() {
        let mut rng = Rng::new(12);
        for _ in 0..50 {
            let g = generate(&mut rng, Family::Linux, 32, 29);
            assert!(g.num_nodes() <= 16);
            assert!(g.labels().iter().all(|&l| l == 0));
            assert!(g.is_connected());
        }
    }

    #[test]
    fn imdb_is_denser_than_aids() {
        let mut rng = Rng::new(13);
        let density = |f: Family, rng: &mut Rng| {
            let mut d = 0.0;
            for _ in 0..100 {
                let g = generate(rng, f, 32, 29);
                let n = g.num_nodes() as f64;
                d += g.num_edges() as f64 / (n * (n - 1.0) / 2.0);
            }
            d / 100.0
        };
        let d_imdb = density(Family::Imdb, &mut rng);
        let d_aids = density(Family::Aids, &mut rng);
        assert!(d_imdb > 2.0 * d_aids, "imdb {d_imdb} vs aids {d_aids}");
    }

    #[test]
    fn perturb_preserves_invariants() {
        let mut rng = Rng::new(14);
        let g = generate(&mut rng, Family::Aids, 32, 29);
        let g2 = perturb(&mut rng, &g, 6, 32, 29);
        assert!(g2.num_nodes() <= 32);
        assert_eq!(g2.labels().len(), g2.num_nodes());
        assert!(g2.num_edges() + 6 >= g2.num_nodes() - 1);
    }

    #[test]
    fn perturb_zero_is_identity() {
        let mut rng = Rng::new(15);
        let g = generate(&mut rng, Family::Aids, 32, 29);
        let g2 = perturb(&mut rng, &g, 0, 32, 29);
        assert_eq!(g, g2);
    }
}
