//! The fact table: every Rust source in the repo, lexed and reduced to
//! the queryable facts the rules consume (DESIGN.md S18).
//!
//! `RepoModel::load` walks `rust/src`, `rust/tests`, `benches` and
//! `examples` from the repo root; `RepoModel::from_sources` builds the
//! same model from in-memory `(path, text)` pairs so every rule can be
//! fixture-tested without touching the filesystem.

use std::fmt;
use std::fs;
use std::io;
use std::path::Path;

use super::lexer::{lex, Lexed, Tok, TokKind};

/// One lexed source file plus its repo coordinates.
#[derive(Debug, Clone)]
pub struct SourceFile {
    /// Repo-relative path, forward slashes (`rust/src/nn/simgnn.rs`).
    pub path: String,
    /// Top-level module under `rust/src` (`nn`, `coordinator`, ...);
    /// `lib` / `bin` for the crate roots, `tests` / `benches` /
    /// `examples` for the out-of-tree code.
    pub module: String,
    /// Raw source lines (1-based indexing via `line_text`) for waiver
    /// matching and diagnostics.
    pub lines: Vec<String>,
    pub lex: Lexed,
}

/// A `.method(` call site with its receiver chain.
#[derive(Debug, Clone)]
pub struct MethodCall {
    pub name: String,
    /// Trailing ident chain of the receiver (`self.state.lock()` →
    /// `["self", "state"]`); empty when the receiver is an expression
    /// (`foo().lock()`).
    pub receiver: Vec<String>,
    pub line: u32,
    pub in_test: bool,
    pub func: Option<String>,
}

/// A `name!(` macro invocation site.
#[derive(Debug, Clone)]
pub struct MacroCall {
    pub name: String,
    pub line: u32,
    pub in_test: bool,
    pub func: Option<String>,
}

/// A name reached through a `root::` path — either a direct
/// `root::name(` / `root::name` token or a brace import
/// `use ...::root::{name, other}`.
#[derive(Debug, Clone)]
pub struct QualifiedName {
    pub name: String,
    pub line: u32,
    pub in_test: bool,
}

impl SourceFile {
    fn new(path: String, module: String, src: &str) -> SourceFile {
        SourceFile {
            lines: src.lines().map(str::to_string).collect(),
            lex: lex(src),
            path,
            module,
        }
    }

    /// Raw text of a 1-based line (for waivers and messages).
    pub fn line_text(&self, line: u32) -> &str {
        self.lines
            .get(line.saturating_sub(1) as usize)
            .map(String::as_str)
            .unwrap_or("")
    }

    fn toks(&self) -> &[Tok] {
        &self.lex.toks
    }

    /// Lines where `pat` matches a consecutive token run. Each pattern
    /// element matches a token's text exactly. Test-scope matches are
    /// skipped unless `include_tests`.
    pub fn find_seq(&self, pat: &[&str], include_tests: bool) -> Vec<u32> {
        let toks = self.toks();
        let mut hits = Vec::new();
        if pat.is_empty() || toks.len() < pat.len() {
            return hits;
        }
        for w in toks.windows(pat.len()) {
            if (include_tests || !w[0].in_test)
                && w.iter().zip(pat).all(|(t, p)| t.text == *p)
            {
                hits.push(w[0].line);
            }
        }
        hits
    }

    /// Non-test occurrences of a bare identifier.
    pub fn ident_sites(&self, name: &str, include_tests: bool) -> Vec<u32> {
        self.toks()
            .iter()
            .filter(|t| {
                t.kind == TokKind::Ident
                    && t.text == name
                    && (include_tests || !t.in_test)
            })
            .map(|t| t.line)
            .collect()
    }

    /// Top-level crate modules this file references (`use crate::X`,
    /// inline `crate::X::`), with lines. Non-test only: the layering
    /// contract binds shipped code, not test scaffolding.
    pub fn crate_imports(&self) -> Vec<(String, u32)> {
        let toks = self.toks();
        let mut out = Vec::new();
        let mut i = 0;
        while i + 2 < toks.len() {
            if !toks[i].in_test
                && toks[i].kind == TokKind::Ident
                && toks[i].text == "crate"
                && toks[i + 1].text == ":"
                && toks[i + 2].text == ":"
            {
                let mut j = i + 3;
                if j < toks.len() && toks[j].text == "{" {
                    // `use crate::{a, b::c}` — each group head is an edge.
                    let mut depth = 1;
                    let mut head = true;
                    j += 1;
                    while j < toks.len() && depth > 0 {
                        match toks[j].text.as_str() {
                            "{" => depth += 1,
                            "}" => depth -= 1,
                            "," if depth == 1 => head = true,
                            _ => {
                                if head && toks[j].kind == TokKind::Ident {
                                    out.push((toks[j].text.clone(), toks[j].line));
                                    head = false;
                                }
                            }
                        }
                        j += 1;
                    }
                } else if j < toks.len() && toks[j].kind == TokKind::Ident {
                    out.push((toks[j].text.clone(), toks[j].line));
                }
            }
            i += 1;
        }
        out
    }

    /// `.name(` method-call sites with receiver chains.
    pub fn method_calls(&self) -> Vec<MethodCall> {
        let toks = self.toks();
        let mut out = Vec::new();
        for i in 2..toks.len().saturating_sub(1) {
            if toks[i].kind == TokKind::Ident
                && toks[i - 1].text == "."
                && toks[i + 1].text == "("
            {
                // Walk back over `ident . ident . ... .` to the chain head.
                let mut receiver = Vec::new();
                let mut j = i - 1; // at the `.`
                while j >= 1 && toks[j].text == "." && toks[j - 1].kind == TokKind::Ident {
                    receiver.push(toks[j - 1].text.clone());
                    if j < 2 {
                        break;
                    }
                    j -= 2;
                }
                receiver.reverse();
                out.push(MethodCall {
                    name: toks[i].text.clone(),
                    receiver,
                    line: toks[i].line,
                    in_test: toks[i].in_test,
                    func: self.lex.func_name(&toks[i]).map(str::to_string),
                });
            }
        }
        out
    }

    /// `name!(`-style macro invocation sites.
    pub fn macro_calls(&self) -> Vec<MacroCall> {
        let toks = self.toks();
        let mut out = Vec::new();
        for i in 0..toks.len().saturating_sub(2) {
            if toks[i].kind == TokKind::Ident
                && toks[i + 1].text == "!"
                && matches!(toks[i + 2].text.as_str(), "(" | "[" | "{")
            {
                out.push(MacroCall {
                    name: toks[i].text.clone(),
                    line: toks[i].line,
                    in_test: toks[i].in_test,
                    func: self.lex.func_name(&toks[i]).map(str::to_string),
                });
            }
        }
        out
    }

    /// Names reached through `root::...`: direct paths
    /// (`root::name`) and brace imports (`use ...::root::{a, b}`).
    pub fn qualified_names(&self, root: &str) -> Vec<QualifiedName> {
        let toks = self.toks();
        let mut out = Vec::new();
        let mut i = 0;
        while i + 3 < toks.len() {
            if toks[i].kind == TokKind::Ident
                && toks[i].text == root
                && toks[i + 1].text == ":"
                && toks[i + 2].text == ":"
            {
                let j = i + 3;
                if toks[j].text == "{" {
                    let mut k = j + 1;
                    let mut depth = 1;
                    while k < toks.len() && depth > 0 {
                        match toks[k].text.as_str() {
                            "{" => depth += 1,
                            "}" => depth -= 1,
                            _ => {
                                if toks[k].kind == TokKind::Ident {
                                    out.push(QualifiedName {
                                        name: toks[k].text.clone(),
                                        line: toks[k].line,
                                        in_test: toks[k].in_test,
                                    });
                                }
                            }
                        }
                        k += 1;
                    }
                } else if toks[j].kind == TokKind::Ident {
                    out.push(QualifiedName {
                        name: toks[j].text.clone(),
                        line: toks[j].line,
                        in_test: toks[j].in_test,
                    });
                }
            }
            i += 1;
        }
        out
    }

    /// Local names bound to a `HashMap` (`let mut x: HashMap<..> = ..`,
    /// `x: HashMap<..>` params/fields) — the determinism rule forbids
    /// iterating these where ordering feeds scores.
    pub fn hashmap_bindings(&self) -> Vec<String> {
        let toks = self.toks();
        let mut out = Vec::new();
        for i in 0..toks.len() {
            if toks[i].kind != TokKind::Ident || toks[i].text != "HashMap" {
                continue;
            }
            // Walk back over path qualifiers (`std :: collections ::`)
            // to the `:` of a `name: HashMap<..>` binding.
            let mut j = i;
            while j >= 2
                && toks[j - 1].text == ":"
                && toks[j - 2].text == ":"
                && j >= 3
                && toks[j - 3].kind == TokKind::Ident
            {
                j -= 3;
            }
            if j >= 2 && toks[j - 1].text == ":" && toks[j - 2].kind == TokKind::Ident {
                // Exclude `::` (already unwound) — lone `:` = binding.
                if !(j >= 3 && toks[j - 3].text == ":") {
                    out.push(toks[j - 2].text.clone());
                }
            }
        }
        out.sort();
        out.dedup();
        out
    }

    /// Iteration sites over any of `names`: `for _ in name` /
    /// `name.iter()` / `.values()` / `.keys()` / `.drain()` /
    /// `.into_values()` / `.into_keys()` / `.into_iter()`.
    pub fn iteration_sites(&self, names: &[String]) -> Vec<(String, u32, bool)> {
        const ITER_METHODS: &[&str] = &[
            "iter",
            "iter_mut",
            "into_iter",
            "values",
            "values_mut",
            "into_values",
            "keys",
            "into_keys",
            "drain",
            "retain",
        ];
        let mut out: Vec<(String, u32, bool)> = self
            .method_calls()
            .into_iter()
            .filter(|m| {
                ITER_METHODS.contains(&m.name.as_str())
                    && m.receiver.last().is_some_and(|r| names.contains(r))
            })
            .map(|m| (m.receiver.join("."), m.line, m.in_test))
            .collect();
        let toks = self.toks();
        for i in 0..toks.len().saturating_sub(1) {
            if toks[i].text == "in" && toks[i].kind == TokKind::Ident {
                // `for x in name` (allowing & / &mut).
                let mut j = i + 1;
                while j < toks.len() && (toks[j].text == "&" || toks[j].text == "mut") {
                    j += 1;
                }
                if j < toks.len()
                    && toks[j].kind == TokKind::Ident
                    && names.contains(&toks[j].text)
                    // Direct iteration only: `in name.method()` is
                    // already covered (or deliberate keyed access).
                    && toks.get(j + 1).map(|t| t.text != ".").unwrap_or(true)
                {
                    out.push((toks[j].text.clone(), toks[j].line, toks[j].in_test));
                }
            }
        }
        out
    }

    /// `recv[_timeout]` / `lock` / Condvar-`wait` / blocking-`send`
    /// sites, in source order per function — the raw material for the
    /// lock/channel-order rule.
    pub fn blocking_sites(&self) -> Vec<MethodCall> {
        const BLOCKING: &[&str] = &["lock", "wait", "wait_timeout", "send", "recv", "recv_timeout"];
        self.method_calls()
            .into_iter()
            .filter(|m| BLOCKING.contains(&m.name.as_str()))
            .collect()
    }

    /// `recv`-style indexing sites `ident[...]` (panic-capable facts;
    /// surfaced in `--json`, not a hard rule — see DESIGN.md S18).
    pub fn index_sites(&self) -> Vec<(String, u32, bool)> {
        let toks = self.toks();
        let mut out = Vec::new();
        for i in 0..toks.len().saturating_sub(1) {
            if toks[i].kind == TokKind::Ident
                && toks[i + 1].text == "["
                // `#[attr]` and `<[T; N]>` never have an ident right
                // before `[`, but `matches!(x, Some[..])` patterns do
                // not exist — ident+`[` is an index or a slice pattern.
                && !toks[i].in_test
            {
                out.push((toks[i].text.clone(), toks[i].line, toks[i].in_test));
            }
        }
        out
    }
}

/// The whole-repo fact table.
#[derive(Debug, Clone, Default)]
pub struct RepoModel {
    pub files: Vec<SourceFile>,
    /// Raw `Cargo.toml` lines (comments stripped) for the dependency
    /// and feature rules.
    pub cargo_toml: Vec<String>,
    /// True when loaded from a real tree (`load`): presence anchors
    /// (required files/tokens) apply. False for in-memory fixture
    /// models, which only carry the files under test.
    pub complete: bool,
}

/// Failure to build the model (unreadable tree). Rule violations are
/// never errors — they are findings.
#[derive(Debug)]
pub struct ModelError {
    pub detail: String,
}

impl fmt::Display for ModelError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "analysis model: {}", self.detail)
    }
}

impl std::error::Error for ModelError {}

impl RepoModel {
    /// Walk the repo from `root` (the directory holding `Cargo.toml`).
    pub fn load(root: &Path) -> Result<RepoModel, ModelError> {
        let mut files = Vec::new();
        for tree in ["rust/src", "rust/tests", "benches", "examples"] {
            let dir = root.join(tree);
            if dir.is_dir() {
                walk(&dir, root, &mut files).map_err(|e| ModelError {
                    detail: format!("walking {}: {e}", dir.display()),
                })?;
            }
        }
        if files.is_empty() {
            return Err(ModelError {
                detail: format!("no Rust sources under {} — wrong --root?", root.display()),
            });
        }
        // Deterministic order whatever the filesystem returns.
        files.sort();
        let sources: Vec<(String, String)> = files
            .into_iter()
            .map(|p| {
                let text = fs::read_to_string(root.join(&p)).map_err(|e| ModelError {
                    detail: format!("reading {p}: {e}"),
                })?;
                Ok((p, text))
            })
            .collect::<Result<_, ModelError>>()?;
        let cargo = fs::read_to_string(root.join("Cargo.toml")).unwrap_or_default();
        let mut model = Self::from_parts(sources, &cargo);
        model.complete = true;
        Ok(model)
    }

    /// Build from in-memory sources (rule fixtures). Paths use the same
    /// repo-relative shape as `load` produces.
    pub fn from_sources(sources: Vec<(&str, &str)>) -> RepoModel {
        Self::from_parts(
            sources
                .into_iter()
                .map(|(p, s)| (p.to_string(), s.to_string()))
                .collect(),
            "",
        )
    }

    /// As `from_sources`, with a Cargo.toml body.
    pub fn from_sources_with_cargo(sources: Vec<(&str, &str)>, cargo: &str) -> RepoModel {
        Self::from_parts(
            sources
                .into_iter()
                .map(|(p, s)| (p.to_string(), s.to_string()))
                .collect(),
            cargo,
        )
    }

    fn from_parts(sources: Vec<(String, String)>, cargo: &str) -> RepoModel {
        let files = sources
            .into_iter()
            .map(|(path, text)| {
                let module = module_of(&path);
                SourceFile::new(path, module, &text)
            })
            .collect();
        let cargo_toml = cargo
            .lines()
            .map(|l| l.split('#').next().unwrap_or("").to_string())
            .collect();
        RepoModel { files, cargo_toml, complete: false }
    }

    /// The file at a repo-relative path.
    pub fn file(&self, path: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.path == path)
    }

    /// Files under a repo-relative prefix.
    pub fn under<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a SourceFile> {
        self.files.iter().filter(move |f| f.path.starts_with(prefix))
    }

    /// Non-comment Cargo.toml text contains `needle`.
    pub fn cargo_contains(&self, needle: &str) -> bool {
        self.cargo_toml.iter().any(|l| l.contains(needle))
    }
}

/// Top-level module classification from a repo-relative path.
fn module_of(path: &str) -> String {
    if let Some(rest) = path.strip_prefix("rust/src/") {
        match rest {
            "lib.rs" => "lib".into(),
            "main.rs" => "bin".into(),
            _ => rest.split('/').next().unwrap_or(rest).trim_end_matches(".rs").into(),
        }
    } else if path.starts_with("rust/tests/") {
        "tests".into()
    } else if path.starts_with("benches/") {
        "benches".into()
    } else if path.starts_with("examples/") {
        "examples".into()
    } else {
        "external".into()
    }
}

fn walk(dir: &Path, root: &Path, out: &mut Vec<String>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let p = entry.path();
        if p.is_dir() {
            walk(&p, root, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push(rel);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(path: &str, src: &str) -> RepoModel {
        RepoModel::from_sources(vec![(path, src)])
    }

    #[test]
    fn module_classification() {
        assert_eq!(module_of("rust/src/nn/simgnn.rs"), "nn");
        assert_eq!(module_of("rust/src/lib.rs"), "lib");
        assert_eq!(module_of("rust/src/main.rs"), "bin");
        assert_eq!(module_of("rust/tests/golden.rs"), "tests");
        assert_eq!(module_of("benches/kernels.rs"), "benches");
    }

    #[test]
    fn crate_imports_direct_and_inline_and_braced() {
        let m = one(
            "rust/src/net/x.rs",
            "use crate::coordinator::metrics::Metrics;\n\
             use crate::{graph, nn::config::ModelConfig};\n\
             fn f() { let r = crate::util::rng::Rng::new(1); }\n\
             #[cfg(test)] mod tests { use crate::report::Table; }",
        );
        let f = m.file("rust/src/net/x.rs").unwrap();
        let mods: Vec<String> = f.crate_imports().into_iter().map(|(m, _)| m).collect();
        assert!(mods.contains(&"coordinator".into()));
        assert!(mods.contains(&"graph".into()));
        assert!(mods.contains(&"nn".into()));
        assert!(mods.contains(&"util".into()));
        // test-scope import is invisible to the layering rule
        assert!(!mods.contains(&"report".into()));
    }

    #[test]
    fn method_receiver_chains() {
        let m = one(
            "rust/src/a/b.rs",
            "fn f() { self.state.lock(); ctx.buckets.admit(x); make().lock(); }",
        );
        let calls = m.file("rust/src/a/b.rs").unwrap().method_calls();
        let lock = calls.iter().find(|c| c.name == "lock").unwrap();
        assert_eq!(lock.receiver, vec!["self", "state"]);
        let admit = calls.iter().find(|c| c.name == "admit").unwrap();
        assert_eq!(admit.receiver, vec!["ctx", "buckets"]);
        let expr = calls.iter().filter(|c| c.name == "lock").nth(1).unwrap();
        assert!(expr.receiver.is_empty());
        assert_eq!(lock.func.as_deref(), Some("f"));
    }

    #[test]
    fn qualified_names_paths_and_braces() {
        let m = one(
            "rust/src/nn/x.rs",
            "use super::linalg::{csr_spmm, onehot_gather};\n\
             fn f() { kernels::ntn_bilinear(a, b); }",
        );
        let f = m.file("rust/src/nn/x.rs").unwrap();
        let lin: Vec<String> = f
            .qualified_names("linalg")
            .into_iter()
            .map(|q| q.name)
            .collect();
        assert_eq!(lin, vec!["csr_spmm", "onehot_gather"]);
        let ker: Vec<String> = f
            .qualified_names("kernels")
            .into_iter()
            .map(|q| q.name)
            .collect();
        assert_eq!(ker, vec!["ntn_bilinear"]);
    }

    #[test]
    fn hashmap_bindings_and_iteration() {
        let m = one(
            "rust/src/a/b.rs",
            "fn f(open: HashMap<u64, E>) {\n\
               let mut tab: std::collections::HashMap<u64, E> = Default::default();\n\
               for e in open.into_values() { use_it(e); }\n\
               for k in keys_vec { other(k); }\n\
               tab.insert(1, e);\n\
             }",
        );
        let f = m.file("rust/src/a/b.rs").unwrap();
        let names = f.hashmap_bindings();
        assert!(names.contains(&"open".to_string()), "{names:?}");
        assert!(names.contains(&"tab".to_string()), "{names:?}");
        let iters = f.iteration_sites(&names);
        assert_eq!(iters.len(), 1, "{iters:?}");
        assert_eq!(iters[0].0, "open");
    }

    #[test]
    fn find_seq_skips_comments_strings_tests() {
        let m = one(
            "rust/src/a/b.rs",
            "// SendPolicy::DropNewest in a comment\n\
             let s = \"SendPolicy::DropNewest\";\n\
             #[cfg(test)] mod tests { fn t() { SendPolicy::DropNewest; } }",
        );
        let f = m.file("rust/src/a/b.rs").unwrap();
        assert!(f
            .find_seq(&["SendPolicy", ":", ":", "DropNewest"], false)
            .is_empty());
        assert_eq!(
            f.find_seq(&["SendPolicy", ":", ":", "DropNewest"], true)
                .len(),
            1
        );
    }
}
