//! A minimal Rust lexer for the architecture linter (DESIGN.md S18).
//!
//! This is NOT a compiler front end: it produces exactly what the rule
//! engine needs and nothing more — a token stream with comments,
//! string literals and char literals removed, each token annotated
//! with its line number, whether it sits in test scope
//! (`#[cfg(test)]` items, `#[test]` functions, or a `mod tests`
//! block), and the innermost enclosing `fn` name. Everything the old
//! CI grep guards could not see (a forbidden token inside a comment
//! or string, a test-only token inside `#[cfg(test)]`) is handled
//! here, once, instead of in twenty shell pipelines.
//!
//! Known approximations, acceptable for linting (and covered by unit
//! tests where they matter): const-generic braces in signatures are
//! not distinguished from block braces, and exotic numeric literal
//! forms lex as a single opaque token.

/// Token classes the scanner distinguishes. Strings/chars/comments are
/// consumed but never emitted — rules must not see into them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `unwrap`, `SendPolicy`, ...).
    Ident,
    /// A numeric literal, kept as one opaque token.
    Num,
    /// Lifetime token (`'a`, `'static`) — emitted so char-literal
    /// handling is honest, ignored by every rule.
    Lifetime,
    /// Single punctuation character (`.`, `:`, `(`, `!`, ...).
    Punct,
}

/// One surviving token.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    /// 1-based source line.
    pub line: u32,
    /// Inside `#[cfg(test)]` / `#[test]` / `mod tests` scope.
    pub in_test: bool,
    /// Innermost enclosing function name, if any.
    pub func: Option<u32>,
}

/// A lexed file: tokens plus the function-name table `Tok::func`
/// indexes into.
#[derive(Debug, Clone, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub funcs: Vec<String>,
}

impl Lexed {
    /// The function name a token belongs to (for diagnostics).
    pub fn func_name(&self, t: &Tok) -> Option<&str> {
        t.func.map(|i| self.funcs[i as usize].as_str())
    }
}

/// Strip comments/strings/chars and tokenize. Never fails: unterminated
/// constructs consume to end-of-input (the linter must not panic on a
/// half-saved file; rustc will complain about it soon enough).
pub fn lex(src: &str) -> Lexed {
    let raw = raw_tokens(src);
    annotate(raw)
}

/// Pass 1: raw tokens with line numbers, comments/strings removed.
fn raw_tokens(src: &str) -> Vec<(TokKind, String, u32)> {
    let b: Vec<char> = src.chars().collect();
    let mut out = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < b.len() {
        let c = b[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_whitespace() => i += 1,
            '/' if i + 1 < b.len() && b[i + 1] == '/' => {
                while i < b.len() && b[i] != '\n' {
                    i += 1;
                }
            }
            '/' if i + 1 < b.len() && b[i + 1] == '*' => {
                // Block comments nest in Rust.
                let mut depth = 1;
                i += 2;
                while i < b.len() && depth > 0 {
                    if b[i] == '\n' {
                        line += 1;
                        i += 1;
                    } else if b[i] == '/' && i + 1 < b.len() && b[i + 1] == '*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == '*' && i + 1 < b.len() && b[i + 1] == '/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            '"' => i = skip_string(&b, i, &mut line),
            'r' | 'b' if starts_raw_or_byte_string(&b, i) => {
                // r"..", r#".."#, b"..", br".." , rb is not a thing but
                // br# is; skip the prefix letters then dispatch.
                let mut j = i;
                while j < b.len() && (b[j] == 'r' || b[j] == 'b') {
                    j += 1;
                }
                if j < b.len() && b[j] == '#' || (j < b.len() && b[j] == '"') {
                    if b[i..j.min(b.len())].contains(&'r') {
                        i = skip_raw_string(&b, j, &mut line);
                    } else {
                        i = skip_string(&b, j, &mut line);
                    }
                } else if j < b.len() && b[j] == '\'' {
                    // b'x' byte literal.
                    i = skip_char(&b, j, &mut line);
                } else {
                    // Plain identifier starting with r/b after all.
                    i = push_ident(&b, i, line, &mut out);
                }
            }
            '\'' => {
                // Lifetime or char literal. `'ident` not followed by a
                // closing quote is a lifetime; anything else is a char.
                if is_lifetime(&b, i) {
                    let mut j = i + 1;
                    let start = j;
                    while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
                        j += 1;
                    }
                    let name: String = b[start..j].iter().collect();
                    out.push((TokKind::Lifetime, format!("'{name}"), line));
                    i = j;
                } else {
                    i = skip_char(&b, i, &mut line);
                }
            }
            c if c.is_alphabetic() || c == '_' => i = push_ident(&b, i, line, &mut out),
            c if c.is_ascii_digit() => {
                let start = i;
                while i < b.len()
                    && (b[i].is_alphanumeric()
                        || b[i] == '_'
                        || (b[i] == '.' && i + 1 < b.len() && b[i + 1].is_ascii_digit())
                        || ((b[i] == '+' || b[i] == '-')
                            && i > start
                            && (b[i - 1] == 'e' || b[i - 1] == 'E')))
                {
                    i += 1;
                }
                out.push((TokKind::Num, b[start..i].iter().collect(), line));
            }
            c => {
                out.push((TokKind::Punct, c.to_string(), line));
                i += 1;
            }
        }
    }
    out
}

fn starts_raw_or_byte_string(b: &[char], i: usize) -> bool {
    // r" r#" b" b' br" br#" — a prefix of r/b letters followed by a
    // quote, hashes-then-quote, or byte-char quote. `r#ident` (a raw
    // identifier) and plain identifiers starting with r/b (`radius`)
    // must NOT match.
    let mut j = i;
    while j < b.len() && (b[j] == 'r' || b[j] == 'b') && j - i < 2 {
        j += 1;
    }
    if j == i || j >= b.len() {
        return false;
    }
    match b[j] {
        '"' => true,
        '\'' => b[i..j].contains(&'b') && !b[i..j].contains(&'r'),
        '#' => {
            // Raw string only if the hash run ends at a quote.
            let mut k = j;
            while k < b.len() && b[k] == '#' {
                k += 1;
            }
            b[i..j].contains(&'r') && k < b.len() && b[k] == '"'
        }
        _ => false,
    }
}

fn is_lifetime(b: &[char], i: usize) -> bool {
    // 'x is a lifetime unless the ident is one char and followed by '.
    if i + 1 >= b.len() || !(b[i + 1].is_alphabetic() || b[i + 1] == '_') {
        return false;
    }
    let mut j = i + 1;
    while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
        j += 1;
    }
    !(j < b.len() && b[j] == '\'')
}

fn push_ident(b: &[char], i: usize, line: u32, out: &mut Vec<(TokKind, String, u32)>) -> usize {
    let start = i;
    let mut j = i;
    while j < b.len() && (b[j].is_alphanumeric() || b[j] == '_') {
        j += 1;
    }
    out.push((TokKind::Ident, b[start..j].iter().collect(), line));
    j
}

fn skip_string(b: &[char], mut i: usize, line: &mut u32) -> usize {
    debug_assert_eq!(b[i], '"');
    i += 1;
    while i < b.len() {
        match b[i] {
            '\\' => i += 2,
            '\n' => {
                *line += 1;
                i += 1;
            }
            '"' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

fn skip_raw_string(b: &[char], mut i: usize, line: &mut u32) -> usize {
    // At `#...#"` or `"`; count hashes, then scan for `"` + that many #.
    let mut hashes = 0;
    while i < b.len() && b[i] == '#' {
        hashes += 1;
        i += 1;
    }
    if i < b.len() && b[i] == '"' {
        i += 1;
    }
    while i < b.len() {
        if b[i] == '\n' {
            *line += 1;
            i += 1;
        } else if b[i] == '"' {
            let mut j = i + 1;
            let mut h = 0;
            while j < b.len() && b[j] == '#' && h < hashes {
                h += 1;
                j += 1;
            }
            if h == hashes {
                return j;
            }
            i += 1;
        } else {
            i += 1;
        }
    }
    i
}

fn skip_char(b: &[char], mut i: usize, line: &mut u32) -> usize {
    debug_assert_eq!(b[i], '\'');
    i += 1;
    while i < b.len() {
        match b[i] {
            '\\' => i += 2,
            '\n' => {
                *line += 1;
                i += 1;
            }
            '\'' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

/// Append one annotated token (shared by every `annotate` arm; a free
/// function because the arms also mutate the scope stacks).
fn emit_tok(
    toks: &mut Vec<Tok>,
    test_close: &[usize],
    fn_stack: &[(u32, usize)],
    kind: TokKind,
    text: &str,
    line: u32,
) {
    toks.push(Tok {
        kind,
        text: text.to_string(),
        line,
        in_test: !test_close.is_empty(),
        func: fn_stack.last().map(|(f, _)| *f),
    });
}

/// Pass 2: brace-depth scope machine. Marks test scope and the
/// innermost function per token.
fn annotate(raw: Vec<(TokKind, String, u32)>) -> Lexed {
    let mut toks = Vec::with_capacity(raw.len());
    let mut funcs: Vec<String> = Vec::new();

    let mut depth = 0usize;
    // Brace depths at which a test region closes.
    let mut test_close: Vec<usize> = Vec::new();
    // (func table index, body depth).
    let mut fn_stack: Vec<(u32, usize)> = Vec::new();
    // A `#[cfg(test)]` / `#[test]` attribute awaits its item's block.
    let mut pending_test = false;
    // A `fn NAME` awaits its body block.
    let mut pending_fn: Option<u32> = None;

    let mut i = 0usize;
    while i < raw.len() {
        let (kind, text, line) = (&raw[i].0, raw[i].1.as_str(), raw[i].2);
        match (kind, text) {
            (TokKind::Punct, "#")
                if matches!(raw.get(i + 1), Some((TokKind::Punct, t, _)) if t == "[") =>
            {
                // Consume the whole attribute, bracket-balanced, and
                // look for `cfg ( test` or a bare `test` / `should_panic`.
                let mut j = i + 2;
                let mut nest = 1usize;
                let mut attr: Vec<&str> = Vec::new();
                while j < raw.len() && nest > 0 {
                    match (&raw[j].0, raw[j].1.as_str()) {
                        (TokKind::Punct, "[") => nest += 1,
                        (TokKind::Punct, "]") => nest -= 1,
                        (_, t) => attr.push(t),
                    }
                    if nest > 0 {
                        j += 1;
                    }
                }
                let is_cfg_test = attr
                    .windows(3)
                    .any(|w| w[0] == "cfg" && w[1] == "(" && w[2] == "test");
                let is_test_attr =
                    attr.first().is_some_and(|t| *t == "test" || *t == "should_panic");
                if is_cfg_test || is_test_attr {
                    pending_test = true;
                }
                // Emit the attribute tokens too (rules may want e.g.
                // `#[derive(...)]` facts) — annotated with current scope.
                for k in i..=j.min(raw.len().saturating_sub(1)) {
                    let (ak, at, al) = (&raw[k].0, raw[k].1.as_str(), raw[k].2);
                    emit_tok(&mut toks, &test_close, &fn_stack, *ak, at, al);
                }
                i = j + 1;
                continue;
            }
            (TokKind::Ident, "fn") => {
                if let Some((TokKind::Ident, name, _)) = raw.get(i + 1) {
                    let idx = funcs.len() as u32;
                    funcs.push(name.clone());
                    pending_fn = Some(idx);
                }
            }
            (TokKind::Ident, "mod") => {
                if matches!(raw.get(i + 1), Some((TokKind::Ident, n, _)) if n == "tests") {
                    pending_test = true;
                }
            }
            (TokKind::Punct, "{") => {
                emit_tok(&mut toks, &test_close, &fn_stack, TokKind::Punct, "{", line);
                depth += 1;
                if pending_test {
                    test_close.push(depth);
                    pending_test = false;
                }
                if let Some(f) = pending_fn.take() {
                    fn_stack.push((f, depth));
                }
                i += 1;
                continue;
            }
            (TokKind::Punct, "}") => {
                if test_close.last() == Some(&depth) {
                    test_close.pop();
                }
                if fn_stack.last().map(|(_, d)| *d) == Some(depth) {
                    fn_stack.pop();
                }
                depth = depth.saturating_sub(1);
                emit_tok(&mut toks, &test_close, &fn_stack, TokKind::Punct, "}", line);
                i += 1;
                continue;
            }
            (TokKind::Punct, ";") => {
                // `#[cfg(test)] use ...;` or a bodyless trait fn: the
                // pending markers never get a block — drop them.
                if fn_stack.last().map(|(_, d)| *d) != Some(depth) {
                    pending_fn = None;
                }
                pending_test = false;
            }
            _ => {}
        }
        emit_tok(&mut toks, &test_close, &fn_stack, *kind, text, line);
        i += 1;
    }
    Lexed { toks, funcs }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(l: &Lexed) -> Vec<&str> {
        l.toks.iter().map(|t| t.text.as_str()).collect()
    }

    #[test]
    fn strips_line_and_block_comments() {
        let l = lex("a // unwrap()\nb /* panic! /* nested */ still */ c");
        assert_eq!(texts(&l), vec!["a", "b", "c"]);
        assert_eq!(l.toks[1].line, 2);
        assert_eq!(l.toks[2].line, 2);
    }

    #[test]
    fn strips_strings_and_chars() {
        let l = lex(r#"let x = "unwrap()"; let c = '\''; let s = 'a';"#);
        assert!(!texts(&l).contains(&"unwrap"));
        // multi-line string keeps line numbers honest
        let l = lex("let x = \"a\nb\";\ny");
        assert_eq!(l.toks.last().unwrap().line, 3);
    }

    #[test]
    fn raw_strings_and_byte_strings() {
        let l = lex(r###"let x = r#"panic!("inside")"#; after"###);
        assert!(!texts(&l).contains(&"panic"));
        assert!(texts(&l).contains(&"after"));
        let l = lex(r#"let y = b"unwrap"; z"#);
        assert!(!texts(&l).contains(&"unwrap"));
        assert!(texts(&l).contains(&"z"));
    }

    #[test]
    fn lifetimes_are_not_chars() {
        let l = lex("fn f<'a>(x: &'a str) -> &'static str { x }");
        assert!(texts(&l).contains(&"'a"));
        assert!(texts(&l).contains(&"'static"));
        assert!(texts(&l).contains(&"str"));
    }

    #[test]
    fn cfg_test_scope_marks_tokens() {
        let src = "fn live() { a.unwrap(); }\n\
                   #[cfg(test)]\nmod checks {\n fn t() { b.unwrap(); } }";
        let l = lex(src);
        let hits: Vec<_> = l
            .toks
            .iter()
            .filter(|t| t.text == "unwrap")
            .map(|t| t.in_test)
            .collect();
        assert_eq!(hits, vec![false, true]);
    }

    #[test]
    fn mod_tests_scope_without_attr() {
        let l = lex("mod tests { fn t() { x.unwrap(); } }\nfn live() { y.unwrap(); }");
        let hits: Vec<_> = l
            .toks
            .iter()
            .filter(|t| t.text == "unwrap")
            .map(|t| t.in_test)
            .collect();
        assert_eq!(hits, vec![true, false]);
    }

    #[test]
    fn cfg_test_on_single_item_does_not_leak() {
        let src = "#[cfg(test)]\nuse std::fmt;\nfn live() { x.unwrap(); }";
        let l = lex(src);
        let t = l.toks.iter().find(|t| t.text == "unwrap").unwrap();
        assert!(!t.in_test, "cfg(test) on a use item leaked to the next fn");
    }

    #[test]
    fn function_attribution() {
        let l = lex("fn outer() { inner_call(); fn nested() { deep(); } tail(); }");
        let f = |name: &str| {
            let t = l.toks.iter().find(|t| t.text == name).unwrap();
            l.func_name(t).unwrap().to_string()
        };
        assert_eq!(f("inner_call"), "outer");
        assert_eq!(f("deep"), "nested");
        assert_eq!(f("tail"), "outer");
    }

    #[test]
    fn test_attr_marks_next_fn() {
        let l = lex("#[test]\nfn check() { x.unwrap(); }\nfn live() { y.unwrap(); }");
        let hits: Vec<_> = l
            .toks
            .iter()
            .filter(|t| t.text == "unwrap")
            .map(|t| t.in_test)
            .collect();
        assert_eq!(hits, vec![true, false]);
    }

    #[test]
    fn numbers_lex_opaque() {
        let l = lex("let a = 1_000.5e-3; let b = 0xFFu32; c");
        assert!(texts(&l).contains(&"c"));
        assert!(l.toks.iter().any(|t| t.kind == TokKind::Num));
    }
}
