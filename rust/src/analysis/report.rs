//! Render lint findings through the repo's report harness
//! (`report::Table`) and to machine-readable JSON for the CI artifact.

use crate::report::Table;
use crate::util::json::{arr, obj, s, Json};

use super::rules::{active, Finding, RULES};
use super::LintOutcome;

/// Findings as an aligned table: one row per finding, waived rows
/// tagged so the full picture stays visible next to the verdict.
pub fn findings_table(outcome: &LintOutcome) -> Table {
    let mut t = Table::new("spa-gcn lint", &["location", "rule", "status", "detail"]);
    for f in &outcome.findings {
        let loc = if f.line > 0 {
            format!("{}:{}", f.path, f.line)
        } else {
            f.path.clone()
        };
        let status = if f.waived.is_some() { "waived" } else { "FAIL" };
        t.row(vec![loc, f.rule.to_string(), status.into(), f.message.clone()]);
    }
    let failing = active(&outcome.findings).count();
    let waived = outcome.findings.len() - failing;
    t.note(&format!(
        "{} files scanned, {} rules, {failing} failing, {waived} waived",
        outcome.files_scanned,
        RULES.len(),
    ));
    t
}

/// Human-readable lint report: table when anything is failing, a
/// one-line all-clear (with the waived count) otherwise — a tree that
/// is clean *because of* waivers says so rather than dumping the table.
pub fn render_text(outcome: &LintOutcome) -> String {
    if active(&outcome.findings).next().is_some() {
        findings_table(outcome).render()
    } else {
        let waived = outcome.findings.len();
        let tail = if waived > 0 {
            format!(", {waived} waived")
        } else {
            String::new()
        };
        format!(
            "spa-gcn lint: clean ({} files, {} rules{tail})\n",
            outcome.files_scanned,
            RULES.len()
        )
    }
}

fn finding_json(f: &Finding) -> Json {
    let mut fields = vec![
        ("rule", s(f.rule)),
        ("path", s(&f.path)),
        ("line", Json::Num(f.line as f64)),
        ("message", s(&f.message)),
    ];
    match &f.waived {
        Some(j) => fields.push(("waived", s(j))),
        None => fields.push(("waived", Json::Null)),
    }
    obj(fields)
}

/// Full machine-readable dump: verdict, rule catalog, every finding
/// (waived included). Uploaded as the CI lint artifact.
pub fn to_json(outcome: &LintOutcome) -> Json {
    let failing = active(&outcome.findings).count();
    obj(vec![
        ("schema", s("spa-gcn-lint-v1")),
        ("ok", Json::Bool(failing == 0)),
        ("files_scanned", Json::Num(outcome.files_scanned as f64)),
        ("failing", Json::Num(failing as f64)),
        (
            "waived",
            Json::Num((outcome.findings.len() - failing) as f64),
        ),
        (
            "rules",
            arr(RULES
                .iter()
                .map(|(id, contract)| obj(vec![("id", s(id)), ("contract", s(contract))]))
                .collect()),
        ),
        (
            "findings",
            arr(outcome.findings.iter().map(finding_json).collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome_with(findings: Vec<Finding>) -> LintOutcome {
        LintOutcome { findings, files_scanned: 3 }
    }

    fn one_finding(waived: Option<&str>) -> Finding {
        Finding {
            rule: "PANIC-FREE",
            path: "rust/src/net/server.rs".into(),
            line: 7,
            message: "unwrap in serving code (fn serve)".into(),
            waived: waived.map(str::to_string),
        }
    }

    #[test]
    fn clean_tree_renders_one_line() {
        let text = render_text(&outcome_with(Vec::new()));
        assert!(text.starts_with("spa-gcn lint: clean"), "{text}");
    }

    #[test]
    fn waived_only_tree_renders_one_line_with_count() {
        let text = render_text(&outcome_with(vec![one_finding(Some("poisoned-lock recovery"))]));
        assert!(text.starts_with("spa-gcn lint: clean"), "{text}");
        assert!(text.contains("1 waived"), "{text}");
    }

    #[test]
    fn findings_render_with_status() {
        let text = render_text(&outcome_with(vec![
            one_finding(None),
            one_finding(Some("poisoned-lock recovery")),
        ]));
        assert!(text.contains("rust/src/net/server.rs:7"), "{text}");
        assert!(text.contains("FAIL"), "{text}");
        assert!(text.contains("waived"), "{text}");
        assert!(text.contains("1 failing, 1 waived"), "{text}");
    }

    #[test]
    fn json_carries_verdict_and_catalog() {
        let j = to_json(&outcome_with(vec![one_finding(None)])).to_string();
        assert!(j.contains("\"schema\":\"spa-gcn-lint-v1\""), "{j}");
        assert!(j.contains("\"ok\":false"), "{j}");
        assert!(j.contains("PANIC-FREE"), "{j}");
        assert!(j.contains("\"contract\""), "{j}");
        let clean = to_json(&outcome_with(Vec::new())).to_string();
        assert!(clean.contains("\"ok\":true"), "{clean}");
    }
}
