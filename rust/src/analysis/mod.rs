//! In-repo architecture linter (`spa-gcn lint`, DESIGN.md S18).
//!
//! A lightweight static-analysis pass over the repo's own sources that
//! enforces the load-bearing invariants the CI grep-guards used to
//! approximate: the sparse-only scoring path (S13), the split
//! embed/pair cache API (S14/S15), the single ranking comparator
//! (S15), the kernel dispatch layer (S16), the std-only net front door
//! (S17), the module layering DAG, panic-freedom of serving threads,
//! and lock/channel acquisition ordering. Unlike grep, the lexer sees
//! through comments, strings and `#[cfg(test)]` scope, so rules bind
//! to code rather than to bytes.
//!
//! Exceptions live in `waivers.txt` next to this module — every entry
//! carries a justification, stale entries are themselves findings.

pub mod lexer;
pub mod model;
pub mod report;
pub mod rules;

use std::path::Path;

pub use model::{ModelError, RepoModel};
pub use rules::{active, Finding};

/// The checked-in waiver list; each line is
/// `rule | path | line fragment | justification`.
pub const WAIVERS: &str = include_str!("waivers.txt");

/// Result of a lint run over a tree.
#[derive(Debug, Clone)]
pub struct LintOutcome {
    /// Every finding, waived ones marked. Sorted by (path, line, rule).
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
}

impl LintOutcome {
    /// True when no unwaived finding remains.
    pub fn ok(&self) -> bool {
        active(&self.findings).next().is_none()
    }
}

/// Lint the tree rooted at `root` (the directory holding `Cargo.toml`)
/// against every rule and the checked-in waivers.
pub fn run_lint(root: &Path) -> Result<LintOutcome, ModelError> {
    let model = RepoModel::load(root)?;
    Ok(lint_model(&model))
}

/// Lint an already-built model (fixtures, tests).
pub fn lint_model(model: &RepoModel) -> LintOutcome {
    LintOutcome {
        findings: rules::run(model, WAIVERS),
        files_scanned: model.files.len(),
    }
}
