//! The typed rule engine: every invariant the repo enforces, as a
//! function over the [`RepoModel`] fact table, with `file:line`
//! diagnostics and a checked-in waiver list.
//!
//! Rule ids are stable (DESIGN.md S18 maps each id to its contract and
//! origin PR). A finding is *waived* — reported but not failing — when
//! `waivers.txt` carries a matching `(rule, path, fragment)` entry with
//! a justification; stale or malformed waivers are themselves findings.

use std::collections::{BTreeMap, BTreeSet};

use super::model::{RepoModel, SourceFile};

/// Stable rule ids and their one-line contracts, in report order.
pub const RULES: &[(&str, &str)] = &[
    ("ENGINE-API-BUILD", "no string engine dispatch: build_engine() must not exist"),
    ("ENGINE-API-TIMING", "no last_timing side-channel: engines report telemetry via typed QueryTiming"),
    ("SPARSE-DENSE-SINGLE", "exactly one dense A'@X aggregation site (the SparsePolicy::Dense branch in nn/simgnn.rs)"),
    ("SPARSE-DENSE-CONFINED", "dense aggregation never reaches runtime/, coordinator/ or sim/"),
    ("SPARSE-DEFAULT-CSR", "the native engine defaults to SparsePolicy::Csr"),
    ("CACHE-SPLIT-API", "cached scoring paths use embed_graph/pair_score, never the fused simgnn_forward"),
    ("CACHE-CONSTRUCT", "both cache-bearing engines default-construct an Arc'd EmbedCache and expose with_cache"),
    ("DET-RANK-SITE", "pipeline.rs grows no ranking implementation: no sort/BinaryHeap/total_cmp; gather merges via rank_sharded"),
    ("DET-TIEBREAK", "exactly one ranking comparator (total_cmp) exists, in corpus.rs"),
    ("DET-HASH-ITER", "no HashMap iteration order feeds scores or ranking in corpus.rs/pipeline.rs"),
    ("ARCH-DAG", "module imports follow util -> graph -> {ged,nn} -> {sim,runtime} -> report -> coordinator -> net"),
    ("ARCH-KERNEL-CALLER", "only nn/simgnn.rs calls the kernels::* dispatchers"),
    ("ARCH-LINALG-CONFINED", "only nn/kernels.rs calls the guarded linalg reference kernels"),
    ("ARCH-KERNEL-PRESENT", "nn/simgnn.rs scores through the kernels:: dispatch layer"),
    ("KERNEL-DEFAULT-SIMD", "the simd feature stays default-on so serving builds ship the lanes path"),
    ("NET-STD-ONLY", "no async runtime / HTTP stack / serde in Cargo.toml or rust/src/net"),
    ("NET-STD-PINNED", "net/server.rs serves over the pinned std::net listener types"),
    ("NET-SINGLE-SUBMITTER", "the listener submits only through the admission submit_handle"),
    ("NET-QUERY-CONFINED", "only net/admission.rs constructs Query values"),
    ("NET-DROP-NEWEST", "the admission queue keeps SendPolicy::DropNewest"),
    ("TRACE-CONFINED", "only coordinator/trace.rs constructs TraceEntry values (TraceWriter/Trace::parse are the codec)"),
    ("EPOCH-SWAP-CONFINED", "only coordinator/corpus_store.rs Arc-wraps a Corpus (epoch generations swap through CorpusStore)"),
    ("PANIC-FREE", "serving threads (net/, coordinator pipeline/channel/batcher/router) carry no panic-capable tokens"),
    ("LOCK-ORDER", "the per-function lock/channel acquisition graph has no cross-module cycle"),
    ("WAIVER-MALFORMED", "every waiver entry parses and carries a justification"),
    ("WAIVER-STALE", "every waiver entry suppresses at least one live finding"),
];

/// One diagnostic. `line == 0` means a file- or repo-level finding
/// (a required token is absent).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub path: String,
    pub line: u32,
    pub message: String,
    /// Justification from the matching waiver, when one applies.
    pub waived: Option<String>,
}

impl Finding {
    fn new(rule: &'static str, path: &str, line: u32, message: String) -> Finding {
        Finding { rule, path: path.to_string(), line, message, waived: None }
    }

    /// `rust/src/x.rs:12 [RULE] message` (line elided when 0).
    pub fn render(&self) -> String {
        let loc = if self.line > 0 {
            format!("{}:{}", self.path, self.line)
        } else {
            self.path.clone()
        };
        let tag = match &self.waived {
            Some(j) => format!(" (waived: {j})"),
            None => String::new(),
        };
        format!("{loc} [{}] {}{tag}", self.rule, self.message)
    }
}

/// One `waivers.txt` entry: `rule | path | line fragment | justification`.
#[derive(Debug, Clone)]
pub struct Waiver {
    pub rule: String,
    pub path: String,
    pub fragment: String,
    pub justification: String,
    /// 1-based line in waivers.txt, for stale-waiver diagnostics.
    pub line: u32,
}

const WAIVERS_PATH: &str = "rust/src/analysis/waivers.txt";

/// Parse the waiver list; malformed lines become findings.
pub fn parse_waivers(text: &str) -> (Vec<Waiver>, Vec<Finding>) {
    let mut waivers = Vec::new();
    let mut findings = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = (i + 1) as u32;
        let l = raw.trim();
        if l.is_empty() || l.starts_with('#') {
            continue;
        }
        let parts: Vec<&str> = l.splitn(4, '|').map(str::trim).collect();
        if parts.len() != 4 || parts.iter().any(|p| p.is_empty()) {
            findings.push(Finding::new(
                "WAIVER-MALFORMED",
                WAIVERS_PATH,
                line,
                format!("need `rule | path | fragment | justification`, got {l:?}"),
            ));
            continue;
        }
        waivers.push(Waiver {
            rule: parts[0].to_string(),
            path: parts[1].to_string(),
            fragment: parts[2].to_string(),
            justification: parts[3].to_string(),
            line,
        });
    }
    (waivers, findings)
}

/// Run every rule over the model, apply waivers, report stale ones.
/// Waived findings stay in the output (marked) so `--json` shows the
/// full picture; only unwaived findings fail the lint.
pub fn run(model: &RepoModel, waivers_text: &str) -> Vec<Finding> {
    let (waivers, mut findings) = parse_waivers(waivers_text);
    let mut raw = Vec::new();
    if model.complete {
        // Files that rules anchor invariants to: deleting one must not
        // silently retire its contract.
        for path in [
            "rust/src/nn/simgnn.rs",
            "rust/src/nn/kernels.rs",
            "rust/src/coordinator/pipeline.rs",
            "rust/src/coordinator/corpus.rs",
        ] {
            if model.file(path).is_none() {
                raw.push(Finding::new(
                    "ARCH-KERNEL-PRESENT",
                    path,
                    0,
                    "rule anchor file missing from the tree".into(),
                ));
            }
        }
    }
    engine_api(model, &mut raw);
    sparse_path(model, &mut raw);
    cache_api(model, &mut raw);
    determinism(model, &mut raw);
    layering(model, &mut raw);
    kernel_dispatch(model, &mut raw);
    net_front_door(model, &mut raw);
    trace_confined(model, &mut raw);
    epoch_swap_confined(model, &mut raw);
    panic_free(model, &mut raw);
    lock_order(model, &mut raw);

    let mut used = vec![false; waivers.len()];
    for f in &mut raw {
        let text = model.file(&f.path).map(|s| s.line_text(f.line)).unwrap_or("");
        for (i, w) in waivers.iter().enumerate() {
            if w.rule == f.rule && w.path == f.path && text.contains(w.fragment.as_str()) {
                f.waived = Some(w.justification.clone());
                used[i] = true;
                break;
            }
        }
    }
    for (w, used) in waivers.iter().zip(&used) {
        if !used {
            findings.push(Finding::new(
                "WAIVER-STALE",
                WAIVERS_PATH,
                w.line,
                format!(
                    "waiver for {} at {} ({:?}) matches no finding — delete it",
                    w.rule, w.path, w.fragment
                ),
            ));
        }
    }
    findings.extend(raw);
    findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule).cmp(&(b.path.as_str(), b.line, b.rule))
    });
    findings
}

/// The findings that actually fail the lint.
pub fn active(findings: &[Finding]) -> impl Iterator<Item = &Finding> {
    findings.iter().filter(|f| f.waived.is_none())
}

// ---------------------------------------------------------------- rules

/// ENGINE-API-BUILD / ENGINE-API-TIMING (ported grep: "engine API v2
/// guards"): the typed EngineBuilder/EngineKind API replaced string
/// dispatch and the last_timing side-channel (DESIGN.md S6).
fn engine_api(m: &RepoModel, out: &mut Vec<Finding>) {
    for f in &m.files {
        for line in f.find_seq(&["build_engine", "("], true) {
            out.push(Finding::new(
                "ENGINE-API-BUILD",
                &f.path,
                line,
                "string engine dispatch reintroduced".into(),
            ));
        }
        for line in f.ident_sites("last_timing", true) {
            out.push(Finding::new(
                "ENGINE-API-TIMING",
                &f.path,
                line,
                "last_timing side-channel reintroduced".into(),
            ));
        }
    }
}

const DENSE_AGG: &[&str] = &["matmul", "(", "&", "g", ".", "a_norm"];

/// SPARSE-DENSE-SINGLE / SPARSE-DENSE-CONFINED / SPARSE-DEFAULT-CSR
/// (ported grep: "sparse scoring-path guards", DESIGN.md S13).
fn sparse_path(m: &RepoModel, out: &mut Vec<Finding>) {
    if let Some(f) = m.file("rust/src/nn/simgnn.rs") {
        let hits = f.find_seq(DENSE_AGG, true);
        if hits.len() != 1 {
            out.push(Finding::new(
                "SPARSE-DENSE-SINGLE",
                &f.path,
                hits.get(1).copied().unwrap_or(0),
                format!(
                    "want exactly one dense aggregation matmul (the SparsePolicy::Dense branch), found {}",
                    hits.len()
                ),
            ));
        }
    }
    for f in m.files.iter().filter(|f| {
        ["rust/src/runtime/", "rust/src/coordinator/", "rust/src/sim/"]
            .iter()
            .any(|p| f.path.starts_with(p))
    }) {
        for line in f.find_seq(DENSE_AGG, true) {
            out.push(Finding::new(
                "SPARSE-DENSE-CONFINED",
                &f.path,
                line,
                "dense aggregation leaked into the serving path".into(),
            ));
        }
    }
    require_seq(
        m,
        "rust/src/runtime/native.rs",
        &["policy", ":", "SparsePolicy", ":", ":", "Csr"],
        "SPARSE-DEFAULT-CSR",
        "native engine no longer defaults to the sparse policy",
        out,
    );
}

/// CACHE-SPLIT-API / CACHE-CONSTRUCT (ported grep: "embed cache
/// guards", DESIGN.md S14/S15).
fn cache_api(m: &RepoModel, out: &mut Vec<Finding>) {
    for f in m.files.iter().filter(|f| {
        f.path.starts_with("rust/src/runtime/")
            || f.path.starts_with("rust/src/coordinator/")
            || f.path == "rust/src/sim/engine.rs"
    }) {
        for line in f.ident_sites("simgnn_forward", true) {
            out.push(Finding::new(
                "CACHE-SPLIT-API",
                &f.path,
                line,
                "full pairwise forward reached the cached scoring path".into(),
            ));
        }
    }
    for path in ["rust/src/runtime/native.rs", "rust/src/sim/engine.rs"] {
        require_seq(
            m,
            path,
            &["cache", ":", "Arc", ":", ":", "new", "(", "EmbedCache", ":", ":", "new"],
            "CACHE-CONSTRUCT",
            "engine stopped default-constructing a shared EmbedCache",
            out,
        );
        require_seq(
            m,
            path,
            &["pub", "fn", "with_cache"],
            "CACHE-CONSTRUCT",
            "cache injection point (with_cache) disappeared",
            out,
        );
    }
}

/// DET-RANK-SITE / DET-TIEBREAK / DET-HASH-ITER (ported grep: "shard
/// merge guards", DESIGN.md S15, plus the beyond-grep HashMap-order
/// rule). Ordering must flow through the single `Corpus::rank`
/// comparator; iteration over a HashMap anywhere near scores risks
/// nondeterministic ranking.
fn determinism(m: &RepoModel, out: &mut Vec<Finding>) {
    if let Some(f) = m.file("rust/src/coordinator/pipeline.rs") {
        for t in f.lex.toks.iter() {
            let banned = t.text == "sort"
                || t.text.starts_with("sort_")
                || t.text == "BinaryHeap"
                || t.text == "total_cmp";
            if banned {
                out.push(Finding::new(
                    "DET-RANK-SITE",
                    &f.path,
                    t.line,
                    format!("gather stage grew its own ranking implementation ({})", t.text),
                ));
            }
        }
        if f.ident_sites("rank_sharded", true).is_empty() {
            out.push(Finding::new(
                "DET-RANK-SITE",
                &f.path,
                0,
                "gather stage no longer merges through Corpus::rank_sharded".into(),
            ));
        }
    }
    if let Some(f) = m.file("rust/src/coordinator/corpus.rs") {
        let hits = f.ident_sites("total_cmp", true);
        if hits.len() != 1 {
            out.push(Finding::new(
                "DET-TIEBREAK",
                &f.path,
                hits.get(1).copied().unwrap_or(0),
                format!("want exactly one ranking comparator (total_cmp), found {}", hits.len()),
            ));
        }
    }
    for path in ["rust/src/coordinator/corpus.rs", "rust/src/coordinator/pipeline.rs"] {
        if let Some(f) = m.file(path) {
            let names = f.hashmap_bindings();
            for (name, line, in_test) in f.iteration_sites(&names) {
                if !in_test {
                    out.push(Finding::new(
                        "DET-HASH-ITER",
                        path,
                        line,
                        format!("iteration over HashMap `{name}` — order is nondeterministic"),
                    ));
                }
            }
        }
    }
}

/// Module ranks for ARCH-DAG. An import must point to a strictly lower
/// rank; `sim` and `runtime` form one tier (the Engine trait lives in
/// runtime, the cycle-model engine in sim, and the builder constructs
/// both) whose internal edges are allowed.
const RANKS: &[(&str, u32)] = &[
    ("util", 0),
    ("graph", 1),
    ("ged", 2),
    ("nn", 2),
    ("sim", 3),
    ("runtime", 3),
    ("report", 4),
    ("analysis", 5),
    ("coordinator", 5),
    ("net", 6),
];

fn rank(module: &str) -> Option<u32> {
    RANKS.iter().find(|(m, _)| *m == module).map(|&(_, r)| r)
}

const SIM_TIER: &[&str] = &["sim", "runtime"];

/// ARCH-DAG (beyond grep): layering over `use crate::X` and inline
/// `crate::X::` edges, non-test scope. Crate roots (lib/bin) and
/// out-of-tree code (tests/benches/examples) may see everything.
fn layering(m: &RepoModel, out: &mut Vec<Finding>) {
    for f in &m.files {
        let Some(src_rank) = rank(&f.module) else { continue };
        for (target, line) in f.crate_imports() {
            if target == f.module {
                continue;
            }
            let Some(dst_rank) = rank(&target) else { continue };
            let same_tier =
                SIM_TIER.contains(&f.module.as_str()) && SIM_TIER.contains(&target.as_str());
            if dst_rank >= src_rank && !same_tier {
                out.push(Finding::new(
                    "ARCH-DAG",
                    &f.path,
                    line,
                    format!(
                        "layering violation: {} (rank {src_rank}) imports {} (rank {dst_rank})",
                        f.module, target
                    ),
                ));
            }
        }
    }
}

/// Names dispatched by nn/kernels.rs; calling them via `kernels::` is
/// the privilege of nn/simgnn.rs alone, and calling the guarded linalg
/// reference loops directly is the privilege of nn/kernels.rs alone.
const GUARDED_LINALG: &[&str] =
    &["csr_spmm", "onehot_gather", "sparse_row_matmul", "ntn_bilinear"];
/// Non-dispatcher items other modules may import from nn/kernels.rs
/// (bench plumbing, not scoring kernels).
const KERNEL_NON_DISPATCH: &[&str] = &["set_kernel_path", "kernel_path", "KernelPath"];

/// ARCH-KERNEL-CALLER / ARCH-LINALG-CONFINED / ARCH-KERNEL-PRESENT /
/// KERNEL-DEFAULT-SIMD (ported grep: "kernel dispatch guards",
/// DESIGN.md S16, widened from simgnn.rs to the whole tree).
fn kernel_dispatch(m: &RepoModel, out: &mut Vec<Finding>) {
    for f in m.files.iter().filter(|f| f.path.starts_with("rust/src/")) {
        if !["rust/src/nn/simgnn.rs", "rust/src/nn/kernels.rs"].contains(&f.path.as_str()) {
            for q in f.qualified_names("kernels") {
                if !q.in_test && !KERNEL_NON_DISPATCH.contains(&q.name.as_str()) {
                    out.push(Finding::new(
                        "ARCH-KERNEL-CALLER",
                        &f.path,
                        q.line,
                        format!("kernels::{} called outside nn/simgnn.rs", q.name),
                    ));
                }
            }
        }
        if f.path != "rust/src/nn/kernels.rs" {
            for q in f.qualified_names("linalg") {
                if !q.in_test && GUARDED_LINALG.contains(&q.name.as_str()) {
                    out.push(Finding::new(
                        "ARCH-LINALG-CONFINED",
                        &f.path,
                        q.line,
                        format!("linalg::{} bypassed the nn/kernels.rs dispatch layer", q.name),
                    ));
                }
            }
        }
    }
    if let Some(f) = m.file("rust/src/nn/simgnn.rs") {
        let called: BTreeSet<String> =
            f.qualified_names("kernels").into_iter().map(|q| q.name).collect();
        for want in GUARDED_LINALG {
            if !called.contains(*want) {
                out.push(Finding::new(
                    "ARCH-KERNEL-PRESENT",
                    &f.path,
                    0,
                    format!("scoring no longer dispatches kernels::{want}"),
                ));
            }
        }
        if f.find_seq(&["use", "super", ":", ":", "kernels"], false).is_empty() {
            out.push(Finding::new(
                "ARCH-KERNEL-PRESENT",
                &f.path,
                0,
                "nn/simgnn.rs no longer imports the kernels dispatch layer".into(),
            ));
        }
    }
    if !m.cargo_toml.is_empty() && !m.cargo_contains("default = [\"simd\"]") {
        out.push(Finding::new(
            "KERNEL-DEFAULT-SIMD",
            "Cargo.toml",
            0,
            "the simd feature is no longer default-on".into(),
        ));
    }
}

/// NET-* (ported grep: "net front-door guards", DESIGN.md S17).
fn net_front_door(m: &RepoModel, out: &mut Vec<Finding>) {
    for dep in ["tokio", "hyper", "serde", "reqwest"] {
        if m.cargo_contains(dep) {
            out.push(Finding::new(
                "NET-STD-ONLY",
                "Cargo.toml",
                0,
                format!("net front door grew a non-std dependency ({dep})"),
            ));
        }
    }
    for f in m.under("rust/src/net/") {
        for dep in ["tokio", "hyper", "reqwest", "async_std"] {
            for line in f.ident_sites(dep, true) {
                out.push(Finding::new(
                    "NET-STD-ONLY",
                    &f.path,
                    line,
                    format!("async/http stack ({dep}) reached rust/src/net"),
                ));
            }
        }
        if f.path != "rust/src/net/admission.rs" {
            for line in f.find_seq(&["Query", ":", ":"], true) {
                out.push(Finding::new(
                    "NET-QUERY-CONFINED",
                    &f.path,
                    line,
                    "query construction leaked out of admission.rs".into(),
                ));
            }
        }
    }
    if let Some(f) = m.file("rust/src/net/server.rs") {
        for t in &f.lex.toks {
            if t.text.contains("submit") && t.text != "submit_handle" {
                out.push(Finding::new(
                    "NET-SINGLE-SUBMITTER",
                    &f.path,
                    t.line,
                    format!("listener bypassed the admission front stage ({})", t.text),
                ));
            }
        }
    }
    require_seq(
        m,
        "rust/src/net/server.rs",
        &[
            "use", "std", ":", ":", "net", ":", ":", "{", "SocketAddr", ",", "TcpListener", ",",
            "TcpStream", "}",
        ],
        "NET-STD-PINNED",
        "listener moved off the pinned std::net types",
        out,
    );
    require_seq(
        m,
        "rust/src/net/server.rs",
        &["SendPolicy", ":", ":", "DropNewest"],
        "NET-DROP-NEWEST",
        "admission queue lost its DropNewest overload policy",
        out,
    );
}

/// TRACE-CONFINED (DESIGN.md S19): trace entries are born in exactly
/// one place — the parser/writer in coordinator/trace.rs. Everyone
/// else records through `TraceRecorder`/`TraceWriter` and consumes
/// through `Trace::read`, so the workload wire format has a single
/// hostile-input-safe codec (type *mentions* stay legal; construction
/// and associated-path calls are what's banned, test scope included —
/// a test hand-rolling entries would bypass the codec's validation).
fn trace_confined(m: &RepoModel, out: &mut Vec<Finding>) {
    const TRACE_RS: &str = "rust/src/coordinator/trace.rs";
    for f in m.files.iter().filter(|f| f.path != TRACE_RS) {
        for seq in [&["TraceEntry", ":", ":"][..], &["TraceEntry", "{"][..]] {
            for line in f.find_seq(seq, true) {
                out.push(Finding::new(
                    "TRACE-CONFINED",
                    &f.path,
                    line,
                    "trace entry construction leaked out of coordinator/trace.rs".into(),
                ));
            }
        }
    }
    require_seq(
        m,
        TRACE_RS,
        &["impl", "TraceRecorder"],
        "TRACE-CONFINED",
        "the TraceRecorder tap disappeared from coordinator/trace.rs",
        out,
    );
}

/// EPOCH-SWAP-CONFINED (DESIGN.md S20): live-corpus generations are
/// born in exactly one place — the rebuild-and-swap commit in
/// coordinator/corpus_store.rs. Any other non-test `Arc::new(Corpus...)`
/// is a corpus outside the store's epoch ledger: queries admitted
/// against it can't be pinned, replayed, or shard-merge-checked by
/// epoch. Test scope stays legal (fixtures build corpora directly);
/// `Arc::new(CorpusSnapshot ...)` never matches — `CorpusSnapshot` is
/// a different token than `Corpus`.
fn epoch_swap_confined(m: &RepoModel, out: &mut Vec<Finding>) {
    const STORE_RS: &str = "rust/src/coordinator/corpus_store.rs";
    for f in m.files.iter().filter(|f| f.path.starts_with("rust/src/") && f.path != STORE_RS) {
        for line in f.find_seq(&["Arc", ":", ":", "new", "(", "Corpus"], false) {
            out.push(Finding::new(
                "EPOCH-SWAP-CONFINED",
                &f.path,
                line,
                "corpus construction bypassed the epoch-snapshotted CorpusStore".into(),
            ));
        }
    }
    require_seq(
        m,
        STORE_RS,
        &["impl", "CorpusStore"],
        "EPOCH-SWAP-CONFINED",
        "the CorpusStore snapshot swap disappeared from coordinator/corpus_store.rs",
        out,
    );
}

/// Panic-capable macro names (debug_assert* excluded: compiled out of
/// release serving builds).
const PANIC_MACROS: &[&str] = &[
    "panic",
    "assert",
    "assert_eq",
    "assert_ne",
    "unreachable",
    "todo",
    "unimplemented",
];

fn panic_scope(path: &str) -> bool {
    path.starts_with("rust/src/net/")
        || [
            "rust/src/coordinator/pipeline.rs",
            "rust/src/coordinator/channel.rs",
            "rust/src/coordinator/batcher.rs",
            "rust/src/coordinator/router.rs",
        ]
        .contains(&path)
}

/// PANIC-FREE (beyond grep): serving threads must not panic — a panic
/// in a stage thread wedges every in-flight query behind it. Lock
/// poisoning and structural dispatch invariants are waivable with
/// justification; everything else converts to typed errors.
fn panic_free(m: &RepoModel, out: &mut Vec<Finding>) {
    for f in m.files.iter().filter(|f| panic_scope(&f.path)) {
        for c in f.method_calls() {
            if !c.in_test && (c.name == "unwrap" || c.name == "expect") {
                out.push(Finding::new(
                    "PANIC-FREE",
                    &f.path,
                    c.line,
                    format!(
                        "{} in serving code (fn {})",
                        c.name,
                        c.func.as_deref().unwrap_or("<item>")
                    ),
                ));
            }
        }
        for c in f.macro_calls() {
            if !c.in_test && PANIC_MACROS.contains(&c.name.as_str()) {
                out.push(Finding::new(
                    "PANIC-FREE",
                    &f.path,
                    c.line,
                    format!(
                        "{}! in serving code (fn {})",
                        c.name,
                        c.func.as_deref().unwrap_or("<item>")
                    ),
                ));
            }
        }
    }
}

/// LOCK-ORDER (beyond grep): build a global acquisition graph — an
/// edge `a -> b` whenever a function, having acquired `a`
/// (`.lock()` / Condvar `.wait()`), later blocks on `b` (lock, wait,
/// channel send/recv). Nodes are receiver idents shared across files;
/// a strongly-connected component whose edges span two modules is a
/// deadlock surface (front stage <-> responder tap <-> gather).
fn lock_order(m: &RepoModel, out: &mut Vec<Finding>) {
    const ACQUIRE: &[&str] = &["lock", "wait", "wait_timeout"];
    // edge -> (module, path, line) witnesses
    let mut edges: BTreeMap<(String, String), Vec<(String, String, u32)>> = BTreeMap::new();
    for f in m.files.iter().filter(|f| f.path.starts_with("rust/src/")) {
        let mut per_fn: BTreeMap<String, Vec<(String, String, u32)>> = BTreeMap::new();
        for c in f.blocking_sites() {
            if c.in_test {
                continue;
            }
            let Some(func) = c.func else { continue };
            let Some(recv) = c.receiver.last() else { continue };
            per_fn.entry(func).or_default().push((c.name, recv.clone(), c.line));
        }
        for sites in per_fn.values() {
            for (i, (name_a, recv_a, line_a)) in sites.iter().enumerate() {
                if !ACQUIRE.contains(&name_a.as_str()) {
                    continue;
                }
                for (_, recv_b, _) in &sites[i + 1..] {
                    if recv_a != recv_b {
                        edges
                            .entry((recv_a.clone(), recv_b.clone()))
                            .or_default()
                            .push((f.module.clone(), f.path.clone(), *line_a));
                    }
                }
            }
        }
    }
    // Cross-module cycle = an edge a->b where b reaches a, and the
    // witnesses along some return path include a second module.
    let adj: BTreeMap<&str, BTreeSet<&str>> = edges.keys().fold(
        BTreeMap::new(),
        |mut adj, (a, b)| {
            adj.entry(a.as_str()).or_default().insert(b.as_str());
            adj
        },
    );
    let reaches = |from: &str, to: &str| -> bool {
        let mut seen = BTreeSet::new();
        let mut stack = vec![from];
        while let Some(n) = stack.pop() {
            if n == to {
                return true;
            }
            if seen.insert(n) {
                if let Some(next) = adj.get(n) {
                    stack.extend(next.iter().copied());
                }
            }
        }
        false
    };
    let mut reported = BTreeSet::new();
    for ((a, b), witnesses) in &edges {
        if !reaches(b, a) {
            continue;
        }
        // Modules on any edge inside the cycle's SCC.
        let mut mods: BTreeSet<&str> = witnesses.iter().map(|(m, _, _)| m.as_str()).collect();
        for ((x, y), w) in &edges {
            if reaches(b, x) && reaches(y, a) {
                mods.extend(w.iter().map(|(m, _, _)| m.as_str()));
            }
        }
        if mods.len() < 2 {
            continue;
        }
        let key = if a < b { (a.clone(), b.clone()) } else { (b.clone(), a.clone()) };
        if !reported.insert(key) {
            continue;
        }
        let (_, path, line) = &witnesses[0];
        out.push(Finding::new(
            "LOCK-ORDER",
            path,
            *line,
            format!(
                "acquisition cycle `{a}` <-> `{b}` spans modules {:?} — lock-order deadlock surface",
                mods
            ),
        ));
    }
}

/// Presence check: the file must contain the token sequence somewhere
/// (test scope included — these are structural anchors, not bans).
/// Fixture models (`!m.complete`) are only held to anchors for files
/// they actually contain; on the real tree a missing file fires too.
fn require_seq(
    m: &RepoModel,
    path: &str,
    seq: &[&str],
    rule: &'static str,
    message: &str,
    out: &mut Vec<Finding>,
) {
    match m.file(path) {
        Some(f) => {
            if f.find_seq(seq, true).is_empty() {
                out.push(Finding::new(rule, path, 0, message.to_string()));
            }
        }
        None => {
            if m.complete {
                out.push(Finding::new(rule, path, 0, format!("{message} (file missing)")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint(sources: Vec<(&str, &str)>) -> Vec<Finding> {
        run(&RepoModel::from_sources(sources), "")
    }

    fn lint_cargo(sources: Vec<(&str, &str)>, cargo: &str) -> Vec<Finding> {
        run(&RepoModel::from_sources_with_cargo(sources, cargo), "")
    }

    fn rules_fired(fs: &[Finding]) -> Vec<&str> {
        let mut r: Vec<&str> = fs.iter().map(|f| f.rule).collect();
        r.sort();
        r.dedup();
        r
    }

    #[test]
    fn engine_api_fires_and_conforms() {
        let bad = lint(vec![(
            "rust/src/runtime/mod.rs",
            "fn f() { let e = build_engine(\"sim\"); e.last_timing(); }",
        )]);
        assert!(rules_fired(&bad).contains(&"ENGINE-API-BUILD"), "{bad:?}");
        assert!(rules_fired(&bad).contains(&"ENGINE-API-TIMING"), "{bad:?}");
        // grep would flag all three decoys below; the lexer flags none.
        let ok = lint(vec![(
            "rust/src/runtime/mod.rs",
            "// build_engine( was replaced by EngineBuilder\n\
             const DOC: &str = \"build_engine( last_timing\";\n\
             fn f() {}",
        )]);
        assert!(
            !rules_fired(&ok).iter().any(|r| r.starts_with("ENGINE-API")),
            "{ok:?}"
        );
    }

    #[test]
    fn sparse_single_site_counts() {
        let simgnn_ok = "fn dense(g: &E) { matmul(&g.a_norm, x); } fn k() { kernels::csr_spmm(); kernels::onehot_gather(); kernels::sparse_row_matmul(); kernels::ntn_bilinear(); } use super::kernels;";
        assert!(
            !rules_fired(&lint(vec![("rust/src/nn/simgnn.rs", simgnn_ok)]))
                .contains(&"SPARSE-DENSE-SINGLE")
        );
        let two = lint(vec![(
            "rust/src/nn/simgnn.rs",
            &format!("{simgnn_ok} fn extra(g: &E) {{ matmul(&g.a_norm, y); }}"),
        )]);
        assert!(rules_fired(&two).contains(&"SPARSE-DENSE-SINGLE"), "{two:?}");
        let leak = lint(vec![(
            "rust/src/coordinator/pipeline.rs",
            "fn f(g: &E) { matmul(&g.a_norm, x); rank_sharded(); }",
        )]);
        assert!(rules_fired(&leak).contains(&"SPARSE-DENSE-CONFINED"), "{leak:?}");
    }

    #[test]
    fn cache_split_api_fires() {
        let bad = lint(vec![(
            "rust/src/sim/engine.rs",
            "fn score() { simgnn_forward(a, b); }",
        )]);
        assert!(rules_fired(&bad).contains(&"CACHE-SPLIT-API"), "{bad:?}");
        // nn/ keeps the fused forward legally
        let ok = lint(vec![("rust/src/nn/simgnn.rs", "pub fn simgnn_forward() {} fn k() { kernels::csr_spmm(); kernels::onehot_gather(); kernels::sparse_row_matmul(); kernels::ntn_bilinear(); } use super::kernels; fn d(g: &E) { matmul(&g.a_norm, x); }")]);
        assert!(!rules_fired(&ok).contains(&"CACHE-SPLIT-API"), "{ok:?}");
    }

    #[test]
    fn cache_construct_required_when_engine_exists() {
        let missing = lint(vec![("rust/src/runtime/native.rs", "pub struct NativeEngine;")]);
        assert!(rules_fired(&missing).contains(&"CACHE-CONSTRUCT"), "{missing:?}");
        let ok = lint(vec![(
            "rust/src/runtime/native.rs",
            "impl NativeEngine { fn load() -> Self { Self { policy: SparsePolicy::Csr, cache: Arc::new(EmbedCache::new(N)) } } pub fn with_cache(self, c: Arc<EmbedCache>) -> Self { self } }",
        )]);
        assert!(!rules_fired(&ok).contains(&"CACHE-CONSTRUCT"), "{ok:?}");
        assert!(!rules_fired(&ok).contains(&"SPARSE-DEFAULT-CSR"), "{ok:?}");
    }

    #[test]
    fn det_rank_site_catches_bare_sort_too() {
        // grep only knew sort_by/sort_unstable; `.sort()` evaded it.
        let bad = lint(vec![(
            "rust/src/coordinator/pipeline.rs",
            "fn gather(mut v: Vec<f32>) { v.sort(); rank_sharded(); }",
        )]);
        assert!(rules_fired(&bad).contains(&"DET-RANK-SITE"), "{bad:?}");
        let missing = lint(vec![("rust/src/coordinator/pipeline.rs", "fn gather() {}")]);
        assert!(rules_fired(&missing).contains(&"DET-RANK-SITE"), "{missing:?}");
        let ok = lint(vec![(
            "rust/src/coordinator/pipeline.rs",
            "// sort_by lives in corpus.rs, not here\nfn gather(c: &Corpus) { c.rank_sharded(); }",
        )]);
        assert!(!rules_fired(&ok).contains(&"DET-RANK-SITE"), "{ok:?}");
    }

    #[test]
    fn det_tiebreak_exactly_one() {
        let ok = lint(vec![(
            "rust/src/coordinator/corpus.rs",
            "fn rank() { v.sort_by(|a, b| b.1.total_cmp(&a.1)); }",
        )]);
        assert!(!rules_fired(&ok).contains(&"DET-TIEBREAK"), "{ok:?}");
        let two = lint(vec![(
            "rust/src/coordinator/corpus.rs",
            "fn rank() { v.sort_by(|a, b| b.1.total_cmp(&a.1)); } fn other() { x.total_cmp(&y); }",
        )]);
        assert!(rules_fired(&two).contains(&"DET-TIEBREAK"), "{two:?}");
    }

    #[test]
    fn det_hash_iter_fires_outside_tests_only() {
        let bad = lint(vec![(
            "rust/src/coordinator/pipeline.rs",
            "fn gather(open: HashMap<u64, E>) { for e in open.into_values() { score(e); } rank_sharded(); }",
        )]);
        assert!(rules_fired(&bad).contains(&"DET-HASH-ITER"), "{bad:?}");
        // the cfg(test) decoy grep would false-negative on is invisible here
        let ok = lint(vec![(
            "rust/src/coordinator/pipeline.rs",
            "fn gather(open: HashMap<u64, E>) { let _ = open.get(&1); rank_sharded(); }\n\
             #[cfg(test)] mod tests { fn t(open: HashMap<u64, E>) { for e in open.values() {} } }",
        )]);
        assert!(!rules_fired(&ok).contains(&"DET-HASH-ITER"), "{ok:?}");
    }

    #[test]
    fn layering_dag_direction() {
        let bad = lint(vec![(
            "rust/src/nn/simgnn.rs",
            "use crate::coordinator::pipeline::Pipeline; fn k() { kernels::csr_spmm(); kernels::onehot_gather(); kernels::sparse_row_matmul(); kernels::ntn_bilinear(); } use super::kernels; fn d(g: &E) { matmul(&g.a_norm, x); }",
        )]);
        assert!(rules_fired(&bad).contains(&"ARCH-DAG"), "{bad:?}");
        let ok = lint(vec![(
            "rust/src/net/server.rs",
            "use std::net::{SocketAddr, TcpListener, TcpStream};\n\
             use crate::coordinator::metrics::Metrics;\n\
             fn f(q: Q) { front.submit_handle(q); }\n\
             const P: SendPolicy = SendPolicy::DropNewest;",
        )]);
        assert!(!rules_fired(&ok).contains(&"ARCH-DAG"), "{ok:?}");
        // sim <-> runtime is one tier: both directions legal
        let tier = lint(vec![
            ("rust/src/sim/engine.rs", "use crate::runtime::Engine; fn f() { let c: Arc<EmbedCache> = cache; } impl E { fn l() -> Self { Self { cache: Arc::new(EmbedCache::new(1)) } } pub fn with_cache(self) -> Self { self } }"),
            ("rust/src/runtime/mod.rs", "fn build() { crate::sim::engine::SimEngine::load(); }"),
        ]);
        assert!(!rules_fired(&tier).contains(&"ARCH-DAG"), "{tier:?}");
        // test-scoped upward import is legal (nn tests use the simulator)
        let test_scoped = lint(vec![(
            "rust/src/nn/simgnn.rs",
            "fn k() { kernels::csr_spmm(); kernels::onehot_gather(); kernels::sparse_row_matmul(); kernels::ntn_bilinear(); } use super::kernels; fn d(g: &E) { matmul(&g.a_norm, x); }\n\
             #[cfg(test)] mod tests { use crate::sim::ft::nonzero_stream; }",
        )]);
        assert!(!rules_fired(&test_scoped).contains(&"ARCH-DAG"), "{test_scoped:?}");
    }

    #[test]
    fn kernel_caller_confined_to_simgnn() {
        let bad = lint(vec![(
            "rust/src/coordinator/pipeline.rs",
            "fn f() { kernels::csr_spmm(p, i, w, x, r, c); rank_sharded(); }",
        )]);
        assert!(rules_fired(&bad).contains(&"ARCH-KERNEL-CALLER"), "{bad:?}");
        // main.rs importing the path-pinning plumbing is not a dispatch call
        let ok = lint(vec![(
            "rust/src/main.rs",
            "use spa_gcn::nn::kernels::{set_kernel_path, KernelPath};",
        )]);
        assert!(!rules_fired(&ok).contains(&"ARCH-KERNEL-CALLER"), "{ok:?}");
    }

    #[test]
    fn linalg_confined_to_kernels() {
        let bad = lint(vec![(
            "rust/src/nn/simgnn.rs",
            "use super::linalg::{csr_spmm, relu_inplace}; fn k() { kernels::csr_spmm(); kernels::onehot_gather(); kernels::sparse_row_matmul(); kernels::ntn_bilinear(); } use super::kernels; fn d(g: &E) { matmul(&g.a_norm, x); }",
        )]);
        assert!(rules_fired(&bad).contains(&"ARCH-LINALG-CONFINED"), "{bad:?}");
        // unguarded linalg helpers (relu, sigmoid) stay importable
        let ok = lint(vec![(
            "rust/src/nn/simgnn.rs",
            "use super::linalg::{matmul, relu_inplace, sigmoid}; fn k() { kernels::csr_spmm(); kernels::onehot_gather(); kernels::sparse_row_matmul(); kernels::ntn_bilinear(); } use super::kernels; fn d(g: &E) { matmul(&g.a_norm, x); }",
        )]);
        assert!(!rules_fired(&ok).contains(&"ARCH-LINALG-CONFINED"), "{ok:?}");
        // kernels.rs itself calls the reference loops legally
        let kernels = lint(vec![(
            "rust/src/nn/kernels.rs",
            "use super::linalg; fn scalar() { linalg::csr_spmm(p, i, w, x, r, c); }",
        )]);
        assert!(!rules_fired(&kernels).contains(&"ARCH-LINALG-CONFINED"), "{kernels:?}");
    }

    #[test]
    fn kernel_present_and_simd_default() {
        let stripped = lint(vec![("rust/src/nn/simgnn.rs", "fn forward(g: &E) { matmul(&g.a_norm, x); }")]);
        assert!(rules_fired(&stripped).contains(&"ARCH-KERNEL-PRESENT"), "{stripped:?}");
        let no_default =
            lint_cargo(vec![("rust/src/util/mod.rs", "")], "[features]\ndefault = []\n");
        assert!(rules_fired(&no_default).contains(&"KERNEL-DEFAULT-SIMD"), "{no_default:?}");
        let ok = lint_cargo(
            vec![("rust/src/util/mod.rs", "")],
            "[features]\ndefault = [\"simd\"]\nsimd = []\n",
        );
        assert!(!rules_fired(&ok).contains(&"KERNEL-DEFAULT-SIMD"), "{ok:?}");
    }

    #[test]
    fn net_std_only_and_query_confinement() {
        let bad = lint_cargo(
            vec![(
                "rust/src/net/wire.rs",
                "use tokio::net::TcpListener; fn f() { let q = Query::new(); }",
            )],
            "[dependencies]\nserde = \"1\"\n",
        );
        let fired = rules_fired(&bad);
        assert!(fired.contains(&"NET-STD-ONLY"), "{bad:?}");
        assert!(fired.contains(&"NET-QUERY-CONFINED"), "{bad:?}");
        // admission.rs constructs queries legally; comment decoys ignored
        let ok = lint(vec![(
            "rust/src/net/admission.rs",
            "// tokio would be banned here\nfn f() -> Query { Query::TopK { k: 8 } }",
        )]);
        assert!(!rules_fired(&ok).iter().any(|r| r.starts_with("NET-")), "{ok:?}");
    }

    #[test]
    fn net_single_submitter_and_anchors() {
        let bad = lint(vec![(
            "rust/src/net/server.rs",
            "use std::net::{SocketAddr, TcpListener, TcpStream};\n\
             fn f(p: &Pipeline, q: Q) { p.submit(q); }\n\
             const P: SendPolicy = SendPolicy::DropNewest;",
        )]);
        assert!(rules_fired(&bad).contains(&"NET-SINGLE-SUBMITTER"), "{bad:?}");
        let unpinned = lint(vec![(
            "rust/src/net/server.rs",
            "use std::net::TcpListener;\nfn f(front: &F, q: Q) { front.submit_handle(q); }\nconst P: SendPolicy = SendPolicy::DropNewest;",
        )]);
        assert!(rules_fired(&unpinned).contains(&"NET-STD-PINNED"), "{unpinned:?}");
        let no_policy = lint(vec![(
            "rust/src/net/server.rs",
            "use std::net::{SocketAddr, TcpListener, TcpStream};\nfn f(front: &F, q: Q) { front.submit_handle(q); }",
        )]);
        assert!(rules_fired(&no_policy).contains(&"NET-DROP-NEWEST"), "{no_policy:?}");
    }

    #[test]
    fn panic_free_fires_outside_tests_waives_with_justification() {
        let src = "fn serve(x: Option<u32>) { let _ = x.unwrap(); }\n\
                   #[cfg(test)] mod tests { #[test] fn t() { Some(1).unwrap(); } }";
        let bad = lint(vec![("rust/src/net/server.rs", src)]);
        let panics: Vec<&Finding> =
            bad.iter().filter(|f| f.rule == "PANIC-FREE").collect();
        assert_eq!(panics.len(), 1, "{bad:?}"); // test-scope unwrap exempt
        assert_eq!(panics[0].line, 1);
        assert!(panics[0].message.contains("fn serve"), "{:?}", panics[0]);
        // waive it: same rule/path + line fragment + justification
        let model = RepoModel::from_sources(vec![("rust/src/net/server.rs", src)]);
        let waived = run(
            &model,
            "PANIC-FREE | rust/src/net/server.rs | x.unwrap() | fixture: poisoned-lock recovery\n",
        );
        assert!(active(&waived).all(|f| f.rule != "PANIC-FREE"), "{waived:?}");
        assert!(
            waived.iter().any(|f| f.rule == "PANIC-FREE" && f.waived.is_some()),
            "{waived:?}"
        );
    }

    #[test]
    fn panic_free_catches_macros_not_debug_asserts() {
        let bad = lint(vec![(
            "rust/src/coordinator/batcher.rs",
            "fn push() { assert!(cap > 0); debug_assert!(cap < 10); }",
        )]);
        let panics: Vec<&Finding> = bad.iter().filter(|f| f.rule == "PANIC-FREE").collect();
        assert_eq!(panics.len(), 1, "{bad:?}");
        assert!(panics[0].message.starts_with("assert!"), "{:?}", panics[0]);
    }

    #[test]
    fn waiver_hygiene() {
        let model = RepoModel::from_sources(vec![("rust/src/util/mod.rs", "fn f() {}")]);
        let fs = run(
            &model,
            "# comment\n\
             PANIC-FREE | rust/src/net/server.rs | nothing here | stale entry\n\
             PANIC-FREE | rust/src/net/server.rs | missing justification\n",
        );
        assert!(fs.iter().any(|f| f.rule == "WAIVER-STALE" && f.line == 2), "{fs:?}");
        assert!(fs.iter().any(|f| f.rule == "WAIVER-MALFORMED" && f.line == 3), "{fs:?}");
    }

    #[test]
    fn lock_order_cross_module_cycle() {
        // net locks `a` then sends on `b`; coordinator locks `b` then
        // waits on `a` — classic inverted order across modules.
        let bad = lint(vec![
            (
                "rust/src/net/admission.rs",
                "fn f(s: &S) { let g = s.a.lock(); s.b.send(1); }",
            ),
            (
                "rust/src/coordinator/router.rs",
                "fn g(s: &S) { let h = s.b.lock(); s.a.wait(h); }",
            ),
        ]);
        assert!(rules_fired(&bad).contains(&"LOCK-ORDER"), "{bad:?}");
        // same shape inside ONE module: not a cross-module surface
        let intra = lint(vec![(
            "rust/src/coordinator/router.rs",
            "fn f(s: &S) { let g = s.a.lock(); s.b.send(1); }\n\
             fn g(s: &S) { let h = s.b.lock(); s.a.wait(h); }",
        )]);
        assert!(!rules_fired(&intra).contains(&"LOCK-ORDER"), "{intra:?}");
        // consistent order across modules: fine
        let ok = lint(vec![
            ("rust/src/net/admission.rs", "fn f(s: &S) { let g = s.a.lock(); s.b.send(1); }"),
            ("rust/src/coordinator/router.rs", "fn g(s: &S) { let h = s.a.lock(); s.b.recv(); }"),
        ]);
        assert!(!rules_fired(&ok).contains(&"LOCK-ORDER"), "{ok:?}");
    }

    #[test]
    fn trace_construction_confined() {
        let literal = lint(vec![(
            "rust/src/coordinator/server.rs",
            "fn f() { let e = TraceEntry { id: 1 }; }",
        )]);
        assert!(rules_fired(&literal).contains(&"TRACE-CONFINED"), "{literal:?}");
        let assoc = lint(vec![(
            "rust/src/net/admission.rs",
            "fn g() { let e = TraceEntry::synthetic(1); }",
        )]);
        assert!(rules_fired(&assoc).contains(&"TRACE-CONFINED"), "{assoc:?}");
        // test scope is NOT exempt: hand-rolled entries bypass the codec
        let in_test = lint(vec![(
            "rust/src/coordinator/server.rs",
            "#[cfg(test)] mod tests { fn t() { let e = TraceEntry { id: 1 }; } }",
        )]);
        assert!(rules_fired(&in_test).contains(&"TRACE-CONFINED"), "{in_test:?}");
        // trace.rs itself constructs legally; type mentions stay legal
        let ok = lint(vec![
            (
                "rust/src/coordinator/trace.rs",
                "pub struct TraceEntry { id: u64 }\n\
                 impl TraceRecorder { fn rec() { let e = TraceEntry { id: 1 }; } }",
            ),
            ("rust/src/coordinator/server.rs", "fn f(es: &[TraceEntry]) {}"),
        ]);
        assert!(!rules_fired(&ok).contains(&"TRACE-CONFINED"), "{ok:?}");
    }

    #[test]
    fn epoch_swap_confined_to_corpus_store() {
        let bad = lint(vec![(
            "rust/src/coordinator/server.rs",
            "fn f() { let c = Arc::new(Corpus::from_db(\"x\", &db, 8, 4)?); }",
        )]);
        assert!(rules_fired(&bad).contains(&"EPOCH-SWAP-CONFINED"), "{bad:?}");
        // Test scope stays legal: fixtures build corpora directly.
        let in_test = lint(vec![(
            "rust/src/coordinator/pipeline.rs",
            "fn gather(c: &Corpus) { c.rank_sharded(); }\n\
             #[cfg(test)] mod tests { fn t() { let c = Arc::new(Corpus::build(\"c\", &e, 8, 4).unwrap()); } }",
        )]);
        assert!(!rules_fired(&in_test).contains(&"EPOCH-SWAP-CONFINED"), "{in_test:?}");
        // The store itself swaps legally, and CorpusSnapshot is not Corpus.
        let ok = lint(vec![(
            "rust/src/coordinator/corpus_store.rs",
            "impl CorpusStore { fn commit(&self) { let s = Arc::new(CorpusSnapshot { epoch, corpus: Arc::new(corpus) }); } }",
        )]);
        assert!(!rules_fired(&ok).contains(&"EPOCH-SWAP-CONFINED"), "{ok:?}");
    }

    #[test]
    fn every_rule_id_is_documented() {
        let ids: BTreeSet<&str> = RULES.iter().map(|(id, _)| *id).collect();
        for id in [
            "ENGINE-API-BUILD",
            "SPARSE-DENSE-SINGLE",
            "DET-RANK-SITE",
            "ARCH-DAG",
            "TRACE-CONFINED",
            "EPOCH-SWAP-CONFINED",
            "PANIC-FREE",
            "LOCK-ORDER",
            "WAIVER-STALE",
        ] {
            assert!(ids.contains(id));
        }
    }
}
