//! FPGA resource model: DSP / BRAM / URAM / LUT / FF per module, summed
//! per stage for the Fig. 10 breakdown and the Table 4/5 utilization rows.
//!
//! Calibration constants (documented per the Vitis HLS defaults on
//! UltraScale+):
//!  * f32 multiplier: 3 DSP48E2; f32 adds are implemented in fabric
//!    (LUT-based) as Vitis does under DSP pressure — this reproduces the
//!    paper's DSP counts within ~20% (Table 4: baseline 7.4%, +IL 18%,
//!    +sparsity 4.4% on U280's 9024 DSPs).
//!  * BRAM18 = 18 Kbit blocks; a banked buffer consumes at least one
//!    block per bank. URAM (288 Kbit) is used for buffers > 72 Kbit, as
//!    Vitis' resource pragma defaults would.
//!  * LUT/FF: per-PE and per-FIFO constants + module control overhead.

use crate::nn::config::ModelConfig;

use super::config::ArchConfig;
use super::platform::Platform;

/// Absolute resource usage of a module or design.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Resources {
    pub dsp: f64,
    pub bram18: f64,
    pub uram: f64,
    pub lut: f64,
    pub ff: f64,
}

impl Resources {
    pub fn add(&self, other: &Resources) -> Resources {
        Resources {
            dsp: self.dsp + other.dsp,
            bram18: self.bram18 + other.bram18,
            uram: self.uram + other.uram,
            lut: self.lut + other.lut,
            ff: self.ff + other.ff,
        }
    }

    pub fn scale(&self, k: f64) -> Resources {
        Resources {
            dsp: self.dsp * k,
            bram18: self.bram18 * k,
            uram: self.uram * k,
            lut: self.lut * k,
            ff: self.ff * k,
        }
    }

    /// Utilization percentages against a platform (LUT, FF, DSP, BRAM, URAM).
    pub fn utilization(&self, p: &Platform) -> [f64; 5] {
        let bram18_total = p.bram_mb * 1e6 / 18_000.0;
        let uram_total = p.uram_mb * 1e6 / 288_000.0;
        [
            100.0 * self.lut / (p.lut_k * 1e3),
            100.0 * self.ff / (p.ff_k * 1e3),
            100.0 * self.dsp / p.dsp as f64,
            100.0 * self.bram18 / bram18_total,
            100.0 * self.uram / uram_total,
        ]
    }
}

const DSP_PER_MUL: f64 = 3.0;
const LUT_PER_ADD: f64 = 430.0; // fabric f32 adder
const LUT_PER_MUL: f64 = 120.0; // DSP-assisted f32 mul glue
const FF_PER_LANE: f64 = 260.0;
const LUT_PER_FIFO: f64 = 60.0;
const FF_PER_FIFO: f64 = 110.0;
const MODULE_CTRL_LUT: f64 = 1800.0;
const MODULE_CTRL_FF: f64 = 2500.0;
const ACT_UNIT_DSP: f64 = 8.0; // tanh/exp from HLS math lib
const ACT_UNIT_LUT: f64 = 3200.0;

/// Buffer -> memory blocks given size and banking.
fn buffer_blocks(bytes: f64, banks: usize) -> (f64, f64) {
    let bits = bytes * 8.0;
    if bits > 72_000.0 && banks <= 4 {
        // large, lightly banked: URAM
        (0.0, (bits / 288_000.0).ceil().max(1.0))
    } else {
        let per_bank_bits = bits / banks as f64;
        let blocks_per_bank = (per_bank_bits / 18_000.0).ceil().max(1.0);
        (blocks_per_bank * banks as f64, 0.0)
    }
}

/// Resources of one GCN layer's MULT+ACG module pair.
pub fn gcn_layer_resources(cfg: &ModelConfig, arch: &ArchConfig, layer: usize) -> Resources {
    let p = if arch.dataflow() {
        arch.layers[layer]
    } else {
        arch.layers[0]
    };
    let dims_in = cfg.feature_dims();
    let f_in = dims_in[layer];
    let f_out = cfg.filters[layer];
    let mult_lanes = (p.simd_ft * p.df) as f64;
    let agg_lanes = p.simd_agg as f64;

    let dsp = DSP_PER_MUL * (mult_lanes + agg_lanes);
    let mut lut = mult_lanes * (LUT_PER_MUL + LUT_PER_ADD) // MULT + ACC
        + agg_lanes * (LUT_PER_MUL + LUT_PER_ADD)          // weighted agg
        + MODULE_CTRL_LUT * 2.0;
    let mut ff = (mult_lanes + agg_lanes) * FF_PER_LANE + MODULE_CTRL_FF * 2.0;

    // Buffers: weight cache (banked SIMD-wide), features buffer (banked
    // DF x SIMD), output buffer.
    let (b1, u1) = buffer_blocks((f_in * f_out * 4) as f64, p.simd_ft);
    let (b2, u2) = buffer_blocks(
        (cfg.n_max * f_out * 4) as f64,
        (p.df * p.simd_ft).max(1),
    );
    let (b3, u3) = buffer_blocks((cfg.n_max * f_out * 4) as f64, p.simd_agg);
    let mut bram = b1 + b2 + b3;
    let uram = u1 + u2 + u3;

    // Sparse-dispatch plumbing: P FIFOs + arbiter + prev-iter buffer.
    if arch.sparse_ft() {
        lut += p.p as f64 * LUT_PER_FIFO + 900.0; // arbiter
        ff += p.p as f64 * FF_PER_FIFO + 700.0;
        bram += p.p as f64; // one block per FIFO
    }
    Resources {
        dsp,
        bram18: bram,
        uram,
        lut,
        ff,
    }
}

/// Resources of the whole GCN stage.
pub fn gcn_resources(cfg: &ModelConfig, arch: &ArchConfig) -> Resources {
    let layers = if arch.dataflow() { 3 } else { 1 };
    let mut total = Resources::default();
    for l in 0..layers {
        total = total.add(&gcn_layer_resources(cfg, arch, l));
    }
    // Inter-module FIFOs between layers.
    if arch.dataflow() {
        total.lut += 2.0 * 4.0 * LUT_PER_FIFO;
        total.ff += 2.0 * 4.0 * FF_PER_FIFO;
        total.bram18 += 8.0;
    }
    total
}

/// Resources of the Att stage (kept small by design, §4.2).
pub fn att_resources(arch: &ArchConfig) -> Resources {
    let lanes = arch.att_simd as f64;
    Resources {
        dsp: DSP_PER_MUL * lanes + 2.0 * ACT_UNIT_DSP, // + tanh + sigmoid(exp)
        bram18: 4.0,
        uram: 0.0,
        lut: lanes * (LUT_PER_MUL + LUT_PER_ADD) + 2.0 * ACT_UNIT_LUT + MODULE_CTRL_LUT,
        ff: lanes * FF_PER_LANE + MODULE_CTRL_FF,
    }
}

/// Resources of the NTN + FCN stage (§4.3).
pub fn ntn_fcn_resources(cfg: &ModelConfig, arch: &ArchConfig) -> Resources {
    let lanes = arch.ntn_simd as f64;
    let (bram_w, uram_w) = buffer_blocks(
        (cfg.ntn_k * cfg.embed_dim() * cfg.embed_dim() * 4) as f64,
        arch.ntn_simd,
    );
    Resources {
        dsp: DSP_PER_MUL * (lanes + 4.0) + ACT_UNIT_DSP, // MVMs + FCN + sigmoid
        bram18: bram_w + 4.0,
        uram: uram_w,
        lut: (lanes + 4.0) * (LUT_PER_MUL + LUT_PER_ADD) + ACT_UNIT_LUT + MODULE_CTRL_LUT,
        ff: (lanes + 4.0) * FF_PER_LANE + MODULE_CTRL_FF,
    }
}

/// Prefetcher / memory interface.
pub fn prefetch_resources() -> Resources {
    Resources {
        dsp: 0.0,
        bram18: 16.0,
        uram: 0.0,
        lut: 9_000.0,
        ff: 14_000.0,
    }
}

/// Whole-SimGNN-pipeline resources + the Fig. 10 per-stage breakdown.
#[derive(Debug, Clone)]
pub struct Breakdown {
    pub gcn: Resources,
    pub att: Resources,
    pub ntn_fcn: Resources,
    pub prefetch: Resources,
    pub total: Resources,
}

pub fn simgnn_resources(cfg: &ModelConfig, arch: &ArchConfig) -> Breakdown {
    let gcn = gcn_resources(cfg, arch);
    let att = att_resources(arch);
    let ntn_fcn = ntn_fcn_resources(cfg, arch);
    let prefetch = prefetch_resources();
    let total = gcn.add(&att).add(&ntn_fcn).add(&prefetch);
    Breakdown {
        gcn,
        att,
        ntn_fcn,
        prefetch,
        total,
    }
}

/// How many full SimGNN pipelines fit under `cap` (fractional) resource
/// usage of the platform (§5.4.3 replication; paper caps at 80%).
pub fn max_replicas(cfg: &ModelConfig, arch: &ArchConfig, plat: &Platform, cap: f64) -> usize {
    let one = simgnn_resources(cfg, arch).total;
    let util = one.utilization(plat);
    let max_by_resource = util
        .iter()
        .map(|&u| if u <= 0.0 { f64::INFINITY } else { cap * 100.0 / u })
        .fold(f64::INFINITY, f64::min);
    // Memory channels also bound replication: 4 PCs per pipeline.
    let by_channels = (plat.mem_channels / 4).max(1) as f64;
    max_by_resource.min(by_channels).floor().max(1.0) as usize
}

/// Table 4's latency-area metric: kernel_ms x DSP count.
pub fn kernel_dsp_product(kernel_ms: f64, r: &Resources) -> f64 {
    kernel_ms * r.dsp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::platform::{KU15P, U280};

    fn cfg() -> ModelConfig {
        ModelConfig::default()
    }

    #[test]
    fn table4_dsp_directions() {
        let c = cfg();
        let base = gcn_resources(&c, &ArchConfig::baseline());
        let il = gcn_resources(&c, &ArchConfig::inter_layer());
        let es = gcn_resources(&c, &ArchConfig::extended_sparsity());
        // Paper: +IL uses ~2.4x the baseline DSPs; +sparsity cuts ~4x.
        assert!(il.dsp > 2.0 * base.dsp, "il {} base {}", il.dsp, base.dsp);
        assert!(il.dsp > 2.5 * es.dsp, "il {} es {}", il.dsp, es.dsp);
        // U280 percentages in plausible ranges (paper: 7.4 / 18 / 4.4).
        let u = |r: &Resources| r.utilization(&U280)[2];
        assert!(u(&base) > 2.0 && u(&base) < 12.0, "{}", u(&base));
        assert!(u(&il) > 12.0 && u(&il) < 25.0, "{}", u(&il));
        assert!(u(&es) > 2.0 && u(&es) < 10.0, "{}", u(&es));
    }

    #[test]
    fn fig10_gcn_dominates() {
        let c = cfg();
        let b = simgnn_resources(&c, &ArchConfig::spa_gcn());
        assert!(b.gcn.dsp > b.att.dsp);
        assert!(b.gcn.dsp > b.ntn_fcn.dsp);
        assert!(b.gcn.lut > b.att.lut);
    }

    #[test]
    fn replication_matches_section_543() {
        let c = cfg();
        let n = max_replicas(&c, &ArchConfig::spa_gcn(), &U280, 0.8);
        // paper: 6 pipelines on U280 before the 80% cap (we also cap at
        // 32 HBM channels / 4 per pipeline = 8).
        assert!((4..=8).contains(&n), "U280 replicas = {n}");
        let k = max_replicas(&c, &ArchConfig::spa_gcn(), &KU15P, 0.8);
        assert!(k <= 2, "KU15P replicas = {k}");
    }

    #[test]
    fn utilization_fits_smallest_fpga() {
        let c = cfg();
        let b = simgnn_resources(&c, &ArchConfig::spa_gcn());
        let u = b.total.utilization(&KU15P);
        // Table 5: the whole pipeline fits KU15P at ~35% DSP.
        for (i, v) in u.iter().enumerate() {
            assert!(*v < 80.0, "resource {i} at {v}% exceeds KU15P");
        }
    }

    #[test]
    fn buffer_blocks_uses_uram_for_big_buffers() {
        let (b, u) = buffer_blocks(64.0 * 1024.0, 1); // 64 KiB, 1 bank
        assert_eq!(b, 0.0);
        assert!(u >= 1.0);
        let (b2, u2) = buffer_blocks(4096.0, 8);
        assert!(b2 >= 8.0);
        assert_eq!(u2, 0.0);
    }
}
