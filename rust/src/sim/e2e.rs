//! End-to-end time model: kernel time + host-side overheads (PCIe DMA,
//! OpenCL API calls), query batching (Fig. 11) and pipeline replication
//! (§5.4.3).
//!
//! Calibration: the paper measures OpenCL APIs at 10-100 µs each
//! (§5.4.3) and reports E2E-vs-kernel gaps of 0.349/0.115/0.182 ms on
//! KU15P/U50/U280 (Table 5). We model a fixed per-launch overhead (API
//! calls + DMA setup) plus a per-byte PCIe cost; batching amortizes the
//! fixed part across B queries, saturating at the kernel-bound floor —
//! the Fig. 11 knee.

use super::platform::Platform;

/// Host-overhead model parameters.
#[derive(Debug, Clone, Copy)]
pub struct HostOverhead {
    /// Fixed per-launch cost (OpenCL enqueue + sync + DMA descriptors), ms.
    pub fixed_ms: f64,
    /// Additional per-query host bookkeeping when queries are issued
    /// one-at-a-time (buffer registration etc.), ms.
    pub per_query_ms: f64,
}

impl HostOverhead {
    /// Calibrated against Table 5's E2E-kernel gaps.
    pub fn for_platform(p: &Platform) -> HostOverhead {
        // DDR platforms pay more DMA setup (no direct host-HBM path).
        let fixed_ms = if p.max_bw_gbs < 100.0 { 0.28 } else { 0.12 };
        HostOverhead {
            fixed_ms,
            per_query_ms: 0.06,
        }
    }
}

/// Bytes transferred over PCIe per query (pruned edge stream + packed
/// one-hot features + weights are resident; result is 4 bytes).
pub fn query_bytes(num_nodes: usize, num_edges: usize) -> f64 {
    ((num_edges * 2 + num_nodes) * 8 + num_nodes * 8 + 4) as f64
}

/// End-to-end milliseconds per query when `batch` queries share one
/// launch (the Fig. 11 experiment).
pub fn e2e_ms_per_query(
    kernel_ms: f64,
    bytes_per_query: f64,
    plat: &Platform,
    over: &HostOverhead,
    batch: usize,
) -> f64 {
    assert!(batch >= 1);
    let pcie_ms = bytes_per_query * 2.0 / (plat.pcie_gbs * 1e6); // in+out
    let fixed = over.fixed_ms + over.per_query_ms; // one launch
    kernel_ms + pcie_ms + fixed / batch as f64
}

/// Fig. 11 sweep: per-query E2E time for each batch size.
pub fn batching_sweep(
    kernel_ms: f64,
    bytes_per_query: f64,
    plat: &Platform,
    over: &HostOverhead,
    batches: &[usize],
) -> Vec<(usize, f64)> {
    batches
        .iter()
        .map(|&b| (b, e2e_ms_per_query(kernel_ms, bytes_per_query, plat, over, b)))
        .collect()
}

/// Throughput (queries/s) with `replicas` independent pipelines fed from
/// separate HBM channel groups (§5.4.3): latency per query unchanged,
/// aggregate throughput scales with replicas until PCIe saturates.
pub fn replicated_throughput(
    e2e_ms_per_q: f64,
    kernel_ms: f64,
    bytes_per_query: f64,
    plat: &Platform,
    replicas: usize,
) -> f64 {
    let per_pipe = 1000.0 / e2e_ms_per_q.max(kernel_ms);
    let pcie_bound = plat.pcie_gbs * 1e9 / (bytes_per_query * 2.0);
    (per_pipe * replicas as f64).min(pcie_bound)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::platform::{KU15P, U280};

    #[test]
    fn batching_amortizes_fixed_overhead() {
        let over = HostOverhead::for_platform(&U280);
        let bytes = query_bytes(26, 28);
        let sweep = batching_sweep(0.33, bytes, &U280, &over, &[1, 4, 16, 64, 256, 512]);
        // monotone non-increasing
        for w in sweep.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-12);
        }
        // saturation: large batches approach the kernel floor
        let first = sweep[0].1;
        let last = sweep.last().unwrap().1;
        let speedup = first / last;
        assert!(
            speedup > 1.3 && speedup < 4.0,
            "batching speedup {speedup} out of the paper's regime (~2.8x)"
        );
        assert!(last >= 0.33, "cannot beat the kernel time");
    }

    #[test]
    fn ddr_platform_has_bigger_gap() {
        let bytes = query_bytes(26, 28);
        let ku = e2e_ms_per_query(0.79, bytes, &KU15P, &HostOverhead::for_platform(&KU15P), 1);
        let u280 = e2e_ms_per_query(0.33, bytes, &U280, &HostOverhead::for_platform(&U280), 1);
        assert!(ku - 0.79 > u280 - 0.33, "KU15P overhead should exceed U280");
    }

    #[test]
    fn replication_scales_until_pcie() {
        let bytes = query_bytes(26, 28);
        let over = HostOverhead::for_platform(&U280);
        let e2e = e2e_ms_per_query(0.33, bytes, &U280, &over, 512);
        let t1 = replicated_throughput(e2e, 0.33, bytes, &U280, 1);
        let t6 = replicated_throughput(e2e, 0.33, bytes, &U280, 6);
        assert!(t6 > 5.0 * t1, "6 replicas ~ 6x throughput ({t1} -> {t6})");
    }
}
