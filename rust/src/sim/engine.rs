//! SimEngine: functional scores (via the rust reference numerics) plus an
//! accumulated FPGA cycle report. Lets the coordinator and benches drive
//! the cycle simulator with exactly the workload the serving path sees.

use std::path::Path;

use anyhow::{Context, Result};

use crate::graph::encode::{encode, EncodedGraph, PackedBatch};
use crate::graph::Graph;
use crate::nn::config::{ArtifactsMeta, ModelConfig};
use crate::nn::simgnn::simgnn_forward;
use crate::nn::weights::Weights;
use crate::runtime::Engine;

use super::config::ArchConfig;
use super::gcn::{kernel_ms, simulate_query, QueryCycles};
use super::platform::Platform;

/// Aggregate simulation statistics over all queries processed.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    pub queries: u64,
    pub total_interval_cycles: u64,
    pub total_latency_cycles: u64,
    pub ft_elements: u64,
    pub ft_bubbles: u64,
    pub ft_starve: u64,
    pub agg_edges: u64,
    pub pad_rows: u64,
}

impl SimStats {
    fn absorb(&mut self, qc: &QueryCycles) {
        self.queries += 1;
        self.total_interval_cycles += qc.interval;
        self.total_latency_cycles += qc.latency;
        for gcn in [&qc.gcn1, &qc.gcn2] {
            for l in &gcn.layers {
                self.ft_elements += l.ft.elements;
                self.ft_bubbles += l.ft.raw_bubbles;
                self.ft_starve += l.ft.starve_cycles;
                self.agg_edges += l.agg.edges;
                self.pad_rows += l.ft.pad_rows;
            }
        }
    }

    /// Mean steady-state kernel time per query, ms.
    pub fn mean_kernel_ms(&self, plat: &Platform, arch: &ArchConfig) -> f64 {
        if self.queries == 0 {
            return 0.0;
        }
        kernel_ms(
            self.total_interval_cycles / self.queries,
            plat,
            arch.variant,
        )
    }
}

/// Cycle-simulating engine (functionally identical to NativeEngine).
pub struct SimEngine {
    cfg: ModelConfig,
    weights: Weights,
    arch: ArchConfig,
    plat: Platform,
    pub stats: SimStats,
}

impl SimEngine {
    pub fn load(artifacts_dir: &Path, arch: ArchConfig, plat: Platform) -> Result<Self> {
        let meta = ArtifactsMeta::load(artifacts_dir)
            .context("loading artifacts/meta.json (run `make artifacts`)")?;
        let weights = Weights::load(&meta.config, artifacts_dir)?;
        Ok(SimEngine {
            cfg: meta.config,
            weights,
            arch,
            plat,
            stats: SimStats::default(),
        })
    }

    pub fn new(cfg: ModelConfig, weights: Weights, arch: ArchConfig, plat: Platform) -> Self {
        SimEngine {
            cfg,
            weights,
            arch,
            plat,
            stats: SimStats::default(),
        }
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn arch(&self) -> &ArchConfig {
        &self.arch
    }

    pub fn platform(&self) -> &Platform {
        &self.plat
    }

    /// Score one query AND simulate its cycles (returns score + cycles).
    pub fn run_query(&mut self, g1: &Graph, g2: &Graph) -> Result<(f32, QueryCycles)> {
        let e1 = encode(g1, self.cfg.n_max, self.cfg.num_labels)?;
        let e2 = encode(g2, self.cfg.n_max, self.cfg.num_labels)?;
        let (score, qc) = self.run_encoded(g1, &e1, g2, &e2)?;
        Ok((score, qc))
    }

    /// Score + simulate with pre-encoded graphs (stats absorbed). The
    /// forward pass is computed ONCE and its traces drive the cycle sim
    /// (perf pass: this path previously ran the GCN forward twice).
    pub fn run_encoded(
        &mut self,
        g1: &Graph,
        e1: &EncodedGraph,
        g2: &Graph,
        e2: &EncodedGraph,
    ) -> Result<(f32, QueryCycles)> {
        let trace = simgnn_forward(&self.cfg, &self.weights, e1, e2);
        let qc = simulate_query(
            &self.cfg,
            &self.arch,
            &self.plat,
            (g1, e1, &trace.trace1),
            (g2, e2, &trace.trace2),
        );
        self.stats.absorb(&qc);
        Ok((trace.score, qc))
    }
}

impl Engine for SimEngine {
    fn name(&self) -> &str {
        "spa-gcn-sim"
    }

    fn supported_batch_sizes(&self) -> Vec<usize> {
        vec![1, 4, 16, 64]
    }

    /// Functional scoring of a packed batch (cycle stats are NOT absorbed
    /// on this path — PackedBatch has no Graph structure; use `run_query`
    /// for simulation-aware serving).
    fn score_batch(&mut self, batch: &PackedBatch) -> Result<Vec<f32>> {
        let n = batch.n_max;
        let l = batch.num_labels;
        let mut out = Vec::with_capacity(batch.batch);
        for i in 0..batch.batch {
            let grab = |a: &[f32], h: &[f32], m: &[f32]| EncodedGraph {
                a_norm: a[i * n * n..(i + 1) * n * n].to_vec(),
                h0: h[i * n * l..(i + 1) * n * l].to_vec(),
                mask: m[i * n..(i + 1) * n].to_vec(),
                num_nodes: m[i * n..(i + 1) * n].iter().filter(|&&x| x != 0.0).count(),
                num_edges: 0,
            };
            let e1 = grab(&batch.a1, &batch.h1, &batch.m1);
            let e2 = grab(&batch.a2, &batch.h2, &batch.m2);
            out.push(simgnn_forward(&self.cfg, &self.weights, &e1, &e2).score);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{generate, Family};
    use crate::sim::platform::U280;
    use crate::util::rng::Rng;

    fn tiny_engine() -> SimEngine {
        let cfg = ModelConfig {
            n_max: 8,
            num_labels: 4,
            filters: [4, 4, 4],
            relu_mask: [true, true, false],
            ntn_k: 4,
            fc_dims: vec![4],
            seed: 0,
        };
        let mut rng = Rng::new(81);
        let mut v = |len: usize| -> Vec<f32> {
            (0..len).map(|_| (rng.f32() - 0.5) * 0.5).collect()
        };
        let w = Weights {
            gcn_w: [v(16), v(16), v(16)],
            gcn_b: [vec![0.05; 4], vec![0.05; 4], vec![0.05; 4]],
            att_w: v(16),
            ntn_w: v(64),
            ntn_v: v(32),
            ntn_b: vec![0.0; 4],
            fc_w: vec![v(16)],
            fc_b: vec![vec![0.0; 4]],
            out_w: v(4),
            out_b: vec![0.0],
        };
        SimEngine::new(cfg, w, ArchConfig::spa_gcn(), U280)
    }

    #[test]
    fn run_query_accumulates_stats() {
        let mut eng = tiny_engine();
        let mut rng = Rng::new(82);
        let f = Family::ErdosRenyi { n: 6, p_millis: 300 };
        for _ in 0..3 {
            let g1 = generate(&mut rng, f, 8, 4);
            let g2 = generate(&mut rng, f, 8, 4);
            let (score, qc) = eng.run_query(&g1, &g2).unwrap();
            assert!(score > 0.0 && score < 1.0);
            assert!(qc.interval > 0);
        }
        assert_eq!(eng.stats.queries, 3);
        assert!(eng.stats.agg_edges > 0);
        assert!(eng.stats.mean_kernel_ms(&U280, &ArchConfig::spa_gcn()) > 0.0);
    }

    #[test]
    fn sim_scores_match_native_reference() {
        let mut eng = tiny_engine();
        let mut rng = Rng::new(83);
        let f = Family::ErdosRenyi { n: 5, p_millis: 300 };
        let g1 = generate(&mut rng, f, 8, 4);
        let g2 = generate(&mut rng, f, 8, 4);
        let e1 = encode(&g1, 8, 4).unwrap();
        let e2 = encode(&g2, 8, 4).unwrap();
        let (score, _) = eng.run_query(&g1, &g2).unwrap();
        let direct = simgnn_forward(eng.config(), &eng.weights, &e1, &e2).score;
        assert_eq!(score, direct);
        assert_eq!(eng.stats.queries, 1, "forward+sim must run exactly once");
    }
}
