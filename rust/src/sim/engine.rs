//! SimEngine: functional scores (via the rust reference numerics) plus an
//! accumulated FPGA cycle report. Lets the coordinator and benches drive
//! the cycle simulator with exactly the workload the serving path sees.

use std::path::Path;

use anyhow::{Context, Result};

use crate::graph::encode::{encode, EncodedGraph, PackedBatch};
use crate::graph::Graph;
use crate::nn::config::{ArtifactsMeta, ModelConfig, AOT_BATCH_LADDER};
use crate::nn::simgnn::simgnn_forward;
use crate::nn::weights::Weights;
use crate::runtime::{
    BatchOutput, CycleReport, Engine, EngineCaps, EngineError, QueryTelemetry,
};

use super::config::ArchConfig;
use super::gcn::{kernel_ms, simulate_query, QueryCycles};
use super::platform::Platform;

/// Aggregate simulation statistics over all queries processed.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    pub queries: u64,
    pub total_interval_cycles: u64,
    pub total_latency_cycles: u64,
    pub ft_elements: u64,
    pub ft_bubbles: u64,
    pub ft_starve: u64,
    pub agg_edges: u64,
    pub pad_rows: u64,
}

impl SimStats {
    fn absorb(&mut self, qc: &QueryCycles) {
        self.queries += 1;
        self.total_interval_cycles += qc.interval;
        self.total_latency_cycles += qc.latency;
        for gcn in [&qc.gcn1, &qc.gcn2] {
            for l in &gcn.layers {
                self.ft_elements += l.ft.elements;
                self.ft_bubbles += l.ft.raw_bubbles;
                self.ft_starve += l.ft.starve_cycles;
                self.agg_edges += l.agg.edges;
                self.pad_rows += l.ft.pad_rows;
            }
        }
    }

    /// Mean steady-state kernel time per query, ms.
    pub fn mean_kernel_ms(&self, plat: &Platform, arch: &ArchConfig) -> f64 {
        if self.queries == 0 {
            return 0.0;
        }
        kernel_ms(
            self.total_interval_cycles / self.queries,
            plat,
            arch.variant,
        )
    }
}

/// Cycle-simulating engine (functionally identical to NativeEngine).
/// Reports per-query interval/latency cycles as
/// [`QueryTelemetry::cycles`] and accumulates [`SimStats`] across every
/// query it scores — including batches served through the `dyn Engine`
/// trait object.
pub struct SimEngine {
    cfg: ModelConfig,
    weights: Weights,
    arch: ArchConfig,
    plat: Platform,
    caps: EngineCaps,
    /// Accumulated cycle statistics over every query scored so far.
    pub stats: SimStats,
}

impl SimEngine {
    /// Load config + weights from an artifacts directory and simulate
    /// under `arch` on `plat`. The batch ladder comes from `meta.json`,
    /// the same source the PJRT engine compiles from.
    pub fn load(artifacts_dir: &Path, arch: ArchConfig, plat: Platform) -> Result<Self> {
        let meta = ArtifactsMeta::load(artifacts_dir)
            .context("loading artifacts/meta.json (run `make artifacts`)")?;
        let weights = Weights::load(&meta.config, artifacts_dir)?;
        Ok(Self::with_ladder(meta.config, weights, arch, plat, meta.batch_sizes))
    }

    /// Build from an in-memory config + weights (tests, benches);
    /// advertises the shared [`AOT_BATCH_LADDER`].
    pub fn new(cfg: ModelConfig, weights: Weights, arch: ArchConfig, plat: Platform) -> Self {
        Self::with_ladder(cfg, weights, arch, plat, AOT_BATCH_LADDER.to_vec())
    }

    fn with_ladder(
        cfg: ModelConfig,
        weights: Weights,
        arch: ArchConfig,
        plat: Platform,
        ladder: Vec<usize>,
    ) -> Self {
        let caps = EngineCaps::new("spa-gcn-sim", ladder, cfg.n_max, cfg.num_labels)
            .with_cycle_reports();
        SimEngine {
            cfg,
            weights,
            arch,
            plat,
            caps,
            stats: SimStats::default(),
        }
    }

    /// The model configuration this engine scores with.
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// The simulated accelerator architecture.
    pub fn arch(&self) -> &ArchConfig {
        &self.arch
    }

    /// The simulated FPGA platform (clock/bandwidth model).
    pub fn platform(&self) -> &Platform {
        &self.plat
    }

    /// Score one query AND simulate its cycles (returns score + cycles).
    pub fn run_query(&mut self, g1: &Graph, g2: &Graph) -> Result<(f32, QueryCycles)> {
        let e1 = encode(g1, self.cfg.n_max, self.cfg.num_labels)?;
        let e2 = encode(g2, self.cfg.n_max, self.cfg.num_labels)?;
        let (score, qc) = self.run_encoded(g1, &e1, g2, &e2)?;
        Ok((score, qc))
    }

    /// Score + simulate with pre-encoded graphs (stats absorbed). The
    /// forward pass is computed ONCE and its traces drive the cycle sim
    /// (perf pass: this path previously ran the GCN forward twice).
    pub fn run_encoded(
        &mut self,
        g1: &Graph,
        e1: &EncodedGraph,
        g2: &Graph,
        e2: &EncodedGraph,
    ) -> Result<(f32, QueryCycles)> {
        let trace = simgnn_forward(&self.cfg, &self.weights, e1, e2);
        let qc = simulate_query(
            &self.cfg,
            &self.arch,
            &self.plat,
            (g1, e1, &trace.trace1),
            (g2, e2, &trace.trace2),
        );
        self.stats.absorb(&qc);
        Ok((trace.score, qc))
    }
}

impl Engine for SimEngine {
    fn caps(&self) -> &EngineCaps {
        &self.caps
    }

    /// Functional scoring of a packed batch WITH cycle simulation: each
    /// real slot's graph structure is recovered from its padded tensors
    /// (`PackedBatch::unpack_slot` + `EncodedGraph::decode`), the cycle
    /// simulator runs on it, its stats are absorbed into [`SimEngine::stats`]
    /// and its interval/latency cycles ride back as per-slot telemetry.
    /// Padding slots score the harmless bias-path value and carry no
    /// cycle report.
    fn score_batch(&mut self, batch: &PackedBatch) -> std::result::Result<BatchOutput, EngineError> {
        let mut scores = Vec::with_capacity(batch.batch);
        let mut telemetry = Vec::with_capacity(batch.batch);
        let invalid = |i: usize, e: crate::graph::encode::NonPrefixMask| {
            EngineError::InvalidInput {
                detail: format!("slot {i}: {e}"),
            }
        };
        for i in 0..batch.batch {
            let (e1, e2) = batch.unpack_slot(i).map_err(|e| invalid(i, e))?;
            if e1.num_nodes == 0 && e2.num_nodes == 0 {
                // Zero-padding slot: no real query to simulate.
                scores.push(simgnn_forward(&self.cfg, &self.weights, &e1, &e2).score);
                telemetry.push(QueryTelemetry::default());
                continue;
            }
            let (g1, g2) = (
                e1.decode().map_err(|e| invalid(i, e))?,
                e2.decode().map_err(|e| invalid(i, e))?,
            );
            let (score, qc) =
                self.run_encoded(&g1, &e1, &g2, &e2)
                    .map_err(|err| EngineError::Backend {
                        engine: self.caps.name.clone(),
                        detail: format!("{err:#}"),
                    })?;
            scores.push(score);
            telemetry.push(QueryTelemetry {
                cycles: Some(CycleReport {
                    interval: qc.interval,
                    latency: qc.latency,
                }),
                ..QueryTelemetry::default()
            });
        }
        Ok(BatchOutput { scores, telemetry })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{generate, Family};
    use crate::sim::platform::U280;
    use crate::util::rng::Rng;

    fn tiny_engine() -> SimEngine {
        let cfg = ModelConfig {
            n_max: 8,
            num_labels: 4,
            filters: [4, 4, 4],
            relu_mask: [true, true, false],
            ntn_k: 4,
            fc_dims: vec![4],
            seed: 0,
        };
        let mut rng = Rng::new(81);
        let mut v = |len: usize| -> Vec<f32> {
            (0..len).map(|_| (rng.f32() - 0.5) * 0.5).collect()
        };
        let w = Weights {
            gcn_w: [v(16), v(16), v(16)],
            gcn_b: [vec![0.05; 4], vec![0.05; 4], vec![0.05; 4]],
            att_w: v(16),
            ntn_w: v(64),
            ntn_v: v(32),
            ntn_b: vec![0.0; 4],
            fc_w: vec![v(16)],
            fc_b: vec![vec![0.0; 4]],
            out_w: v(4),
            out_b: vec![0.0],
        };
        SimEngine::new(cfg, w, ArchConfig::spa_gcn(), U280)
    }

    #[test]
    fn run_query_accumulates_stats() {
        let mut eng = tiny_engine();
        // In-memory construction advertises the shared AOT ladder (load()
        // derives it from meta.json, the same source PJRT compiles from).
        assert_eq!(eng.caps().batch_ladder(), &AOT_BATCH_LADDER);
        let mut rng = Rng::new(82);
        let f = Family::ErdosRenyi { n: 6, p_millis: 300 };
        for _ in 0..3 {
            let g1 = generate(&mut rng, f, 8, 4);
            let g2 = generate(&mut rng, f, 8, 4);
            let (score, qc) = eng.run_query(&g1, &g2).unwrap();
            assert!(score > 0.0 && score < 1.0);
            assert!(qc.interval > 0);
        }
        assert_eq!(eng.stats.queries, 3);
        assert!(eng.stats.agg_edges > 0);
        assert!(eng.stats.mean_kernel_ms(&U280, &ArchConfig::spa_gcn()) > 0.0);
    }

    /// Build 3 encoded pairs + the same pairs packed to batch size 4.
    fn packed_workload(eng: &SimEngine) -> (Vec<(EncodedGraph, EncodedGraph)>, PackedBatch) {
        let mut rng = Rng::new(84);
        let f = Family::ErdosRenyi { n: 6, p_millis: 300 };
        let pairs: Vec<_> = (0..3)
            .map(|_| {
                let g1 = generate(&mut rng, f, eng.cfg.n_max, eng.cfg.num_labels);
                let g2 = generate(&mut rng, f, eng.cfg.n_max, eng.cfg.num_labels);
                (
                    encode(&g1, eng.cfg.n_max, eng.cfg.num_labels).unwrap(),
                    encode(&g2, eng.cfg.n_max, eng.cfg.num_labels).unwrap(),
                )
            })
            .collect();
        let pb = PackedBatch::pack(&pairs, 4).unwrap();
        (pairs, pb)
    }

    #[test]
    fn score_batch_through_trait_object_absorbs_stats() {
        // Regression: the old score_batch silently skipped cycle
        // accounting, so serving `--engine sim` produced empty reports.
        let mut eng = tiny_engine();
        let (_, pb) = packed_workload(&eng);
        let out = {
            let dyn_eng: &mut dyn Engine = &mut eng;
            assert!(dyn_eng.caps().reports_cycles);
            dyn_eng.score_batch(&pb).unwrap()
        };
        assert_eq!(eng.stats.queries, 3, "one stats entry per real slot");
        assert!(eng.stats.agg_edges > 0, "decoded graphs must carry edges");
        // Real slots report cycles, the padding slot does not.
        for t in &out.telemetry[..3] {
            let c = t.cycles.expect("real slot carries a cycle report");
            assert!(c.interval > 0 && c.latency > 0);
        }
        assert_eq!(out.telemetry[3].cycles, None);
    }

    #[test]
    fn native_and_sim_agree_through_dyn_engine() {
        // Cross-engine parity: identical scores for the same PackedBatch
        // through both trait objects, and telemetry well-formed per caps
        // profile (sim reports cycles, native per-slot CPU time).
        let mut sim = tiny_engine();
        let native = crate::runtime::native::NativeEngine::new(
            sim.cfg.clone(),
            sim.weights.clone(),
        );
        let (_, pb) = packed_workload(&sim);
        let mut engines: Vec<Box<dyn Engine>> = vec![Box::new(native), Box::new(sim)];
        let outs: Vec<BatchOutput> = engines
            .iter_mut()
            .map(|e| e.score_batch(&pb).unwrap())
            .collect();
        assert_eq!(outs[0].scores, outs[1].scores, "same numerics, same scores");
        for (eng, out) in engines.iter().zip(&outs) {
            let caps = eng.caps();
            assert_eq!(out.telemetry.len(), out.scores.len());
            for (i, t) in out.telemetry.iter().enumerate() {
                let padding = i >= 3;
                assert_eq!(
                    t.cycles.is_some(),
                    caps.reports_cycles && !padding,
                    "{}: slot {i} cycle telemetry vs caps",
                    caps.name
                );
                assert_eq!(
                    t.exec.is_some(),
                    caps.reports_exec_timing,
                    "{}: slot {i} exec telemetry vs caps",
                    caps.name
                );
                assert_eq!(
                    t.macs.is_some(),
                    caps.reports_macs,
                    "{}: slot {i} mac telemetry vs caps",
                    caps.name
                );
            }
        }
    }

    #[test]
    fn sim_scores_match_native_reference() {
        let mut eng = tiny_engine();
        let mut rng = Rng::new(83);
        let f = Family::ErdosRenyi { n: 5, p_millis: 300 };
        let g1 = generate(&mut rng, f, 8, 4);
        let g2 = generate(&mut rng, f, 8, 4);
        let e1 = encode(&g1, 8, 4).unwrap();
        let e2 = encode(&g2, 8, 4).unwrap();
        let (score, _) = eng.run_query(&g1, &g2).unwrap();
        let direct = simgnn_forward(eng.config(), &eng.weights, &e1, &e2).score;
        assert_eq!(score, direct);
        assert_eq!(eng.stats.queries, 1, "forward+sim must run exactly once");
    }
}
