//! SimEngine: functional scores (via the rust reference numerics) plus an
//! accumulated FPGA cycle report. Lets the coordinator and benches drive
//! the cycle simulator with exactly the workload the serving path sees.
//!
//! Serving goes through the graph-embedding cache (DESIGN.md S14): a
//! cached graph skips its GCN + Att simulation entirely, so the cycle
//! model charges a fully-cached pair NTN+FCN only — the hardware
//! analogue of what the cache saves the host. Cold queries compose to
//! exactly `simulate_query`'s numbers (tested), so cache-off behavior
//! is unchanged.

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::graph::encode::{encode, EncodedGraph, NonPrefixMask, PackedBatch};
use crate::graph::Graph;
use crate::nn::config::{ArtifactsMeta, ModelConfig, AOT_BATCH_LADDER};
use crate::nn::simgnn::{attention_pool, gcn_forward, pair_score};
use crate::nn::weights::Weights;
use crate::runtime::embed_cache::{CachedEmbed, EmbedCache, DEFAULT_CAPACITY};
use crate::runtime::{
    BatchOutput, CorpusOutput, CycleReport, EmbedCacheTelemetry, Engine, EngineCaps, EngineError,
    MacCounts, QueryEmbed, QueryTelemetry,
};

use super::config::ArchConfig;
use super::gcn::{
    compose_cached_query, embed_only_cycles, embed_profile, kernel_ms, simulate_query,
    EmbedCycleProfile, GcnCycles, QueryCycles,
};
use super::platform::Platform;

/// Aggregate simulation statistics over all queries processed.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    pub queries: u64,
    pub total_interval_cycles: u64,
    pub total_latency_cycles: u64,
    pub ft_elements: u64,
    pub ft_bubbles: u64,
    pub ft_starve: u64,
    pub agg_edges: u64,
    pub pad_rows: u64,
}

impl SimStats {
    /// Count one completed query's steady-state contribution.
    fn note_query(&mut self, interval: u64, latency: u64) {
        self.queries += 1;
        self.total_interval_cycles += interval;
        self.total_latency_cycles += latency;
    }

    /// Absorb one graph's simulated GCN layer statistics (on the cached
    /// serving path this runs per *embed executed*, i.e. per cache miss).
    fn absorb_gcn(&mut self, gcn: &GcnCycles) {
        for l in &gcn.layers {
            self.ft_elements += l.ft.elements;
            self.ft_bubbles += l.ft.raw_bubbles;
            self.ft_starve += l.ft.starve_cycles;
            self.agg_edges += l.agg.edges;
            self.pad_rows += l.ft.pad_rows;
        }
    }

    fn absorb(&mut self, qc: &QueryCycles) {
        self.note_query(qc.interval, qc.latency);
        self.absorb_gcn(&qc.gcn1);
        self.absorb_gcn(&qc.gcn2);
    }

    /// Mean steady-state kernel time per query, ms.
    pub fn mean_kernel_ms(&self, plat: &Platform, arch: &ArchConfig) -> f64 {
        if self.queries == 0 {
            return 0.0;
        }
        kernel_ms(
            self.total_interval_cycles / self.queries,
            plat,
            arch.variant,
        )
    }
}

/// Cycle-simulating engine (functionally identical to NativeEngine).
/// Reports per-query interval/latency cycles as
/// [`QueryTelemetry::cycles`] and accumulates [`SimStats`] across every
/// query it scores — including batches served through the `dyn Engine`
/// trait object.
#[derive(Debug)]
pub struct SimEngine {
    cfg: ModelConfig,
    weights: Weights,
    arch: ArchConfig,
    plat: Platform,
    caps: EngineCaps,
    /// Behind `Arc` so same-kind lanes can serve from one shared cache
    /// (injected via `EngineBuilder::with_embed_cache`, DESIGN.md S15).
    cache: Arc<EmbedCache>,
    /// Accumulated cycle statistics over every query scored so far.
    pub stats: SimStats,
}

impl SimEngine {
    /// Load config + weights from an artifacts directory and simulate
    /// under `arch` on `plat`. The batch ladder comes from `meta.json`,
    /// the same source the PJRT engine compiles from.
    pub fn load(artifacts_dir: &Path, arch: ArchConfig, plat: Platform) -> Result<Self> {
        let meta = ArtifactsMeta::load(artifacts_dir)
            .context("loading artifacts/meta.json (run `make artifacts`)")?;
        let weights = Weights::load(&meta.config, artifacts_dir)?;
        Ok(Self::with_ladder(meta.config, weights, arch, plat, meta.batch_sizes))
    }

    /// Build from an in-memory config + weights (tests, benches);
    /// advertises the shared [`AOT_BATCH_LADDER`].
    pub fn new(cfg: ModelConfig, weights: Weights, arch: ArchConfig, plat: Platform) -> Self {
        Self::with_ladder(cfg, weights, arch, plat, AOT_BATCH_LADDER.to_vec())
    }

    fn with_ladder(
        cfg: ModelConfig,
        weights: Weights,
        arch: ArchConfig,
        plat: Platform,
        ladder: Vec<usize>,
    ) -> Self {
        let caps = EngineCaps::new("spa-gcn-sim", ladder, cfg.n_max, cfg.num_labels)
            .with_cycle_reports()
            .with_embed_cache()
            .with_corpus_scoring()
            .with_corpus_sharding();
        SimEngine {
            cfg,
            weights,
            arch,
            plat,
            caps,
            cache: Arc::new(EmbedCache::new(DEFAULT_CAPACITY)),
            stats: SimStats::default(),
        }
    }

    /// Serve from a shared embedding cache instead of the private one
    /// (same-kind lanes only — see `EngineBuilder::with_embed_cache`).
    pub fn with_cache(mut self, cache: Arc<EmbedCache>) -> Self {
        self.cache = cache;
        self
    }

    /// The model configuration this engine scores with.
    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// The simulated accelerator architecture.
    pub fn arch(&self) -> &ArchConfig {
        &self.arch
    }

    /// The simulated FPGA platform (clock/bandwidth model).
    pub fn platform(&self) -> &Platform {
        &self.plat
    }

    /// Score one query AND simulate its cycles (returns score + cycles).
    pub fn run_query(&mut self, g1: &Graph, g2: &Graph) -> Result<(f32, QueryCycles)> {
        let e1 = encode(g1, self.cfg.n_max, self.cfg.num_labels)?;
        let e2 = encode(g2, self.cfg.n_max, self.cfg.num_labels)?;
        let (score, qc) = self.run_encoded(g1, &e1, g2, &e2)?;
        Ok((score, qc))
    }

    /// Score + simulate with pre-encoded graphs (stats absorbed). The
    /// forward pass is computed ONCE and its traces drive the cycle sim
    /// (perf pass: this path previously ran the GCN forward twice). This
    /// is the report-harness path; it deliberately bypasses the
    /// embedding cache so ablation tables always measure cold work.
    pub fn run_encoded(
        &mut self,
        g1: &Graph,
        e1: &EncodedGraph,
        g2: &Graph,
        e2: &EncodedGraph,
    ) -> Result<(f32, QueryCycles)> {
        let t1 = gcn_forward(&self.cfg, &self.weights, e1);
        let t2 = gcn_forward(&self.cfg, &self.weights, e2);
        let hg1 = attention_pool(&self.cfg, &self.weights, &t1.embeddings, &e1.mask);
        let hg2 = attention_pool(&self.cfg, &self.weights, &t2.embeddings, &e2.mask);
        let (_, score) = pair_score(&self.cfg, &self.weights, &hg1, &hg2);
        let qc = simulate_query(
            &self.cfg,
            &self.arch,
            &self.plat,
            (g1, e1, &t1),
            (g2, e2, &t2),
        );
        self.stats.absorb(&qc);
        Ok((score, qc))
    }

    /// The engine's embedding cache (stats inspection).
    pub fn embed_cache(&self) -> &EmbedCache {
        &self.cache
    }

    /// Embed one graph through the cache. A hit returns the stored
    /// embedding with the zero cycle profile (the hardware skips the
    /// GCN + Att stage entirely); a miss runs the reference forward,
    /// simulates its embed-stage cycles, absorbs the layer statistics,
    /// and caches the embedding.
    fn embed_cached(
        &mut self,
        e: &EncodedGraph,
    ) -> std::result::Result<(Arc<CachedEmbed>, bool, EmbedCycleProfile), NonPrefixMask> {
        let key = e.fingerprint();
        if let Some(hit) = self.cache.get(key) {
            return Ok((hit, true, EmbedCycleProfile::default()));
        }
        let trace = gcn_forward(&self.cfg, &self.weights, e);
        let hg = attention_pool(&self.cfg, &self.weights, &trace.embeddings, &e.mask);
        let profile = if e.num_nodes == 0 {
            // Empty graph: charged zero, warm or cold (simulate_query
            // would bill only degenerate activation-latency constants
            // here; see the score_batch doc for the stated exception).
            EmbedCycleProfile::default()
        } else {
            let g = e.decode()?;
            let (gcn, profile) = embed_profile(&self.cfg, &self.arch, &self.plat, &g, e, &trace);
            self.stats.absorb_gcn(&gcn);
            profile
        };
        let cached = Arc::new(CachedEmbed {
            hg,
            macs: MacCounts {
                macs: trace.macs,
                ft_elements: trace.ft_elements.iter().sum(),
                agg_elements: trace.agg_elements,
            },
        });
        self.cache.insert(key, Arc::clone(&cached));
        Ok((cached, false, profile))
    }

    /// Shared fan-out of `score_corpus` / `score_corpus_with`: score
    /// each candidate against a resolved query embedding and accumulate
    /// the composed cycle charge. `query_profile` — the query graph's
    /// own embed cost — is composed into the first candidate only;
    /// shard lanes pass the zero profile because the scatter-time
    /// [`SimEngine::embed_query`] already charged it. `what` labels the
    /// candidate slice in errors (`"corpus"` for whole queries,
    /// `"shard"` for shard jobs, whose indices are shard-local).
    fn fan_out_tail(
        &mut self,
        query_hg: &[f32],
        shard: &[EncodedGraph],
        what: &str,
        mut query_profile: EmbedCycleProfile,
        cache_stats: &mut EmbedCacheTelemetry,
    ) -> std::result::Result<(Vec<f32>, u64, u64), EngineError> {
        let (mut total_interval, mut total_latency) = (0u64, 0u64);
        let mut scores = Vec::with_capacity(shard.len());
        for (i, g) in shard.iter().enumerate() {
            let (c, hit, p) = self.embed_cached(g).map_err(|e| EngineError::InvalidInput {
                detail: format!("{what}[{i}]: {e}"),
            })?;
            if hit {
                cache_stats.hits += 1;
            } else {
                cache_stats.misses += 1;
            }
            let (_, score) = pair_score(&self.cfg, &self.weights, query_hg, &c.hg);
            scores.push(score);
            let (interval, latency) =
                compose_cached_query(&self.cfg, &self.arch, &self.plat, &query_profile, &p);
            total_interval += interval;
            total_latency += latency;
            query_profile = EmbedCycleProfile::default();
        }
        Ok((scores, total_interval, total_latency))
    }
}

impl Engine for SimEngine {
    fn caps(&self) -> &EngineCaps {
        &self.caps
    }

    /// Functional scoring of a packed batch WITH cycle simulation, both
    /// cache-aware: each slot's graphs go through the embedding cache, a
    /// miss is simulated from its recovered structure
    /// (`PackedBatch::unpack_slot` + `EncodedGraph::decode`) and
    /// absorbed into [`SimEngine::stats`], a hit contributes zero embed
    /// cycles — so a fully-cached pair is charged NTN+FCN only. Cold
    /// slots report exactly `simulate_query`'s numbers, with one
    /// deliberate exception: a zero-node graph's embed stage is charged
    /// zero (`simulate_query` would bill its degenerate activation
    /// constants), so an empty side costs the same warm or cold.
    /// Padding slots score the harmless bias-path value and carry no
    /// cycle report.
    /// (Unlike `NativeEngine`, this engine unpacks every slot even on
    /// cache hits: it is the cycle *model*, not the measured path, and
    /// it needs the recovered node counts for padding detection.)
    fn score_batch(&mut self, batch: &PackedBatch) -> std::result::Result<BatchOutput, EngineError> {
        let mut scores = Vec::with_capacity(batch.batch);
        let mut telemetry = Vec::with_capacity(batch.batch);
        let invalid = |i: usize, e: NonPrefixMask| EngineError::InvalidInput {
            detail: format!("slot {i}: {e}"),
        };
        for i in 0..batch.batch {
            let (e1, e2) = batch.unpack_slot(i).map_err(|e| invalid(i, e))?;
            let (c1, hit1, p1) = self.embed_cached(&e1).map_err(|e| invalid(i, e))?;
            let (c2, hit2, p2) = self.embed_cached(&e2).map_err(|e| invalid(i, e))?;
            let (_, score) = pair_score(&self.cfg, &self.weights, &c1.hg, &c2.hg);
            scores.push(score);
            let cache_stats = EmbedCacheTelemetry {
                hits: hit1 as u64 + hit2 as u64,
                misses: (!hit1) as u64 + (!hit2) as u64,
                entries: self.cache.len() as u64,
            };
            if e1.num_nodes == 0 && e2.num_nodes == 0 {
                // Zero-padding slot: no real query to simulate.
                telemetry.push(QueryTelemetry {
                    embed_cache: Some(cache_stats),
                    ..QueryTelemetry::default()
                });
                continue;
            }
            let (interval, latency) =
                compose_cached_query(&self.cfg, &self.arch, &self.plat, &p1, &p2);
            self.stats.note_query(interval, latency);
            telemetry.push(QueryTelemetry {
                cycles: Some(CycleReport { interval, latency }),
                embed_cache: Some(cache_stats),
                ..QueryTelemetry::default()
            });
        }
        Ok(BatchOutput { scores, telemetry })
    }

    /// One-vs-many with cycle accounting: the query graph embeds once
    /// (cache-aware), every candidate that hits the cache is charged
    /// NTN+FCN only, and the reported cycles are the totals across the
    /// whole fan-out (the steady-state cost of answering this corpus
    /// query on the modeled accelerator).
    fn score_corpus(
        &mut self,
        query: &EncodedGraph,
        corpus: &[EncodedGraph],
    ) -> std::result::Result<CorpusOutput, EngineError> {
        crate::runtime::check_corpus_shapes(self.cfg.n_max, self.cfg.num_labels, query, corpus)?;
        if corpus.is_empty() {
            // Nothing to rank: embedding the query anyway would record
            // GCN work into SimStats with zero composed cycles
            // (pipeline admission rejects this; direct API use gets an
            // empty result, no stats skew).
            return Ok(CorpusOutput {
                scores: Vec::new(),
                telemetry: QueryTelemetry::default(),
            });
        }
        let mut cache_stats = EmbedCacheTelemetry::default();
        let (cq, hitq, pq) = self.embed_cached(query).map_err(|e| EngineError::InvalidInput {
            detail: format!("query: {e}"),
        })?;
        if hitq {
            cache_stats.hits += 1;
        } else {
            cache_stats.misses += 1;
        }
        // The query's embed cost is charged once, on the first candidate.
        let (scores, total_interval, total_latency) =
            self.fan_out_tail(&cq.hg, corpus, "corpus", pq, &mut cache_stats)?;
        cache_stats.entries = self.cache.len() as u64;
        self.stats.note_query(total_interval, total_latency);
        Ok(CorpusOutput {
            scores,
            telemetry: QueryTelemetry {
                cycles: Some(CycleReport {
                    interval: total_interval,
                    latency: total_latency,
                }),
                embed_cache: Some(cache_stats),
                ..QueryTelemetry::default()
            },
        })
    }

    /// Scatter-time query embed for a sharded corpus query: one
    /// cache-aware forward, charged its standalone embed cycles (GCN +
    /// Att + input stream, no pair tail — the tails are paid by the
    /// shard lanes in [`SimEngine::score_corpus_with`]).
    fn embed_query(
        &mut self,
        query: &EncodedGraph,
    ) -> std::result::Result<QueryEmbed, EngineError> {
        let (n_max, num_labels) = (self.cfg.n_max, self.cfg.num_labels);
        crate::runtime::check_graph_shape(n_max, num_labels, "query graph", query)?;
        let (cq, hitq, pq) = self.embed_cached(query).map_err(|e| EngineError::InvalidInput {
            detail: format!("query: {e}"),
        })?;
        let (interval, latency) = embed_only_cycles(&self.arch, &self.plat, &pq);
        Ok(QueryEmbed {
            embed: cq,
            telemetry: QueryTelemetry {
                cycles: Some(CycleReport { interval, latency }),
                embed_cache: Some(EmbedCacheTelemetry {
                    hits: hitq as u64,
                    misses: (!hitq) as u64,
                    entries: self.cache.len() as u64,
                }),
                ..QueryTelemetry::default()
            },
        })
    }

    /// One shard of a scattered corpus query, charged *independently*:
    /// this shard's candidates' embeds plus their NTN+FCN tails, with
    /// the query's embed contributing nothing here (it was charged at
    /// scatter time). Each shard runs on its own lane, so the gather
    /// stage merges shard cycle reports with a max — the cycle model's
    /// view of the parallel speedup. Each shard also counts as one
    /// entry in [`SimEngine::stats`] (one simulated accelerator
    /// occupation), so sharded runs show more, shorter stream entries.
    fn score_corpus_with(
        &mut self,
        query_hg: &[f32],
        shard: &[EncodedGraph],
    ) -> std::result::Result<CorpusOutput, EngineError> {
        crate::runtime::check_shard_shapes(self.cfg.n_max, self.cfg.num_labels, "shard", shard)?;
        if query_hg.len() != self.cfg.embed_dim() {
            return Err(EngineError::InvalidInput {
                detail: format!(
                    "query embedding has {} floats, model embeds into {}",
                    query_hg.len(),
                    self.cfg.embed_dim()
                ),
            });
        }
        if shard.is_empty() {
            return Ok(CorpusOutput {
                scores: Vec::new(),
                telemetry: QueryTelemetry::default(),
            });
        }
        let mut cache_stats = EmbedCacheTelemetry::default();
        let (scores, total_interval, total_latency) = self.fan_out_tail(
            query_hg,
            shard,
            "shard",
            EmbedCycleProfile::default(),
            &mut cache_stats,
        )?;
        cache_stats.entries = self.cache.len() as u64;
        self.stats.note_query(total_interval, total_latency);
        Ok(CorpusOutput {
            scores,
            telemetry: QueryTelemetry {
                cycles: Some(CycleReport {
                    interval: total_interval,
                    latency: total_latency,
                }),
                embed_cache: Some(cache_stats),
                ..QueryTelemetry::default()
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{generate, Family};
    use crate::sim::platform::U280;
    use crate::util::rng::Rng;

    fn tiny_engine() -> SimEngine {
        let cfg = ModelConfig {
            n_max: 8,
            num_labels: 4,
            filters: [4, 4, 4],
            relu_mask: [true, true, false],
            ntn_k: 4,
            fc_dims: vec![4],
            seed: 0,
        };
        let mut rng = Rng::new(81);
        let mut v = |len: usize| -> Vec<f32> {
            (0..len).map(|_| (rng.f32() - 0.5) * 0.5).collect()
        };
        let w = Weights {
            gcn_w: [v(16), v(16), v(16)],
            gcn_b: [vec![0.05; 4], vec![0.05; 4], vec![0.05; 4]],
            att_w: v(16),
            ntn_w: v(64),
            ntn_v: v(32),
            ntn_b: vec![0.0; 4],
            fc_w: vec![v(16)],
            fc_b: vec![vec![0.0; 4]],
            out_w: v(4),
            out_b: vec![0.0],
        };
        SimEngine::new(cfg, w, ArchConfig::spa_gcn(), U280)
    }

    #[test]
    fn run_query_accumulates_stats() {
        let mut eng = tiny_engine();
        // In-memory construction advertises the shared AOT ladder (load()
        // derives it from meta.json, the same source PJRT compiles from).
        assert_eq!(eng.caps().batch_ladder(), &AOT_BATCH_LADDER);
        let mut rng = Rng::new(82);
        let f = Family::ErdosRenyi { n: 6, p_millis: 300 };
        for _ in 0..3 {
            let g1 = generate(&mut rng, f, 8, 4);
            let g2 = generate(&mut rng, f, 8, 4);
            let (score, qc) = eng.run_query(&g1, &g2).unwrap();
            assert!(score > 0.0 && score < 1.0);
            assert!(qc.interval > 0);
        }
        assert_eq!(eng.stats.queries, 3);
        assert!(eng.stats.agg_edges > 0);
        assert!(eng.stats.mean_kernel_ms(&U280, &ArchConfig::spa_gcn()) > 0.0);
    }

    /// Build 3 encoded pairs + the same pairs packed to batch size 4.
    fn packed_workload(eng: &SimEngine) -> (Vec<(EncodedGraph, EncodedGraph)>, PackedBatch) {
        let mut rng = Rng::new(84);
        let f = Family::ErdosRenyi { n: 6, p_millis: 300 };
        let pairs: Vec<_> = (0..3)
            .map(|_| {
                let g1 = generate(&mut rng, f, eng.cfg.n_max, eng.cfg.num_labels);
                let g2 = generate(&mut rng, f, eng.cfg.n_max, eng.cfg.num_labels);
                (
                    encode(&g1, eng.cfg.n_max, eng.cfg.num_labels).unwrap(),
                    encode(&g2, eng.cfg.n_max, eng.cfg.num_labels).unwrap(),
                )
            })
            .collect();
        let pb = PackedBatch::pack(&pairs, 4).unwrap();
        (pairs, pb)
    }

    #[test]
    fn score_batch_through_trait_object_absorbs_stats() {
        // Regression: the old score_batch silently skipped cycle
        // accounting, so serving `--engine sim` produced empty reports.
        let mut eng = tiny_engine();
        let (_, pb) = packed_workload(&eng);
        let out = {
            let dyn_eng: &mut dyn Engine = &mut eng;
            assert!(dyn_eng.caps().reports_cycles);
            dyn_eng.score_batch(&pb).unwrap()
        };
        assert_eq!(eng.stats.queries, 3, "one stats entry per real slot");
        assert!(eng.stats.agg_edges > 0, "decoded graphs must carry edges");
        // Real slots report cycles, the padding slot does not.
        for t in &out.telemetry[..3] {
            let c = t.cycles.expect("real slot carries a cycle report");
            assert!(c.interval > 0 && c.latency > 0);
        }
        assert_eq!(out.telemetry[3].cycles, None);
    }

    #[test]
    fn native_and_sim_agree_through_dyn_engine() {
        // Cross-engine parity: identical scores for the same PackedBatch
        // through both trait objects, and telemetry well-formed per caps
        // profile (sim reports cycles, native per-slot CPU time).
        let mut sim = tiny_engine();
        let native = crate::runtime::native::NativeEngine::new(
            sim.cfg.clone(),
            sim.weights.clone(),
        );
        let (_, pb) = packed_workload(&sim);
        let mut engines: Vec<Box<dyn Engine>> = vec![Box::new(native), Box::new(sim)];
        let outs: Vec<BatchOutput> = engines
            .iter_mut()
            .map(|e| e.score_batch(&pb).unwrap())
            .collect();
        assert_eq!(outs[0].scores, outs[1].scores, "same numerics, same scores");
        for (eng, out) in engines.iter().zip(&outs) {
            let caps = eng.caps();
            assert_eq!(out.telemetry.len(), out.scores.len());
            for (i, t) in out.telemetry.iter().enumerate() {
                let padding = i >= 3;
                assert_eq!(
                    t.cycles.is_some(),
                    caps.reports_cycles && !padding,
                    "{}: slot {i} cycle telemetry vs caps",
                    caps.name
                );
                assert_eq!(
                    t.exec.is_some(),
                    caps.reports_exec_timing,
                    "{}: slot {i} exec telemetry vs caps",
                    caps.name
                );
                assert_eq!(
                    t.macs.is_some(),
                    caps.reports_macs,
                    "{}: slot {i} mac telemetry vs caps",
                    caps.name
                );
                assert_eq!(
                    t.embed_cache.is_some(),
                    caps.reports_embed_cache,
                    "{}: slot {i} embed-cache telemetry vs caps",
                    caps.name
                );
            }
        }
    }

    #[test]
    fn cache_hits_are_charged_ntn_fcn_only() {
        // First pass: cold cache, full GCN+Att+tail cycles. Second pass
        // over the same batch: every graph hits, so the cycle model must
        // charge exactly the NTN+FCN tail per real slot — the hardware
        // saving the embedding cache buys (DESIGN.md S14).
        use crate::sim::gcn::pair_tail_cycles;
        let mut eng = tiny_engine();
        let (_, pb) = packed_workload(&eng);
        let cold = eng.score_batch(&pb).unwrap();
        let warm = eng.score_batch(&pb).unwrap();
        assert_eq!(cold.scores, warm.scores, "caching must not change scores");
        let tail = pair_tail_cycles(eng.config(), eng.arch());
        for i in 0..3 {
            let c = cold.telemetry[i].cycles.unwrap();
            let w = warm.telemetry[i].cycles.unwrap();
            assert_eq!(w.interval, tail, "slot {i} warm interval");
            assert_eq!(w.latency, tail, "slot {i} warm latency");
            // Interval is a max over units, so it can only shrink or
            // stay; latency always pays the embed fill, so it strictly
            // shrinks once the embeds are cached.
            assert!(c.interval >= w.interval, "slot {i}: cold {c:?} < warm {w:?}");
            assert!(c.latency > w.latency, "slot {i}: cold {c:?} !> warm {w:?}");
            let cs = cold.telemetry[i].embed_cache.unwrap();
            let ws = warm.telemetry[i].embed_cache.unwrap();
            assert_eq!((cs.hits, cs.misses), (0, 2), "slot {i} cold");
            assert_eq!((ws.hits, ws.misses), (2, 0), "slot {i} warm");
        }
    }

    #[test]
    fn corpus_scoring_matches_pairwise_and_skips_cached_embeds() {
        let mut eng = tiny_engine();
        let (pairs, _) = packed_workload(&eng);
        // Corpus = the six workload graphs, with one duplicate appended.
        let mut corpus: Vec<EncodedGraph> = pairs
            .iter()
            .flat_map(|(a, b)| [a.clone(), b.clone()])
            .collect();
        corpus.push(corpus[0].clone());
        let mut rng = Rng::new(86);
        let q = generate(&mut rng, Family::ErdosRenyi { n: 6, p_millis: 300 }, 8, 4);
        let eq = encode(&q, 8, 4).unwrap();
        let out = eng.score_corpus(&eq, &corpus).unwrap();
        assert_eq!(out.scores.len(), 7);
        let cs = out.telemetry.embed_cache.unwrap();
        assert_eq!(cs.misses, 7, "query + six unique corpus graphs");
        assert_eq!(cs.hits, 1, "the duplicated entry");
        assert!(out.telemetry.cycles.unwrap().interval > 0);
        // Scores match the pairwise batch path bit for bit.
        let pairs: Vec<_> = corpus.iter().map(|c| (eq.clone(), c.clone())).collect();
        let pb = PackedBatch::pack(&pairs, pairs.len()).unwrap();
        let mut fresh = tiny_engine();
        let pairwise = fresh.score_batch(&pb).unwrap();
        assert_eq!(out.scores, &pairwise.scores[..7]);
        // Warm repeat: all hits, and the total charge collapses to
        // corpus.len() NTN+FCN tails.
        use crate::sim::gcn::pair_tail_cycles;
        let warm = eng.score_corpus(&eq, &corpus).unwrap();
        assert_eq!(warm.scores, out.scores);
        let wc = warm.telemetry.cycles.unwrap();
        assert_eq!(wc.interval, 7 * pair_tail_cycles(eng.config(), eng.arch()));
        assert_eq!(warm.telemetry.embed_cache.unwrap().misses, 0);
    }

    #[test]
    fn sharded_corpus_matches_unsharded_and_shards_charge_independently() {
        use crate::runtime::embed_cache::EmbedCache;
        let base = tiny_engine();
        let (pairs, _) = packed_workload(&base);
        let corpus: Vec<EncodedGraph> = pairs
            .iter()
            .flat_map(|(a, b)| [a.clone(), b.clone()])
            .collect(); // 6 candidates
        let mut rng = Rng::new(87);
        let q = generate(&mut rng, Family::ErdosRenyi { n: 6, p_millis: 300 }, 8, 4);
        let eq = encode(&q, 8, 4).unwrap();

        let mut reference = tiny_engine();
        let want = reference.score_corpus(&eq, &corpus).unwrap();

        // Two sim "lanes" on one shared cache, sharded 4 + 2.
        let shared = Arc::new(EmbedCache::new(256));
        let mut lane_a = SimEngine::new(
            base.cfg.clone(),
            base.weights.clone(),
            ArchConfig::spa_gcn(),
            U280,
        )
        .with_cache(Arc::clone(&shared));
        let mut lane_b = SimEngine::new(
            base.cfg.clone(),
            base.weights.clone(),
            ArchConfig::spa_gcn(),
            U280,
        )
        .with_cache(Arc::clone(&shared));
        let embed = lane_a.embed_query(&eq).unwrap();
        let embed_cycles = embed.telemetry.cycles.unwrap();
        assert!(embed_cycles.interval > 0, "cold query embed is charged");
        let a = lane_a.score_corpus_with(&embed.embed.hg, &corpus[..4]).unwrap();
        let b = lane_b.score_corpus_with(&embed.embed.hg, &corpus[4..]).unwrap();
        let mut got = a.scores.clone();
        got.extend_from_slice(&b.scores);
        assert_eq!(got, want.scores, "sharded scores diverged from score_corpus");
        // Shards are charged independently: each report covers only its
        // own candidates, so either shard costs less than the unsharded
        // whole — the parallel speedup the gather's max-merge surfaces.
        let whole = want.telemetry.cycles.unwrap();
        let ca = a.telemetry.cycles.unwrap();
        let cb = b.telemetry.cycles.unwrap();
        assert!(ca.interval < whole.interval, "shard A {ca:?} !< whole {whole:?}");
        assert!(cb.interval < whole.interval, "shard B {cb:?} !< whole {whole:?}");
        // A warm embed_query is free: the profile is the zero profile.
        let warm = lane_b.embed_query(&eq).unwrap();
        assert_eq!(warm.telemetry.cycles.unwrap(), CycleReport { interval: 0, latency: 0 });
        assert_eq!(warm.telemetry.embed_cache.unwrap().hits, 1);
    }

    #[test]
    fn sim_scores_match_native_reference() {
        use crate::nn::simgnn::simgnn_score;
        let mut eng = tiny_engine();
        let mut rng = Rng::new(83);
        let f = Family::ErdosRenyi { n: 5, p_millis: 300 };
        let g1 = generate(&mut rng, f, 8, 4);
        let g2 = generate(&mut rng, f, 8, 4);
        let e1 = encode(&g1, 8, 4).unwrap();
        let e2 = encode(&g2, 8, 4).unwrap();
        let (score, _) = eng.run_query(&g1, &g2).unwrap();
        let direct = simgnn_score(eng.config(), &eng.weights, &e1, &e2);
        assert_eq!(score, direct);
        assert_eq!(eng.stats.queries, 1, "forward+sim must run exactly once");
    }
}
