//! Architecture parameters of the SPA-GCN accelerator (paper Table 2) and
//! the three design points evaluated in Table 4.

/// Per-GCN-layer parallelization parameters (paper Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerParams {
    /// SIMD factor of the Feature Transformation step (output-feature
    /// lanes per PE).
    pub simd_ft: usize,
    /// SIMD factor of the Aggregation step (feature lanes; node-level
    /// parallelism is deliberately absent there, §3.2.2).
    pub simd_agg: usize,
    /// Duplication factor: number of SIMD PEs in the FT step (node-level
    /// parallelism).
    pub df: usize,
    /// Number of input FIFOs feeding the sparse-dispatch arbiter (only
    /// meaningful when the architecture prunes zeros, §3.4).
    pub p: usize,
}

/// Which architecture variant of Table 4 is being simulated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArchVariant {
    /// One set of modules reused for all layers; dense FT; sparse Agg.
    Baseline,
    /// Dedicated modules per layer connected by FIFOs (dataflow).
    InterLayerPipeline,
    /// Inter-layer pipeline + zero-pruning FT with P-FIFO arbiter.
    ExtendedSparsity,
}

/// Full accelerator configuration.
#[derive(Debug, Clone)]
pub struct ArchConfig {
    pub variant: ArchVariant,
    /// Per-layer params; for `Baseline` only `layers[0]` is used (one
    /// shared module).
    pub layers: [LayerParams; 3],
    /// SIMD factor of the Att stage MVM (kept small, §4.2).
    pub att_simd: usize,
    /// SIMD factor of the NTN stage MVMs (§4.3).
    pub ntn_simd: usize,
    /// Zero-pruning FIFO width at the ACG output (elements/cycle), §3.4.
    pub prune_width: usize,
}

impl ArchConfig {
    /// Table 4 row 1: "Baseline" — shared hardware, SIMD_FT 16,
    /// SIMD_Agg 32, DF 8.
    pub fn baseline() -> Self {
        let l = LayerParams {
            simd_ft: 16,
            simd_agg: 32,
            df: 8,
            p: 0,
        };
        ArchConfig {
            variant: ArchVariant::Baseline,
            layers: [l, l, l],
            att_simd: 8,
            ntn_simd: 8,
            prune_width: 0,
        }
    }

    /// Table 4 row 2: "+Inter-Layer Pipeline" — per-layer modules,
    /// SIMD_FT 32/16/16, SIMD_Agg 32/32/16, DF 8/8/8.
    pub fn inter_layer() -> Self {
        ArchConfig {
            variant: ArchVariant::InterLayerPipeline,
            layers: [
                LayerParams { simd_ft: 32, simd_agg: 32, df: 8, p: 0 },
                LayerParams { simd_ft: 16, simd_agg: 32, df: 8, p: 0 },
                LayerParams { simd_ft: 16, simd_agg: 16, df: 8, p: 0 },
            ],
            att_simd: 8,
            ntn_simd: 8,
            prune_width: 0,
        }
    }

    /// Table 4 row 3: "+Extended Sparsity" — SIMD_FT 32/32/16,
    /// SIMD_Agg 32/32/16, DF 2/1/1, P 8/2/2.
    pub fn extended_sparsity() -> Self {
        ArchConfig {
            variant: ArchVariant::ExtendedSparsity,
            layers: [
                LayerParams { simd_ft: 32, simd_agg: 32, df: 2, p: 8 },
                LayerParams { simd_ft: 32, simd_agg: 32, df: 1, p: 2 },
                LayerParams { simd_ft: 16, simd_agg: 16, df: 1, p: 2 },
            ],
            att_simd: 8,
            ntn_simd: 8,
            prune_width: 4,
        }
    }

    /// The design point used for the full-SimGNN evaluation (Table 5/6).
    pub fn spa_gcn() -> Self {
        Self::extended_sparsity()
    }

    pub fn name(&self) -> &'static str {
        match self.variant {
            ArchVariant::Baseline => "baseline",
            ArchVariant::InterLayerPipeline => "+inter-layer-pipeline",
            ArchVariant::ExtendedSparsity => "+extended-sparsity",
        }
    }

    /// Sparse FT (zero-pruning + arbiter) enabled?
    pub fn sparse_ft(&self) -> bool {
        self.variant == ArchVariant::ExtendedSparsity
    }

    /// Dedicated per-layer modules (dataflow across layers)?
    pub fn dataflow(&self) -> bool {
        self.variant != ArchVariant::Baseline
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_table4() {
        let b = ArchConfig::baseline();
        assert_eq!(b.layers[0].simd_ft, 16);
        assert_eq!(b.layers[0].df, 8);
        assert!(!b.sparse_ft() && !b.dataflow());

        let il = ArchConfig::inter_layer();
        assert_eq!(il.layers[0].simd_ft, 32);
        assert_eq!(il.layers[2].simd_agg, 16);
        assert!(il.dataflow() && !il.sparse_ft());

        let es = ArchConfig::extended_sparsity();
        assert_eq!(es.layers[0].p, 8);
        assert_eq!(es.layers[1].df, 1);
        assert!(es.dataflow() && es.sparse_ft());
    }
}
