//! The SPA-GCN accelerator cycle simulator (DESIGN.md S9-S12).
//!
//! Models the paper's architecture at the scheduling level: the dense and
//! sparse Feature-Transformation engines (with the P-FIFO arbiter and
//! RAW-bubble control unit of §3.4), the edge-streaming Aggregation
//! engine (§3.2.2), per-layer dataflow composition (§3.3), the Att / NTN
//! / FCN stages (§4), FPGA resources (Fig. 10), host overheads + batching
//! (Fig. 11) and analytical CPU/GPU baselines (Table 6).
pub mod agg;
pub mod baseline;
pub mod config;
pub mod dataflow;
pub mod e2e;
pub mod energy;
pub mod engine;
pub mod ft;
pub mod gcn;
pub mod platform;
pub mod resources;
