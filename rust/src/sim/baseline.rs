//! Analytical CPU / GPU execution models for the Table 6 comparison.
//!
//! These encode the mechanism the paper measured rather than guessing
//! absolute speeds:
//!
//!  * **PyG-CPU** (Xeon E5-2699 v4): per-PyTorch-op dispatch overhead
//!    dominates small-graph kernels; MKL GEMMs on 32xF matrices run far
//!    below peak. The paper measured 5.85 ms kernel / 9.27 ms E2E.
//!  * **PyG-GPU** (V100): nvprof showed 225 kernel launches per query,
//!    ~4.6 KFLOP per kernel, <=6% SM utilization (mostly 1 SM of 80) —
//!    launch overhead exceeds compute, so the GPU is *slower* than the
//!    CPU (9.68 ms kernel / 13.7 ms E2E).
//!
//! Model constants are calibrated to those published measurements and
//! used to regenerate Table 6's *shape*; the real measured rust-native
//! and PJRT-CPU engines provide the grounded companion numbers.

/// Workload description of one SimGNN query.
#[derive(Debug, Clone, Copy)]
pub struct QueryWork {
    /// Total FLOPs of the query (2 graphs through GCN + Att + NTN + FCN).
    pub flops: f64,
    /// Framework ops dispatched per query (PyG: scatter + mm + act per
    /// layer per graph, plus attention/NTN/FCN glue).
    pub torch_ops: u32,
    /// CUDA kernels launched per query (paper nvprof: 225).
    pub cuda_kernels: u32,
}

impl QueryWork {
    /// FLOP count from the model dims and mean graph size.
    pub fn from_dims(n: usize, filters: [usize; 3], num_labels: usize, k: usize) -> QueryWork {
        let f = filters[2];
        let mut flops = 0f64;
        let dims_in = [num_labels, filters[0], filters[1]];
        for l in 0..3 {
            // H@W + A'@X per graph
            flops += 2.0 * (n * dims_in[l] * filters[l]) as f64;
            flops += 2.0 * (n * n * filters[l]) as f64;
        }
        flops *= 2.0; // two graphs
        flops += 2.0 * 2.0 * (f * f * n) as f64; // attention MVMs
        flops += 2.0 * (k * f * f + k * 2 * f) as f64; // NTN
        flops += 2.0 * (k * 16 + 16 * 8) as f64; // FCN
        QueryWork {
            flops,
            torch_ops: 70,     // ~11 ops x 6 layer-graphs + glue
            cuda_kernels: 225, // paper §5.4.2
        }
    }
}

/// CPU model (PyG on a 22-core Xeon at 2.2 GHz).
#[derive(Debug, Clone, Copy)]
pub struct CpuModel {
    /// Effective GEMM throughput on tiny matrices, GFLOP/s. Peak AVX2 FMA
    /// on 22 cores is ~1.5 TFLOP/s; tiny matrices with scatter/gather in
    /// between reach a fraction of a percent of that.
    pub eff_gflops: f64,
    /// Per-op framework dispatch cost, µs (PyTorch eager).
    pub dispatch_us: f64,
    /// Python-side per-query E2E overhead, ms (data prep + profiler gap).
    pub e2e_extra_ms: f64,
}

impl Default for CpuModel {
    fn default() -> Self {
        CpuModel {
            eff_gflops: 1.6,
            dispatch_us: 62.0,
            e2e_extra_ms: 3.4,
        }
    }
}

impl CpuModel {
    pub fn kernel_ms(&self, w: &QueryWork) -> f64 {
        w.flops / (self.eff_gflops * 1e6) + w.torch_ops as f64 * self.dispatch_us / 1e3
    }
    pub fn e2e_ms(&self, w: &QueryWork) -> f64 {
        self.kernel_ms(w) + self.e2e_extra_ms
    }
}

/// GPU model (PyG on a V100, coarse-grained execution).
#[derive(Debug, Clone, Copy)]
pub struct GpuModel {
    /// Per-kernel launch + sync overhead, µs (cudaLaunchKernel + driver).
    pub launch_us: f64,
    /// Achieved throughput per kernel: the paper saw 1 SM used; one SM
    /// at 1.3 GHz with partial occupancy on 4.6 KFLOP kernels.
    pub eff_gflops: f64,
    /// Host-side per-query overhead (python + transfers), ms.
    pub e2e_extra_ms: f64,
}

impl Default for GpuModel {
    fn default() -> Self {
        GpuModel {
            launch_us: 41.0,
            eff_gflops: 25.0,
            e2e_extra_ms: 4.0,
        }
    }
}

impl GpuModel {
    pub fn kernel_ms(&self, w: &QueryWork) -> f64 {
        let launch = w.cuda_kernels as f64 * self.launch_us / 1e3;
        let compute = w.flops / (self.eff_gflops * 1e6);
        launch + compute
    }
    pub fn e2e_ms(&self, w: &QueryWork) -> f64 {
        self.kernel_ms(w) + self.e2e_extra_ms
    }
    /// Fraction of kernel time that is launch overhead (paper: dominant).
    pub fn launch_fraction(&self, w: &QueryWork) -> f64 {
        let launch = w.cuda_kernels as f64 * self.launch_us / 1e3;
        launch / self.kernel_ms(w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn work() -> QueryWork {
        QueryWork::from_dims(26, [64, 32, 16], 29, 16)
    }

    #[test]
    fn cpu_lands_near_paper_numbers() {
        let m = CpuModel::default();
        let k = m.kernel_ms(&work());
        // paper: 5.85 ms kernel; we require the same order of magnitude.
        assert!((3.0..=9.0).contains(&k), "cpu kernel {k} ms");
        let e = m.e2e_ms(&work());
        assert!((6.0..=13.0).contains(&e), "cpu e2e {e} ms");
    }

    #[test]
    fn gpu_is_slower_than_cpu() {
        // The paper's headline pathology: coarse-grained execution makes
        // the V100 SLOWER than the Xeon on 10-node graphs.
        let w = work();
        let cpu = CpuModel::default();
        let gpu = GpuModel::default();
        assert!(gpu.kernel_ms(&w) > cpu.kernel_ms(&w));
        assert!((7.0..=13.0).contains(&gpu.kernel_ms(&w)), "{}", gpu.kernel_ms(&w));
    }

    #[test]
    fn gpu_time_is_launch_dominated() {
        let gpu = GpuModel::default();
        assert!(
            gpu.launch_fraction(&work()) > 0.9,
            "launch fraction {}",
            gpu.launch_fraction(&work())
        );
    }

    #[test]
    fn flop_count_scales_with_graph_size() {
        let small = QueryWork::from_dims(10, [64, 32, 16], 29, 16);
        let big = QueryWork::from_dims(30, [64, 32, 16], 29, 16);
        assert!(big.flops > 2.0 * small.flops);
    }
}
