//! Cycle model of the Feature-Transformation engine (MULT + ACC units,
//! paper §3.2.1 and §3.4).
//!
//! Two modes:
//!
//! * **Dense** (baseline / inter-layer variants): closed form from the
//!   outer-product schedule of Fig. 3 — stream H column-major, broadcast
//!   each element to a SIMD PE that updates `f_out` outputs over
//!   `ceil(f_out/SIMD)` cycles, DF PEs across the node dimension. II=1
//!   requires the RAW window `(rows/DF) * ceil(f_out/SIMD) >= L`
//!   (§3.2.1); when a small graph cannot fill the window the matrix is
//!   padded with zero rows — the small-graph tax the paper highlights.
//!
//! * **Sparse** (extended-sparsity variant, §3.4): cycle-accurate
//!   simulation of the P-FIFO round-robin arbiter dispatching non-zero
//!   elements to DF SIMD PEs, with the bank rule (one dispatch per output
//!   bank per cycle) and the `prev iter` RAW control unit inserting
//!   bubbles when the same output row is touched within `L` cycles.

use super::config::LayerParams;

/// A non-zero input element: (row = node index, col = input feature).
/// The stream must be in the paper's column-major order (feature outer,
/// node inner) — `nonzero_stream` produces it from a dense matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NzElem {
    pub row: u16,
    pub col: u16,
}

/// Column-major non-zero scan of a row-major `n x f` matrix, restricted
/// to the first `rows` rows.
pub fn nonzero_stream(h: &[f32], rows: usize, f: usize) -> Vec<NzElem> {
    let mut out = Vec::new();
    for k in 0..f {
        for v in 0..rows {
            if h[v * f + k] != 0.0 {
                out.push(NzElem {
                    row: v as u16,
                    col: k as u16,
                });
            }
        }
    }
    out
}

/// Result of one FT pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct FtCycles {
    /// Busy cycles of the MULT/ACC pipeline pair (they are II-matched).
    pub busy: u64,
    /// Bubbles inserted by the RAW control unit (sparse mode only).
    pub raw_bubbles: u64,
    /// Cycles lost to head-of-line blocking / empty FIFOs at the arbiter.
    pub starve_cycles: u64,
    /// Elements actually processed (non-zeros in sparse mode; full padded
    /// matrix in dense mode).
    pub elements: u64,
    /// Zero-padding rows added to satisfy the II=1 RAW window (dense).
    pub pad_rows: u64,
}

/// Dense FT (Fig. 3 schedule): returns cycles for a `rows x f_in` input
/// against a `f_in x f_out` weight, with `l_add` the accumulator latency.
pub fn dense_ft_cycles(
    rows: usize,
    f_in: usize,
    f_out: usize,
    p: &LayerParams,
    l_add: usize,
) -> FtCycles {
    let per_elem = f_out.div_ceil(p.simd_ft) as u64;
    // RAW window: consecutive updates to the same output row happen every
    // (rows/DF)*per_elem cycles; pad rows until that reaches l_add.
    let mut rows_padded = rows.next_multiple_of(p.df).max(p.df);
    while (rows_padded / p.df) as u64 * per_elem < l_add as u64 {
        rows_padded += p.df;
    }
    let row_groups = (rows_padded / p.df) as u64;
    let busy = row_groups * f_in as u64 * per_elem;
    FtCycles {
        busy,
        raw_bubbles: 0,
        starve_cycles: 0,
        elements: rows_padded as u64 * f_in as u64,
        pad_rows: (rows_padded - rows) as u64,
    }
}

/// Sparse FT: cycle-accurate arbiter simulation.
///
/// * `stream`: column-major non-zeros of the real input data;
/// * `feed_rate`: elements/cycle arriving from the producer (the previous
///   stage's pruning unit, `prune_width`); `usize::MAX` = all available
///   up-front (first layer reads from memory);
/// * `l_add`: accumulator latency = RAW window.
pub fn sparse_ft_cycles(
    stream: &[NzElem],
    f_out: usize,
    p: &LayerParams,
    l_add: usize,
    feed_rate: usize,
) -> FtCycles {
    assert!(p.p >= 1, "sparse FT needs P >= 1 FIFOs");
    assert!(p.df >= 1);
    let per_elem = f_out.div_ceil(p.simd_ft) as u64;
    let n_fifos = p.p;
    let mut fifos: Vec<std::collections::VecDeque<NzElem>> =
        vec![Default::default(); n_fifos];
    // Producer pushes round-robin; `fed` counts elements already pushed.
    let mut fed = 0usize;
    // PE busy-until cycle, one per DF (PE b owns output bank b).
    let mut pe_free_at = vec![0u64; p.df];
    // prev-iter buffer: cycle at which each row was last issued (flat
    // array — rows are bounded by n_max, and u64::MAX marks "never").
    let max_row = stream.iter().map(|e| e.row as usize).max().unwrap_or(0);
    let mut last_issue = vec![u64::MAX; max_row + 1];
    let mut cycle: u64 = 0;
    let mut done = 0usize;
    let mut bubbles = 0u64;
    let mut starve = 0u64;
    let total = stream.len();
    let max_cycles = (total as u64 + 16) * per_elem.max(1) * (l_add as u64 + 4) + 1024;

    while done < total {
        // Producer: feed up to feed_rate elements round-robin into FIFOs.
        let feed = feed_rate.min(total - fed);
        for _ in 0..feed {
            fifos[fed % n_fifos].push_back(stream[fed]);
            fed += 1;
        }
        // Arbiter: one pass over FIFOs in round-robin starting at cycle
        // offset; dispatch at most one element per free bank (bank set is
        // a bitmask: DF <= 64 always).
        debug_assert!(p.df <= 64);
        let mut dispatched_banks: u64 = 0;
        let mut any = false;
        for f_idx in 0..n_fifos {
            let fi = (cycle as usize + f_idx) % n_fifos;
            let Some(&head) = fifos[fi].front() else {
                continue;
            };
            let bank = head.row as usize % p.df;
            if dispatched_banks & (1 << bank) != 0 || pe_free_at[bank] > cycle {
                continue; // bank taken this cycle or PE still busy
            }
            // RAW check against the prev-iter buffer: the previous update
            // to this row commits l_add cycles after issue.
            let prev = last_issue[head.row as usize];
            if prev != u64::MAX && cycle < prev + l_add as u64 {
                bubbles += 1;
                continue; // bubble: leave element queued
            }
            fifos[fi].pop_front();
            dispatched_banks |= 1 << bank;
            pe_free_at[bank] = cycle + per_elem;
            last_issue[head.row as usize] = cycle + per_elem - 1;
            done += 1;
            any = true;
        }
        if !any && done < total {
            starve += 1;
        }
        cycle += 1;
        if cycle > max_cycles {
            // Defensive: the schedule above always progresses, but guard
            // against a modeling bug turning into an infinite loop.
            panic!("sparse FT simulation did not converge");
        }
    }
    // Drain: last element's outputs commit after the accumulate latency.
    let busy = cycle + per_elem + l_add as u64;
    FtCycles {
        busy,
        raw_bubbles: bubbles,
        starve_cycles: starve,
        elements: total as u64,
        pad_rows: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params(simd: usize, df: usize, p: usize) -> LayerParams {
        LayerParams {
            simd_ft: simd,
            simd_agg: simd,
            df,
            p,
        }
    }

    #[test]
    fn dense_matches_closed_form() {
        // 32 rows, DF 8, f_in 29, f_out 64, SIMD 16 -> 4 * 29 * 4 = 464
        let c = dense_ft_cycles(32, 29, 64, &params(16, 8, 0), 7);
        assert_eq!(c.busy, 464);
        assert_eq!(c.pad_rows, 0);
    }

    #[test]
    fn dense_pads_small_graphs_for_raw_window() {
        // 8 rows, DF 8, f_out 16, SIMD 16 -> window = 1*1 = 1 < L=7:
        // needs rows_padded/8 * 1 >= 7 -> 56 rows.
        let c = dense_ft_cycles(8, 32, 16, &params(16, 8, 0), 7);
        assert_eq!(c.pad_rows, 48);
        assert_eq!(c.busy, 7 * 32);
    }

    #[test]
    fn sparse_processes_all_elements() {
        // 20 nonzeros, DF 2, SIMD covers f_out in 1 cycle.
        let stream: Vec<NzElem> = (0..20)
            .map(|i| NzElem {
                row: (i % 10) as u16,
                col: (i / 10) as u16,
            })
            .collect();
        let c = sparse_ft_cycles(&stream, 32, &params(32, 2, 4), 7, usize::MAX);
        assert_eq!(c.elements, 20);
        // 2 banks dispatch ~2/cycle; each row repeats once (distance 10
        // elements ~ 5 cycles < L=7) so a few RAW bubbles are expected.
        assert!(c.busy >= 10 && c.busy < 48, "busy={}", c.busy);
        assert!(c.raw_bubbles > 0, "5-cycle row reuse must bubble at L=7");
    }

    #[test]
    fn sparse_same_row_burst_stalls() {
        // All elements hit row 0 -> every dispatch waits the full RAW
        // window: heavy bubbles.
        let stream: Vec<NzElem> = (0..8)
            .map(|k| NzElem { row: 0, col: k })
            .collect();
        let c = sparse_ft_cycles(&stream, 32, &params(32, 2, 4), 7, usize::MAX);
        assert!(c.raw_bubbles > 0);
        assert!(c.busy >= 8 * 7, "busy={} should be ~L per element", c.busy);
    }

    #[test]
    fn sparse_beats_dense_on_sparse_input() {
        // 32x64 input at 90% sparsity: sparse engine with modest DF should
        // need far fewer cycles than the dense schedule.
        let rows = 32;
        let f_in = 64;
        let f_out = 32;
        let mut h = vec![0.0f32; rows * f_in];
        // deterministic 10% fill
        for i in (0..h.len()).step_by(10) {
            h[i] = 1.0;
        }
        let stream = nonzero_stream(&h, rows, f_in);
        let dense = dense_ft_cycles(rows, f_in, f_out, &params(16, 8, 0), 7);
        let sparse = sparse_ft_cycles(&stream, f_out, &params(32, 2, 8), 7, usize::MAX);
        assert!(
            sparse.busy < dense.busy / 2,
            "sparse {} vs dense {}",
            sparse.busy,
            dense.busy
        );
    }

    #[test]
    fn sparse_more_fifos_never_hurt() {
        let mut h = vec![0.0f32; 32 * 32];
        for i in (0..h.len()).step_by(3) {
            h[i] = 1.0;
        }
        let stream = nonzero_stream(&h, 32, 32);
        let p2 = sparse_ft_cycles(&stream, 32, &params(32, 2, 2), 7, usize::MAX);
        let p8 = sparse_ft_cycles(&stream, 32, &params(32, 2, 8), 7, usize::MAX);
        assert!(p8.busy <= p2.busy + 4, "P8 {} vs P2 {}", p8.busy, p2.busy);
    }

    #[test]
    fn nonzero_stream_is_column_major() {
        // 2x3 matrix with nonzeros at (0,0),(1,2)
        let h = vec![5.0, 0.0, 0.0, 0.0, 0.0, 7.0];
        let s = nonzero_stream(&h, 2, 3);
        assert_eq!(
            s,
            vec![NzElem { row: 0, col: 0 }, NzElem { row: 1, col: 2 }]
        );
    }

    #[test]
    fn limited_feed_rate_slows_start() {
        let stream: Vec<NzElem> = (0..64)
            .map(|i| NzElem {
                row: (i % 32) as u16,
                col: (i / 32) as u16,
            })
            .collect();
        let fast = sparse_ft_cycles(&stream, 32, &params(32, 4, 8), 7, usize::MAX);
        let slow = sparse_ft_cycles(&stream, 32, &params(32, 4, 8), 7, 1);
        assert!(slow.busy >= fast.busy);
        assert!(slow.busy >= 64, "1 elem/cycle feed bounds at 64+");
    }
}
