//! Event-driven tandem-pipeline simulation with finite FIFOs.
//!
//! The analytic composition in `gcn.rs` uses the steady-state rule
//! "interval = max(module busy time)". That rule is exact only with
//! sufficient inter-module buffering; the real architecture connects
//! modules with *finite* FIFOs (Fig. 2/4), where a slow downstream module
//! can block an upstream one (backpressure). This module simulates the
//! blocking-after-service recurrence for a chain of stages with
//! per-item service times and per-stage output-buffer capacities:
//!
//!   depart[i][s] = max(depart[i-1][s],            server frees
//!                      depart[i][s-1])            input available
//!                  + t[i][s]
//!   then blocking: depart[i][s] >= depart[i - B_{s+1}][s+1]
//!
//! Used by the `fifo-depth` ablation bench and as a validation oracle
//! for the analytic interval (they must agree once buffers are deep).

/// One pipeline stage: per-item service times (cycles).
#[derive(Debug, Clone)]
pub struct Stage {
    pub name: String,
    pub service: Vec<u64>,
    /// Capacity of the FIFO *feeding* this stage (items). The first
    /// stage's input is unbounded (memory).
    pub input_fifo: usize,
}

/// Result of simulating `n` items through the chain.
#[derive(Debug, Clone)]
pub struct PipelineRun {
    /// Completion cycle of each item at the last stage.
    pub completions: Vec<u64>,
    /// Makespan (last completion).
    pub makespan: u64,
    /// Steady-state inter-completion interval (mean over the second half).
    pub steady_interval: f64,
    /// Cycles each stage spent blocked on a full downstream FIFO.
    pub blocked_cycles: Vec<u64>,
}

/// Simulate the tandem pipeline (items flow through all stages in order).
pub fn simulate_pipeline(stages: &[Stage]) -> PipelineRun {
    assert!(!stages.is_empty());
    let n = stages[0].service.len();
    assert!(
        stages.iter().all(|s| s.service.len() == n),
        "all stages must see every item"
    );
    let s_count = stages.len();
    // depart[s][i]: cycle item i leaves stage s (enters FIFO to s+1).
    let mut depart = vec![vec![0u64; n]; s_count];
    let mut blocked = vec![0u64; s_count];
    for i in 0..n {
        for s in 0..s_count {
            let server_free = if i > 0 { depart[s][i - 1] } else { 0 };
            let input_ready = if s > 0 { depart[s - 1][i] } else { 0 };
            let mut d = server_free.max(input_ready) + stages[s].service[i];
            // Blocking-after-service: item i cannot leave stage s until
            // there is space in stage s+1's input FIFO, i.e. item
            // i - B_{s+1} has departed stage s+1.
            if s + 1 < s_count {
                let b = stages[s + 1].input_fifo.max(1);
                if i >= b {
                    let gate = depart[s + 1][i - b];
                    if gate > d {
                        blocked[s] += gate - d;
                        d = gate;
                    }
                }
            }
            depart[s][i] = d;
        }
    }
    let completions = depart[s_count - 1].clone();
    let makespan = *completions.last().unwrap();
    let steady_interval = if n >= 4 {
        let half = n / 2;
        (completions[n - 1] - completions[half - 1]) as f64 / (n - half) as f64
    } else {
        makespan as f64 / n as f64
    };
    PipelineRun {
        completions,
        makespan,
        steady_interval,
        blocked_cycles: blocked,
    }
}

/// Build the SimGNN stage chain for a stream of per-query GCN layer busy
/// times + stage models, with a given inter-module FIFO depth.
pub fn simgnn_chain(
    layer_busy: &[[u64; 3]],
    att: u64,
    ntn_fcn: u64,
    fifo_depth: usize,
) -> Vec<Stage> {
    let n = layer_busy.len();
    let layer = |l: usize| -> Vec<u64> { (0..n).map(|i| layer_busy[i][l]).collect() };
    vec![
        Stage {
            name: "GCN-L1".into(),
            service: layer(0),
            input_fifo: usize::MAX,
        },
        Stage {
            name: "GCN-L2".into(),
            service: layer(1),
            input_fifo: fifo_depth,
        },
        Stage {
            name: "GCN-L3".into(),
            service: layer(2),
            input_fifo: fifo_depth,
        },
        Stage {
            name: "Att".into(),
            service: vec![att; n],
            input_fifo: fifo_depth,
        },
        Stage {
            name: "NTN+FCN".into(),
            service: vec![ntn_fcn; n],
            input_fifo: fifo_depth,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform(stage_times: &[u64], n: usize, fifo: usize) -> Vec<Stage> {
        stage_times
            .iter()
            .enumerate()
            .map(|(s, &t)| Stage {
                name: format!("s{s}"),
                service: vec![t; n],
                input_fifo: if s == 0 { usize::MAX } else { fifo },
            })
            .collect()
    }

    #[test]
    fn deep_fifos_match_max_rule() {
        // Steady interval == max stage time with ample buffering.
        let stages = uniform(&[3, 7, 5], 64, 16);
        let run = simulate_pipeline(&stages);
        assert!((run.steady_interval - 7.0).abs() < 0.2, "{}", run.steady_interval);
        // latency of first item = sum of stage times
        assert_eq!(run.completions[0], 15);
    }

    #[test]
    fn tiny_fifos_cause_backpressure() {
        // A slow last stage with depth-1 FIFOs blocks everything upstream;
        // steady interval is still max (=9) but blocked cycles appear.
        let stages = uniform(&[3, 3, 9], 64, 1);
        let run = simulate_pipeline(&stages);
        assert!(run.blocked_cycles[0] + run.blocked_cycles[1] > 0);
        assert!((run.steady_interval - 9.0).abs() < 0.3);
    }

    #[test]
    fn variable_service_interval_exceeds_mean_max_with_shallow_fifos() {
        // Alternating fast/slow items: shallow FIFOs cannot smooth the
        // variance, deep FIFOs can (classic tandem-queue result).
        let mut svc1 = Vec::new();
        let mut svc2 = Vec::new();
        for i in 0..128 {
            svc1.push(if i % 2 == 0 { 10 } else { 2 });
            svc2.push(if i % 2 == 0 { 2 } else { 10 });
        }
        let shallow = simulate_pipeline(&[
            Stage { name: "a".into(), service: svc1.clone(), input_fifo: usize::MAX },
            Stage { name: "b".into(), service: svc2.clone(), input_fifo: 1 },
        ]);
        let deep = simulate_pipeline(&[
            Stage { name: "a".into(), service: svc1, input_fifo: usize::MAX },
            Stage { name: "b".into(), service: svc2, input_fifo: 64 },
        ]);
        assert!(deep.steady_interval <= shallow.steady_interval + 1e-9);
    }

    #[test]
    fn simgnn_chain_shape() {
        let layers = vec![[5u64, 7, 3]; 10];
        let chain = simgnn_chain(&layers, 4, 2, 4);
        assert_eq!(chain.len(), 5);
        let run = simulate_pipeline(&chain);
        // bottleneck = 7
        assert!((run.steady_interval - 7.0).abs() < 0.5);
    }

    #[test]
    #[should_panic(expected = "all stages must see every item")]
    fn rejects_ragged_service() {
        simulate_pipeline(&[
            Stage { name: "a".into(), service: vec![1, 2], input_fifo: 1 },
            Stage { name: "b".into(), service: vec![1], input_fifo: 1 },
        ]);
    }
}
