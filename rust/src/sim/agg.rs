//! Cycle model of the Aggregation engine (paper §3.2.2).
//!
//! The engine streams the (pre-processed) weighted edge list of A' and,
//! per edge, updates all `f_out` features of the destination node using
//! `SIMD_Agg` feature lanes — feature-level parallelism only (edge-level
//! parallelism would bank-conflict on random destinations). The offline
//! reordering (graph::reorder) guarantees II=1; a non-reordered stream
//! pays RAW stalls, which this model charges explicitly.

use crate::graph::normalize::WEdge;
use crate::graph::reorder::raw_stall_cycles;

use super::config::LayerParams;

/// Result of one Aggregation pass.
#[derive(Debug, Clone, Copy, Default)]
pub struct AggCycles {
    pub busy: u64,
    pub raw_stalls: u64,
    pub edges: u64,
}

/// Cycles for aggregating `edges` (already includes both directions and
/// self-loops) into `f_out`-wide features.
pub fn agg_cycles(
    edges: &[WEdge],
    f_out: usize,
    p: &LayerParams,
    l_add: usize,
    reordered: bool,
) -> AggCycles {
    let per_edge = f_out.div_ceil(p.simd_agg) as u64;
    let stalls = if reordered {
        0
    } else {
        // Each stall in edge-issue terms blocks `per_edge` engine cycles.
        raw_stall_cycles(edges, l_add.div_ceil(per_edge as usize)) as u64 * per_edge
    };
    let busy = edges.len() as u64 * per_edge + stalls + l_add as u64;
    AggCycles {
        busy,
        raw_stalls: stalls,
        edges: edges.len() as u64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{generate, Family};
    use crate::graph::normalize::normalized_edges;
    use crate::graph::reorder::reorder_edges;
    use crate::sim::config::LayerParams;
    use crate::util::rng::Rng;

    fn params(simd_agg: usize) -> LayerParams {
        LayerParams {
            simd_ft: 16,
            simd_agg,
            df: 8,
            p: 0,
        }
    }

    #[test]
    fn reordered_stream_is_stall_free() {
        let mut rng = Rng::new(61);
        let g = generate(&mut rng, Family::Aids, 32, 29);
        let edges = normalized_edges(&g);
        let r = reorder_edges(&edges, 8);
        let c = agg_cycles(&r.edges, 64, &params(32), 7, true);
        assert_eq!(c.raw_stalls, 0);
        assert_eq!(c.busy, edges.len() as u64 * 2 + 7);
    }

    #[test]
    fn sorted_stream_pays_stalls() {
        let mut rng = Rng::new(62);
        let g = generate(&mut rng, Family::Aids, 32, 29);
        let edges = normalized_edges(&g); // dst-sorted: worst case
        let c = agg_cycles(&edges, 64, &params(32), 7, false);
        assert!(c.raw_stalls > 0);
        let r = reorder_edges(&edges, 8);
        let c2 = agg_cycles(&r.edges, 64, &params(32), 7, true);
        assert!(c2.busy < c.busy);
    }

    #[test]
    fn wider_simd_reduces_busy() {
        let mut rng = Rng::new(63);
        let g = generate(&mut rng, Family::Aids, 32, 29);
        let edges = reorder_edges(&normalized_edges(&g), 8).edges;
        let narrow = agg_cycles(&edges, 64, &params(16), 7, true);
        let wide = agg_cycles(&edges, 64, &params(64), 7, true);
        assert!(wide.busy < narrow.busy);
    }
}
