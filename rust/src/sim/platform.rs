//! Target platform models: the three FPGAs of paper Table 3 plus the
//! floating-point unit latencies reported in §5.4.1.

/// An FPGA platform (paper Table 3).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Platform {
    pub name: &'static str,
    /// BRAM capacity, Mb.
    pub bram_mb: f64,
    /// LUTs, thousands.
    pub lut_k: f64,
    /// Flip-flops, thousands.
    pub ff_k: f64,
    /// DSP48 slices.
    pub dsp: u32,
    /// URAM capacity, Mb.
    pub uram_mb: f64,
    /// Peak global-memory bandwidth, GB/s.
    pub max_bw_gbs: f64,
    /// Independent global-memory channels (HBM pseudo-channels or DDR
    /// banks) — §5.4.3 uses 4 PCs per query pipeline.
    pub mem_channels: u32,
    /// f32 multiplier pipeline latency in cycles (§5.4.1: 5 on KU15P,
    /// 4 on U280-class parts).
    pub mul_latency: usize,
    /// f32 adder pipeline latency in cycles (8 / 7).
    pub add_latency: usize,
    /// Achievable clock for a well-placed small design, MHz (Table 5).
    pub fmax_mhz: f64,
    /// PCIe host->device effective bandwidth, GB/s (gen3 x16 practical).
    pub pcie_gbs: f64,
}

/// Xilinx Kintex UltraScale+ KU15P (DDR4).
pub const KU15P: Platform = Platform {
    name: "KU15P",
    bram_mb: 34.6,
    lut_k: 523.0,
    ff_k: 1045.0,
    dsp: 1968,
    uram_mb: 36.0,
    max_bw_gbs: 19.2,
    mem_channels: 2,
    mul_latency: 5,
    add_latency: 8,
    fmax_mhz: 201.0,
    pcie_gbs: 8.0,
};

/// Xilinx Alveo U50 (HBM2, 316 GB/s).
pub const U50: Platform = Platform {
    name: "U50",
    bram_mb: 47.3,
    lut_k: 872.0,
    ff_k: 1743.0,
    dsp: 5952,
    uram_mb: 180.0,
    max_bw_gbs: 316.0,
    mem_channels: 32,
    mul_latency: 4,
    add_latency: 7,
    fmax_mhz: 279.0,
    pcie_gbs: 12.0,
};

/// Xilinx Alveo U280 (HBM2, 460 GB/s).
pub const U280: Platform = Platform {
    name: "U280",
    bram_mb: 70.9,
    lut_k: 1304.0,
    ff_k: 2607.0,
    dsp: 9024,
    uram_mb: 270.0,
    max_bw_gbs: 460.0,
    mem_channels: 32,
    mul_latency: 4,
    add_latency: 7,
    fmax_mhz: 290.0,
    pcie_gbs: 12.0,
};

pub const ALL_PLATFORMS: [Platform; 3] = [KU15P, U50, U280];

impl Platform {
    /// Achieved clock for a given architecture variant, MHz.
    ///
    /// Calibrated against the paper's measurements (Table 4 on U280:
    /// baseline 265, +inter-layer 271, +sparsity 300; Table 5 full
    /// pipeline: 201/279/290). Model: the shared-hardware baseline pays a
    /// muxing penalty; the sparse design is smaller and routes better.
    pub fn achieved_freq_mhz(&self, variant: super::config::ArchVariant) -> f64 {
        use super::config::ArchVariant::*;
        let scale = match variant {
            Baseline => 265.0 / 300.0,
            InterLayerPipeline => 271.0 / 300.0,
            ExtendedSparsity => 1.0,
        };
        // fmax is the Table 5 full-pipeline clock, which used the sparse
        // GCN design; scale other variants down by the U280-calibrated
        // ratio.
        (self.fmax_mhz + 10.0).min(300.0 * (self.fmax_mhz / 290.0)) * scale
    }

    /// Bytes/cycle of streaming bandwidth available to one accelerator
    /// pipeline at frequency `mhz`, assuming `channels_used` channels.
    pub fn stream_bytes_per_cycle(&self, mhz: f64, channels_used: u32) -> f64 {
        let share = channels_used.min(self.mem_channels) as f64
            / self.mem_channels as f64;
        let bw = self.max_bw_gbs * share * 1e9; // bytes/s
        bw / (mhz * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::config::ArchVariant;

    #[test]
    fn table3_values() {
        assert_eq!(KU15P.dsp, 1968);
        assert_eq!(U50.dsp, 5952);
        assert_eq!(U280.dsp, 9024);
        assert!(U280.max_bw_gbs > U50.max_bw_gbs);
        assert!(KU15P.max_bw_gbs < 20.0);
    }

    #[test]
    fn freq_ordering_matches_table4() {
        let f_base = U280.achieved_freq_mhz(ArchVariant::Baseline);
        let f_il = U280.achieved_freq_mhz(ArchVariant::InterLayerPipeline);
        let f_es = U280.achieved_freq_mhz(ArchVariant::ExtendedSparsity);
        assert!(f_base < f_il && f_il < f_es);
        assert!((f_es - 300.0).abs() < 5.0, "U280 sparse ~300MHz, got {f_es}");
        assert!((f_base - 265.0).abs() < 10.0);
    }

    #[test]
    fn ku15p_is_slowest() {
        let f = KU15P.achieved_freq_mhz(ArchVariant::ExtendedSparsity);
        assert!(f < 215.0 && f > 190.0, "{f}");
    }

    #[test]
    fn hbm_streams_much_faster_than_ddr() {
        let hbm = U280.stream_bytes_per_cycle(300.0, 4);
        let ddr = KU15P.stream_bytes_per_cycle(200.0, 2);
        assert!(hbm > ddr);
    }
}
