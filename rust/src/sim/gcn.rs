//! Whole-accelerator cycle composition: per-layer GCN modules, the Att /
//! NTN / FCN stages, and the three dataflow levels of §4.4.
//!
//! Simulation is driven by REAL data: the layer input matrices (and hence
//! the exact non-zero structure the pruning units see) come from the rust
//! reference forward (`nn::simgnn::gcn_forward`), and the edge stream is
//! the actual pre-processed (reordered) weighted adjacency of the query's
//! graphs.

use crate::graph::encode::EncodedGraph;
use crate::graph::normalize::normalized_edges;
use crate::graph::reorder::reorder_edges;
use crate::graph::Graph;
use crate::nn::config::ModelConfig;
use crate::nn::simgnn::GcnTrace;

use super::agg::{agg_cycles, AggCycles};
use super::config::{ArchConfig, ArchVariant};
use super::ft::{dense_ft_cycles, nonzero_stream, sparse_ft_cycles, FtCycles};
use super::platform::Platform;

/// Cycle accounting for one GCN layer of one graph.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerCycles {
    pub ft: FtCycles,
    pub agg: AggCycles,
}

impl LayerCycles {
    /// Busy time of the layer's ACG module (ACC mirrors the FT stream,
    /// then Aggregation runs on the committed buffer — §3.2.3).
    pub fn acg_busy(&self) -> u64 {
        self.ft.busy + self.agg.busy
    }
}

/// Cycle accounting for the full GCN stage on one graph.
#[derive(Debug, Clone, Default)]
pub struct GcnCycles {
    pub layers: [LayerCycles; 3],
    /// Off-chip roundtrip cycles between layers (baseline variant only).
    pub interlayer_transfer: u64,
    /// Steady-state initiation interval per graph (throughput^-1).
    pub interval: u64,
    /// Fill latency for one graph (first-result latency).
    pub latency: u64,
}

/// Simulate the GCN stage for one graph under `arch` on `plat`.
///
/// `trace` supplies the real per-layer input data (sparsity structure).
pub fn simulate_gcn(
    cfg: &ModelConfig,
    arch: &ArchConfig,
    plat: &Platform,
    graph: &Graph,
    enc: &EncodedGraph,
    trace: &GcnTrace,
) -> GcnCycles {
    let l_add = plat.add_latency;
    let dims_in = cfg.feature_dims();
    let edges = normalized_edges(graph);
    let reordered = reorder_edges(&edges, l_add).edges;

    let mut layers = [LayerCycles::default(); 3];
    for l in 0..3 {
        let p = if arch.dataflow() {
            arch.layers[l]
        } else {
            arch.layers[0] // baseline: one shared module
        };
        let f_in = dims_in[l];
        let f_out = cfg.filters[l];
        let ft = if arch.sparse_ft() {
            let stream = nonzero_stream(&trace.layer_inputs[l], enc.num_nodes, f_in);
            // Layer 1 streams pruned one-hot inputs from memory (fast);
            // later layers are fed by the previous ACG's pruning unit.
            let feed = if l == 0 {
                usize::MAX
            } else {
                arch.prune_width.max(1)
            };
            sparse_ft_cycles(&stream, f_out, &p, l_add, feed)
        } else {
            dense_ft_cycles(enc.num_nodes, f_in, f_out, &p, l_add)
        };
        let agg = agg_cycles(&reordered, f_out, &p, l_add, true);
        layers[l] = LayerCycles { ft, agg };
    }

    // Baseline: intermediate H written to and re-read from global memory.
    let transfer = if arch.dataflow() {
        0
    } else {
        let freq = plat.achieved_freq_mhz(arch.variant);
        let bpc = plat.stream_bytes_per_cycle(freq, 4);
        let mut bytes = 0f64;
        for l in 0..2 {
            bytes += (cfg.n_max * cfg.filters[l] * 4 * 2) as f64; // write+read
        }
        // burst initiation per transfer (4 transfers), ~64 cycles each
        (bytes / bpc).ceil() as u64 + 4 * 64
    };

    let (interval, latency) = if arch.dataflow() {
        let max_acg = layers.iter().map(|l| l.acg_busy()).max().unwrap();
        let sum: u64 = layers.iter().map(|l| l.acg_busy()).sum();
        (max_acg, sum)
    } else {
        let sum: u64 = layers.iter().map(|l| l.acg_busy()).sum();
        (sum + transfer, sum + transfer)
    };

    GcnCycles {
        layers,
        interlayer_transfer: transfer,
        interval,
        latency,
    }
}

/// Cycle accounting for the non-GCN SimGNN stages (closed-form models —
/// the paper deliberately under-parallelizes these, §4.1). The Att stage
/// runs once per graph and scales with that graph's node count, so it is
/// charged per graph — not twice at `max(n1, n2)`.
#[derive(Debug, Clone, Copy, Default)]
pub struct StageCycles {
    /// Att pass over the query's first graph (`n1` real nodes).
    pub att1: u64,
    /// Att pass over the query's second graph (`n2` real nodes).
    pub att2: u64,
    pub ntn: u64,
    pub fcn: u64,
}

/// Fixed per-activation-unit pipeline latency (tanh/exp from the HLS math
/// library, §4.2).
const ACT_LATENCY: u64 = 18;

/// One Att pass (Eq. 5 form) over a graph with `n_real` real nodes:
/// W_att . H as one MVM per node column (F*F MACs each) + sigmoid scores
/// + weighted sum H x a.
pub fn att_cycles(cfg: &ModelConfig, arch: &ArchConfig, n_real: usize) -> u64 {
    let f = cfg.embed_dim() as u64;
    let n = n_real as u64;
    let att_simd = arch.att_simd as u64;
    (f * f).div_ceil(att_simd) * n             // sum(W.H, 2)
        + ACT_LATENCY                              // tanh
        + n * f.div_ceil(att_simd) + ACT_LATENCY   // h_n . c + sigmoid
        + n * f.div_ceil(att_simd)                 // H x a
}

pub fn stage_cycles(cfg: &ModelConfig, arch: &ArchConfig, n1: usize, n2: usize) -> StageCycles {
    let f = cfg.embed_dim() as u64;
    let k = cfg.ntn_k as u64;
    let ntn_simd = arch.ntn_simd as u64;
    // NTN: K slices of (F x F MVM + dot) + V [2F] + bias.
    let ntn = k * (f * f).div_ceil(ntn_simd) + k * (2 * f).div_ceil(ntn_simd) + ACT_LATENCY;
    // FCN: chain of small MVMs + sigmoid.
    let mut fcn = 0u64;
    let mut d = k;
    for &h in &cfg.fc_dims {
        fcn += (d * h as u64).div_ceil(ntn_simd);
        d = h as u64;
    }
    fcn += d + ACT_LATENCY;
    StageCycles {
        att1: att_cycles(cfg, arch, n1),
        att2: att_cycles(cfg, arch, n2),
        ntn,
        fcn,
    }
}

/// Whole-pipeline cycle accounting for one query (two graphs).
#[derive(Debug, Clone, Default)]
pub struct QueryCycles {
    pub gcn1: GcnCycles,
    pub gcn2: GcnCycles,
    pub stages: StageCycles,
    /// Input streaming cycles (edges + pruned features over the memory
    /// channels), overlapped with compute by the dataflow prefetcher.
    pub input_stream: u64,
    /// Steady-state interval between query completions.
    pub interval: u64,
    /// One-query latency.
    pub latency: u64,
}

/// Simulate one full SimGNN query under `arch` on `plat`.
///
/// Composition (§4.4): the GCN module is shared by the two graphs of a
/// query (serial), Att overlaps GCN of the other graph, NTN+FCN overlap
/// the GCN stage of the next query. Steady state is bounded by the
/// busiest unit — normally the GCN stage (gcn1 + gcn2 intervals), with
/// the Att unit (att1 + att2, each billed at its own graph's node
/// count), the NTN_FCN chain and the input stream as the other bounds.
pub fn simulate_query(
    cfg: &ModelConfig,
    arch: &ArchConfig,
    plat: &Platform,
    q1: (&Graph, &EncodedGraph, &GcnTrace),
    q2: (&Graph, &EncodedGraph, &GcnTrace),
) -> QueryCycles {
    let gcn1 = simulate_gcn(cfg, arch, plat, q1.0, q1.1, q1.2);
    let gcn2 = simulate_gcn(cfg, arch, plat, q2.0, q2.1, q2.2);
    // Each graph's Att pass is billed at its own node count (the old
    // composition charged both at max(n1, n2), overcounting mixed-size
    // pairs in the serial baseline).
    let stages = stage_cycles(cfg, arch, q1.1.num_nodes, q2.1.num_nodes);

    // Input streaming: edge stream (8 B/entry) + pruned one-hot features
    // (8 B/entry: value+address packing, §3.4).
    let freq = plat.achieved_freq_mhz(arch.variant);
    let bpc = plat.stream_bytes_per_cycle(freq, 4);
    let in_bytes = ((q1.0.num_edges() * 2 + q1.0.num_nodes())
        + (q2.0.num_edges() * 2 + q2.0.num_nodes())) as f64
        * 8.0
        + (q1.0.num_nodes() + q2.0.num_nodes()) as f64 * 8.0;
    let input_stream = (in_bytes / bpc).ceil() as u64 + 64;

    let gcn_total = gcn1.interval + gcn2.interval;
    let att_total = stages.att1 + stages.att2;
    let (interval, latency) = if arch.dataflow() {
        // Level-1/2 dataflow: Att overlaps GCN of the other graph,
        // NTN_FCN overlaps the next query's GCN; prefetch overlaps
        // compute. Steady state is bounded by the busiest unit: the GCN
        // module (both graphs), the Att unit (both passes), the NTN_FCN
        // chain, or the input stream.
        let interval = gcn_total
            .max(att_total)
            .max(stages.ntn + stages.fcn)
            .max(input_stream);
        // First-result latency: att1 (started when gcn1 finished) runs
        // concurrent with gcn2, but the single Att unit cannot start
        // att2 until BOTH gcn2 and att1 are done — for a (large, small)
        // pair att1 can outlive gcn2, so its overhang is charged.
        let latency = gcn1.latency
            + gcn2.latency.max(stages.att1)
            + stages.att2
            + stages.ntn
            + stages.fcn;
        (interval, latency)
    } else {
        // Baseline: everything serial; each Att pass at its own size.
        let total = gcn_total + att_total + stages.ntn + stages.fcn + input_stream;
        (total, total)
    };

    QueryCycles {
        gcn1,
        gcn2,
        stages,
        input_stream,
        interval,
        latency,
    }
}

/// Convenience: kernel milliseconds for a steady-state query stream.
pub fn kernel_ms(cycles_interval: u64, plat: &Platform, variant: ArchVariant) -> f64 {
    cycles_interval as f64 / (plat.achieved_freq_mhz(variant) * 1e3)
}

/// Per-graph share of one query's cycle cost — everything a graph-level
/// embedding-cache hit skips (DESIGN.md S14): the GCN stage, the Att
/// pass, and this graph's input streaming bytes. The zero profile
/// (`default()`) IS the cache hit: composing two of them charges the
/// query NTN+FCN only.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EmbedCycleProfile {
    /// Steady-state GCN interval for this graph.
    pub gcn_interval: u64,
    /// GCN fill latency for this graph.
    pub gcn_latency: u64,
    /// This graph's Att pass.
    pub att: u64,
    /// Input-stream bytes (edges + pruned features) this graph adds.
    pub input_bytes: u64,
}

/// Simulate the embed stage (GCN + Att + input bytes) of one graph and
/// return both the full [`GcnCycles`] (for stats absorption) and the
/// compact [`EmbedCycleProfile`] used to compose cache-aware queries.
pub fn embed_profile(
    cfg: &ModelConfig,
    arch: &ArchConfig,
    plat: &Platform,
    graph: &Graph,
    enc: &EncodedGraph,
    trace: &GcnTrace,
) -> (GcnCycles, EmbedCycleProfile) {
    let gcn = simulate_gcn(cfg, arch, plat, graph, enc, trace);
    let profile = EmbedCycleProfile {
        gcn_interval: gcn.interval,
        gcn_latency: gcn.latency,
        att: att_cycles(cfg, arch, enc.num_nodes),
        // Mirrors `simulate_query`'s byte accounting: edge stream +
        // pruned one-hot features at 8 B/entry each.
        input_bytes: ((graph.num_edges() * 2 + graph.num_nodes()) * 8
            + graph.num_nodes() * 8) as u64,
    };
    (gcn, profile)
}

/// The per-pair tail a cache hit still pays: NTN + FCN cycles (node-count
/// independent).
pub fn pair_tail_cycles(cfg: &ModelConfig, arch: &ArchConfig) -> u64 {
    let s = stage_cycles(cfg, arch, 0, 0);
    s.ntn + s.fcn
}

/// Cycles to stream `bytes` of input at the platform's achieved rate
/// (shared by [`compose_cached_query`] and [`embed_only_cycles`] so the
/// two chargings cannot drift). Zero bytes stream for free; otherwise a
/// 64-cycle setup charge applies, as in `simulate_query`.
fn input_stream_cycles(plat: &Platform, variant: ArchVariant, bytes: u64) -> u64 {
    if bytes == 0 {
        return 0;
    }
    let freq = plat.achieved_freq_mhz(variant);
    let bpc = plat.stream_bytes_per_cycle(freq, 4);
    (bytes as f64 / bpc).ceil() as u64 + 64
}

/// Cycle charge of one *standalone* embed — the scatter-time query
/// embed of a sharded corpus query (DESIGN.md S15): this graph's GCN,
/// Att and input streaming, with no pair tail (the tails are paid by
/// the shard lanes). The zero profile (a cache hit) charges zero.
pub fn embed_only_cycles(
    arch: &ArchConfig,
    plat: &Platform,
    p: &EmbedCycleProfile,
) -> (u64, u64) {
    let stream = input_stream_cycles(plat, arch.variant, p.input_bytes);
    if arch.dataflow() {
        (p.gcn_interval.max(p.att).max(stream), p.gcn_latency + p.att)
    } else {
        let total = p.gcn_interval + p.att + stream;
        (total, total)
    }
}

/// Compose two per-graph embed profiles + the NTN/FCN tail into one
/// query's (interval, latency) — the cache-aware counterpart of
/// [`simulate_query`]. With both profiles live (cache misses) this
/// reproduces `simulate_query`'s numbers exactly; a cached graph passes
/// the zero profile and contributes nothing, so a fully-cached query is
/// charged NTN+FCN only.
pub fn compose_cached_query(
    cfg: &ModelConfig,
    arch: &ArchConfig,
    plat: &Platform,
    p1: &EmbedCycleProfile,
    p2: &EmbedCycleProfile,
) -> (u64, u64) {
    let tail = pair_tail_cycles(cfg, arch);
    let input_stream = input_stream_cycles(plat, arch.variant, p1.input_bytes + p2.input_bytes);
    let gcn_total = p1.gcn_interval + p2.gcn_interval;
    let att_total = p1.att + p2.att;
    if arch.dataflow() {
        let interval = gcn_total.max(att_total).max(tail).max(input_stream);
        let latency = p1.gcn_latency + p2.gcn_latency.max(p1.att) + p2.att + tail;
        (interval, latency)
    } else {
        let total = gcn_total + att_total + tail + input_stream;
        (total, total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::encode::encode;
    use crate::graph::generate::{generate, Family};
    use crate::nn::simgnn::gcn_forward;
    use crate::nn::weights::Weights;
    use crate::sim::platform::U280;
    use crate::util::rng::Rng;

    fn setup() -> (ModelConfig, Weights, Graph, EncodedGraph, GcnTrace) {
        let cfg = ModelConfig::default();
        // pseudo-random weights with ~50% post-ReLU sparsity
        let mut rng = Rng::new(71);
        let mut vecr = |len: usize, s: f32| -> Vec<f32> {
            (0..len).map(|_| (rng.f32() - 0.5) * s).collect()
        };
        let dims_in = cfg.feature_dims();
        let w = Weights {
            gcn_w: [
                vecr(dims_in[0] * cfg.filters[0], 0.5),
                vecr(dims_in[1] * cfg.filters[1], 0.5),
                vecr(dims_in[2] * cfg.filters[2], 0.5),
            ],
            gcn_b: [
                vec![0.0; cfg.filters[0]],
                vec![0.0; cfg.filters[1]],
                vec![0.0; cfg.filters[2]],
            ],
            att_w: vecr(16 * 16, 0.5),
            ntn_w: vecr(16 * 256, 0.5),
            ntn_v: vecr(16 * 32, 0.5),
            ntn_b: vec![0.0; 16],
            fc_w: vec![vecr(256, 0.5), vecr(128, 0.5)],
            fc_b: vec![vec![0.0; 16], vec![0.0; 8]],
            out_w: vecr(8, 0.5),
            out_b: vec![0.0],
        };
        let mut rng2 = Rng::new(72);
        let g = generate(&mut rng2, Family::Aids, 32, 29);
        let e = encode(&g, cfg.n_max, cfg.num_labels).unwrap();
        let t = gcn_forward(&cfg, &w, &e);
        (cfg, w, g, e, t)
    }

    #[test]
    fn dataflow_beats_baseline_interval() {
        let (cfg, _w, g, e, t) = setup();
        let base = simulate_gcn(&cfg, &ArchConfig::baseline(), &U280, &g, &e, &t);
        let il = simulate_gcn(&cfg, &ArchConfig::inter_layer(), &U280, &g, &e, &t);
        assert!(
            il.interval < base.interval,
            "inter-layer {} !< baseline {}",
            il.interval,
            base.interval
        );
        // baseline pays off-chip roundtrips
        assert!(base.interlayer_transfer > 0);
        assert_eq!(il.interlayer_transfer, 0);
    }

    #[test]
    fn sparse_uses_fewer_ft_elements() {
        let (cfg, _w, g, e, t) = setup();
        let il = simulate_gcn(&cfg, &ArchConfig::inter_layer(), &U280, &g, &e, &t);
        let es = simulate_gcn(&cfg, &ArchConfig::extended_sparsity(), &U280, &g, &e, &t);
        // layer 1 input is one-hot: sparse processes ~n elements instead
        // of n*29.
        assert!(es.layers[0].ft.elements * 10 < il.layers[0].ft.elements);
    }

    #[test]
    fn query_interval_is_busiest_unit() {
        let (cfg, _w, g, e, t) = setup();
        let arch = ArchConfig::spa_gcn();
        let qc = simulate_query(&cfg, &arch, &U280, (&g, &e, &t), (&g, &e, &t));
        // The composition wiring: steady-state interval is the max of the
        // per-unit busy times exposed on the report.
        let gcn = qc.gcn1.interval + qc.gcn2.interval;
        let att = qc.stages.att1 + qc.stages.att2;
        let tail = qc.stages.ntn + qc.stages.fcn;
        assert_eq!(qc.interval, gcn.max(att).max(tail).max(qc.input_stream));
        assert!(qc.latency >= qc.gcn1.latency + qc.gcn2.latency);
        // Latency charges the att1 overhang when it outlives gcn2 (the
        // single Att unit serializes att1 before att2).
        assert_eq!(
            qc.latency,
            qc.gcn1.latency
                + qc.gcn2.latency.max(qc.stages.att1)
                + qc.stages.att2
                + tail
        );
        // Identical graphs on both sides: both Att passes cost the same.
        assert_eq!(qc.stages.att1, qc.stages.att2);
    }

    #[test]
    fn att_is_charged_per_graph_not_at_max() {
        // Regression for the baseline overcount: a (small, large) pair
        // used to bill BOTH Att passes at max(n1, n2). Now each pass
        // scales with its own graph.
        let (cfg, w, g_big, e_big, t_big) = setup();
        let mut rng = Rng::new(73);
        let g_small = generate(
            &mut rng,
            crate::graph::generate::Family::ErdosRenyi { n: 6, p_millis: 300 },
            32,
            29,
        );
        let e_small = encode(&g_small, cfg.n_max, cfg.num_labels).unwrap();
        let t_small = gcn_forward(&cfg, &w, &e_small);
        assert!(e_small.num_nodes < e_big.num_nodes, "fixture sizes");

        let arch = ArchConfig::baseline();
        let s = stage_cycles(&cfg, &arch, e_small.num_nodes, e_big.num_nodes);
        assert!(s.att1 < s.att2, "small graph's Att must cost less");
        assert_eq!(s.att1, att_cycles(&cfg, &arch, e_small.num_nodes));
        assert_eq!(s.att2, att_cycles(&cfg, &arch, e_big.num_nodes));
        // NTN/FCN are node-count independent.
        let sym = stage_cycles(&cfg, &arch, e_big.num_nodes, e_big.num_nodes);
        assert_eq!(s.ntn, sym.ntn);
        assert_eq!(s.fcn, sym.fcn);

        // End to end: the serial baseline now charges a mixed pair less
        // than a pair of two large graphs by exactly the Att delta plus
        // the smaller graph's cheaper GCN/stream work.
        let qc_mixed = simulate_query(
            &cfg,
            &arch,
            &U280,
            (&g_small, &e_small, &t_small),
            (&g_big, &e_big, &t_big),
        );
        let qc_big = simulate_query(
            &cfg,
            &arch,
            &U280,
            (&g_big, &e_big, &t_big),
            (&g_big, &e_big, &t_big),
        );
        assert!(
            qc_mixed.interval < qc_big.interval,
            "mixed {} !< big {}",
            qc_mixed.interval,
            qc_big.interval
        );
        assert_eq!(qc_mixed.stages.att2, qc_big.stages.att2);
        assert!(qc_mixed.stages.att1 < qc_big.stages.att1);
    }

    #[test]
    fn cached_composition_matches_simulate_query_when_cold() {
        // Both sides live (cache miss): the composed numbers must equal
        // simulate_query's exactly — the cached path is not a second,
        // drifting cycle model.
        let (cfg, w, g_big, e_big, t_big) = setup();
        let mut rng = Rng::new(74);
        let g_small = generate(
            &mut rng,
            crate::graph::generate::Family::ErdosRenyi { n: 6, p_millis: 300 },
            32,
            29,
        );
        let e_small = encode(&g_small, cfg.n_max, cfg.num_labels).unwrap();
        let t_small = gcn_forward(&cfg, &w, &e_small);
        for arch in [ArchConfig::spa_gcn(), ArchConfig::baseline()] {
            let qc = simulate_query(
                &cfg,
                &arch,
                &U280,
                (&g_small, &e_small, &t_small),
                (&g_big, &e_big, &t_big),
            );
            let (_, p1) = embed_profile(&cfg, &arch, &U280, &g_small, &e_small, &t_small);
            let (_, p2) = embed_profile(&cfg, &arch, &U280, &g_big, &e_big, &t_big);
            let (interval, latency) = compose_cached_query(&cfg, &arch, &U280, &p1, &p2);
            assert_eq!(interval, qc.interval, "variant {:?}", arch.variant);
            assert_eq!(latency, qc.latency, "variant {:?}", arch.variant);
        }
    }

    #[test]
    fn fully_cached_query_is_charged_ntn_fcn_only() {
        let (cfg, _w, _g, _e, _t) = setup();
        let arch = ArchConfig::spa_gcn();
        let zero = EmbedCycleProfile::default();
        let (interval, latency) = compose_cached_query(&cfg, &arch, &U280, &zero, &zero);
        let tail = pair_tail_cycles(&cfg, &arch);
        assert_eq!(interval, tail);
        assert_eq!(latency, tail);
        assert!(tail > 0);
    }

    #[test]
    fn embed_only_charges_the_graph_without_a_tail() {
        let (cfg, _w, g, e, t) = setup();
        for arch in [ArchConfig::spa_gcn(), ArchConfig::baseline()] {
            let (_, p) = embed_profile(&cfg, &arch, &U280, &g, &e, &t);
            let (interval, latency) = embed_only_cycles(&arch, &U280, &p);
            assert!(interval > 0 && latency > 0);
            // No pair tail: a standalone embed costs strictly less than
            // composing the same profile into a one-sided cached query.
            let (paired, paired_lat) =
                compose_cached_query(&cfg, &arch, &U280, &p, &EmbedCycleProfile::default());
            assert!(interval <= paired, "variant {:?}", arch.variant);
            assert!(latency < paired_lat, "variant {:?}", arch.variant);
            // The cached profile (a hit) embeds for free.
            let zero = EmbedCycleProfile::default();
            assert_eq!(embed_only_cycles(&arch, &U280, &zero), (0, 0));
        }
    }

    #[test]
    fn kernel_ms_scales_with_freq() {
        let c = 300_000u64;
        let ms = kernel_ms(c, &U280, ArchVariant::ExtendedSparsity);
        assert!((ms - 1.0).abs() < 0.05, "300k cycles @300MHz ~ 1ms, got {ms}");
    }
}
