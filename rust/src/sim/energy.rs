//! Energy model: Table 3 quotes TDPs for the HBM cards (U50 75 W, U280
//! 225 W); the paper argues FPGAs win on efficiency as well as latency.
//! This model combines a platform TDP share (proportional to resource
//! utilization, plus static overhead) with the kernel time to estimate
//! energy per query — the standard back-of-envelope the FPGA literature
//! uses when no power measurement exists.

use super::platform::Platform;
use super::resources::Resources;

/// Platform TDP in watts (Table 3 references + vendor datasheets).
pub fn tdp_watts(p: &Platform) -> f64 {
    match p.name {
        "KU15P" => 40.0,  // Kintex US+ typical board power
        "U50" => 75.0,    // paper §5.2
        "U280" => 225.0,  // paper §5.2
        _ => 100.0,
    }
}

/// Estimated board power for a design: static floor + dynamic share
/// proportional to LUT+DSP utilization (simple affine model).
pub fn design_power_watts(p: &Platform, r: &Resources) -> f64 {
    let util = r.utilization(p);
    let activity = (util[0] + util[2]) / 200.0; // mean of LUT and DSP fractions
    let tdp = tdp_watts(p);
    0.25 * tdp + 0.75 * tdp * activity.min(1.0)
}

/// Energy per query in millijoules.
pub fn energy_per_query_mj(p: &Platform, r: &Resources, kernel_ms: f64) -> f64 {
    design_power_watts(p, r) * kernel_ms
}

/// Reference points for the comparison: Xeon E5-2699v4 TDP 145 W, V100
/// TDP 300 W (paper's baseline hardware).
pub fn cpu_energy_per_query_mj(kernel_ms: f64) -> f64 {
    145.0 * kernel_ms
}

pub fn gpu_energy_per_query_mj(kernel_ms: f64) -> f64 {
    300.0 * kernel_ms
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::platform::{KU15P, U280, U50};

    fn small_design() -> Resources {
        Resources {
            dsp: 660.0,
            bram18: 324.0,
            uram: 0.0,
            lut: 150_000.0,
            ff: 90_000.0,
        }
    }

    #[test]
    fn power_between_static_floor_and_tdp() {
        for p in [&KU15P, &U50, &U280] {
            let w = design_power_watts(p, &small_design());
            assert!(w >= 0.25 * tdp_watts(p) - 1e-9);
            assert!(w <= tdp_watts(p));
        }
    }

    #[test]
    fn fpga_beats_cpu_and_gpu_on_energy() {
        // paper's narrative: ~18x faster at a fraction of the power.
        let r = small_design();
        let fpga = energy_per_query_mj(&U280, &r, 0.327);
        let cpu = cpu_energy_per_query_mj(5.85);
        let gpu = gpu_energy_per_query_mj(9.68);
        assert!(fpga < cpu / 10.0, "fpga {fpga} mJ vs cpu {cpu} mJ");
        assert!(fpga < gpu / 10.0, "fpga {fpga} mJ vs gpu {gpu} mJ");
    }

    #[test]
    fn u50_lower_power_than_u280() {
        let r = small_design();
        assert!(design_power_watts(&U50, &r) < design_power_watts(&U280, &r));
    }
}
