//! Bipartite GED approximation (Riesen & Bunke style) — the "Hungarian"
//! baseline from SimGNN's evaluation: build a node-assignment cost matrix
//! (substitution / deletion / insertion with a local degree+label
//! heuristic), solve it optimally with the O(n^3) Hungarian algorithm
//! (Jonker-Volgenant shortest augmenting path), then score the *induced*
//! edit path — which makes the result a valid GED upper bound.

use crate::graph::Graph;

/// Solve the square assignment problem; returns (assignment, total cost)
/// where `assignment[row] = col`. O(n^3) shortest augmenting path.
pub fn hungarian(cost: &[Vec<f64>]) -> (Vec<usize>, f64) {
    let n = cost.len();
    if n == 0 {
        return (Vec::new(), 0.0);
    }
    for row in cost {
        assert_eq!(row.len(), n, "cost matrix must be square");
    }
    const INF: f64 = f64::INFINITY;
    // 1-indexed potentials/links per the classic formulation.
    let mut u = vec![0.0f64; n + 1];
    let mut v = vec![0.0f64; n + 1];
    let mut p = vec![0usize; n + 1]; // p[col] = row matched to col
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![INF; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            for j in 1..=n {
                if used[j] {
                    continue;
                }
                let cur = cost[i0 - 1][j - 1] - u[i0] - v[j];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut assignment = vec![0usize; n];
    let mut total = 0.0;
    for j in 1..=n {
        if p[j] != 0 {
            assignment[p[j] - 1] = j - 1;
            total += cost[p[j] - 1][j - 1];
        }
    }
    (assignment, total)
}

/// Induced edit cost of a full g1 -> g2 node mapping: label substitutions
/// + node deletions/insertions + exact edge mismatch. Any mapping gives a
/// valid GED upper bound.
fn induced_cost(g1: &Graph, g2: &Graph, mapping: &[Option<u16>]) -> f64 {
    let mut cost = 0.0;
    let mut used = vec![false; g2.num_nodes()];
    for (i, m) in mapping.iter().enumerate() {
        match m {
            Some(j) => {
                used[*j as usize] = true;
                if g1.labels()[i] != g2.labels()[*j as usize] {
                    cost += 1.0;
                }
            }
            None => cost += 1.0, // deletion
        }
    }
    cost += used.iter().filter(|&&x| !x).count() as f64; // insertions
    // Edge terms: g1 edges not preserved + g2 edges not covered.
    for &(a, b) in g1.edges() {
        let ok = matches!(
            (mapping[a as usize], mapping[b as usize]),
            (Some(x), Some(y)) if g2.has_edge(x, y)
        );
        if !ok {
            cost += 1.0;
        }
    }
    for &(x, y) in g2.edges() {
        let covered = mapping.iter().enumerate().any(|(a, m)| {
            m == &Some(x)
                && mapping
                    .iter()
                    .enumerate()
                    .any(|(b, m2)| m2 == &Some(y) && g1.has_edge(a as u16, b as u16))
        });
        if !covered {
            cost += 1.0;
        }
    }
    cost
}

/// Bipartite GED upper bound: Hungarian assignment on the
/// label+half-degree-difference cost matrix, scored by the induced edit
/// path.
pub fn hungarian_ged(g1: &Graph, g2: &Graph) -> f64 {
    let n1 = g1.num_nodes();
    let n2 = g2.num_nodes();
    let n = n1 + n2;
    if n == 0 {
        return 0.0;
    }
    let d1 = g1.degrees();
    let d2 = g2.degrees();
    // (n1+n2) x (n1+n2) matrix: rows = g1 nodes then n2 "insert" slots,
    // cols = g2 nodes then n1 "delete" slots (Riesen-Bunke construction).
    let mut cost = vec![vec![0.0f64; n]; n];
    for i in 0..n1 {
        for j in 0..n2 {
            let label = if g1.labels()[i] == g2.labels()[j] { 0.0 } else { 1.0 };
            let degree = (d1[i] as f64 - d2[j] as f64).abs() / 2.0;
            cost[i][j] = label + degree;
        }
        for j in 0..n1 {
            cost[i][n2 + j] = if i == j {
                1.0 + d1[i] as f64 / 2.0 // delete node i + its edges
            } else {
                f64::INFINITY / 4.0
            };
        }
    }
    for i in 0..n2 {
        for j in 0..n2 {
            cost[n1 + i][j] = if i == j {
                1.0 + d2[i] as f64 / 2.0 // insert node i + its edges
            } else {
                f64::INFINITY / 4.0
            };
        }
        for j in 0..n1 {
            cost[n1 + i][n2 + j] = 0.0; // dummy-dummy
        }
    }
    let (assignment, _) = hungarian(&cost);
    let mapping: Vec<Option<u16>> = (0..n1)
        .map(|i| {
            let j = assignment[i];
            if j < n2 {
                Some(j as u16)
            } else {
                None
            }
        })
        .collect();
    induced_cost(g1, g2, &mapping)
}

#[cfg(test)]
mod tests {
    use super::super::exact_ged;
    use super::*;
    use crate::graph::generate::{generate, perturb, Family};
    use crate::util::rng::Rng;

    #[test]
    fn hungarian_solves_known_assignment() {
        // cost = [[4,1,3],[2,0,5],[3,2,2]] -> optimal 1+2+2 = 5
        let c = vec![
            vec![4.0, 1.0, 3.0],
            vec![2.0, 0.0, 5.0],
            vec![3.0, 2.0, 2.0],
        ];
        let (a, total) = hungarian(&c);
        assert_eq!(total, 5.0);
        // assignment must be a permutation
        let mut seen = vec![false; 3];
        for &j in &a {
            assert!(!seen[j]);
            seen[j] = true;
        }
    }

    #[test]
    fn hungarian_identity_matrix() {
        let c = vec![
            vec![0.0, 1.0, 1.0],
            vec![1.0, 0.0, 1.0],
            vec![1.0, 1.0, 0.0],
        ];
        let (a, total) = hungarian(&c);
        assert_eq!(total, 0.0);
        assert_eq!(a, vec![0, 1, 2]);
    }

    #[test]
    fn upper_bounds_exact_ged() {
        let mut rng = Rng::new(121);
        for _ in 0..15 {
            let f = Family::ErdosRenyi { n: 6, p_millis: 300 };
            let a = generate(&mut rng, f, 8, 4);
            let k = rng.below(4);
            let b = perturb(&mut rng, &a, k, 8, 4);
            let exact = exact_ged(&a, &b, 2_000_000).unwrap();
            let hun = hungarian_ged(&a, &b);
            assert!(hun >= exact - 1e-9, "hungarian {hun} < exact {exact}");
        }
    }

    #[test]
    fn identical_graphs_cost_zero() {
        let mut rng = Rng::new(122);
        let g = generate(&mut rng, Family::ErdosRenyi { n: 7, p_millis: 300 }, 8, 4);
        assert_eq!(hungarian_ged(&g, &g), 0.0);
    }

    #[test]
    fn handles_size_mismatch() {
        let a = Graph::new(2, vec![(0, 1)], vec![1, 1]);
        let b = Graph::new(4, vec![(0, 1), (1, 2), (2, 3)], vec![1, 1, 1, 1]);
        let hun = hungarian_ged(&a, &b);
        let exact = exact_ged(&a, &b, 1_000_000).unwrap();
        assert!(hun >= exact - 1e-9);
        assert!(hun <= exact + 6.0, "hun {hun} far above exact {exact}");
    }
}
