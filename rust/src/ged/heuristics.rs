//! Classical GED approximations — the baselines the SimGNN paper itself
//! evaluates against (Beam search [GED literature the paper cites as
//! [46]/[75]], and a Hungarian-style greedy assignment). SPA-GCN
//! accelerates SimGNN; reproducing the *accuracy* context requires these
//! comparators so `report accuracy` can rank SimGNN vs classical
//! heuristics against exact GED on tiny graphs.

use crate::graph::Graph;

/// Cost of mapping g1 node i -> g2 node j given a (possibly partial)
/// prefix `mapping` (same semantics as the A* expansion step).
fn assign_cost(
    g1: &Graph,
    g2: &Graph,
    mapping: &[Option<u16>],
    i: usize,
    j: Option<u16>,
) -> f64 {
    let mut cost = match j {
        Some(j) => {
            if g1.labels()[i] == g2.labels()[j as usize] {
                0.0
            } else {
                1.0
            }
        }
        None => 1.0,
    };
    for (p, &mp) in mapping.iter().enumerate() {
        let e1 = g1.has_edge(p as u16, i as u16);
        let e2 = match (mp, j) {
            (Some(a), Some(b)) => g2.has_edge(a, b),
            _ => false,
        };
        if e1 != e2 {
            cost += 1.0;
        }
    }
    cost
}

/// Completion cost once all g1 nodes are decided: unused g2 nodes and
/// their incident edges are insertions.
fn completion_cost(g2: &Graph, mapping: &[Option<u16>]) -> f64 {
    let mut used = vec![false; g2.num_nodes()];
    for m in mapping.iter().flatten() {
        used[*m as usize] = true;
    }
    let mut cost = used.iter().filter(|&&u| !u).count() as f64;
    for &(a, b) in g2.edges() {
        if !used[a as usize] || !used[b as usize] {
            cost += 1.0;
        }
    }
    cost
}

/// Greedy assignment: each g1 node takes the locally-cheapest unused g2
/// node (or deletion). Fast upper bound; O(n^2) per node.
pub fn greedy_ged(g1: &Graph, g2: &Graph) -> f64 {
    if g1.num_nodes() > g2.num_nodes() {
        return greedy_ged(g2, g1);
    }
    let mut mapping: Vec<Option<u16>> = Vec::with_capacity(g1.num_nodes());
    let mut used = vec![false; g2.num_nodes()];
    let mut total = 0.0;
    for i in 0..g1.num_nodes() {
        let mut best: (f64, Option<u16>) = (assign_cost(g1, g2, &mapping, i, None), None);
        for j in 0..g2.num_nodes() {
            if used[j] {
                continue;
            }
            let c = assign_cost(g1, g2, &mapping, i, Some(j as u16));
            if c < best.0 {
                best = (c, Some(j as u16));
            }
        }
        total += best.0;
        if let Some(j) = best.1 {
            used[j as usize] = true;
        }
        mapping.push(best.1);
    }
    total + completion_cost(g2, &mapping)
}

/// Beam search over assignment prefixes with beam width `w` — the
/// "Beam" baseline from the GED literature (anytime upper bound;
/// exact when w is large enough).
pub fn beam_ged(g1: &Graph, g2: &Graph, w: usize) -> f64 {
    if g1.num_nodes() > g2.num_nodes() {
        return beam_ged(g2, g1, w);
    }
    // Clamp instead of asserting: this is the degraded-scoring fallback
    // path (`net/admission.rs`), and no caller-supplied width may panic
    // it. w = 0 behaves like the narrowest useful beam.
    let w = w.max(1);
    // Beam entries: (cost so far, mapping prefix).
    let mut beam: Vec<(f64, Vec<Option<u16>>)> = vec![(0.0, Vec::new())];
    for i in 0..g1.num_nodes() {
        let mut next: Vec<(f64, Vec<Option<u16>>)> = Vec::new();
        for (g, mapping) in &beam {
            let mut used = vec![false; g2.num_nodes()];
            for m in mapping.iter().flatten() {
                used[*m as usize] = true;
            }
            for j in 0..g2.num_nodes() {
                if used[j] {
                    continue;
                }
                let c = g + assign_cost(g1, g2, mapping, i, Some(j as u16));
                let mut m2 = mapping.clone();
                m2.push(Some(j as u16));
                next.push((c, m2));
            }
            let c = g + assign_cost(g1, g2, mapping, i, None);
            let mut m2 = mapping.clone();
            m2.push(None);
            next.push((c, m2));
        }
        // `total_cmp`: a NaN cost (impossible today, but this path must
        // stay panic-free) orders instead of panicking, and the *stable*
        // sort breaks cost ties by insertion index, so the surviving
        // beam — and therefore the returned bound — is deterministic.
        next.sort_by(|a, b| a.0.total_cmp(&b.0));
        next.truncate(w);
        beam = next;
    }
    beam.iter()
        .map(|(g, mapping)| g + completion_cost(g2, mapping))
        .fold(f64::INFINITY, f64::min)
}

#[cfg(test)]
mod tests {
    use super::super::exact_ged;
    use super::*;
    use crate::graph::generate::{generate, perturb, Family};
    use crate::util::rng::Rng;

    fn pair(rng: &mut Rng) -> (Graph, Graph) {
        let f = Family::ErdosRenyi { n: 6, p_millis: 300 };
        let a = generate(rng, f, 8, 4);
        let k = rng.below(4);
        let b = perturb(rng, &a, k, 8, 4);
        (a, b)
    }

    #[test]
    fn heuristics_upper_bound_exact() {
        let mut rng = Rng::new(91);
        for _ in 0..15 {
            let (a, b) = pair(&mut rng);
            let exact = exact_ged(&a, &b, 2_000_000).unwrap();
            let greedy = greedy_ged(&a, &b);
            let beam = beam_ged(&a, &b, 8);
            assert!(greedy >= exact - 1e-9, "greedy {greedy} < exact {exact}");
            assert!(beam >= exact - 1e-9, "beam {beam} < exact {exact}");
        }
    }

    #[test]
    fn beam_dominates_greedy_on_average_and_wide_beam_improves() {
        let mut rng = Rng::new(92);
        let mut greedy_sum = 0.0;
        let mut beam1_sum = 0.0;
        let mut beam16_sum = 0.0;
        for _ in 0..20 {
            let (a, b) = pair(&mut rng);
            greedy_sum += greedy_ged(&a, &b);
            beam1_sum += beam_ged(&a, &b, 1);
            beam16_sum += beam_ged(&a, &b, 16);
        }
        // beam(1) and greedy make the same local choices up to
        // tie-breaking (greedy prefers deletion on ties, beam prefers the
        // first substitution) — close but not identical in aggregate.
        assert!((beam1_sum - greedy_sum).abs() <= 0.25 * greedy_sum + 1e-6);
        // a wide beam is never worse than the width-1 beam on average.
        assert!(beam16_sum <= beam1_sum + 1e-9);
    }

    #[test]
    fn identical_graphs_cost_zero() {
        let mut rng = Rng::new(93);
        let (a, _) = pair(&mut rng);
        assert_eq!(greedy_ged(&a, &a), 0.0);
        assert_eq!(beam_ged(&a, &a, 4), 0.0);
    }

    #[test]
    fn beam_is_deterministic_and_clamps_width() {
        // Tie-heavy inputs: uniform labels and no edges make every
        // assignment prefix cost the same, so a nondeterministic
        // tie-break would shuffle the beam. The bound must come out
        // bit-identical across repeated calls and must equal the pure
        // insertion cost |n2 - n1|.
        let a = Graph::new(3, vec![], vec![1, 1, 1]);
        let b = Graph::new(5, vec![], vec![1, 1, 1, 1, 1]);
        let first = beam_ged(&a, &b, 4);
        assert_eq!(first, 2.0);
        for _ in 0..10 {
            assert_eq!(beam_ged(&a, &b, 4).to_bits(), first.to_bits());
        }
        // Width 0 clamps to 1 instead of panicking the degraded path.
        assert_eq!(beam_ged(&a, &b, 0).to_bits(), beam_ged(&a, &b, 1).to_bits());
        // Tied costs with real structure: repeated calls stay stable.
        let mut rng = Rng::new(95);
        let (x, y) = pair(&mut rng);
        let r = beam_ged(&x, &y, 6);
        for _ in 0..5 {
            assert_eq!(beam_ged(&x, &y, 6).to_bits(), r.to_bits());
        }
    }

    #[test]
    fn wide_beam_recovers_exact_on_tiny_graphs() {
        let mut rng = Rng::new(94);
        let f = Family::ErdosRenyi { n: 4, p_millis: 300 };
        for _ in 0..10 {
            let a = generate(&mut rng, f, 8, 3);
            let b = generate(&mut rng, f, 8, 3);
            let exact = exact_ged(&a, &b, 2_000_000).unwrap();
            let beam = beam_ged(&a, &b, 64);
            assert!(
                (beam - exact).abs() < 1e-9 || beam >= exact,
                "beam {beam} vs exact {exact}"
            );
        }
    }
}
