//! Exact Graph Edit Distance for tiny graphs.
//!
//! SimGNN's whole point (paper §1) is that exact GED is NP-complete and
//! intractable beyond ~10 nodes; the network learns to approximate it.
//! To *evaluate* that approximation (examples/ged_search.rs) we need the
//! exact value on small graphs, so this module implements the standard
//! A* search over node-assignment prefixes with an admissible label-
//! mismatch lower bound (uniform cost model: node substitution/insertion/
//! deletion and edge insertion/deletion all cost 1 — the cost model used
//! by the GED literature the paper cites [46, 75] and by SimGNN's AIDS
//! benchmarks).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::graph::Graph;

/// Search node: a prefix assignment of g1 nodes to g2 nodes (or deletion).
#[derive(Debug, Clone)]
struct State {
    /// mapping[i] = Some(j): g1 node i -> g2 node j; None = deleted.
    mapping: Vec<Option<u16>>,
    g: f64,
    f: f64,
    /// Terminal state: `g` already includes the completion cost (insertion
    /// of unused g2 nodes and their edges). A* may only return when it
    /// POPS a terminal state — returning at first complete mapping would
    /// be unsound because completion adds cost beyond the popped `f`.
    done: bool,
}

impl PartialEq for State {
    fn eq(&self, other: &Self) -> bool {
        self.f == other.f
    }
}
impl Eq for State {}
impl PartialOrd for State {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for State {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap on f
        other.f.partial_cmp(&self.f).unwrap_or(Ordering::Equal)
    }
}

/// Edge-cost contribution of assigning g1 node `i` -> `j` given the
/// existing prefix: for every already-mapped neighbor relation, edges must
/// match or cost 1 each.
fn edge_delta(g1: &Graph, g2: &Graph, mapping: &[Option<u16>], i: usize, j: Option<u16>) -> f64 {
    let mut cost = 0.0;
    for (p, &mp) in mapping.iter().enumerate() {
        let e1 = g1.has_edge(p as u16, i as u16);
        let e2 = match (mp, j) {
            (Some(a), Some(b)) => g2.has_edge(a, b),
            _ => false,
        };
        if e1 != e2 {
            cost += 1.0;
        }
    }
    cost
}

/// Admissible lower bound for the unmapped remainder: label-multiset
/// mismatch between g1's unassigned nodes and g2's unused nodes, plus the
/// node-count difference. (Ignores edges entirely, hence admissible.)
fn remainder_lb(g1: &Graph, g2: &Graph, mapping: &[Option<u16>]) -> f64 {
    let assigned = mapping.len();
    let mut used = vec![false; g2.num_nodes()];
    for m in mapping.iter().flatten() {
        used[*m as usize] = true;
    }
    let mut c1 = std::collections::HashMap::<u16, i64>::new();
    for i in assigned..g1.num_nodes() {
        *c1.entry(g1.labels()[i]).or_default() += 1;
    }
    let mut c2 = std::collections::HashMap::<u16, i64>::new();
    for (j, &u) in used.iter().enumerate() {
        if !u {
            *c2.entry(g2.labels()[j]).or_default() += 1;
        }
    }
    let n1 = (g1.num_nodes() - assigned) as i64;
    let n2: i64 = c2.values().sum();
    // Max-matching on labels: matched same-label pairs cost 0, other
    // matched pairs cost 1 (substitution), unmatched cost 1 (ins/del).
    let mut same = 0i64;
    for (lab, &a) in &c1 {
        if let Some(&b) = c2.get(lab) {
            same += a.min(b);
        }
    }
    let matched = n1.min(n2);
    let substitutions = matched - same.min(matched);
    let insdel = (n1 - n2).abs();
    (substitutions + insdel) as f64
}

/// Exact GED via A*. `limit` bounds the expanded-state count; returns None
/// if exceeded (caller should fall back to an approximation).
pub fn exact_ged(g1: &Graph, g2: &Graph, limit: usize) -> Option<f64> {
    // Order so the outer (assigned) graph is the smaller one: fewer levels.
    if g1.num_nodes() > g2.num_nodes() {
        return exact_ged(g2, g1, limit);
    }
    let mut heap = BinaryHeap::new();
    heap.push(State {
        mapping: Vec::new(),
        g: 0.0,
        f: remainder_lb(g1, g2, &[]),
        done: false,
    });
    let mut expanded = 0usize;
    while let Some(state) = heap.pop() {
        expanded += 1;
        if expanded > limit {
            return None;
        }
        if state.done {
            return Some(state.g);
        }
        let i = state.mapping.len();
        if i == g1.num_nodes() {
            // All g1 nodes decided; remaining g2 nodes are insertions, and
            // their incident edges (to used nodes or each other) too.
            // Re-queue as a terminal state: it may only win when its TOTAL
            // cost is minimal among all frontier states.
            let mut used = vec![false; g2.num_nodes()];
            for m in state.mapping.iter().flatten() {
                used[*m as usize] = true;
            }
            let mut cost = state.g;
            for j in 0..g2.num_nodes() {
                if !used[j] {
                    cost += 1.0; // node insertion
                }
            }
            for &(a, b) in g2.edges() {
                if !used[a as usize] || !used[b as usize] {
                    cost += 1.0; // edge insertion
                }
            }
            heap.push(State {
                mapping: state.mapping,
                g: cost,
                f: cost,
                done: true,
            });
            continue;
        }
        // Option A: substitute i -> each unused j.
        let mut used = vec![false; g2.num_nodes()];
        for m in state.mapping.iter().flatten() {
            used[*m as usize] = true;
        }
        for j in 0..g2.num_nodes() {
            if used[j] {
                continue;
            }
            let label_cost = if g1.labels()[i] == g2.labels()[j] {
                0.0
            } else {
                1.0
            };
            let g = state.g + label_cost + edge_delta(g1, g2, &state.mapping, i, Some(j as u16));
            let mut mapping = state.mapping.clone();
            mapping.push(Some(j as u16));
            let f = g + remainder_lb(g1, g2, &mapping);
            heap.push(State { mapping, g, f, done: false });
        }
        // Option B: delete node i (plus its edges to mapped prefix).
        let g = state.g + 1.0 + edge_delta(g1, g2, &state.mapping, i, None);
        let mut mapping = state.mapping.clone();
        mapping.push(None);
        let f = g + remainder_lb(g1, g2, &mapping);
        heap.push(State { mapping, g, f, done: false });
    }
    None
}

/// Normalized similarity from an edit distance, the SimGNN target:
/// exp(-2 GED / (|V1| + |V2|)).
pub fn ged_similarity(ged: f64, n1: usize, n2: usize) -> f64 {
    (-2.0 * ged / (n1 + n2) as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{generate, perturb, Family};
    use crate::util::rng::Rng;

    fn g(n: usize, edges: &[(u16, u16)], labels: &[u16]) -> Graph {
        Graph::new(n, edges.to_vec(), labels.to_vec())
    }

    #[test]
    fn identical_graphs_have_zero_ged() {
        let a = g(4, &[(0, 1), (1, 2), (2, 3)], &[1, 2, 3, 4]);
        assert_eq!(exact_ged(&a, &a, 100_000), Some(0.0));
    }

    #[test]
    fn single_relabel_costs_one() {
        let a = g(3, &[(0, 1), (1, 2)], &[1, 2, 3]);
        let b = g(3, &[(0, 1), (1, 2)], &[1, 2, 9]);
        assert_eq!(exact_ged(&a, &b, 100_000), Some(1.0));
    }

    #[test]
    fn single_edge_delete_costs_one() {
        let a = g(3, &[(0, 1), (1, 2), (0, 2)], &[1, 1, 1]);
        let b = g(3, &[(0, 1), (1, 2)], &[1, 1, 1]);
        assert_eq!(exact_ged(&a, &b, 100_000), Some(1.0));
    }

    #[test]
    fn node_insert_with_edge_costs_two() {
        let a = g(2, &[(0, 1)], &[1, 1]);
        let b = g(3, &[(0, 1), (1, 2)], &[1, 1, 1]);
        // insert node (1) + insert edge (1)
        assert_eq!(exact_ged(&a, &b, 100_000), Some(2.0));
    }

    #[test]
    fn ged_is_symmetric() {
        let mut rng = Rng::new(41);
        for _ in 0..5 {
            let a = generate(&mut rng, Family::ErdosRenyi { n: 6, p_millis: 300 }, 8, 4);
            let b = generate(&mut rng, Family::ErdosRenyi { n: 7, p_millis: 300 }, 8, 4);
            let ab = exact_ged(&a, &b, 500_000);
            let ba = exact_ged(&b, &a, 500_000);
            assert_eq!(ab, ba);
        }
    }

    #[test]
    fn perturbation_upper_bounds_ged() {
        let mut rng = Rng::new(42);
        for _ in 0..10 {
            let a = generate(&mut rng, Family::ErdosRenyi { n: 6, p_millis: 250 }, 8, 4);
            let k = rng.below(4);
            let b = perturb(&mut rng, &a, k, 8, 4);
            if let Some(d) = exact_ged(&a, &b, 500_000) {
                // each perturbation op costs at most 2 (node insert = node+edge)
                assert!(
                    d <= 2.0 * k as f64 + 1e-9,
                    "ged {d} exceeds bound for k={k}"
                );
            }
        }
    }

    #[test]
    fn triangle_inequality_on_small_samples() {
        let mut rng = Rng::new(43);
        let f = Family::ErdosRenyi { n: 5, p_millis: 300 };
        for _ in 0..5 {
            let a = generate(&mut rng, f, 8, 3);
            let b = generate(&mut rng, f, 8, 3);
            let c = generate(&mut rng, f, 8, 3);
            let ab = exact_ged(&a, &b, 500_000).unwrap();
            let bc = exact_ged(&b, &c, 500_000).unwrap();
            let ac = exact_ged(&a, &c, 500_000).unwrap();
            assert!(ac <= ab + bc + 1e-9, "triangle violated: {ac} > {ab}+{bc}");
        }
    }

    #[test]
    fn similarity_normalization() {
        assert_eq!(ged_similarity(0.0, 5, 5), 1.0);
        assert!(ged_similarity(5.0, 5, 5) < 0.4);
    }
}

pub mod heuristics;
pub mod hungarian;
