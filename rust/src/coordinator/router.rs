//! Admission control + worker routing.
//!
//! The router validates queries against the artifact shape limits (the
//! fixed n_max/num_labels the AOT HLO was compiled for — oversize graphs
//! must be rejected, not silently truncated) and distributes admitted
//! queries round-robin across worker queues.

use std::sync::mpsc::SyncSender;

use crate::graph::Graph;
use crate::nn::config::ModelConfig;

use super::query::{Outcome, Query, QueryResult, RejectReason};

/// Validate a query against the model's static shapes.
pub fn validate(cfg: &ModelConfig, g1: &Graph, g2: &Graph) -> Result<(), RejectReason> {
    for g in [g1, g2] {
        if g.num_nodes() > cfg.n_max {
            return Err(RejectReason::TooManyNodes {
                nodes: g.num_nodes(),
                n_max: cfg.n_max,
            });
        }
        if let Some(&bad) = g.labels().iter().find(|&&l| (l as usize) >= cfg.num_labels) {
            return Err(RejectReason::LabelOutOfRange {
                label: bad,
                num_labels: cfg.num_labels,
            });
        }
    }
    Ok(())
}

/// Round-robin router over worker input queues.
pub struct Router {
    cfg: ModelConfig,
    workers: Vec<SyncSender<Query>>,
    next: usize,
    pub admitted: u64,
    pub rejected: u64,
}

impl Router {
    pub fn new(cfg: ModelConfig, workers: Vec<SyncSender<Query>>) -> Self {
        assert!(!workers.is_empty(), "router needs at least one worker");
        Router {
            cfg,
            workers,
            next: 0,
            admitted: 0,
            rejected: 0,
        }
    }

    /// Route one query; invalid queries produce an immediate rejection
    /// result instead of reaching a worker.
    pub fn route(&mut self, q: Query) -> Option<QueryResult> {
        if let Err(reason) = validate(&self.cfg, &q.g1, &q.g2) {
            self.rejected += 1;
            return Some(QueryResult {
                id: q.id,
                outcome: Outcome::Rejected(reason),
                latency_us: q.submitted.elapsed().as_secs_f64() * 1e6,
                batch_size: 0,
            });
        }
        let w = self.next;
        self.next = (self.next + 1) % self.workers.len();
        self.admitted += 1;
        if self.workers[w].send(q).is_err() {
            // Worker gone (shutdown race): surface as engine error.
            self.admitted -= 1;
            self.rejected += 1;
            return Some(QueryResult {
                id: u64::MAX,
                outcome: Outcome::Rejected(RejectReason::ShuttingDown),
                latency_us: 0.0,
                batch_size: 0,
            });
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    fn cfg() -> ModelConfig {
        ModelConfig {
            n_max: 8,
            num_labels: 4,
            ..ModelConfig::default()
        }
    }

    fn graph(n: usize, label: u16) -> Graph {
        Graph::new(n, (1..n).map(|v| (0u16, v as u16)).collect(), vec![label; n])
    }

    #[test]
    fn validate_rejects_oversize() {
        let c = cfg();
        let ok = graph(5, 1);
        let big = graph(12, 1);
        assert!(validate(&c, &ok, &ok).is_ok());
        assert!(matches!(
            validate(&c, &ok, &big),
            Err(RejectReason::TooManyNodes { .. })
        ));
        let badlabel = graph(4, 9);
        assert!(matches!(
            validate(&c, &badlabel, &ok),
            Err(RejectReason::LabelOutOfRange { .. })
        ));
    }

    #[test]
    fn round_robin_distribution() {
        let (tx1, rx1) = sync_channel(16);
        let (tx2, rx2) = sync_channel(16);
        let mut r = Router::new(cfg(), vec![tx1, tx2]);
        for i in 0..6 {
            let g = graph(4, 1);
            assert!(r.route(Query::new(i, g.clone(), g)).is_none());
        }
        assert_eq!(r.admitted, 6);
        let c1 = rx1.try_iter().count();
        let c2 = rx2.try_iter().count();
        assert_eq!((c1, c2), (3, 3));
    }

    #[test]
    fn invalid_query_rejected_inline() {
        let (tx, _rx) = sync_channel(4);
        let mut r = Router::new(cfg(), vec![tx]);
        let g = graph(4, 1);
        let big = graph(20, 1);
        let res = r.route(Query::new(7, g, big)).expect("rejection");
        assert!(res.is_rejected());
        assert_eq!(res.id, 7);
        assert_eq!(r.rejected, 1);
    }
}
