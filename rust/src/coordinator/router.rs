//! Admission control (the pipeline's first stage), the per-lane
//! capability handshake, and fan-out of released batches across the
//! worker encode/execute lanes.
//!
//! Admission validates queries against the artifact shape limits (the
//! fixed n_max/num_labels the AOT HLO was compiled for — oversize graphs
//! must be rejected, not silently truncated) before they ever enter the
//! pipeline; rejects flow straight to the responder stage.
//!
//! Each worker lane publishes its engine's [`EngineCaps`] (or the typed
//! construction error) through a [`LaneCaps`] cell once the executor has
//! built its engine in-thread. The encoder blocks on it to learn the
//! batch ladder; the [`CapsRouter`] peeks at it to steer released
//! batches away from lanes whose engines are known-dead — so a mixed
//! `native,sim` deployment keeps serving even if one backend's
//! artifacts are missing.

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};

use crate::graph::encode::CheapSignals;
use crate::graph::Graph;
use crate::nn::config::ModelConfig;
use crate::runtime::{EngineCaps, EngineError};

use super::channel::{NamedSender, SendResult};
use super::query::{CascadeMode, Query, QueryPayload, QueryResult, RejectReason};

/// Validate one graph against the model's static shapes. Public so the
/// net front stage (`net/admission.rs`) can apply the *same* gate to
/// wire graphs before any scoring lane — including the degraded GED
/// fallback, which never reaches this pipeline stage.
pub fn validate_graph(cfg: &ModelConfig, g: &Graph) -> Result<(), RejectReason> {
    if g.num_nodes() > cfg.n_max {
        return Err(RejectReason::TooManyNodes {
            nodes: g.num_nodes(),
            n_max: cfg.n_max,
        });
    }
    if let Some(&bad) = g.labels().iter().find(|&&l| (l as usize) >= cfg.num_labels) {
        return Err(RejectReason::LabelOutOfRange {
            label: bad,
            num_labels: cfg.num_labels,
        });
    }
    Ok(())
}

/// Validate a pair query against the model's static shapes.
pub fn validate(cfg: &ModelConfig, g1: &Graph, g2: &Graph) -> Result<(), RejectReason> {
    validate_graph(cfg, g1)?;
    validate_graph(cfg, g2)
}

/// Validate any payload: pair queries check both graphs; top-k queries
/// check the query graph, reject rankings over an empty corpus, and
/// reject a corpus encoded for different shapes than the serving model
/// (its padded tensors would be indexed with the wrong strides — a
/// lane panic at best, silently wrong scores at worst).
pub fn validate_payload(cfg: &ModelConfig, payload: &QueryPayload) -> Result<(), RejectReason> {
    match payload {
        QueryPayload::Pair { g1, g2 } => validate(cfg, g1, g2),
        QueryPayload::TopK { graph, corpus, .. } => {
            if corpus.is_empty() {
                return Err(RejectReason::EmptyCorpus);
            }
            if corpus.n_max() != cfg.n_max || corpus.num_labels() != cfg.num_labels {
                return Err(RejectReason::CorpusShapeMismatch {
                    corpus: (corpus.n_max(), corpus.num_labels()),
                    model: (cfg.n_max, cfg.num_labels),
                });
            }
            validate_graph(cfg, graph)
        }
    }
}

/// Admission-stage state: shape validation against the artifact limits.
/// (Admit/reject counts live in `Metrics`, fed by the responder — no
/// duplicate bookkeeping here.)
#[derive(Debug)]
pub struct Admission {
    cfg: ModelConfig,
}

impl Admission {
    /// Admission against `cfg`'s fixed shapes.
    pub fn new(cfg: ModelConfig) -> Self {
        Admission { cfg }
    }

    /// Admit one query, or return the rejection result to send to the
    /// responder. For a `Budgeted` top-k query this is also where the
    /// cascade's coarse stage runs — once, against the same snapshot
    /// the exact stage will score, before the query is ever enqueued —
    /// so every downstream shard reads one shared [`PrunePlan`] and
    /// the in-process and network paths prune identically.
    pub fn admit(&self, q: Query) -> Result<Query, QueryResult> {
        if let Err(reason) = validate_payload(&self.cfg, &q.payload) {
            return Err(QueryResult::rejected(&q, reason));
        }
        let mut q = q;
        if let QueryPayload::TopK {
            graph,
            corpus,
            mode: CascadeMode::Budgeted { budget },
            prune,
            ..
        } = &mut q.payload
        {
            if prune.is_none() {
                let signals = CheapSignals::from_graph(graph, corpus.num_labels());
                *prune = Some(Arc::new(corpus.prune(&signals, *budget)));
            }
        }
        Ok(q)
    }
}

/// One lane's capability handshake: the executor publishes its engine's
/// [`EngineCaps`] (or the construction [`EngineError`]) exactly once;
/// the encoder blocks on [`LaneCaps::wait`], the router and the final
/// metrics snapshot read it non-blockingly via [`LaneCaps::get`].
#[derive(Debug)]
pub struct LaneCaps {
    state: Mutex<Option<Result<EngineCaps, EngineError>>>,
    ready: Condvar,
}

impl LaneCaps {
    /// An unset cell, shared between a lane's stages.
    pub fn new() -> Arc<Self> {
        Arc::new(LaneCaps {
            state: Mutex::new(None),
            ready: Condvar::new(),
        })
    }

    /// Publish the lane's outcome. First set wins; later calls (e.g. the
    /// executor's panic guard after a normal set) are ignored.
    pub fn set(&self, outcome: Result<EngineCaps, EngineError>) {
        let mut state = self.state.lock().expect("LaneCaps lock poisoned");
        if state.is_none() {
            *state = Some(outcome);
            self.ready.notify_all();
        }
    }

    /// Block until the lane has published, then return a copy.
    pub fn wait(&self) -> Result<EngineCaps, EngineError> {
        let mut state = self.state.lock().expect("LaneCaps lock poisoned");
        loop {
            if let Some(outcome) = state.as_ref() {
                return outcome.clone();
            }
            state = self.ready.wait(state).expect("LaneCaps lock poisoned");
        }
    }

    /// Non-blocking read: `None` while the engine is still constructing.
    pub fn get(&self) -> Option<Result<EngineCaps, EngineError>> {
        self.state.lock().expect("LaneCaps lock poisoned").clone()
    }

    /// True once the lane is known to have no working engine.
    pub fn known_failed(&self) -> bool {
        matches!(
            self.state.lock().expect("LaneCaps lock poisoned").as_ref(),
            Some(Err(_))
        )
    }

    /// True when the lane has published working caps satisfying `pred`
    /// — evaluated under the lock, no [`EngineCaps`] clone (the
    /// router's steady-state dispatch probe).
    pub fn satisfies(&self, pred: impl Fn(&EngineCaps) -> bool) -> bool {
        matches!(
            self.state.lock().expect("LaneCaps lock poisoned").as_ref(),
            Some(Ok(caps)) if pred(caps)
        )
    }

    /// True while the lane has not yet published any outcome (its
    /// engine is still constructing).
    pub fn is_unset(&self) -> bool {
        self.state.lock().expect("LaneCaps lock poisoned").is_none()
    }
}

/// Caps-aware round-robin dispatcher over the worker lanes. Healthy (or
/// not-yet-known) lanes take traffic in rotation; lanes whose engine
/// construction is known to have failed are skipped while any
/// alternative exists. If every lane is dead the batch still goes to one
/// of them, whose drain answers each query with the typed construction
/// error — results are reported, never silently dropped.
pub struct CapsRouter<T> {
    lanes: Vec<(NamedSender<T>, Arc<LaneCaps>)>,
    next: usize,
}

// Manual impl: no `T: Debug` bound — the router's identity is its lane
// set and cursor, not the queued payloads.
impl<T> std::fmt::Debug for CapsRouter<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("CapsRouter")
            .field("lanes", &self.lanes.len())
            .field("next", &self.next)
            .finish()
    }
}

impl<T> CapsRouter<T> {
    /// Route over `lanes` (sender + that lane's caps cell). Panics on an
    /// empty lane set.
    pub fn new(lanes: Vec<(NamedSender<T>, Arc<LaneCaps>)>) -> Self {
        assert!(!lanes.is_empty(), "router needs at least one lane");
        CapsRouter { lanes, next: 0 }
    }

    /// Number of lanes (dead or alive).
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Number of lanes whose *published* caps satisfy `pred`. Lanes
    /// still constructing do not count: a scatter must know its fan-out
    /// before splitting, so during the startup window corpus queries
    /// take the whole-query path instead of guessing at lane counts.
    pub fn count_satisfying(&self, pred: impl Fn(&EngineCaps) -> bool + Copy) -> usize {
        self.lanes.iter().filter(|(_, lc)| lc.satisfies(pred)).count()
    }

    /// Among lanes whose *published* caps satisfy `pred`, the engine
    /// name with the most lanes, and that count. Scatter sizing wants
    /// the largest *same-kind* pool — shards of one query must land on
    /// identical engines, because per-shard telemetry is
    /// policy-specific (a `native` shard's executed-work MacCounts
    /// summed with a `native-dense` shard's padded-schedule counts
    /// would corrupt the per-engine comparison rows the metrics keep
    /// apart). Ties break toward the lexicographically smaller name so
    /// the choice is deterministic.
    pub fn largest_cohort(
        &self,
        pred: impl Fn(&EngineCaps) -> bool + Copy,
    ) -> Option<(String, usize)> {
        let mut counts: BTreeMap<String, usize> = BTreeMap::new();
        for (_, lc) in &self.lanes {
            if let Some(Ok(caps)) = lc.get() {
                if pred(&caps) {
                    *counts.entry(caps.name).or_insert(0) += 1;
                }
            }
        }
        // BTreeMap iterates names ascending; strict `>` keeps the
        // smallest name among equal counts.
        let mut best: Option<(String, usize)> = None;
        for (name, n) in counts {
            if best.as_ref().is_none_or(|(_, b)| n > *b) {
                best = Some((name, n));
            }
        }
        best
    }

    /// Dispatch to the next healthy (or still-constructing) lane in
    /// strict rotation; fall back to any lane when all are known-failed
    /// (their drains report the error per query).
    pub fn send(&mut self, v: T) -> SendResult<T> {
        match self.try_rotation(v, |lc| !lc.known_failed()) {
            Ok(delivered) => delivered,
            Err(v) => self.try_rotation(v, |_| true).unwrap_or_else(SendResult::Disconnected),
        }
    }

    /// Dispatch preferring lanes whose *published* caps satisfy `pred`,
    /// then lanes still constructing (their caps may turn out to
    /// satisfy it — only the startup window before any capable lane
    /// has published can misroute), and finally anyone, so the
    /// executor/drain answers each query with a typed error — work is
    /// reported, never silently dropped. Used to keep top-k work off
    /// lanes whose engines lack corpus support.
    pub fn send_filtered(
        &mut self,
        v: T,
        pred: impl Fn(&EngineCaps) -> bool + Copy,
    ) -> SendResult<T> {
        // Pass 1: published-and-satisfying. Pass 2: still unknown.
        // Pass 3: unconditional fallback.
        let v = match self.try_rotation(v, |lc| lc.satisfies(pred)) {
            Ok(delivered) => return delivered,
            Err(v) => v,
        };
        let v = match self.try_rotation(v, |lc| lc.is_unset()) {
            Ok(delivered) => return delivered,
            Err(v) => v,
        };
        self.try_rotation(v, |_| true).unwrap_or_else(SendResult::Disconnected)
    }

    /// One rotation over all lanes starting at `self.next`, offering
    /// the value to every lane whose caps cell passes `eligible`.
    /// `Err(v)` hands the value back if nobody accepted it.
    fn try_rotation(
        &mut self,
        mut v: T,
        eligible: impl Fn(&LaneCaps) -> bool,
    ) -> Result<SendResult<T>, T> {
        for _ in 0..self.lanes.len() {
            let lane = self.next;
            self.next = (self.next + 1) % self.lanes.len();
            if !eligible(&self.lanes[lane].1) {
                continue;
            }
            match self.lanes[lane].0.send(v) {
                SendResult::Disconnected(back) => v = back,
                delivered => return Ok(delivered),
            }
        }
        Err(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::channel::{channel, SendPolicy};

    fn cfg() -> ModelConfig {
        ModelConfig {
            n_max: 8,
            num_labels: 4,
            ..ModelConfig::default()
        }
    }

    fn graph(n: usize, label: u16) -> Graph {
        Graph::new(n, (1..n).map(|v| (0u16, v as u16)).collect(), vec![label; n])
    }

    fn caps(name: &str) -> EngineCaps {
        EngineCaps::new(name, vec![1, 4], 8, 4)
    }

    #[test]
    fn validate_rejects_oversize() {
        let c = cfg();
        let ok = graph(5, 1);
        let big = graph(12, 1);
        assert!(validate(&c, &ok, &ok).is_ok());
        assert!(matches!(
            validate(&c, &ok, &big),
            Err(RejectReason::TooManyNodes { .. })
        ));
        let badlabel = graph(4, 9);
        assert!(matches!(
            validate(&c, &badlabel, &ok),
            Err(RejectReason::LabelOutOfRange { .. })
        ));
    }

    #[test]
    fn admission_rejects_inline_with_query_identity() {
        let adm = Admission::new(cfg());
        let g = graph(4, 1);
        let big = graph(20, 1);
        assert!(adm.admit(Query::new(1, g.clone(), g.clone())).is_ok());
        let res = adm.admit(Query::new(7, g, big)).unwrap_err();
        assert!(res.is_rejected());
        assert_eq!(res.id, 7);
    }

    #[test]
    fn admission_validates_topk_payloads() {
        use super::super::corpus::Corpus;
        use super::super::query::RejectReason;
        let adm = Admission::new(cfg());
        let g = graph(4, 1);
        let corpus = Arc::new(
            Corpus::build("c", &[(0, g.clone()), (1, graph(3, 2))], 8, 4).unwrap(),
        );
        assert!(adm.admit(Query::topk(1, g.clone(), Arc::clone(&corpus), 5)).is_ok());
        // Oversize query graph is rejected like a pair graph.
        let res = adm.admit(Query::topk(2, graph(20, 1), Arc::clone(&corpus), 5)).unwrap_err();
        assert!(res.is_rejected());
        // An empty corpus has nothing to rank.
        let empty = Arc::new(Corpus::build("e", &[], 8, 4).unwrap());
        let res = adm.admit(Query::topk(3, g.clone(), empty, 5)).unwrap_err();
        assert!(matches!(
            res.outcome,
            super::super::query::Outcome::Rejected(RejectReason::EmptyCorpus)
        ));
        // A corpus encoded for different artifact shapes than the
        // serving model must be rejected, not scored with mismatched
        // tensor strides.
        let mismatched = Arc::new(
            Corpus::build("wide", &[(0, graph(3, 1))], 16, 4).unwrap(),
        );
        let res = adm.admit(Query::topk(4, g, mismatched, 5)).unwrap_err();
        assert!(matches!(
            res.outcome,
            super::super::query::Outcome::Rejected(RejectReason::CorpusShapeMismatch {
                corpus: (16, 4),
                model: (8, 4),
            })
        ));
    }

    #[test]
    fn admission_computes_the_prune_plan_for_budgeted_queries() {
        use super::super::corpus::Corpus;
        use super::super::query::CascadeMode;
        let adm = Admission::new(cfg());
        let entries: Vec<(u64, Graph)> = (0..6)
            .map(|i| (i as u64, graph(2 + (i as usize) / 2, 1)))
            .collect();
        let corpus = Arc::new(Corpus::build("c", &entries, 8, 4).unwrap());
        // Exact queries pass through untouched — no plan, no pruning.
        let q = adm
            .admit(Query::topk(1, graph(2, 1), Arc::clone(&corpus), 3))
            .unwrap();
        match &q.payload {
            QueryPayload::TopK { mode, prune, .. } => {
                assert_eq!(*mode, CascadeMode::Exact);
                assert!(prune.is_none());
            }
            other => panic!("expected TopK, got {other:?}"),
        }
        // Budgeted queries get their coarse verdict here, once.
        let q = adm
            .admit(Query::topk_with(
                2,
                graph(2, 1),
                Arc::clone(&corpus),
                3,
                CascadeMode::Budgeted { budget: 2 },
            ))
            .unwrap();
        match &q.payload {
            QueryPayload::TopK { prune, .. } => {
                let plan = prune.as_ref().expect("admission fills the plan");
                assert_eq!(plan.survivors, 2);
                assert_eq!(plan.pruned, 4);
                // The 2-node candidates (ids 0, 1) are nearest the
                // 2-node query.
                assert_eq!(plan.keep[..3], [true, true, false]);
            }
            other => panic!("expected TopK, got {other:?}"),
        }
        // Validation still runs first: a budgeted query against an
        // empty corpus is rejected before any pruning.
        let empty = Arc::new(Corpus::build("e", &[], 8, 4).unwrap());
        let res = adm
            .admit(Query::topk_with(
                3,
                graph(2, 1),
                empty,
                3,
                CascadeMode::Budgeted { budget: 2 },
            ))
            .unwrap_err();
        assert!(res.is_rejected());
    }

    #[test]
    fn lane_caps_first_set_wins_and_wait_returns_it() {
        let lc = LaneCaps::new();
        assert_eq!(lc.get(), None);
        assert!(!lc.known_failed());
        assert!(lc.is_unset());
        assert!(!lc.satisfies(|_| true), "unset lane satisfies nothing");
        lc.set(Ok(caps("a")));
        assert!(!lc.is_unset());
        assert!(lc.satisfies(|c| c.name == "a"));
        assert!(!lc.satisfies(|c| c.supports_corpus));
        lc.set(Err(EngineError::Unavailable { reason: "late".into() }));
        assert_eq!(lc.wait().unwrap().name, "a");
        assert!(!lc.known_failed());

        let dead = LaneCaps::new();
        dead.set(Err(EngineError::Unavailable { reason: "no backend".into() }));
        assert!(dead.known_failed());
        assert!(dead.wait().is_err());
    }

    #[test]
    fn lane_caps_wait_blocks_until_published() {
        let lc = LaneCaps::new();
        let waiter = {
            let lc = Arc::clone(&lc);
            std::thread::spawn(move || lc.wait().unwrap().name)
        };
        std::thread::sleep(std::time::Duration::from_millis(10));
        lc.set(Ok(caps("published")));
        assert_eq!(waiter.join().unwrap(), "published");
    }

    #[test]
    fn caps_router_distributes_round_robin_across_healthy_lanes() {
        let (tx1, rx1) = channel::<u64>("lane.0", 16, SendPolicy::Block);
        let (tx2, rx2) = channel::<u64>("lane.1", 16, SendPolicy::Block);
        let (c1, c2) = (LaneCaps::new(), LaneCaps::new());
        c1.set(Ok(caps("a")));
        c2.set(Ok(caps("b")));
        let mut router = CapsRouter::new(vec![(tx1, c1), (tx2, c2)]);
        assert_eq!(router.lanes(), 2);
        for i in 0..6 {
            assert!(router.send(i).is_sent());
        }
        let drain = |rx: &super::super::channel::NamedReceiver<u64>| {
            let mut got = Vec::new();
            while let Ok(v) = rx.try_recv() {
                got.push(v);
            }
            got
        };
        assert_eq!(drain(&rx1), vec![0, 2, 4]);
        assert_eq!(drain(&rx2), vec![1, 3, 5]);
    }

    #[test]
    fn caps_router_skips_disconnected_lanes() {
        let (tx1, rx1) = channel::<u64>("lane.0", 16, SendPolicy::Block);
        let (tx2, rx2) = channel::<u64>("lane.1", 16, SendPolicy::Block);
        let mut router = CapsRouter::new(vec![(tx1, LaneCaps::new()), (tx2, LaneCaps::new())]);
        drop(rx1);
        for i in 0..4 {
            assert!(router.send(i).is_sent(), "live lane must absorb traffic");
        }
        let mut got = Vec::new();
        while let Ok(v) = rx2.try_recv() {
            got.push(v);
        }
        assert_eq!(got, vec![0, 1, 2, 3]);
        drop(rx2);
        assert!(matches!(router.send(9), SendResult::Disconnected(9)));
    }

    #[test]
    fn caps_router_avoids_known_failed_lanes() {
        let (tx1, rx1) = channel::<u64>("lane.0", 16, SendPolicy::Block);
        let (tx2, rx2) = channel::<u64>("lane.1", 16, SendPolicy::Block);
        let (dead, healthy) = (LaneCaps::new(), LaneCaps::new());
        dead.set(Err(EngineError::Unavailable { reason: "no artifacts".into() }));
        healthy.set(Ok(caps("ok")));
        let mut router = CapsRouter::new(vec![(tx1, dead), (tx2, healthy)]);
        for i in 0..4 {
            assert!(router.send(i).is_sent());
        }
        assert!(rx1.try_recv().is_err(), "dead lane must stay empty");
        let mut got = Vec::new();
        while let Ok(v) = rx2.try_recv() {
            got.push(v);
        }
        assert_eq!(got, vec![0, 1, 2, 3]);
    }

    #[test]
    fn caps_router_filter_steers_to_capable_lanes() {
        let (tx1, rx1) = channel::<u64>("lane.0", 16, SendPolicy::Block);
        let (tx2, rx2) = channel::<u64>("lane.1", 16, SendPolicy::Block);
        let (plain, capable) = (LaneCaps::new(), LaneCaps::new());
        plain.set(Ok(caps("pairs-only")));
        capable.set(Ok(caps("corpus").with_corpus_scoring()));
        let mut router = CapsRouter::new(vec![(tx1, plain), (tx2, capable)]);
        for i in 0..4 {
            assert!(router.send_filtered(i, |c| c.supports_corpus).is_sent());
        }
        assert!(rx1.try_recv().is_err(), "unsupporting lane must stay empty");
        let mut got = Vec::new();
        while let Ok(v) = rx2.try_recv() {
            got.push(v);
        }
        assert_eq!(got, vec![0, 1, 2, 3]);
        // No capable lane at all: fall back to any lane, whose executor
        // answers with the typed error — never dropped.
        drop(rx2);
        assert!(router.send_filtered(9, |c| c.supports_corpus).is_sent());
        assert_eq!(rx1.try_recv().unwrap(), 9);
        // Unfiltered traffic still round-robins over live lanes.
        assert!(router.send(10).is_sent());
        assert_eq!(rx1.try_recv().unwrap(), 10);
    }

    #[test]
    fn count_satisfying_sees_only_published_caps() {
        let (tx1, _rx1) = channel::<u64>("lane.0", 4, SendPolicy::Block);
        let (tx2, _rx2) = channel::<u64>("lane.1", 4, SendPolicy::Block);
        let (tx3, _rx3) = channel::<u64>("lane.2", 4, SendPolicy::Block);
        let (capable, plain, pending) = (LaneCaps::new(), LaneCaps::new(), LaneCaps::new());
        capable.set(Ok(caps("a").with_corpus_scoring()));
        plain.set(Ok(caps("b")));
        let router = CapsRouter::new(vec![(tx1, capable), (tx2, plain), (tx3, pending)]);
        assert_eq!(router.count_satisfying(|c| c.supports_corpus), 1);
        assert_eq!(router.count_satisfying(|_| true), 2, "unset lanes never count");
        // A published failure counts for nothing either.
        router.lanes[2].1.set(Err(EngineError::Unavailable { reason: "x".into() }));
        assert_eq!(router.count_satisfying(|_| true), 2);
    }

    #[test]
    fn largest_cohort_groups_by_engine_name() {
        let (tx1, _rx1) = channel::<u64>("lane.0", 4, SendPolicy::Block);
        let (tx2, _rx2) = channel::<u64>("lane.1", 4, SendPolicy::Block);
        let (tx3, _rx3) = channel::<u64>("lane.2", 4, SendPolicy::Block);
        let (tx4, _rx4) = channel::<u64>("lane.3", 4, SendPolicy::Block);
        let cells: Vec<_> = (0..4).map(|_| LaneCaps::new()).collect();
        cells[0].set(Ok(caps("sim").with_corpus_scoring()));
        cells[1].set(Ok(caps("native").with_corpus_scoring()));
        cells[2].set(Ok(caps("sim").with_corpus_scoring()));
        // cells[3] never publishes: pending lanes count for nothing.
        let router = CapsRouter::new(vec![
            (tx1, Arc::clone(&cells[0])),
            (tx2, Arc::clone(&cells[1])),
            (tx3, Arc::clone(&cells[2])),
            (tx4, Arc::clone(&cells[3])),
        ]);
        assert_eq!(
            router.largest_cohort(|c| c.supports_corpus),
            Some(("sim".into(), 2)),
            "the biggest same-name pool wins"
        );
        assert_eq!(router.largest_cohort(|c| c.reports_cycles), None);
        // Equal-sized cohorts: the lexicographically smaller name, so
        // scatter sizing is deterministic.
        cells[3].set(Ok(caps("native").with_corpus_scoring()));
        assert_eq!(
            router.largest_cohort(|c| c.supports_corpus),
            Some(("native".into(), 2))
        );
    }

    #[test]
    fn caps_router_falls_back_when_all_lanes_failed() {
        // All engines failed: traffic still lands on a lane so its drain
        // can answer with the typed error (results are never dropped).
        let (tx1, rx1) = channel::<u64>("lane.0", 16, SendPolicy::Block);
        let lc = LaneCaps::new();
        lc.set(Err(EngineError::Unavailable { reason: "dead".into() }));
        let mut router = CapsRouter::new(vec![(tx1, lc)]);
        assert!(router.send(7).is_sent());
        assert_eq!(rx1.try_recv().unwrap(), 7);
        drop(rx1);
        assert!(matches!(router.send(8), SendResult::Disconnected(8)));
    }

    #[test]
    fn caps_router_routes_while_caps_unknown() {
        // Engines construct asynchronously: before the handshake lands,
        // every lane is assumed healthy.
        let (tx1, rx1) = channel::<u64>("lane.0", 16, SendPolicy::Block);
        let mut router = CapsRouter::new(vec![(tx1, LaneCaps::new())]);
        assert!(router.send(1).is_sent());
        assert_eq!(rx1.try_recv().unwrap(), 1);
    }
}
