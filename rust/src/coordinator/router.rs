//! Admission control (the pipeline's first stage) and round-robin
//! fan-out (how the batcher stage spreads released batches across the
//! worker encode/execute lanes).
//!
//! Admission validates queries against the artifact shape limits (the
//! fixed n_max/num_labels the AOT HLO was compiled for — oversize graphs
//! must be rejected, not silently truncated) before they ever enter the
//! pipeline; rejects flow straight to the responder stage.

use crate::graph::Graph;
use crate::nn::config::ModelConfig;

use super::channel::{NamedSender, SendResult};
use super::query::{Query, QueryResult, RejectReason};

/// Validate a query against the model's static shapes.
pub fn validate(cfg: &ModelConfig, g1: &Graph, g2: &Graph) -> Result<(), RejectReason> {
    for g in [g1, g2] {
        if g.num_nodes() > cfg.n_max {
            return Err(RejectReason::TooManyNodes {
                nodes: g.num_nodes(),
                n_max: cfg.n_max,
            });
        }
        if let Some(&bad) = g.labels().iter().find(|&&l| (l as usize) >= cfg.num_labels) {
            return Err(RejectReason::LabelOutOfRange {
                label: bad,
                num_labels: cfg.num_labels,
            });
        }
    }
    Ok(())
}

/// Admission-stage state: shape validation against the artifact limits.
/// (Admit/reject counts live in `Metrics`, fed by the responder — no
/// duplicate bookkeeping here.)
pub struct Admission {
    cfg: ModelConfig,
}

impl Admission {
    pub fn new(cfg: ModelConfig) -> Self {
        Admission { cfg }
    }

    /// Admit one query, or return the rejection result to send to the
    /// responder.
    pub fn admit(&self, q: Query) -> Result<Query, QueryResult> {
        match validate(&self.cfg, &q.g1, &q.g2) {
            Ok(()) => Ok(q),
            Err(reason) => Err(QueryResult::rejected(&q, reason)),
        }
    }
}

/// Round-robin dispatcher over downstream stage inputs. If the preferred
/// lane has shut down, the remaining lanes are tried once around before
/// giving up.
pub struct RoundRobin<T> {
    outs: Vec<NamedSender<T>>,
    next: usize,
}

impl<T> RoundRobin<T> {
    pub fn new(outs: Vec<NamedSender<T>>) -> Self {
        assert!(!outs.is_empty(), "round-robin needs at least one lane");
        RoundRobin { outs, next: 0 }
    }

    pub fn lanes(&self) -> usize {
        self.outs.len()
    }

    pub fn send(&mut self, mut v: T) -> SendResult<T> {
        for _ in 0..self.outs.len() {
            let lane = self.next;
            self.next = (self.next + 1) % self.outs.len();
            match self.outs[lane].send(v) {
                SendResult::Disconnected(back) => v = back,
                delivered => return delivered,
            }
        }
        SendResult::Disconnected(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::channel::{channel, SendPolicy};

    fn cfg() -> ModelConfig {
        ModelConfig {
            n_max: 8,
            num_labels: 4,
            ..ModelConfig::default()
        }
    }

    fn graph(n: usize, label: u16) -> Graph {
        Graph::new(n, (1..n).map(|v| (0u16, v as u16)).collect(), vec![label; n])
    }

    #[test]
    fn validate_rejects_oversize() {
        let c = cfg();
        let ok = graph(5, 1);
        let big = graph(12, 1);
        assert!(validate(&c, &ok, &ok).is_ok());
        assert!(matches!(
            validate(&c, &ok, &big),
            Err(RejectReason::TooManyNodes { .. })
        ));
        let badlabel = graph(4, 9);
        assert!(matches!(
            validate(&c, &badlabel, &ok),
            Err(RejectReason::LabelOutOfRange { .. })
        ));
    }

    #[test]
    fn admission_rejects_inline_with_query_identity() {
        let adm = Admission::new(cfg());
        let g = graph(4, 1);
        let big = graph(20, 1);
        assert!(adm.admit(Query::new(1, g.clone(), g.clone())).is_ok());
        let res = adm.admit(Query::new(7, g, big)).unwrap_err();
        assert!(res.is_rejected());
        assert_eq!(res.id, 7);
    }

    #[test]
    fn round_robin_distribution() {
        let (tx1, rx1) = channel::<u64>("lane.0", 16, SendPolicy::Block);
        let (tx2, rx2) = channel::<u64>("lane.1", 16, SendPolicy::Block);
        let mut rr = RoundRobin::new(vec![tx1, tx2]);
        for i in 0..6 {
            assert!(rr.send(i).is_sent());
        }
        let drain = |rx: &super::super::channel::NamedReceiver<u64>| {
            let mut got = Vec::new();
            while let Ok(v) = rx.try_recv() {
                got.push(v);
            }
            got
        };
        assert_eq!(drain(&rx1), vec![0, 2, 4]);
        assert_eq!(drain(&rx2), vec![1, 3, 5]);
    }

    #[test]
    fn round_robin_skips_dead_lanes() {
        let (tx1, rx1) = channel::<u64>("lane.0", 16, SendPolicy::Block);
        let (tx2, rx2) = channel::<u64>("lane.1", 16, SendPolicy::Block);
        let mut rr = RoundRobin::new(vec![tx1, tx2]);
        drop(rx1);
        for i in 0..4 {
            assert!(rr.send(i).is_sent(), "live lane must absorb traffic");
        }
        let mut got = Vec::new();
        while let Ok(v) = rx2.try_recv() {
            got.push(v);
        }
        assert_eq!(got, vec![0, 1, 2, 3]);
        drop(rx2);
        assert!(matches!(rr.send(9), SendResult::Disconnected(9)));
    }
}
