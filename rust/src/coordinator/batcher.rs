//! Dynamic batcher: the L3 analogue of the paper's query batching
//! (§5.4.3 / Fig. 11). Accumulates queries until either the maximum
//! batch size is reached or the oldest enqueued query has waited past
//! the timeout — the standard size-or-deadline policy (vLLM-style).
//!
//! Implemented as a pure state machine (`push`/`push_all`/`poll`/`flush`
//! driven by explicit timestamps — no internal clock reads) so the
//! invariants are property-testable without threads:
//!   * a flushed batch never exceeds `max_batch`;
//!   * queries leave in arrival order;
//!   * no query waits longer than `timeout` past its arrival before its
//!     batch is eligible for flush.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::query::Query;

#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub timeout: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 64,
            timeout: Duration::from_micros(200),
        }
    }
}

/// Size-or-deadline batcher.
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    pending: VecDeque<Query>,
    oldest_arrival: Option<Instant>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Self {
        assert!(policy.max_batch >= 1);
        Batcher {
            policy,
            pending: VecDeque::new(),
            oldest_arrival: None,
        }
    }

    pub fn pending(&self) -> usize {
        self.pending.len()
    }

    pub fn max_batch(&self) -> usize {
        self.policy.max_batch
    }

    /// Enqueue a query (arriving at `now`); returns a full batch if the
    /// size threshold was reached.
    pub fn push(&mut self, q: Query, now: Instant) -> Option<Vec<Query>> {
        if self.pending.is_empty() {
            self.oldest_arrival = Some(now);
        }
        self.pending.push_back(q);
        if self.pending.len() >= self.policy.max_batch {
            return self.drain(now);
        }
        None
    }

    /// Enqueue a burst (all arriving at `now`); returns every full batch
    /// released. A leftover remainder smaller than `max_batch` stays
    /// pending with its deadline restarted at `now`.
    pub fn push_all(
        &mut self,
        qs: impl IntoIterator<Item = Query>,
        now: Instant,
    ) -> Vec<Vec<Query>> {
        let was_empty = self.pending.is_empty();
        self.pending.extend(qs);
        if was_empty && !self.pending.is_empty() {
            self.oldest_arrival = Some(now);
        }
        let mut out = Vec::new();
        while self.pending.len() >= self.policy.max_batch {
            match self.drain(now) {
                Some(b) => out.push(b),
                None => break,
            }
        }
        out
    }

    /// Deadline check: flush if the oldest query has waited >= timeout.
    pub fn poll(&mut self, now: Instant) -> Option<Vec<Query>> {
        match self.oldest_arrival {
            Some(t0) if now.duration_since(t0) >= self.policy.timeout => self.drain(now),
            _ => None,
        }
    }

    /// Unconditional flush (shutdown path); callers loop until `None` —
    /// each call releases at most `max_batch` queries.
    pub fn flush(&mut self, now: Instant) -> Option<Vec<Query>> {
        self.drain(now)
    }

    /// Time until the current deadline fires (for the worker's
    /// recv_timeout), or None when empty.
    pub fn time_to_deadline(&self, now: Instant) -> Option<Duration> {
        self.oldest_arrival.map(|t0| {
            (t0 + self.policy.timeout)
                .checked_duration_since(now)
                .unwrap_or(Duration::ZERO)
        })
    }

    fn drain(&mut self, now: Instant) -> Option<Vec<Query>> {
        if self.pending.is_empty() {
            return None;
        }
        let take = self.pending.len().min(self.policy.max_batch);
        let batch: Vec<Query> = self.pending.drain(..take).collect();
        self.oldest_arrival = if self.pending.is_empty() {
            None
        } else {
            // Conservative: restart the clock for the remainder at the
            // caller-supplied drain time (keeps the state machine pure —
            // no hidden clock reads, so deadlines are property-testable).
            Some(now)
        };
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::util::prop::check;
    use crate::util::rng::Rng;

    fn q(id: u64) -> Query {
        let g = Graph::new(2, vec![(0, 1)], vec![0, 0]);
        Query::new(id, g.clone(), g)
    }

    #[test]
    fn flushes_at_max_batch() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 3,
            timeout: Duration::from_secs(10),
        });
        let now = Instant::now();
        assert!(b.push(q(0), now).is_none());
        assert!(b.push(q(1), now).is_none());
        let batch = b.push(q(2), now).expect("should flush at 3");
        assert_eq!(batch.iter().map(|x| x.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 100,
            timeout: Duration::from_micros(50),
        });
        let t0 = Instant::now();
        b.push(q(0), t0);
        assert!(b.poll(t0).is_none(), "deadline not reached yet");
        let later = t0 + Duration::from_micros(60);
        let batch = b.poll(later).expect("deadline flush");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn empty_batcher_never_releases_a_batch() {
        // Regression for the executor-lane panic: an empty flush/poll
        // must yield None — never Some(vec![]) — because an empty batch
        // reaching the encoder would hit PackError::EmptyBatch downstream.
        let mut b = Batcher::new(BatchPolicy::default());
        let now = Instant::now();
        assert!(b.flush(now).is_none());
        assert!(b.poll(now + Duration::from_secs(1)).is_none());
        assert!(b.push_all(Vec::new(), now).is_empty());
        assert_eq!(b.time_to_deadline(now), None, "empty burst must not arm a deadline");
        assert!(b.flush(now).is_none());
        // A real push then a full drain returns the batcher to the same
        // release-nothing state.
        b.push(q(0), now);
        assert_eq!(b.flush(now).unwrap().len(), 1);
        assert!(b.flush(now).is_none());
        assert!(b.poll(now + Duration::from_secs(1)).is_none());
    }

    #[test]
    fn flush_drains_everything_in_order() {
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 10,
            timeout: Duration::from_secs(1),
        });
        let now = Instant::now();
        for i in 0..5 {
            b.push(q(i), now);
        }
        let batch = b.flush(now).unwrap();
        assert_eq!(batch.iter().map(|x| x.id).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
        assert!(b.flush(now).is_none());
    }

    #[test]
    fn remainder_deadline_restarts_from_drain_time() {
        let timeout = Duration::from_micros(100);
        let mut b = Batcher::new(BatchPolicy {
            max_batch: 3,
            timeout,
        });
        let t0 = Instant::now();
        // Burst of 7 at t0: two full batches out, remainder of 1 pending.
        let batches = b.push_all((0..7).map(q), t0);
        assert_eq!(batches.len(), 2);
        assert_eq!(b.pending(), 1);
        // The remainder's deadline is measured from the drain time t0 —
        // with no hidden Instant::now() inside drain this is exact.
        assert_eq!(b.time_to_deadline(t0), Some(timeout));
        assert!(b.poll(t0 + timeout - Duration::from_micros(1)).is_none());
        let rem = b.poll(t0 + timeout).expect("remainder deadline flush");
        assert_eq!(rem.iter().map(|x| x.id).collect::<Vec<_>>(), vec![6]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn property_never_exceeds_max_and_preserves_order() {
        check(
            "batcher-order",
            60,
            |rng: &mut Rng| {
                let max_batch = rng.range(1, 8);
                let ops: Vec<(u8, u8)> = (0..rng.range(1, 40))
                    .map(|_| (rng.below(4) as u8, rng.range(1, 12) as u8))
                    .collect();
                (max_batch, ops)
            },
            |(max_batch, ops)| {
                let timeout = Duration::from_micros(10);
                let mut b = Batcher::new(BatchPolicy {
                    max_batch: *max_batch,
                    timeout,
                });
                let mut next_id = 0u64;
                let mut out = Vec::new();
                let t0 = Instant::now();
                let mut now = t0;
                for (op, arg) in ops {
                    match op {
                        0 => {
                            if let Some(batch) = b.push(q(next_id), now) {
                                if batch.len() > *max_batch {
                                    return Err("batch too big".into());
                                }
                                out.extend(batch.iter().map(|x| x.id));
                            }
                            next_id += 1;
                        }
                        1 => {
                            now += Duration::from_micros(15);
                            if let Some(batch) = b.poll(now) {
                                if batch.len() > *max_batch {
                                    return Err("batch too big".into());
                                }
                                out.extend(batch.iter().map(|x| x.id));
                            }
                        }
                        2 => {
                            // Burst push: the op that leaves a remainder.
                            let burst: Vec<Query> =
                                (0..*arg as u64).map(|i| q(next_id + i)).collect();
                            next_id += *arg as u64;
                            let released = b.push_all(burst, now);
                            for batch in released {
                                if batch.len() > *max_batch {
                                    return Err("batch too big".into());
                                }
                                out.extend(batch.iter().map(|x| x.id));
                            }
                            // Leftover-remainder deadline: whatever stays
                            // pending after a burst is due no later than
                            // `now + timeout` (exactly that when drains
                            // restarted the clock).
                            if b.pending() > 0 {
                                match b.time_to_deadline(now) {
                                    Some(d) if d <= timeout => {}
                                    other => {
                                        return Err(format!(
                                            "remainder deadline {other:?} exceeds timeout"
                                        ))
                                    }
                                }
                            }
                        }
                        _ => {
                            if let Some(batch) = b.flush(now) {
                                out.extend(batch.iter().map(|x| x.id));
                            }
                        }
                    }
                }
                while let Some(batch) = b.flush(now) {
                    out.extend(batch.iter().map(|x| x.id));
                }
                // all ids delivered exactly once, in order
                let want: Vec<u64> = (0..next_id).collect();
                if out != want {
                    return Err(format!("order violated: {out:?}"));
                }
                Ok(())
            },
        );
    }
}
