//! Named corpora for one-vs-many similarity search (the paper's actual
//! use case: score a query graph against a *database* of graphs, §5.1).
//!
//! A [`Corpus`] holds encoded graphs with their ids; each carries its
//! content fingerprint, computed once at encode time. The engine-side
//! embedding cache (DESIGN.md S14) keys on those fingerprints, so the
//! first top-k query against a corpus embeds each unique graph once
//! and every later query — on any lane that has seen the corpus —
//! pays only the NTN+FCN tail per candidate. The corpus itself stays
//! engine-agnostic: embeddings depend on an engine's weights, so they
//! live in each engine's cache, not here.

use std::collections::HashSet;

use crate::graph::dataset::GraphDb;
use crate::graph::encode::{encode, EncodeError, EncodedGraph, GraphKey};
use crate::graph::Graph;

/// A contiguous view over one slice of a corpus's candidates — the unit
/// the scatter stage hands to one executor lane. Shards are cheap id
/// ranges over the already-encoded candidates: no graph is re-encoded
/// or cloned to scatter a query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CorpusShard {
    /// First candidate index (inclusive).
    pub start: usize,
    /// One past the last candidate index (exclusive).
    pub end: usize,
}

impl CorpusShard {
    /// Candidates in this shard.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// True when the shard covers no candidates.
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// Why a set of shard partials could not be merged back into one
/// ranking: the shards must tile the corpus exactly, one score per
/// candidate. The gather stage converts this into a typed engine error
/// instead of panicking its thread.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardCoverageError {
    /// Human-readable description of the coverage violation.
    pub detail: String,
}

impl std::fmt::Display for ShardCoverageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "shard merge: {}", self.detail)
    }
}

impl std::error::Error for ShardCoverageError {}

/// An immutable named set of candidate graphs, encoded once at build
/// time for the artifact shapes it will be served with.
#[derive(Debug)]
pub struct Corpus {
    name: String,
    ids: Vec<u64>,
    graphs: Vec<EncodedGraph>,
    keys: Vec<GraphKey>,
    unique: usize,
    /// The artifact shapes the candidates were encoded for; admission
    /// rejects a corpus whose shapes don't match the serving model.
    n_max: usize,
    num_labels: usize,
}

impl Corpus {
    /// Encode `entries` (caller-chosen id per graph) for the given
    /// artifact shapes. Fails on the first graph the shapes cannot hold
    /// — a corpus must be fully servable or not registered at all.
    pub fn build(
        name: impl Into<String>,
        entries: &[(u64, Graph)],
        n_max: usize,
        num_labels: usize,
    ) -> Result<Self, EncodeError> {
        Self::build_from(
            name.into(),
            entries.iter().map(|(id, g)| (*id, g)),
            n_max,
            num_labels,
        )
    }

    /// Build from a graph database, ids = positions (graphs are read by
    /// reference — nothing is cloned before encoding).
    pub fn from_db(
        name: impl Into<String>,
        db: &GraphDb,
        n_max: usize,
        num_labels: usize,
    ) -> Result<Self, EncodeError> {
        Self::build_from(
            name.into(),
            db.graphs.iter().enumerate().map(|(i, g)| (i as u64, g)),
            n_max,
            num_labels,
        )
    }

    /// Shared borrowing construction core for [`Corpus::build`] /
    /// [`Corpus::from_db`].
    fn build_from<'a>(
        name: String,
        entries: impl Iterator<Item = (u64, &'a Graph)>,
        n_max: usize,
        num_labels: usize,
    ) -> Result<Self, EncodeError> {
        let mut ids = Vec::new();
        let mut graphs = Vec::new();
        let mut keys = Vec::new();
        for (id, g) in entries {
            let e = encode(g, n_max, num_labels)?;
            keys.push(e.fingerprint());
            graphs.push(e);
            ids.push(id);
        }
        let unique = keys.iter().map(|k| k.0).collect::<HashSet<u128>>().len();
        Ok(Corpus {
            name,
            ids,
            graphs,
            keys,
            unique,
            n_max,
            num_labels,
        })
    }

    /// The corpus name (reports, logs).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The `n_max` the candidates were encoded for.
    pub fn n_max(&self) -> usize {
        self.n_max
    }

    /// The label vocabulary the candidates were encoded for.
    pub fn num_labels(&self) -> usize {
        self.num_labels
    }

    /// Candidate count.
    pub fn len(&self) -> usize {
        self.graphs.len()
    }

    /// True when the corpus holds no candidates.
    pub fn is_empty(&self) -> bool {
        self.graphs.is_empty()
    }

    /// The encoded candidates, in id order — the slice handed to
    /// [`Engine::score_corpus`](crate::runtime::Engine::score_corpus).
    pub fn graphs(&self) -> &[EncodedGraph] {
        &self.graphs
    }

    /// Caller-chosen candidate ids, parallel to [`Corpus::graphs`].
    pub fn ids(&self) -> &[u64] {
        &self.ids
    }

    /// Precomputed content fingerprints, parallel to [`Corpus::graphs`].
    pub fn keys(&self) -> &[GraphKey] {
        &self.keys
    }

    /// Number of distinct graphs (by fingerprint) — the exact number of
    /// GCN forwards a cold top-k query over this corpus costs, query
    /// graph excluded.
    pub fn unique_graphs(&self) -> usize {
        self.unique
    }

    /// Split the corpus into `n` contiguous shard views for a scattered
    /// top-k query. `n` clamps to the candidate count (every returned
    /// shard is non-empty) and sizes differ by at most one candidate —
    /// the workload-balanced partitioning Accel-GCN applies across its
    /// parallel units, here across executor lanes. An empty corpus has
    /// no shards.
    pub fn shards(&self, n: usize) -> Vec<CorpusShard> {
        if self.is_empty() {
            return Vec::new();
        }
        let n = n.clamp(1, self.len());
        let base = self.len() / n;
        let extra = self.len() % n;
        let mut shards = Vec::with_capacity(n);
        let mut start = 0;
        for i in 0..n {
            let end = start + base + usize::from(i < extra);
            shards.push(CorpusShard { start, end });
            start = end;
        }
        shards
    }

    /// The encoded candidates of one shard — the slice handed to
    /// [`Engine::score_corpus_with`](crate::runtime::Engine::score_corpus_with).
    pub fn shard_graphs(&self, shard: CorpusShard) -> &[EncodedGraph] {
        &self.graphs[shard.start..shard.end]
    }

    /// Number of distinct graphs (by fingerprint) within one shard —
    /// what a cold lane pays in GCN forwards for that shard. Shards are
    /// views over the same fingerprinted candidates, so dedup awareness
    /// costs no re-hashing.
    pub fn unique_in(&self, shard: CorpusShard) -> usize {
        self.keys[shard.start..shard.end]
            .iter()
            .map(|k| k.0)
            .collect::<HashSet<u128>>()
            .len()
    }

    /// Merge scattered shard partials back into one ranking. Each
    /// partial is `(shard, scores-for-that-shard)`; together they must
    /// tile the corpus exactly (no gap, no overlap, one score per
    /// candidate). The merged ranking goes through [`Corpus::rank`] —
    /// the one and only sort/tie-break implementation — so sharded and
    /// unsharded results are bit-identical by construction.
    pub fn rank_sharded(
        &self,
        partials: &[(CorpusShard, &[f32])],
        k: usize,
    ) -> Result<Vec<(u64, f32)>, ShardCoverageError> {
        let mut scores = vec![0.0f32; self.len()];
        let mut covered = vec![false; self.len()];
        for (shard, s) in partials {
            if shard.end > self.len() || shard.start > shard.end {
                return Err(ShardCoverageError {
                    detail: format!(
                        "shard {}..{} outside corpus of {} candidates",
                        shard.start,
                        shard.end,
                        self.len()
                    ),
                });
            }
            if s.len() != shard.len() {
                return Err(ShardCoverageError {
                    detail: format!(
                        "shard {}..{} carries {} scores for {} candidates",
                        shard.start,
                        shard.end,
                        s.len(),
                        shard.len()
                    ),
                });
            }
            for (i, &score) in s.iter().enumerate() {
                let at = shard.start + i;
                if covered[at] {
                    return Err(ShardCoverageError {
                        detail: format!("candidate {at} scored by two shards"),
                    });
                }
                covered[at] = true;
                scores[at] = score;
            }
        }
        if let Some(gap) = covered.iter().position(|c| !c) {
            return Err(ShardCoverageError {
                detail: format!("candidate {gap} not covered by any shard"),
            });
        }
        Ok(self.rank(&scores, k))
    }

    /// Rank one engine fan-out: top `k` of `scores` (one per candidate,
    /// [`Corpus::graphs`] order) as `(id, score)` pairs, best first.
    /// Ties break toward the smaller id so rankings are deterministic;
    /// `k` is clamped to the corpus size.
    pub fn rank(&self, scores: &[f32], k: usize) -> Vec<(u64, f32)> {
        assert_eq!(
            scores.len(),
            self.graphs.len(),
            "one score per corpus candidate"
        );
        let mut ranked: Vec<(u64, f32)> = self
            .ids
            .iter()
            .copied()
            .zip(scores.iter().copied())
            .collect();
        ranked.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        ranked.truncate(k);
        ranked
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::Family;
    use crate::util::rng::Rng;

    fn corpus_with_dup() -> Corpus {
        let mut rng = Rng::new(61);
        let db = GraphDb::synthesize(&mut rng, Family::Aids, 5, 32, 29);
        let mut entries: Vec<(u64, Graph)> = db
            .graphs
            .iter()
            .cloned()
            .enumerate()
            .map(|(i, g)| (i as u64, g))
            .collect();
        // Entry 5 duplicates entry 0's graph under a fresh id.
        entries.push((5, db.graphs[0].clone()));
        Corpus::build("dup", &entries, 32, 29).unwrap()
    }

    #[test]
    fn build_precomputes_keys_and_unique_count() {
        let c = corpus_with_dup();
        assert_eq!(c.name(), "dup");
        assert_eq!(c.len(), 6);
        assert_eq!(c.unique_graphs(), 5, "duplicate must not count twice");
        assert_eq!(c.keys().len(), 6);
        assert_eq!(c.keys()[0], c.keys()[5], "same graph, same key");
        assert_eq!(c.graphs()[0].fingerprint(), c.keys()[0]);
        assert_eq!(c.ids(), &[0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn build_rejects_unservable_graphs() {
        let big = Graph::new(10, (1..10).map(|v| (0u16, v)).collect(), vec![0; 10]);
        let err = Corpus::build("bad", &[(0, big)], 8, 4).unwrap_err();
        assert!(matches!(err, EncodeError::TooManyNodes { .. }));
    }

    #[test]
    fn rank_sorts_desc_clamps_k_and_breaks_ties_by_id() {
        let c = corpus_with_dup();
        let scores = [0.3, 0.9, 0.5, 0.9, 0.1, 0.5];
        let top = c.rank(&scores, 4);
        assert_eq!(top, vec![(1, 0.9), (3, 0.9), (2, 0.5), (5, 0.5)]);
        // k larger than the corpus: everything, still ordered.
        let all = c.rank(&scores, 100);
        assert_eq!(all.len(), 6);
        assert_eq!(all[5], (4, 0.1));
        // k == 0 is a valid (empty) request.
        assert!(c.rank(&scores, 0).is_empty());
    }

    #[test]
    fn shards_tile_the_corpus_balanced() {
        let c = corpus_with_dup(); // 6 candidates
        // 6 over 4 lanes: sizes 2,2,1,1 — never more than one apart.
        let shards = c.shards(4);
        assert_eq!(shards.len(), 4);
        let sizes: Vec<usize> = shards.iter().map(CorpusShard::len).collect();
        assert_eq!(sizes, vec![2, 2, 1, 1]);
        // Contiguous tiling, in order.
        assert_eq!(shards[0], CorpusShard { start: 0, end: 2 });
        assert_eq!(shards[3], CorpusShard { start: 5, end: 6 });
        let mut covered = 0;
        for s in &shards {
            assert_eq!(s.start, covered);
            assert!(!s.is_empty());
            assert_eq!(c.shard_graphs(*s).len(), s.len());
            covered = s.end;
        }
        assert_eq!(covered, c.len());
        // n clamps to the candidate count; 1 shard is the whole corpus.
        assert_eq!(c.shards(100).len(), 6);
        assert_eq!(c.shards(1), vec![CorpusShard { start: 0, end: 6 }]);
        assert_eq!(c.shards(0), c.shards(1), "n=0 clamps up to one shard");
        let empty = Corpus::build("e", &[], 8, 4).unwrap();
        assert!(empty.shards(3).is_empty());
    }

    #[test]
    fn shard_unique_counts_follow_fingerprints() {
        let c = corpus_with_dup(); // entry 5 duplicates entry 0
        let whole = c.shards(1)[0];
        assert_eq!(c.unique_in(whole), c.unique_graphs());
        // Split so the duplicate lands in a different shard than its
        // original: both shards then count it as locally unique.
        let shards = c.shards(2); // 0..3, 3..6
        assert_eq!(c.unique_in(shards[0]) + c.unique_in(shards[1]), 6);
    }

    #[test]
    fn rank_sharded_matches_rank_and_rejects_bad_coverage() {
        let c = corpus_with_dup();
        let scores = [0.3, 0.9, 0.5, 0.9, 0.1, 0.5];
        for n in 1..=6 {
            let shards = c.shards(n);
            let partials: Vec<(CorpusShard, &[f32])> = shards
                .iter()
                .map(|s| (*s, &scores[s.start..s.end]))
                .collect();
            for k in [0usize, 1, 3, 6, 13] {
                assert_eq!(
                    c.rank_sharded(&partials, k).unwrap(),
                    c.rank(&scores, k),
                    "n={n} k={k}"
                );
            }
        }
        // A gap, an overlap, and a length mismatch are each rejected.
        let s02 = CorpusShard { start: 0, end: 2 };
        let s26 = CorpusShard { start: 2, end: 6 };
        assert!(c.rank_sharded(&[(s02, &scores[0..2])], 3).is_err());
        assert!(c
            .rank_sharded(&[(s02, &scores[0..2]), (s02, &scores[0..2]), (s26, &scores[2..6])], 3)
            .is_err());
        assert!(c
            .rank_sharded(&[(s02, &scores[0..1]), (s26, &scores[2..6])], 3)
            .is_err());
        let oob = CorpusShard { start: 4, end: 9 };
        assert!(c.rank_sharded(&[(oob, &scores[0..5])], 3).is_err());
    }

    #[test]
    fn from_db_uses_positions_as_ids() {
        let mut rng = Rng::new(62);
        let db = GraphDb::synthesize(&mut rng, Family::Aids, 4, 32, 29);
        let c = Corpus::from_db("db", &db, 32, 29).unwrap();
        assert_eq!(c.len(), 4);
        assert_eq!(c.ids(), &[0, 1, 2, 3]);
        assert!(!c.is_empty());
    }
}
