//! Named corpora for one-vs-many similarity search (the paper's actual
//! use case: score a query graph against a *database* of graphs, §5.1).
//!
//! A [`Corpus`] holds encoded graphs with their ids; each carries its
//! content fingerprint, computed once at encode time. The engine-side
//! embedding cache (DESIGN.md S14) keys on those fingerprints, so the
//! first top-k query against a corpus embeds each unique graph once
//! and every later query — on any lane that has seen the corpus —
//! pays only the NTN+FCN tail per candidate. The corpus itself stays
//! engine-agnostic: embeddings depend on an engine's weights, so they
//! live in each engine's cache, not here.

use std::collections::{HashMap, HashSet};
use std::time::Instant;

use crate::graph::dataset::GraphDb;
use crate::graph::encode::{encode, CheapSignals, EncodeError, EncodedGraph, GraphKey};
use crate::graph::Graph;

/// Why a corpus could not be built or grown.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CorpusError {
    /// Two entries claimed the same candidate id. [`Corpus::rank`]
    /// documents a deterministic smaller-id tie-break; with duplicate
    /// ids the same id could appear twice in one top-k response, so
    /// they are rejected at build/upsert time instead of corrupting
    /// rankings later.
    DuplicateId { id: u64 },
    /// A graph the artifact shapes cannot hold.
    Encode(EncodeError),
}

impl std::fmt::Display for CorpusError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CorpusError::DuplicateId { id } => {
                write!(f, "duplicate candidate id {id}")
            }
            CorpusError::Encode(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for CorpusError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CorpusError::Encode(e) => Some(e),
            CorpusError::DuplicateId { .. } => None,
        }
    }
}

impl From<EncodeError> for CorpusError {
    fn from(e: EncodeError) -> Self {
        CorpusError::Encode(e)
    }
}

/// A contiguous view over one slice of a corpus's candidates — the unit
/// the scatter stage hands to one executor lane. Shards are cheap id
/// ranges over the already-encoded candidates: no graph is re-encoded
/// or cloned to scatter a query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CorpusShard {
    /// First candidate index (inclusive).
    pub start: usize,
    /// One past the last candidate index (exclusive).
    pub end: usize,
}

impl CorpusShard {
    /// Candidates in this shard.
    pub fn len(&self) -> usize {
        self.end - self.start
    }

    /// True when the shard covers no candidates.
    pub fn is_empty(&self) -> bool {
        self.start == self.end
    }
}

/// Why a set of shard partials could not be merged back into one
/// ranking: the shards must tile the corpus exactly, one score per
/// candidate, and every partial must have been scored against the same
/// corpus generation as the merging corpus. The gather stage converts
/// this into a typed engine error instead of panicking its thread.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShardCoverageError {
    /// The shards do not tile the corpus (gap, overlap, out-of-range,
    /// or a score-count mismatch).
    Coverage {
        /// Human-readable description of the coverage violation.
        detail: String,
    },
    /// A partial was scored against a different corpus epoch than the
    /// one merging it — a live-corpus mutation landed mid-flight and
    /// two generations almost mixed into one ranking.
    EpochMismatch { expected: u64, got: u64 },
}

impl std::fmt::Display for ShardCoverageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardCoverageError::Coverage { detail } => {
                write!(f, "shard merge: {detail}")
            }
            ShardCoverageError::EpochMismatch { expected, got } => {
                write!(
                    f,
                    "shard merge: partial from corpus epoch {got}, merging at epoch {expected}"
                )
            }
        }
    }
}

impl std::error::Error for ShardCoverageError {}

/// One lane's scored slice of a scattered top-k query, stamped with the
/// epoch of the corpus snapshot the lane scored against.
#[derive(Debug, Clone, Copy)]
pub struct ShardPartial<'a> {
    /// Epoch of the corpus the scores were computed against.
    pub epoch: u64,
    /// The candidate range the scores cover.
    pub shard: CorpusShard,
    /// One score per candidate in `shard`, corpus order.
    pub scores: &'a [f32],
}

/// A balanced shard plan with its per-shard distinct-fingerprint counts
/// precomputed — the scatter stage reads `uniques[i]` as a field
/// instead of hashing candidates per query (see [`Corpus::shard_plan`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// Contiguous shards tiling the corpus, sizes within ±1.
    pub shards: Vec<CorpusShard>,
    /// Distinct fingerprints per shard, parallel to `shards` — what a
    /// cold lane pays in GCN forwards for that shard.
    pub uniques: Vec<usize>,
}

/// The coarse stage's verdict for one budgeted top-k query: which
/// candidates survive to the exact NTN+FCN tail (DESIGN.md S20).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PrunePlan {
    /// One flag per candidate, [`Corpus::graphs`] order.
    pub keep: Vec<bool>,
    /// Number of `true` flags.
    pub survivors: usize,
    /// Candidates ruled out by the cheap signals.
    pub pruned: usize,
    /// Wall time the coarse stage took, microseconds.
    pub prune_us: u64,
}

/// An immutable named set of candidate graphs, encoded once at build
/// time for the artifact shapes it will be served with. Liveness comes
/// from above: `CorpusStore` swaps whole immutable `Corpus` generations
/// (each stamped with an epoch), it never mutates one in place.
#[derive(Debug)]
pub struct Corpus {
    name: String,
    ids: Vec<u64>,
    graphs: Vec<EncodedGraph>,
    keys: Vec<GraphKey>,
    /// Cheap per-candidate signals (node/edge counts, label histogram),
    /// parallel to `graphs` — the coarse stage of cascade retrieval.
    signals: Vec<CheapSignals>,
    /// `prev_same[i]` = index of the nearest earlier candidate with the
    /// same fingerprint, if any. Lets [`Corpus::unique_in`] count
    /// distinct graphs in any contiguous shard with a linear scan and
    /// zero hashing on the per-query scatter path.
    prev_same: Vec<Option<usize>>,
    unique: usize,
    /// Generation stamp assigned by the owning `CorpusStore` (0 for a
    /// standalone build). Queries resolve one epoch at admission and
    /// carry it end-to-end; `rank_sharded` refuses partials from any
    /// other epoch.
    epoch: u64,
    /// The artifact shapes the candidates were encoded for; admission
    /// rejects a corpus whose shapes don't match the serving model.
    n_max: usize,
    num_labels: usize,
}

impl Corpus {
    /// Encode `entries` (caller-chosen id per graph) for the given
    /// artifact shapes. Fails on the first graph the shapes cannot hold
    /// — a corpus must be fully servable or not registered at all.
    pub fn build(
        name: impl Into<String>,
        entries: &[(u64, Graph)],
        n_max: usize,
        num_labels: usize,
    ) -> Result<Self, CorpusError> {
        Self::build_from(
            name.into(),
            entries.iter().map(|(id, g)| (*id, g)),
            n_max,
            num_labels,
        )
    }

    /// Build from a graph database, ids = positions (graphs are read by
    /// reference — nothing is cloned before encoding).
    pub fn from_db(
        name: impl Into<String>,
        db: &GraphDb,
        n_max: usize,
        num_labels: usize,
    ) -> Result<Self, CorpusError> {
        Self::build_from(
            name.into(),
            db.graphs.iter().enumerate().map(|(i, g)| (i as u64, g)),
            n_max,
            num_labels,
        )
    }

    /// Shared borrowing construction core for [`Corpus::build`] /
    /// [`Corpus::from_db`].
    fn build_from<'a>(
        name: String,
        entries: impl Iterator<Item = (u64, &'a Graph)>,
        n_max: usize,
        num_labels: usize,
    ) -> Result<Self, CorpusError> {
        let mut ids = Vec::new();
        let mut graphs = Vec::new();
        let mut keys = Vec::new();
        let mut signals = Vec::new();
        let mut seen_ids = HashSet::new();
        for (id, g) in entries {
            if !seen_ids.insert(id) {
                return Err(CorpusError::DuplicateId { id });
            }
            let e = encode(g, n_max, num_labels)?;
            signals.push(CheapSignals::from_graph(g, num_labels));
            keys.push(e.fingerprint());
            graphs.push(e);
            ids.push(id);
        }
        // Build-time hashing is fine — this is the one place that may
        // hash fingerprints; every per-query path reads `prev_same`.
        let mut last: HashMap<u128, usize> = HashMap::new();
        let mut prev_same = Vec::with_capacity(keys.len());
        for (i, k) in keys.iter().enumerate() {
            prev_same.push(last.insert(k.0, i));
        }
        let unique = prev_same.iter().filter(|p| p.is_none()).count();
        Ok(Corpus {
            name,
            ids,
            graphs,
            keys,
            signals,
            prev_same,
            unique,
            epoch: 0,
            n_max,
            num_labels,
        })
    }

    /// Stamp this corpus with a generation number. Only `CorpusStore`
    /// assigns non-zero epochs (the EPOCH-SWAP-CONFINED lint keeps
    /// production snapshot construction in `corpus_store.rs`).
    pub fn with_epoch(mut self, epoch: u64) -> Self {
        self.epoch = epoch;
        self
    }

    /// The generation this corpus belongs to (0 for standalone builds).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// The corpus name (reports, logs).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The `n_max` the candidates were encoded for.
    pub fn n_max(&self) -> usize {
        self.n_max
    }

    /// The label vocabulary the candidates were encoded for.
    pub fn num_labels(&self) -> usize {
        self.num_labels
    }

    /// Candidate count.
    pub fn len(&self) -> usize {
        self.graphs.len()
    }

    /// True when the corpus holds no candidates.
    pub fn is_empty(&self) -> bool {
        self.graphs.is_empty()
    }

    /// The encoded candidates, in id order — the slice handed to
    /// [`Engine::score_corpus`](crate::runtime::Engine::score_corpus).
    pub fn graphs(&self) -> &[EncodedGraph] {
        &self.graphs
    }

    /// Caller-chosen candidate ids, parallel to [`Corpus::graphs`].
    pub fn ids(&self) -> &[u64] {
        &self.ids
    }

    /// Precomputed content fingerprints, parallel to [`Corpus::graphs`].
    pub fn keys(&self) -> &[GraphKey] {
        &self.keys
    }

    /// Cheap per-candidate signals, parallel to [`Corpus::graphs`] —
    /// the coarse stage of cascade retrieval reads these.
    pub fn signals(&self) -> &[CheapSignals] {
        &self.signals
    }

    /// Number of distinct graphs (by fingerprint) — the exact number of
    /// GCN forwards a cold top-k query over this corpus costs, query
    /// graph excluded.
    pub fn unique_graphs(&self) -> usize {
        self.unique
    }

    /// Split the corpus into `n` contiguous shard views for a scattered
    /// top-k query. `n` clamps to the candidate count (every returned
    /// shard is non-empty) and sizes differ by at most one candidate —
    /// the workload-balanced partitioning Accel-GCN applies across its
    /// parallel units, here across executor lanes. An empty corpus has
    /// no shards.
    pub fn shards(&self, n: usize) -> Vec<CorpusShard> {
        if self.is_empty() {
            return Vec::new();
        }
        let n = n.clamp(1, self.len());
        let base = self.len() / n;
        let extra = self.len() % n;
        let mut shards = Vec::with_capacity(n);
        let mut start = 0;
        for i in 0..n {
            let end = start + base + usize::from(i < extra);
            shards.push(CorpusShard { start, end });
            start = end;
        }
        shards
    }

    /// The encoded candidates of one shard — the slice handed to
    /// [`Engine::score_corpus_with`](crate::runtime::Engine::score_corpus_with).
    pub fn shard_graphs(&self, shard: CorpusShard) -> &[EncodedGraph] {
        &self.graphs[shard.start..shard.end]
    }

    /// Number of distinct graphs (by fingerprint) within one shard —
    /// what a cold lane pays in GCN forwards for that shard. Shards are
    /// views over the same fingerprinted candidates, so dedup awareness
    /// costs no re-hashing.
    pub fn unique_in(&self, shard: CorpusShard) -> usize {
        // A candidate is the shard-local first of its fingerprint
        // exactly when its nearest earlier duplicate (if any) falls
        // before the shard: a linear scan over the precomputed
        // `prev_same` field, no per-query hashing.
        self.prev_same[shard.start..shard.end]
            .iter()
            .filter(|p| p.map_or(true, |prev| prev < shard.start))
            .count()
    }

    /// Build the balanced shard plan for `n` lanes with every shard's
    /// distinct-fingerprint count precomputed — one linear pass at plan
    /// time, so the scatter stage reads `uniques[i]` as a field.
    pub fn shard_plan(&self, n: usize) -> ShardPlan {
        let shards = self.shards(n);
        let uniques = shards.iter().map(|s| self.unique_in(*s)).collect();
        ShardPlan { shards, uniques }
    }

    /// Coarse stage of cascade retrieval: keep the `budget` candidates
    /// whose [`CheapSignals`] are nearest the query's, rule out the
    /// rest before any of them costs a GCN forward or an NTN+FCN tail.
    /// Selection is deterministic — integer `(distance, index)` keys,
    /// smaller index on ties — and a budget covering the whole corpus
    /// degenerates to keep-everything (`Exact` never calls this).
    pub fn prune(&self, query: &CheapSignals, budget: usize) -> PrunePlan {
        let started = Instant::now();
        let n = self.len();
        let mut keep = vec![false; n];
        let budget = budget.max(1);
        if budget >= n {
            keep.iter_mut().for_each(|f| *f = true);
            return PrunePlan {
                keep,
                survivors: n,
                pruned: 0,
                prune_us: started.elapsed().as_micros() as u64,
            };
        }
        let mut order: Vec<(u64, usize)> = self
            .signals
            .iter()
            .enumerate()
            .map(|(i, s)| (query.distance(s), i))
            .collect();
        // O(n) selection; membership of the first `budget` entries is
        // deterministic because every (distance, index) key is unique.
        order.select_nth_unstable(budget - 1);
        for &(_, i) in order.iter().take(budget) {
            keep[i] = true;
        }
        PrunePlan {
            keep,
            survivors: budget,
            pruned: n - budget,
            prune_us: started.elapsed().as_micros() as u64,
        }
    }

    /// Merge scattered shard partials back into one ranking. Each
    /// [`ShardPartial`] must carry this corpus's epoch (a partial
    /// scored against another generation is refused — mutations landing
    /// mid-flight can never mix epochs into one ranking), and together
    /// the shards must tile the corpus exactly (no gap, no overlap, one
    /// score per candidate). The merged ranking goes through
    /// [`Corpus::rank`] — the one and only sort/tie-break
    /// implementation — so sharded and unsharded results are
    /// bit-identical by construction.
    pub fn rank_sharded(
        &self,
        partials: &[ShardPartial],
        k: usize,
    ) -> Result<Vec<(u64, f32)>, ShardCoverageError> {
        let mut scores = vec![0.0f32; self.len()];
        let mut covered = vec![false; self.len()];
        for p in partials {
            if p.epoch != self.epoch {
                return Err(ShardCoverageError::EpochMismatch {
                    expected: self.epoch,
                    got: p.epoch,
                });
            }
            let shard = p.shard;
            if shard.end > self.len() || shard.start > shard.end {
                return Err(ShardCoverageError::Coverage {
                    detail: format!(
                        "shard {}..{} outside corpus of {} candidates",
                        shard.start,
                        shard.end,
                        self.len()
                    ),
                });
            }
            if p.scores.len() != shard.len() {
                return Err(ShardCoverageError::Coverage {
                    detail: format!(
                        "shard {}..{} carries {} scores for {} candidates",
                        shard.start,
                        shard.end,
                        p.scores.len(),
                        shard.len()
                    ),
                });
            }
            for (i, &score) in p.scores.iter().enumerate() {
                let at = shard.start + i;
                if covered[at] {
                    return Err(ShardCoverageError::Coverage {
                        detail: format!("candidate {at} scored by two shards"),
                    });
                }
                covered[at] = true;
                scores[at] = score;
            }
        }
        if let Some(gap) = covered.iter().position(|c| !c) {
            return Err(ShardCoverageError::Coverage {
                detail: format!("candidate {gap} not covered by any shard"),
            });
        }
        Ok(self.rank(&scores, k))
    }

    /// Rank one engine fan-out: top `k` of `scores` (one per candidate,
    /// [`Corpus::graphs`] order) as `(id, score)` pairs, best first.
    /// Ties break toward the smaller id so rankings are deterministic;
    /// `k` is clamped to the corpus size.
    pub fn rank(&self, scores: &[f32], k: usize) -> Vec<(u64, f32)> {
        assert_eq!(
            scores.len(),
            self.graphs.len(),
            "one score per corpus candidate"
        );
        let mut ranked: Vec<(u64, f32)> = self
            .ids
            .iter()
            .copied()
            .zip(scores.iter().copied())
            .collect();
        ranked.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        ranked.truncate(k);
        ranked
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::Family;
    use crate::util::rng::Rng;

    fn corpus_with_dup() -> Corpus {
        let mut rng = Rng::new(61);
        let db = GraphDb::synthesize(&mut rng, Family::Aids, 5, 32, 29);
        let mut entries: Vec<(u64, Graph)> = db
            .graphs
            .iter()
            .cloned()
            .enumerate()
            .map(|(i, g)| (i as u64, g))
            .collect();
        // Entry 5 duplicates entry 0's graph under a fresh id.
        entries.push((5, db.graphs[0].clone()));
        Corpus::build("dup", &entries, 32, 29).unwrap()
    }

    #[test]
    fn build_precomputes_keys_and_unique_count() {
        let c = corpus_with_dup();
        assert_eq!(c.name(), "dup");
        assert_eq!(c.len(), 6);
        assert_eq!(c.unique_graphs(), 5, "duplicate must not count twice");
        assert_eq!(c.keys().len(), 6);
        assert_eq!(c.keys()[0], c.keys()[5], "same graph, same key");
        assert_eq!(c.graphs()[0].fingerprint(), c.keys()[0]);
        assert_eq!(c.ids(), &[0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn build_rejects_unservable_graphs() {
        let big = Graph::new(10, (1..10).map(|v| (0u16, v)).collect(), vec![0; 10]);
        let err = Corpus::build("bad", &[(0, big)], 8, 4).unwrap_err();
        assert!(matches!(
            err,
            CorpusError::Encode(EncodeError::TooManyNodes { .. })
        ));
    }

    #[test]
    fn build_rejects_duplicate_ids() {
        let g = Graph::new(2, vec![(0, 1)], vec![0, 1]);
        let h = Graph::new(3, vec![(0, 1)], vec![0, 1, 2]);
        // Same id, different graphs: still a duplicate — ids are the
        // ranking identity, not the content fingerprint.
        let err = Corpus::build("dup-id", &[(7, g.clone()), (7, h)], 8, 4).unwrap_err();
        assert_eq!(err, CorpusError::DuplicateId { id: 7 });
        assert!(err.to_string().contains("duplicate candidate id 7"));
        // Distinct ids over identical graphs are fine (that's the
        // fingerprint-dedup case, not an id collision).
        assert!(Corpus::build("ok", &[(1, g.clone()), (2, g)], 8, 4).is_ok());
    }

    #[test]
    fn epoch_stamps_and_defaults() {
        let g = Graph::new(2, vec![(0, 1)], vec![0, 1]);
        let c = Corpus::build("e0", &[(0, g)], 8, 4).unwrap();
        assert_eq!(c.epoch(), 0, "standalone builds are generation 0");
        let c = c.with_epoch(41);
        assert_eq!(c.epoch(), 41);
    }

    #[test]
    fn rank_sorts_desc_clamps_k_and_breaks_ties_by_id() {
        let c = corpus_with_dup();
        let scores = [0.3, 0.9, 0.5, 0.9, 0.1, 0.5];
        let top = c.rank(&scores, 4);
        assert_eq!(top, vec![(1, 0.9), (3, 0.9), (2, 0.5), (5, 0.5)]);
        // k larger than the corpus: everything, still ordered.
        let all = c.rank(&scores, 100);
        assert_eq!(all.len(), 6);
        assert_eq!(all[5], (4, 0.1));
        // k == 0 is a valid (empty) request.
        assert!(c.rank(&scores, 0).is_empty());
    }

    #[test]
    fn shards_tile_the_corpus_balanced() {
        let c = corpus_with_dup(); // 6 candidates
        // 6 over 4 lanes: sizes 2,2,1,1 — never more than one apart.
        let shards = c.shards(4);
        assert_eq!(shards.len(), 4);
        let sizes: Vec<usize> = shards.iter().map(CorpusShard::len).collect();
        assert_eq!(sizes, vec![2, 2, 1, 1]);
        // Contiguous tiling, in order.
        assert_eq!(shards[0], CorpusShard { start: 0, end: 2 });
        assert_eq!(shards[3], CorpusShard { start: 5, end: 6 });
        let mut covered = 0;
        for s in &shards {
            assert_eq!(s.start, covered);
            assert!(!s.is_empty());
            assert_eq!(c.shard_graphs(*s).len(), s.len());
            covered = s.end;
        }
        assert_eq!(covered, c.len());
        // n clamps to the candidate count; 1 shard is the whole corpus.
        assert_eq!(c.shards(100).len(), 6);
        assert_eq!(c.shards(1), vec![CorpusShard { start: 0, end: 6 }]);
        assert_eq!(c.shards(0), c.shards(1), "n=0 clamps up to one shard");
        let empty = Corpus::build("e", &[], 8, 4).unwrap();
        assert!(empty.shards(3).is_empty());
    }

    #[test]
    fn shard_unique_counts_follow_fingerprints() {
        let c = corpus_with_dup(); // entry 5 duplicates entry 0
        let whole = c.shards(1)[0];
        assert_eq!(c.unique_in(whole), c.unique_graphs());
        // Split so the duplicate lands in a different shard than its
        // original: both shards then count it as locally unique.
        let shards = c.shards(2); // 0..3, 3..6
        assert_eq!(c.unique_in(shards[0]) + c.unique_in(shards[1]), 6);
        // A shard containing both copies counts the pair once.
        let both = CorpusShard { start: 0, end: 6 };
        assert_eq!(c.unique_in(both), 5);
    }

    #[test]
    fn shard_plan_precomputes_per_shard_uniques() {
        let c = corpus_with_dup();
        for n in 1..=6 {
            let plan = c.shard_plan(n);
            assert_eq!(plan.shards, c.shards(n), "n={n}");
            let expect: Vec<usize> =
                plan.shards.iter().map(|s| c.unique_in(*s)).collect();
            assert_eq!(plan.uniques, expect, "n={n}");
        }
    }

    #[test]
    fn prune_keeps_nearest_by_cheap_signals_deterministically() {
        // Candidates at increasing cheap-distance from a 2-node query:
        // ids 0,1 are 2-node graphs (distance 0 to the query profile),
        // then progressively larger graphs.
        let mk = |n: usize| {
            Graph::new(n, (1..n).map(|v| (0u16, v as u16)).collect(), vec![1; n])
        };
        let entries: Vec<(u64, Graph)> =
            (0..8).map(|i| (i as u64, mk(2 + (i as usize) / 2))).collect();
        let c = Corpus::build("prune", &entries, 16, 4).unwrap();
        let q = CheapSignals::from_graph(&mk(2), 4);
        let plan = c.prune(&q, 3);
        assert_eq!(plan.survivors, 3);
        assert_eq!(plan.pruned, 5);
        assert_eq!(plan.keep.iter().filter(|&&k| k).count(), 3);
        // ids 0,1 tie at distance 0; id 2 wins the next slot on the
        // (distance, index) key over its equal-distance peer id 3.
        assert_eq!(plan.keep[..4], [true, true, true, false]);
        // Deterministic across calls (timing aside).
        assert_eq!(c.prune(&q, 3).keep, plan.keep);
        // Budget >= len keeps everything; budget 0 clamps to 1.
        assert_eq!(c.prune(&q, 100).survivors, 8);
        assert_eq!(c.prune(&q, 0).survivors, 1);
    }

    fn part<'a>(c: &Corpus, shard: CorpusShard, scores: &'a [f32]) -> ShardPartial<'a> {
        ShardPartial {
            epoch: c.epoch(),
            shard,
            scores,
        }
    }

    #[test]
    fn rank_sharded_matches_rank_and_rejects_bad_coverage() {
        let c = corpus_with_dup();
        let scores = [0.3, 0.9, 0.5, 0.9, 0.1, 0.5];
        for n in 1..=6 {
            let shards = c.shards(n);
            let partials: Vec<ShardPartial> = shards
                .iter()
                .map(|s| part(&c, *s, &scores[s.start..s.end]))
                .collect();
            for k in [0usize, 1, 3, 6, 13] {
                assert_eq!(
                    c.rank_sharded(&partials, k).unwrap(),
                    c.rank(&scores, k),
                    "n={n} k={k}"
                );
            }
        }
        // A gap, an overlap, and a length mismatch are each rejected.
        let s02 = CorpusShard { start: 0, end: 2 };
        let s26 = CorpusShard { start: 2, end: 6 };
        assert!(c.rank_sharded(&[part(&c, s02, &scores[0..2])], 3).is_err());
        assert!(c
            .rank_sharded(
                &[
                    part(&c, s02, &scores[0..2]),
                    part(&c, s02, &scores[0..2]),
                    part(&c, s26, &scores[2..6])
                ],
                3
            )
            .is_err());
        assert!(c
            .rank_sharded(
                &[part(&c, s02, &scores[0..1]), part(&c, s26, &scores[2..6])],
                3
            )
            .is_err());
        let oob = CorpusShard { start: 4, end: 9 };
        assert!(c.rank_sharded(&[part(&c, oob, &scores[0..5])], 3).is_err());
    }

    #[test]
    fn rank_sharded_rejects_mixed_epoch_partials() {
        let c = corpus_with_dup().with_epoch(3);
        let scores = [0.3, 0.9, 0.5, 0.9, 0.1, 0.5];
        let shards = c.shards(2);
        // Both partials at the corpus epoch: fine.
        let good: Vec<ShardPartial> = shards
            .iter()
            .map(|s| part(&c, *s, &scores[s.start..s.end]))
            .collect();
        assert!(c.rank_sharded(&good, 3).is_ok());
        // One partial scored against an older generation: refused with
        // the typed epoch error even though coverage would be perfect.
        let mut mixed = good.clone();
        mixed[1].epoch = 2;
        assert_eq!(
            c.rank_sharded(&mixed, 3).unwrap_err(),
            ShardCoverageError::EpochMismatch {
                expected: 3,
                got: 2
            }
        );
    }

    #[test]
    fn from_db_uses_positions_as_ids() {
        let mut rng = Rng::new(62);
        let db = GraphDb::synthesize(&mut rng, Family::Aids, 4, 32, 29);
        let c = Corpus::from_db("db", &db, 32, 29).unwrap();
        assert_eq!(c.len(), 4);
        assert_eq!(c.ids(), &[0, 1, 2, 3]);
        assert!(!c.is_empty());
    }
}
