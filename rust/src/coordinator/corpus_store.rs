//! Live corpora (DESIGN.md S20): a [`CorpusStore`] owns a sequence of
//! immutable [`Corpus`] generations and swaps the current one
//! atomically per mutation. Readers grab an [`Arc<CorpusSnapshot>`]
//! exactly once at admission and keep scoring against it no matter how
//! many upserts land mid-flight — a query can never observe two
//! generations, and `rank_sharded`'s epoch check makes mixing a typed
//! error rather than a silent mis-rank.
//!
//! This file is the ONLY place production code may construct a corpus
//! snapshot (`Arc<Corpus>`): the EPOCH-SWAP-CONFINED lint rule pins
//! every other `Arc::new(Corpus...)` site to test code.
//!
//! Each commit re-encodes the full entry set. That keeps generation
//! construction trivially correct (every `Corpus` invariant — balanced
//! shards, `prev_same` dedup links, cheap-signal sidecars — is rebuilt
//! from scratch) at O(corpus) cost per mutation; incremental re-encode
//! of only the touched entries is future work noted in DESIGN.md.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::graph::dataset::GraphDb;
use crate::graph::encode::{encode, GraphKey};
use crate::graph::Graph;

use super::corpus::{Corpus, CorpusError};

/// One immutable corpus generation. `epoch` duplicates
/// `corpus.epoch()` so callers holding the snapshot can read it
/// without touching the corpus.
#[derive(Debug, Clone)]
pub struct CorpusSnapshot {
    /// Generation number, strictly increasing per committed mutation.
    pub epoch: u64,
    /// The generation's candidates, encoded and fingerprinted.
    pub corpus: Arc<Corpus>,
}

/// What a committed (or deduplicated) mutation left behind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CommitOutcome {
    /// Epoch now current (unchanged when `mutated` is false).
    pub epoch: u64,
    /// Candidate count now current.
    pub size: usize,
    /// False when the mutation was a no-op (fingerprint-identical
    /// upsert, or removing an id the store never held) — no new
    /// generation was published.
    pub mutated: bool,
}

/// The mutable master record behind the snapshots.
#[derive(Debug)]
struct StoreInner {
    /// Entries in candidate order — the order every generation's
    /// shards tile.
    entries: Vec<(u64, Graph)>,
    /// id -> position in `entries`.
    index: HashMap<u64, usize>,
    /// Content fingerprints parallel to `entries`, for ingest dedup.
    keys: Vec<GraphKey>,
    /// Epoch of the currently published generation.
    epoch: u64,
}

/// A named, mutable corpus publishing immutable epoch snapshots.
#[derive(Debug)]
pub struct CorpusStore {
    name: String,
    n_max: usize,
    num_labels: usize,
    /// Master entries + dedup index; held across rebuild-and-swap so
    /// mutations serialize (single writer, many snapshot readers).
    inner: Mutex<StoreInner>,
    /// The published generation; `snapshot()` clones the Arc.
    snap: Mutex<Arc<CorpusSnapshot>>,
}

impl CorpusStore {
    /// Build a store from explicit `(id, graph)` entries and publish
    /// generation 1. Duplicate ids and unservable graphs are rejected
    /// exactly as [`Corpus::build`] rejects them.
    pub fn build(
        name: impl Into<String>,
        entries: &[(u64, Graph)],
        n_max: usize,
        num_labels: usize,
    ) -> Result<Self, CorpusError> {
        let name = name.into();
        let corpus = Corpus::build(name.clone(), entries, n_max, num_labels)?.with_epoch(1);
        Ok(Self::assemble(name, entries.to_vec(), n_max, num_labels, corpus))
    }

    /// Build from a graph database, ids = positions (the live analogue
    /// of [`Corpus::from_db`]).
    pub fn from_db(
        name: impl Into<String>,
        db: &GraphDb,
        n_max: usize,
        num_labels: usize,
    ) -> Result<Self, CorpusError> {
        let entries: Vec<(u64, Graph)> = db
            .graphs
            .iter()
            .enumerate()
            .map(|(i, g)| (i as u64, g.clone()))
            .collect();
        Self::build(name, &entries, n_max, num_labels)
    }

    /// Wrap an already-built corpus as the current generation (at the
    /// corpus's own epoch). Mainly for tests and adapters that hold an
    /// `Arc<Corpus>` and need store-shaped plumbing; the master entry
    /// list is recovered by decoding the encoded candidates (decode
    /// cannot fail for a corpus that came through `encode`).
    pub fn adopt(corpus: Arc<Corpus>) -> Self {
        let entries: Vec<(u64, Graph)> = corpus
            .ids()
            .iter()
            .zip(corpus.graphs())
            .filter_map(|(id, e)| e.decode().ok().map(|g| (*id, g)))
            .collect();
        debug_assert_eq!(entries.len(), corpus.len(), "adopted corpus must decode");
        let name = corpus.name().to_string();
        let (n_max, num_labels) = (corpus.n_max(), corpus.num_labels());
        let epoch = corpus.epoch();
        let keys = corpus.keys().to_vec();
        let index = entries
            .iter()
            .enumerate()
            .map(|(pos, (id, _))| (*id, pos))
            .collect();
        CorpusStore {
            name,
            n_max,
            num_labels,
            inner: Mutex::new(StoreInner {
                entries,
                index,
                keys,
                epoch,
            }),
            snap: Mutex::new(Arc::new(CorpusSnapshot { epoch, corpus })),
        }
    }

    fn assemble(
        name: String,
        entries: Vec<(u64, Graph)>,
        n_max: usize,
        num_labels: usize,
        corpus: Corpus,
    ) -> Self {
        let epoch = corpus.epoch();
        let keys = corpus.keys().to_vec();
        let index = entries
            .iter()
            .enumerate()
            .map(|(pos, (id, _))| (*id, pos))
            .collect();
        CorpusStore {
            name,
            n_max,
            num_labels,
            inner: Mutex::new(StoreInner {
                entries,
                index,
                keys,
                epoch,
            }),
            snap: Mutex::new(Arc::new(CorpusSnapshot {
                epoch,
                corpus: Arc::new(corpus),
            })),
        }
    }

    /// The store's corpus name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The current generation. This is the ONE resolution point: take
    /// it once per query at admission and pass the same snapshot to
    /// every downstream stage.
    pub fn snapshot(&self) -> Arc<CorpusSnapshot> {
        let snap = self.snap.lock().unwrap_or_else(|p| p.into_inner());
        Arc::clone(&snap)
    }

    /// Epoch of the current generation.
    pub fn epoch(&self) -> u64 {
        self.snapshot().epoch
    }

    /// Insert or replace candidate `id`. An upsert whose graph is
    /// fingerprint-identical to what the store already holds at `id`
    /// is a dedup no-op: no rebuild, no epoch bump. Anything else
    /// rebuilds and publishes generation `epoch + 1`.
    pub fn upsert(&self, id: u64, graph: Graph) -> Result<CommitOutcome, CorpusError> {
        // Validate + fingerprint before taking the lock: a rejected
        // graph must not stall readers or writers.
        let key = encode(&graph, self.n_max, self.num_labels)
            .map_err(CorpusError::Encode)?
            .fingerprint();
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        match inner.index.get(&id).copied() {
            Some(pos) => {
                if inner.keys[pos] == key {
                    return Ok(CommitOutcome {
                        epoch: inner.epoch,
                        size: inner.entries.len(),
                        mutated: false,
                    });
                }
                inner.entries[pos] = (id, graph);
                inner.keys[pos] = key;
            }
            None => {
                let pos = inner.entries.len();
                inner.entries.push((id, graph));
                inner.keys.push(key);
                inner.index.insert(id, pos);
            }
        }
        self.commit(&mut inner)
    }

    /// Remove candidate `id`. Removing an id the store never held is a
    /// no-op (no epoch bump).
    pub fn remove(&self, id: u64) -> Result<CommitOutcome, CorpusError> {
        let mut inner = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        match inner.index.remove(&id) {
            None => Ok(CommitOutcome {
                epoch: inner.epoch,
                size: inner.entries.len(),
                mutated: false,
            }),
            Some(pos) => {
                inner.entries.remove(pos);
                inner.keys.remove(pos);
                // Later entries shifted down one position (disjoint
                // field borrows through the guard).
                let StoreInner { entries, index, .. } = &mut *inner;
                for (i, (eid, _)) in entries.iter().enumerate().skip(pos) {
                    index.insert(*eid, i);
                }
                self.commit(&mut inner)
            }
        }
    }

    /// Rebuild the corpus from the master entries and publish it as
    /// the next generation. Caller holds the `inner` lock, so commits
    /// serialize and epochs are strictly increasing; readers only ever
    /// see fully-built generations through `snap`.
    fn commit(&self, inner: &mut StoreInner) -> Result<CommitOutcome, CorpusError> {
        let next = inner.epoch + 1;
        let corpus = Corpus::build(
            self.name.clone(),
            &inner.entries,
            self.n_max,
            self.num_labels,
        )?
        .with_epoch(next);
        inner.epoch = next;
        let published = Arc::new(CorpusSnapshot {
            epoch: next,
            corpus: Arc::new(corpus),
        });
        *self.snap.lock().unwrap_or_else(|p| p.into_inner()) = published;
        Ok(CommitOutcome {
            epoch: next,
            size: inner.entries.len(),
            mutated: true,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::corpus::CorpusShard;
    use crate::graph::encode::EncodeError;

    fn g(n: usize, label: u16) -> Graph {
        Graph::new(
            n,
            (1..n).map(|v| (0u16, v as u16)).collect(),
            vec![label; n],
        )
    }

    #[test]
    fn build_publishes_generation_one() {
        let store = CorpusStore::build("live", &[(0, g(2, 0)), (1, g(3, 1))], 8, 4).unwrap();
        let snap = store.snapshot();
        assert_eq!(snap.epoch, 1);
        assert_eq!(snap.corpus.epoch(), 1);
        assert_eq!(snap.corpus.len(), 2);
        assert_eq!(store.epoch(), 1);
        assert_eq!(store.name(), "live");
    }

    #[test]
    fn upserts_bump_epochs_and_old_snapshots_stay_frozen() {
        let store = CorpusStore::build("live", &[(0, g(2, 0))], 8, 4).unwrap();
        let before = store.snapshot();
        let out = store.upsert(1, g(3, 1)).unwrap();
        assert_eq!(
            out,
            CommitOutcome {
                epoch: 2,
                size: 2,
                mutated: true
            }
        );
        // The pre-mutation snapshot is untouched — an in-flight query
        // holding it keeps its one-candidate view.
        assert_eq!(before.epoch, 1);
        assert_eq!(before.corpus.len(), 1);
        let after = store.snapshot();
        assert_eq!(after.epoch, 2);
        assert_eq!(after.corpus.len(), 2);
        assert_eq!(after.corpus.ids(), &[0, 1]);
        // Replacing an existing id keeps the size and its position.
        let out = store.upsert(0, g(4, 2)).unwrap();
        assert_eq!(out.epoch, 3);
        assert_eq!(out.size, 2);
        assert_eq!(store.snapshot().corpus.ids(), &[0, 1]);
        assert_eq!(store.snapshot().corpus.graphs()[0].num_nodes, 4);
    }

    #[test]
    fn fingerprint_identical_upsert_is_a_dedup_noop() {
        let store = CorpusStore::build("live", &[(0, g(2, 0))], 8, 4).unwrap();
        let out = store.upsert(0, g(2, 0)).unwrap();
        assert_eq!(
            out,
            CommitOutcome {
                epoch: 1,
                size: 1,
                mutated: false
            }
        );
        assert_eq!(store.epoch(), 1, "no generation published");
        // Same graph under a NEW id is a real insert, not a dedup.
        let out = store.upsert(9, g(2, 0)).unwrap();
        assert!(out.mutated);
        assert_eq!(out.size, 2);
    }

    #[test]
    fn remove_commits_and_unknown_ids_are_noops() {
        let store =
            CorpusStore::build("live", &[(0, g(2, 0)), (1, g(3, 1)), (2, g(4, 2))], 8, 4).unwrap();
        let out = store.remove(1).unwrap();
        assert_eq!(
            out,
            CommitOutcome {
                epoch: 2,
                size: 2,
                mutated: true
            }
        );
        assert_eq!(store.snapshot().corpus.ids(), &[0, 2]);
        // The shifted entry's id still resolves (index was rebuilt):
        // replacing it lands at its new position.
        let out = store.upsert(2, g(5, 3)).unwrap();
        assert!(out.mutated);
        assert_eq!(store.snapshot().corpus.ids(), &[0, 2]);
        assert_eq!(store.snapshot().corpus.graphs()[1].num_nodes, 5);
        // Unknown id: no-op.
        let out = store.remove(77).unwrap();
        assert!(!out.mutated);
        assert_eq!(out.epoch, 3);
    }

    #[test]
    fn rejects_unservable_upserts_without_publishing() {
        let store = CorpusStore::build("live", &[(0, g(2, 0))], 8, 4).unwrap();
        let err = store.upsert(1, g(20, 0)).unwrap_err();
        assert!(matches!(
            err,
            CorpusError::Encode(EncodeError::TooManyNodes { .. })
        ));
        assert_eq!(store.epoch(), 1);
        assert_eq!(store.snapshot().corpus.len(), 1);
    }

    #[test]
    fn shards_stay_balanced_as_the_store_grows() {
        let store = CorpusStore::build("live", &[(0, g(2, 0))], 16, 4).unwrap();
        for i in 1..10u64 {
            store.upsert(i, g(2 + (i as usize % 5), (i % 4) as u16)).unwrap();
        }
        let snap = store.snapshot();
        assert_eq!(snap.corpus.len(), 10);
        for n in [1usize, 3, 4, 7] {
            let shards = snap.corpus.shards(n);
            let sizes: Vec<usize> = shards.iter().map(CorpusShard::len).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1, "n={n}: unbalanced {sizes:?}");
            assert_eq!(sizes.iter().sum::<usize>(), 10);
        }
    }

    #[test]
    fn adopt_wraps_an_existing_corpus_and_mutates_from_there() {
        let corpus = Arc::new(
            Corpus::build("adopted", &[(3, g(2, 0)), (4, g(3, 1))], 8, 4).unwrap(),
        );
        let store = CorpusStore::adopt(Arc::clone(&corpus));
        assert_eq!(store.name(), "adopted");
        let snap = store.snapshot();
        assert_eq!(snap.epoch, 0, "adopted at the corpus's own epoch");
        assert!(Arc::ptr_eq(&snap.corpus, &corpus), "no rebuild on adopt");
        // Dedup state survived adoption: re-upserting an existing graph
        // under its id is a no-op.
        assert!(!store.upsert(3, g(2, 0)).unwrap().mutated);
        // And a real mutation publishes the next generation.
        let out = store.upsert(5, g(4, 2)).unwrap();
        assert_eq!(out.epoch, 1);
        assert_eq!(store.snapshot().corpus.ids(), &[3, 4, 5]);
    }
}
