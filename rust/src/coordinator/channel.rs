//! Named bounded channels: the software analogue of the paper's FIFO
//! streams between accelerator stages.
//!
//! Every inter-stage queue in the serving pipeline is a `NamedChannel`:
//! a bounded `sync_channel` plus a name, a capacity, occupancy gauges
//! (current/peak depth, sent/dropped counters) and an explicit send
//! policy. The gauges are what let the serve report show *where* a
//! pipeline stalls — the same per-FIFO occupancy visibility LW-GCN and
//! Accel-GCN use to diagnose accelerator pipeline bubbles, recovered
//! here for the host-side pipeline.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{
    sync_channel, Receiver, RecvError, RecvTimeoutError, SyncSender, TryRecvError, TrySendError,
};
use std::sync::Arc;
use std::time::Duration;

/// What a sender does when the channel is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SendPolicy {
    /// Block until space frees up (backpressure; must-deliver traffic).
    Block,
    /// Return the value to the caller immediately (caller decides).
    Try,
    /// Drop the value, count it, and log the first occurrence (load
    /// shedding for traffic where freshness beats completeness).
    DropWithLog,
    /// Drop the value and count it, silently. For front-door admission
    /// queues where the *sender* turns the drop into a typed
    /// retry-after response — the client hears about every drop, so
    /// logging each one server-side would only duplicate the signal.
    DropNewest,
}

/// Live occupancy counters for one channel, shared by all its senders
/// and its receiver. Relaxed atomics: these are statistics, not
/// synchronization.
#[derive(Debug)]
pub struct ChannelStats {
    name: String,
    capacity: usize,
    depth: AtomicUsize,
    max_depth: AtomicUsize,
    sent: AtomicU64,
    dropped: AtomicU64,
    shed: AtomicU64,
}

impl ChannelStats {
    fn new(name: &str, capacity: usize) -> Self {
        ChannelStats {
            name: name.to_string(),
            capacity,
            depth: AtomicUsize::new(0),
            max_depth: AtomicUsize::new(0),
            sent: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            shed: AtomicU64::new(0),
        }
    }

    /// Called BEFORE the underlying send so the gauge increment always
    /// precedes the receiver's decrement (else a fast consumer could
    /// underflow `depth`). Returns the provisional depth; the caller
    /// commits it to `max_depth` only once the send is known to have
    /// gone through (or, for blocking sends, is about to park — blocked
    /// senders are deliberately part of the peak).
    fn note_send(&self) -> usize {
        self.sent.fetch_add(1, Ordering::Relaxed);
        self.depth.fetch_add(1, Ordering::Relaxed) + 1
    }

    fn commit_depth(&self, provisional: usize) {
        self.max_depth.fetch_max(provisional, Ordering::Relaxed);
    }

    /// Undo a `note_send` whose send did not go through.
    fn unsend(&self) {
        self.sent.fetch_sub(1, Ordering::Relaxed);
        self.depth.fetch_sub(1, Ordering::Relaxed);
    }

    fn note_recv(&self) {
        self.depth.fetch_sub(1, Ordering::Relaxed);
    }

    /// Returns the post-increment drop count.
    fn note_drop(&self) -> u64 {
        self.dropped.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Receiver-side shed accounting: the item was *delivered* (it
    /// counted as sent and occupied depth) but the consumer discarded
    /// it unprocessed — e.g. a front-door frame dequeued after its
    /// deadline. Distinct from `dropped`, which counts items that never
    /// entered the queue.
    pub fn note_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Current occupancy (buffered items + senders mid-send). A load
    /// signal, not a synchronization primitive.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn snapshot(&self) -> ChannelSnapshot {
        ChannelSnapshot {
            name: self.name.clone(),
            capacity: self.capacity,
            sent: self.sent.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            max_depth: self.max_depth.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of a channel's counters, for reports.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChannelSnapshot {
    pub name: String,
    pub capacity: usize,
    pub sent: u64,
    pub dropped: u64,
    /// Items delivered but discarded unprocessed by the consumer
    /// (deadline shedding at dequeue — see [`ChannelStats::note_shed`]).
    pub shed: u64,
    /// Peak occupancy observed over the channel's lifetime. The gauge
    /// counts buffered items plus senders mid-send (the increment happens
    /// before the blocking send, so it can exceed `capacity` by the
    /// number of blocked senders, and a lone handed-over item already
    /// reads 1). Interpretation: a peak of 2+ on a stage-feeding channel
    /// means work queued up while the consumer was busy — the witness
    /// that producer and consumer stages genuinely ran concurrently;
    /// a peak of 0-1 means the consumer was never behind.
    pub max_depth: usize,
}

/// Outcome of a [`NamedSender::send`].
#[derive(Debug)]
pub enum SendResult<T> {
    Sent,
    /// `Try` policy only: channel full, value handed back.
    Full(T),
    /// `DropWithLog` / `DropNewest` policies only: channel full, value
    /// dropped + counted.
    Dropped,
    /// Receiver gone; value handed back.
    Disconnected(T),
}

impl<T> SendResult<T> {
    pub fn is_sent(&self) -> bool {
        matches!(self, SendResult::Sent)
    }
}

/// Sending half. Clonable; all clones share the same stats.
pub struct NamedSender<T> {
    tx: SyncSender<T>,
    policy: SendPolicy,
    stats: Arc<ChannelStats>,
}

// Manual impl: prints the channel identity, not the payload type, so no
// `T: Debug` bound leaks into every queue element.
impl<T> std::fmt::Debug for NamedSender<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("NamedSender")
            .field("channel", &self.stats.name)
            .field("policy", &self.policy)
            .finish_non_exhaustive()
    }
}

impl<T> Clone for NamedSender<T> {
    fn clone(&self) -> Self {
        NamedSender {
            tx: self.tx.clone(),
            policy: self.policy,
            stats: Arc::clone(&self.stats),
        }
    }
}

impl<T> NamedSender<T> {
    pub fn send(&self, v: T) -> SendResult<T> {
        let provisional = self.stats.note_send();
        match self.policy {
            SendPolicy::Block => {
                // Peak includes senders parked on a full channel: that
                // backpressure is exactly what the gauge should show.
                self.stats.commit_depth(provisional);
                match self.tx.send(v) {
                    Ok(()) => SendResult::Sent,
                    Err(e) => {
                        self.stats.unsend();
                        SendResult::Disconnected(e.0)
                    }
                }
            }
            SendPolicy::Try | SendPolicy::DropWithLog | SendPolicy::DropNewest => {
                match self.tx.try_send(v) {
                    Ok(()) => {
                        self.stats.commit_depth(provisional);
                        SendResult::Sent
                    }
                    Err(TrySendError::Full(v)) => {
                        // Failed attempt: retract without touching max_depth,
                        // so peaks never count items that were never queued.
                        self.stats.unsend();
                        match self.policy {
                            SendPolicy::Try => SendResult::Full(v),
                            SendPolicy::DropNewest => {
                                self.stats.note_drop();
                                SendResult::Dropped
                            }
                            _ => {
                                if self.stats.note_drop() == 1 {
                                    eprintln!(
                                        "channel '{}' full (cap {}): dropping (further drops counted silently)",
                                        self.stats.name, self.stats.capacity
                                    );
                                }
                                SendResult::Dropped
                            }
                        }
                    }
                    Err(TrySendError::Disconnected(v)) => {
                        self.stats.unsend();
                        SendResult::Disconnected(v)
                    }
                }
            }
        }
    }

    pub fn stats(&self) -> Arc<ChannelStats> {
        Arc::clone(&self.stats)
    }
}

/// Receiving half. Single consumer, like `mpsc::Receiver`.
pub struct NamedReceiver<T> {
    rx: Receiver<T>,
    stats: Arc<ChannelStats>,
}

impl<T> std::fmt::Debug for NamedReceiver<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("NamedReceiver")
            .field("channel", &self.stats.name)
            .finish_non_exhaustive()
    }
}

impl<T> NamedReceiver<T> {
    pub fn recv(&self) -> Result<T, RecvError> {
        let v = self.rx.recv()?;
        self.stats.note_recv();
        Ok(v)
    }

    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvTimeoutError> {
        let v = self.rx.recv_timeout(timeout)?;
        self.stats.note_recv();
        Ok(v)
    }

    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        let v = self.rx.try_recv()?;
        self.stats.note_recv();
        Ok(v)
    }

    pub fn stats(&self) -> Arc<ChannelStats> {
        Arc::clone(&self.stats)
    }
}

/// Create a named bounded channel. Capacity 0 is a rendezvous channel.
pub fn channel<T>(
    name: &str,
    capacity: usize,
    policy: SendPolicy,
) -> (NamedSender<T>, NamedReceiver<T>) {
    let stats = Arc::new(ChannelStats::new(name, capacity));
    let (tx, rx) = sync_channel(capacity);
    (
        NamedSender {
            tx,
            policy,
            stats: Arc::clone(&stats),
        },
        NamedReceiver { rx, stats },
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn depth_and_peak_tracked() {
        let (tx, rx) = channel::<u32>("t", 8, SendPolicy::Block);
        for i in 0..3 {
            assert!(tx.send(i).is_sent());
        }
        let snap = tx.stats().snapshot();
        assert_eq!(snap.sent, 3);
        assert_eq!(snap.max_depth, 3);
        assert_eq!(rx.recv().unwrap(), 0);
        assert_eq!(rx.recv().unwrap(), 1);
        // Peak is monotonic even after drains.
        assert_eq!(rx.stats().snapshot().max_depth, 3);
        assert_eq!(rx.try_recv().unwrap(), 2);
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn try_policy_returns_value_when_full() {
        let (tx, _rx) = channel::<u32>("t", 1, SendPolicy::Try);
        assert!(tx.send(7).is_sent());
        match tx.send(8) {
            SendResult::Full(v) => assert_eq!(v, 8),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(tx.stats().snapshot().dropped, 0);
    }

    #[test]
    fn drop_policy_counts_drops() {
        let (tx, rx) = channel::<u32>("t", 1, SendPolicy::DropWithLog);
        assert!(tx.send(1).is_sent());
        assert!(matches!(tx.send(2), SendResult::Dropped));
        assert!(matches!(tx.send(3), SendResult::Dropped));
        let snap = tx.stats().snapshot();
        assert_eq!(snap.sent, 1);
        assert_eq!(snap.dropped, 2);
        assert_eq!(rx.recv().unwrap(), 1);
    }

    #[test]
    fn drop_newest_policy_counts_silently() {
        let (tx, rx) = channel::<u32>("t", 1, SendPolicy::DropNewest);
        assert!(tx.send(1).is_sent());
        assert!(matches!(tx.send(2), SendResult::Dropped));
        assert!(matches!(tx.send(3), SendResult::Dropped));
        let snap = tx.stats().snapshot();
        assert_eq!(snap.sent, 1, "drops never count as sent");
        assert_eq!(snap.dropped, 2);
        assert_eq!(snap.shed, 0, "sender-side drops are not sheds");
        assert_eq!(snap.max_depth, 1, "dropped items never occupy depth");
        // The survivor is the OLDEST item: DropNewest sheds arrivals,
        // not queued work.
        assert_eq!(rx.recv().unwrap(), 1);
    }

    #[test]
    fn block_policy_never_drops() {
        let (tx, rx) = channel::<u32>("t", 2, SendPolicy::Block);
        for i in 0..2 {
            assert!(tx.send(i).is_sent());
        }
        let snap = tx.stats().snapshot();
        assert_eq!((snap.sent, snap.dropped, snap.shed), (2, 0, 0));
        drop(rx);
    }

    #[test]
    fn receiver_side_shed_accounting() {
        let (tx, rx) = channel::<u32>("t", 4, SendPolicy::DropNewest);
        assert!(tx.send(1).is_sent());
        assert!(tx.send(2).is_sent());
        // Consumer dequeues both but discards the first unprocessed
        // (e.g. its deadline passed while queued).
        assert_eq!(rx.recv().unwrap(), 1);
        rx.stats().note_shed();
        assert_eq!(rx.recv().unwrap(), 2);
        let snap = rx.stats().snapshot();
        assert_eq!(snap.sent, 2, "shed items still count as sent");
        assert_eq!(snap.dropped, 0, "sheds are not sender-side drops");
        assert_eq!(snap.shed, 1);
    }

    #[test]
    fn depth_gauge_reads_current_occupancy() {
        let (tx, rx) = channel::<u32>("t", 4, SendPolicy::Block);
        assert_eq!(tx.stats().depth(), 0);
        assert!(tx.send(1).is_sent());
        assert!(tx.send(2).is_sent());
        assert_eq!(tx.stats().depth(), 2);
        assert_eq!(rx.recv().unwrap(), 1);
        assert_eq!(tx.stats().depth(), 1);
    }

    #[test]
    fn disconnect_hands_value_back() {
        let (tx, rx) = channel::<String>("t", 4, SendPolicy::Block);
        drop(rx);
        match tx.send("hello".to_string()) {
            SendResult::Disconnected(v) => assert_eq!(v, "hello"),
            other => panic!("expected Disconnected, got {other:?}"),
        }
    }

    #[test]
    fn cross_thread_delivery() {
        let (tx, rx) = channel::<u64>("t", 2, SendPolicy::Block);
        let h = std::thread::spawn(move || {
            // More sends than capacity: exercises blocking backpressure.
            for i in 0..10u64 {
                assert!(tx.send(i).is_sent());
            }
        });
        let got: Vec<u64> = std::iter::from_fn(|| rx.recv().ok()).collect();
        h.join().unwrap();
        assert_eq!(got, (0..10).collect::<Vec<_>>());
        let snap = rx.stats().snapshot();
        assert_eq!(snap.sent, 10);
        // Peak is bounded by capacity plus one in-flight blocked sender.
        assert!(snap.max_depth <= 3, "peak {} too high", snap.max_depth);
    }
}
