//! Query and result types flowing through the serving coordinator.

use std::sync::Arc;
use std::time::Instant;

use crate::graph::Graph;
use crate::runtime::{EngineError, QueryTelemetry};

use super::corpus::{Corpus, PrunePlan};

/// The exactness contract of a top-k query (DESIGN.md S20).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CascadeMode {
    /// Score every candidate — bit-identical to the pre-cascade path.
    Exact,
    /// Coarse-to-fine: rule candidates out with cheap signals until at
    /// most `budget` survive, then run the exact NTN+FCN tail over the
    /// survivors only. Candidates whose cheap profile is far from the
    /// query's can be ranked out without ever being scored, so the
    /// returned ranking is best-effort below the survivor cut.
    Budgeted {
        /// Maximum candidates the exact stage may score (clamped to at
        /// least 1).
        budget: usize,
    },
}

/// What one query asks for: an independent pair score (the original
/// workload unit) or a one-vs-many ranking against a registered corpus
/// (the paper's similarity-search use case). Both ride the same
/// admission → batcher → executor pipeline.
#[derive(Debug, Clone)]
pub enum QueryPayload {
    /// Score one graph pair.
    Pair {
        /// First graph of the pair.
        g1: Graph,
        /// Second graph of the pair.
        g2: Graph,
    },
    /// Rank `corpus` by similarity to `graph`, keep the best `k`.
    TopK {
        /// The query graph (embedded once, cache-aware).
        graph: Graph,
        /// Shared candidate set (pre-encoded, fingerprinted). Resolved
        /// exactly once at admission — every stage downstream scores
        /// and merges against this same snapshot.
        corpus: Arc<Corpus>,
        /// How many ranked candidates to return (clamped to the corpus).
        k: usize,
        /// Cached copy of `corpus.epoch()` — the generation this query
        /// was admitted against, carried for traces and responses.
        epoch: u64,
        /// Exactness contract for this query.
        mode: CascadeMode,
        /// The coarse stage's verdict, computed once at admission for
        /// `Budgeted` queries (`None` = score everything). Shared so a
        /// scattered query's shards all read one plan.
        prune: Option<Arc<PrunePlan>>,
    },
}

/// A graph-similarity query (the unit of work, paper §5.1).
#[derive(Debug, Clone)]
pub struct Query {
    /// Caller-chosen identifier echoed back on the result.
    pub id: u64,
    /// What this query asks for.
    pub payload: QueryPayload,
    /// When the query entered the pipeline.
    pub submitted: Instant,
}

impl Query {
    /// Stamp a new pair query with the current time.
    pub fn new(id: u64, g1: Graph, g2: Graph) -> Self {
        Query {
            id,
            payload: QueryPayload::Pair { g1, g2 },
            submitted: Instant::now(),
        }
    }

    /// Stamp a new exact top-k corpus query with the current time.
    pub fn topk(id: u64, graph: Graph, corpus: Arc<Corpus>, k: usize) -> Self {
        Self::topk_with(id, graph, corpus, k, CascadeMode::Exact)
    }

    /// Stamp a new top-k corpus query with an explicit exactness
    /// contract. The epoch is pinned from the corpus snapshot here;
    /// the prune plan (for `Budgeted`) is filled in at admission.
    pub fn topk_with(
        id: u64,
        graph: Graph,
        corpus: Arc<Corpus>,
        k: usize,
        mode: CascadeMode,
    ) -> Self {
        let epoch = corpus.epoch();
        Query {
            id,
            payload: QueryPayload::TopK {
                graph,
                corpus,
                k,
                epoch,
                mode,
                prune: None,
            },
            submitted: Instant::now(),
        }
    }
}

/// Why a query was rejected before reaching an engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RejectReason {
    /// A graph exceeds the artifact's fixed `n_max`.
    TooManyNodes {
        /// Offending node count.
        nodes: usize,
        /// The artifact limit.
        n_max: usize,
    },
    /// A node label is outside the artifact's vocabulary.
    LabelOutOfRange {
        /// Offending label.
        label: u16,
        /// Vocabulary size.
        num_labels: usize,
    },
    /// A top-k query against an empty corpus (nothing to rank).
    EmptyCorpus,
    /// A top-k query whose corpus was encoded for different artifact
    /// shapes than the serving model — scoring it would index
    /// mismatched tensors (lane panic or silent garbage), so it is
    /// rejected at admission.
    CorpusShapeMismatch {
        /// Shapes the corpus was encoded for.
        corpus: (usize, usize),
        /// Shapes the serving model expects.
        model: (usize, usize),
    },
    /// The pipeline is shutting down.
    ShuttingDown,
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::TooManyNodes { nodes, n_max } => {
                write!(f, "graph has {nodes} nodes > artifact limit {n_max}")
            }
            RejectReason::LabelOutOfRange { label, num_labels } => {
                write!(f, "label {label} >= vocab {num_labels}")
            }
            RejectReason::EmptyCorpus => write!(f, "top-k query against an empty corpus"),
            RejectReason::CorpusShapeMismatch { corpus, model } => write!(
                f,
                "corpus encoded for (n_max, labels) = {corpus:?}, model expects {model:?}"
            ),
            RejectReason::ShuttingDown => write!(f, "coordinator shutting down"),
        }
    }
}

/// Outcome of one query.
#[derive(Debug, Clone)]
pub enum Outcome {
    /// Pair query scored successfully.
    Score(f32),
    /// Top-k query ranked successfully: `(corpus id, score)`, best
    /// first, at most `k` entries.
    TopK(Vec<(u64, f32)>),
    /// Rejected before reaching an engine.
    Rejected(RejectReason),
    /// An engine-side failure (typed, see [`EngineError`]).
    EngineError(EngineError),
}

/// Where one query's latency went, stage by stage (µs). The split the
/// pipeline reports: `latency_us ≈ queue_us + encode_us + execute_us`
/// plus responder/channel overhead.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageTiming {
    /// Submit -> encode start: admission + batcher + queueing time.
    pub queue_us: f64,
    /// Encode + pack time of the chunk this query rode in.
    pub encode_us: f64,
    /// Engine execution time of that chunk.
    pub execute_us: f64,
}

/// How a top-k corpus query was spread across executor lanes — the
/// scatter/gather visibility the serve report renders as
/// `topk shards mean` / `topk lane spread (ms)` (DESIGN.md S15).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShardingInfo {
    /// Corpus shards the query was scattered into (1 = served whole on
    /// one lane — the fallback when fewer than two capable lanes have
    /// published, or the corpus is too small to split).
    pub shards: usize,
    /// Slowest minus fastest shard execute time, µs: the lane-balance
    /// witness (a small spread means the contiguous-range partitioning
    /// kept every lane equally busy; 0 for unsharded queries).
    pub spread_us: f64,
}

/// What the coarse stage did for one budgeted top-k query — the
/// cascade telemetry Metrics aggregates into `cascade *` rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CascadeInfo {
    /// Candidates ruled out by cheap signals (never scored).
    pub pruned: usize,
    /// Candidates that reached the exact NTN+FCN tail.
    pub survivors: usize,
    /// Wall time of the coarse stage, µs.
    pub prune_us: u64,
}

/// Completed query with timing and engine telemetry.
#[derive(Debug, Clone)]
pub struct QueryResult {
    /// The submitting caller's query id.
    pub id: u64,
    /// What happened.
    pub outcome: Outcome,
    /// submit -> completion latency, µs.
    pub latency_us: f64,
    /// Size of the batch this query was executed in (0 for rejects).
    pub batch_size: usize,
    /// Per-stage latency split (zeros for rejects).
    pub stage: StageTiming,
    /// Engine telemetry for this query's slot (cycle report, DMA split,
    /// per-slot CPU time — whatever the engine's caps declare). A
    /// gathered top-k query carries the merged telemetry of all its
    /// shards.
    pub telemetry: QueryTelemetry,
    /// Name of the engine that served this query (from its caps), if it
    /// reached one (the embedder lane's engine for scattered queries).
    pub engine: Option<Arc<str>>,
    /// Scatter/gather shape for served top-k queries; `None` for pair
    /// queries, rejects and errors.
    pub sharding: Option<ShardingInfo>,
    /// Coarse-stage telemetry for budgeted top-k queries; `None` when
    /// the query ran `Exact` (or never reached the cascade).
    pub cascade: Option<CascadeInfo>,
}

impl QueryResult {
    /// Rejection result for a query that never reached an engine.
    pub fn rejected(q: &Query, reason: RejectReason) -> Self {
        QueryResult {
            id: q.id,
            outcome: Outcome::Rejected(reason),
            latency_us: q.submitted.elapsed().as_secs_f64() * 1e6,
            batch_size: 0,
            stage: StageTiming::default(),
            telemetry: QueryTelemetry::default(),
            engine: None,
            sharding: None,
            cascade: None,
        }
    }

    /// Engine-side failure (construction or execution).
    pub fn engine_error(q: &Query, err: EngineError, batch_size: usize) -> Self {
        QueryResult {
            id: q.id,
            outcome: Outcome::EngineError(err),
            latency_us: q.submitted.elapsed().as_secs_f64() * 1e6,
            batch_size,
            stage: StageTiming::default(),
            telemetry: QueryTelemetry::default(),
            engine: None,
            sharding: None,
            cascade: None,
        }
    }

    /// Tag this result with the engine name that produced it.
    pub fn with_engine(mut self, engine: Arc<str>) -> Self {
        self.engine = Some(engine);
        self
    }

    /// Tag this result with its scatter/gather shape.
    pub fn with_sharding(mut self, sharding: ShardingInfo) -> Self {
        self.sharding = Some(sharding);
        self
    }

    /// Tag this result with its coarse-stage telemetry.
    pub fn with_cascade(mut self, cascade: CascadeInfo) -> Self {
        self.cascade = Some(cascade);
        self
    }

    /// The score, if this pair query succeeded.
    pub fn score(&self) -> Option<f32> {
        match self.outcome {
            Outcome::Score(s) => Some(s),
            _ => None,
        }
    }

    /// The ranking, if this top-k query succeeded.
    pub fn ranked(&self) -> Option<&[(u64, f32)]> {
        match &self.outcome {
            Outcome::TopK(r) => Some(r),
            _ => None,
        }
    }

    /// True when the query was rejected before reaching an engine.
    pub fn is_rejected(&self) -> bool {
        matches!(self.outcome, Outcome::Rejected(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scored(outcome: Outcome) -> QueryResult {
        QueryResult {
            id: 1,
            outcome,
            latency_us: 10.0,
            batch_size: 4,
            stage: StageTiming::default(),
            telemetry: QueryTelemetry::default(),
            engine: None,
            sharding: None,
            cascade: None,
        }
    }

    #[test]
    fn sharding_tag_rides_the_result() {
        let r = scored(Outcome::TopK(vec![(3, 0.9)]));
        assert_eq!(r.sharding, None);
        let r = r.with_sharding(ShardingInfo { shards: 3, spread_us: 120.0 });
        assert_eq!(r.sharding, Some(ShardingInfo { shards: 3, spread_us: 120.0 }));
    }

    #[test]
    fn reject_reasons_display() {
        let r = RejectReason::TooManyNodes { nodes: 40, n_max: 32 };
        assert!(r.to_string().contains("40"));
        let r = RejectReason::LabelOutOfRange { label: 31, num_labels: 29 };
        assert!(r.to_string().contains("31"));
    }

    #[test]
    fn result_accessors() {
        let r = scored(Outcome::Score(0.5));
        assert_eq!(r.score(), Some(0.5));
        assert_eq!(r.ranked(), None);
        assert!(!r.is_rejected());
        let r = scored(Outcome::Rejected(RejectReason::ShuttingDown));
        assert_eq!(r.score(), None);
        assert!(r.is_rejected());
        let r = scored(Outcome::TopK(vec![(3, 0.9), (1, 0.2)]));
        assert_eq!(r.score(), None);
        assert_eq!(r.ranked(), Some(&[(3, 0.9), (1, 0.2)][..]));
    }

    #[test]
    fn topk_constructor_carries_payload() {
        use super::super::corpus::Corpus;
        let g = crate::graph::Graph::new(2, vec![(0, 1)], vec![0, 0]);
        let corpus =
            Arc::new(Corpus::build("c", &[(0, g.clone()), (7, g.clone())], 8, 4).unwrap());
        let q = Query::topk(9, g.clone(), Arc::clone(&corpus), 1);
        assert_eq!(q.id, 9);
        match &q.payload {
            QueryPayload::TopK {
                corpus,
                k,
                epoch,
                mode,
                prune,
                ..
            } => {
                assert_eq!(corpus.len(), 2);
                assert_eq!(*k, 1);
                assert_eq!(*epoch, 0, "standalone corpus pins epoch 0");
                assert_eq!(*mode, CascadeMode::Exact, "4-arg constructor is exact");
                assert!(prune.is_none(), "prune plans are admission's job");
            }
            other => panic!("expected TopK payload, got {other:?}"),
        }
        // topk_with pins the corpus's actual epoch and the given mode.
        let stamped = Arc::new(
            Corpus::build("c2", &[(0, g.clone())], 8, 4)
                .unwrap()
                .with_epoch(6),
        );
        let q = Query::topk_with(10, g, stamped, 1, CascadeMode::Budgeted { budget: 2 });
        match &q.payload {
            QueryPayload::TopK { epoch, mode, .. } => {
                assert_eq!(*epoch, 6);
                assert_eq!(*mode, CascadeMode::Budgeted { budget: 2 });
            }
            other => panic!("expected TopK payload, got {other:?}"),
        }
    }

    #[test]
    fn constructors_carry_query_identity() {
        let g = crate::graph::Graph::new(2, vec![(0, 1)], vec![0, 0]);
        let q = Query::new(42, g.clone(), g);
        let r = QueryResult::rejected(&q, RejectReason::ShuttingDown);
        assert_eq!(r.id, 42);
        assert!(r.is_rejected());
        assert_eq!(r.engine, None);
        let err = EngineError::Unavailable { reason: "boom".into() };
        let r = QueryResult::engine_error(&q, err, 3).with_engine(Arc::from("mock"));
        assert_eq!(r.id, 42);
        assert!(
            matches!(r.outcome, Outcome::EngineError(EngineError::Unavailable { ref reason }) if reason == "boom")
        );
        assert_eq!(r.batch_size, 3);
        assert_eq!(r.engine.as_deref(), Some("mock"));
    }
}
