//! Query and result types flowing through the serving coordinator.

use std::time::Instant;

use crate::graph::Graph;

/// A graph-similarity query (the unit of work, paper §5.1).
#[derive(Debug, Clone)]
pub struct Query {
    pub id: u64,
    pub g1: Graph,
    pub g2: Graph,
    pub submitted: Instant,
}

impl Query {
    pub fn new(id: u64, g1: Graph, g2: Graph) -> Self {
        Query {
            id,
            g1,
            g2,
            submitted: Instant::now(),
        }
    }
}

/// Why a query was rejected before reaching an engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RejectReason {
    TooManyNodes { nodes: usize, n_max: usize },
    LabelOutOfRange { label: u16, num_labels: usize },
    ShuttingDown,
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::TooManyNodes { nodes, n_max } => {
                write!(f, "graph has {nodes} nodes > artifact limit {n_max}")
            }
            RejectReason::LabelOutOfRange { label, num_labels } => {
                write!(f, "label {label} >= vocab {num_labels}")
            }
            RejectReason::ShuttingDown => write!(f, "coordinator shutting down"),
        }
    }
}

/// Outcome of one query.
#[derive(Debug, Clone)]
pub enum Outcome {
    Score(f32),
    Rejected(RejectReason),
    EngineError(String),
}

/// Completed query with timing.
#[derive(Debug, Clone)]
pub struct QueryResult {
    pub id: u64,
    pub outcome: Outcome,
    /// submit -> completion latency, µs.
    pub latency_us: f64,
    /// Size of the batch this query was executed in (0 for rejects).
    pub batch_size: usize,
}

impl QueryResult {
    pub fn score(&self) -> Option<f32> {
        match self.outcome {
            Outcome::Score(s) => Some(s),
            _ => None,
        }
    }
    pub fn is_rejected(&self) -> bool {
        matches!(self.outcome, Outcome::Rejected(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reject_reasons_display() {
        let r = RejectReason::TooManyNodes { nodes: 40, n_max: 32 };
        assert!(r.to_string().contains("40"));
        let r = RejectReason::LabelOutOfRange { label: 31, num_labels: 29 };
        assert!(r.to_string().contains("31"));
    }

    #[test]
    fn result_accessors() {
        let r = QueryResult {
            id: 1,
            outcome: Outcome::Score(0.5),
            latency_us: 10.0,
            batch_size: 4,
        };
        assert_eq!(r.score(), Some(0.5));
        assert!(!r.is_rejected());
        let r = QueryResult {
            id: 2,
            outcome: Outcome::Rejected(RejectReason::ShuttingDown),
            latency_us: 1.0,
            batch_size: 0,
        };
        assert_eq!(r.score(), None);
        assert!(r.is_rejected());
    }
}
