//! Query and result types flowing through the serving coordinator.

use std::time::Instant;

use crate::graph::Graph;

/// A graph-similarity query (the unit of work, paper §5.1).
#[derive(Debug, Clone)]
pub struct Query {
    pub id: u64,
    pub g1: Graph,
    pub g2: Graph,
    pub submitted: Instant,
}

impl Query {
    pub fn new(id: u64, g1: Graph, g2: Graph) -> Self {
        Query {
            id,
            g1,
            g2,
            submitted: Instant::now(),
        }
    }
}

/// Why a query was rejected before reaching an engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RejectReason {
    TooManyNodes { nodes: usize, n_max: usize },
    LabelOutOfRange { label: u16, num_labels: usize },
    ShuttingDown,
}

impl std::fmt::Display for RejectReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RejectReason::TooManyNodes { nodes, n_max } => {
                write!(f, "graph has {nodes} nodes > artifact limit {n_max}")
            }
            RejectReason::LabelOutOfRange { label, num_labels } => {
                write!(f, "label {label} >= vocab {num_labels}")
            }
            RejectReason::ShuttingDown => write!(f, "coordinator shutting down"),
        }
    }
}

/// Outcome of one query.
#[derive(Debug, Clone)]
pub enum Outcome {
    Score(f32),
    Rejected(RejectReason),
    EngineError(String),
}

/// Where one query's latency went, stage by stage (µs). The split the
/// pipeline reports: `latency_us ≈ queue_us + encode_us + execute_us`
/// plus responder/channel overhead.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StageTiming {
    /// Submit -> encode start: admission + batcher + queueing time.
    pub queue_us: f64,
    /// Encode + pack time of the chunk this query rode in.
    pub encode_us: f64,
    /// Engine execution time of that chunk.
    pub execute_us: f64,
}

/// Completed query with timing.
#[derive(Debug, Clone)]
pub struct QueryResult {
    pub id: u64,
    pub outcome: Outcome,
    /// submit -> completion latency, µs.
    pub latency_us: f64,
    /// Size of the batch this query was executed in (0 for rejects).
    pub batch_size: usize,
    /// Per-stage latency split (zeros for rejects).
    pub stage: StageTiming,
}

impl QueryResult {
    /// Rejection result for a query that never reached an engine.
    pub fn rejected(q: &Query, reason: RejectReason) -> Self {
        QueryResult {
            id: q.id,
            outcome: Outcome::Rejected(reason),
            latency_us: q.submitted.elapsed().as_secs_f64() * 1e6,
            batch_size: 0,
            stage: StageTiming::default(),
        }
    }

    /// Engine-side failure (construction or execution).
    pub fn engine_error(q: &Query, msg: impl Into<String>, batch_size: usize) -> Self {
        QueryResult {
            id: q.id,
            outcome: Outcome::EngineError(msg.into()),
            latency_us: q.submitted.elapsed().as_secs_f64() * 1e6,
            batch_size,
            stage: StageTiming::default(),
        }
    }

    pub fn score(&self) -> Option<f32> {
        match self.outcome {
            Outcome::Score(s) => Some(s),
            _ => None,
        }
    }
    pub fn is_rejected(&self) -> bool {
        matches!(self.outcome, Outcome::Rejected(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reject_reasons_display() {
        let r = RejectReason::TooManyNodes { nodes: 40, n_max: 32 };
        assert!(r.to_string().contains("40"));
        let r = RejectReason::LabelOutOfRange { label: 31, num_labels: 29 };
        assert!(r.to_string().contains("31"));
    }

    #[test]
    fn result_accessors() {
        let r = QueryResult {
            id: 1,
            outcome: Outcome::Score(0.5),
            latency_us: 10.0,
            batch_size: 4,
            stage: StageTiming::default(),
        };
        assert_eq!(r.score(), Some(0.5));
        assert!(!r.is_rejected());
        let r = QueryResult {
            id: 2,
            outcome: Outcome::Rejected(RejectReason::ShuttingDown),
            latency_us: 1.0,
            batch_size: 0,
            stage: StageTiming::default(),
        };
        assert_eq!(r.score(), None);
        assert!(r.is_rejected());
    }

    #[test]
    fn constructors_carry_query_identity() {
        let g = crate::graph::Graph::new(2, vec![(0, 1)], vec![0, 0]);
        let q = Query::new(42, g.clone(), g);
        let r = QueryResult::rejected(&q, RejectReason::ShuttingDown);
        assert_eq!(r.id, 42);
        assert!(r.is_rejected());
        let r = QueryResult::engine_error(&q, "boom", 3);
        assert_eq!(r.id, 42);
        assert!(matches!(r.outcome, Outcome::EngineError(ref m) if m == "boom"));
        assert_eq!(r.batch_size, 3);
    }
}
