//! Serving metrics: latency distribution, batch-size histogram,
//! throughput and rejection counters.

use std::time::Instant;

use crate::util::stats::Samples;

#[derive(Debug)]
pub struct Metrics {
    pub latency_us: Samples,
    pub batch_sizes: Samples,
    pub scored: u64,
    pub rejected: u64,
    pub engine_errors: u64,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            latency_us: Samples::new(),
            batch_sizes: Samples::new(),
            scored: 0,
            rejected: 0,
            engine_errors: 0,
            started: Instant::now(),
        }
    }

    pub fn record(&mut self, r: &super::query::QueryResult) {
        match &r.outcome {
            super::query::Outcome::Score(_) => {
                self.scored += 1;
                self.latency_us.push(r.latency_us);
                self.batch_sizes.push(r.batch_size as f64);
            }
            super::query::Outcome::Rejected(_) => self.rejected += 1,
            super::query::Outcome::EngineError(_) => self.engine_errors += 1,
        }
    }

    pub fn throughput_qps(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs == 0.0 {
            0.0
        } else {
            self.scored as f64 / secs
        }
    }

    /// Render as a report table.
    pub fn render_table(&self, title: &str) -> crate::report::Table {
        use crate::report::{fmt, Table};
        let mut t = Table::new(title, &["Metric", "Value"]);
        t.row(vec!["queries scored".into(), format!("{}", self.scored)]);
        t.row(vec!["queries rejected".into(), format!("{}", self.rejected)]);
        t.row(vec!["engine errors".into(), format!("{}", self.engine_errors)]);
        t.row(vec!["throughput (query/s)".into(), fmt(self.throughput_qps())]);
        t.row(vec![
            "latency mean (ms)".into(),
            fmt(self.latency_us.mean() / 1000.0),
        ]);
        t.row(vec![
            "latency p50 (ms)".into(),
            fmt(self.latency_us.percentile(50.0) / 1000.0),
        ]);
        t.row(vec![
            "latency p95 (ms)".into(),
            fmt(self.latency_us.percentile(95.0) / 1000.0),
        ]);
        t.row(vec![
            "latency p99 (ms)".into(),
            fmt(self.latency_us.percentile(99.0) / 1000.0),
        ]);
        t.row(vec![
            "mean batch size".into(),
            fmt(self.batch_sizes.mean()),
        ]);
        t
    }
}

#[cfg(test)]
mod tests {
    use super::super::query::{Outcome, QueryResult};
    use super::*;

    fn res(outcome: Outcome) -> QueryResult {
        QueryResult {
            id: 0,
            outcome,
            latency_us: 100.0,
            batch_size: 4,
        }
    }

    #[test]
    fn counters_split_by_outcome() {
        let mut m = Metrics::new();
        m.record(&res(Outcome::Score(0.5)));
        m.record(&res(Outcome::Rejected(
            super::super::query::RejectReason::ShuttingDown,
        )));
        m.record(&res(Outcome::EngineError("x".into())));
        assert_eq!(m.scored, 1);
        assert_eq!(m.rejected, 1);
        assert_eq!(m.engine_errors, 1);
        assert_eq!(m.latency_us.len(), 1);
    }

    #[test]
    fn table_renders() {
        let mut m = Metrics::new();
        m.record(&res(Outcome::Score(0.9)));
        let t = m.render_table("serve metrics");
        assert!(t.render().contains("queries scored"));
    }
}
