//! Serving metrics: latency distribution, per-stage latency split
//! (queue-wait / encode / execute), batch-size histogram, channel-depth
//! statistics, throughput and rejection counters, and the per-engine
//! telemetry the engines report through `BatchOutput` — simulator cycle
//! counts, device DMA/execute splits, per-slot CPU time.
//!
//! The stage split is the host-side analogue of the per-FIFO occupancy
//! counters accelerator papers use to find pipeline stalls: queue-wait
//! dominating means admission/batching is the bottleneck, encode
//! dominating means the host can't feed the engine, execute dominating
//! means the engine itself is saturated. The cycle rows recover the
//! paper's Table 4/5-style numbers from exactly the workload the
//! serving path saw.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::stats::Samples;

use super::channel::ChannelSnapshot;

/// One engine's MAC/element sample set (see [`Metrics::mac_counts`]).
#[derive(Debug)]
pub struct MacSamples {
    /// Multiply-accumulates charged per scored query.
    pub macs: Samples,
    /// Feature-transform input elements consumed per scored query.
    pub ft_elements: Samples,
    /// Aggregation adjacency entries consumed per scored query.
    pub agg_elements: Samples,
}

impl MacSamples {
    fn new() -> Self {
        MacSamples {
            macs: Samples::new(),
            ft_elements: Samples::new(),
            agg_elements: Samples::new(),
        }
    }
}

/// One worker lane's identity in the final report.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneInfo {
    /// Lane label (`"lane.0"`, ...).
    pub lane: String,
    /// Engine name from its caps, or the construction error when the
    /// lane never got a working engine.
    pub engine: String,
}

/// Point-in-time copy of the net front door's counters (accepted /
/// throttled / shed / degraded), attached to [`Metrics`] by the net
/// server at shutdown the same way the pipeline attaches channel
/// snapshots. `None` means serving didn't run behind a listener.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NetSnapshot {
    /// Frames that passed the token buckets and entered admission.
    pub accepted: u64,
    /// Frames answered with `retry_after_ms` (token bucket empty, or
    /// the admission queue shed the newest arrival).
    pub throttled: u64,
    /// Frames dequeued after their deadline and shed unscored.
    pub shed_deadline: u64,
    /// Responses served by a degraded lane (shrunk top-k, or the GED
    /// heuristic fallback scorer).
    pub degraded: u64,
}

/// Aggregated serving statistics, owned by the responder stage.
#[derive(Debug)]
pub struct Metrics {
    /// End-to-end latency per scored query, µs.
    pub latency_us: Samples,
    /// Submit -> encode-start (admission + batcher + queueing), µs.
    pub queue_us: Samples,
    /// Encode+pack time of the chunk each query rode in, µs.
    pub encode_us: Samples,
    /// Engine execution time of that chunk, µs.
    pub execute_us: Samples,
    /// Executed batch size per scored *pair* query (a batcher-packing
    /// occupancy metric; top-k queries always execute alone however
    /// wide their fan-out, so they are excluded rather than diluting
    /// the row toward 1).
    pub batch_sizes: Samples,
    /// Simulator steady-state interval cycles per query (engines with
    /// `reports_cycles`).
    pub cycle_interval: Samples,
    /// Simulator one-query latency cycles per query.
    pub cycle_latency: Samples,
    /// Device input-upload ("DMA write") time per chunk-slot, µs
    /// (engines with `reports_exec_timing`).
    pub dma_upload_us: Samples,
    /// Device execute time per chunk-slot, µs.
    pub device_execute_us: Samples,
    /// Device output-download ("DMA read") time per chunk-slot, µs.
    pub dma_download_us: Samples,
    /// Per-slot CPU scoring time, µs (native engine).
    pub engine_cpu_us: Samples,
    /// MAC/element work counts per scored query, keyed by engine name
    /// (engines with `reports_macs`). Keyed — not pooled — so a mixed
    /// `native,native-dense` deployment keeps the two policies apart;
    /// the dense/sparse ratio of the `macs` row is the Table 6-style
    /// schedule saving.
    pub mac_counts: BTreeMap<String, MacSamples>,
    /// Scored-query count per engine name.
    pub by_engine: BTreeMap<String, u64>,
    /// Embedding-cache hits summed over scored queries (engines with
    /// `reports_embed_cache`).
    pub embed_hits: u64,
    /// Embedding-cache misses (= GCN forwards executed) summed over
    /// scored queries.
    pub embed_misses: u64,
    /// Largest cache entry count any result reported (a per-lane
    /// gauge: every lane owns an independent cache, so the max — the
    /// biggest single cache observed — is the only per-query-derivable
    /// number that isn't arbitrary; with L same-engine lanes the
    /// process-wide total is up to L times this).
    pub embed_entries: u64,
    /// GCN forwards executed per scored query (pair queries cost at most
    /// 2, cached ones less; top-k queries cost at most `1 + K`). The
    /// mean is the report's `gcn forwards per query` row.
    pub gcn_forwards: Samples,
    /// Successfully scored queries (pair + top-k).
    pub scored: u64,
    /// Top-k corpus queries among `scored`.
    pub topk: u64,
    /// Shards per served top-k query (1 = whole-query path). The mean
    /// is the `topk shards mean` report row: ~lane count means the
    /// scatter engaged, 1.0 means single-lane serving.
    pub topk_shards: Samples,
    /// Slowest-minus-fastest shard execute time per scattered query, µs
    /// — the Accel-GCN-style balance witness (`topk lane spread (ms)`).
    pub topk_spread_us: Samples,
    /// Candidates pruned by the cheap-signal cascade per budgeted top-k
    /// query (queries served `CascadeMode::Exact` contribute nothing).
    pub cascade_pruned: Samples,
    /// Candidates that survived the cascade and were scored.
    pub cascade_survivors: Samples,
    /// Prune-stage time per budgeted query, µs.
    pub cascade_prune_us: Samples,
    /// Queries rejected at admission (or during shutdown).
    pub rejected: u64,
    /// Queries answered with an engine error.
    pub engine_errors: u64,
    /// Per-channel occupancy statistics, filled in by the pipeline at
    /// shutdown (empty when serving didn't run through a pipeline).
    pub channels: Vec<ChannelSnapshot>,
    /// Lane -> engine mapping, filled in by the pipeline at shutdown.
    pub lanes: Vec<LaneInfo>,
    /// Net front-door counters, filled in by the net server at shutdown
    /// (`None` when serving ran in-process only).
    pub net: Option<NetSnapshot>,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    /// Empty metrics, clock started now.
    pub fn new() -> Self {
        Metrics {
            latency_us: Samples::new(),
            queue_us: Samples::new(),
            encode_us: Samples::new(),
            execute_us: Samples::new(),
            batch_sizes: Samples::new(),
            cycle_interval: Samples::new(),
            cycle_latency: Samples::new(),
            dma_upload_us: Samples::new(),
            device_execute_us: Samples::new(),
            dma_download_us: Samples::new(),
            engine_cpu_us: Samples::new(),
            mac_counts: BTreeMap::new(),
            by_engine: BTreeMap::new(),
            embed_hits: 0,
            embed_misses: 0,
            embed_entries: 0,
            gcn_forwards: Samples::new(),
            scored: 0,
            topk: 0,
            topk_shards: Samples::new(),
            topk_spread_us: Samples::new(),
            cascade_pruned: Samples::new(),
            cascade_survivors: Samples::new(),
            cascade_prune_us: Samples::new(),
            rejected: 0,
            engine_errors: 0,
            channels: Vec::new(),
            lanes: Vec::new(),
            net: None,
            started: Instant::now(),
        }
    }

    /// Absorb one query result (counters, latency split, telemetry).
    pub fn record(&mut self, r: &super::query::QueryResult) {
        match &r.outcome {
            super::query::Outcome::Score(_) | super::query::Outcome::TopK(_) => {
                self.scored += 1;
                if matches!(r.outcome, super::query::Outcome::TopK(_)) {
                    self.topk += 1;
                    if let Some(sh) = r.sharding {
                        self.topk_shards.push(sh.shards as f64);
                        self.topk_spread_us.push(sh.spread_us);
                    }
                    if let Some(c) = r.cascade {
                        self.cascade_pruned.push(c.pruned as f64);
                        self.cascade_survivors.push(c.survivors as f64);
                        self.cascade_prune_us.push(c.prune_us as f64);
                    }
                } else {
                    // Pair queries only: see the `batch_sizes` field doc.
                    self.batch_sizes.push(r.batch_size as f64);
                }
                self.latency_us.push(r.latency_us);
                self.queue_us.push(r.stage.queue_us);
                self.encode_us.push(r.stage.encode_us);
                self.execute_us.push(r.stage.execute_us);
                if let Some(engine) = &r.engine {
                    // get_mut first: no per-query String allocation once
                    // the engine's entry exists.
                    match self.by_engine.get_mut(engine.as_ref()) {
                        Some(count) => *count += 1,
                        None => {
                            self.by_engine.insert(engine.to_string(), 1);
                        }
                    }
                }
                if let Some(c) = &r.telemetry.cycles {
                    self.cycle_interval.push(c.interval as f64);
                    self.cycle_latency.push(c.latency as f64);
                }
                if let Some(e) = &r.telemetry.exec {
                    self.dma_upload_us.push(e.upload_us);
                    self.device_execute_us.push(e.execute_us);
                    self.dma_download_us.push(e.download_us);
                }
                if let Some(cpu) = r.telemetry.cpu_us {
                    self.engine_cpu_us.push(cpu);
                }
                if let Some(c) = r.telemetry.embed_cache {
                    self.embed_hits += c.hits;
                    self.embed_misses += c.misses;
                    self.embed_entries = self.embed_entries.max(c.entries);
                    self.gcn_forwards.push(c.gcn_forwards() as f64);
                }
                if let Some(m) = r.telemetry.macs {
                    let name = r.engine.as_deref().unwrap_or("unknown");
                    // contains_key first: no per-query String allocation
                    // once the engine's entry exists.
                    if !self.mac_counts.contains_key(name) {
                        self.mac_counts.insert(name.to_string(), MacSamples::new());
                    }
                    let s = self.mac_counts.get_mut(name).expect("inserted above");
                    s.macs.push(m.macs as f64);
                    s.ft_elements.push(m.ft_elements as f64);
                    s.agg_elements.push(m.agg_elements as f64);
                }
            }
            super::query::Outcome::Rejected(_) => self.rejected += 1,
            super::query::Outcome::EngineError(_) => self.engine_errors += 1,
        }
    }

    /// Scored queries per wall-clock second since construction.
    pub fn throughput_qps(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs == 0.0 {
            0.0
        } else {
            self.scored as f64 / secs
        }
    }

    /// Render as a report table.
    ///
    /// Row order is stable API for the first nine rows (benches, examples
    /// and tests index them); new rows are only ever appended. Telemetry
    /// rows (per-engine counts, cycle and DMA aggregates) appear only
    /// when an engine actually reported them; channel rows come last.
    pub fn render_table(&self, title: &str) -> crate::report::Table {
        use crate::report::{fmt, Table};
        let mut t = Table::new(title, &["Metric", "Value"]);
        t.row(vec!["queries scored".into(), format!("{}", self.scored)]);
        t.row(vec!["queries rejected".into(), format!("{}", self.rejected)]);
        t.row(vec!["engine errors".into(), format!("{}", self.engine_errors)]);
        t.row(vec!["throughput (query/s)".into(), fmt(self.throughput_qps())]);
        t.row(vec![
            "latency mean (ms)".into(),
            fmt(self.latency_us.mean() / 1000.0),
        ]);
        t.row(vec![
            "latency p50 (ms)".into(),
            fmt(self.latency_us.percentile(50.0) / 1000.0),
        ]);
        t.row(vec![
            "latency p95 (ms)".into(),
            fmt(self.latency_us.percentile(95.0) / 1000.0),
        ]);
        t.row(vec![
            "latency p99 (ms)".into(),
            fmt(self.latency_us.percentile(99.0) / 1000.0),
        ]);
        t.row(vec![
            "mean batch size".into(),
            fmt(self.batch_sizes.mean()),
        ]);
        // Per-stage latency split (where latency_us went).
        for (label, s) in [
            ("queue wait", &self.queue_us),
            ("encode", &self.encode_us),
            ("execute", &self.execute_us),
        ] {
            t.row(vec![
                format!("{label} mean (ms)"),
                fmt(s.mean() / 1000.0),
            ]);
            t.row(vec![
                format!("{label} p95 (ms)"),
                fmt(s.percentile(95.0) / 1000.0),
            ]);
        }
        // Lane identity + per-engine traffic (mixed-kind deployments).
        for lane in &self.lanes {
            t.row(vec![format!("{} engine", lane.lane), lane.engine.clone()]);
        }
        for (engine, count) in &self.by_engine {
            t.row(vec![format!("engine {engine} scored"), format!("{count}")]);
        }
        // Accelerator telemetry the engines reported through BatchOutput.
        if !self.cycle_interval.is_empty() {
            t.row(vec![
                "sim interval cycles mean".into(),
                fmt(self.cycle_interval.mean()),
            ]);
            t.row(vec![
                "sim interval cycles p95".into(),
                fmt(self.cycle_interval.percentile(95.0)),
            ]);
            t.row(vec![
                "sim latency cycles mean".into(),
                fmt(self.cycle_latency.mean()),
            ]);
        }
        if !self.device_execute_us.is_empty() {
            t.row(vec![
                "dma upload mean (ms)".into(),
                fmt(self.dma_upload_us.mean() / 1000.0),
            ]);
            t.row(vec![
                "device execute mean (ms)".into(),
                fmt(self.device_execute_us.mean() / 1000.0),
            ]);
            t.row(vec![
                "dma download mean (ms)".into(),
                fmt(self.dma_download_us.mean() / 1000.0),
            ]);
        }
        if !self.engine_cpu_us.is_empty() {
            t.row(vec![
                "engine cpu mean (ms)".into(),
                fmt(self.engine_cpu_us.mean() / 1000.0),
            ]);
        }
        // Embedding-cache effectiveness (DESIGN.md S14). Hit rate over
        // every embed the engines attempted; `gcn forwards per query` is
        // the mean number of GCN+attention forwards actually executed
        // per scored query (2.0 = no reuse on pair traffic).
        if self.topk > 0 {
            t.row(vec!["topk queries".into(), format!("{}", self.topk)]);
            if !self.topk_shards.is_empty() {
                t.row(vec![
                    "topk shards mean".into(),
                    fmt(self.topk_shards.mean()),
                ]);
                t.row(vec![
                    "topk lane spread (ms)".into(),
                    fmt(self.topk_spread_us.mean() / 1000.0),
                ]);
            }
            // Cascade rows: only budgeted queries feed these samples,
            // so an all-Exact run renders no cascade rows at all.
            if !self.cascade_pruned.is_empty() {
                t.row(vec![
                    "cascade queries".into(),
                    format!("{}", self.cascade_pruned.len()),
                ]);
                t.row(vec![
                    "cascade pruned mean".into(),
                    fmt(self.cascade_pruned.mean()),
                ]);
                t.row(vec![
                    "cascade survivors mean".into(),
                    fmt(self.cascade_survivors.mean()),
                ]);
                t.row(vec![
                    "cascade prune mean (ms)".into(),
                    fmt(self.cascade_prune_us.mean() / 1000.0),
                ]);
            }
        }
        if self.embed_hits + self.embed_misses > 0 {
            t.row(vec![
                "embed cache hit rate".into(),
                fmt(self.embed_hits as f64 / (self.embed_hits + self.embed_misses) as f64),
            ]);
            t.row(vec![
                "embed cache entries".into(),
                format!("{}", self.embed_entries),
            ]);
            t.row(vec![
                "gcn forwards per query".into(),
                fmt(self.gcn_forwards.mean()),
            ]);
        }
        for (engine, s) in &self.mac_counts {
            t.row(vec![
                format!("engine {engine} macs mean"),
                fmt(s.macs.mean()),
            ]);
            t.row(vec![
                format!("engine {engine} ft elements mean"),
                fmt(s.ft_elements.mean()),
            ]);
            t.row(vec![
                format!("engine {engine} agg elements mean"),
                fmt(s.agg_elements.mean()),
            ]);
        }
        // Net front-door counters (present only when serving ran behind
        // a listener). Appended after the stable rows like all newer
        // telemetry; the overload story in one glance: how much traffic
        // the wire offered, how much the buckets/queue turned away, how
        // much the deadline shed, and how much was answered degraded.
        if let Some(net) = &self.net {
            t.row(vec!["net accepted".into(), format!("{}", net.accepted)]);
            t.row(vec!["net throttled".into(), format!("{}", net.throttled)]);
            t.row(vec![
                "net shed (deadline)".into(),
                format!("{}", net.shed_deadline),
            ]);
            t.row(vec![
                "degraded responses".into(),
                format!("{}", net.degraded),
            ]);
        }
        // Channel occupancy: peak depth >= 2 on an exec lane means the
        // encoder genuinely ran ahead of the executor (overlap) — a peak
        // of 1 is just a single hand-off in flight.
        for c in &self.channels {
            t.row(vec![
                format!("chan {} (cap {})", c.name, c.capacity),
                format!(
                    "peak depth {}  sent {}  dropped {}  shed {}",
                    c.max_depth, c.sent, c.dropped, c.shed
                ),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use crate::runtime::{CycleReport, EngineError, ExecTiming, MacCounts, QueryTelemetry};

    use super::super::query::{Outcome, QueryResult, StageTiming};
    use super::*;

    fn res(outcome: Outcome) -> QueryResult {
        QueryResult {
            id: 0,
            outcome,
            latency_us: 100.0,
            batch_size: 4,
            stage: StageTiming {
                queue_us: 60.0,
                encode_us: 10.0,
                execute_us: 25.0,
            },
            telemetry: QueryTelemetry::default(),
            engine: None,
            sharding: None,
            cascade: None,
        }
    }

    #[test]
    fn cascade_rows_render_only_for_budgeted_queries() {
        use super::super::query::CascadeInfo;
        let mut m = Metrics::new();
        // An Exact top-k query contributes nothing to the cascade rows.
        m.record(&res(Outcome::TopK(vec![(0, 0.9)])));
        assert!(m.cascade_pruned.is_empty());
        assert!(!m.render_table("t").render().contains("cascade"));
        // Two budgeted queries.
        let budgeted = res(Outcome::TopK(vec![(1, 0.8)])).with_cascade(CascadeInfo {
            pruned: 3000,
            survivors: 1096,
            prune_us: 250,
        });
        m.record(&budgeted);
        let budgeted2 = res(Outcome::TopK(vec![(2, 0.7)])).with_cascade(CascadeInfo {
            pruned: 3100,
            survivors: 996,
            prune_us: 150,
        });
        m.record(&budgeted2);
        assert_eq!(m.cascade_pruned.len(), 2);
        assert_eq!(m.cascade_pruned.mean(), 3050.0);
        assert_eq!(m.cascade_survivors.mean(), 1046.0);
        assert_eq!(m.cascade_prune_us.mean(), 200.0);
        let t = m.render_table("t");
        assert_eq!(t.get("cascade queries"), Some("2"));
        let rendered = t.render();
        assert!(rendered.contains("cascade pruned mean"));
        assert!(rendered.contains("cascade survivors mean"));
        assert!(rendered.contains("cascade prune mean (ms)"));
    }

    #[test]
    fn sharding_rows_render_per_topk_query() {
        use super::super::query::ShardingInfo;
        let mut m = Metrics::new();
        // One scattered query (2 shards, 400 µs spread), one whole.
        let scattered = res(Outcome::TopK(vec![(0, 0.9)]))
            .with_sharding(ShardingInfo { shards: 2, spread_us: 400.0 });
        m.record(&scattered);
        let whole = res(Outcome::TopK(vec![(1, 0.8)]))
            .with_sharding(ShardingInfo { shards: 1, spread_us: 0.0 });
        m.record(&whole);
        // Pair queries never touch the shard samples.
        m.record(&res(Outcome::Score(0.5)));
        assert_eq!(m.topk, 2);
        assert_eq!(m.topk_shards.len(), 2);
        assert_eq!(m.topk_shards.mean(), 1.5);
        assert_eq!(m.topk_spread_us.mean(), 200.0);
        let rendered = m.render_table("t").render();
        assert!(rendered.contains("topk shards mean"));
        assert!(rendered.contains("topk lane spread (ms)"));
    }

    #[test]
    fn counters_split_by_outcome() {
        let mut m = Metrics::new();
        m.record(&res(Outcome::Score(0.5)));
        m.record(&res(Outcome::Rejected(
            super::super::query::RejectReason::ShuttingDown,
        )));
        m.record(&res(Outcome::EngineError(EngineError::Unavailable {
            reason: "x".into(),
        })));
        assert_eq!(m.scored, 1);
        assert_eq!(m.rejected, 1);
        assert_eq!(m.engine_errors, 1);
        assert_eq!(m.latency_us.len(), 1);
        // Stage samples only accumulate for scored queries.
        assert_eq!(m.queue_us.len(), 1);
        assert_eq!(m.encode_us.len(), 1);
        assert_eq!(m.execute_us.len(), 1);
        assert_eq!(m.queue_us.mean(), 60.0);
    }

    #[test]
    fn telemetry_accumulates_per_engine() {
        let mut m = Metrics::new();
        let mut sim = res(Outcome::Score(0.5)).with_engine(Arc::from("spa-gcn-sim"));
        sim.telemetry.cycles = Some(CycleReport {
            interval: 1000,
            latency: 1500,
        });
        m.record(&sim);
        let mut xla = res(Outcome::Score(0.6)).with_engine(Arc::from("xla-pjrt"));
        xla.telemetry.exec = Some(ExecTiming {
            upload_us: 10.0,
            execute_us: 90.0,
            download_us: 5.0,
        });
        m.record(&xla);
        let mut native = res(Outcome::Score(0.7)).with_engine(Arc::from("native-cpu"));
        native.telemetry.cpu_us = Some(42.0);
        native.telemetry.macs = Some(MacCounts {
            macs: 5000,
            ft_elements: 60,
            agg_elements: 170,
        });
        m.record(&native);

        assert_eq!(m.by_engine["spa-gcn-sim"], 1);
        assert_eq!(m.by_engine["xla-pjrt"], 1);
        assert_eq!(m.by_engine["native-cpu"], 1);
        assert_eq!(m.cycle_interval.mean(), 1000.0);
        assert_eq!(m.cycle_latency.mean(), 1500.0);
        assert_eq!(m.device_execute_us.mean(), 90.0);
        assert_eq!(m.engine_cpu_us.mean(), 42.0);
        let native_macs = &m.mac_counts["native-cpu"];
        assert_eq!(native_macs.macs.mean(), 5000.0);
        assert_eq!(native_macs.ft_elements.mean(), 60.0);
        assert_eq!(native_macs.agg_elements.mean(), 170.0);

        let rendered = m.render_table("t").render();
        assert!(rendered.contains("engine spa-gcn-sim scored"));
        assert!(rendered.contains("sim interval cycles mean"));
        assert!(rendered.contains("device execute mean (ms)"));
        assert!(rendered.contains("engine cpu mean (ms)"));
        assert!(rendered.contains("engine native-cpu macs mean"));
        assert!(rendered.contains("engine native-cpu ft elements mean"));
        assert!(rendered.contains("engine native-cpu agg elements mean"));
    }

    #[test]
    fn mac_rows_keyed_per_engine_in_mixed_deployments() {
        // A native + native-dense pipeline must NOT blend the two
        // policies' counts — the rows exist to compare them.
        let mut m = Metrics::new();
        let mut sparse = res(Outcome::Score(0.5)).with_engine(Arc::from("native-cpu"));
        sparse.telemetry.macs = Some(MacCounts {
            macs: 2_000,
            ft_elements: 50,
            agg_elements: 150,
        });
        m.record(&sparse);
        let mut dense = res(Outcome::Score(0.5)).with_engine(Arc::from("native-cpu-dense"));
        dense.telemetry.macs = Some(MacCounts {
            macs: 180_000,
            ft_elements: 2_000,
            agg_elements: 3_000,
        });
        m.record(&dense);
        assert_eq!(m.mac_counts["native-cpu"].macs.mean(), 2_000.0);
        assert_eq!(m.mac_counts["native-cpu-dense"].macs.mean(), 180_000.0);
        let rendered = m.render_table("t").render();
        assert!(rendered.contains("engine native-cpu macs mean"));
        assert!(rendered.contains("engine native-cpu-dense macs mean"));
    }

    #[test]
    fn topk_and_embed_cache_rows_accumulate() {
        use crate::runtime::EmbedCacheTelemetry;
        let mut m = Metrics::new();
        // A pair query that embedded both graphs (cold cache).
        let mut pair = res(Outcome::Score(0.5)).with_engine(Arc::from("native-cpu"));
        pair.telemetry.embed_cache = Some(EmbedCacheTelemetry {
            hits: 0,
            misses: 2,
            entries: 2,
        });
        m.record(&pair);
        // A top-k query over 9 candidates: only 4 unique embeds ran.
        let mut topk = res(Outcome::TopK(vec![(1, 0.9), (0, 0.4)]))
            .with_engine(Arc::from("native-cpu"));
        topk.telemetry.embed_cache = Some(EmbedCacheTelemetry {
            hits: 6,
            misses: 4,
            entries: 6,
        });
        m.record(&topk);
        // A later result from a smaller lane cache must not shrink the
        // gauge: entries is the max cache size observed, not last-wins.
        let mut small = res(Outcome::Score(0.4)).with_engine(Arc::from("native-cpu"));
        small.telemetry.embed_cache = Some(EmbedCacheTelemetry {
            hits: 2,
            misses: 0,
            entries: 3,
        });
        m.record(&small);
        assert_eq!(m.scored, 3, "top-k results count as scored");
        assert_eq!(m.topk, 1);
        assert_eq!(m.by_engine["native-cpu"], 3);
        assert_eq!((m.embed_hits, m.embed_misses), (8, 6));
        assert_eq!(m.embed_entries, 6, "entries gauge keeps the max");
        assert_eq!(m.gcn_forwards.mean(), 2.0, "(2 + 4 + 0) / 3 forwards");
        let rendered = m.render_table("t").render();
        assert!(rendered.contains("topk queries"));
        assert!(rendered.contains("embed cache hit rate"));
        assert!(rendered.contains("embed cache entries"));
        assert!(rendered.contains("gcn forwards per query"));
    }

    #[test]
    fn telemetry_rows_absent_without_telemetry() {
        let mut m = Metrics::new();
        m.record(&res(Outcome::Score(0.5)));
        let rendered = m.render_table("t").render();
        assert!(!rendered.contains("sim interval cycles"));
        assert!(!rendered.contains("dma upload"));
        assert!(!rendered.contains("engine cpu"));
        assert!(!rendered.contains("macs mean"));
        assert!(!rendered.contains("embed cache"));
        assert!(!rendered.contains("topk queries"));
    }

    #[test]
    fn table_renders_with_stage_and_channel_rows() {
        let mut m = Metrics::new();
        m.record(&res(Outcome::Score(0.9)));
        m.lanes.push(LaneInfo {
            lane: "lane.0".into(),
            engine: "native-cpu".into(),
        });
        m.channels.push(ChannelSnapshot {
            name: "exec.0".into(),
            capacity: 2,
            sent: 5,
            dropped: 0,
            shed: 0,
            max_depth: 2,
        });
        let t = m.render_table("serve metrics");
        let rendered = t.render();
        assert!(rendered.contains("queries scored"));
        assert!(rendered.contains("queue wait mean (ms)"));
        assert!(rendered.contains("execute p95 (ms)"));
        assert!(rendered.contains("lane.0 engine"));
        assert!(rendered.contains("chan exec.0 (cap 2)"));
        // The first nine rows are a stable indexing API.
        assert_eq!(t.rows[0][0], "queries scored");
        assert_eq!(t.rows[3][0], "throughput (query/s)");
        assert_eq!(t.rows[5][0], "latency p50 (ms)");
        assert_eq!(t.rows[8][0], "mean batch size");
    }

    #[test]
    fn net_rows_render_after_stable_rows() {
        let mut m = Metrics::new();
        m.record(&res(Outcome::Score(0.9)));
        m.net = Some(NetSnapshot {
            accepted: 40,
            throttled: 7,
            shed_deadline: 3,
            degraded: 5,
        });
        m.channels.push(ChannelSnapshot {
            name: "net.admit".into(),
            capacity: 8,
            sent: 43,
            dropped: 2,
            shed: 3,
            max_depth: 8,
        });
        let t = m.render_table("t");
        // Name-based reads through Table::get — the counters land
        // verbatim.
        assert_eq!(t.get("net accepted"), Some("40"));
        assert_eq!(t.get("net throttled"), Some("7"));
        assert_eq!(t.get("net shed (deadline)"), Some("3"));
        assert_eq!(t.get("degraded responses"), Some("5"));
        // Appended after the stable indexed prefix, never inside it.
        assert_eq!(t.rows[0][0], "queries scored");
        assert_eq!(t.rows[8][0], "mean batch size");
        let accepted_at = t.rows.iter().position(|r| r[0] == "net accepted").unwrap();
        assert!(accepted_at > 8);
        // The per-channel shed counter reaches the channel row.
        assert_eq!(
            t.get("chan net.admit (cap 8)"),
            Some("peak depth 8  sent 43  dropped 2  shed 3")
        );
    }

    #[test]
    fn net_rows_absent_without_listener() {
        let mut m = Metrics::new();
        m.record(&res(Outcome::Score(0.5)));
        let rendered = m.render_table("t").render();
        assert!(!rendered.contains("net accepted"));
        assert!(!rendered.contains("degraded responses"));
    }
}
