//! Serving metrics: latency distribution, per-stage latency split
//! (queue-wait / encode / execute), batch-size histogram, channel-depth
//! statistics, throughput and rejection counters.
//!
//! The stage split is the host-side analogue of the per-FIFO occupancy
//! counters accelerator papers use to find pipeline stalls: queue-wait
//! dominating means admission/batching is the bottleneck, encode
//! dominating means the host can't feed the engine, execute dominating
//! means the engine itself is saturated.

use std::time::Instant;

use crate::util::stats::Samples;

use super::channel::ChannelSnapshot;

#[derive(Debug)]
pub struct Metrics {
    pub latency_us: Samples,
    /// Submit -> encode-start (admission + batcher + queueing), µs.
    pub queue_us: Samples,
    /// Encode+pack time of the chunk each query rode in, µs.
    pub encode_us: Samples,
    /// Engine execution time of that chunk, µs.
    pub execute_us: Samples,
    pub batch_sizes: Samples,
    pub scored: u64,
    pub rejected: u64,
    pub engine_errors: u64,
    /// Per-channel occupancy statistics, filled in by the pipeline at
    /// shutdown (empty when serving didn't run through a pipeline).
    pub channels: Vec<ChannelSnapshot>,
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            latency_us: Samples::new(),
            queue_us: Samples::new(),
            encode_us: Samples::new(),
            execute_us: Samples::new(),
            batch_sizes: Samples::new(),
            scored: 0,
            rejected: 0,
            engine_errors: 0,
            channels: Vec::new(),
            started: Instant::now(),
        }
    }

    pub fn record(&mut self, r: &super::query::QueryResult) {
        match &r.outcome {
            super::query::Outcome::Score(_) => {
                self.scored += 1;
                self.latency_us.push(r.latency_us);
                self.queue_us.push(r.stage.queue_us);
                self.encode_us.push(r.stage.encode_us);
                self.execute_us.push(r.stage.execute_us);
                self.batch_sizes.push(r.batch_size as f64);
            }
            super::query::Outcome::Rejected(_) => self.rejected += 1,
            super::query::Outcome::EngineError(_) => self.engine_errors += 1,
        }
    }

    pub fn throughput_qps(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs == 0.0 {
            0.0
        } else {
            self.scored as f64 / secs
        }
    }

    /// Render as a report table.
    ///
    /// Row order is stable API for the first nine rows (benches, examples
    /// and tests index them); new rows are only ever appended.
    pub fn render_table(&self, title: &str) -> crate::report::Table {
        use crate::report::{fmt, Table};
        let mut t = Table::new(title, &["Metric", "Value"]);
        t.row(vec!["queries scored".into(), format!("{}", self.scored)]);
        t.row(vec!["queries rejected".into(), format!("{}", self.rejected)]);
        t.row(vec!["engine errors".into(), format!("{}", self.engine_errors)]);
        t.row(vec!["throughput (query/s)".into(), fmt(self.throughput_qps())]);
        t.row(vec![
            "latency mean (ms)".into(),
            fmt(self.latency_us.mean() / 1000.0),
        ]);
        t.row(vec![
            "latency p50 (ms)".into(),
            fmt(self.latency_us.percentile(50.0) / 1000.0),
        ]);
        t.row(vec![
            "latency p95 (ms)".into(),
            fmt(self.latency_us.percentile(95.0) / 1000.0),
        ]);
        t.row(vec![
            "latency p99 (ms)".into(),
            fmt(self.latency_us.percentile(99.0) / 1000.0),
        ]);
        t.row(vec![
            "mean batch size".into(),
            fmt(self.batch_sizes.mean()),
        ]);
        // Per-stage latency split (where latency_us went).
        for (label, s) in [
            ("queue wait", &self.queue_us),
            ("encode", &self.encode_us),
            ("execute", &self.execute_us),
        ] {
            t.row(vec![
                format!("{label} mean (ms)"),
                fmt(s.mean() / 1000.0),
            ]);
            t.row(vec![
                format!("{label} p95 (ms)"),
                fmt(s.percentile(95.0) / 1000.0),
            ]);
        }
        // Channel occupancy: peak depth >= 2 on an exec lane means the
        // encoder genuinely ran ahead of the executor (overlap) — a peak
        // of 1 is just a single hand-off in flight.
        for c in &self.channels {
            t.row(vec![
                format!("chan {} (cap {})", c.name, c.capacity),
                format!(
                    "peak depth {}  sent {}  dropped {}",
                    c.max_depth, c.sent, c.dropped
                ),
            ]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::super::query::{Outcome, QueryResult, StageTiming};
    use super::*;

    fn res(outcome: Outcome) -> QueryResult {
        QueryResult {
            id: 0,
            outcome,
            latency_us: 100.0,
            batch_size: 4,
            stage: StageTiming {
                queue_us: 60.0,
                encode_us: 10.0,
                execute_us: 25.0,
            },
        }
    }

    #[test]
    fn counters_split_by_outcome() {
        let mut m = Metrics::new();
        m.record(&res(Outcome::Score(0.5)));
        m.record(&res(Outcome::Rejected(
            super::super::query::RejectReason::ShuttingDown,
        )));
        m.record(&res(Outcome::EngineError("x".into())));
        assert_eq!(m.scored, 1);
        assert_eq!(m.rejected, 1);
        assert_eq!(m.engine_errors, 1);
        assert_eq!(m.latency_us.len(), 1);
        // Stage samples only accumulate for scored queries.
        assert_eq!(m.queue_us.len(), 1);
        assert_eq!(m.encode_us.len(), 1);
        assert_eq!(m.execute_us.len(), 1);
        assert_eq!(m.queue_us.mean(), 60.0);
    }

    #[test]
    fn table_renders_with_stage_and_channel_rows() {
        let mut m = Metrics::new();
        m.record(&res(Outcome::Score(0.9)));
        m.channels.push(ChannelSnapshot {
            name: "exec.0".into(),
            capacity: 2,
            sent: 5,
            dropped: 0,
            max_depth: 2,
        });
        let t = m.render_table("serve metrics");
        let rendered = t.render();
        assert!(rendered.contains("queries scored"));
        assert!(rendered.contains("queue wait mean (ms)"));
        assert!(rendered.contains("execute p95 (ms)"));
        assert!(rendered.contains("chan exec.0 (cap 2)"));
        // The first nine rows are a stable indexing API.
        assert_eq!(t.rows[0][0], "queries scored");
        assert_eq!(t.rows[3][0], "throughput (query/s)");
        assert_eq!(t.rows[5][0], "latency p50 (ms)");
        assert_eq!(t.rows[8][0], "mean batch size");
    }
}
