//! The serving entrypoints: build a staged [`Pipeline`], pump a workload
//! through it (closed-loop flood or open-loop Poisson pacing), render
//! the metrics report.
//!
//! Matches the paper's deployment: a host process owns a compiled
//! accelerator (PJRT executable here, bitstream there), queries stream
//! in, the coordinator batches them to amortize per-launch overhead
//! (Fig. 11) and replicates worker lanes (§5.4.3). Lanes are typed
//! [`EngineKind`]s and may be heterogeneous (`native` lanes serving next
//! to `sim` lanes — the Accel-GCN/LW-GCN-style mixed-accelerator
//! deployment); engine construction goes through [`EngineBuilder`], not
//! string matching. The stage wiring itself lives in
//! [`super::pipeline`]; both entrypoints share the one construction
//! path.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use std::collections::HashMap;

use crate::graph::dataset::{random_pairs, GraphDb};
use crate::graph::generate::{generate, Family};
use crate::nn::config::ArtifactsMeta;
use crate::runtime::embed_cache::{EmbedCache, DEFAULT_CAPACITY};
use crate::runtime::{EngineBuilder, EngineFactory, EngineKind};
use crate::util::rng::Rng;

use std::collections::BTreeMap;
use std::sync::Mutex;

use super::batcher::BatchPolicy;
use super::corpus::Corpus;
use super::corpus_store::CorpusStore;
use super::load::{poisson_schedule, Pacer};
use super::metrics::Metrics;
use super::pipeline::{Pipeline, PipelineConfig, ResultTap};
use super::query::{CascadeMode, Query};
use super::trace::{outcome_line, Trace, TraceHeader, TraceRecorder};

/// Serving configuration (CLI `spa-gcn serve`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Where the AOT artifacts live.
    pub artifacts_dir: PathBuf,
    /// Engine kind per lane pattern: lanes cycle through this list (one
    /// entry = homogeneous lanes; `[Native, Sim]` = alternating kinds).
    pub engines: Vec<EngineKind>,
    /// Number of queries to synthesize and serve.
    pub queries: usize,
    /// Worker lane count; raised to `engines.len()` so every requested
    /// kind gets at least one lane.
    pub workers: usize,
    /// Batcher release size.
    pub batch_max: usize,
    /// Batcher release deadline.
    pub batch_timeout_us: u64,
    /// Workload RNG seed.
    pub seed: u64,
    /// Encoded-chunk buffer per worker lane: >= 1 overlaps encode with
    /// engine execution (2 = double buffering), 0 runs them sequentially
    /// in one thread (the no-overlap baseline).
    pub pipeline_depth: usize,
    /// Corpus size for one-vs-many workloads (`--corpus N`): 0 serves
    /// the classic pairwise workload; > 0 synthesizes an N-graph corpus
    /// and every query becomes a top-k ranking against it.
    pub corpus_size: usize,
    /// How many ranked candidates each corpus query returns (`--topk K`).
    pub topk: usize,
    /// Cascade candidate budget per top-k query (`--budget N`): 0 serves
    /// `CascadeMode::Exact`; > 0 prunes to at most N candidates with
    /// cheap signals before the NTN+FCN tail runs.
    pub budget: usize,
    /// Record every admitted query (with its arrival offset) to this
    /// trace file (`--record PATH`, DESIGN.md S19). `None` = no tap.
    pub record: Option<PathBuf>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            engines: vec![EngineKind::Xla],
            queries: 1000,
            workers: 1,
            batch_max: 64,
            batch_timeout_us: 200,
            seed: 42,
            pipeline_depth: 2,
            corpus_size: 0,
            topk: 10,
            budget: 0,
            record: None,
        }
    }
}

impl ServeConfig {
    pub(crate) fn pipeline_config(&self) -> PipelineConfig {
        PipelineConfig {
            policy: BatchPolicy {
                max_batch: self.batch_max.max(1),
                timeout: Duration::from_micros(self.batch_timeout_us),
            },
            depth: self.pipeline_depth,
            admit_cap: (self.batch_max * 4).max(64),
            batch_cap: 8,
            results_cap: 1024,
        }
    }

    /// Effective worker lane count: `workers` raised so every requested
    /// engine kind gets at least one lane.
    pub(crate) fn lanes(&self) -> usize {
        self.workers.max(1).max(self.engines.len())
    }

    /// One [`EngineFactory`] per worker lane, cycling through the
    /// requested kinds (`--engine native,sim` with 4 workers yields
    /// native, sim, native, sim). At least one lane per kind.
    ///
    /// Lanes of the same kind share one embedding cache (the server
    /// constructs the `Arc<EmbedCache>` here, one per distinct kind —
    /// DESIGN.md S15): corpus candidates warmed by one lane hit on its
    /// siblings, and a scattered top-k query costs one GCN forward per
    /// unique graph across the whole pipeline, not per lane. Kinds
    /// never share a cache with each other — cached work counters are
    /// policy-specific (`native` vs `native-dense`).
    pub(crate) fn lane_factories(&self) -> Vec<EngineFactory> {
        let mut caches: HashMap<EngineKind, Arc<EmbedCache>> = HashMap::new();
        (0..self.lanes())
            .map(|w| {
                let kind = self.engines[w % self.engines.len()];
                let cache = Arc::clone(
                    caches
                        .entry(kind)
                        .or_insert_with(|| Arc::new(EmbedCache::new(DEFAULT_CAPACITY))),
                );
                EngineBuilder::new(kind, self.artifacts_dir.clone())
                    .with_embed_cache(cache)
                    .into_factory()
            })
            .collect()
    }

    /// The engine list as a CLI-style string (report titles).
    pub(crate) fn engines_label(&self) -> String {
        self.engines
            .iter()
            .map(EngineKind::as_str)
            .collect::<Vec<_>>()
            .join(",")
    }

    /// Title suffix describing the workload shape.
    fn workload_label(&self) -> String {
        if self.corpus_size > 0 {
            let budget = if self.budget > 0 {
                format!(" budget={}", self.budget)
            } else {
                String::new()
            };
            format!(" corpus={} topk={}{budget}", self.corpus_size, self.topk)
        } else {
            String::new()
        }
    }

    /// The cascade mode top-k queries are built with.
    pub(crate) fn cascade_mode(&self) -> CascadeMode {
        if self.budget > 0 {
            CascadeMode::Budgeted { budget: self.budget }
        } else {
            CascadeMode::Exact
        }
    }
}

/// Submit lazily-built queries, optionally paced by a Poisson schedule
/// (queries are constructed at submit time so `submitted` timestamps —
/// and thus queue-wait metrics — reflect real arrival, not workload
/// synthesis). Returns the worst pacing lateness observed.
fn pump(
    pipeline: &Pipeline,
    queries: impl Iterator<Item = Query>,
    schedule: Option<Vec<Duration>>,
) -> Duration {
    let mut max_late = Duration::ZERO;
    match schedule {
        Some(schedule) => {
            let pacer = Pacer::new();
            for (q, at) in queries.zip(schedule) {
                max_late = max_late.max(pacer.wait_until(at));
                pipeline.submit(q);
            }
        }
        None => {
            for q in queries {
                pipeline.submit(q);
            }
        }
    }
    max_late
}

/// Shared serving core: synthesize the workload (pairwise, or top-k
/// corpus search when `corpus_size > 0`), run it through one staged
/// pipeline (closed-loop when `pace_qps` is None, open-loop Poisson
/// otherwise), return (metrics, wall seconds, max lateness).
fn run_serve(cfg: &ServeConfig, pace_qps: Option<f64>) -> Result<(Metrics, f64, Duration)> {
    anyhow::ensure!(!cfg.engines.is_empty(), "serve needs at least one engine kind");
    let meta = ArtifactsMeta::load(&cfg.artifacts_dir)
        .context("loading artifacts (run `make artifacts`)")?;
    let model_cfg = meta.config.clone();
    let (n_max, num_labels) = (model_cfg.n_max, model_cfg.num_labels);

    // The trace recorder taps the submit path (DESIGN.md S19): its
    // header carries the synthesis recipe, so `spa-gcn replay` can
    // rebuild the same corpus without embedding it in the trace.
    let recorder = match &cfg.record {
        Some(path) => Some(
            TraceRecorder::create(
                path,
                &TraceHeader {
                    seed: cfg.seed,
                    corpus_size: cfg.corpus_size,
                    topk: cfg.topk,
                    n_max,
                    num_labels,
                },
            )
            .map_err(|e| anyhow::anyhow!("creating trace recorder: {e}"))?,
        ),
        None => None,
    };
    let tap_query = |q: Query| {
        if let Some(rec) = &recorder {
            rec.record_query("cli", &q);
        }
        q
    };

    let mut rng = Rng::new(cfg.seed);
    let pipeline = Pipeline::start(model_cfg, cfg.lane_factories(), cfg.pipeline_config());

    // Workload synthesis stays OUTSIDE the measured window (the clock
    // starts just before the submit loop, as it always has): corpus
    // encoding and graph generation are setup, not serving.
    let (max_late, t0) = if cfg.corpus_size > 0 {
        // One-vs-many workload: a shared AIDS-like corpus, fresh query
        // graphs of the same family (so each query embeds once and the
        // corpus embeds amortize across the run — DESIGN.md S14).
        let db = GraphDb::synthesize(&mut rng, Family::Aids, cfg.corpus_size, n_max, num_labels);
        // Production corpora live behind a CorpusStore (EPOCH-SWAP-
        // CONFINED): the snapshot is resolved once, before the submit
        // loop, so every query of this run pins one epoch.
        let store = CorpusStore::from_db("aids-synth", &db, n_max, num_labels)
            .map_err(|e| anyhow::anyhow!("encoding corpus: {e}"))?;
        let corpus = Arc::clone(&store.snapshot().corpus);
        let graphs: Vec<_> = (0..cfg.queries)
            .map(|id| (id as u64, generate(&mut rng, Family::Aids, n_max, num_labels)))
            .collect();
        let k = cfg.topk;
        let mode = cfg.cascade_mode();
        let queries = graphs
            .into_iter()
            .map(|(id, g)| Query::topk_with(id, g, Arc::clone(&corpus), k, mode))
            .map(tap_query);
        // The Poisson schedule draws AFTER workload synthesis, keeping
        // the seed → workload mapping identical across paced and
        // unpaced runs (and across releases).
        let schedule = pace_qps.map(|rate| poisson_schedule(&mut rng, rate, cfg.queries));
        // Engine construction overlapped workload synthesis above; wait
        // for the caps handshakes (outside the measured window) so
        // capability-dependent routing — the top-k scatter across
        // corpus-capable lanes in particular — is in effect from the
        // first query, not from whenever the slowest lane finished
        // loading. Failed lanes publish too: this never hangs.
        pipeline.wait_ready();
        // Recorded offsets measure arrival into the serving window, the
        // same clock the report's wall time uses.
        if let Some(rec) = &recorder {
            rec.rebase();
        }
        let t0 = Instant::now();
        (pump(&pipeline, queries, schedule), t0)
    } else {
        // Classic workload: AIDS-like random pairs (paper §5.1).
        let db = GraphDb::synthesize(&mut rng, Family::Aids, 512, n_max, num_labels);
        let pairs = random_pairs(&mut rng, &db, cfg.queries);
        let queries = pairs
            .into_iter()
            .map(|q| Query::new(q.id, q.g1, q.g2))
            .map(tap_query);
        let schedule = pace_qps.map(|rate| poisson_schedule(&mut rng, rate, cfg.queries));
        // Same handshake wait as the corpus branch: steady-state
        // serving is what's measured, not engine construction.
        pipeline.wait_ready();
        if let Some(rec) = &recorder {
            rec.rebase();
        }
        let t0 = Instant::now();
        (pump(&pipeline, queries, schedule), t0)
    };
    let metrics = pipeline.finish();
    if let Some(rec) = &recorder {
        anyhow::ensure!(rec.finish(), "trace recording failed (unwritable --record path?)");
    }
    Ok((metrics, t0.elapsed().as_secs_f64(), max_late))
}

/// Replay a recorded trace through the serving pipeline: the recorded
/// arrival schedule replaces `poisson_schedule` synthesis, the recorded
/// payloads replace workload generation, and every outcome is collected
/// through the responder tap into a deterministic dump (sorted
/// [`outcome_line`]s) — two replays of the same trace must return
/// byte-identical dumps (the CI determinism gate, DESIGN.md S19).
///
/// `speed` scales the recorded schedule (2.0 = twice as fast); `None`
/// floods the pipeline as fast as it admits (closed-loop).
pub fn run_replay(
    cfg: &ServeConfig,
    trace: &Trace,
    speed: Option<f64>,
) -> Result<(Metrics, f64, String)> {
    anyhow::ensure!(!cfg.engines.is_empty(), "replay needs at least one engine kind");
    let meta = ArtifactsMeta::load(&cfg.artifacts_dir)
        .context("loading artifacts (run `make artifacts`)")?;
    let model_cfg = meta.config.clone();
    let (n_max, num_labels) = (model_cfg.n_max, model_cfg.num_labels);
    let h = trace.header();

    // Rebuild the recorded corpus from the header's recipe — the exact
    // synthesis `run_serve` performs, so corpus ids and candidate
    // contents match the recorded run.
    let mut corpora: BTreeMap<String, Arc<Corpus>> = BTreeMap::new();
    if h.corpus_size > 0 {
        let mut rng = Rng::new(h.seed);
        let db = GraphDb::synthesize(&mut rng, Family::Aids, h.corpus_size, n_max, num_labels);
        // Same construction path as run_serve: the rebuilt corpus pins
        // the same initial epoch, so epoch-stamped partials merge.
        let store = CorpusStore::from_db("aids-synth", &db, n_max, num_labels)
            .map_err(|e| anyhow::anyhow!("encoding corpus: {e}"))?;
        let corpus = Arc::clone(&store.snapshot().corpus);
        corpora.insert(corpus.name().to_string(), corpus);
    }
    // Fail fast on unknown corpus names, so the schedule/query pairing
    // below can't silently skip entries.
    for e in trace.entries() {
        if let Some(name) = e.corpus() {
            anyhow::ensure!(
                corpora.contains_key(name),
                "trace entry {} names corpus '{name}' this replay can't rebuild",
                e.id()
            );
        }
    }

    let outcomes: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::with_capacity(trace.len())));
    let tap: ResultTap = {
        let lines = Arc::clone(&outcomes);
        Arc::new(move |r| {
            lines
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .push(outcome_line(r));
        })
    };
    let pipeline =
        Pipeline::start_with_tap(model_cfg, cfg.lane_factories(), cfg.pipeline_config(), Some(tap));

    let schedule = match speed {
        Some(s) => {
            anyhow::ensure!(s > 0.0 && s.is_finite(), "replay speed must be a positive number");
            Some(trace.entries().iter().map(|e| e.offset().div_f64(s)).collect())
        }
        None => None,
    };
    // Queries are rebuilt lazily at submit time (same reason run_serve
    // builds them lazily: the `submitted` stamp is the arrival clock).
    // to_query can't fail here — corpus names were checked above.
    let queries = trace.entries().iter().filter_map(|e| e.to_query(&corpora).ok());
    pipeline.wait_ready();
    let t0 = Instant::now();
    pump(&pipeline, queries, schedule);
    let metrics = pipeline.finish();
    let wall = t0.elapsed().as_secs_f64();

    let mut lines = std::mem::take(&mut *outcomes.lock().unwrap_or_else(|p| p.into_inner()));
    lines.sort();
    let mut dump = lines.join("\n");
    if !dump.is_empty() {
        dump.push('\n');
    }
    Ok((metrics, wall, dump))
}

/// Closed-loop serving: flood the pipeline with a synthetic workload and
/// report peak throughput (queueing delay inflates latency).
pub fn serve_workload(cfg: &ServeConfig) -> Result<crate::report::Table> {
    let (metrics, wall, _) = run_serve(cfg, None)?;
    let mut t = metrics.render_table(&format!(
        "serve: engine={} lanes={} batch_max={} timeout={}us depth={} queries={}{}",
        cfg.engines_label(),
        cfg.lanes(),
        cfg.batch_max,
        cfg.batch_timeout_us,
        cfg.pipeline_depth,
        cfg.queries,
        cfg.workload_label()
    ));
    t.row(vec!["wall time (s)".into(), crate::report::fmt(wall)]);
    t.row(vec![
        "offered throughput (query/s)".into(),
        crate::report::fmt(metrics.scored as f64 / wall),
    ]);
    Ok(t)
}

/// Open-loop serving: Poisson arrivals at `rate_qps` (the
/// latency-under-load methodology; closed-loop `serve_workload` measures
/// peak throughput but conflates queueing delay into latency).
pub fn serve_paced(cfg: &ServeConfig, rate_qps: f64) -> Result<crate::report::Table> {
    let (metrics, _wall, max_late) = run_serve(cfg, Some(rate_qps))?;
    let mut t = metrics.render_table(&format!(
        "serve-paced: engine={} rate={:.0} q/s lanes={} batch_max={} depth={} queries={}{}",
        cfg.engines_label(),
        rate_qps,
        cfg.lanes(),
        cfg.batch_max,
        cfg.pipeline_depth,
        cfg.queries,
        cfg.workload_label()
    ));
    t.row(vec![
        "max submit lateness (ms)".into(),
        crate::report::fmt(max_late.as_secs_f64() * 1e3),
    ]);
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("meta.json").exists() {
            Some(dir)
        } else {
            eprintln!("SKIP: artifacts missing");
            None
        }
    }

    #[test]
    fn serve_native_end_to_end() {
        let Some(dir) = artifacts() else { return };
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engines: vec![EngineKind::Native],
            queries: 40,
            workers: 2,
            batch_max: 8,
            batch_timeout_us: 100,
            seed: 5,
            ..ServeConfig::default()
        };
        let t = serve_workload(&cfg).unwrap();
        let scored: f64 = t.rows[0][1].parse().unwrap();
        assert_eq!(scored, 40.0, "{}", t.render());
        // Per-stage breakdown and channel stats present in the report.
        assert!(t.get("queue wait mean (ms)").is_some(), "{}", t.render());
        assert!(t.get("execute p95 (ms)").is_some(), "{}", t.render());
        assert!(
            t.rows.iter().any(|r| r[0].starts_with("chan exec.0")),
            "{}",
            t.render()
        );
        // Both lanes are named with the native engine and the native
        // path reports its per-slot CPU telemetry.
        assert_eq!(t.get("lane.0 engine"), Some("native-cpu"), "{}", t.render());
        assert_eq!(t.get("lane.1 engine"), Some("native-cpu"), "{}", t.render());
        assert_eq!(t.get("engine native-cpu scored"), Some("40"), "{}", t.render());
        let cpu: f64 = t.get("engine cpu mean (ms)").unwrap().parse().unwrap();
        assert!(cpu > 0.0, "{}", t.render());
    }

    #[test]
    fn serve_sim_engine_reports_cycle_telemetry() {
        let Some(dir) = artifacts() else { return };
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engines: vec![EngineKind::Sim],
            queries: 10,
            workers: 1,
            batch_max: 4,
            batch_timeout_us: 100,
            seed: 6,
            ..ServeConfig::default()
        };
        let t = serve_workload(&cfg).unwrap();
        let scored: f64 = t.rows[0][1].parse().unwrap();
        assert_eq!(scored, 10.0, "{}", t.render());
        // The simulator's cycle counts now reach the serve report.
        let interval: f64 = t
            .get("sim interval cycles mean")
            .expect("cycle telemetry row present")
            .parse()
            .unwrap();
        assert!(interval > 0.0, "{}", t.render());
        let latency: f64 = t.get("sim latency cycles mean").unwrap().parse().unwrap();
        assert!(latency > 0.0, "{}", t.render());
        assert_eq!(t.get("lane.0 engine"), Some("spa-gcn-sim"), "{}", t.render());
    }

    #[test]
    fn serve_mixed_engine_lanes() {
        let Some(dir) = artifacts() else { return };
        // One native lane + one sim lane in the same pipeline: both
        // serve traffic, the report names each lane's engine and carries
        // both telemetry flavors.
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engines: vec![EngineKind::Native, EngineKind::Sim],
            queries: 24,
            workers: 1, // raised to engines.len() internally
            batch_max: 4,
            batch_timeout_us: 100,
            seed: 9,
            ..ServeConfig::default()
        };
        let t = serve_workload(&cfg).unwrap();
        let scored: f64 = t.rows[0][1].parse().unwrap();
        assert_eq!(scored, 24.0, "{}", t.render());
        assert_eq!(t.get("lane.0 engine"), Some("native-cpu"), "{}", t.render());
        assert_eq!(t.get("lane.1 engine"), Some("spa-gcn-sim"), "{}", t.render());
        // Round-robin across healthy lanes: both engines actually scored.
        let native: u64 = t.get("engine native-cpu scored").unwrap().parse().unwrap();
        let sim: u64 = t.get("engine spa-gcn-sim scored").unwrap().parse().unwrap();
        assert_eq!(native + sim, 24, "{}", t.render());
        assert!(native > 0 && sim > 0, "{}", t.render());
        // Sim lanes contributed cycle rows, native lanes CPU rows.
        assert!(t.get("sim interval cycles mean").is_some(), "{}", t.render());
        assert!(t.get("engine cpu mean (ms)").is_some(), "{}", t.render());
    }

    #[test]
    fn serve_corpus_topk_end_to_end() {
        let Some(dir) = artifacts() else { return };
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engines: vec![EngineKind::Native],
            queries: 12,
            workers: 2,
            batch_max: 4,
            batch_timeout_us: 100,
            seed: 11,
            corpus_size: 32,
            topk: 5,
            ..ServeConfig::default()
        };
        let t = serve_workload(&cfg).unwrap();
        let scored: f64 = t.rows[0][1].parse().unwrap();
        assert_eq!(scored, 12.0, "{}", t.render());
        assert_eq!(t.get("topk queries"), Some("12"), "{}", t.render());
        // With 12 queries × 32 candidates against one shared corpus the
        // cache must be doing real work: far fewer forwards than the
        // 1 + 32 a cacheless engine would pay per query.
        let forwards: f64 = t.get("gcn forwards per query").unwrap().parse().unwrap();
        assert!(
            forwards < 33.0,
            "cache inactive: {forwards} forwards/query\n{}",
            t.render()
        );
        let hit_rate: f64 = t.get("embed cache hit rate").unwrap().parse().unwrap();
        assert!(hit_rate > 0.0, "{}", t.render());
        assert!(t.get("embed cache entries").is_some(), "{}", t.render());
        // run_serve waits for both caps handshakes before submitting,
        // so with two shard-capable native lanes every top-k query is
        // deterministically scattered into exactly two shards.
        let shards: f64 = t.get("topk shards mean").unwrap().parse().unwrap();
        assert_eq!(shards, 2.0, "{}", t.render());
        assert!(t.get("topk lane spread (ms)").is_some(), "{}", t.render());
    }

    #[test]
    fn serve_budgeted_cascade_end_to_end() {
        let Some(dir) = artifacts() else { return };
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engines: vec![EngineKind::Native],
            queries: 10,
            workers: 2,
            batch_max: 4,
            batch_timeout_us: 100,
            seed: 17,
            corpus_size: 32,
            topk: 4,
            budget: 8,
            ..ServeConfig::default()
        };
        let t = serve_workload(&cfg).unwrap();
        let scored: f64 = t.rows[0][1].parse().unwrap();
        assert_eq!(scored, 10.0, "{}", t.render());
        // Every query went through the cascade: exactly `budget`
        // survivors, the rest pruned before the NTN+FCN tail.
        assert_eq!(t.get("cascade queries"), Some("10"), "{}", t.render());
        let survivors: f64 = t.get("cascade survivors mean").unwrap().parse().unwrap();
        let pruned: f64 = t.get("cascade pruned mean").unwrap().parse().unwrap();
        assert_eq!(survivors, 8.0, "{}", t.render());
        assert_eq!(pruned, 24.0, "{}", t.render());
        assert!(t.get("cascade prune mean (ms)").is_some(), "{}", t.render());
    }

    #[test]
    fn budgeted_record_then_replay_is_deterministic() {
        let Some(dir) = artifacts() else { return };
        let trace_path = std::env::temp_dir()
            .join(format!("spa-gcn-budget-replay-{}.trace", std::process::id()));
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engines: vec![EngineKind::Native],
            queries: 8,
            workers: 2,
            batch_max: 4,
            batch_timeout_us: 100,
            seed: 19,
            corpus_size: 16,
            topk: 3,
            budget: 6,
            record: Some(trace_path.clone()),
            ..ServeConfig::default()
        };
        serve_workload(&cfg).unwrap();
        let trace = Trace::read(&trace_path).unwrap();
        std::fs::remove_file(&trace_path).ok();
        assert_eq!(trace.len(), 8);
        // The recorder captured the cascade budget and the store's
        // first-generation epoch on every entry.
        assert!(trace.entries().iter().all(|e| e.budget() == 6), "budget recorded");
        assert!(trace.entries().iter().all(|e| e.epoch() == 1), "epoch recorded");

        let replay_cfg = ServeConfig { record: None, ..cfg };
        let (m1, _, dump1) = run_replay(&replay_cfg, &trace, None).unwrap();
        let (_, _, dump2) = run_replay(&replay_cfg, &trace, None).unwrap();
        assert_eq!(m1.scored, 8, "replay scores every recorded query");
        assert_eq!(dump1, dump2, "budgeted replays are byte-identical");
        // Budgeted rankings never answer more than `budget` candidates.
        for line in dump1.lines() {
            let ranked = line.split("ranked=").nth(1).unwrap_or("");
            let n = ranked.split(',').filter(|s| !s.is_empty()).count();
            assert!(n <= 6, "{line}");
        }
    }

    #[test]
    fn serve_sequential_baseline_depth_zero() {
        let Some(dir) = artifacts() else { return };
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engines: vec![EngineKind::Native],
            queries: 20,
            workers: 1,
            batch_max: 8,
            batch_timeout_us: 100,
            seed: 7,
            pipeline_depth: 0,
            ..ServeConfig::default()
        };
        let t = serve_workload(&cfg).unwrap();
        let scored: f64 = t.rows[0][1].parse().unwrap();
        assert_eq!(scored, 20.0, "{}", t.render());
    }

    #[test]
    fn serve_paced_under_light_load() {
        let Some(dir) = artifacts() else { return };
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engines: vec![EngineKind::Native],
            queries: 30,
            workers: 1,
            batch_max: 8,
            batch_timeout_us: 300,
            seed: 8,
            ..ServeConfig::default()
        };
        let t = serve_paced(&cfg, 100.0).unwrap();
        let scored: f64 = t.rows[0][1].parse().unwrap();
        assert_eq!(scored, 30.0, "{}", t.render());
        // light load (100 q/s against a ~ms-scale engine): p50 latency
        // stays well below the 10 ms inter-arrival scale even in debug
        // builds.
        let p50: f64 = t.rows[5][1].parse().unwrap();
        assert!(p50 < 200.0, "p50 {p50} ms too high for light load");
    }

    #[test]
    fn record_then_replay_is_deterministic() {
        let Some(dir) = artifacts() else { return };
        let trace_path = std::env::temp_dir()
            .join(format!("spa-gcn-replay-test-{}.trace", std::process::id()));
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engines: vec![EngineKind::Native],
            queries: 12,
            workers: 2,
            batch_max: 4,
            batch_timeout_us: 100,
            seed: 13,
            corpus_size: 16,
            topk: 3,
            record: Some(trace_path.clone()),
            ..ServeConfig::default()
        };
        serve_workload(&cfg).unwrap();
        let trace = Trace::read(&trace_path).unwrap();
        std::fs::remove_file(&trace_path).ok();
        assert_eq!(trace.len(), 12, "every submitted query recorded");
        assert_eq!(trace.header().corpus_size, 16);

        let replay_cfg = ServeConfig { record: None, ..cfg };
        let (m1, _, dump1) = run_replay(&replay_cfg, &trace, None).unwrap();
        let (m2, _, dump2) = run_replay(&replay_cfg, &trace, None).unwrap();
        assert_eq!(m1.scored, 12, "replay scores every recorded query");
        assert_eq!(dump1, dump2, "same trace, byte-identical outcome dumps");
        assert_eq!(
            m1.gcn_forwards.mean(),
            m2.gcn_forwards.mean(),
            "identical forwards-per-query telemetry"
        );
        // The dump carries one line per recorded query, id-sorted.
        assert_eq!(dump1.lines().count(), 12, "{dump1}");
        // Paced replay serves the same outcomes as the flood replay.
        let (_, _, dump3) = run_replay(&replay_cfg, &trace, Some(1000.0)).unwrap();
        assert_eq!(dump1, dump3, "pacing must not change scores");
    }

    #[test]
    fn serve_requires_engine_kinds() {
        // Unknown engine *strings* are now unrepresentable (typed
        // EngineKind, parse-time rejection — see runtime::tests); the
        // remaining config error is an empty lane pattern.
        let cfg = ServeConfig {
            engines: vec![],
            queries: 1,
            ..ServeConfig::default()
        };
        let err = serve_workload(&cfg).unwrap_err();
        assert!(err.to_string().contains("at least one engine"), "{err:#}");
    }
}
