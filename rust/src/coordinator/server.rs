//! The serving loop: leader thread (router) + worker threads (batcher +
//! engine), connected by bounded channels for backpressure.
//!
//! Matches the paper's deployment: a host process owns a compiled
//! accelerator (PJRT executable here, bitstream there), queries stream
//! in, the coordinator batches them to amortize per-launch overhead
//! (Fig. 11) and can replicate workers (§5.4.3).

use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::graph::dataset::{random_pairs, GraphDb};
use crate::graph::encode::{encode, PackedBatch};
use crate::graph::generate::Family;
use crate::nn::config::ArtifactsMeta;
use crate::runtime::native::NativeEngine;
use crate::runtime::pjrt::XlaEngine;
use crate::runtime::{pick_batch_size, Engine};
use crate::sim::config::ArchConfig;
use crate::sim::engine::SimEngine;
use crate::sim::platform::U280;
use crate::util::rng::Rng;

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::query::{Outcome, Query, QueryResult};
use super::router::Router;

/// Serving configuration (CLI `spa-gcn serve`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifacts_dir: PathBuf,
    /// "xla" | "native" | "sim"
    pub engine: String,
    pub queries: usize,
    pub workers: usize,
    pub batch_max: usize,
    pub batch_timeout_us: u64,
    pub seed: u64,
}

fn build_engine(kind: &str, artifacts_dir: &PathBuf) -> Result<Box<dyn Engine>> {
    match kind {
        "xla" => Ok(Box::new(XlaEngine::load(artifacts_dir)?)),
        "xla-fused" => Ok(Box::new(XlaEngine::load_fused(artifacts_dir)?)),
        "native" => Ok(Box::new(NativeEngine::load(artifacts_dir)?)),
        "sim" => Ok(Box::new(SimEngine::load(
            artifacts_dir,
            ArchConfig::spa_gcn(),
            U280,
        )?)),
        other => anyhow::bail!("unknown engine '{other}' (xla|xla-fused|native|sim)"),
    }
}

/// Worker loop: drain the queue through the batcher into the engine.
fn worker_loop(
    rx: Receiver<Query>,
    results: Sender<QueryResult>,
    mut engine: Box<dyn Engine>,
    policy: BatchPolicy,
    n_max: usize,
    num_labels: usize,
) {
    let mut batcher = Batcher::new(policy);
    let supported = engine.supported_batch_sizes();
    let mut execute = |batch: Vec<Query>| {
        let bsz = pick_batch_size(&supported, batch.len());
        // Chunk if the batch exceeds the largest artifact.
        for chunk in batch.chunks(bsz.max(1)) {
            let encoded: Vec<_> = chunk
                .iter()
                .map(|q| {
                    (
                        encode(&q.g1, n_max, num_labels).expect("router validated"),
                        encode(&q.g2, n_max, num_labels).expect("router validated"),
                    )
                })
                .collect();
            let eff = pick_batch_size(&supported, chunk.len());
            let packed = PackedBatch::pack(&encoded, eff);
            match engine.score_batch(&packed) {
                Ok(scores) => {
                    for (i, q) in chunk.iter().enumerate() {
                        let _ = results.send(QueryResult {
                            id: q.id,
                            outcome: Outcome::Score(scores[i]),
                            latency_us: q.submitted.elapsed().as_secs_f64() * 1e6,
                            batch_size: chunk.len(),
                        });
                    }
                }
                Err(e) => {
                    for q in chunk {
                        let _ = results.send(QueryResult {
                            id: q.id,
                            outcome: Outcome::EngineError(e.to_string()),
                            latency_us: q.submitted.elapsed().as_secs_f64() * 1e6,
                            batch_size: chunk.len(),
                        });
                    }
                }
            }
        }
    };

    loop {
        let wait = batcher
            .time_to_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(wait) {
            Ok(q) => {
                if let Some(batch) = batcher.push(q, Instant::now()) {
                    execute(batch);
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if let Some(batch) = batcher.poll(Instant::now()) {
                    execute(batch);
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                if let Some(batch) = batcher.flush() {
                    execute(batch);
                }
                break;
            }
        }
    }
}

/// Serve a synthetic workload end-to-end and report metrics.
pub fn serve_workload(cfg: &ServeConfig) -> Result<crate::report::Table> {
    let meta = ArtifactsMeta::load(&cfg.artifacts_dir)
        .context("loading artifacts (run `make artifacts`)")?;
    let model_cfg = meta.config.clone();

    // Workload: AIDS-like random pairs (paper §5.1).
    let mut rng = Rng::new(cfg.seed);
    let db = GraphDb::synthesize(
        &mut rng,
        Family::Aids,
        512,
        model_cfg.n_max,
        model_cfg.num_labels,
    );
    let pairs = random_pairs(&mut rng, &db, cfg.queries);

    // Workers.
    let (result_tx, result_rx) = std::sync::mpsc::channel::<QueryResult>();
    let mut worker_txs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..cfg.workers.max(1) {
        let (tx, rx) = sync_channel::<Query>(cfg.batch_max * 4);
        worker_txs.push(tx);
        let results = result_tx.clone();
        let engine_kind = cfg.engine.clone();
        let dir = cfg.artifacts_dir.clone();
        let policy = BatchPolicy {
            max_batch: cfg.batch_max,
            timeout: Duration::from_micros(cfg.batch_timeout_us),
        };
        let (n_max, num_labels) = (model_cfg.n_max, model_cfg.num_labels);
        handles.push(thread::spawn(move || {
            // Engines are constructed in-thread (PJRT handles are not Send).
            let engine = build_engine(&engine_kind, &dir).expect("engine construction");
            worker_loop(rx, results, engine, policy, n_max, num_labels);
        }));
    }
    drop(result_tx);

    let mut metrics = Metrics::new();
    let mut router = Router::new(model_cfg, worker_txs);
    let t0 = Instant::now();
    for q in pairs {
        if let Some(reject) = router.route(Query::new(q.id, q.g1, q.g2)) {
            metrics.record(&reject);
        }
    }
    // Close worker queues; they flush + exit.
    router_shutdown(router);
    for r in result_rx {
        metrics.record(&r);
    }
    for h in handles {
        let _ = h.join();
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut t = metrics.render_table(&format!(
        "serve: engine={} workers={} batch_max={} timeout={}us queries={}",
        cfg.engine, cfg.workers, cfg.batch_max, cfg.batch_timeout_us, cfg.queries
    ));
    t.row(vec![
        "wall time (s)".into(),
        crate::report::fmt(wall),
    ]);
    t.row(vec![
        "offered throughput (query/s)".into(),
        crate::report::fmt(metrics.scored as f64 / wall),
    ]);
    Ok(t)
}

fn router_shutdown(router: Router) {
    drop(router); // drops worker senders -> workers drain + exit
}

/// Open-loop serving: Poisson arrivals at `rate_qps` (the
/// latency-under-load methodology; closed-loop `serve_workload` measures
/// peak throughput but conflates queueing delay into latency).
pub fn serve_paced(cfg: &ServeConfig, rate_qps: f64) -> Result<crate::report::Table> {
    use super::load::{poisson_schedule, Pacer};

    let meta = ArtifactsMeta::load(&cfg.artifacts_dir)
        .context("loading artifacts (run `make artifacts`)")?;
    let model_cfg = meta.config.clone();
    let mut rng = Rng::new(cfg.seed);
    let db = GraphDb::synthesize(
        &mut rng,
        Family::Aids,
        512,
        model_cfg.n_max,
        model_cfg.num_labels,
    );
    let pairs = random_pairs(&mut rng, &db, cfg.queries);
    let schedule = poisson_schedule(&mut rng, rate_qps, cfg.queries);

    let (result_tx, result_rx) = std::sync::mpsc::channel::<QueryResult>();
    let mut worker_txs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..cfg.workers.max(1) {
        let (tx, rx) = sync_channel::<Query>(cfg.batch_max * 16);
        worker_txs.push(tx);
        let results = result_tx.clone();
        let engine_kind = cfg.engine.clone();
        let dir = cfg.artifacts_dir.clone();
        let policy = BatchPolicy {
            max_batch: cfg.batch_max,
            timeout: Duration::from_micros(cfg.batch_timeout_us),
        };
        let (n_max, num_labels) = (model_cfg.n_max, model_cfg.num_labels);
        handles.push(thread::spawn(move || {
            let engine = build_engine(&engine_kind, &dir).expect("engine construction");
            worker_loop(rx, results, engine, policy, n_max, num_labels);
        }));
    }
    drop(result_tx);

    let mut metrics = Metrics::new();
    let mut router = Router::new(model_cfg, worker_txs);
    let pacer = Pacer::new();
    let mut max_late = Duration::ZERO;
    for (q, at) in pairs.into_iter().zip(schedule) {
        max_late = max_late.max(pacer.wait_until(at));
        if let Some(reject) = router.route(Query::new(q.id, q.g1, q.g2)) {
            metrics.record(&reject);
        }
    }
    router_shutdown(router);
    for r in result_rx {
        metrics.record(&r);
    }
    for h in handles {
        let _ = h.join();
    }
    let mut t = metrics.render_table(&format!(
        "serve-paced: engine={} rate={:.0} q/s workers={} batch_max={} queries={}",
        cfg.engine, rate_qps, cfg.workers, cfg.batch_max, cfg.queries
    ));
    t.row(vec![
        "max submit lateness (ms)".into(),
        crate::report::fmt(max_late.as_secs_f64() * 1e3),
    ]);
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("meta.json").exists() {
            Some(dir)
        } else {
            eprintln!("SKIP: artifacts missing");
            None
        }
    }

    #[test]
    fn serve_native_end_to_end() {
        let Some(dir) = artifacts() else { return };
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engine: "native".into(),
            queries: 40,
            workers: 2,
            batch_max: 8,
            batch_timeout_us: 100,
            seed: 5,
        };
        let t = serve_workload(&cfg).unwrap();
        let scored: f64 = t.rows[0][1].parse().unwrap();
        assert_eq!(scored, 40.0, "{}", t.render());
    }

    #[test]
    fn serve_sim_engine() {
        let Some(dir) = artifacts() else { return };
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engine: "sim".into(),
            queries: 10,
            workers: 1,
            batch_max: 4,
            batch_timeout_us: 100,
            seed: 6,
        };
        let t = serve_workload(&cfg).unwrap();
        let scored: f64 = t.rows[0][1].parse().unwrap();
        assert_eq!(scored, 10.0, "{}", t.render());
    }

    #[test]
    fn serve_paced_under_light_load() {
        let Some(dir) = artifacts() else { return };
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engine: "native".into(),
            queries: 30,
            workers: 1,
            batch_max: 8,
            batch_timeout_us: 300,
            seed: 8,
        };
        let t = serve_paced(&cfg, 100.0).unwrap();
        let scored: f64 = t.rows[0][1].parse().unwrap();
        assert_eq!(scored, 30.0, "{}", t.render());
        // light load (100 q/s against a ~ms-scale engine): p50 latency
        // stays well below the 10 ms inter-arrival scale even in debug
        // builds.
        let p50: f64 = t.rows[5][1].parse().unwrap();
        assert!(p50 < 200.0, "p50 {p50} ms too high for light load");
    }

    #[test]
    fn serve_rejects_unknown_engine() {
        let Some(dir) = artifacts() else { return };
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engine: "bogus".into(),
            queries: 1,
            workers: 1,
            batch_max: 1,
            batch_timeout_us: 1,
            seed: 0,
        };
        // Worker thread panics on engine construction; results channel
        // closes; all queries unaccounted -> scored == 0.
        let t = serve_workload(&cfg).unwrap();
        let scored: f64 = t.rows[0][1].parse().unwrap();
        assert_eq!(scored, 0.0, "{}", t.render());
    }
}
