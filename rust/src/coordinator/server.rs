//! The serving entrypoints: build a staged [`Pipeline`], pump a workload
//! through it (closed-loop flood or open-loop Poisson pacing), render
//! the metrics report.
//!
//! Matches the paper's deployment: a host process owns a compiled
//! accelerator (PJRT executable here, bitstream there), queries stream
//! in, the coordinator batches them to amortize per-launch overhead
//! (Fig. 11) and replicates worker lanes (§5.4.3). The stage wiring
//! itself lives in [`super::pipeline`]; both entrypoints share the one
//! construction path.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::graph::dataset::{random_pairs, GraphDb};
use crate::graph::generate::Family;
use crate::nn::config::ArtifactsMeta;
use crate::runtime::native::NativeEngine;
use crate::runtime::pjrt::XlaEngine;
use crate::runtime::{Engine, EngineFactory};
use crate::sim::config::ArchConfig;
use crate::sim::engine::SimEngine;
use crate::sim::platform::U280;
use crate::util::rng::Rng;

use super::batcher::BatchPolicy;
use super::load::{poisson_schedule, Pacer};
use super::metrics::Metrics;
use super::pipeline::{Pipeline, PipelineConfig};
use super::query::Query;

/// Serving configuration (CLI `spa-gcn serve`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub artifacts_dir: PathBuf,
    /// "xla" | "xla-fused" | "native" | "sim"
    pub engine: String,
    pub queries: usize,
    pub workers: usize,
    pub batch_max: usize,
    pub batch_timeout_us: u64,
    pub seed: u64,
    /// Encoded-chunk buffer per worker lane: >= 1 overlaps encode with
    /// engine execution (2 = double buffering), 0 runs them sequentially
    /// in one thread (the no-overlap baseline).
    pub pipeline_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            engine: "xla".into(),
            queries: 1000,
            workers: 1,
            batch_max: 64,
            batch_timeout_us: 200,
            seed: 42,
            pipeline_depth: 2,
        }
    }
}

impl ServeConfig {
    fn pipeline_config(&self) -> PipelineConfig {
        PipelineConfig {
            workers: self.workers.max(1),
            policy: BatchPolicy {
                max_batch: self.batch_max.max(1),
                timeout: Duration::from_micros(self.batch_timeout_us),
            },
            depth: self.pipeline_depth,
            admit_cap: (self.batch_max * 4).max(64),
            batch_cap: 8,
            results_cap: 1024,
        }
    }
}

/// Construct an engine by name. Called inside executor threads (PJRT
/// handles are not `Send`), so it takes owned-ish borrows only.
pub fn build_engine(kind: &str, artifacts_dir: &Path) -> Result<Box<dyn Engine>> {
    match kind {
        "xla" => Ok(Box::new(XlaEngine::load(artifacts_dir)?)),
        "xla-fused" => Ok(Box::new(XlaEngine::load_fused(artifacts_dir)?)),
        "native" => Ok(Box::new(NativeEngine::load(artifacts_dir)?)),
        "sim" => Ok(Box::new(SimEngine::load(
            artifacts_dir,
            ArchConfig::spa_gcn(),
            U280,
        )?)),
        other => anyhow::bail!("unknown engine '{other}' (xla|xla-fused|native|sim)"),
    }
}

/// The `Send` closure executor stages call in-thread to build their
/// (non-`Send`) engine.
pub fn engine_factory(kind: String, artifacts_dir: PathBuf) -> EngineFactory {
    Arc::new(move || build_engine(&kind, &artifacts_dir))
}

/// Shared serving core: synthesize the workload, run it through one
/// staged pipeline (closed-loop when `pace_qps` is None, open-loop
/// Poisson otherwise), return (metrics, wall seconds, max lateness).
fn run_serve(cfg: &ServeConfig, pace_qps: Option<f64>) -> Result<(Metrics, f64, Duration)> {
    let meta = ArtifactsMeta::load(&cfg.artifacts_dir)
        .context("loading artifacts (run `make artifacts`)")?;
    let model_cfg = meta.config.clone();

    // Workload: AIDS-like random pairs (paper §5.1).
    let mut rng = Rng::new(cfg.seed);
    let db = GraphDb::synthesize(
        &mut rng,
        Family::Aids,
        512,
        model_cfg.n_max,
        model_cfg.num_labels,
    );
    let pairs = random_pairs(&mut rng, &db, cfg.queries);
    let schedule = pace_qps.map(|rate| poisson_schedule(&mut rng, rate, cfg.queries));

    let pipeline = Pipeline::start(
        model_cfg,
        engine_factory(cfg.engine.clone(), cfg.artifacts_dir.clone()),
        cfg.pipeline_config(),
    );

    let t0 = Instant::now();
    let mut max_late = Duration::ZERO;
    match schedule {
        Some(schedule) => {
            let pacer = Pacer::new();
            for (q, at) in pairs.into_iter().zip(schedule) {
                max_late = max_late.max(pacer.wait_until(at));
                pipeline.submit(Query::new(q.id, q.g1, q.g2));
            }
        }
        None => {
            for q in pairs {
                pipeline.submit(Query::new(q.id, q.g1, q.g2));
            }
        }
    }
    let metrics = pipeline.finish();
    Ok((metrics, t0.elapsed().as_secs_f64(), max_late))
}

/// Closed-loop serving: flood the pipeline with a synthetic workload and
/// report peak throughput (queueing delay inflates latency).
pub fn serve_workload(cfg: &ServeConfig) -> Result<crate::report::Table> {
    let (metrics, wall, _) = run_serve(cfg, None)?;
    let mut t = metrics.render_table(&format!(
        "serve: engine={} workers={} batch_max={} timeout={}us depth={} queries={}",
        cfg.engine, cfg.workers, cfg.batch_max, cfg.batch_timeout_us, cfg.pipeline_depth,
        cfg.queries
    ));
    t.row(vec!["wall time (s)".into(), crate::report::fmt(wall)]);
    t.row(vec![
        "offered throughput (query/s)".into(),
        crate::report::fmt(metrics.scored as f64 / wall),
    ]);
    Ok(t)
}

/// Open-loop serving: Poisson arrivals at `rate_qps` (the
/// latency-under-load methodology; closed-loop `serve_workload` measures
/// peak throughput but conflates queueing delay into latency).
pub fn serve_paced(cfg: &ServeConfig, rate_qps: f64) -> Result<crate::report::Table> {
    let (metrics, _wall, max_late) = run_serve(cfg, Some(rate_qps))?;
    let mut t = metrics.render_table(&format!(
        "serve-paced: engine={} rate={:.0} q/s workers={} batch_max={} depth={} queries={}",
        cfg.engine, rate_qps, cfg.workers, cfg.batch_max, cfg.pipeline_depth, cfg.queries
    ));
    t.row(vec![
        "max submit lateness (ms)".into(),
        crate::report::fmt(max_late.as_secs_f64() * 1e3),
    ]);
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("meta.json").exists() {
            Some(dir)
        } else {
            eprintln!("SKIP: artifacts missing");
            None
        }
    }

    #[test]
    fn serve_native_end_to_end() {
        let Some(dir) = artifacts() else { return };
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engine: "native".into(),
            queries: 40,
            workers: 2,
            batch_max: 8,
            batch_timeout_us: 100,
            seed: 5,
            ..ServeConfig::default()
        };
        let t = serve_workload(&cfg).unwrap();
        let scored: f64 = t.rows[0][1].parse().unwrap();
        assert_eq!(scored, 40.0, "{}", t.render());
        // Per-stage breakdown and channel stats present in the report.
        assert!(t.get("queue wait mean (ms)").is_some(), "{}", t.render());
        assert!(t.get("execute p95 (ms)").is_some(), "{}", t.render());
        assert!(
            t.rows.iter().any(|r| r[0].starts_with("chan exec.0")),
            "{}",
            t.render()
        );
    }

    #[test]
    fn serve_sim_engine() {
        let Some(dir) = artifacts() else { return };
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engine: "sim".into(),
            queries: 10,
            workers: 1,
            batch_max: 4,
            batch_timeout_us: 100,
            seed: 6,
            ..ServeConfig::default()
        };
        let t = serve_workload(&cfg).unwrap();
        let scored: f64 = t.rows[0][1].parse().unwrap();
        assert_eq!(scored, 10.0, "{}", t.render());
    }

    #[test]
    fn serve_sequential_baseline_depth_zero() {
        let Some(dir) = artifacts() else { return };
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engine: "native".into(),
            queries: 20,
            workers: 1,
            batch_max: 8,
            batch_timeout_us: 100,
            seed: 7,
            pipeline_depth: 0,
        };
        let t = serve_workload(&cfg).unwrap();
        let scored: f64 = t.rows[0][1].parse().unwrap();
        assert_eq!(scored, 20.0, "{}", t.render());
    }

    #[test]
    fn serve_paced_under_light_load() {
        let Some(dir) = artifacts() else { return };
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engine: "native".into(),
            queries: 30,
            workers: 1,
            batch_max: 8,
            batch_timeout_us: 300,
            seed: 8,
            ..ServeConfig::default()
        };
        let t = serve_paced(&cfg, 100.0).unwrap();
        let scored: f64 = t.rows[0][1].parse().unwrap();
        assert_eq!(scored, 30.0, "{}", t.render());
        // light load (100 q/s against a ~ms-scale engine): p50 latency
        // stays well below the 10 ms inter-arrival scale even in debug
        // builds.
        let p50: f64 = t.rows[5][1].parse().unwrap();
        assert!(p50 < 200.0, "p50 {p50} ms too high for light load");
    }

    #[test]
    fn serve_rejects_unknown_engine() {
        let Some(dir) = artifacts() else { return };
        let cfg = ServeConfig {
            artifacts_dir: dir,
            engine: "bogus".into(),
            queries: 1,
            workers: 1,
            batch_max: 1,
            batch_timeout_us: 1,
            seed: 0,
            ..ServeConfig::default()
        };
        // Engine construction fails inside the executor stage; the lane
        // downgrades to an error drain and every query surfaces as a
        // per-query EngineError (no panic, no silently closed channel).
        let t = serve_workload(&cfg).unwrap();
        let scored: f64 = t.rows[0][1].parse().unwrap();
        let errors: f64 = t.rows[2][1].parse().unwrap();
        assert_eq!(scored, 0.0, "{}", t.render());
        assert_eq!(errors, 1.0, "{}", t.render());
    }
}
