//! The staged serving pipeline: SPA-GCN's FIFO-connected dataflow,
//! recovered on the host side.
//!
//! The paper's central design idea is a deep pipeline of stages joined
//! by FIFO streams so every unit stays busy. The serving path mirrors
//! that structure in software — one thread (or lane pool) per stage,
//! joined by [`NamedChannel`](super::channel)s:
//!
//! ```text
//! submit() ──admit──▶ [admission] ──ingest──▶ [batcher] ─┬─batch.0─▶ [encode.0] ──exec.0──▶ [execute.0] ─┐
//!                          │                             └─batch.1─▶ [encode.1] ──exec.1──▶ [execute.1] ─┤
//!                          │ rejects                                      │ encode errors                │
//!                          └────────────────────────────▶ results ◀───────┴───────────────────────────────┘
//!                                                            │
//!                                                            ▼
//!                                                       [responder] → Metrics
//! ```
//!
//! Each lane owns one engine, built in-thread from its own
//! [`EngineFactory`] — lanes may run *different* engine kinds (e.g.
//! `native` next to `sim`). Once built, the executor publishes the
//! engine's [`EngineCaps`] through the lane's
//! [`LaneCaps`](super::router::LaneCaps) cell: the encoder picks the
//! batch ladder from it, the batcher's [`CapsRouter`] steers traffic
//! away from lanes whose construction failed, and the final metrics
//! name each lane's engine. Engine telemetry (cycle reports, DMA
//! splits, per-slot CPU time) rides each result into the responder.
//!
//! Because the encoder and executor are separate threads joined by a
//! bounded `exec` channel (capacity = `depth`, default 2), batch *k+1*
//! encodes while batch *k* is inside the engine — the paper's
//! compute/transfer overlap claim, recovered for the host. `depth == 0`
//! fuses the two stages into one sequential thread: the no-overlap
//! baseline the benches compare against.
//!
//! Top-k corpus queries ([`QueryPayload::TopK`]) ride the same stages.
//! When two or more lanes have published corpus-shard-capable caps, the
//! batcher *scatters* the query: the corpus splits into contiguous
//! [`CorpusShard`] views (one per capable lane), the first shard's lane
//! embeds the query graph once (cache-aware) and publishes the
//! embedding through a first-wins cell, sibling lanes pay only the
//! NTN+FCN fan-out over their slice, and a dedicated *gather* stage
//! merges the partial scores back through `Corpus::rank_sharded` — so
//! sharded and unsharded rankings are bit-identical (DESIGN.md S15).
//! With fewer than two capable lanes (startup window, dead lanes, tiny
//! corpus) the query takes the whole-query path: the executor calls
//! `Engine::score_corpus` and assembles the ranking in place
//! (DESIGN.md S14).
//!
//! Shutdown is an ordered drop-sender cascade: dropping the pipeline's
//! submit sender makes admission drain and exit, which drops the ingest
//! sender, which makes the batcher flush and exit, and so on down the
//! chain until the responder sees its channel close and returns the
//! final [`Metrics`]. No query is lost or duplicated on the way down.

use std::cell::Cell;
use std::collections::HashMap;
use std::sync::mpsc::RecvTimeoutError;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::graph::encode::{encode, EncodedGraph, PackedBatch};
use crate::nn::config::ModelConfig;
use crate::runtime::embed_cache::CachedEmbed;
use crate::runtime::{Engine, EngineCaps, EngineError, EngineFactory, QueryTelemetry};

use super::batcher::{BatchPolicy, Batcher};
use super::channel::{channel, ChannelStats, NamedReceiver, NamedSender, SendPolicy, SendResult};
use super::corpus::{Corpus, CorpusShard, PrunePlan, ShardPartial};
use super::metrics::{LaneInfo, Metrics};
use super::query::{
    CascadeInfo, Outcome, Query, QueryPayload, QueryResult, RejectReason, ShardingInfo,
    StageTiming,
};
use super::router::{Admission, CapsRouter, LaneCaps};

/// A batch released by the batcher stage, bound for one worker lane.
#[derive(Debug)]
pub struct Batch {
    /// The queries riding in this batch, submission order.
    pub queries: Vec<Query>,
}

/// Unit of traffic on a lane's batch channel: a released batch of pair
/// and/or whole top-k queries, or one shard of a scattered top-k query.
enum LaneTask {
    Batch(Batch),
    Shard(ShardTask),
}

/// One scattered top-k query's shared, immutable plan: the query itself
/// (owned here once, `Arc`-shared by every shard task), the shard
/// count the gather stage must wait for, and the first-wins cell the
/// embedder lane publishes the query embedding through.
struct ShardPlan {
    /// Gather-stage correlation key (unique per scattered query).
    id: u64,
    query: Query,
    n_shards: usize,
    embed: QueryEmbedCell,
}

/// One shard of a scattered top-k query, bound for one capable lane.
/// Shard 0 is the *embedder*: its lane computes the query embedding
/// once (cache-aware) and publishes it through the plan's cell; sibling
/// lanes wait on the cell instead of re-running the query's GCN.
///
/// The task carries its own gather sender and reports its outcome
/// exactly once: through [`ShardTask::report`] on the normal and typed
/// failure paths, or through the `Drop` backstop when a lane dies
/// *unwinding* (an engine panic, or a thread panicking on earlier work
/// with this task still queued — the channel then drops it
/// unprocessed). Either way the gather stage hears from every shard,
/// so a scattered query always resolves promptly.
struct ShardTask {
    plan: Arc<ShardPlan>,
    shard: CorpusShard,
    index: usize,
    /// Set by [`ShardTask::report`]; `Drop` reports abandonment only
    /// while this is still false.
    reported: Cell<bool>,
    gather: NamedSender<ShardOutcome>,
}

impl ShardTask {
    fn is_embedder(&self) -> bool {
        self.index == 0
    }

    /// Send this shard's outcome to the gather stage (and silence the
    /// `Drop` backstop).
    fn report(&self, result: Result<ShardDone, EngineError>, engine: Option<Arc<str>>) {
        self.reported.set(true);
        let _ = self.gather.send(ShardOutcome {
            plan: Arc::clone(&self.plan),
            index: self.index,
            result,
            engine,
        });
    }
}

impl Drop for ShardTask {
    /// Panic/abandonment backstop. The typed failure paths all poison
    /// the embed cell and report explicitly ([`fail_shard`]); this
    /// covers the unwinding paths, where the task is dropped without
    /// either. Poisoning the cell un-hangs sibling lanes blocked in
    /// [`QueryEmbedCell::wait`] (`set` is first-wins, so it is a no-op
    /// after any normal publish), and the abandonment report lets the
    /// gather stage resolve the query now rather than at shutdown.
    fn drop(&mut self) {
        let abandoned = || EngineError::Unavailable {
            reason: "shard abandoned: its lane died before scoring it".into(),
        };
        if self.is_embedder() {
            self.plan.embed.set(Err(abandoned()));
        }
        if !self.reported.get() {
            self.reported.set(true);
            let _ = self.gather.send(ShardOutcome {
                plan: Arc::clone(&self.plan),
                index: self.index,
                result: Err(abandoned()),
                engine: None,
            });
        }
    }
}

/// First-wins slot for a scattered query's embedding. The embedder lane
/// publishes `Ok` (or its typed failure — a poisoned cell fails sibling
/// shards fast instead of hanging them); siblings block on [`wait`].
///
/// Deadlock-freedom: the batcher scatters queries one at a time and
/// every channel is FIFO, so within any lane all of query *n*'s shard
/// work precedes query *n+1*'s. A lane blocked waiting on query *n*'s
/// cell therefore only ever waits on work that is strictly ahead of
/// query *n* elsewhere — the minimal in-flight query's embedder never
/// waits, so by induction some lane always makes progress. Every
/// failure path that consumes an embedder task must poison the cell
/// (see [`fail_shard`]).
struct QueryEmbedCell {
    state: Mutex<Option<Result<Arc<CachedEmbed>, EngineError>>>,
    ready: Condvar,
}

impl QueryEmbedCell {
    fn new() -> Self {
        QueryEmbedCell {
            state: Mutex::new(None),
            ready: Condvar::new(),
        }
    }

    /// Publish the embed outcome. First set wins; later calls are
    /// ignored (a late panic-path poison after a normal publish).
    fn set(&self, outcome: Result<Arc<CachedEmbed>, EngineError>) {
        let mut state = self.state.lock().expect("embed cell poisoned");
        if state.is_none() {
            *state = Some(outcome);
            self.ready.notify_all();
        }
    }

    /// Block until the embedder lane publishes, then return a copy.
    fn wait(&self) -> Result<Arc<CachedEmbed>, EngineError> {
        let mut state = self.state.lock().expect("embed cell poisoned");
        loop {
            if let Some(outcome) = state.as_ref() {
                return outcome.clone();
            }
            state = self.ready.wait(state).expect("embed cell poisoned");
        }
    }
}

/// One shard's completed (or failed) work, en route to the gather
/// stage, which resolves each scattered query exactly once.
struct ShardOutcome {
    plan: Arc<ShardPlan>,
    index: usize,
    result: Result<ShardDone, EngineError>,
    engine: Option<Arc<str>>,
}

/// The success half of a [`ShardOutcome`].
struct ShardDone {
    /// Epoch of the corpus snapshot the lane scored against — stamped
    /// from the payload's corpus (the one snapshot resolved at
    /// admission), and re-checked by `rank_sharded` at merge time so
    /// partials from two corpus generations can never blend.
    epoch: u64,
    shard: CorpusShard,
    /// One score per shard candidate, shard order.
    scores: Vec<f32>,
    telemetry: QueryTelemetry,
    queue_us: f64,
    encode_us: f64,
    execute_us: f64,
}

/// An encoded chunk in flight between an encoder and its executor.
struct EncodedChunk {
    queries: Vec<Query>,
    packed: PackedBatch,
    /// Submit -> encode-start wait per query, µs.
    queue_us: Vec<f64>,
    /// Encode+pack time for the whole chunk, µs.
    encode_us: f64,
}

/// An encoded one-vs-many query in flight to an executor. The corpus
/// rides inside the query's payload (an `Arc` — nothing is copied).
struct TopKJob {
    query: Query,
    /// The encoded query graph (corpus graphs are pre-encoded).
    encoded: EncodedGraph,
    /// Submit -> encode-start wait, µs.
    queue_us: f64,
    /// Encode time for the query graph, µs.
    encode_us: f64,
}

/// One shard of a scattered top-k query in flight to an executor. Only
/// the embedder shard carries the encoded query graph — sibling lanes
/// receive the finished embedding through the plan's cell and never
/// touch the query graph at all.
struct ShardJob {
    task: ShardTask,
    /// The encoded query graph (embedder shard only).
    encoded: Option<EncodedGraph>,
    /// Submit -> encode-start wait, µs.
    queue_us: f64,
    /// Encode time for the query graph (embedder shard only), µs.
    encode_us: f64,
}

/// Unit of work an encoder hands its executor: a packed pair chunk, a
/// whole top-k corpus query, or one shard of a scattered one.
enum Work {
    Chunk(EncodedChunk),
    TopK(TopKJob),
    TopKShard(ShardJob),
}

/// Pipeline shape knobs. `ServeConfig` derives one of these; tests build
/// them directly. The lane count is the length of the factory vector
/// handed to [`Pipeline::start`].
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Batch release policy (size-or-deadline).
    pub policy: BatchPolicy,
    /// Encoded-chunk buffer per lane. >= 1 runs encode and execute as
    /// separate overlapped stages (2 = classic double-buffering);
    /// 0 fuses them into one sequential stage (no-overlap baseline).
    pub depth: usize,
    /// Admission + ingest channel capacity (submit backpressure bound).
    pub admit_cap: usize,
    /// Released-batch channel capacity per lane.
    pub batch_cap: usize,
    /// Results channel capacity.
    pub results_cap: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            policy: BatchPolicy::default(),
            depth: 2,
            admit_cap: 256,
            batch_cap: 8,
            results_cap: 1024,
        }
    }
}

/// Observer the responder stage invokes on every [`QueryResult`] just
/// before recording it into [`Metrics`]. This is how out-of-process
/// front doors (the net subsystem) hear about their queries' outcomes
/// without a second results channel: the tap runs on the responder
/// thread, so it must never block (route-and-send to a buffered
/// per-request slot, not synchronous work).
pub type ResultTap = Arc<dyn Fn(&QueryResult) + Send + Sync>;

/// A running pipeline. `submit` queries, then `finish` to shut down and
/// collect metrics. Dropping without `finish` detaches the stage threads
/// (they drain and exit on their own).
#[derive(Debug)]
pub struct Pipeline {
    submit_tx: NamedSender<Query>,
    stages: Vec<JoinHandle<()>>,
    responder: JoinHandle<Metrics>,
    lane_caps: Vec<Arc<LaneCaps>>,
}

/// A clonable submit handle for multi-producer ingest (the net front
/// door's admission stage). Shares the admission channel — and its
/// blocking backpressure — with [`Pipeline::submit`].
///
/// Shutdown contract: [`Pipeline::finish`] only starts the stage drop
/// cascade once every outstanding `SubmitHandle` has been dropped, so
/// holders must be stopped (and their handles dropped) *before* calling
/// `finish`, or `finish` will block indefinitely.
#[derive(Debug)]
pub struct SubmitHandle {
    tx: NamedSender<Query>,
}

impl Clone for SubmitHandle {
    fn clone(&self) -> Self {
        SubmitHandle { tx: self.tx.clone() }
    }
}

impl SubmitHandle {
    /// Submit one query. Blocks when admission is saturated
    /// (backpressure). Returns false if the pipeline has shut down.
    pub fn submit(&self, q: Query) -> bool {
        self.tx.send(q).is_sent()
    }
}

impl Pipeline {
    /// Spawn every stage, one worker lane per factory in `factories`
    /// (lanes may construct different engine kinds). Engines are built
    /// inside the executor threads (PJRT handles are not `Send`); a
    /// construction failure downgrades that lane to an error-reporting
    /// drain and the caps-aware router steers traffic to the surviving
    /// lanes.
    pub fn start(
        model: ModelConfig,
        factories: Vec<EngineFactory>,
        cfg: PipelineConfig,
    ) -> Pipeline {
        Self::start_with_tap(model, factories, cfg, None)
    }

    /// [`Pipeline::start`] with an optional [`ResultTap`] the responder
    /// invokes on every result before recording it (net front door).
    pub fn start_with_tap(
        model: ModelConfig,
        factories: Vec<EngineFactory>,
        cfg: PipelineConfig,
        tap: Option<ResultTap>,
    ) -> Pipeline {
        assert!(!factories.is_empty(), "pipeline needs at least one engine lane");
        let (admit_tx, admit_rx) = channel("admit", cfg.admit_cap, SendPolicy::Block);
        let (ingest_tx, ingest_rx) = channel("ingest", cfg.admit_cap, SendPolicy::Block);
        let (results_tx, results_rx) = channel("results", cfg.results_cap, SendPolicy::Block);
        // Shard partials from every lane converge here; the gather
        // stage merges them back into one result per scattered query.
        let (gather_tx, gather_rx) = channel("gather", cfg.results_cap, SendPolicy::Block);

        let mut stats: Vec<Arc<ChannelStats>> =
            vec![admit_tx.stats(), ingest_tx.stats(), gather_tx.stats()];
        let mut stages = Vec::new();

        // Stage 1: admission (validation + reject short-circuit).
        {
            let adm = Admission::new(model.clone());
            let results = results_tx.clone();
            stages.push(spawn("admission", move || {
                admission_stage(adm, admit_rx, ingest_tx, results)
            }));
        }

        // Stage 5: gather (merge scattered top-k shard partials).
        {
            let results = results_tx.clone();
            stages.push(spawn("gather", move || gather_stage(gather_rx, results)));
        }

        // Stages 3+4 per lane: encoder -> executor (or fused when depth=0).
        let mut lanes = Vec::new();
        let mut lane_caps = Vec::new();
        for (w, lane_factory) in factories.into_iter().enumerate() {
            let (batch_tx, batch_rx) =
                channel(&format!("batch.{w}"), cfg.batch_cap, SendPolicy::Block);
            stats.push(batch_tx.stats());
            let caps_cell = LaneCaps::new();
            lanes.push((batch_tx, Arc::clone(&caps_cell)));
            lane_caps.push(Arc::clone(&caps_cell));
            let results = results_tx.clone();
            let (n_max, num_labels) = (model.n_max, model.num_labels);
            if cfg.depth == 0 {
                stages.push(spawn(&format!("encode+execute.{w}"), move || {
                    fused_stage(lane_factory, batch_rx, results, caps_cell, n_max, num_labels)
                }));
            } else {
                let (exec_tx, exec_rx) =
                    channel(&format!("exec.{w}"), cfg.depth, SendPolicy::Block);
                stats.push(exec_tx.stats());
                let enc_results = results_tx.clone();
                let enc_caps = Arc::clone(&caps_cell);
                stages.push(spawn(&format!("encode.{w}"), move || {
                    encoder_stage(batch_rx, exec_tx, enc_results, enc_caps, n_max, num_labels)
                }));
                stages.push(spawn(&format!("execute.{w}"), move || {
                    executor_stage(lane_factory, exec_rx, results, caps_cell)
                }));
            }
        }

        // Stage 2: batcher (size-or-deadline, caps-aware fan-out +
        // top-k scatter across corpus-capable lanes). Only the batcher
        // holds a gather sender: each ShardTask carries its own clone,
        // so the gather stage exits once the batcher is gone and every
        // in-flight shard task has dropped.
        {
            let batcher = Batcher::new(cfg.policy);
            let fan_out = CapsRouter::new(lanes);
            let results = results_tx.clone();
            stages.push(spawn("batcher", move || {
                batcher_stage(batcher, ingest_rx, fan_out, results, gather_tx)
            }));
        }

        stats.push(results_tx.stats());
        // The pipeline keeps no results sender: once every stage drops
        // its clones the drop cascade reaches the responder.
        drop(results_tx);
        let responder = spawn("responder", move || responder_stage(results_rx, stats, tap));

        Pipeline {
            submit_tx: admit_tx,
            stages,
            responder,
            lane_caps,
        }
    }

    /// Submit one query. Blocks when admission is saturated
    /// (backpressure). Returns false if the pipeline has shut down.
    pub fn submit(&self, q: Query) -> bool {
        self.submit_tx.send(q).is_sent()
    }

    /// A clonable submit handle for producers that outlive this
    /// reference (the net admission stage). See [`SubmitHandle`] for
    /// the shutdown contract.
    pub fn submit_handle(&self) -> SubmitHandle {
        SubmitHandle {
            tx: self.submit_tx.clone(),
        }
    }

    /// Block until every lane's caps handshake has published (engine
    /// built, or typed construction failure); returns the number of
    /// lanes with a working engine. Capability-dependent routing — the
    /// top-k scatter in particular — is only deterministic once the
    /// handshakes have landed, so tests and benches that assert on
    /// shard counts call this before submitting.
    pub fn wait_ready(&self) -> usize {
        self.lane_caps.iter().filter(|c| c.wait().is_ok()).count()
    }

    /// Ordered shutdown: drop the submit sender (starting the cascade),
    /// join every stage front-to-back, and collect the final metrics
    /// (channel-depth snapshots + per-lane engine names) from the
    /// responder.
    pub fn finish(self) -> Metrics {
        let Pipeline {
            submit_tx,
            stages,
            responder,
            lane_caps,
        } = self;
        drop(submit_tx);
        for h in stages {
            let _ = h.join();
        }
        let mut metrics = responder.join().expect("responder stage panicked");
        metrics.lanes = lane_caps
            .iter()
            .enumerate()
            .map(|(w, caps)| LaneInfo {
                lane: format!("lane.{w}"),
                engine: match caps.get() {
                    Some(Ok(caps)) => caps.name,
                    Some(Err(err)) => format!("unavailable ({err})"),
                    None => "never constructed".into(),
                },
            })
            .collect();
        metrics
    }
}

fn spawn<T, F>(name: &str, f: F) -> JoinHandle<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    thread::Builder::new()
        .name(format!("spa-{name}"))
        .spawn(f)
        .expect("spawning pipeline stage")
}

fn admission_stage(
    adm: Admission,
    rx: NamedReceiver<Query>,
    out: NamedSender<Query>,
    results: NamedSender<QueryResult>,
) {
    while let Ok(q) = rx.recv() {
        match adm.admit(q) {
            Ok(q) => {
                if let SendResult::Disconnected(q) = out.send(q) {
                    let _ = results.send(QueryResult::rejected(&q, RejectReason::ShuttingDown));
                }
            }
            Err(reject) => {
                let _ = results.send(reject);
            }
        }
    }
}

fn batcher_stage(
    mut batcher: Batcher,
    rx: NamedReceiver<Query>,
    mut fan_out: CapsRouter<LaneTask>,
    results: NamedSender<QueryResult>,
    gather: NamedSender<ShardOutcome>,
) {
    // Scattered-query correlation ids for the gather stage; unique per
    // pipeline because only this thread scatters.
    let mut next_plan_id = 0u64;
    loop {
        let wait = batcher
            .time_to_deadline(Instant::now())
            .unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(wait) {
            Ok(q) => {
                // Greedily absorb whatever else is already queued: fewer
                // per-query wakeups, and bursts release full batches at
                // once (push_all leaves any remainder on a fresh
                // deadline).
                let mut burst = vec![q];
                while burst.len() < 4 * batcher.max_batch() {
                    match rx.try_recv() {
                        Ok(more) => burst.push(more),
                        Err(_) => break,
                    }
                }
                for batch in batcher.push_all(burst, Instant::now()) {
                    dispatch(&mut fan_out, batch, &results, &gather, &mut next_plan_id);
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if let Some(batch) = batcher.poll(Instant::now()) {
                    dispatch(&mut fan_out, batch, &results, &gather, &mut next_plan_id);
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                let now = Instant::now();
                while let Some(batch) = batcher.flush(now) {
                    dispatch(&mut fan_out, batch, &results, &gather, &mut next_plan_id);
                }
                break;
            }
        }
    }
}

/// The scatter eligibility predicate: a lane can take one shard of a
/// scattered corpus query only if its engine implements the
/// embed-once/score-shard pair, not just whole-corpus scoring.
fn shard_capable(caps: &EngineCaps) -> bool {
    caps.supports_corpus && caps.supports_corpus_shards
}

fn dispatch(
    fan_out: &mut CapsRouter<LaneTask>,
    queries: Vec<Query>,
    results: &NamedSender<QueryResult>,
    gather: &NamedSender<ShardOutcome>,
    next_plan_id: &mut u64,
) {
    // Top-k queries are steered to lanes whose published caps support
    // corpus scoring (a mixed `native,xla` deployment must not
    // round-robin them onto engines that can only answer with a typed
    // Unavailable) — and scattered across every shard-capable lane when
    // more than one has published; pair queries take any healthy lane.
    let (pairs, topk) = split_batch(queries);
    if !pairs.is_empty() {
        let sent = fan_out.send(LaneTask::Batch(Batch { queries: pairs }));
        if let SendResult::Disconnected(LaneTask::Batch(batch)) = sent {
            for q in batch.queries {
                let _ = results.send(QueryResult::rejected(&q, RejectReason::ShuttingDown));
            }
        }
    }
    for q in topk {
        dispatch_topk(fan_out, q, results, gather, next_plan_id);
    }
}

/// Scatter one top-k query across the shard-capable lanes, or fall back
/// to the whole-query path when only one capable lane survives (or the
/// corpus is too small to split, or the capability handshakes have not
/// landed yet).
fn dispatch_topk(
    fan_out: &mut CapsRouter<LaneTask>,
    q: Query,
    results: &NamedSender<QueryResult>,
    gather: &NamedSender<ShardOutcome>,
    next_plan_id: &mut u64,
) {
    let QueryPayload::TopK { corpus, .. } = &q.payload else {
        unreachable!("split_batch only routes top-k payloads here");
    };
    // Shards must land on lanes of ONE engine kind: per-shard telemetry
    // is policy-specific (executed-work vs padded-schedule MacCounts,
    // cycle reports), so a scatter spanning `native` and `native-dense`
    // would blend the very rows the metrics keep apart. Size the
    // scatter by the largest same-name capable pool.
    let cohort = fan_out.largest_cohort(shard_capable);
    let n_shards = cohort.as_ref().map_or(0, |(_, n)| *n).min(corpus.len());
    if n_shards < 2 {
        let sent =
            fan_out.send_filtered(LaneTask::Batch(Batch { queries: vec![q] }), |caps| {
                caps.supports_corpus
            });
        if let SendResult::Disconnected(LaneTask::Batch(batch)) = sent {
            for q in batch.queries {
                let _ = results.send(QueryResult::rejected(&q, RejectReason::ShuttingDown));
            }
        }
        return;
    }
    let (cohort_name, _) = cohort.expect("n_shards >= 2 implies a cohort");
    let cohort_pred = |caps: &EngineCaps| shard_capable(caps) && caps.name == cohort_name;
    let shards = corpus.shards(n_shards);
    *next_plan_id += 1;
    let plan = Arc::new(ShardPlan {
        id: *next_plan_id,
        query: q,
        n_shards,
        embed: QueryEmbedCell::new(),
    });
    // Index order matters: the embedder (shard 0) is dispatched first,
    // so even if the rotation ever hands two shards of one query to the
    // same lane, the embed is published before any sibling waits on it.
    for (index, shard) in shards.into_iter().enumerate() {
        let task = ShardTask {
            plan: Arc::clone(&plan),
            shard,
            index,
            reported: Cell::new(false),
            gather: gather.clone(),
        };
        let sent = fan_out.send_filtered(LaneTask::Shard(task), cohort_pred);
        if let SendResult::Disconnected(t) = sent {
            let LaneTask::Shard(task) = t else {
                unreachable!("a shard send hands back a shard");
            };
            fail_shard(
                task,
                EngineError::Unavailable {
                    reason: "lane channels closed mid-scatter".into(),
                },
                None,
            );
        }
    }
}

/// Answer one shard's failure: poison the embed cell when the failing
/// shard is the embedder (so sibling lanes fail fast instead of waiting
/// forever) and report to the gather stage, which resolves the query
/// with one typed error — never a hang, never a lost query.
fn fail_shard(task: ShardTask, err: EngineError, engine: Option<Arc<str>>) {
    if task.is_embedder() {
        task.plan.embed.set(Err(err.clone()));
    }
    task.report(Err(err), engine);
}

fn encoder_stage(
    rx: NamedReceiver<LaneTask>,
    out: NamedSender<Work>,
    results: NamedSender<QueryResult>,
    lane_caps: Arc<LaneCaps>,
    n_max: usize,
    num_labels: usize,
) {
    // Learn the lane's batch ladder from the executor's caps handshake.
    let caps = match lane_caps.wait() {
        Ok(caps) => caps,
        Err(err) => return drain_failed(rx, &results, err),
    };
    while let Ok(task) = rx.recv() {
        match task {
            LaneTask::Batch(batch) => {
                let (pairs, topk) = split_batch(batch.queries);
                for q in topk {
                    if let Some(job) = encode_topk(q, n_max, num_labels, &results) {
                        send_work(&out, Work::TopK(job), &results);
                    }
                }
                for chunk in make_chunks(pairs, &caps) {
                    if let Some(encoded) = encode_chunk(chunk, &caps, n_max, num_labels, &results)
                    {
                        send_work(&out, Work::Chunk(encoded), &results);
                    }
                }
            }
            LaneTask::Shard(task) => {
                if let Some(job) = encode_shard(task, n_max, num_labels) {
                    send_work(&out, Work::TopKShard(job), &results);
                }
            }
        }
    }
}

/// Hand one encoded work unit to the executor; a dead executor answers
/// every affected query with a typed error instead of dropping it (a
/// dead shard additionally poisons its plan's embed cell via
/// [`fail_shard`] so sibling lanes never hang).
fn send_work(out: &NamedSender<Work>, work: Work, results: &NamedSender<QueryResult>) {
    if let SendResult::Disconnected(work) = out.send(work) {
        let err = EngineError::Unavailable {
            reason: "executor stage gone".into(),
        };
        match work {
            Work::Chunk(chunk) => {
                for q in chunk.queries {
                    let _ = results.send(QueryResult::engine_error(&q, err.clone(), 0));
                }
            }
            Work::TopK(job) => {
                let _ = results.send(QueryResult::engine_error(&job.query, err, 0));
            }
            Work::TopKShard(job) => fail_shard(job.task, err, None),
        }
    }
}

/// Prepare one shard task for its executor. Only the embedder shard
/// encodes the query graph (siblings receive the embedding through the
/// plan's cell); an encode failure fails the shard through the gather
/// stage instead of losing the query.
fn encode_shard(task: ShardTask, n_max: usize, num_labels: usize) -> Option<ShardJob> {
    let t0 = Instant::now();
    let queue_us = t0.saturating_duration_since(task.plan.query.submitted).as_secs_f64() * 1e6;
    if !task.is_embedder() {
        return Some(ShardJob {
            task,
            encoded: None,
            queue_us,
            encode_us: 0.0,
        });
    }
    let QueryPayload::TopK { graph, .. } = &task.plan.query.payload else {
        // dispatch_topk precludes this; a wiring bug upstream must
        // still resolve the query, never lose it silently.
        let err = EngineError::InvalidInput {
            detail: "pair payload reached the shard encoder".into(),
        };
        fail_shard(task, err, None);
        return None;
    };
    match encode(graph, n_max, num_labels) {
        Ok(encoded) => Some(ShardJob {
            encode_us: t0.elapsed().as_secs_f64() * 1e6,
            encoded: Some(encoded),
            queue_us,
            task,
        }),
        Err(e) => {
            let err = EngineError::InvalidInput {
                detail: format!("encode: {e}"),
            };
            fail_shard(task, err, None);
            None
        }
    }
}

/// Partition a released batch by payload kind, preserving order within
/// each kind (pair queries chunk and pack; top-k queries execute one at
/// a time — each already fans out over a whole corpus).
fn split_batch(queries: Vec<Query>) -> (Vec<Query>, Vec<Query>) {
    queries
        .into_iter()
        .partition(|q| matches!(q.payload, QueryPayload::Pair { .. }))
}

/// Publishes a "thread died" caps outcome if the executor unwinds before
/// its normal handshake (LaneCaps ignores the second set otherwise).
struct CapsPanicGuard(Arc<LaneCaps>);

impl Drop for CapsPanicGuard {
    fn drop(&mut self) {
        self.0.set(Err(EngineError::Unavailable {
            reason: "engine thread died before reporting caps".into(),
        }));
    }
}

fn executor_stage(
    factory: EngineFactory,
    rx: NamedReceiver<Work>,
    results: NamedSender<QueryResult>,
    lane_caps: Arc<LaneCaps>,
) {
    let guard = CapsPanicGuard(Arc::clone(&lane_caps));
    let mut engine = match factory() {
        Ok(engine) => {
            lane_caps.set(Ok(engine.caps().clone()));
            engine
        }
        Err(err) => {
            // Report instead of panicking: the encoder downgrades the
            // lane to per-query EngineError results and the router
            // steers new traffic to surviving lanes.
            lane_caps.set(Err(err));
            return;
        }
    };
    drop(guard);
    let tag: Arc<str> = Arc::from(engine.caps().name.as_str());
    while let Ok(work) = rx.recv() {
        match work {
            Work::Chunk(chunk) => execute_chunk(engine.as_mut(), &tag, chunk, &results),
            Work::TopK(job) => execute_topk(engine.as_mut(), &tag, job, &results),
            Work::TopKShard(job) => execute_shard(engine.as_mut(), &tag, job),
        }
    }
}

/// Fused encode+execute lane (`depth == 0`): the sequential baseline —
/// identical per-query work, no overlap between the two stages.
fn fused_stage(
    factory: EngineFactory,
    rx: NamedReceiver<LaneTask>,
    results: NamedSender<QueryResult>,
    lane_caps: Arc<LaneCaps>,
    n_max: usize,
    num_labels: usize,
) {
    let guard = CapsPanicGuard(Arc::clone(&lane_caps));
    let mut engine = match factory() {
        Ok(engine) => {
            lane_caps.set(Ok(engine.caps().clone()));
            engine
        }
        Err(err) => {
            lane_caps.set(Err(err.clone()));
            drop(guard);
            return drain_failed(rx, &results, err);
        }
    };
    drop(guard);
    let caps = engine.caps().clone();
    let tag: Arc<str> = Arc::from(caps.name.as_str());
    while let Ok(task) = rx.recv() {
        match task {
            LaneTask::Batch(batch) => {
                let (pairs, topk) = split_batch(batch.queries);
                for q in topk {
                    if let Some(job) = encode_topk(q, n_max, num_labels, &results) {
                        execute_topk(engine.as_mut(), &tag, job, &results);
                    }
                }
                for chunk in make_chunks(pairs, &caps) {
                    if let Some(encoded) = encode_chunk(chunk, &caps, n_max, num_labels, &results)
                    {
                        execute_chunk(engine.as_mut(), &tag, encoded, &results);
                    }
                }
            }
            LaneTask::Shard(task) => {
                if let Some(job) = encode_shard(task, n_max, num_labels) {
                    execute_shard(engine.as_mut(), &tag, job);
                }
            }
        }
    }
}

fn responder_stage(
    rx: NamedReceiver<QueryResult>,
    stats: Vec<Arc<ChannelStats>>,
    tap: Option<ResultTap>,
) -> Metrics {
    let mut metrics = Metrics::new();
    while let Ok(r) = rx.recv() {
        if let Some(tap) = &tap {
            tap(&r);
        }
        metrics.record(&r);
    }
    metrics.channels = stats.iter().map(|s| s.snapshot()).collect();
    metrics
}

/// Answer every remaining query on a dead lane with its typed error;
/// shard tasks are failed through the gather stage (poisoning the embed
/// cell where needed) so scattered queries resolve instead of hanging.
fn drain_failed(rx: NamedReceiver<LaneTask>, results: &NamedSender<QueryResult>, err: EngineError) {
    while let Ok(task) = rx.recv() {
        match task {
            LaneTask::Batch(batch) => {
                for q in batch.queries {
                    let _ = results.send(QueryResult::engine_error(&q, err.clone(), 0));
                }
            }
            LaneTask::Shard(task) => fail_shard(task, err.clone(), None),
        }
    }
}

/// Split a released batch into engine-sized chunks (a batch larger than
/// the biggest supported artifact executes as several launches).
fn make_chunks(queries: Vec<Query>, caps: &EngineCaps) -> Vec<Vec<Query>> {
    let cap = caps.pick_batch_size(queries.len()).max(1);
    let mut chunks = Vec::with_capacity(queries.len().div_ceil(cap));
    let mut current = Vec::with_capacity(cap.min(queries.len()));
    for q in queries {
        current.push(q);
        if current.len() == cap {
            chunks.push(std::mem::take(&mut current));
        }
    }
    if !current.is_empty() {
        chunks.push(current);
    }
    chunks
}

/// Encode + pack one chunk. Queries that fail to encode (can only happen
/// if admission and the artifact shapes disagree) are answered with an
/// EngineError instead of poisoning the chunk.
fn encode_chunk(
    queries: Vec<Query>,
    caps: &EngineCaps,
    n_max: usize,
    num_labels: usize,
    results: &NamedSender<QueryResult>,
) -> Option<EncodedChunk> {
    let t0 = Instant::now();
    let mut ok_queries = Vec::with_capacity(queries.len());
    let mut pairs = Vec::with_capacity(queries.len());
    let mut queue_us = Vec::with_capacity(queries.len());
    for q in queries {
        let QueryPayload::Pair { g1, g2 } = &q.payload else {
            // split_batch routes top-k payloads elsewhere; a stray one
            // is a wiring bug upstream — answer it, don't drop it.
            let err = EngineError::InvalidInput {
                detail: "top-k payload reached the pair encoder".into(),
            };
            let _ = results.send(QueryResult::engine_error(&q, err, 0));
            continue;
        };
        match (encode(g1, n_max, num_labels), encode(g2, n_max, num_labels)) {
            (Ok(e1), Ok(e2)) => {
                queue_us.push(t0.saturating_duration_since(q.submitted).as_secs_f64() * 1e6);
                pairs.push((e1, e2));
                ok_queries.push(q);
            }
            (Err(e), _) | (_, Err(e)) => {
                let err = EngineError::InvalidInput {
                    detail: format!("encode: {e}"),
                };
                let _ = results.send(QueryResult::engine_error(&q, err, 0));
            }
        }
    }
    if ok_queries.is_empty() {
        return None;
    }
    let eff = caps.pick_batch_size(ok_queries.len());
    // pack() is typed-fallible (empty chunk / ladder overflow). Neither
    // can happen here — ok_queries is non-empty and chunks fit the
    // ladder — but a bug upstream must answer queries with an error, not
    // take the lane down.
    let packed = match PackedBatch::pack(&pairs, eff) {
        Ok(packed) => packed,
        Err(e) => {
            let err = EngineError::InvalidInput {
                detail: format!("pack: {e}"),
            };
            for q in ok_queries {
                let _ = results.send(QueryResult::engine_error(&q, err.clone(), 0));
            }
            return None;
        }
    };
    Some(EncodedChunk {
        queries: ok_queries,
        packed,
        queue_us,
        encode_us: t0.elapsed().as_secs_f64() * 1e6,
    })
}

/// Encode one top-k query's graph (its corpus is pre-encoded). Encode
/// failures answer the query with a typed error instead of losing it.
fn encode_topk(
    q: Query,
    n_max: usize,
    num_labels: usize,
    results: &NamedSender<QueryResult>,
) -> Option<TopKJob> {
    let t0 = Instant::now();
    let encoded = match &q.payload {
        QueryPayload::TopK { graph, .. } => encode(graph, n_max, num_labels),
        QueryPayload::Pair { .. } => {
            // split_batch precludes this; a wiring bug upstream must
            // still answer the query, never lose it silently (mirror of
            // encode_chunk's stray-TopK handling).
            let err = EngineError::InvalidInput {
                detail: "pair payload reached the top-k encoder".into(),
            };
            let _ = results.send(QueryResult::engine_error(&q, err, 0));
            return None;
        }
    };
    match encoded {
        Ok(encoded) => Some(TopKJob {
            queue_us: t0.saturating_duration_since(q.submitted).as_secs_f64() * 1e6,
            encode_us: t0.elapsed().as_secs_f64() * 1e6,
            encoded,
            query: q,
        }),
        Err(e) => {
            let err = EngineError::InvalidInput {
                detail: format!("encode: {e}"),
            };
            let _ = results.send(QueryResult::engine_error(&q, err, 0));
            None
        }
    }
}

/// The pruned-slot sentinel. Real similarities are sigmoid outputs
/// (finite, non-negative), so a pruned candidate filled with `-inf`
/// orders strictly after every scored one at the single rank site and
/// is stripped by [`strip_pruned`] before the result leaves the
/// pipeline.
const PRUNED_SCORE: f32 = f32::NEG_INFINITY;

/// Score one corpus-index window of a top-k query against a
/// precomputed query embedding. Without a prune plan this is a single
/// `score_corpus_with` call over the window; with one, each contiguous
/// survivor run is scored separately — pruned candidates never reach
/// the engine, which is the whole point of the cascade — and their
/// slots are filled with [`PRUNED_SCORE`]. Returns one score per
/// window candidate plus the serially-merged telemetry of the runs.
fn score_window(
    engine: &mut dyn Engine,
    tag: &Arc<str>,
    query_hg: &[f32],
    corpus: &Corpus,
    window: CorpusShard,
    prune: Option<&PrunePlan>,
) -> Result<(Vec<f32>, QueryTelemetry), EngineError> {
    let run_scores = |engine: &mut dyn Engine, run: CorpusShard| {
        let out = engine.score_corpus_with(query_hg, corpus.shard_graphs(run))?;
        if out.scores.len() != run.len() {
            // A misbehaving engine yields a typed error, not a gather
            // coverage panic or a mis-shaped rank input.
            return Err(EngineError::Backend {
                engine: tag.to_string(),
                detail: format!(
                    "score_corpus_with returned {} scores for {} candidates",
                    out.scores.len(),
                    run.len()
                ),
            });
        }
        Ok(out)
    };
    let Some(plan) = prune else {
        let out = run_scores(engine, window)?;
        return Ok((out.scores, out.telemetry));
    };
    let mut scores = vec![PRUNED_SCORE; window.len()];
    let mut telemetry = QueryTelemetry::default();
    let mut i = window.start;
    while i < window.end {
        if !plan.keep[i] {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        while j < window.end && plan.keep[j] {
            j += 1;
        }
        let run = CorpusShard { start: i, end: j };
        let out = run_scores(engine, run)?;
        scores[i - window.start..j - window.start].copy_from_slice(&out.scores);
        telemetry.merge_serial(&out.telemetry);
        i = j;
    }
    Ok((scores, telemetry))
}

/// Drop the pruned-slot sentinels from a ranking before it leaves the
/// pipeline: a budgeted query answers with at most `survivors` entries,
/// never with a candidate the cascade ruled out.
fn strip_pruned(ranked: &mut Vec<(u64, f32)>, prune: Option<&Arc<PrunePlan>>) {
    if prune.is_some() {
        ranked.retain(|&(_, s)| s != PRUNED_SCORE);
    }
}

/// Run one top-k query: the engine embeds the query once (cache-aware)
/// and fans the NTN+FCN tail over the corpus; the ranking is assembled
/// here, where the corpus ids live. Engines without corpus support
/// answer with their typed error. Budgeted queries score survivor runs
/// only (via [`score_window`]) on shard-capable engines; an engine
/// with whole-corpus support but no shard API scores everything and
/// masks afterwards — correct, just without the cascade's savings.
fn execute_topk(
    engine: &mut dyn Engine,
    tag: &Arc<str>,
    job: TopKJob,
    results: &NamedSender<QueryResult>,
) {
    let QueryPayload::TopK {
        corpus, k, prune, ..
    } = &job.query.payload
    else {
        unreachable!("encode_topk only forwards top-k payloads");
    };
    let t0 = Instant::now();
    let whole = CorpusShard {
        start: 0,
        end: corpus.len(),
    };
    let scored: Result<(Vec<f32>, QueryTelemetry), EngineError> = match prune {
        Some(plan) if engine.caps().supports_corpus_shards => {
            engine.embed_query(&job.encoded).and_then(|q| {
                let (scores, mut telemetry) =
                    score_window(engine, tag, &q.embed.hg, corpus, whole, Some(plan))?;
                let mut merged = q.telemetry;
                merged.merge_serial(&telemetry);
                telemetry = merged;
                Ok((scores, telemetry))
            })
        }
        _ => engine.score_corpus(&job.encoded, corpus.graphs()).and_then(|out| {
            if out.scores.len() != corpus.len() {
                // A misbehaving engine must yield a typed error, not
                // panic the lane via rank()'s one-score-per-candidate
                // contract.
                return Err(EngineError::Backend {
                    engine: tag.to_string(),
                    detail: format!(
                        "score_corpus returned {} scores for {} candidates",
                        out.scores.len(),
                        corpus.len()
                    ),
                });
            }
            let mut scores = out.scores;
            if let Some(plan) = prune {
                // No shard API: everything was scored; mask the pruned
                // slots so the contract (only survivors are ranked)
                // still holds.
                for (s, &keep) in scores.iter_mut().zip(&plan.keep) {
                    if !keep {
                        *s = PRUNED_SCORE;
                    }
                }
            }
            Ok((scores, out.telemetry))
        }),
    };
    match scored {
        Ok((scores, telemetry)) => {
            let mut ranked = corpus.rank(&scores, *k);
            strip_pruned(&mut ranked, prune.as_ref());
            let mut result = QueryResult {
                id: job.query.id,
                outcome: Outcome::TopK(ranked),
                latency_us: job.query.submitted.elapsed().as_secs_f64() * 1e6,
                // One query through the engine, however wide the fan-out.
                batch_size: 1,
                stage: StageTiming {
                    queue_us: job.queue_us,
                    encode_us: job.encode_us,
                    execute_us: t0.elapsed().as_secs_f64() * 1e6,
                },
                telemetry,
                engine: Some(Arc::clone(tag)),
                // The whole-query path: one shard, nothing to spread.
                sharding: Some(ShardingInfo {
                    shards: 1,
                    spread_us: 0.0,
                }),
                cascade: None,
            };
            if let Some(plan) = prune {
                result = result.with_cascade(CascadeInfo {
                    pruned: plan.pruned,
                    survivors: plan.survivors,
                    prune_us: plan.prune_us,
                });
            }
            let _ = results.send(result);
        }
        Err(err) => {
            let _ = results.send(
                QueryResult::engine_error(&job.query, err, 1).with_engine(Arc::clone(tag)),
            );
        }
    }
}

/// Run one shard of a scattered top-k query. The embedder shard embeds
/// the query once (cache-aware) and publishes the embedding through the
/// plan's cell; sibling shards receive it there and pay only the
/// NTN+FCN fan-out over their corpus slice. Partials converge on the
/// gather stage.
fn execute_shard(engine: &mut dyn Engine, tag: &Arc<str>, job: ShardJob) {
    let ShardJob {
        task,
        encoded,
        queue_us,
        encode_us,
    } = job;
    let QueryPayload::TopK { corpus, prune, .. } = &task.plan.query.payload else {
        unreachable!("shard tasks only carry top-k payloads");
    };
    let corpus = Arc::clone(corpus);
    let prune = prune.clone();
    let t0 = Instant::now();
    let (embed, mut telemetry) = if task.is_embedder() {
        let encoded = encoded.expect("the embedder shard carries the encoded query");
        match engine.embed_query(&encoded) {
            Ok(q) => {
                // Publish before scoring: sibling lanes start their
                // fan-out while this lane scores its own shard.
                task.plan.embed.set(Ok(Arc::clone(&q.embed)));
                (q.embed, q.telemetry)
            }
            // fail_shard poisons the cell, unblocking the siblings.
            Err(err) => return fail_shard(task, err, Some(Arc::clone(tag))),
        }
    } else {
        match task.plan.embed.wait() {
            Ok(embed) => (embed, QueryTelemetry::default()),
            Err(err) => return fail_shard(task, err, Some(Arc::clone(tag))),
        }
    };
    match score_window(
        engine,
        tag,
        &embed.hg,
        &corpus,
        task.shard,
        prune.as_deref(),
    ) {
        Ok((scores, shard_telemetry)) => {
            telemetry.merge_serial(&shard_telemetry);
            task.report(
                Ok(ShardDone {
                    epoch: corpus.epoch(),
                    shard: task.shard,
                    scores,
                    telemetry,
                    queue_us,
                    encode_us,
                    execute_us: t0.elapsed().as_secs_f64() * 1e6,
                }),
                Some(Arc::clone(tag)),
            );
        }
        Err(err) => fail_shard(task, err, Some(Arc::clone(tag))),
    }
}

/// One scattered query's partials accumulating in the gather stage.
struct GatherEntry {
    plan: Arc<ShardPlan>,
    parts: Vec<Option<ShardDone>>,
    engines: Vec<Option<Arc<str>>>,
    received: usize,
    resolved: bool,
}

/// The gather stage: collect per-shard partials and resolve each
/// scattered query exactly once — a merged ranking when every shard
/// reports scores, one typed error as soon as any shard fails (later
/// partials for a failed query are absorbed and dropped). On shutdown
/// any still-open query is answered with a typed error rather than
/// lost: the drop cascade reaches this stage only after every shard
/// producer has exited.
fn gather_stage(rx: NamedReceiver<ShardOutcome>, results: NamedSender<QueryResult>) {
    let mut open: HashMap<u64, GatherEntry> = HashMap::new();
    while let Ok(outcome) = rx.recv() {
        let n_shards = outcome.plan.n_shards;
        let entry = open.entry(outcome.plan.id).or_insert_with(|| GatherEntry {
            plan: Arc::clone(&outcome.plan),
            parts: (0..n_shards).map(|_| None).collect(),
            engines: vec![None; n_shards],
            received: 0,
            resolved: false,
        });
        entry.received += 1;
        if let Some(slot) = entry.engines.get_mut(outcome.index) {
            *slot = outcome.engine;
        }
        match outcome.result {
            Ok(done) => {
                if let Some(slot) = entry.parts.get_mut(outcome.index) {
                    *slot = Some(done);
                }
            }
            Err(err) if !entry.resolved => {
                entry.resolved = true;
                let mut r = QueryResult::engine_error(&entry.plan.query, err, 1);
                r.engine = entry.engines[outcome.index.min(n_shards - 1)].clone();
                let _ = results.send(r);
            }
            Err(_) => {}
        }
        if entry.received == n_shards {
            let entry = open.remove(&outcome.plan.id).expect("entry just updated");
            if !entry.resolved {
                let _ = results.send(merge_shards(entry));
            }
        }
    }
    // Shutdown with shards still outstanding (a lane thread died
    // without draining): answer, never lose.
    for entry in open.into_values() {
        if !entry.resolved {
            let err = EngineError::Unavailable {
                reason: "gather stage shut down before every shard reported".into(),
            };
            let _ = results.send(QueryResult::engine_error(&entry.plan.query, err, 1));
        }
    }
}

/// Merge one complete set of shard partials into the final top-k
/// result. The ranking goes through `Corpus::rank_sharded` — which
/// reassembles the full score vector and calls `Corpus::rank` — so
/// sharded and unsharded rankings are bit-identical by construction
/// (no second sort or tie-break implementation exists; CI greps for
/// it). Telemetry merges with parallel semantics: work counters sum,
/// cycle reports take the slowest shard.
fn merge_shards(entry: GatherEntry) -> QueryResult {
    let GatherEntry {
        plan,
        parts,
        engines,
        ..
    } = entry;
    let QueryPayload::TopK {
        corpus, k, prune, ..
    } = &plan.query.payload
    else {
        unreachable!("shard plans only carry top-k payloads");
    };
    let mut telemetry = QueryTelemetry::default();
    let (mut queue_us, mut encode_us) = (0.0f64, 0.0f64);
    let (mut exec_max, mut exec_min) = (0.0f64, f64::INFINITY);
    let mut done: Vec<ShardDone> = Vec::with_capacity(parts.len());
    for part in parts {
        let p = part.expect("complete unresolved gather has every partial");
        telemetry.merge_parallel(&p.telemetry);
        // Shards run concurrently: the query waited for the slowest
        // lane (max), while the spread between the lanes is the
        // balance witness the metrics surface.
        queue_us = queue_us.max(p.queue_us);
        encode_us += p.encode_us;
        exec_max = exec_max.max(p.execute_us);
        exec_min = exec_min.min(p.execute_us);
        done.push(p);
    }
    let partials: Vec<ShardPartial> = done
        .iter()
        .map(|p| ShardPartial {
            epoch: p.epoch,
            shard: p.shard,
            scores: p.scores.as_slice(),
        })
        .collect();
    let mut ranked = match corpus.rank_sharded(&partials, *k) {
        Ok(ranked) => ranked,
        Err(e) => {
            // Unreachable through dispatch_topk (shards come from
            // Corpus::shards on the same corpus), but a typed answer
            // beats a panicked gather thread.
            let err = EngineError::Backend {
                engine: "gather".into(),
                detail: e.to_string(),
            };
            return QueryResult::engine_error(&plan.query, err, 1);
        }
    };
    strip_pruned(&mut ranked, prune.as_ref());
    let mut result = QueryResult {
        id: plan.query.id,
        outcome: Outcome::TopK(ranked),
        latency_us: plan.query.submitted.elapsed().as_secs_f64() * 1e6,
        // One query through the engines, however wide the scatter.
        batch_size: 1,
        stage: StageTiming {
            queue_us,
            encode_us,
            execute_us: exec_max,
        },
        telemetry,
        // Attribute the query to the embedder lane's engine.
        engine: engines.into_iter().next().flatten(),
        sharding: Some(ShardingInfo {
            shards: plan.n_shards,
            spread_us: exec_max - exec_min,
        }),
        cascade: None,
    };
    if let Some(plan) = prune {
        result = result.with_cascade(CascadeInfo {
            pruned: plan.pruned,
            survivors: plan.survivors,
            prune_us: plan.prune_us,
        });
    }
    result
}

fn execute_chunk(
    engine: &mut dyn Engine,
    tag: &Arc<str>,
    chunk: EncodedChunk,
    results: &NamedSender<QueryResult>,
) {
    let t0 = Instant::now();
    let scored = engine.score_batch(&chunk.packed);
    let execute_us = t0.elapsed().as_secs_f64() * 1e6;
    let batch_size = chunk.queries.len();
    match scored {
        Ok(out) => {
            for (i, q) in chunk.queries.iter().enumerate() {
                let _ = results.send(QueryResult {
                    id: q.id,
                    outcome: Outcome::Score(out.scores[i]),
                    latency_us: q.submitted.elapsed().as_secs_f64() * 1e6,
                    batch_size,
                    stage: StageTiming {
                        queue_us: chunk.queue_us[i],
                        encode_us: chunk.encode_us,
                        execute_us,
                    },
                    telemetry: out.telemetry.get(i).cloned().unwrap_or_default(),
                    engine: Some(Arc::clone(tag)),
                    sharding: None,
                    cascade: None,
                });
            }
        }
        Err(err) => {
            for q in &chunk.queries {
                let _ = results.send(
                    QueryResult::engine_error(q, err.clone(), batch_size)
                        .with_engine(Arc::clone(tag)),
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::corpus::Corpus;
    use crate::graph::Graph;
    use crate::runtime::{BatchOutput, CorpusOutput, MacCounts, QueryEmbed};
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Deterministic engine double: fixed batch ladder, optional per-call
    /// delay (to make the executor the bottleneck), call counter.
    struct MockEngine {
        caps: EngineCaps,
        delay: Duration,
        calls: Arc<AtomicU64>,
    }

    impl Engine for MockEngine {
        fn caps(&self) -> &EngineCaps {
            &self.caps
        }
        fn score_batch(&mut self, batch: &PackedBatch) -> Result<BatchOutput, EngineError> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            if !self.delay.is_zero() {
                thread::sleep(self.delay);
            }
            Ok(BatchOutput::untimed(vec![0.5; batch.batch]))
        }
    }

    fn mock_factory(sizes: Vec<usize>, delay: Duration, calls: Arc<AtomicU64>) -> EngineFactory {
        Arc::new(move || {
            Ok(Box::new(MockEngine {
                caps: EngineCaps::new("mock", sizes.clone(), 8, 4),
                delay,
                calls: Arc::clone(&calls),
            }) as Box<dyn Engine>)
        })
    }

    fn failing_factory(msg: &'static str) -> EngineFactory {
        Arc::new(move || {
            Err(EngineError::Unavailable {
                reason: msg.into(),
            })
        })
    }

    /// Mock with corpus support: deterministic descending scores so the
    /// executor-side ranking is predictable.
    struct CorpusMockEngine {
        caps: EngineCaps,
        corpus_calls: Arc<AtomicU64>,
    }

    impl Engine for CorpusMockEngine {
        fn caps(&self) -> &EngineCaps {
            &self.caps
        }
        fn score_batch(&mut self, batch: &PackedBatch) -> Result<BatchOutput, EngineError> {
            Ok(BatchOutput::untimed(vec![0.5; batch.batch]))
        }
        fn score_corpus(
            &mut self,
            _query: &crate::graph::encode::EncodedGraph,
            corpus: &[crate::graph::encode::EncodedGraph],
        ) -> Result<CorpusOutput, EngineError> {
            self.corpus_calls.fetch_add(1, Ordering::Relaxed);
            Ok(CorpusOutput {
                scores: (0..corpus.len()).map(|i| 1.0 / (1.0 + i as f32)).collect(),
                telemetry: QueryTelemetry::default(),
            })
        }
    }

    fn corpus_mock_factory(calls: Arc<AtomicU64>) -> EngineFactory {
        Arc::new(move || {
            Ok(Box::new(CorpusMockEngine {
                caps: EngineCaps::new("corpus-mock", vec![1, 4], 8, 4).with_corpus_scoring(),
                corpus_calls: Arc::clone(&calls),
            }) as Box<dyn Engine>)
        })
    }

    /// Mock with full sharded-corpus support: content-derived scores
    /// (so results are independent of how candidates were sharded) and
    /// separate counters for the embed-once and per-shard calls.
    struct ShardMockEngine {
        caps: EngineCaps,
        embed_calls: Arc<AtomicU64>,
        shard_calls: Arc<AtomicU64>,
        fail_embed: bool,
        fail_shard: bool,
    }

    fn content_score(g: &crate::graph::encode::EncodedGraph) -> f32 {
        (g.fingerprint().0 % 997) as f32 / 997.0
    }

    impl Engine for ShardMockEngine {
        fn caps(&self) -> &EngineCaps {
            &self.caps
        }
        fn score_batch(&mut self, batch: &PackedBatch) -> Result<BatchOutput, EngineError> {
            Ok(BatchOutput::untimed(vec![0.5; batch.batch]))
        }
        fn score_corpus(
            &mut self,
            _query: &crate::graph::encode::EncodedGraph,
            corpus: &[crate::graph::encode::EncodedGraph],
        ) -> Result<CorpusOutput, EngineError> {
            Ok(CorpusOutput {
                scores: corpus.iter().map(content_score).collect(),
                telemetry: QueryTelemetry::default(),
            })
        }
        fn embed_query(
            &mut self,
            _query: &crate::graph::encode::EncodedGraph,
        ) -> Result<QueryEmbed, EngineError> {
            self.embed_calls.fetch_add(1, Ordering::Relaxed);
            if self.fail_embed {
                return Err(EngineError::Backend {
                    engine: "shard-mock".into(),
                    detail: "embed failure injected".into(),
                });
            }
            Ok(QueryEmbed {
                embed: Arc::new(CachedEmbed {
                    hg: vec![0.25; 4],
                    macs: MacCounts::default(),
                }),
                telemetry: QueryTelemetry::default(),
            })
        }
        fn score_corpus_with(
            &mut self,
            _query_hg: &[f32],
            shard: &[crate::graph::encode::EncodedGraph],
        ) -> Result<CorpusOutput, EngineError> {
            self.shard_calls.fetch_add(1, Ordering::Relaxed);
            if self.fail_shard {
                return Err(EngineError::Backend {
                    engine: "shard-mock".into(),
                    detail: "shard failure injected".into(),
                });
            }
            Ok(CorpusOutput {
                scores: shard.iter().map(content_score).collect(),
                telemetry: QueryTelemetry::default(),
            })
        }
    }

    fn named_shard_mock_factory(
        name: &'static str,
        embed_calls: Arc<AtomicU64>,
        shard_calls: Arc<AtomicU64>,
        fail_embed: bool,
        fail_shard: bool,
    ) -> EngineFactory {
        Arc::new(move || {
            Ok(Box::new(ShardMockEngine {
                caps: EngineCaps::new(name, vec![1, 4], 8, 4)
                    .with_corpus_scoring()
                    .with_corpus_sharding(),
                embed_calls: Arc::clone(&embed_calls),
                shard_calls: Arc::clone(&shard_calls),
                fail_embed,
                fail_shard,
            }) as Box<dyn Engine>)
        })
    }

    fn shard_mock_factory(
        embed_calls: Arc<AtomicU64>,
        shard_calls: Arc<AtomicU64>,
        fail_embed: bool,
        fail_shard: bool,
    ) -> EngineFactory {
        named_shard_mock_factory("shard-mock", embed_calls, shard_calls, fail_embed, fail_shard)
    }

    fn tiny_corpus(entries: usize) -> Arc<Corpus> {
        let graphs: Vec<(u64, Graph)> = (0..entries)
            .map(|i| (i as u64, Graph::new(3, vec![(0, 1), (1, 2)], vec![0, 1, (i % 4) as u16])))
            .collect();
        Arc::new(Corpus::build("test", &graphs, 8, 4).unwrap())
    }

    fn model() -> ModelConfig {
        ModelConfig {
            n_max: 8,
            num_labels: 4,
            ..ModelConfig::default()
        }
    }

    fn query(id: u64) -> Query {
        let g = Graph::new(3, vec![(0, 1), (1, 2)], vec![0, 1, 2]);
        Query::new(id, g.clone(), g)
    }

    fn oversize_query(id: u64) -> Query {
        let g = Graph::new(20, (1..20).map(|v| (0u16, v as u16)).collect(), vec![0; 20]);
        Query::new(id, g.clone(), g)
    }

    fn pcfg(max_batch: usize, depth: usize, timeout: Duration) -> PipelineConfig {
        PipelineConfig {
            policy: BatchPolicy { max_batch, timeout },
            depth,
            ..PipelineConfig::default()
        }
    }

    fn caps(sizes: &[usize]) -> EngineCaps {
        EngineCaps::new("mock", sizes.to_vec(), 8, 4)
    }

    #[test]
    fn make_chunks_respects_engine_ladder() {
        let qs: Vec<Query> = (0..10).map(query).collect();
        let chunks = make_chunks(qs, &caps(&[1, 4]));
        let lens: Vec<usize> = chunks.iter().map(Vec::len).collect();
        assert_eq!(lens, vec![4, 4, 2]);
        // Order and identity preserved across the split.
        let ids: Vec<u64> = chunks.into_iter().flatten().map(|q| q.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<_>>());
        // A batch already within the ladder stays whole.
        let qs: Vec<Query> = (0..3).map(query).collect();
        assert_eq!(make_chunks(qs, &caps(&[1, 4])).len(), 1);
    }

    #[test]
    fn no_query_lost_or_duplicated_through_shutdown() {
        let calls = Arc::new(AtomicU64::new(0));
        let factory = mock_factory(vec![1, 4], Duration::ZERO, Arc::clone(&calls));
        let pipeline = Pipeline::start(
            model(),
            vec![Arc::clone(&factory), factory],
            pcfg(8, 2, Duration::from_micros(200)),
        );
        let n = 57u64;
        for id in 0..n {
            assert!(pipeline.submit(query(id)));
        }
        let metrics = pipeline.finish();
        // Every submitted query produced exactly one result: fewer means
        // lost in the cascade, more means duplicated.
        assert_eq!(metrics.scored, n);
        assert_eq!(metrics.rejected, 0);
        assert_eq!(metrics.engine_errors, 0);
        assert!(calls.load(Ordering::Relaxed) > 0);
        // Every scored query is attributed to the mock engine and both
        // lanes are named in the final metrics.
        assert_eq!(metrics.by_engine["mock"], n);
        assert_eq!(metrics.lanes.len(), 2);
        assert!(metrics.lanes.iter().all(|l| l.engine == "mock"));
    }

    #[test]
    fn oversized_batches_chunk_to_engine_limit() {
        let calls = Arc::new(AtomicU64::new(0));
        // batch_max 10 exceeds the engine's largest artifact (4): the
        // encoder must chunk, and every chunk must fit the ladder.
        let pipeline = Pipeline::start(
            model(),
            vec![mock_factory(vec![1, 4], Duration::ZERO, Arc::clone(&calls))],
            pcfg(10, 2, Duration::from_secs(5)),
        );
        for id in 0..10 {
            assert!(pipeline.submit(query(id)));
        }
        let metrics = pipeline.finish();
        assert_eq!(metrics.scored, 10);
        assert!(
            metrics.batch_sizes.max() <= 4.0,
            "chunk exceeded engine limit: {}",
            metrics.batch_sizes.max()
        );
    }

    #[test]
    fn engine_construction_failure_reports_per_query_errors() {
        let pipeline = Pipeline::start(
            model(),
            vec![failing_factory("no such backend")],
            pcfg(4, 2, Duration::from_micros(100)),
        );
        for id in 0..5 {
            assert!(pipeline.submit(query(id)));
        }
        let metrics = pipeline.finish();
        assert_eq!(metrics.engine_errors, 5);
        assert_eq!(metrics.scored, 0);
        // The lane is named with its failure in the final metrics.
        assert!(metrics.lanes[0].engine.contains("unavailable"));
    }

    #[test]
    fn engine_construction_failure_reports_errors_in_fused_lane() {
        let pipeline = Pipeline::start(
            model(),
            vec![failing_factory("no such backend")],
            pcfg(4, 0, Duration::from_micros(100)),
        );
        for id in 0..3 {
            assert!(pipeline.submit(query(id)));
        }
        let metrics = pipeline.finish();
        assert_eq!(metrics.engine_errors, 3);
        assert_eq!(metrics.scored, 0);
    }

    #[test]
    fn dead_lane_traffic_routes_to_surviving_lane() {
        // One lane's engine fails to construct, the other is healthy:
        // the caps-aware router must keep every query on the healthy
        // lane once the failure is known. Serve in two waves so the
        // second wave definitely arrives after the handshake.
        let calls = Arc::new(AtomicU64::new(0));
        let pipeline = Pipeline::start(
            model(),
            vec![
                failing_factory("no artifacts"),
                mock_factory(vec![1, 4], Duration::ZERO, Arc::clone(&calls)),
            ],
            pcfg(4, 2, Duration::from_micros(100)),
        );
        for id in 0..4 {
            assert!(pipeline.submit(query(id)));
        }
        // Let the failed handshake land before the second wave.
        thread::sleep(Duration::from_millis(20));
        for id in 4..12 {
            assert!(pipeline.submit(query(id)));
        }
        let metrics = pipeline.finish();
        assert_eq!(metrics.scored + metrics.engine_errors, 12);
        assert!(
            metrics.scored >= 8,
            "post-handshake queries must route around the dead lane \
             (scored {}, errors {})",
            metrics.scored,
            metrics.engine_errors
        );
        assert!(metrics.lanes[0].engine.contains("unavailable"));
        assert_eq!(metrics.lanes[1].engine, "mock");
    }

    #[test]
    fn topk_queries_ride_the_pipeline_with_pairs() {
        let corpus_calls = Arc::new(AtomicU64::new(0));
        let pipeline = Pipeline::start(
            model(),
            vec![corpus_mock_factory(Arc::clone(&corpus_calls))],
            pcfg(4, 2, Duration::from_micros(100)),
        );
        let corpus = tiny_corpus(6);
        for id in 0..6 {
            assert!(pipeline.submit(query(id)));
        }
        for id in 6..9 {
            assert!(pipeline.submit(Query::topk(
                id,
                Graph::new(2, vec![(0, 1)], vec![0, 1]),
                Arc::clone(&corpus),
                2,
            )));
        }
        let metrics = pipeline.finish();
        assert_eq!(metrics.scored, 9, "pairs and top-k both complete");
        assert_eq!(metrics.topk, 3);
        assert_eq!(metrics.rejected, 0);
        assert_eq!(metrics.engine_errors, 0);
        assert_eq!(corpus_calls.load(Ordering::Relaxed), 3);
        assert_eq!(metrics.by_engine["corpus-mock"], 9);
    }

    #[test]
    fn topk_routes_to_corpus_capable_lane_in_mixed_deployment() {
        // One plain lane (no corpus support) + one corpus-capable lane:
        // after the caps handshakes land, every top-k query must reach
        // the capable lane instead of round-robining into typed errors.
        let pair_calls = Arc::new(AtomicU64::new(0));
        let corpus_calls = Arc::new(AtomicU64::new(0));
        let pipeline = Pipeline::start(
            model(),
            vec![
                mock_factory(vec![1, 4], Duration::ZERO, Arc::clone(&pair_calls)),
                corpus_mock_factory(Arc::clone(&corpus_calls)),
            ],
            pcfg(4, 2, Duration::from_micros(100)),
        );
        // Block until both caps handshakes have landed: routing by
        // published capability is only deterministic once published.
        for lane in &pipeline.lane_caps {
            lane.wait().expect("mock engines construct successfully");
        }
        let corpus = tiny_corpus(4);
        for id in 0..8 {
            assert!(pipeline.submit(Query::topk(
                id,
                Graph::new(2, vec![(0, 1)], vec![0, 1]),
                Arc::clone(&corpus),
                2,
            )));
        }
        let metrics = pipeline.finish();
        assert_eq!(metrics.scored, 8, "every top-k served by the capable lane");
        assert_eq!(metrics.topk, 8);
        assert_eq!(metrics.engine_errors, 0);
        assert_eq!(corpus_calls.load(Ordering::Relaxed), 8);
        assert_eq!(metrics.by_engine.get("mock"), None);
    }

    #[test]
    fn topk_scatters_across_capable_lanes_and_gathers_once() {
        let embed_calls = Arc::new(AtomicU64::new(0));
        let shard_calls = Arc::new(AtomicU64::new(0));
        let factory = shard_mock_factory(
            Arc::clone(&embed_calls),
            Arc::clone(&shard_calls),
            false,
            false,
        );
        for depth in [2usize, 0] {
            embed_calls.store(0, Ordering::Relaxed);
            shard_calls.store(0, Ordering::Relaxed);
            let pipeline = Pipeline::start(
                model(),
                vec![Arc::clone(&factory), Arc::clone(&factory)],
                pcfg(4, depth, Duration::from_micros(100)),
            );
            // Scatter sizing reads *published* caps: wait for both
            // handshakes so every query is deterministically split.
            assert_eq!(pipeline.wait_ready(), 2);
            let corpus = tiny_corpus(6);
            for id in 0..4 {
                assert!(pipeline.submit(Query::topk(
                    id,
                    Graph::new(2, vec![(0, 1)], vec![0, 1]),
                    Arc::clone(&corpus),
                    3,
                )));
            }
            let metrics = pipeline.finish();
            assert_eq!(metrics.scored, 4, "depth {depth}: every scattered query resolves");
            assert_eq!(metrics.topk, 4);
            assert_eq!(metrics.engine_errors, 0);
            assert_eq!(metrics.rejected, 0);
            // Embed-once contract: one embed per query, one shard call
            // per (query, lane).
            assert_eq!(embed_calls.load(Ordering::Relaxed), 4, "depth {depth}");
            assert_eq!(shard_calls.load(Ordering::Relaxed), 8, "depth {depth}");
            // The shard telemetry reached the metrics.
            assert_eq!(metrics.topk_shards.len(), 4);
            assert_eq!(metrics.topk_shards.mean(), 2.0, "depth {depth}");
            assert_eq!(metrics.topk_spread_us.len(), 4);
            assert_eq!(metrics.by_engine["shard-mock"], 4);
            // The gather channel is visible in the FIFO stats.
            assert!(metrics.channels.iter().any(|c| c.name == "gather"));
        }
    }

    #[test]
    fn scatter_falls_back_to_whole_query_without_two_capable_lanes() {
        // One shard-capable lane + one plain lane: no scatter, the
        // whole-query path serves (shards mean 1.0, no shard calls).
        let embed_calls = Arc::new(AtomicU64::new(0));
        let shard_calls = Arc::new(AtomicU64::new(0));
        let pair_calls = Arc::new(AtomicU64::new(0));
        let sharder =
            shard_mock_factory(Arc::clone(&embed_calls), Arc::clone(&shard_calls), false, false);
        let pipeline = Pipeline::start(
            model(),
            vec![
                sharder,
                mock_factory(vec![1, 4], Duration::ZERO, Arc::clone(&pair_calls)),
            ],
            pcfg(4, 2, Duration::from_micros(100)),
        );
        assert_eq!(pipeline.wait_ready(), 2);
        let corpus = tiny_corpus(6);
        for id in 0..3 {
            assert!(pipeline.submit(Query::topk(
                id,
                Graph::new(2, vec![(0, 1)], vec![0, 1]),
                Arc::clone(&corpus),
                2,
            )));
        }
        let metrics = pipeline.finish();
        assert_eq!(metrics.scored, 3);
        assert_eq!(metrics.topk, 3);
        assert_eq!(metrics.engine_errors, 0);
        assert_eq!(shard_calls.load(Ordering::Relaxed), 0, "nothing scattered");
        assert_eq!(embed_calls.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.topk_shards.mean(), 1.0);
        assert_eq!(metrics.topk_spread_us.mean(), 0.0);
    }

    #[test]
    fn scatter_stays_within_one_engine_kind() {
        // Shard-capable lanes of DIFFERENT kinds must not share one
        // query's shards: per-shard telemetry is policy-specific, so a
        // cross-kind scatter would blend the per-engine rows. Two
        // mixed-kind lanes -> cohorts of one each -> whole-query path;
        // adding a second lane of one kind -> that cohort scatters.
        let embed_a = Arc::new(AtomicU64::new(0));
        let shard_a = Arc::new(AtomicU64::new(0));
        let embed_b = Arc::new(AtomicU64::new(0));
        let shard_b = Arc::new(AtomicU64::new(0));
        let kind_a = named_shard_mock_factory(
            "shard-mock-a",
            Arc::clone(&embed_a),
            Arc::clone(&shard_a),
            false,
            false,
        );
        let kind_b = named_shard_mock_factory(
            "shard-mock-b",
            Arc::clone(&embed_b),
            Arc::clone(&shard_b),
            false,
            false,
        );
        let pipeline = Pipeline::start(
            model(),
            vec![Arc::clone(&kind_a), Arc::clone(&kind_b)],
            pcfg(4, 2, Duration::from_micros(100)),
        );
        assert_eq!(pipeline.wait_ready(), 2);
        let corpus = tiny_corpus(6);
        for id in 0..3 {
            assert!(pipeline.submit(Query::topk(
                id,
                Graph::new(2, vec![(0, 1)], vec![0, 1]),
                Arc::clone(&corpus),
                2,
            )));
        }
        let metrics = pipeline.finish();
        assert_eq!(metrics.scored, 3);
        assert_eq!(metrics.topk_shards.mean(), 1.0, "no cross-kind scatter");
        assert_eq!(shard_a.load(Ordering::Relaxed) + shard_b.load(Ordering::Relaxed), 0);

        // A second kind-a lane forms a cohort of two: every query now
        // scatters, and only onto the kind-a lanes.
        let pipeline = Pipeline::start(
            model(),
            vec![Arc::clone(&kind_a), kind_b, kind_a],
            pcfg(4, 2, Duration::from_micros(100)),
        );
        assert_eq!(pipeline.wait_ready(), 3);
        for id in 0..3 {
            assert!(pipeline.submit(Query::topk(
                id,
                Graph::new(2, vec![(0, 1)], vec![0, 1]),
                Arc::clone(&corpus),
                2,
            )));
        }
        let metrics = pipeline.finish();
        assert_eq!(metrics.scored, 3);
        assert_eq!(metrics.engine_errors, 0);
        assert_eq!(metrics.topk_shards.mean(), 2.0, "kind-a cohort scatters");
        assert_eq!(shard_a.load(Ordering::Relaxed), 6, "two shards per query, all kind-a");
        assert_eq!(shard_b.load(Ordering::Relaxed), 0, "kind-b never sees a shard");
        assert_eq!(embed_a.load(Ordering::Relaxed), 3);
        assert_eq!(embed_b.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.by_engine["shard-mock-a"], 3);
    }

    #[test]
    fn single_candidate_corpus_never_scatters() {
        // Two capable lanes but one candidate: nothing to split.
        let embed_calls = Arc::new(AtomicU64::new(0));
        let shard_calls = Arc::new(AtomicU64::new(0));
        let factory = shard_mock_factory(
            Arc::clone(&embed_calls),
            Arc::clone(&shard_calls),
            false,
            false,
        );
        let pipeline = Pipeline::start(
            model(),
            vec![Arc::clone(&factory), factory],
            pcfg(4, 2, Duration::from_micros(100)),
        );
        assert_eq!(pipeline.wait_ready(), 2);
        assert!(pipeline.submit(Query::topk(
            0,
            Graph::new(2, vec![(0, 1)], vec![0, 1]),
            tiny_corpus(1),
            1,
        )));
        let metrics = pipeline.finish();
        assert_eq!(metrics.scored, 1);
        assert_eq!(shard_calls.load(Ordering::Relaxed), 0);
        assert_eq!(metrics.topk_shards.mean(), 1.0);
    }

    #[test]
    fn topk_on_unsupporting_engine_answers_typed_error() {
        // The plain mock keeps score_corpus's default: pair traffic is
        // served, the top-k query comes back as a typed engine error
        // (never silently dropped, never K full forwards).
        let calls = Arc::new(AtomicU64::new(0));
        let pipeline = Pipeline::start(
            model(),
            vec![mock_factory(vec![1, 4], Duration::ZERO, calls)],
            pcfg(4, 2, Duration::from_micros(100)),
        );
        for id in 0..3 {
            assert!(pipeline.submit(query(id)));
        }
        assert!(pipeline.submit(Query::topk(
            9,
            Graph::new(2, vec![(0, 1)], vec![0, 1]),
            tiny_corpus(4),
            2,
        )));
        let metrics = pipeline.finish();
        assert_eq!(metrics.scored, 3);
        assert_eq!(metrics.engine_errors, 1);
        assert_eq!(metrics.topk, 0);
    }

    #[test]
    fn empty_corpus_topk_is_rejected_at_admission() {
        let calls = Arc::new(AtomicU64::new(0));
        let pipeline = Pipeline::start(
            model(),
            vec![corpus_mock_factory(calls)],
            pcfg(4, 2, Duration::from_micros(100)),
        );
        let empty = Arc::new(Corpus::build("empty", &[], 8, 4).unwrap());
        assert!(pipeline.submit(Query::topk(
            1,
            Graph::new(2, vec![(0, 1)], vec![0, 1]),
            empty,
            3,
        )));
        let metrics = pipeline.finish();
        assert_eq!(metrics.rejected, 1);
        assert_eq!(metrics.scored, 0);
    }

    #[test]
    fn rejects_flow_to_responder() {
        let calls = Arc::new(AtomicU64::new(0));
        let pipeline = Pipeline::start(
            model(),
            vec![mock_factory(vec![1, 4], Duration::ZERO, calls)],
            pcfg(4, 2, Duration::from_micros(100)),
        );
        assert!(pipeline.submit(oversize_query(0)));
        for id in 1..4 {
            assert!(pipeline.submit(query(id)));
        }
        let metrics = pipeline.finish();
        assert_eq!(metrics.rejected, 1);
        assert_eq!(metrics.scored, 3);
    }

    #[test]
    fn encoder_overlaps_with_executor() {
        // Executor sleeps 3ms per chunk, encoding is microseconds: if the
        // stages overlap, encoded chunks pile up in the bounded exec
        // channel while the engine is busy. Peak depth >= 2 is the
        // witness that batch k+1 encoded while batch k was in the engine
        // (a peak of 1 would be just a single hand-off in flight, which
        // even a fully serialized lane records).
        let calls = Arc::new(AtomicU64::new(0));
        let pipeline = Pipeline::start(
            model(),
            vec![mock_factory(vec![1, 4], Duration::from_millis(3), calls)],
            pcfg(4, 2, Duration::from_micros(100)),
        );
        for id in 0..24 {
            assert!(pipeline.submit(query(id)));
        }
        let metrics = pipeline.finish();
        assert_eq!(metrics.scored, 24);
        let exec = metrics
            .channels
            .iter()
            .find(|c| c.name == "exec.0")
            .expect("exec channel snapshot present");
        assert!(
            exec.max_depth >= 2,
            "no overlap observed: exec.0 peak depth {} (snapshots: {:?})",
            exec.max_depth,
            metrics.channels
        );
        // Executor time dominates and is visible in the stage split.
        assert!(metrics.execute_us.mean() > metrics.encode_us.mean());
    }

    #[test]
    fn sequential_lane_still_serves_everything() {
        let calls = Arc::new(AtomicU64::new(0));
        let factory = mock_factory(vec![1, 4], Duration::ZERO, calls);
        let pipeline = Pipeline::start(
            model(),
            vec![Arc::clone(&factory), factory],
            pcfg(4, 0, Duration::from_micros(100)),
        );
        for id in 0..20 {
            assert!(pipeline.submit(query(id)));
        }
        let metrics = pipeline.finish();
        assert_eq!(metrics.scored, 20);
    }
}
