//! Deterministic workload traces: record what the serving path admitted,
//! replay it bit-for-bit, snapshot the result (DESIGN.md S19).
//!
//! The paper's argument is measured speedup on a serving-shaped workload
//! (Table 6 / Fig. 11), so the perf trajectory needs a *reproducible*
//! workload, not a fresh Poisson draw per run. This module is the whole
//! trace story in one place:
//!
//! * **Format** — line-delimited JSON (`spa-gcn-trace-v1`): one header
//!   line carrying the synthesis recipe (seed, corpus size, model
//!   shapes), then one object per admitted query with its arrival offset
//!   (µs), client id, payload kind and inline graphs. Hand-rolled on
//!   [`util::json`] like the wire protocol — no serde — and
//!   hostile-input-safe the same way `net/wire.rs` is: every field is
//!   validated before any [`Graph`] is constructed, line length is
//!   bounded, and malformed input surfaces as a typed [`TraceError`],
//!   never a panic.
//! * **Record** — [`TraceRecorder`], the tap `run_serve` and the net
//!   front stage write through (`serve --record PATH`). Append-only,
//!   lock-per-line, and failure-latching: a full disk degrades the trace,
//!   never the serving path.
//! * **Replay** — [`Trace`] parses a recorded file back into entries;
//!   [`TraceEntry::to_query`] rebuilds the exact [`Query`] stream for
//!   `run_replay`, which substitutes the recorded schedule for
//!   `poisson_schedule` synthesis. [`outcome_line`] renders each result
//!   as a deterministic text line (`f32::to_bits`, zero-padded ids) so
//!   two replays diff byte-for-byte.
//! * **Snapshot** — [`bench_snapshot`] serializes a [`Metrics`] into the
//!   `bench-serving-v1` JSON schema (`BENCH_<n>.json`, CI `bench.json`);
//!   [`check_bench`] validates that schema for `spa-gcn bench-check`,
//!   and [`bench_is_estimated`] keeps analytic estimates from ever
//!   serving as regression baselines.
//!
//! Trace entries are constructed *only* here (the `TRACE-CONFINED` lint
//! rule, DESIGN.md S18): consumers read entries through accessors and
//! convert them with [`TraceEntry::to_query`], so the format can evolve
//! without chasing construction sites across the tree.
//!
//! [`util::json`]: crate::util::json

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::fs::File;
use std::io::{BufRead as _, BufReader, BufWriter, Read as _, Write as _};
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::graph::Graph;
use crate::util::json::{self, Json};

use super::corpus::Corpus;
use super::metrics::Metrics;
use super::query::{CascadeMode, Outcome, Query, QueryPayload, QueryResult};

/// Trace format version tag, first field checked on the header line.
pub const TRACE_SCHEMA: &str = "spa-gcn-trace-v1";

/// Serving-bench snapshot schema tag (`BENCH_<n>.json`, CI `bench.json`).
pub const BENCH_SCHEMA: &str = "bench-serving-v1";

/// Largest node count accepted from a trace graph — same spirit as the
/// wire codec's node cap: bound allocation before construction.
pub const MAX_TRACE_NODES: usize = 4096;

/// Longest accepted trace line in bytes. Generous (a recorded graph near
/// the wire frame cap re-encodes at about the same size) but bounded, so
/// a hostile file can't make the reader buffer a gigabyte "line".
pub const MAX_TRACE_LINE: usize = 4 << 20;

/// Largest top-k depth accepted from a trace (the pipeline clamps to the
/// corpus anyway; this bounds the field before it goes anywhere).
pub const MAX_TRACE_TOPK: usize = 1 << 20;

/// Exact-integer ceiling for JSON numbers (2^53): ids and offsets above
/// this would silently lose precision in an f64, so the parser rejects
/// them and the recorder clamps.
const MAX_JSON_INT: f64 = 9_007_199_254_740_992.0;

/// Typed trace codec failure. Like `WireError`: every variant names what
/// was wrong and where, [`code`](TraceError::code) gives CI-greppable
/// tags, and nothing in the parse path panics on hostile input.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceError {
    /// Underlying file I/O failed.
    Io(String),
    /// The file ended before a header line was seen.
    MissingHeader,
    /// The header's `schema` field is missing or names another format.
    BadSchema {
        /// What the header actually said (empty if missing).
        found: String,
    },
    /// A line exceeded [`MAX_TRACE_LINE`].
    LineTooLong {
        /// 1-based line number.
        line: usize,
        /// Observed length in bytes.
        len: usize,
    },
    /// A line is not a well-formed JSON object (truncation lands here).
    Parse {
        /// 1-based line number.
        line: usize,
        /// Underlying parser message.
        msg: String,
    },
    /// A field is missing, mistyped or out of range.
    Field {
        /// 1-based line number.
        line: usize,
        /// Field name.
        field: &'static str,
        /// What was wrong with it.
        msg: String,
    },
    /// An inline graph failed validation (shape, labels, endpoints).
    BadGraph {
        /// 1-based line number.
        line: usize,
        /// What was wrong with it.
        msg: String,
    },
    /// A top-k entry names a corpus the replay environment doesn't have.
    UnknownCorpus {
        /// The entry's query id.
        id: u64,
        /// The corpus name it asked for.
        corpus: String,
    },
}

impl TraceError {
    /// Stable machine-readable tag per variant.
    pub fn code(&self) -> &'static str {
        match self {
            TraceError::Io(_) => "io",
            TraceError::MissingHeader => "missing_header",
            TraceError::BadSchema { .. } => "bad_schema",
            TraceError::LineTooLong { .. } => "line_too_long",
            TraceError::Parse { .. } => "parse",
            TraceError::Field { .. } => "field",
            TraceError::BadGraph { .. } => "bad_graph",
            TraceError::UnknownCorpus { .. } => "unknown_corpus",
        }
    }
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceError::Io(e) => write!(f, "trace i/o: {e}"),
            TraceError::MissingHeader => write!(f, "trace has no header line"),
            TraceError::BadSchema { found } => {
                write!(f, "trace schema is '{found}', expected '{TRACE_SCHEMA}'")
            }
            TraceError::LineTooLong { line, len } => {
                write!(f, "line {line}: {len} bytes exceeds the {MAX_TRACE_LINE}-byte cap")
            }
            TraceError::Parse { line, msg } => write!(f, "line {line}: {msg}"),
            TraceError::Field { line, field, msg } => {
                write!(f, "line {line}: field '{field}': {msg}")
            }
            TraceError::BadGraph { line, msg } => write!(f, "line {line}: graph: {msg}"),
            TraceError::UnknownCorpus { id, corpus } => {
                write!(f, "entry {id} names unknown corpus '{corpus}'")
            }
        }
    }
}

impl std::error::Error for TraceError {}

/// Trace header: the synthesis recipe replay needs to rebuild the exact
/// serving environment (the `aids-synth` corpus in particular) without
/// embedding it in the trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceHeader {
    /// Workload RNG seed of the recorded run.
    pub seed: u64,
    /// Corpus size of the recorded run (0 = pairwise workload, no
    /// corpus to rebuild).
    pub corpus_size: usize,
    /// Default top-k depth of the recorded run (informational; each
    /// entry carries its own `k`).
    pub topk: usize,
    /// Model `n_max` the recorded run served with.
    pub n_max: usize,
    /// Model label-vocabulary size of the recorded run.
    pub num_labels: usize,
}

/// What one recorded query asked for. Private on purpose: construction
/// stays inside this module (TRACE-CONFINED) and consumers go through
/// [`TraceEntry`] accessors.
#[derive(Debug, Clone)]
enum Payload {
    Pair {
        g1: Graph,
        g2: Graph,
    },
    TopK {
        graph: Graph,
        corpus: String,
        k: usize,
        /// Corpus epoch the query was admitted against (0 = pre-epoch
        /// trace, or the corpus' initial generation). Informational on
        /// replay — the rebuilt corpus pins its own epoch — but it
        /// keeps recorded dumps attributable to one snapshot.
        epoch: u64,
        /// Cascade candidate budget (0 = `CascadeMode::Exact`).
        budget: usize,
    },
}

/// One recorded query: arrival offset, origin client, payload.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    id: u64,
    offset_us: u64,
    client: String,
    payload: Payload,
}

impl TraceEntry {
    /// The recorded query id (echoed into replayed results).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Arrival offset from the trace epoch, µs.
    pub fn offset_us(&self) -> u64 {
        self.offset_us
    }

    /// Arrival offset as a [`Duration`] (the replay schedule unit).
    pub fn offset(&self) -> Duration {
        Duration::from_micros(self.offset_us)
    }

    /// The recorded client id (`"cli"` for in-process serving).
    pub fn client(&self) -> &str {
        &self.client
    }

    /// Payload kind tag, `"pair"` or `"topk"`.
    pub fn kind(&self) -> &'static str {
        match self.payload {
            Payload::Pair { .. } => "pair",
            Payload::TopK { .. } => "topk",
        }
    }

    /// The corpus a top-k entry ranks against (`None` for pairs).
    pub fn corpus(&self) -> Option<&str> {
        match &self.payload {
            Payload::TopK { corpus, .. } => Some(corpus),
            Payload::Pair { .. } => None,
        }
    }

    /// The corpus epoch a top-k entry was admitted against (0 for pairs
    /// and for traces recorded before epochs existed).
    pub fn epoch(&self) -> u64 {
        match &self.payload {
            Payload::TopK { epoch, .. } => *epoch,
            Payload::Pair { .. } => 0,
        }
    }

    /// The cascade candidate budget a top-k entry recorded (0 = exact).
    pub fn budget(&self) -> usize {
        match &self.payload {
            Payload::TopK { budget, .. } => *budget,
            Payload::Pair { .. } => 0,
        }
    }

    /// Rebuild the pipeline [`Query`] this entry recorded. Top-k entries
    /// resolve their corpus by name against `corpora`; the `submitted`
    /// timestamp is stamped at call time, so convert at submit time to
    /// keep queue-wait metrics honest (same reason `run_serve` builds
    /// queries lazily).
    pub fn to_query(
        &self,
        corpora: &BTreeMap<String, Arc<Corpus>>,
    ) -> Result<Query, TraceError> {
        match &self.payload {
            Payload::Pair { g1, g2 } => Ok(Query::new(self.id, g1.clone(), g2.clone())),
            Payload::TopK {
                graph,
                corpus,
                k,
                budget,
                ..
            } => match corpora.get(corpus) {
                // The rebuilt query pins the *replay* corpus' epoch:
                // the recorded epoch documents the live run, it doesn't
                // override the environment replay resolved.
                Some(c) => {
                    let mode = if *budget > 0 {
                        CascadeMode::Budgeted { budget: *budget }
                    } else {
                        CascadeMode::Exact
                    };
                    Ok(Query::topk_with(self.id, graph.clone(), Arc::clone(c), *k, mode))
                }
                None => Err(TraceError::UnknownCorpus {
                    id: self.id,
                    corpus: corpus.clone(),
                }),
            },
        }
    }
}

/// A parsed trace: header plus entries in recorded (arrival) order.
#[derive(Debug, Clone)]
pub struct Trace {
    header: TraceHeader,
    entries: Vec<TraceEntry>,
}

impl Trace {
    /// Parse a whole trace document (tests, in-memory round trips).
    pub fn parse(text: &str) -> Result<Trace, TraceError> {
        let mut b = TraceBuilder::default();
        for (i, line) in text.lines().enumerate() {
            b.line(i + 1, line)?;
        }
        b.finish()
    }

    /// Read a trace file, streaming line by line so memory stays bounded
    /// by [`MAX_TRACE_LINE`] plus the parsed entries.
    pub fn read(path: &Path) -> Result<Trace, TraceError> {
        let file = File::open(path).map_err(|e| TraceError::Io(format!("{}: {e}", path.display())))?;
        let mut reader = BufReader::new(file);
        let mut b = TraceBuilder::default();
        let mut buf = Vec::new();
        let mut line_no = 0usize;
        loop {
            buf.clear();
            // Bounded read: stop at the cap + 1 so an endless "line"
            // can't grow the buffer past the documented limit.
            let n = (&mut reader)
                .take(MAX_TRACE_LINE as u64 + 1)
                .read_until(b'\n', &mut buf)
                .map_err(|e| TraceError::Io(e.to_string()))?;
            if n == 0 {
                break;
            }
            line_no += 1;
            if buf.len() > MAX_TRACE_LINE {
                return Err(TraceError::LineTooLong { line: line_no, len: buf.len() });
            }
            let text = std::str::from_utf8(&buf).map_err(|e| TraceError::Parse {
                line: line_no,
                msg: format!("not utf-8: {e}"),
            })?;
            b.line(line_no, text)?;
        }
        b.finish()
    }

    /// The synthesis recipe recorded on the header line.
    pub fn header(&self) -> &TraceHeader {
        &self.header
    }

    /// Entries in recorded arrival order.
    pub fn entries(&self) -> &[TraceEntry] {
        &self.entries
    }

    /// Entry count.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the trace recorded no queries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Incremental line-at-a-time parser shared by [`Trace::parse`] and
/// [`Trace::read`].
#[derive(Debug, Default)]
struct TraceBuilder {
    header: Option<TraceHeader>,
    entries: Vec<TraceEntry>,
}

impl TraceBuilder {
    fn line(&mut self, line_no: usize, raw: &str) -> Result<(), TraceError> {
        let text = raw.trim();
        if text.is_empty() {
            return Ok(());
        }
        if text.len() > MAX_TRACE_LINE {
            return Err(TraceError::LineTooLong { line: line_no, len: text.len() });
        }
        let v = json::parse(text).map_err(|msg| TraceError::Parse { line: line_no, msg })?;
        if v.as_obj().is_none() {
            return Err(TraceError::Parse {
                line: line_no,
                msg: "line is not a JSON object".into(),
            });
        }
        match self.header {
            None => self.header = Some(header_from_json(&v)?),
            Some(_) => self.entries.push(entry_from_json(&v, line_no)?),
        }
        Ok(())
    }

    fn finish(self) -> Result<Trace, TraceError> {
        match self.header {
            Some(header) => Ok(Trace { header, entries: self.entries }),
            None => Err(TraceError::MissingHeader),
        }
    }
}

// ---------------------------------------------------------------------------
// Serialization (one canonical text form: BTreeMap key order + compact
// writer, so identical entries always serialize to identical bytes).

fn clamp_int(x: u64) -> f64 {
    (x as f64).min(MAX_JSON_INT)
}

fn graph_to_json(g: &Graph) -> Json {
    json::obj(vec![
        ("n", json::num(g.num_nodes() as f64)),
        (
            "labels",
            json::arr(g.labels().iter().map(|&l| json::num(l as f64)).collect()),
        ),
        (
            "edges",
            json::arr(
                g.edges()
                    .iter()
                    .map(|&(u, v)| json::arr(vec![json::num(u as f64), json::num(v as f64)]))
                    .collect(),
            ),
        ),
    ])
}

fn header_line(h: &TraceHeader) -> String {
    json::obj(vec![
        ("corpus_size", json::num(h.corpus_size as f64)),
        ("n_max", json::num(h.n_max as f64)),
        ("num_labels", json::num(h.num_labels as f64)),
        ("schema", json::s(TRACE_SCHEMA)),
        ("seed", json::num(clamp_int(h.seed))),
        ("topk", json::num(h.topk as f64)),
    ])
    .to_string()
}

fn pair_line(client: &str, id: u64, offset_us: u64, g1: &Graph, g2: &Graph) -> String {
    json::obj(vec![
        ("client", json::s(client)),
        ("graphs", json::arr(vec![graph_to_json(g1), graph_to_json(g2)])),
        ("id", json::num(clamp_int(id))),
        ("kind", json::s("pair")),
        ("offset_us", json::num(clamp_int(offset_us))),
    ])
    .to_string()
}

#[allow(clippy::too_many_arguments)]
fn topk_line(
    client: &str,
    id: u64,
    offset_us: u64,
    g: &Graph,
    corpus: &str,
    k: usize,
    epoch: u64,
    budget: usize,
) -> String {
    json::obj(vec![
        ("budget", json::num(budget as f64)),
        ("client", json::s(client)),
        ("corpus", json::s(corpus)),
        ("epoch", json::num(clamp_int(epoch))),
        ("graphs", json::arr(vec![graph_to_json(g)])),
        ("id", json::num(clamp_int(id))),
        ("k", json::num(k as f64)),
        ("kind", json::s("topk")),
        ("offset_us", json::num(clamp_int(offset_us))),
    ])
    .to_string()
}

// ---------------------------------------------------------------------------
// Parsing (validate everything before constructing anything).

fn field_u64(v: &Json, field: &'static str, line: usize) -> Result<u64, TraceError> {
    match v.get(field).as_f64() {
        Some(x) if x >= 0.0 && x.fract() == 0.0 && x <= MAX_JSON_INT => Ok(x as u64),
        Some(_) => Err(TraceError::Field {
            line,
            field,
            msg: "not an exact nonnegative integer".into(),
        }),
        None => Err(TraceError::Field { line, field, msg: "missing or not a number".into() }),
    }
}

fn field_usize(v: &Json, field: &'static str, line: usize) -> Result<usize, TraceError> {
    Ok(field_u64(v, field, line)? as usize)
}

/// Optional nonnegative integer: absent fields default to 0 (traces
/// recorded before the field existed), present fields still validate.
fn field_u64_or_zero(v: &Json, field: &'static str, line: usize) -> Result<u64, TraceError> {
    if matches!(v.get(field), Json::Null) {
        return Ok(0);
    }
    field_u64(v, field, line)
}

fn field_str(v: &Json, field: &'static str, line: usize) -> Result<String, TraceError> {
    v.get(field)
        .as_str()
        .map(str::to_string)
        .ok_or(TraceError::Field { line, field, msg: "missing or not a string".into() })
}

fn header_from_json(v: &Json) -> Result<TraceHeader, TraceError> {
    let found = v.get("schema").as_str().unwrap_or_default();
    if found != TRACE_SCHEMA {
        return Err(TraceError::BadSchema { found: found.to_string() });
    }
    Ok(TraceHeader {
        seed: field_u64(v, "seed", 1)?,
        corpus_size: field_usize(v, "corpus_size", 1)?,
        topk: field_usize(v, "topk", 1)?,
        n_max: field_usize(v, "n_max", 1)?,
        num_labels: field_usize(v, "num_labels", 1)?,
    })
}

fn graph_from_json(v: &Json, line: usize) -> Result<Graph, TraceError> {
    if v.as_obj().is_none() {
        return Err(TraceError::BadGraph { line, msg: "graph must be an object".into() });
    }
    let n = match v.get("n").as_f64() {
        Some(x) if x >= 0.0 && x.fract() == 0.0 && x <= MAX_TRACE_NODES as f64 => x as usize,
        Some(_) => {
            return Err(TraceError::BadGraph {
                line,
                msg: format!("n must be an integer in 0..={MAX_TRACE_NODES}"),
            })
        }
        None => return Err(TraceError::BadGraph { line, msg: "n missing or not a number".into() }),
    };
    let labels_json = v.get("labels").as_arr().ok_or_else(|| TraceError::BadGraph {
        line,
        msg: "labels missing or not an array".into(),
    })?;
    if labels_json.len() != n {
        return Err(TraceError::BadGraph {
            line,
            msg: format!("labels has {} entries, n is {n}", labels_json.len()),
        });
    }
    let mut labels = Vec::with_capacity(n);
    for l in labels_json {
        match l.as_f64() {
            Some(x) if x >= 0.0 && x.fract() == 0.0 && x <= f64::from(u16::MAX) => {
                labels.push(x as u16)
            }
            _ => {
                return Err(TraceError::BadGraph {
                    line,
                    msg: "label is not an integer in u16 range".into(),
                })
            }
        }
    }
    let edges_json = v.get("edges").as_arr().ok_or_else(|| TraceError::BadGraph {
        line,
        msg: "edges missing or not an array".into(),
    })?;
    let mut edges = Vec::with_capacity(edges_json.len());
    for e in edges_json {
        let pair = match e.as_arr() {
            Some(p) if p.len() == 2 => p,
            _ => {
                return Err(TraceError::BadGraph {
                    line,
                    msg: "edge must be a [u, v] pair".into(),
                })
            }
        };
        let mut uv = [0u16; 2];
        for (slot, x) in uv.iter_mut().zip(pair) {
            match x.as_f64() {
                // Endpoint closure before construction: n <= 4096 so a
                // valid endpoint always fits u16.
                Some(f) if f >= 0.0 && f.fract() == 0.0 && (f as usize) < n => *slot = f as u16,
                _ => {
                    return Err(TraceError::BadGraph {
                        line,
                        msg: format!("edge endpoint out of range 0..{n}"),
                    })
                }
            }
        }
        edges.push((uv[0], uv[1]));
    }
    // Only now is the data allowed to meet Graph::new's asserts.
    Ok(Graph::new(n, edges, labels))
}

fn entry_from_json(v: &Json, line: usize) -> Result<TraceEntry, TraceError> {
    let id = field_u64(v, "id", line)?;
    let offset_us = field_u64(v, "offset_us", line)?;
    let client = field_str(v, "client", line)?;
    let kind = field_str(v, "kind", line)?;
    let graphs = v.get("graphs").as_arr().ok_or(TraceError::Field {
        line,
        field: "graphs",
        msg: "missing or not an array".into(),
    })?;
    let payload = match kind.as_str() {
        "pair" => {
            if graphs.len() != 2 {
                return Err(TraceError::Field {
                    line,
                    field: "graphs",
                    msg: format!("pair entry needs 2 graphs, has {}", graphs.len()),
                });
            }
            Payload::Pair {
                g1: graph_from_json(&graphs[0], line)?,
                g2: graph_from_json(&graphs[1], line)?,
            }
        }
        "topk" => {
            if graphs.len() != 1 {
                return Err(TraceError::Field {
                    line,
                    field: "graphs",
                    msg: format!("topk entry needs 1 graph, has {}", graphs.len()),
                });
            }
            let k = field_usize(v, "k", line)?;
            if k == 0 || k > MAX_TRACE_TOPK {
                return Err(TraceError::Field {
                    line,
                    field: "k",
                    msg: format!("k must be in 1..={MAX_TRACE_TOPK}"),
                });
            }
            Payload::TopK {
                graph: graph_from_json(&graphs[0], line)?,
                corpus: field_str(v, "corpus", line)?,
                k,
                epoch: field_u64_or_zero(v, "epoch", line)?,
                budget: field_u64_or_zero(v, "budget", line)? as usize,
            }
        }
        other => {
            return Err(TraceError::Field {
                line,
                field: "kind",
                msg: format!("unknown kind '{other}'"),
            })
        }
    };
    Ok(TraceEntry { id, offset_us, client, payload })
}

// ---------------------------------------------------------------------------
// Writing.

/// In-memory trace writer (tests, benches, tools). The recorder below
/// shares its line formatting, so a written trace and a recorded trace
/// of the same queries are byte-identical apart from offsets.
#[derive(Debug)]
pub struct TraceWriter {
    text: String,
}

impl TraceWriter {
    /// Start a trace document with its header line.
    pub fn new(header: &TraceHeader) -> TraceWriter {
        let mut text = header_line(header);
        text.push('\n');
        TraceWriter { text }
    }

    /// Append a pair entry.
    pub fn pair(&mut self, client: &str, id: u64, offset_us: u64, g1: &Graph, g2: &Graph) {
        self.text.push_str(&pair_line(client, id, offset_us, g1, g2));
        self.text.push('\n');
    }

    /// Append a top-k entry (epoch 0, exact mode — the pre-cascade
    /// shape tests and benches mostly want).
    pub fn topk(&mut self, client: &str, id: u64, offset_us: u64, g: &Graph, corpus: &str, k: usize) {
        self.topk_at(client, id, offset_us, g, corpus, k, 0, 0);
    }

    /// Append a top-k entry pinned to a corpus epoch, with a cascade
    /// budget (0 = exact).
    #[allow(clippy::too_many_arguments)]
    pub fn topk_at(
        &mut self,
        client: &str,
        id: u64,
        offset_us: u64,
        g: &Graph,
        corpus: &str,
        k: usize,
        epoch: u64,
        budget: usize,
    ) {
        self.text
            .push_str(&topk_line(client, id, offset_us, g, corpus, k, epoch, budget));
        self.text.push('\n');
    }

    /// The document so far.
    pub fn as_text(&self) -> &str {
        &self.text
    }

    /// Write the document to a file.
    pub fn write_to(&self, path: &Path) -> Result<(), TraceError> {
        std::fs::write(path, &self.text)
            .map_err(|e| TraceError::Io(format!("{}: {e}", path.display())))
    }
}

/// Inner recorder state behind the mutex: the sink, the arrival epoch
/// and the failure latch.
#[derive(Debug)]
struct RecorderSink {
    out: BufWriter<File>,
    epoch: Instant,
    failed: bool,
}

/// Live trace recorder, shared by the submit loop (`run_serve`) or the
/// net front stage. One short lock per admitted query; record methods
/// never block on anything but that lock and never panic (the callers
/// sit in PANIC-FREE lint scope), and a write failure latches the
/// recorder off instead of surfacing mid-serve.
#[derive(Debug)]
pub struct TraceRecorder {
    sink: Mutex<RecorderSink>,
}

impl TraceRecorder {
    /// Create the trace file and write its header line. The arrival
    /// epoch starts now; call [`rebase`](TraceRecorder::rebase) when the
    /// serving window actually opens.
    pub fn create(path: &Path, header: &TraceHeader) -> Result<TraceRecorder, TraceError> {
        let file =
            File::create(path).map_err(|e| TraceError::Io(format!("{}: {e}", path.display())))?;
        let mut out = BufWriter::new(file);
        writeln!(out, "{}", header_line(header)).map_err(|e| TraceError::Io(e.to_string()))?;
        Ok(TraceRecorder {
            sink: Mutex::new(RecorderSink { out, epoch: Instant::now(), failed: false }),
        })
    }

    /// Reset the arrival epoch to now. `run_serve` calls this right
    /// after the lane handshake, so recorded offsets measure arrival
    /// into the *serving window*, not time spent synthesizing the
    /// workload or loading engines.
    pub fn rebase(&self) {
        self.sink.lock().unwrap_or_else(|p| p.into_inner()).epoch = Instant::now();
    }

    /// Record an admitted pair query.
    pub fn record_pair(&self, client: &str, id: u64, g1: &Graph, g2: &Graph) {
        self.append(|off| pair_line(client, id, off, g1, g2));
    }

    /// Record an admitted top-k query, pinned to the corpus epoch it
    /// was admitted against (`budget` 0 = exact mode).
    #[allow(clippy::too_many_arguments)]
    pub fn record_topk(
        &self,
        client: &str,
        id: u64,
        g: &Graph,
        corpus: &str,
        k: usize,
        epoch: u64,
        budget: usize,
    ) {
        self.append(|off| topk_line(client, id, off, g, corpus, k, epoch, budget));
    }

    /// Record an already-built pipeline query (the in-process serve
    /// path; the net front stage records payload fields instead, before
    /// its `Query` exists).
    pub fn record_query(&self, client: &str, q: &Query) {
        match &q.payload {
            QueryPayload::Pair { g1, g2 } => self.record_pair(client, q.id, g1, g2),
            QueryPayload::TopK {
                graph,
                corpus,
                k,
                epoch,
                mode,
                ..
            } => {
                let budget = match mode {
                    CascadeMode::Budgeted { budget } => *budget,
                    CascadeMode::Exact => 0,
                };
                self.record_topk(client, q.id, graph, corpus.name(), *k, *epoch, budget)
            }
        }
    }

    /// Flush buffered lines. Returns false if any write failed along the
    /// way (the trace file is incomplete).
    pub fn finish(&self) -> bool {
        let mut sink = self.sink.lock().unwrap_or_else(|p| p.into_inner());
        if sink.out.flush().is_err() {
            sink.failed = true;
        }
        !sink.failed
    }

    fn append(&self, build: impl FnOnce(u64) -> String) {
        let mut sink = self.sink.lock().unwrap_or_else(|p| p.into_inner());
        if sink.failed {
            return;
        }
        let off = sink.epoch.elapsed().as_micros().min(MAX_JSON_INT as u128) as u64;
        let line = build(off);
        if writeln!(sink.out, "{line}").is_err() {
            sink.failed = true;
        }
    }
}

// ---------------------------------------------------------------------------
// Replay outcome dump.

/// Render one query result as a deterministic text line: zero-padded id
/// first (so a lexicographic sort is an id sort), scores as `f32::to_bits`
/// hex (bit-identity is the contract, not approximate equality), and the
/// per-query GCN forward count from the embed-cache telemetry. Two
/// replays of the same trace must produce byte-identical dumps.
pub fn outcome_line(r: &QueryResult) -> String {
    let forwards = r.telemetry.embed_cache.map(|c| c.gcn_forwards()).unwrap_or(0);
    match &r.outcome {
        Outcome::Score(s) => {
            format!("{:020} pair score_bits={:08x} forwards={forwards}", r.id, s.to_bits())
        }
        Outcome::TopK(ranked) => {
            let mut line = format!("{:020} topk forwards={forwards} ranked=", r.id);
            for (i, (cid, score)) in ranked.iter().enumerate() {
                if i > 0 {
                    line.push(',');
                }
                let _ = write!(line, "{cid}:{:08x}", score.to_bits());
            }
            line
        }
        Outcome::Rejected(reason) => format!("{:020} rejected reason={reason}", r.id),
        Outcome::EngineError(_) => format!("{:020} engine_error", r.id),
    }
}

// ---------------------------------------------------------------------------
// Serving bench snapshot (bench-serving-v1).

/// Serialize a finished run's [`Metrics`] into the `bench-serving-v1`
/// snapshot (`BENCH_<n>.json`, CI `bench.json`). `wall_s` is the
/// measured serving window; `provenance` says how the numbers were
/// obtained (`measured-replay: ...` vs `estimated-analytic: ...` — the
/// latter is refused as a regression baseline, see
/// [`bench_is_estimated`]).
pub fn bench_snapshot(m: &Metrics, wall_s: f64, pr: u64, provenance: &str) -> Json {
    let wall = wall_s.max(1e-9);
    let net = m.net.clone().unwrap_or_default();
    let looked_up = m.embed_hits + m.embed_misses;
    let hit_rate = if looked_up == 0 { 0.0 } else { m.embed_hits as f64 / looked_up as f64 };
    // Cascade prune rate across budgeted queries (0 for all-Exact runs).
    let cascade_seen = m.cascade_pruned.mean() + m.cascade_survivors.mean();
    let prune_rate = if cascade_seen == 0.0 { 0.0 } else { m.cascade_pruned.mean() / cascade_seen };
    json::obj(vec![
        ("schema", json::s(BENCH_SCHEMA)),
        ("pr", json::num(pr as f64)),
        ("provenance", json::s(provenance)),
        ("scored", json::num(m.scored as f64)),
        ("topk", json::num(m.topk as f64)),
        ("rejected", json::num(m.rejected as f64)),
        ("engine_errors", json::num(m.engine_errors as f64)),
        ("throughput_qps", json::num(m.scored as f64 / wall)),
        ("wall_s", json::num(wall_s)),
        (
            "latency_ms",
            json::obj(vec![
                ("e2e_p50", json::num(m.latency_us.percentile(50.0) / 1e3)),
                ("e2e_p99", json::num(m.latency_us.percentile(99.0) / 1e3)),
                ("queue_p50", json::num(m.queue_us.percentile(50.0) / 1e3)),
                ("queue_p99", json::num(m.queue_us.percentile(99.0) / 1e3)),
                ("encode_p50", json::num(m.encode_us.percentile(50.0) / 1e3)),
                ("encode_p99", json::num(m.encode_us.percentile(99.0) / 1e3)),
                ("execute_p50", json::num(m.execute_us.percentile(50.0) / 1e3)),
                ("execute_p99", json::num(m.execute_us.percentile(99.0) / 1e3)),
            ]),
        ),
        (
            "embed_cache",
            json::obj(vec![
                ("hit_rate", json::num(hit_rate)),
                ("entries", json::num(m.embed_entries as f64)),
            ]),
        ),
        ("gcn_forwards_per_query", json::num(m.gcn_forwards.mean())),
        ("topk_shards_mean", json::num(m.topk_shards.mean())),
        ("topk_spread_ms_mean", json::num(m.topk_spread_us.mean() / 1e3)),
        (
            "net",
            json::obj(vec![
                ("accepted", json::num(net.accepted as f64)),
                ("throttled", json::num(net.throttled as f64)),
                ("shed_deadline", json::num(net.shed_deadline as f64)),
                ("degraded", json::num(net.degraded as f64)),
            ]),
        ),
        (
            "cascade",
            json::obj(vec![
                ("queries", json::num(m.cascade_pruned.len() as f64)),
                ("prune_rate", json::num(prune_rate)),
                ("survivors_mean", json::num(m.cascade_survivors.mean())),
                ("prune_ms_mean", json::num(m.cascade_prune_us.mean() / 1e3)),
            ]),
        ),
    ])
}

const BENCH_NUM_FIELDS: &[&str] = &[
    "pr",
    "scored",
    "topk",
    "rejected",
    "engine_errors",
    "throughput_qps",
    "wall_s",
    "gcn_forwards_per_query",
    "topk_shards_mean",
    "topk_spread_ms_mean",
];
const BENCH_LATENCY_FIELDS: &[&str] = &[
    "e2e_p50", "e2e_p99", "queue_p50", "queue_p99", "encode_p50", "encode_p99", "execute_p50",
    "execute_p99",
];
const BENCH_CACHE_FIELDS: &[&str] = &["hit_rate", "entries"];
const BENCH_NET_FIELDS: &[&str] = &["accepted", "throttled", "shed_deadline", "degraded"];
const BENCH_CASCADE_FIELDS: &[&str] = &["queries", "prune_rate", "survivors_mean", "prune_ms_mean"];

/// Validate a `bench-serving-v1` snapshot (the `spa-gcn bench-check`
/// subcommand). Returns the first schema violation as a message.
pub fn check_bench(v: &Json) -> Result<(), String> {
    if v.as_obj().is_none() {
        return Err("snapshot must be a JSON object".into());
    }
    match v.get("schema").as_str() {
        Some(s) if s == BENCH_SCHEMA => {}
        Some(other) => return Err(format!("schema is '{other}', expected '{BENCH_SCHEMA}'")),
        None => return Err("missing 'schema' string".into()),
    }
    if v.get("provenance").as_str().is_none() {
        return Err("missing 'provenance' string".into());
    }
    for f in BENCH_NUM_FIELDS {
        if v.get(f).as_f64().is_none() {
            return Err(format!("missing numeric field '{f}'"));
        }
    }
    for (section, fields) in [
        ("latency_ms", BENCH_LATENCY_FIELDS),
        ("embed_cache", BENCH_CACHE_FIELDS),
        ("net", BENCH_NET_FIELDS),
    ] {
        let obj = v.get(section);
        if obj.as_obj().is_none() {
            return Err(format!("missing object field '{section}'"));
        }
        for f in fields {
            if obj.get(f).as_f64().is_none() {
                return Err(format!("missing numeric field '{section}.{f}'"));
            }
        }
    }
    // The cascade section arrived with PR 10; snapshots committed
    // before it (BENCH_9 and earlier) stay valid, but a snapshot that
    // carries the section must carry it whole.
    let cascade = v.get("cascade");
    if cascade.as_obj().is_some() {
        for f in BENCH_CASCADE_FIELDS {
            if cascade.get(f).as_f64().is_none() {
                return Err(format!("missing numeric field 'cascade.{f}'"));
            }
        }
    }
    Ok(())
}

/// True when the snapshot's numbers are analytic estimates, not
/// measurements — such a snapshot documents expectations and must never
/// anchor a regression comparison.
pub fn bench_is_estimated(v: &Json) -> bool {
    v.get("provenance")
        .as_str()
        .is_some_and(|p| p.starts_with("estimated-analytic"))
}

/// The snapshot's p50 end-to-end latency in ms (the soft-regression
/// comparison key).
pub fn bench_p50_e2e(v: &Json) -> Option<f64> {
    v.get("latency_ms").get("e2e_p50").as_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generate::{generate, Family};
    use crate::runtime::{EmbedCacheTelemetry, QueryTelemetry};
    use crate::util::rng::Rng;

    use super::super::query::{RejectReason, StageTiming};

    fn header() -> TraceHeader {
        TraceHeader { seed: 42, corpus_size: 32, topk: 5, n_max: 10, num_labels: 8 }
    }

    fn tiny_graph() -> Graph {
        Graph::new(3, vec![(0, 1), (1, 2)], vec![0, 1, 2])
    }

    fn sample_trace_text() -> String {
        let mut rng = Rng::new(7);
        let mut w = TraceWriter::new(&header());
        let mut off = 0u64;
        for id in 0..20u64 {
            off += 1 + (rng.next_u64() % 5000);
            let g1 = generate(&mut rng, Family::Aids, 10, 8);
            if id % 3 == 0 {
                w.topk("client-a", id, off, &g1, "aids-synth", 1 + (id as usize % 7));
            } else {
                let g2 = generate(&mut rng, Family::Aids, 10, 8);
                w.pair("client-b", id, off, &g1, &g2);
            }
        }
        w.as_text().to_string()
    }

    #[test]
    fn round_trip_random_schedules_and_payloads() {
        // Property over random workloads: parse(write(x)) == x, and
        // re-serializing the parsed entries reproduces the exact bytes
        // (one canonical text form).
        for seed in [1u64, 9, 1234, 0xdead_beef] {
            let mut rng = Rng::new(seed);
            let mut w = TraceWriter::new(&header());
            let mut off = 0u64;
            let mut expect: Vec<(u64, u64, &'static str)> = Vec::new();
            for id in 0..25u64 {
                off += rng.next_u64() % 10_000;
                let g1 = generate(&mut rng, Family::Aids, 10, 8);
                if rng.next_u64() % 2 == 0 {
                    let k = 1 + (rng.next_u64() % 9) as usize;
                    w.topk("c", id, off, &g1, "aids-synth", k);
                    expect.push((id, off, "topk"));
                } else {
                    let g2 = generate(&mut rng, Family::Aids, 10, 8);
                    w.pair("c", id, off, &g1, &g2);
                    expect.push((id, off, "pair"));
                }
            }
            let t = Trace::parse(w.as_text()).unwrap();
            assert_eq!(t.header(), &header());
            assert_eq!(t.len(), expect.len());
            let mut rewritten = TraceWriter::new(t.header());
            for (e, (id, off, kind)) in t.entries().iter().zip(&expect) {
                assert_eq!((e.id(), e.offset_us(), e.kind()), (*id, *off, *kind));
                assert_eq!(e.offset(), Duration::from_micros(*off));
                match &e.payload {
                    Payload::Pair { g1, g2 } => rewritten.pair(e.client(), e.id, e.offset_us, g1, g2),
                    Payload::TopK {
                        graph,
                        corpus,
                        k,
                        epoch,
                        budget,
                    } => {
                        assert_eq!(e.corpus(), Some(corpus.as_str()));
                        rewritten.topk_at(
                            e.client(),
                            e.id,
                            e.offset_us,
                            graph,
                            corpus,
                            *k,
                            *epoch,
                            *budget,
                        )
                    }
                }
            }
            assert_eq!(rewritten.as_text(), w.as_text(), "seed {seed}");
        }
    }

    #[test]
    fn entries_convert_to_queries() {
        let g = tiny_graph();
        let corpus =
            Arc::new(Corpus::build("c1", &[(5, g.clone()), (6, g.clone())], 8, 4).unwrap());
        let mut corpora = BTreeMap::new();
        corpora.insert(corpus.name().to_string(), Arc::clone(&corpus));

        let mut w = TraceWriter::new(&header());
        w.pair("x", 1, 10, &g, &g);
        w.topk("x", 2, 20, &g, "c1", 2);
        w.topk("x", 3, 30, &g, "nope", 2);
        let t = Trace::parse(w.as_text()).unwrap();

        let q = t.entries()[0].to_query(&corpora).unwrap();
        assert_eq!(q.id, 1);
        assert!(matches!(q.payload, QueryPayload::Pair { .. }));
        let q = t.entries()[1].to_query(&corpora).unwrap();
        match &q.payload {
            QueryPayload::TopK { corpus, k, .. } => {
                assert_eq!(corpus.len(), 2);
                assert_eq!(*k, 2);
            }
            other => panic!("expected TopK, got {other:?}"),
        }
        let err = t.entries()[2].to_query(&corpora).unwrap_err();
        assert_eq!(err.code(), "unknown_corpus");
        assert!(err.to_string().contains("nope"), "{err}");
    }

    #[test]
    fn malformed_zoo() {
        let head = header_line(&header());
        let g = tiny_graph();
        let ok_pair = pair_line("c", 1, 5, &g, &g);
        // Each case: (document, expected error code).
        let cases: Vec<(String, &str)> = vec![
            // Header problems.
            (String::new(), "missing_header"),
            ("\n\n".into(), "missing_header"),
            ("{\"schema\":\"spa-gcn-trace-v2\"}".into(), "bad_schema"),
            ("{\"seed\":1}".into(), "bad_schema"),
            ("[1,2]".into(), "parse"),
            ("{\"schema\":\"spa-gcn-trace-v1\",\"corpus_size\":0,\"topk\":1,\"n_max\":8}".into(), "field"),
            // Truncated / garbage entry lines.
            (format!("{head}\n{}", &ok_pair[..ok_pair.len() / 2]), "parse"),
            (format!("{head}\n{ok_pair} trailing"), "parse"),
            (format!("{head}\n42"), "parse"),
            // Field problems.
            (format!("{head}\n{}", ok_pair.replace("\"id\":1", "\"id\":-3")), "field"),
            (format!("{head}\n{}", ok_pair.replace("\"id\":1", "\"id\":1.5")), "field"),
            (format!("{head}\n{}", ok_pair.replace("\"offset_us\":5", "\"offset_us\":\"x\"")), "field"),
            (format!("{head}\n{}", ok_pair.replace("\"kind\":\"pair\"", "\"kind\":\"zap\"")), "field"),
            (format!("{head}\n{}", ok_pair.replace("\"client\":\"c\"", "\"client\":9")), "field"),
            (
                format!(
                    "{head}\n{}",
                    topk_line("c", 1, 5, &g, "x", 3, 0, 0).replace("\"k\":3", "\"k\":0")
                ),
                "field",
            ),
            // Present-but-mistyped epoch/budget still fail (only
            // *absent* fields default to 0).
            (
                format!(
                    "{head}\n{}",
                    topk_line("c", 1, 5, &g, "x", 3, 0, 0).replace("\"epoch\":0", "\"epoch\":-2")
                ),
                "field",
            ),
            (
                format!(
                    "{head}\n{}",
                    topk_line("c", 1, 5, &g, "x", 3, 0, 0).replace("\"budget\":0", "\"budget\":\"z\"")
                ),
                "field",
            ),
            // Graph problems.
            (format!("{head}\n{}", ok_pair.replace("\"n\":3", "\"n\":99")), "bad_graph"),
            (format!("{head}\n{}", ok_pair.replace("\"n\":3", "\"n\":100000")), "bad_graph"),
            (format!("{head}\n{}", ok_pair.replace("[0,1]", "[0,7]")), "bad_graph"),
            (format!("{head}\n{}", ok_pair.replace("[0,1]", "[0,-1]")), "bad_graph"),
            (format!("{head}\n{}", ok_pair.replace("[0,1]", "[0]")), "bad_graph"),
            (format!("{head}\n{}", ok_pair.replace("[0,1,2]", "[0,1,70000]")), "bad_graph"),
        ];
        for (doc, code) in cases {
            match Trace::parse(&doc) {
                Err(e) => assert_eq!(e.code(), code, "doc {doc:?} gave {e}"),
                Ok(t) => panic!("doc {doc:?} parsed: {} entries", t.len()),
            }
        }
    }

    #[test]
    fn oversized_line_is_rejected() {
        let doc = format!("{}\n{{\"pad\":\"{}\"}}", header_line(&header()), "x".repeat(MAX_TRACE_LINE));
        let err = Trace::parse(&doc).unwrap_err();
        assert_eq!(err.code(), "line_too_long");
    }

    #[test]
    fn truncation_never_panics() {
        // Hostile-input guarantee: any byte-level truncation of a valid
        // trace either parses (fewer entries) or errors — never panics.
        let text = sample_trace_text();
        let full = Trace::parse(&text).unwrap().len();
        for cut in (0..text.len()).step_by(97) {
            if !text.is_char_boundary(cut) {
                continue;
            }
            match Trace::parse(&text[..cut]) {
                Ok(t) => assert!(t.len() <= full),
                Err(e) => assert!(!e.code().is_empty()),
            }
        }
    }

    #[test]
    fn recorder_writes_a_readable_trace() {
        let path = std::env::temp_dir()
            .join(format!("spa-gcn-trace-test-{}-{}", std::process::id(), line!()));
        let rec = TraceRecorder::create(&path, &header()).unwrap();
        rec.rebase();
        let g = tiny_graph();
        rec.record_query("cli", &Query::new(7, g.clone(), g.clone()));
        let corpus = Arc::new(Corpus::build("c9", &[(1, g.clone())], 8, 4).unwrap());
        rec.record_query("cli", &Query::topk(8, g.clone(), corpus, 4));
        rec.record_pair("net", 9, &g, &g);
        rec.record_topk("net", 10, &g, "c9", 2, 4, 64);
        assert!(rec.finish());
        let t = Trace::read(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(t.header(), &header());
        assert_eq!(t.len(), 4);
        assert_eq!(
            t.entries().iter().map(|e| (e.id(), e.kind())).collect::<Vec<_>>(),
            vec![(7, "pair"), (8, "topk"), (9, "pair"), (10, "topk")]
        );
        assert_eq!(t.entries()[1].corpus(), Some("c9"));
        assert_eq!(t.entries()[2].client(), "net");
        // The recorder preserves epoch + budget per top-k entry.
        assert_eq!((t.entries()[1].epoch(), t.entries()[1].budget()), (0, 0));
        assert_eq!((t.entries()[3].epoch(), t.entries()[3].budget()), (4, 64));
        // Offsets are monotone (same clock, sequential records).
        let offs: Vec<_> = t.entries().iter().map(TraceEntry::offset_us).collect();
        let mut sorted = offs.clone();
        sorted.sort_unstable();
        assert_eq!(offs, sorted);
    }

    #[test]
    fn read_rejects_missing_file() {
        let err = Trace::read(Path::new("/nonexistent/spa-gcn.trace")).unwrap_err();
        assert_eq!(err.code(), "io");
    }

    fn fake_result(id: u64, outcome: Outcome, forwards: u64) -> QueryResult {
        QueryResult {
            id,
            outcome,
            latency_us: 1000.0,
            batch_size: 1,
            stage: StageTiming { queue_us: 100.0, encode_us: 50.0, execute_us: 800.0 },
            telemetry: QueryTelemetry {
                embed_cache: Some(EmbedCacheTelemetry { hits: 1, misses: forwards, entries: 3 }),
                ..QueryTelemetry::default()
            },
            engine: None,
            sharding: None,
            cascade: None,
        }
    }

    #[test]
    fn epoch_and_budget_round_trip_and_default_for_old_traces() {
        let g = tiny_graph();
        let corpus = Arc::new(
            Corpus::build("c1", &[(5, g.clone()), (6, g.clone())], 8, 4)
                .unwrap()
                .with_epoch(3),
        );
        let mut corpora = BTreeMap::new();
        corpora.insert(corpus.name().to_string(), Arc::clone(&corpus));

        let mut w = TraceWriter::new(&header());
        w.topk_at("x", 1, 10, &g, "c1", 2, 3, 128);
        w.topk("x", 2, 20, &g, "c1", 2);
        let t = Trace::parse(w.as_text()).unwrap();
        assert_eq!((t.entries()[0].epoch(), t.entries()[0].budget()), (3, 128));
        assert_eq!((t.entries()[1].epoch(), t.entries()[1].budget()), (0, 0));

        // budget > 0 rebuilds a budgeted query; the epoch is pinned
        // from the replay-resolved corpus, not the recorded number.
        let q = t.entries()[0].to_query(&corpora).unwrap();
        match &q.payload {
            QueryPayload::TopK { epoch, mode, .. } => {
                assert_eq!(*epoch, 3);
                assert_eq!(*mode, CascadeMode::Budgeted { budget: 128 });
            }
            other => panic!("expected TopK, got {other:?}"),
        }
        let q = t.entries()[1].to_query(&corpora).unwrap();
        match &q.payload {
            QueryPayload::TopK { mode, .. } => assert_eq!(*mode, CascadeMode::Exact),
            other => panic!("expected TopK, got {other:?}"),
        }

        // A pre-epoch trace line (no epoch/budget keys) still parses,
        // defaulting both to 0.
        let legacy = topk_line("c", 9, 5, &g, "c1", 2, 0, 0)
            .replace("\"budget\":0,", "")
            .replace("\"epoch\":0,", "");
        let doc = format!("{}\n{legacy}", header_line(&header()));
        let t = Trace::parse(&doc).unwrap();
        assert_eq!((t.entries()[0].epoch(), t.entries()[0].budget()), (0, 0));
    }

    #[test]
    fn outcome_lines_are_deterministic_and_sortable() {
        let a = outcome_line(&fake_result(3, Outcome::Score(0.25), 2));
        assert_eq!(a, outcome_line(&fake_result(3, Outcome::Score(0.25), 2)));
        assert!(a.contains(&format!("score_bits={:08x}", 0.25f32.to_bits())), "{a}");
        assert!(a.contains("forwards=2"), "{a}");
        let b = outcome_line(&fake_result(10, Outcome::TopK(vec![(4, 0.5), (1, 0.125)]), 1));
        assert!(b.contains(&format!("4:{:08x},1:{:08x}", 0.5f32.to_bits(), 0.125f32.to_bits())), "{b}");
        let c = outcome_line(&fake_result(2, Outcome::Rejected(RejectReason::ShuttingDown), 0));
        assert!(c.contains("rejected"), "{c}");
        // Zero-padded ids: lexicographic sort == numeric id sort.
        let mut lines = vec![b.clone(), a.clone(), c.clone()];
        lines.sort();
        assert_eq!(lines, vec![c, a, b]);
    }

    #[test]
    fn bench_snapshot_passes_its_own_check() {
        let mut m = Metrics::new();
        m.record(&fake_result(0, Outcome::Score(0.5), 2));
        m.record(&fake_result(1, Outcome::TopK(vec![(2, 0.75)]), 1));
        m.record(&fake_result(2, Outcome::Rejected(RejectReason::ShuttingDown), 0));
        let snap = bench_snapshot(&m, 1.5, 9, "measured-replay: test");
        check_bench(&snap).unwrap();
        assert!(!bench_is_estimated(&snap));
        assert!(bench_p50_e2e(&snap).unwrap() > 0.0);
        assert_eq!(snap.get("scored").as_f64(), Some(2.0));
        assert_eq!(snap.get("rejected").as_f64(), Some(1.0));
        // Round-trips through the JSON codec.
        let parsed = json::parse(&snap.to_string()).unwrap();
        check_bench(&parsed).unwrap();
        assert_eq!(parsed, snap);
    }

    #[test]
    fn bench_check_rejects_drift() {
        let m = Metrics::new();
        let good = bench_snapshot(&m, 1.0, 9, "measured-replay: test");
        let text = good.to_string();
        for (mutation, needle) in [
            (text.replace("bench-serving-v1", "bench-serving-v2"), "schema"),
            (text.replace("\"throughput_qps\"", "\"qps\""), "throughput_qps"),
            (text.replace("\"e2e_p50\"", "\"p50\""), "e2e_p50"),
            (text.replace("\"hit_rate\"", "\"hits\""), "hit_rate"),
            (text.replace("\"shed_deadline\"", "\"shed\""), "shed_deadline"),
            (text.replace("\"prune_rate\"", "\"rate\""), "prune_rate"),
            (text.replace("\"provenance\":\"measured-replay: test\",", ""), "provenance"),
        ] {
            let v = json::parse(&mutation).unwrap();
            let err = check_bench(&v).unwrap_err();
            assert!(err.contains(needle), "mutation {mutation:?} gave {err}");
        }
        assert!(check_bench(&Json::Num(3.0)).is_err());
    }

    #[test]
    fn estimated_snapshots_are_flagged() {
        let m = Metrics::new();
        let est = bench_snapshot(&m, 1.0, 9, "estimated-analytic: authoring container has no rustc");
        check_bench(&est).unwrap();
        assert!(bench_is_estimated(&est));
    }
}
