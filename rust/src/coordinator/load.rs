//! Open-loop load generation: Poisson arrivals at a target rate, the
//! standard serving-systems methodology for latency-under-load curves
//! (closed-loop flooding — what `serve_workload` does — measures peak
//! throughput but inflates tail latency with queueing delay).

use std::time::{Duration, Instant};

use crate::util::rng::Rng;

/// Poisson arrival schedule: exponential inter-arrival gaps at `rate_qps`.
pub fn poisson_schedule(rng: &mut Rng, rate_qps: f64, count: usize) -> Vec<Duration> {
    assert!(rate_qps > 0.0);
    let mut at = 0.0f64;
    (0..count)
        .map(|_| {
            let u = rng.f64().max(1e-12);
            at += -u.ln() / rate_qps; // Exp(rate) gap
            Duration::from_secs_f64(at)
        })
        .collect()
}

/// Busy-wait-free pacer: sleeps until each scheduled offset from `start`.
#[derive(Debug)]
pub struct Pacer {
    start: Instant,
}

impl Pacer {
    pub fn new() -> Self {
        Pacer {
            start: Instant::now(),
        }
    }

    /// Wait until `offset` past the pacer's start; returns the lateness
    /// (how far behind schedule we are), useful to detect overload.
    pub fn wait_until(&self, offset: Duration) -> Duration {
        let target = self.start + offset;
        let now = Instant::now();
        if let Some(remaining) = target.checked_duration_since(now) {
            std::thread::sleep(remaining);
            Duration::ZERO
        } else {
            now.duration_since(target)
        }
    }
}

impl Default for Pacer {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_mean_rate_is_correct() {
        let mut rng = Rng::new(101);
        let rate = 1000.0;
        let n = 5000;
        let sched = poisson_schedule(&mut rng, rate, n);
        let total = sched.last().unwrap().as_secs_f64();
        let observed = n as f64 / total;
        assert!(
            (observed - rate).abs() / rate < 0.1,
            "observed rate {observed} vs target {rate}"
        );
        // strictly increasing
        for w in sched.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn exponential_gaps_have_cv_about_one() {
        let mut rng = Rng::new(102);
        let sched = poisson_schedule(&mut rng, 500.0, 4000);
        let gaps: Vec<f64> = sched
            .windows(2)
            .map(|w| (w[1] - w[0]).as_secs_f64())
            .collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>() / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.15, "cv {cv} should be ~1 for Poisson");
    }

    #[test]
    fn pacer_reports_lateness_when_behind() {
        let p = Pacer::new();
        std::thread::sleep(Duration::from_millis(5));
        let late = p.wait_until(Duration::from_millis(1));
        assert!(late >= Duration::from_millis(3));
        let on_time = p.wait_until(Duration::from_millis(20));
        assert_eq!(on_time, Duration::ZERO);
    }
}
