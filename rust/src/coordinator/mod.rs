//! L3 serving coordinator: admission router, dynamic batcher, worker
//! pool, metrics. The paper's system contribution viewed as a serving
//! problem: many small graph-pair queries, batched to amortize per-launch
//! overheads (Fig. 11), replicated across workers (§5.4.3).
pub mod batcher;
pub mod load;
pub mod metrics;
pub mod query;
pub mod router;
pub mod server;
