//! L3 serving coordinator: a staged dataflow pipeline (admission ->
//! batcher -> encoder -> executor -> responder) joined by named bounded
//! channels — the paper's FIFO-connected stage architecture recovered in
//! software (DESIGN.md §4). Many small graph-pair queries are batched to
//! amortize per-launch overheads (Fig. 11), fanned out across worker
//! lanes (§5.4.3), and encoded concurrently with engine execution.
pub mod batcher;
pub mod channel;
pub mod corpus;
pub mod corpus_store;
pub mod load;
pub mod metrics;
pub mod pipeline;
pub mod query;
pub mod router;
pub mod server;
pub mod trace;
