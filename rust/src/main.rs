//! spa-gcn CLI: the L3 leader entrypoint.
//!
//! Subcommands:
//!   report <name>   regenerate a paper table/figure (table3, table4,
//!                   table5, table6, fig10, fig11, replication, sparsity,
//!                   crosscheck, all)
//!   serve           run the serving coordinator on a synthetic workload
//!   replay          re-drive a recorded trace deterministically
//!   bench-check     validate a bench-serving-v1 snapshot (CI gate)
//!   lint            run the in-repo architecture linter over the tree
//!   gen             synthesize a graph database and print its statistics
//!   ged             exact-GED demo on tiny graphs
//!
//! Flags are simple `--key value` pairs (no external CLI crate offline).

use std::collections::HashMap;
use std::path::PathBuf;

use spa_gcn::coordinator::server::{run_replay, serve_paced, serve_workload, ServeConfig};
use spa_gcn::coordinator::trace::{
    bench_is_estimated, bench_p50_e2e, bench_snapshot, check_bench, Trace, BENCH_SCHEMA,
};
use spa_gcn::ged::{exact_ged, ged_similarity};
use spa_gcn::graph::dataset::GraphDb;
use spa_gcn::graph::generate::{generate, Family};
use spa_gcn::net::client::{run_load, LoadConfig};
use spa_gcn::net::server::serve_listen;
use spa_gcn::net::NetConfig;
use spa_gcn::nn::kernels::{set_kernel_path, KernelPath};
use spa_gcn::report::tables::{self, Context};
use spa_gcn::runtime::EngineKind;
use spa_gcn::util::json::arr;
use spa_gcn::util::rng::Rng;

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut flags = HashMap::new();
    let mut iter = std::env::args().skip(1).peekable();
    while let Some(a) = iter.next() {
        if let Some(key) = a.strip_prefix("--") {
            let val = if iter.peek().map(|v| !v.starts_with("--")).unwrap_or(false) {
                iter.next().unwrap()
            } else {
                "true".to_string()
            };
            flags.insert(key.to_string(), val);
        } else {
            positional.push(a);
        }
    }
    Args { positional, flags }
}

impl Args {
    fn flag(&self, key: &str, default: &str) -> String {
        self.flags
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }
    fn usize(&self, key: &str, default: usize) -> usize {
        self.flag(key, &default.to_string())
            .parse()
            .unwrap_or(default)
    }
    fn bool(&self, key: &str) -> bool {
        self.flags.get(key).map(|v| v == "true").unwrap_or(false)
    }
    fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

fn usage() -> ! {
    // The valid --engine values come straight from the EngineKind enum,
    // so the help text can never drift from what parses.
    let kinds: Vec<&str> = EngineKind::ALL.iter().map(EngineKind::as_str).collect();
    eprintln!(
        "usage: spa-gcn <command>\n\
         \n  report <table3|table4|table5|table6|fig10|fig11|replication|sparsity|accuracy|energy|fifo|crosscheck|all>\n\
         \t[--queries N] [--no-pjrt] [--artifacts DIR] [--json OUT.json]\n\
         \n  serve [--queries N] [--engine KINDS] [--workers K] [--batch-max B]\n\
         \t[--batch-timeout-us T] [--pipeline-depth D] [--rate QPS] [--artifacts DIR]\n\
         \t[--corpus N] [--topk K] [--budget B] [--kernels scalar|lanes] [--record PATH]\n\
         \t(KINDS: comma-separated engine kinds from {{{}}};\n\
         \t a list runs heterogeneous lanes, e.g. --engine native,sim;\n\
         \t --pipeline-depth 0 = sequential encode+execute baseline;\n\
         \t --rate runs open-loop Poisson pacing instead of closed-loop flood;\n\
         \t --corpus N switches to one-vs-many search: each query ranks an\n\
         \t N-graph corpus through the embedding cache and returns its --topk best;\n\
         \t --budget B > 0 runs the coarse-to-fine cascade: cheap signals\n\
         \t prune each query to B candidates before NTN+FCN scoring;\n\
         \t --listen ADDR serves the wire protocol instead of a synthetic\n\
         \t workload — press Enter (or close stdin) to stop and print metrics;\n\
         \t front-door knobs: [--net-conn-cap N] [--net-admit-cap N]\n\
         \t [--net-refill QPS] [--net-burst B] [--net-deadline-ms T];\n\
         \t --record PATH logs every admitted query with its arrival\n\
         \t offset as a spa-gcn-trace-v1 line-delimited JSON trace)\n\
         \n  replay --trace PATH [--speed X | --as-fast-as-possible]\n\
         \t[--engine KINDS] [--workers K] [--artifacts DIR]\n\
         \t[--out DUMP.txt] [--bench-out BENCH.json] [--selfcheck]\n\
         \t(re-drive a recorded trace through the serving pipeline on the\n\
         \t recorded arrival schedule — --speed 2 halves the gaps,\n\
         \t --as-fast-as-possible floods closed-loop; --out writes the\n\
         \t sorted outcome dump (byte-identical across replays of the\n\
         \t same trace), --bench-out writes a bench-serving-v1 snapshot,\n\
         \t --selfcheck replays twice in-process and exits 1 on any\n\
         \t outcome mismatch — the CI determinism gate, DESIGN.md S19)\n\
         \n  bench-check FILE [--baseline BASE.json]\n\
         \t(validate FILE against the bench-serving-v1 schema, exit 1 on\n\
         \t drift; with --baseline, emit a soft ::warning:: annotation —\n\
         \t never a failure — when p50 e2e regresses >20%, refusing\n\
         \t provenance=estimated-analytic baselines outright)\n\
         \n  load --connect ADDR [--clients N] [--rate QPS] [--queries N]\n\
         \t[--topk K] [--budget B] [--upserts N] [--seed S]\n\
         \t(drive a `serve --listen` front door; --upserts N interleaves\n\
         \t live corpus mutations, --budget B asks for cascade retrieval)\n\
         \n  lint [--json OUT.json] [--root DIR]\n\
         \t(check the repo's architecture invariants — layering DAG,\n\
         \t determinism, panic-freedom, lock order; see DESIGN.md S18.\n\
         \t Exit 1 on any unwaived finding; --json writes the full\n\
         \t machine-readable report)\n\
         \n  gen [--family aids|linux|imdb] [--count N]\n\
         \n  ged [--nodes N] [--pairs P]",
        kinds.join(", ")
    );
    std::process::exit(2);
}

fn main() -> anyhow::Result<()> {
    let args = parse_args();
    let Some(cmd) = args.positional.first() else {
        usage()
    };
    match cmd.as_str() {
        "report" => cmd_report(&args),
        "serve" => cmd_serve(&args),
        "replay" => cmd_replay(&args),
        "bench-check" => cmd_bench_check(&args),
        "load" => cmd_load(&args),
        "lint" => cmd_lint(&args),
        "gen" => cmd_gen(&args),
        "ged" => cmd_ged(&args),
        _ => usage(),
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.flag("artifacts", "artifacts"))
}

fn cmd_report(args: &Args) -> anyhow::Result<()> {
    let name = args.positional.get(1).map(String::as_str).unwrap_or("all");
    let queries = args.usize("queries", 64);
    let with_pjrt = !args.bool("no-pjrt");
    let ctx = Context::load(&artifacts_dir(args))?;
    let mut tables_out = Vec::new();
    let mut run = |t: spa_gcn::report::Table| {
        println!("{}", t.render());
        tables_out.push(t);
    };
    match name {
        "table3" => run(tables::table3()),
        "table4" => run(tables::table4(&ctx, queries)),
        "table5" => run(tables::table5(&ctx, queries)),
        "table6" => run(tables::table6(&ctx, queries, with_pjrt)),
        "fig10" => run(tables::fig10(&ctx)),
        "fig11" => run(tables::fig11(&ctx, queries, with_pjrt)),
        "replication" => run(tables::replication(&ctx, queries)),
        "sparsity" => run(tables::sparsity(&ctx, queries)),
        "crosscheck" => run(tables::crosscheck(&ctx)),
        "accuracy" => run(tables::accuracy(&ctx, queries.min(64))),
        "energy" => run(tables::energy(&ctx, queries)),
        "fifo" => run(tables::fifo_ablation(&ctx, queries.min(32))),
        "all" => {
            run(tables::table3());
            run(tables::table4(&ctx, queries));
            run(tables::table5(&ctx, queries));
            run(tables::table6(&ctx, queries, with_pjrt));
            run(tables::fig10(&ctx));
            run(tables::fig11(&ctx, queries, with_pjrt));
            run(tables::replication(&ctx, queries));
            run(tables::sparsity(&ctx, queries));
            run(tables::energy(&ctx, queries));
            run(tables::fifo_ablation(&ctx, queries.min(32)));
            run(tables::accuracy(&ctx, queries.min(48)));
        }
        _ => usage(),
    }
    if let Some(path) = args.flags.get("json") {
        let doc = arr(tables_out.iter().map(|t| t.to_json()).collect());
        std::fs::write(path, doc.to_string())?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// Kernel-path override (DESIGN.md S16): the compiled default comes
/// from the `simd` feature; `--kernels scalar` is the operational
/// escape hatch, `--kernels lanes` forces the vectorized path on a
/// scalar-default build. Must run before any engine is constructed.
fn apply_kernels_flag(args: &Args) -> anyhow::Result<()> {
    match args.flag("kernels", KernelPath::compiled_default().as_str()).as_str() {
        "scalar" => set_kernel_path(KernelPath::Scalar),
        "lanes" => set_kernel_path(KernelPath::Lanes),
        other => anyhow::bail!("--kernels must be scalar or lanes, got {other}"),
    }
    Ok(())
}

fn serve_config(args: &Args) -> anyhow::Result<ServeConfig> {
    Ok(ServeConfig {
        artifacts_dir: artifacts_dir(args),
        engines: EngineKind::parse_list(&args.flag("engine", "xla"))?,
        queries: args.usize("queries", 1000),
        workers: args.usize("workers", 1),
        batch_max: args.usize("batch-max", 64),
        batch_timeout_us: args.usize("batch-timeout-us", 200) as u64,
        seed: args.usize("seed", 42) as u64,
        pipeline_depth: args.usize("pipeline-depth", 2),
        corpus_size: args.usize("corpus", 0),
        topk: args.usize("topk", 10),
        budget: args.usize("budget", 0),
        record: args.flags.get("record").map(PathBuf::from),
    })
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    apply_kernels_flag(args)?;
    let cfg = serve_config(args)?;
    if let Some(listen) = args.flags.get("listen") {
        // Front-door knobs stay a net-layer concern: ServeConfig is a
        // coordinator type and must not carry a NetConfig (ARCH-DAG).
        let net_defaults = NetConfig::default();
        let ncfg = NetConfig {
            conn_cap: args.usize("net-conn-cap", net_defaults.conn_cap),
            admit_cap: args.usize("net-admit-cap", net_defaults.admit_cap),
            refill_per_s: args.f64("net-refill", net_defaults.refill_per_s),
            burst: args.f64("net-burst", net_defaults.burst),
            deadline_ms: args.usize("net-deadline-ms", net_defaults.deadline_ms as usize) as u64,
            ..net_defaults
        };
        let server = serve_listen(&cfg, ncfg, listen)?;
        let ready = server.wait_ready();
        eprintln!(
            "spa-gcn front door listening on {} ({ready} lane(s) ready); press Enter to stop",
            server.addr()
        );
        let mut line = String::new();
        let _ = std::io::stdin().read_line(&mut line);
        let metrics = server.finish();
        let report = metrics.render_table(&format!(
            "serve-listen: engine={} workers={} addr={}",
            args.flag("engine", "xla"),
            args.usize("workers", 1),
            listen
        ));
        println!("{}", report.render());
        return Ok(());
    }
    let report = match args.flags.get("rate") {
        Some(rate) => {
            let rate: f64 = rate
                .parse()
                .ok()
                .filter(|r| *r > 0.0)
                .ok_or_else(|| anyhow::anyhow!("--rate must be a positive number (queries/s)"))?;
            serve_paced(&cfg, rate)?
        }
        None => serve_workload(&cfg)?,
    };
    println!("{}", report.render());
    Ok(())
}

fn cmd_replay(args: &Args) -> anyhow::Result<()> {
    apply_kernels_flag(args)?;
    let trace_path = args
        .flags
        .get("trace")
        .ok_or_else(|| anyhow::anyhow!("replay needs --trace PATH (record one with serve --record)"))?;
    let trace = Trace::read(std::path::Path::new(trace_path))
        .map_err(|e| anyhow::anyhow!("reading trace {trace_path}: {e}"))?;
    anyhow::ensure!(!trace.is_empty(), "trace {trace_path} has no entries");
    let speed = if args.bool("as-fast-as-possible") {
        None
    } else {
        Some(args.f64("speed", 1.0))
    };
    let cfg = ServeConfig {
        record: None, // replaying a recording of a replay is a loop, not a workload
        ..serve_config(args)?
    };
    let (metrics, wall_s, dump) = run_replay(&cfg, &trace, speed)?;
    if args.bool("selfcheck") {
        // The CI determinism gate, in-process: same trace, second
        // replay, byte-identical outcome dump or exit 1.
        let (_, _, dump2) = run_replay(&cfg, &trace, speed)?;
        if dump != dump2 {
            eprintln!(
                "replay selfcheck FAILED: two replays of {trace_path} produced different outcome dumps"
            );
            std::process::exit(1);
        }
        eprintln!(
            "replay selfcheck: {} outcomes bit-identical across two replays",
            trace.len()
        );
    }
    if let Some(out) = args.flags.get("out") {
        std::fs::write(out, &dump)?;
        eprintln!("wrote {out}");
    }
    if let Some(out) = args.flags.get("bench-out") {
        let snap = bench_snapshot(
            &metrics,
            wall_s,
            args.usize("pr", 9) as u64,
            "measured: spa-gcn replay",
        );
        std::fs::write(out, snap.to_string() + "\n")?;
        eprintln!("wrote {out}");
    }
    let report = metrics.render_table(&format!(
        "replay: trace={} entries={} engine={} speed={}",
        trace_path,
        trace.len(),
        args.flag("engine", "xla"),
        match speed {
            Some(s) => format!("{s}x"),
            None => "flood".into(),
        }
    ));
    println!("{}", report.render());
    Ok(())
}

fn cmd_bench_check(args: &Args) -> anyhow::Result<()> {
    let Some(path) = args.positional.get(1) else {
        usage()
    };
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading snapshot {path}: {e}"))?;
    let doc = spa_gcn::util::json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing snapshot {path}: {e}"))?;
    if let Err(msg) = check_bench(&doc) {
        eprintln!("bench-check: {path}: schema drift vs {BENCH_SCHEMA}: {msg}");
        std::process::exit(1);
    }
    if let Some(base_path) = args.flags.get("baseline") {
        let base_text = std::fs::read_to_string(base_path)
            .map_err(|e| anyhow::anyhow!("reading baseline {base_path}: {e}"))?;
        let base = spa_gcn::util::json::parse(&base_text)
            .map_err(|e| anyhow::anyhow!("parsing baseline {base_path}: {e}"))?;
        if let Err(msg) = check_bench(&base) {
            eprintln!("bench-check: baseline {base_path}: schema drift vs {BENCH_SCHEMA}: {msg}");
            std::process::exit(1);
        }
        if bench_is_estimated(&base) {
            // Estimated snapshots carry analytic guesses, not measured
            // latencies — comparing against them would manufacture
            // regressions (or mask real ones). Refuse, loudly, softly.
            println!(
                "bench-check: baseline {base_path} has provenance=estimated-analytic; \
                 refusing to use it as a regression baseline (no comparison made)"
            );
        } else {
            match (bench_p50_e2e(&doc), bench_p50_e2e(&base)) {
                (Some(cand), Some(base_p50)) if base_p50 > 0.0 && cand > base_p50 * 1.2 => {
                    // GitHub annotation syntax: a soft warning on the
                    // run, never a job failure (ISSUE 9 satellite 2).
                    println!(
                        "::warning title=serving p50 regression::p50 e2e {cand:.3} ms is \
                         {:.0}% over baseline {base_p50:.3} ms ({base_path})",
                        (cand / base_p50 - 1.0) * 100.0
                    );
                }
                _ => {}
            }
        }
    }
    println!("bench-check: {path}: ok ({BENCH_SCHEMA})");
    Ok(())
}

fn cmd_load(args: &Args) -> anyhow::Result<()> {
    let defaults = LoadConfig::default();
    let cfg = LoadConfig {
        connect: args.flag("connect", &defaults.connect),
        clients: args.usize("clients", defaults.clients),
        rate_qps: args.f64("rate", defaults.rate_qps),
        queries: args.usize("queries", defaults.queries),
        seed: args.usize("seed", defaults.seed as usize) as u64,
        topk: args.usize("topk", defaults.topk),
        budget: args.usize("budget", defaults.budget),
        upserts: args.usize("upserts", defaults.upserts),
    };
    let report = run_load(&cfg)?;
    println!("{}", report.render());
    Ok(())
}

fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    let root = PathBuf::from(args.flag("root", "."));
    let outcome = spa_gcn::analysis::run_lint(&root)?;
    print!("{}", spa_gcn::analysis::report::render_text(&outcome));
    if let Some(path) = args.flags.get("json") {
        std::fs::write(path, spa_gcn::analysis::report::to_json(&outcome).to_string())?;
        eprintln!("wrote {path}");
    }
    if !outcome.ok() {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_gen(args: &Args) -> anyhow::Result<()> {
    let family = match args.flag("family", "aids").as_str() {
        "aids" => Family::Aids,
        "linux" => Family::Linux,
        "imdb" => Family::Imdb,
        other => anyhow::bail!("unknown family {other}"),
    };
    let count = args.usize("count", 1000);
    let mut rng = Rng::new(args.usize("seed", 1) as u64);
    let db = GraphDb::synthesize(&mut rng, family, count, 32, 29);
    let (n, m) = db.stats();
    println!(
        "family={:?} graphs={} mean_nodes={:.1} mean_edges={:.1}",
        family, count, n, m
    );
    println!("(paper AIDS reference: 25.6 nodes, 27.6 edges)");
    Ok(())
}

fn cmd_ged(args: &Args) -> anyhow::Result<()> {
    let n = args.usize("nodes", 7);
    let pairs = args.usize("pairs", 5);
    let mut rng = Rng::new(9);
    for i in 0..pairs {
        let g1 = generate(&mut rng, Family::ErdosRenyi { n, p_millis: 300 }, 32, 8);
        let g2 = generate(&mut rng, Family::ErdosRenyi { n, p_millis: 300 }, 32, 8);
        match exact_ged(&g1, &g2, 2_000_000) {
            Some(d) => println!(
                "pair {i}: GED = {d}, normalized similarity = {:.4}",
                ged_similarity(d, g1.num_nodes(), g2.num_nodes())
            ),
            None => println!("pair {i}: A* exceeded state limit"),
        }
    }
    Ok(())
}
