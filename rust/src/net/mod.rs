//! Network front door: a std-only wire protocol with first-class
//! overload discipline (DESIGN.md S17).
//!
//! The serving pipeline was library-only — queries entered via
//! in-process [`Pipeline::submit`] — so nothing about the "heavy
//! traffic" north star was testable over a real socket. This subsystem
//! adds the missing edge without adding a dependency:
//!
//! * [`wire`] — length-prefixed frames over `std::net` carrying a
//!   versioned hand-rolled JSON body (`util::json`): Pair and
//!   TopK-by-corpus-id requests; typed score / top-k / retry-after /
//!   error responses.
//! * [`server`] — a blocking connection-per-thread accept loop bounded
//!   by a connection cap, routing every request through [`admission`]
//!   (never directly into the batcher — the NET-SINGLE-SUBMITTER
//!   lint rule, DESIGN.md S18).
//! * [`admission`] — per-client token buckets that answer
//!   `retry_after_ms` instead of queueing; a bounded admission queue
//!   with the [`SendPolicy::DropNewest`] shed policy; deadline shedding
//!   at dequeue; and a degraded mode driven by a queue-depth EWMA that
//!   shrinks top-k depth and falls back to the `ged::heuristics`
//!   bound-based scorer for pair queries under pressure.
//! * [`client`] — a loopback client (`spa-gcn load --connect`) reusing
//!   `coordinator::load` pacing, so overload behavior is drivable
//!   end-to-end in tests and benches without external tools.
//!
//! The overload taxonomy, outermost first: the connection cap refuses
//! sockets (and idle connections time out, so silent sockets can't pin
//! the cap), the token buckets refuse clients, the admission queue
//! refuses bursts (DropNewest), the deadline sheds stale queued work,
//! the front stage rejects graphs outside the model's shapes before
//! any lane runs, and the degraded mode cheapens what's left. Each
//! layer answers with a typed response, and each is counted in
//! [`NetSnapshot`].
//!
//! [`Pipeline::submit`]: crate::coordinator::pipeline::Pipeline::submit
//! [`SendPolicy::DropNewest`]: crate::coordinator::channel::SendPolicy::DropNewest
//! [`NetSnapshot`]: crate::coordinator::metrics::NetSnapshot

pub mod admission;
pub mod client;
pub mod server;
pub mod wire;

use std::sync::atomic::{AtomicU64, Ordering};

use crate::coordinator::metrics::NetSnapshot;

/// Front-door knobs, carried by `ServeConfig::net` (CLI `serve
/// --listen`). Tests build them directly.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Concurrent connection cap; further sockets are answered with a
    /// typed busy error and closed.
    pub conn_cap: usize,
    /// Admission queue capacity (frames between the connection threads
    /// and the front stage). Full = shed newest + retry-after.
    pub admit_cap: usize,
    /// Per-client token refill rate, tokens (= requests) per second.
    pub refill_per_s: f64,
    /// Per-client burst allowance (bucket capacity).
    pub burst: f64,
    /// Frame deadline: a frame still queued this long after arrival is
    /// shed at dequeue, not scored.
    pub deadline_ms: u64,
    /// Largest accepted frame body; bigger length prefixes are rejected
    /// before any allocation.
    pub max_frame: usize,
    /// Degraded mode engages when the admission-queue depth EWMA
    /// exceeds this fraction of `admit_cap`...
    pub degrade_hi: f64,
    /// ...and disengages when it falls back below this fraction
    /// (hysteresis so the mode doesn't flap).
    pub degrade_lo: f64,
    /// Top-k depth served while degraded (requests asking for more are
    /// shrunk to this).
    pub degraded_topk: usize,
    /// While degraded, answer Pair queries from the GED-bound heuristic
    /// scorer instead of the engine pipeline.
    pub ged_fallback: bool,
    /// Distinct client-id buckets tracked before new clients share the
    /// anonymous bucket (bounds table growth under adversarial ids).
    pub max_clients: usize,
    /// Socket read poll interval: how often an idle connection thread
    /// rechecks the shutdown flag.
    pub read_timeout_ms: u64,
    /// Idle-connection deadline: a connection that completes no frame
    /// for this long is closed and its conn-cap slot released, so
    /// silent connections can't pin the cap (and a mid-frame stall is
    /// bounded by the same clock, answered as a truncation).
    pub idle_timeout_ms: u64,
    /// Socket write timeout: a reader stalled longer than this loses
    /// its connection (never stalls sibling connections either way —
    /// connection-per-thread).
    pub write_timeout_ms: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            conn_cap: 64,
            admit_cap: 256,
            refill_per_s: 500.0,
            burst: 50.0,
            deadline_ms: 250,
            max_frame: 1 << 20,
            degrade_hi: 0.75,
            degrade_lo: 0.35,
            degraded_topk: 3,
            ged_fallback: true,
            max_clients: 10_000,
            read_timeout_ms: 50,
            idle_timeout_ms: 60_000,
            write_timeout_ms: 2_000,
        }
    }
}

/// Live front-door counters, shared by the connection threads and the
/// admission front stage; snapshotted into [`NetSnapshot`] at shutdown.
/// Relaxed atomics: statistics, not synchronization.
#[derive(Debug, Default)]
pub struct NetCounters {
    accepted: AtomicU64,
    throttled: AtomicU64,
    shed_deadline: AtomicU64,
    degraded: AtomicU64,
}

impl NetCounters {
    pub fn note_accepted(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }
    pub fn note_throttled(&self) {
        self.throttled.fetch_add(1, Ordering::Relaxed);
    }
    pub fn note_shed_deadline(&self) {
        self.shed_deadline.fetch_add(1, Ordering::Relaxed);
    }
    pub fn note_degraded(&self) {
        self.degraded.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> NetSnapshot {
        NetSnapshot {
            accepted: self.accepted.load(Ordering::Relaxed),
            throttled: self.throttled.load(Ordering::Relaxed),
            shed_deadline: self.shed_deadline.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_snapshot() {
        let c = NetCounters::default();
        c.note_accepted();
        c.note_accepted();
        c.note_throttled();
        c.note_shed_deadline();
        c.note_degraded();
        let s = c.snapshot();
        assert_eq!(
            (s.accepted, s.throttled, s.shed_deadline, s.degraded),
            (2, 1, 1, 1)
        );
    }

    #[test]
    fn default_hysteresis_is_ordered() {
        let cfg = NetConfig::default();
        assert!(cfg.degrade_lo < cfg.degrade_hi);
        assert!(cfg.max_frame >= 4096);
    }
}
